"""Layer-1 correctness: every Pallas engine kernel vs its pure-jnp oracle,
with hypothesis sweeping the engine parameter space. This is the CORE
correctness signal for the compute layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    add_engine,
    conv_engine,
    dwconv_engine,
    emul_engine,
    gelu_engine,
    layernorm_engine,
    mm_engine,
    mm_relu_engine,
    pool_engine,
    ref,
    relu_engine,
    softmax_engine,
)
from compile.kernels.mm import pick_block_k, vmem_footprint

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ----------------------------------------------------------------------
# matmul engine
# ----------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 2, 4, 8, 16]),
    k=st.sampled_from([4, 16, 64, 256, 784]),
    n=st.sampled_from([4, 10, 32, 128]),
)
def test_mm_engine_matches_ref(m, k, n):
    a, b = rand(m * 7 + k, m, k), rand(n * 13 + k, k, n)
    got = mm_engine(m, k, n)(a, b)
    want = ref.mm(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 4, 8]),
    k=st.sampled_from([16, 128]),
    n=st.sampled_from([8, 64]),
)
def test_mm_relu_engine_matches_ref(m, k, n):
    a, b = rand(m + k, m, k), rand(n + k, k, n)
    got = mm_relu_engine(m, k, n)(a, b)
    want = ref.mm_relu(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert (np.asarray(got) >= 0).all()


def test_mm_k_blocking_engages_for_large_k():
    # k = 784 fits a single block now; 1568 exercises the blocked grid.
    assert pick_block_k(784) == 784  # single pass since MAX_BLOCK_K=1024
    assert pick_block_k(1568) == 784  # blocked grid engages above the cap
    a, b = rand(1, 1, 1568), rand(2, 1568, 16)
    np.testing.assert_allclose(
        mm_engine(1, 1568, 16)(a, b), ref.mm(a, b), rtol=1e-4, atol=1e-4
    )


def test_vmem_footprint_bounded():
    # The largest engine in the default library must fit a 16 MiB VMEM.
    for (m, k, n) in [(1, 784, 128), (1, 400, 120), (8, 200, 784)]:
        assert vmem_footprint(m, k, n) < 16 * 1024 * 1024


# ----------------------------------------------------------------------
# elementwise engines
# ----------------------------------------------------------------------


@settings(**SETTINGS)
@given(w=st.sampled_from([4, 10, 32, 100, 128, 1600, 6272]))
def test_relu_engine_matches_ref(w):
    x = rand(w, w)
    np.testing.assert_allclose(relu_engine(w)(x), ref.relu(x), rtol=0, atol=0)


@settings(**SETTINGS)
@given(w=st.sampled_from([4, 10, 64, 128, 1600]))
def test_add_engine_matches_ref(w):
    x, y = rand(w, w), rand(w + 1, w)
    np.testing.assert_allclose(add_engine(w)(x, y), ref.add(x, y), rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(w=st.sampled_from([4, 10, 64, 128, 2048]))
def test_emul_engine_matches_ref(w):
    x, y = rand(w, w), rand(w + 1, w)
    np.testing.assert_allclose(emul_engine(w)(x, y), ref.emul(x, y), rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(w=st.sampled_from([4, 32, 128, 8192]))
def test_gelu_engine_matches_ref(w):
    x = rand(w + 2, w)
    np.testing.assert_allclose(gelu_engine(w)(x), ref.gelu(x), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# row-coupled normalization engines
# ----------------------------------------------------------------------


@settings(**SETTINGS)
@given(w=st.sampled_from([4, 16, 128]))
def test_softmax_engine_matches_ref(w):
    x = rand(w + 3, w)
    got = softmax_engine(w)(x)
    np.testing.assert_allclose(got, ref.softmax(x), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got).sum(), 1.0, rtol=1e-5)


@settings(**SETTINGS)
@given(w=st.sampled_from([4, 16, 128]))
def test_layernorm_engine_matches_ref(w):
    x = rand(w + 5, w)
    got = np.asarray(layernorm_engine(w)(x))
    np.testing.assert_allclose(got, ref.layernorm(x), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got.mean(), 0.0, atol=1e-5)


def test_relu_engine_edge_values():
    w = 8
    x = jnp.array([0.0, -0.0, 1e30, -1e30, jnp.inf, -jnp.inf, 1e-38, -1e-38], jnp.float32)
    got = np.asarray(relu_engine(w)(x))
    want = np.asarray(ref.relu(x))
    np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------------------
# conv / pool engines
# ----------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    c=st.sampled_from([1, 3, 8]),
    k=st.sampled_from([4, 8, 16]),
    kh=st.sampled_from([3, 5]),
    oh=st.sampled_from([4, 8, 10]),
    stride=st.sampled_from([1, 2]),
)
def test_conv_engine_matches_ref(c, k, kh, oh, stride):
    ow = oh
    ih = (oh - 1) * stride + kh
    x = rand(c * 31 + kh, c, ih, ih)
    w = rand(k * 17 + kh, k, c, kh, kh)
    got = conv_engine(oh, ow, c, k, kh, kh, stride)(x, w)
    want = ref.conv2d(x, w, stride)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    c=st.sampled_from([1, 8, 16]),
    kh=st.sampled_from([2, 3]),
    kw=st.sampled_from([2, 4]),
    oh=st.sampled_from([5, 7, 14]),
    stride=st.sampled_from([1, 2]),
)
def test_pool_engine_matches_ref(c, kh, kw, oh, stride):
    ow = oh
    ih = (oh - 1) * stride + kh
    iw = (ow - 1) * stride + kw
    x = rand(c * 3 + oh + kw, c, ih, iw)
    got = pool_engine(oh, ow, c, kh, kw, stride)(x)
    want = ref.maxpool2d(x, kh, kw, stride)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@settings(**SETTINGS)
@given(
    c=st.sampled_from([1, 4, 16]),
    kh=st.sampled_from([3, 5]),
    oh=st.sampled_from([4, 8, 14]),
    stride=st.sampled_from([1, 2]),
)
def test_dwconv_engine_matches_ref(c, kh, oh, stride):
    ow = oh
    ih = (oh - 1) * stride + kh
    x = rand(c * 11 + oh, c, ih, ih)
    w = rand(c * 5 + kh, c, kh, kh)
    got = dwconv_engine(oh, ow, c, kh, kh, stride)(x, w)
    want = ref.dwconv2d(x, w, stride)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv_engine_rectangular_kernel():
    # 3x1 and 1x5 kernels: the kh/kw distinction must reach im2col's patch
    # stride and the mm engine's ckk dimension.
    for kh, kw in [(3, 1), (1, 5)]:
        c, k, oh, stride = 3, 4, 6, 1
        ih = (oh - 1) * stride + kh
        iw = (oh - 1) * stride + kw
        x = rand(c * 7 + kh + kw, c, ih, iw)
        w = rand(k * 3 + kw, k, c, kh, kw)
        got = conv_engine(oh, oh, c, k, kh, kw, stride)(x, w)
        want = ref.conv2d(x, w, stride)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dwconv_engine_rectangular_kernel():
    c, oh, stride = 4, 5, 2
    kh, kw = 3, 5
    ih = (oh - 1) * stride + kh
    iw = (oh - 1) * stride + kw
    x = rand(17, c, ih, iw)
    w = rand(19, c, kh, kw)
    got = dwconv_engine(oh, oh, c, kh, kw, stride)(x, w)
    want = ref.dwconv2d(x, w, stride)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_im2col_matches_conv_identity():
    # The R4 rewrite identity at the numpy level.
    x = rand(3, 3, 8, 8)
    w = rand(4, 4, 3, 3, 3)
    direct = ref.conv2d(x, w, 1)
    via = ref.mm(w.reshape(4, 27), ref.im2col(x, 3, 3, 1)).reshape(4, 6, 6)
    np.testing.assert_allclose(direct, via, rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# engine split identities (the paper's rewrites, validated at the kernel
# level: big engine == schedule over small engines)
# ----------------------------------------------------------------------


def test_fig2_split_identity_on_kernels():
    x = rand(99, 128)
    whole = relu_engine(128)(x)
    halves = jnp.concatenate([relu_engine(64)(x[:64]), relu_engine(64)(x[64:])])
    np.testing.assert_array_equal(np.asarray(whole), np.asarray(halves))


def test_mm_k_split_identity_on_kernels():
    a, b = rand(1, 4, 16), rand(2, 16, 4)
    whole = mm_engine(4, 16, 4)(a, b)
    parts = mm_engine(4, 8, 4)(a[:, :8], b[:8]) + mm_engine(4, 8, 4)(a[:, 8:], b[8:])
    np.testing.assert_allclose(whole, parts, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_dtype_contract(dtype):
    # Engines are f32-in/f32-out by contract (the Rust runtime ships f32).
    out = mm_engine(2, 4, 2)(jnp.zeros((2, 4), dtype), jnp.zeros((4, 2), dtype))
    assert out.dtype == jnp.float32
