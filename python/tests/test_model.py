"""Layer-2 correctness: engine-composed forward passes vs pure-jnp
references, and shape contracts for the AOT artifacts."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def test_mlp_forward_matches_reference():
    params = model.init_mlp_params()
    x = jax.random.normal(jax.random.PRNGKey(42), (1, 784), jnp.float32)
    got = model.mlp_forward(params, x)
    want = model.mlp_reference(params, x)
    assert got.shape == (1, 10)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_lenet_forward_matches_reference():
    params = model.init_lenet_params()
    x = jax.random.normal(jax.random.PRNGKey(43), (1, 28, 28), jnp.float32)
    got = model.lenet_forward(params, x)
    want = model.lenet_reference(params, x)
    assert got.shape == (1, 10)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_mlp_forward_is_jittable():
    params = model.init_mlp_params()
    x = jnp.zeros((1, 784), jnp.float32)
    jitted = jax.jit(model.mlp_forward)
    np.testing.assert_allclose(jitted(params, x), model.mlp_forward(params, x), rtol=1e-5)


def test_mlp_relu_actually_clamps():
    # Guard against a silently-linear model: with strongly negative bias the
    # hidden layer must saturate at exactly zero.
    params = model.init_mlp_params()
    params = dict(params, fc1_b=params["fc1_b"] - 1000.0, fc2_b=params["fc2_b"] - 1000.0)
    x = jnp.ones((1, 784), jnp.float32) * 0.01
    out = model.mlp_forward(params, x)
    np.testing.assert_allclose(out, jnp.broadcast_to(params["fc3_b"], (1, 10)), rtol=1e-4)
