#!/usr/bin/env python3
"""Generate the checked-in ONNX test fixtures for the Rust importer.

Hand-encodes the protobuf wire format with the stdlib only (no `onnx`
package, no protoc) — mirroring the zero-dependency reader in
`rust/src/import/proto.rs`. Field numbers come from onnx/onnx.proto:

    ModelProto      ir_version=1 graph=7 opset_import=8
    GraphProto      node=1 name=2 initializer=5 input=11 output=12
    NodeProto       input=1 output=2 name=3 op_type=4 attribute=5
    AttributeProto  name=1 f=2 i=3 s=4 floats=7 ints=8 type=20
    TensorProto     dims=1 data_type=2 float_data=4 name=8 raw_data=9
    ValueInfoProto  name=1 type=2 -> tensor_type=1 -> elem_type=1 shape=2
                    -> dim=1 -> dim_value=1

Fixtures (all far under the 100 KB budget):

  mobilenet_slice.onnx   [1,3,112,112] -> Conv(3->8,k3,s2,SAME_UPPER) ->
                         Relu -> depthwise Conv(k3,s1,pad 1/side) -> Relu ->
                         1x1 Conv(8->16) -> Relu -> GlobalAveragePool
  attention_slice.onnx   [4,8] -> Gemm q/k/v (transB=1) -> Transpose(K) ->
                         MatMul -> Mul(1/sqrt(8)) -> Softmax -> MatMul
  unsupported_slice.onnx [1,3,8,8] -> Conv(dilations=2) -> HardSwish
                         (both intentionally outside the mapped subset; the
                         golden unsupported-op report test pins its output)

Run from the repo root:  python3 python/tests/gen_onnx_fixtures.py
"""

import math
import os
import struct

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures")

DT_FLOAT = 1

# ---- protobuf wire-format primitives --------------------------------------


def varint(n):
    n %= 1 << 64  # two's-complement for negative int64 (e.g. axis=-1)
    out = bytearray()
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def key(field, wire):
    return varint(field << 3 | wire)


def ld(field, payload):
    """Length-delimited field (strings, bytes, sub-messages)."""
    return key(field, 2) + varint(len(payload)) + payload


def s(field, text):
    return ld(field, text.encode())


def vint(field, n):
    return key(field, 0) + varint(n)


# ---- ONNX message builders ------------------------------------------------


def attr_int(name, v):
    return ld(5, s(1, name) + vint(3, v) + vint(20, 2))  # type INT


def attr_ints(name, vs):
    body = s(1, name) + b"".join(vint(8, v) for v in vs) + vint(20, 7)  # INTS
    return ld(5, body)


def attr_str(name, text):
    return ld(5, s(1, name) + s(4, text) + vint(20, 3))  # STRING


def node(op_type, name, inputs, outputs, attrs=b""):
    body = b"".join(s(1, i) for i in inputs)
    body += b"".join(s(2, o) for o in outputs)
    body += s(3, name) + s(4, op_type) + attrs
    return ld(1, body)  # GraphProto.node


def tensor(name, dims, values, raw=True):
    """Float32 initializer; `raw` picks raw_data vs float_data encoding so
    the fixtures exercise both decode paths in the Rust reader."""
    body = b"".join(vint(1, d) for d in dims) + vint(2, DT_FLOAT) + s(8, name)
    if raw:
        body += ld(9, struct.pack("<%df" % len(values), *values))
    else:
        body += b"".join(key(4, 5) + struct.pack("<f", v) for v in values)
    return ld(5, body)  # GraphProto.initializer


def value_info(name, dims, field=11):
    dim_msgs = b"".join(ld(1, vint(1, d)) for d in dims)  # shape.dim
    tensor_type = vint(1, DT_FLOAT) + ld(2, dim_msgs)
    ty = ld(1, tensor_type)  # TypeProto.tensor_type
    return ld(field, s(1, name) + ld(2, ty))


def model(graph_name, nodes, initializers, inputs, outputs):
    graph = b"".join(nodes) + s(2, graph_name) + b"".join(initializers)
    graph += b"".join(value_info(n, d, 11) for n, d in inputs)
    graph += b"".join(value_info(n, d, 12) for n, d in outputs)
    opset = ld(8, vint(2, 13))  # OperatorSetIdProto{version: 13}
    return vint(1, 8) + ld(7, graph) + opset  # ir_version=8


# ---- deterministic pseudo-weights (no numpy, reproducible forever) --------


def weights(n, seed):
    state = seed * 6364136223846793005 + 1442695040888963407
    out = []
    for _ in range(n):
        state = (state * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        out.append(((state >> 33) / float(1 << 31)) - 0.5)  # [-0.5, 0.5)
    return out


# ---- fixtures -------------------------------------------------------------


def mobilenet_slice():
    nodes = [
        node("Conv", "conv1", ["x", "conv1_w", "conv1_b"], ["t1"],
             attr_str("auto_pad", "SAME_UPPER") + attr_ints("strides", [2, 2])),
        node("Relu", "relu1", ["t1"], ["t2"]),
        node("Conv", "dwconv", ["t2", "dw_w", "dw_b"], ["t3"],
             attr_int("group", 8) + attr_ints("pads", [1, 1, 1, 1])
             + attr_ints("strides", [1, 1])),
        node("Relu", "relu2", ["t3"], ["t4"]),
        node("Conv", "pwconv", ["t4", "pw_w", "pw_b"], ["t5"]),
        node("Relu", "relu3", ["t5"], ["t6"]),
        node("GlobalAveragePool", "gap", ["t6"], ["y"]),
    ]
    inits = [
        tensor("conv1_w", [8, 3, 3, 3], weights(8 * 3 * 3 * 3, 1)),
        tensor("conv1_b", [8], weights(8, 2), raw=False),
        tensor("dw_w", [8, 1, 3, 3], weights(8 * 9, 3)),
        tensor("dw_b", [8], weights(8, 4)),
        tensor("pw_w", [16, 8, 1, 1], weights(16 * 8, 5)),
        tensor("pw_b", [16], weights(16, 6), raw=False),
    ]
    return model("mobilenet_slice", nodes, inits,
                 [("x", [1, 3, 112, 112])], [("y", [1, 16, 1, 1])])


def attention_slice():
    trans_b = attr_int("transB", 1)
    nodes = [
        node("Gemm", "proj_q", ["x", "wq", "bq"], ["q"], trans_b),
        node("Gemm", "proj_k", ["x", "wk", "bk"], ["k"], trans_b),
        node("Gemm", "proj_v", ["x", "wv", "bv"], ["v"], trans_b),
        node("Transpose", "kt", ["k"], ["k_t"], attr_ints("perm", [1, 0])),
        node("MatMul", "scores", ["q", "k_t"], ["sc"]),
        node("Mul", "scale", ["sc", "inv_sqrt_dh"], ["scs"]),
        node("Softmax", "probs", ["scs"], ["p"], attr_int("axis", -1)),
        node("MatMul", "context", ["p", "v"], ["y"]),
    ]
    inits = [
        tensor("wq", [8, 8], weights(64, 11)),
        tensor("bq", [8], weights(8, 12)),
        tensor("wk", [8, 8], weights(64, 13)),
        tensor("bk", [8], weights(8, 14)),
        tensor("wv", [8, 8], weights(64, 15)),
        tensor("bv", [8], weights(8, 16)),
        tensor("inv_sqrt_dh", [], [1.0 / math.sqrt(8.0)]),
    ]
    return model("attention_slice", nodes, inits, [("x", [4, 8])], [("y", [4, 8])])


def unsupported_slice():
    nodes = [
        node("Conv", "conv_dilated", ["x", "w"], ["t1"],
             attr_ints("dilations", [2, 2]) + attr_ints("pads", [2, 2, 2, 2])),
        node("HardSwish", "hswish_0", ["t1"], ["y"]),
    ]
    inits = [tensor("w", [4, 3, 3, 3], weights(4 * 27, 21))]
    return model("unsupported_slice", nodes, inits,
                 [("x", [1, 3, 8, 8])], [("y", [1, 4, 8, 8])])


def main():
    os.makedirs(OUT_DIR, exist_ok=True)
    for name, build in [
        ("mobilenet_slice.onnx", mobilenet_slice),
        ("attention_slice.onnx", attention_slice),
        ("unsupported_slice.onnx", unsupported_slice),
    ]:
        path = os.path.join(OUT_DIR, name)
        data = build()
        assert len(data) < 100_000, "%s exceeds the 100 KB fixture budget" % name
        with open(path, "wb") as f:
            f.write(data)
        print("wrote %s (%d bytes)" % (path, len(data)))


if __name__ == "__main__":
    main()
