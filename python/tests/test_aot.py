"""AOT path: engine specs resolve, lowering emits parseable HLO text, and
the manifest covers the default library."""

import os
import tempfile

import pytest

from compile import aot


def test_build_engine_all_kinds():
    for spec, want in [
        ("mm 1 784 128", "mm_1x784x128"),
        ("mmrelu 1 128 64", "mmrelu_1x128x64"),
        ("relu 128", "relu_128"),
        ("add 64", "add_64"),
        ("emul 64", "emul_64"),
        ("gelu 128", "gelu_128"),
        ("softmax 16", "softmax_16"),
        ("layernorm 128", "layernorm_128"),
        ("conv 28 28 1 8 5 5 1", "conv_28x28x1x8x5x5x1"),
        ("pool 14 14 8 2 4 2", "pool_14x14x8x2x4x2"),
        ("dwconv 8 8 16 3 3 2", "dwconv_8x8x16x3x3x2"),
    ]:
        name, fn, args = aot.build_engine(spec)
        assert name == want
        assert callable(fn)
        assert len(args) >= 1


def test_build_engine_rejects_unknown():
    with pytest.raises(ValueError):
        aot.build_engine("warp 16")


def test_emit_produces_hlo_text_with_entry():
    import jax

    with tempfile.TemporaryDirectory() as d:
        name, fn, args = aot.build_engine("relu 16")
        path = aot.emit(name, fn, args, d, force=True)
        text = open(path).read()
        assert "ENTRY" in text, "expected XLA HLO text"
        assert "f32[16]" in text
        # HLO text (not proto): must be plain ASCII-ish and parse line-wise.
        assert text.lstrip().startswith("HloModule")


def test_emit_skips_existing_unless_forced():
    with tempfile.TemporaryDirectory() as d:
        name, fn, args = aot.build_engine("relu 8")
        p1 = aot.emit(name, fn, args, d, force=True)
        stamp = os.path.getmtime(p1)
        p2 = aot.emit(name, fn, args, d, force=False)
        assert p1 == p2 and os.path.getmtime(p2) == stamp


def test_default_specs_cover_workload_initial_designs():
    names = [aot.build_engine(s)[0] for s in aot.DEFAULT_SPECS]
    for required in [
        "mm_1x784x128",
        "relu_128",
        "add_10",
        "conv_28x28x1x8x5x5x1",
        "pool_5x5x16x2x2x2",
        "mm_1x84x10",
        # transformer engines (attn_block / attn_block_mh4)
        "softmax_16",
        "layernorm_128",
        "gelu_8192",
        "emul_2048",
        "mm_16x128x16",
        "mm_16x32x16",
        # mobile engines (mobile_block / mobile_block_s2)
        "dwconv_14x14x16x3x3x1",
        "dwconv_8x8x16x3x3x2",
    ]:
        assert required in names


def test_default_specs_are_unique():
    names = [aot.build_engine(s)[0] for s in aot.DEFAULT_SPECS]
    assert len(names) == len(set(names))
