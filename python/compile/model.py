"""Layer 2: JAX forward passes for the workload library, composed from the
Layer-1 Pallas engine kernels.

Each function mirrors — by construction, layer for layer and weight name
for weight name — the corresponding Rust workload in
`rust/src/relay/workloads.rs`, so the end-to-end example can hand the same
parameters to both sides and compare numerics.

These graphs are what `aot.py` lowers to HLO text: jitted once at build
time, never traced at runtime.
"""

import jax
import jax.numpy as jnp

from .kernels import add_engine, conv_engine, mm_engine, pool_engine, relu_engine

# ----------------------------------------------------------------------
# Parameter initialization (deterministic; mirrors Tensor::random on the
# Rust side only in spirit — the e2e test ships actual arrays across).
# ----------------------------------------------------------------------


def init_mlp_params(key=None):
    """784 -> 128 -> 64 -> 10, names matching the Rust `mlp` workload."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    scale = 0.1
    return {
        "fc1_w": scale * jax.random.normal(ks[0], (784, 128), jnp.float32),
        "fc1_b": scale * jax.random.normal(ks[1], (128,), jnp.float32),
        "fc2_w": scale * jax.random.normal(ks[2], (128, 64), jnp.float32),
        "fc2_b": scale * jax.random.normal(ks[3], (64,), jnp.float32),
        "fc3_w": scale * jax.random.normal(ks[4], (64, 10), jnp.float32),
        "fc3_b": scale * jax.random.normal(ks[5], (10,), jnp.float32),
    }


def init_lenet_params(key=None):
    """Names matching the Rust `lenet` workload."""
    key = key if key is not None else jax.random.PRNGKey(1)
    ks = jax.random.split(key, 10)
    s = 0.1
    return {
        "c1_w": s * jax.random.normal(ks[0], (8, 1, 5, 5), jnp.float32),
        "c1_b": s * jax.random.normal(ks[1], (8,), jnp.float32),
        "c2_w": s * jax.random.normal(ks[2], (16, 8, 5, 5), jnp.float32),
        "c2_b": s * jax.random.normal(ks[3], (16,), jnp.float32),
        "fc1_w": s * jax.random.normal(ks[4], (400, 120), jnp.float32),
        "fc1_b": s * jax.random.normal(ks[5], (120,), jnp.float32),
        "fc2_w": s * jax.random.normal(ks[6], (120, 84), jnp.float32),
        "fc2_b": s * jax.random.normal(ks[7], (84,), jnp.float32),
        "fc3_w": s * jax.random.normal(ks[8], (84, 10), jnp.float32),
        "fc3_b": s * jax.random.normal(ks[9], (10,), jnp.float32),
    }


# ----------------------------------------------------------------------
# Engine-composed layers (the "initial design point": one full-size engine
# per call, exactly what lower::lower_default produces on the Rust side).
# ----------------------------------------------------------------------


def _dense_layer(x, w, b, apply_relu):
    m, k = x.shape
    n = w.shape[1]
    y = mm_engine(m, k, n)(x, w)
    flat = y.reshape(-1)
    bb = jnp.broadcast_to(b, (m, n)).reshape(-1)
    flat = add_engine(flat.shape[0])(flat, bb)
    if apply_relu:
        flat = relu_engine(flat.shape[0])(flat)
    return flat.reshape(m, n)


def mlp_forward(params, x):
    """MLP inference for one (1, 784) input."""
    h = _dense_layer(x, params["fc1_w"], params["fc1_b"], True)
    h = _dense_layer(h, params["fc2_w"], params["fc2_b"], True)
    return _dense_layer(h, params["fc3_w"], params["fc3_b"], False)


def _conv_layer(x, w, b, pad, stride):
    c, h, wd = x.shape
    k, _, kh, kw = w.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    oh = (x.shape[1] - kh) // stride + 1
    ow = (x.shape[2] - kw) // stride + 1
    y = conv_engine(oh, ow, c, k, kh, kw, stride)(x, w)
    flat = y.reshape(-1)
    bb = jnp.broadcast_to(b[:, None, None], y.shape).reshape(-1)
    flat = add_engine(flat.shape[0])(flat, bb)
    flat = relu_engine(flat.shape[0])(flat)
    return flat.reshape(k, oh, ow)


def lenet_forward(params, x):
    """LeNet inference for one (1, 28, 28) input."""
    h = _conv_layer(x, params["c1_w"], params["c1_b"], pad=2, stride=1)  # (8,28,28)
    h = pool_engine(14, 14, 8, 2, 2, 2)(h)  # (8,14,14)
    h = _conv_layer(h, params["c2_w"], params["c2_b"], pad=0, stride=1)  # (16,10,10)
    h = pool_engine(5, 5, 16, 2, 2, 2)(h)  # (16,5,5)
    h = h.reshape(1, 400)
    h = _dense_layer(h, params["fc1_w"], params["fc1_b"], True)
    h = _dense_layer(h, params["fc2_w"], params["fc2_b"], True)
    return _dense_layer(h, params["fc3_w"], params["fc3_b"], False)


# ----------------------------------------------------------------------
# Pure-jnp references (Layer-2 oracle, used by pytest).
# ----------------------------------------------------------------------


def mlp_reference(params, x):
    h = jnp.maximum(x @ params["fc1_w"] + params["fc1_b"], 0.0)
    h = jnp.maximum(h @ params["fc2_w"] + params["fc2_b"], 0.0)
    return h @ params["fc3_w"] + params["fc3_b"]


def lenet_reference(params, x):
    from .kernels import ref

    h = jnp.pad(x, ((0, 0), (2, 2), (2, 2)))
    h = jnp.maximum(ref.conv2d(h, params["c1_w"]) + params["c1_b"][:, None, None], 0.0)
    h = ref.maxpool2d(h, 2, 2, 2)
    h = jnp.maximum(ref.conv2d(h, params["c2_w"]) + params["c2_b"][:, None, None], 0.0)
    h = ref.maxpool2d(h, 2, 2, 2)
    h = h.reshape(1, 400)
    h = jnp.maximum(h @ params["fc1_w"] + params["fc1_b"], 0.0)
    h = jnp.maximum(h @ params["fc2_w"] + params["fc2_b"], 0.0)
    return h @ params["fc3_w"] + params["fc3_b"]
