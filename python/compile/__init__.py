"""Build-time Python package: Layer-1 Pallas engine kernels, the Layer-2
JAX workload models, and the AOT lowering that emits `artifacts/*.hlo.txt`
for the Rust runtime. Never imported on the request path."""
