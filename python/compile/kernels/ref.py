"""Pure-jnp reference oracles for every engine kernel.

These are the *specification*: each Pallas kernel in this package must be
allclose to its oracle (pytest enforces it across a hypothesis sweep of
shapes), and the Rust-side evaluator mirrors the same semantics.
"""

import jax.numpy as jnp


def mm(a, b):
    """(m,k) @ (k,n) -> (m,n), f32 accumulate."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def mm_relu(a, b):
    """Fused matmul + ReLU epilogue."""
    return jnp.maximum(mm(a, b), 0.0)


def relu(x):
    """Elementwise ReLU on a flat vector."""
    return jnp.maximum(x, 0.0)


def add(x, y):
    """Elementwise add on flat vectors."""
    return x + y


def conv2d(x, w, stride=1):
    """Valid (pre-padded) conv: x:(C,H,W), w:(K,C,KH,KW) -> (K,OH,OW)."""
    c, h, wd = x.shape
    k, c2, kh, kw = w.shape
    assert c == c2
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    # im2col formulation (the same identity rewrite R4 uses).
    cols = im2col(x, kh, kw, stride)  # (c*kh*kw, oh*ow)
    wmat = w.reshape(k, c * kh * kw)
    return mm(wmat, cols).reshape(k, oh, ow)


def im2col(x, kh, kw, stride=1):
    """(C,H,W) -> (C*KH*KW, OH*OW) patch matrix (row-major patch order)."""
    c, h, w = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    rows = []
    for ci in range(c):
        for dy in range(kh):
            for dx in range(kw):
                patch = x[ci, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
                rows.append(patch.reshape(-1))
    return jnp.stack(rows)


def maxpool2d(x, kh, kw, stride):
    """(C,H,W) max pool over a rectangular ``kh``x``kw`` window."""
    c, h, w = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    out = jnp.full((c, oh, ow), -jnp.inf, dtype=x.dtype)
    for dy in range(kh):
        for dx in range(kw):
            out = jnp.maximum(
                out, x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
            )
    return out


def emul(x, y):
    """Elementwise multiply on flat vectors."""
    return x * y


def gelu(x):
    """GELU, tanh approximation — mirrors the Rust oracle exactly."""
    c = 0.7978845608028654  # sqrt(2/pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def softmax(x):
    """Numerically-stable softmax over the last axis."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layernorm(x, eps=1e-5):
    """Non-affine layernorm over the last axis (population variance)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


def dwconv2d(x, w, stride=1):
    """Depthwise valid conv: x:(C,H,W), w:(C,KH,KW) -> (C,OH,OW)."""
    c, h, wd = x.shape
    c2, kh, kw = w.shape
    assert c == c2
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    out = jnp.zeros((c, oh, ow), x.dtype)
    for dy in range(kh):
        for dx in range(kw):
            out = out + (
                w[:, dy, dx][:, None, None]
                * x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
            )
    return out
