"""Pure-jnp reference oracles for every engine kernel.

These are the *specification*: each Pallas kernel in this package must be
allclose to its oracle (pytest enforces it across a hypothesis sweep of
shapes), and the Rust-side evaluator mirrors the same semantics.
"""

import jax.numpy as jnp


def mm(a, b):
    """(m,k) @ (k,n) -> (m,n), f32 accumulate."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def mm_relu(a, b):
    """Fused matmul + ReLU epilogue."""
    return jnp.maximum(mm(a, b), 0.0)


def relu(x):
    """Elementwise ReLU on a flat vector."""
    return jnp.maximum(x, 0.0)


def add(x, y):
    """Elementwise add on flat vectors."""
    return x + y


def conv2d(x, w, stride=1):
    """Valid (pre-padded) conv: x:(C,H,W), w:(K,C,KH,KW) -> (K,OH,OW)."""
    c, h, wd = x.shape
    k, c2, kh, kw = w.shape
    assert c == c2
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    # im2col formulation (the same identity rewrite R4 uses).
    cols = im2col(x, kh, kw, stride)  # (c*kh*kw, oh*ow)
    wmat = w.reshape(k, c * kh * kw)
    return mm(wmat, cols).reshape(k, oh, ow)


def im2col(x, kh, kw, stride=1):
    """(C,H,W) -> (C*KH*KW, OH*OW) patch matrix (row-major patch order)."""
    c, h, w = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    rows = []
    for ci in range(c):
        for dy in range(kh):
            for dx in range(kw):
                patch = x[ci, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
                rows.append(patch.reshape(-1))
    return jnp.stack(rows)


def maxpool2d(x, k, stride):
    """(C,H,W) max pool."""
    c, h, w = x.shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    out = jnp.full((c, oh, ow), -jnp.inf, dtype=x.dtype)
    for dy in range(k):
        for dx in range(k):
            out = jnp.maximum(
                out, x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
            )
    return out
