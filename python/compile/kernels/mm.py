"""The matmul engine (Layer 1): a Pallas kernel computing a fixed-size
``(m,k) @ (k,n)`` — the paper's `mm-engine M K N` hardware unit.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): the engine targets
the MXU systolic array, so the kernel is expressed as a K-blocked
accumulation whose BlockSpecs describe the HBM->VMEM streaming schedule;
block sizes are chosen to bound the VMEM working set (see
``vmem_footprint``). On this image Pallas must run ``interpret=True``
(CPU PJRT cannot execute Mosaic custom-calls), so the kernel's *structure*
— not its wallclock — is what carries the performance claims; real-TPU
efficiency is estimated analytically in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Upper bound on the K-block so a (m, bk) + (bk, n) + (m, n) working set
# stays comfortably inside a ~16 MiB VMEM budget for the engine sizes the
# workload library produces. Perf iteration (EXPERIMENTS.md §Perf): raised
# 512 -> 1024 so every engine in the default library runs as a single
# K-pass (the (1,784,128) engine previously split into a 2-step grid whose
# accumulate round-trip dominated); worst-case working set at 1024 is
# 4*(16*1024 + 1024*128 + 16*128) ≈ 0.6 MiB — far under budget.
MAX_BLOCK_K = 1024


def pick_block_k(k: int) -> int:
    """Largest divisor of ``k`` that is <= MAX_BLOCK_K (k itself if small)."""
    if k <= MAX_BLOCK_K:
        return k
    for bk in range(MAX_BLOCK_K, 0, -1):
        if k % bk == 0:
            return bk
    return 1  # unreachable: 1 divides k


def vmem_footprint(m: int, k: int, n: int) -> int:
    """Bytes of VMEM the kernel holds live per grid step (f32)."""
    bk = pick_block_k(k)
    return 4 * (m * bk + bk * n + m * n)


def _mm_kernel(a_ref, b_ref, o_ref):
    kidx = pl.program_id(0)

    @pl.when(kidx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.matmul(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _mm_relu_kernel(a_ref, b_ref, o_ref):
    kidx = pl.program_id(0)
    nk = pl.num_programs(0)

    @pl.when(kidx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.matmul(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(kidx == nk - 1)
    def _epilogue():
        o_ref[...] = jnp.maximum(o_ref[...], 0.0)


def _build(kernel_body, m: int, k: int, n: int):
    bk = pick_block_k(k)
    grid = (k // bk,)
    return pl.pallas_call(
        kernel_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, bk), lambda kk: (0, kk)),
            pl.BlockSpec((bk, n), lambda kk: (kk, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda kk: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )


@functools.lru_cache(maxsize=None)
def mm_engine(m: int, k: int, n: int):
    """The `(mm-engine m k n)` hardware unit as a callable ``(a, b) -> out``."""
    return _build(_mm_kernel, m, k, n)


@functools.lru_cache(maxsize=None)
def mm_relu_engine(m: int, k: int, n: int):
    """The fused `(mm-relu-engine m k n)` unit (rewrite R7's target)."""
    return _build(_mm_relu_kernel, m, k, n)
