"""Vector elementwise engines (Layer 1): the paper Fig. 2 `relu-engine W`,
the `add-engine W` used by reified bias/residual adds, the `emul-engine W`
carrying affine layernorm's gamma scale, and the `gelu-engine W` behind
the transformer FFN activation.

These map to the TPU VPU (8x128 vector lanes): the BlockSpec streams the
flat vector through VMEM in lane-aligned chunks. Width is the engine's
*hardware* parameter — rewrites shrink/grow it, which on real hardware is
the number of physical lanes instantiated.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chunk bound: keeps the VMEM working set small for very wide engines.
MAX_BLOCK_W = 4096


def pick_block_w(w: int) -> int:
    if w <= MAX_BLOCK_W:
        return w
    for bw in range(MAX_BLOCK_W, 0, -1):
        if w % bw == 0:
            return bw
    return 1


def _relu_kernel(x_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...], 0.0)


def _add_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def _emul_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] * y_ref[...]


def _gelu_kernel(x_ref, o_ref):
    x = x_ref[...]
    c = 0.7978845608028654  # sqrt(2/pi)
    o_ref[...] = 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


@functools.lru_cache(maxsize=None)
def relu_engine(w: int):
    """The `(relu-engine w)` unit as a callable ``x -> relu(x)``."""
    return _unary(_relu_kernel, w)


@functools.lru_cache(maxsize=None)
def add_engine(w: int):
    """The `(add-engine w)` unit as a callable ``(x, y) -> x + y``."""
    return _binary(_add_kernel, w)


@functools.lru_cache(maxsize=None)
def emul_engine(w: int):
    """The `(emul-engine w)` unit as a callable ``(x, y) -> x * y``."""
    return _binary(_emul_kernel, w)


@functools.lru_cache(maxsize=None)
def gelu_engine(w: int):
    """The `(gelu-engine w)` unit as a callable ``x -> gelu(x)``."""
    return _unary(_gelu_kernel, w)


def _unary(kernel_body, w: int):
    bw = pick_block_w(w)
    return pl.pallas_call(
        kernel_body,
        grid=(w // bw,),
        in_specs=[pl.BlockSpec((bw,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.float32),
        interpret=True,
    )


def _binary(kernel_body, w: int):
    bw = pick_block_w(w)
    return pl.pallas_call(
        kernel_body,
        grid=(w // bw,),
        in_specs=[
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((bw,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.float32),
        interpret=True,
    )
