"""Vector elementwise engines (Layer 1): the paper Fig. 2 `relu-engine W`
and the `add-engine W` used by reified bias/residual adds.

These map to the TPU VPU (8x128 vector lanes): the BlockSpec streams the
flat vector through VMEM in lane-aligned chunks. Width is the engine's
*hardware* parameter — rewrites shrink/grow it, which on real hardware is
the number of physical lanes instantiated.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chunk bound: keeps the VMEM working set small for very wide engines.
MAX_BLOCK_W = 4096


def pick_block_w(w: int) -> int:
    if w <= MAX_BLOCK_W:
        return w
    for bw in range(MAX_BLOCK_W, 0, -1):
        if w % bw == 0:
            return bw
    return 1


def _relu_kernel(x_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...], 0.0)


def _add_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


@functools.lru_cache(maxsize=None)
def relu_engine(w: int):
    """The `(relu-engine w)` unit as a callable ``x -> relu(x)``."""
    bw = pick_block_w(w)
    return pl.pallas_call(
        _relu_kernel,
        grid=(w // bw,),
        in_specs=[pl.BlockSpec((bw,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.float32),
        interpret=True,
    )


@functools.lru_cache(maxsize=None)
def add_engine(w: int):
    """The `(add-engine w)` unit as a callable ``(x, y) -> x + y``."""
    bw = pick_block_w(w)
    return pl.pallas_call(
        _add_kernel,
        grid=(w // bw,),
        in_specs=[
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((bw,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.float32),
        interpret=True,
    )
