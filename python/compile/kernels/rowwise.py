"""Row-coupled normalization engines (Layer 1): `softmax-engine W` and
`layernorm-engine W`.

Unlike the vector engines, the row statistics (max/sum or mean/variance)
couple every lane, so the whole row lives in one VMEM block and there is
deliberately no width-blocked grid — mirroring the Rust side, where these
engines carry no `split-*` rewrite (the registry's documented exemptions).
The schedule dimension is the *row loop around* the engine, which the
`parallelize` rewrite replicates.

`layernorm-engine` is non-affine by contract: the EngineIR lowering runs
the gamma/beta affine tail on `emul-engine` / `add-engine` invocations.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-5  # matches the Rust oracle's layernorm epsilon


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e)


def _layernorm_kernel(x_ref, o_ref):
    x = x_ref[...]
    mu = jnp.mean(x)
    var = jnp.mean((x - mu) ** 2)
    o_ref[...] = (x - mu) / jnp.sqrt(var + EPS)


def _row_unit(kernel_body, w: int):
    return pl.pallas_call(
        kernel_body,
        grid=(1,),
        in_specs=[pl.BlockSpec((w,), lambda i: (0,))],
        out_specs=pl.BlockSpec((w,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.float32),
        interpret=True,
    )


@functools.lru_cache(maxsize=None)
def softmax_engine(w: int):
    """The `(softmax-engine w)` row unit: `(w,) -> (w,)`."""
    return _row_unit(_softmax_kernel, w)


@functools.lru_cache(maxsize=None)
def layernorm_engine(w: int):
    """The `(layernorm-engine w)` row unit (non-affine): `(w,) -> (w,)`."""
    return _row_unit(_layernorm_kernel, w)
