"""Layer-1 Pallas engine kernels.

Each function here is one *hardware engine declaration* from EngineIR,
parameterized exactly like the Rust `Op` variants:

- ``mm.mm_engine(m, k, n)``           — `(mm-engine m k n)`
- ``mm.mm_relu_engine(m, k, n)``      — `(mm-relu-engine m k n)`
- ``elementwise.relu_engine(w)``      — `(relu-engine w)`
- ``elementwise.add_engine(w)``       — `(add-engine w)`
- ``elementwise.emul_engine(w)``      — `(emul-engine w)`
- ``elementwise.gelu_engine(w)``      — `(gelu-engine w)`
- ``rowwise.softmax_engine(w)``       — `(softmax-engine w)`
- ``rowwise.layernorm_engine(w)``     — `(layernorm-engine w)`
- ``conv.conv_engine(oh,ow,c,k,kh,kw,s)``— `(conv-engine oh ow c k kh kw s)`
- ``conv.pool_engine(oh,ow,c,kh,kw,s)``  — `(pool-engine oh ow c kh kw s)`
- ``conv.dwconv_engine(oh,ow,c,kh,kw,s)``— `(dw-conv-engine oh ow c kh kw s)`

``ref`` holds the pure-jnp oracles the kernels are tested against.
"""

from . import conv, elementwise, mm, ref, rowwise  # noqa: F401
from .conv import conv_engine, dwconv_engine, pool_engine  # noqa: F401
from .elementwise import add_engine, emul_engine, gelu_engine, relu_engine  # noqa: F401
from .mm import mm_engine, mm_relu_engine  # noqa: F401
from .rowwise import layernorm_engine, softmax_engine  # noqa: F401
