"""Layer-1 Pallas engine kernels.

Each function here is one *hardware engine declaration* from EngineIR,
parameterized exactly like the Rust `Op` variants:

- ``mm.mm_engine(m, k, n)``           — `(mm-engine m k n)`
- ``mm.mm_relu_engine(m, k, n)``      — `(mm-relu-engine m k n)`
- ``elementwise.relu_engine(w)``      — `(relu-engine w)`
- ``elementwise.add_engine(w)``       — `(add-engine w)`
- ``conv.conv_engine(oh,ow,c,k,kh,kw,s)``— `(conv-engine oh ow c k kh kw s)`
- ``conv.pool_engine(oh,ow,c,k,s)``   — `(pool-engine oh ow c k s)`

``ref`` holds the pure-jnp oracles the kernels are tested against.
"""

from . import conv, elementwise, mm, ref  # noqa: F401
from .conv import conv_engine, pool_engine  # noqa: F401
from .elementwise import add_engine, relu_engine  # noqa: F401
from .mm import mm_engine, mm_relu_engine  # noqa: F401
