"""Convolution and pooling engines (Layer 1).

`conv-engine` is realized as im2col staging + the Pallas matmul engine —
the same algebraic identity as rewrite R4 (`conv-as-im2col-mm`), which is
also how TPUs actually execute convolutions on the MXU. The im2col gather
is the HBM->VMEM staging step; the MACs all run in the mm kernel.

`pool-engine` is a Pallas kernel over channel blocks: each grid step loads
one channel tile of the input window into VMEM and reduces the kh*kw
shifted views with `jnp.maximum` (VPU work, no MXU); windows are
rectangular like conv kernels.

`dwconv-engine` follows the same per-channel grid: each step multiplies
kh*kw shifted input views by its channel's kernel taps and accumulates
(depthwise conv has no cross-channel reduction, so no MXU either).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .mm import mm_engine


@functools.lru_cache(maxsize=None)
def conv_engine(oh: int, ow: int, c: int, k: int, kh: int, kw: int, stride: int):
    """The `(conv-engine oh ow c k kh kw stride)` unit.

    Callable ``(x:(c,ih,iw), w:(k,c,kh,kw)) -> (k,oh,ow)`` with
    ``ih = (oh-1)*stride + kh`` (valid conv over a pre-padded tile).
    Kernels are rectangular; ``kw`` is required so stale square-kernel
    positional calls fail loudly instead of silently binding stride to kw.
    """
    ckk = c * kh * kw
    mm = mm_engine(k, ckk, oh * ow)

    def run(x, w):
        cols = ref.im2col(x, kh, kw, stride)  # staging (data movement)
        wmat = w.reshape(k, ckk)
        return mm(wmat, cols).reshape(k, oh, ow)

    return run


def _pool_kernel(x_ref, o_ref, *, kh, kw, stride, oh, ow):
    x = x_ref[...]  # (bc, ih, iw)
    out = jnp.full((x.shape[0], oh, ow), -jnp.inf, dtype=x.dtype)
    for dy in range(kh):
        for dx in range(kw):
            out = jnp.maximum(
                out, x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
            )
    o_ref[...] = out


@functools.lru_cache(maxsize=None)
def pool_engine(oh: int, ow: int, c: int, kh: int, kw: int, stride: int):
    """The `(pool-engine oh ow c kh kw stride)` unit: `(c,ih,iw) -> (c,oh,ow)`.

    Windows are rectangular; ``kw`` is required so stale square-window
    positional calls fail loudly instead of binding stride to kw.
    """
    ih = (oh - 1) * stride + kh
    iw = (ow - 1) * stride + kw
    # One channel per grid step keeps the VMEM tile minimal; channels are
    # independent so this is also the natural split axis in hardware.
    body = functools.partial(_pool_kernel, kh=kh, kw=kw, stride=stride, oh=oh, ow=ow)
    return pl.pallas_call(
        body,
        grid=(c,),
        in_specs=[pl.BlockSpec((1, ih, iw), lambda ci: (ci, 0, 0))],
        out_specs=pl.BlockSpec((1, oh, ow), lambda ci: (ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), jnp.float32),
        interpret=True,
    )


def _dwconv_kernel(x_ref, w_ref, o_ref, *, kh, kw, stride, oh, ow):
    x = x_ref[...]  # (1, ih, iw)
    w = w_ref[...]  # (1, kh, kw)
    acc = jnp.zeros((x.shape[0], oh, ow), x.dtype)
    for dy in range(kh):
        for dx in range(kw):
            acc = acc + (
                w[:, dy, dx][:, None, None]
                * x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
            )
    o_ref[...] = acc


@functools.lru_cache(maxsize=None)
def dwconv_engine(oh: int, ow: int, c: int, kh: int, kw: int, stride: int):
    """The `(dw-conv-engine oh ow c kh kw stride)` unit.

    Callable ``(x:(c,ih,iw), w:(c,kh,kw)) -> (c,oh,ow)`` with
    ``ih = (oh-1)*stride + kh`` (valid conv over a pre-padded tile).
    """
    ih = (oh - 1) * stride + kh
    iw = (ow - 1) * stride + kw
    body = functools.partial(_dwconv_kernel, kh=kh, kw=kw, stride=stride, oh=oh, ow=ow)
    return pl.pallas_call(
        body,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, ih, iw), lambda ci: (ci, 0, 0)),
            pl.BlockSpec((1, kh, kw), lambda ci: (ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow), lambda ci: (ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), jnp.float32),
        interpret=True,
    )
