"""Convolution and pooling engines (Layer 1).

`conv-engine` is realized as im2col staging + the Pallas matmul engine —
the same algebraic identity as rewrite R4 (`conv-as-im2col-mm`), which is
also how TPUs actually execute convolutions on the MXU. The im2col gather
is the HBM->VMEM staging step; the MACs all run in the mm kernel.

`pool-engine` is a Pallas kernel over channel blocks: each grid step loads
one channel tile of the input window into VMEM and reduces the k*k
shifted views with `jnp.maximum` (VPU work, no MXU).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .mm import mm_engine


@functools.lru_cache(maxsize=None)
def conv_engine(oh: int, ow: int, c: int, k: int, kh: int, kw: int, stride: int):
    """The `(conv-engine oh ow c k kh kw stride)` unit.

    Callable ``(x:(c,ih,iw), w:(k,c,kh,kw)) -> (k,oh,ow)`` with
    ``ih = (oh-1)*stride + kh`` (valid conv over a pre-padded tile).
    Kernels are rectangular; ``kw`` is required so stale square-kernel
    positional calls fail loudly instead of silently binding stride to kw.
    """
    ckk = c * kh * kw
    mm = mm_engine(k, ckk, oh * ow)

    def run(x, w):
        cols = ref.im2col(x, kh, kw, stride)  # staging (data movement)
        wmat = w.reshape(k, ckk)
        return mm(wmat, cols).reshape(k, oh, ow)

    return run


def _pool_kernel(x_ref, o_ref, *, k, stride, oh, ow):
    x = x_ref[...]  # (bc, ih, iw)
    out = jnp.full((x.shape[0], oh, ow), -jnp.inf, dtype=x.dtype)
    for dy in range(k):
        for dx in range(k):
            out = jnp.maximum(
                out, x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
            )
    o_ref[...] = out


@functools.lru_cache(maxsize=None)
def pool_engine(oh: int, ow: int, c: int, k: int, stride: int):
    """The `(pool-engine oh ow c k stride)` unit: `(c,ih,iw) -> (c,oh,ow)`."""
    ih = (oh - 1) * stride + k
    iw = (ow - 1) * stride + k
    # One channel per grid step keeps the VMEM tile minimal; channels are
    # independent so this is also the natural split axis in hardware.
    body = functools.partial(_pool_kernel, k=k, stride=stride, oh=oh, ow=ow)
    return pl.pallas_call(
        body,
        grid=(c,),
        in_specs=[pl.BlockSpec((1, ih, iw), lambda ci: (ci, 0, 0))],
        out_specs=pl.BlockSpec((1, oh, ow), lambda ci: (ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), jnp.float32),
        interpret=True,
    )
