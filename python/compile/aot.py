"""AOT lowering: Layer-1/2 JAX programs -> HLO *text* artifacts for the
Rust PJRT runtime.

Run once at build time (`make artifacts`); the Rust binary is self-contained
afterwards. Python never executes on the request path.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` crate links) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifact naming (must match `rust/src/runtime/mod.rs::artifact_name`;
every Engine-class op has a contract — the Rust registry test pins it):

    mm_{m}x{k}x{n}.hlo.txt
    mmrelu_{m}x{k}x{n}.hlo.txt
    relu_{w}.hlo.txt
    add_{w}.hlo.txt
    emul_{w}.hlo.txt
    gelu_{w}.hlo.txt
    softmax_{w}.hlo.txt
    layernorm_{w}.hlo.txt
    conv_{oh}x{ow}x{c}x{k}x{kh}x{kw}x{s}.hlo.txt
    pool_{oh}x{ow}x{c}x{kh}x{kw}x{s}.hlo.txt
    dwconv_{oh}x{ow}x{c}x{kh}x{kw}x{s}.hlo.txt
    model_mlp.hlo.txt                      (full Layer-2 forward)

`manifest.txt` lists every emitted artifact (one name per line); the Rust
runtime reads it to know what is available.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import (
    add_engine,
    conv_engine,
    dwconv_engine,
    emul_engine,
    gelu_engine,
    layernorm_engine,
    mm_engine,
    mm_relu_engine,
    pool_engine,
    relu_engine,
    softmax_engine,
)


def to_hlo_text(lowered) -> str:
    """jax.jit(...).lower(...) -> XLA HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# ----------------------------------------------------------------------
# Engine spec registry: spec string -> (artifact name, fn, example args)
# ----------------------------------------------------------------------


def build_engine(spec: str):
    """Parse an engine spec like 'mm 1 784 128' into (name, fn, args)."""
    parts = spec.split()
    kind, params = parts[0], [int(p) for p in parts[1:]]
    if kind == "mm":
        m, k, n = params
        return f"mm_{m}x{k}x{n}", mm_engine(m, k, n), (f32(m, k), f32(k, n))
    if kind == "mmrelu":
        m, k, n = params
        return f"mmrelu_{m}x{k}x{n}", mm_relu_engine(m, k, n), (f32(m, k), f32(k, n))
    if kind == "relu":
        (w,) = params
        return f"relu_{w}", relu_engine(w), (f32(w),)
    if kind == "add":
        (w,) = params
        return f"add_{w}", add_engine(w), (f32(w), f32(w))
    if kind == "emul":
        (w,) = params
        return f"emul_{w}", emul_engine(w), (f32(w), f32(w))
    if kind == "gelu":
        (w,) = params
        return f"gelu_{w}", gelu_engine(w), (f32(w),)
    if kind == "softmax":
        (w,) = params
        return f"softmax_{w}", softmax_engine(w), (f32(w),)
    if kind == "layernorm":
        (w,) = params
        return f"layernorm_{w}", layernorm_engine(w), (f32(w),)
    if kind == "conv":
        oh, ow, c, k, kh, kw, s = params
        ih, iw = (oh - 1) * s + kh, (ow - 1) * s + kw
        return (
            f"conv_{oh}x{ow}x{c}x{k}x{kh}x{kw}x{s}",
            conv_engine(oh, ow, c, k, kh, kw, s),
            (f32(c, ih, iw), f32(k, c, kh, kw)),
        )
    if kind == "pool":
        oh, ow, c, kh, kw, s = params
        ih, iw = (oh - 1) * s + kh, (ow - 1) * s + kw
        return (
            f"pool_{oh}x{ow}x{c}x{kh}x{kw}x{s}",
            pool_engine(oh, ow, c, kh, kw, s),
            (f32(c, ih, iw),),
        )
    if kind == "dwconv":
        oh, ow, c, kh, kw, s = params
        ih, iw = (oh - 1) * s + kh, (ow - 1) * s + kw
        return (
            f"dwconv_{oh}x{ow}x{c}x{kh}x{kw}x{s}",
            dwconv_engine(oh, ow, c, kh, kw, s),
            (f32(c, ih, iw), f32(c, kh, kw)),
        )
    raise ValueError(f"unknown engine spec: {spec!r}")


# The default engine library: every engine in the *initial* (one engine per
# call site) designs of the `mlp` and `lenet` workloads, plus a set of split
# variants so the e2e example can also run a rewritten design, plus the
# transformer (`attn_block`/`attn_block_mh4`) and mobile
# (`mobile_block`/`mobile_block_s2`) engines.
DEFAULT_SPECS = [
    # mlp initial design
    "mm 1 784 128",
    "add 128",
    "relu 128",
    "mm 1 128 64",
    "add 64",
    "relu 64",
    "mm 1 64 10",
    "add 10",
    # mlp split variants (k-split fc1, n-split fc1/fc2, narrow elementwise)
    "mm 1 392 128",
    "mm 1 784 64",
    "mm 1 128 32",
    "mm 1 64 32",
    "relu 32",
    "add 32",
    "mmrelu 1 128 64",
    # lenet initial design
    "conv 28 28 1 8 5 5 1",
    "add 6272",
    "relu 6272",
    "pool 14 14 8 2 2 2",
    "conv 10 10 8 16 5 5 1",
    "add 1600",
    "relu 1600",
    "pool 5 5 16 2 2 2",
    "mm 1 400 120",
    "add 120",
    "relu 120",
    "mm 1 120 84",
    "add 84",
    "relu 84",
    "mm 1 84 10",
    # lenet split variants (channel-split conv2, row-split pool1)
    "conv 10 10 8 8 5 5 1",
    "pool 7 14 8 2 2 2",
    # attn_block / attn_block_mh4 initial designs (seq 16, hidden 128,
    # FFN 512, 4 heads of width 32): projection/FFN matmuls, single-head
    # and per-head score/context matmuls, row engines, GELU, and the
    # affine-layernorm emul/add tail.
    "mm 16 128 128",
    "mm 16 128 512",
    "mm 16 512 128",
    "mm 16 128 16",
    "mm 16 16 128",
    "mm 16 32 16",
    "mm 16 16 32",
    "add 2048",
    "add 8192",
    "emul 2048",
    "gelu 8192",
    "softmax 16",
    "layernorm 128",
    # mobile_block / mobile_block_s2 initial designs (add 6272 / relu 6272
    # and add 2048 are shared with entries above)
    "dwconv 14 14 16 3 3 1",
    "dwconv 8 8 16 3 3 2",
    "conv 14 14 16 32 1 1 1",
    "conv 8 8 16 32 1 1 1",
    "add 3136",
    "relu 3136",
    "add 1024",
    "relu 1024",
    "relu 2048",
]

# The MLP parameter order for the full-model artifact (documented contract
# with rust/src/runtime: inputs are [x, fc1_w, fc1_b, fc2_w, fc2_b, fc3_w,
# fc3_b] in this exact order).
MLP_PARAM_ORDER = ["fc1_w", "fc1_b", "fc2_w", "fc2_b", "fc3_w", "fc3_b"]


def mlp_flat(x, fc1_w, fc1_b, fc2_w, fc2_b, fc3_w, fc3_b):
    params = {
        "fc1_w": fc1_w,
        "fc1_b": fc1_b,
        "fc2_w": fc2_w,
        "fc2_b": fc2_b,
        "fc3_w": fc3_w,
        "fc3_b": fc3_b,
    }
    return model.mlp_forward(params, x)


def model_artifacts():
    """Full Layer-2 model artifacts: (name, fn, example args)."""
    mlp_args = (
        f32(1, 784),
        f32(784, 128),
        f32(128,),
        f32(128, 64),
        f32(64,),
        f32(64, 10),
        f32(10,),
    )
    return [("model_mlp", mlp_flat, mlp_args)]


def emit(name: str, fn, args, out_dir: str, force: bool) -> str:
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    if not force and os.path.exists(path):
        return path
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--specs", help="file with one engine spec per line (default: built-in set)")
    ap.add_argument("--force", action="store_true", help="re-lower even if the file exists")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    specs = DEFAULT_SPECS
    if args.specs:
        with open(args.specs) as f:
            specs = [l.strip() for l in f if l.strip() and not l.startswith("#")]

    names = []
    for spec in specs:
        name, fn, ex = build_engine(spec)
        emit(name, fn, ex, args.out_dir, args.force)
        names.append(name)
        print(f"  engine {name}")
    for name, fn, ex in model_artifacts():
        emit(name, fn, ex, args.out_dir, args.force)
        names.append(name)
        print(f"  model  {name}")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(names) + "\n")
    print(f"wrote {len(names)} artifacts to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
