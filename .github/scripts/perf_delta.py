#!/usr/bin/env python3
"""Print the perf trajectory delta between two bench_results.json files.

Usage: perf_delta.py <previous.json> <current.json>

Records are keyed by (workload, engine); for every key present in both
files, the throughput metrics (`designs_per_sec`, `queries_per_sec`) are
compared and the relative change printed. A missing previous file is not
an error — the first run on a branch has no trajectory yet — so CI can
run this unconditionally after a best-effort artifact download.
"""

import json
import os
import sys

METRICS = ("designs_per_sec", "queries_per_sec")


def load(path):
    with open(path) as f:
        records = json.load(f)
    return {(r.get("workload", ""), r.get("engine", "")): r for r in records}


def main():
    prev_path, curr_path = sys.argv[1], sys.argv[2]
    if not os.path.exists(prev_path):
        print(f"no previous bench results at {prev_path}; nothing to compare")
        return
    prev, curr = load(prev_path), load(curr_path)

    printed = 0
    for key in sorted(curr):
        workload, engine = key
        for metric in METRICS:
            now = curr[key].get(metric)
            was = prev.get(key, {}).get(metric)
            if not isinstance(now, (int, float)) or not isinstance(was, (int, float)):
                continue
            if was <= 0:
                continue
            pct = 100.0 * (now - was) / was
            print(
                f"{workload:<16} {engine:<22} {metric:<16} "
                f"{was:>10.1f} -> {now:>10.1f}  ({pct:+.1f}%)"
            )
            printed += 1
    if printed == 0:
        print("no overlapping throughput metrics between the two runs")


if __name__ == "__main__":
    main()
