#!/usr/bin/env python3
"""CI smoke for `hwsplit serve`: drive the daemon end to end over the wire.

Single-process mode (default) runs against a daemon started with:
  hwsplit serve --snapshots <file> --port <port> \
      --serve-workers 1 --queue-depth 1 --request-timeout-ms 5000

The 1-worker/1-slot sizing makes backpressure deterministic: with
connection A parked on the worker and B in the queue slot, C must be
refused with a typed `busy` error.

Sharded mode (`--shards`, second argv) runs against a supervisor started
with:
  hwsplit serve --shards 2 --snapshots <relu128>,<mlp> --port <port>

and exercises the router: queries on both shards, aggregated stats,
fault injection (SIGKILL one child, assert the supervisor restarts it
and the query succeeds again), broadcast reload and shutdown.

Protocol spec: docs/serving.md.
"""

import json
import os
import signal
import socket
import sys
import time

HOST = "127.0.0.1"
PORT = int(sys.argv[1]) if len(sys.argv) > 1 else 7979
SHARDED = "--shards" in sys.argv[2:]


def connect(retries=60):
    for _ in range(retries):
        try:
            s = socket.create_connection((HOST, PORT), timeout=30)
            s.settimeout(30)
            return s
        except OSError:
            time.sleep(0.5)
    raise SystemExit("daemon never came up")


def rpc(f, req):
    f.write(json.dumps(req) + "\n")
    f.flush()
    line = f.readline()
    if not line:
        raise SystemExit(f"connection closed instead of answering {req}")
    return json.loads(line)


def expect(cond, what, resp):
    if not cond:
        raise SystemExit(f"FAIL {what}: {resp}")
    print(f"ok: {what}")


def one_shot(req):
    s = connect(retries=1)
    try:
        return rpc(s.makefile("rw"), req)
    finally:
        s.close()


def single_process():
    a = connect()
    fa = a.makefile("rw")
    resp = rpc(fa, {"cmd": "ping"})
    expect(resp.get("pong") is True, "ping answers pong", resp)

    resp = rpc(fa, {"cmd": "query", "workload": "relu128", "samples": 8})
    expect(
        resp.get("ok") is True and resp.get("workload") == "relu128",
        "query served from the snapshot",
        resp,
    )

    # Busy injection: the single worker is parked on connection A; B takes
    # the one queue slot; C must be refused immediately with a typed busy
    # error.
    b = connect(retries=1)
    time.sleep(0.5)  # let the acceptor enqueue B
    c = connect(retries=1)
    line = c.makefile("r").readline()
    expect(bool(line), "refused connection still gets a reply line", line)
    busy = json.loads(line)
    expect(
        busy.get("ok") is False
        and busy.get("code") == "busy"
        and isinstance(busy.get("retry_after_ms"), int),
        "queue overflow answers typed busy with a retry hint",
        busy,
    )
    c.close()

    resp = rpc(fa, {"cmd": "reload"})
    expect(
        resp.get("ok") is True and "relu128" in resp.get("reloaded", ""),
        "hot reload swaps the resident snapshot",
        resp,
    )

    stats = rpc(fa, {"cmd": "stats"})
    expect(
        stats.get("served") == 1
        and stats.get("rejected") == 1
        and stats.get("queue_depth") == 1
        and stats.get("timeouts") == 0
        and stats.get("errors") == 0,
        "stats counters are exact (served/rejected/queued)",
        stats,
    )

    # Free the worker; the queued connection B must now be served.
    fa.close()
    a.close()
    fb = b.makefile("rw")
    resp = rpc(fb, {"cmd": "query", "workload": "relu128", "samples": 8})
    expect(
        resp.get("ok") is True,
        "queued connection drains once the worker frees",
        resp,
    )

    resp = rpc(fb, {"cmd": "shutdown"})
    expect(resp.get("shutting_down") is True, "graceful shutdown acknowledged", resp)
    print("serving smoke passed")


def query_until_ok(workload, timeout_s=60):
    """Poll one workload through the router until it answers ok. While the
    owning shard is mid-restart the router must answer typed busy — any
    other failure is a smoke failure."""
    deadline = time.time() + timeout_s
    while time.time() < deadline:
        resp = one_shot({"cmd": "query", "workload": workload, "samples": 4})
        if resp.get("ok") is True:
            return resp
        if resp.get("code") != "busy":
            raise SystemExit(f"FAIL mid-restart response must be typed busy: {resp}")
        time.sleep(0.5)
    raise SystemExit(f"FAIL {workload} never came back after the restart")


def sharded():
    a = connect()
    fa = a.makefile("rw")
    resp = rpc(fa, {"cmd": "ping"})
    expect(resp.get("pong") is True, "router answers ping locally", resp)

    for workload in ("relu128", "mlp"):
        resp = rpc(fa, {"cmd": "query", "workload": workload, "samples": 8})
        expect(
            resp.get("ok") is True and resp.get("workload") == workload,
            f"query for {workload} routed to its shard",
            resp,
        )

    stats = rpc(fa, {"cmd": "stats"})
    pids = [int(p) for p in stats.get("shard_pids", "").split(",") if p]
    expect(
        stats.get("shards") == 2
        and stats.get("served") == 2
        and stats.get("restarts") == 0
        and len(pids) == 2,
        "aggregated stats see both shards with exact sums",
        stats,
    )

    # Fault injection: SIGKILL one child; the supervisor must notice,
    # restart it, and the routed query must succeed again.
    os.kill(pids[0], signal.SIGKILL)
    print(f"killed shard child pid {pids[0]}")
    for workload in ("relu128", "mlp"):
        resp = query_until_ok(workload)
        expect(resp.get("ok") is True, f"{workload} serves after the restart", resp)

    deadline = time.time() + 60
    while True:
        stats = one_shot({"cmd": "stats"})
        new_pids = [int(p) for p in stats.get("shard_pids", "").split(",") if p]
        if stats.get("restarts", 0) >= 1 and pids[0] not in new_pids:
            break
        if time.time() > deadline:
            raise SystemExit(f"FAIL restart never surfaced in stats: {stats}")
        time.sleep(0.5)
    expect(True, "the restart is counted and the dead pid replaced", stats)

    resp = one_shot({"cmd": "reload"})
    expect(
        resp.get("ok") is True
        and "relu128" in resp.get("reloaded", "")
        and "mlp" in resp.get("reloaded", ""),
        "reload broadcasts to every shard",
        resp,
    )

    resp = one_shot({"cmd": "shutdown"})
    expect(resp.get("shutting_down") is True, "broadcast shutdown acknowledged", resp)
    print("sharded serving smoke passed")


if SHARDED:
    sharded()
else:
    single_process()
