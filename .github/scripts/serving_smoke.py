#!/usr/bin/env python3
"""CI smoke for `hwsplit serve`: drive the daemon end to end over the wire.

Run against a daemon started with:
  hwsplit serve --snapshots <file> --port <port> \
      --serve-workers 1 --queue-depth 1 --request-timeout-ms 5000

The 1-worker/1-slot sizing makes backpressure deterministic: with
connection A parked on the worker and B in the queue slot, C must be
refused with a typed `busy` error. Protocol spec: docs/serving.md.
"""

import json
import socket
import sys
import time

HOST = "127.0.0.1"
PORT = int(sys.argv[1]) if len(sys.argv) > 1 else 7979


def connect(retries=60):
    for _ in range(retries):
        try:
            s = socket.create_connection((HOST, PORT), timeout=30)
            s.settimeout(30)
            return s
        except OSError:
            time.sleep(0.5)
    raise SystemExit("daemon never came up")


def rpc(f, req):
    f.write(json.dumps(req) + "\n")
    f.flush()
    line = f.readline()
    if not line:
        raise SystemExit(f"connection closed instead of answering {req}")
    return json.loads(line)


def expect(cond, what, resp):
    if not cond:
        raise SystemExit(f"FAIL {what}: {resp}")
    print(f"ok: {what}")


a = connect()
fa = a.makefile("rw")
resp = rpc(fa, {"cmd": "ping"})
expect(resp.get("pong") is True, "ping answers pong", resp)

resp = rpc(fa, {"cmd": "query", "workload": "relu128", "samples": 8})
expect(
    resp.get("ok") is True and resp.get("workload") == "relu128",
    "query served from the snapshot",
    resp,
)

# Busy injection: the single worker is parked on connection A; B takes the
# one queue slot; C must be refused immediately with a typed busy error.
b = connect(retries=1)
time.sleep(0.5)  # let the acceptor enqueue B
c = connect(retries=1)
line = c.makefile("r").readline()
expect(bool(line), "refused connection still gets a reply line", line)
busy = json.loads(line)
expect(
    busy.get("ok") is False
    and busy.get("code") == "busy"
    and isinstance(busy.get("retry_after_ms"), int),
    "queue overflow answers typed busy with a retry hint",
    busy,
)
c.close()

resp = rpc(fa, {"cmd": "reload"})
expect(
    resp.get("ok") is True and "relu128" in resp.get("reloaded", ""),
    "hot reload swaps the resident snapshot",
    resp,
)

stats = rpc(fa, {"cmd": "stats"})
expect(
    stats.get("served") == 1
    and stats.get("rejected") == 1
    and stats.get("queue_depth") == 1
    and stats.get("timeouts") == 0
    and stats.get("errors") == 0,
    "stats counters are exact (served/rejected/queued)",
    stats,
)

# Free the worker; the queued connection B must now be served.
fa.close()
a.close()
fb = b.makefile("rw")
resp = rpc(fb, {"cmd": "query", "workload": "relu128", "samples": 8})
expect(
    resp.get("ok") is True,
    "queued connection drains once the worker frees",
    resp,
)

resp = rpc(fb, {"cmd": "shutdown"})
expect(resp.get("shutting_down") is True, "graceful shutdown acknowledged", resp)
print("serving smoke passed")
