//! Bench — saturation engine: incremental dirty-set search vs the
//! full-rescan reference, on a growing workload. The incremental engine's
//! pitch is that search cost tracks the *change* per iteration instead of
//! the accumulated graph size; this bench measures that gap directly and
//! asserts the two engines enumerate identical spaces while doing so.
//! A second section times the wave-parallel apply phase at width 1 vs 4
//! (identical spaces asserted again — the commit step is stream-ordered).
//!
//! Run: `cargo bench --bench saturation`

use hwsplit::egraph::{Runner, RunnerLimits, RunnerReport, SearchMode, StopReason};
use hwsplit::lower::lower_default;
use hwsplit::relay::workload_by_name;
use hwsplit::report::Table;
use hwsplit::rewrites::RuleSet;
use std::time::Instant;

struct RunStats {
    secs: f64,
    nodes: usize,
    classes: usize,
    designs: f64,
    searched_last: usize,
    stop: StopReason,
}

fn run(workload: &str, rules: RuleSet, iters: usize, max_nodes: usize, mode: SearchMode) -> RunStats {
    let w = workload_by_name(workload).expect("known workload");
    let lowered = lower_default(&w.expr).expect("workload lowers");
    // Design counting off: both engines would pay it identically, and the
    // point here is to time search+apply+rebuild.
    let limits = RunnerLimits { max_nodes, track_designs: false, ..Default::default() };
    let mut runner = Runner::new(lowered, rules.rules())
        .with_limits(limits)
        .with_search_mode(mode);
    let t0 = Instant::now();
    let rep = runner.run(iters);
    RunStats {
        secs: t0.elapsed().as_secs_f64(),
        nodes: rep.nodes,
        classes: rep.classes,
        designs: rep.designs_lower_bound,
        searched_last: rep.iterations.last().map(|it| it.searched_classes).unwrap_or(0),
        stop: rep.stop,
    }
}

fn main() {
    // ---- headline: per-workload full-rescan vs incremental -------------
    let cases: &[(&str, RuleSet, usize, usize)] = &[
        ("relu128", RuleSet::Fig2, 16, 50_000),
        ("mlp", RuleSet::Paper, 6, 50_000),
        ("lenet", RuleSet::Paper, 6, 50_000),
    ];
    let mut t = Table::new(
        "saturation engine: full-rescan vs incremental (identical spaces asserted)",
        &["workload", "e-nodes", "e-classes", "full(s)", "incr(s)", "speedup", "stop"],
    );
    let mut csv_rows: Vec<Vec<String>> = vec![];
    for &(name, rules, iters, max_nodes) in cases {
        let full = run(name, rules, iters, max_nodes, SearchMode::FullRescan);
        let incr = run(name, rules, iters, max_nodes, SearchMode::Incremental);
        assert_eq!(
            (full.nodes, full.classes),
            (incr.nodes, incr.classes),
            "{name}: engines enumerated different spaces"
        );
        assert_eq!(full.designs, incr.designs, "{name}: design counts diverged");
        t.row(&[
            name.to_string(),
            incr.nodes.to_string(),
            incr.classes.to_string(),
            format!("{:.3}", full.secs),
            format!("{:.3}", incr.secs),
            format!("{:.2}x", full.secs / incr.secs.max(1e-9)),
            format!("{:?}", incr.stop),
        ]);
        csv_rows.push(vec![
            name.to_string(),
            incr.nodes.to_string(),
            format!("{:.4}", full.secs),
            format!("{:.4}", incr.secs),
        ]);
    }
    print!("{}", t.render());

    // ---- scaling: LeNet with a growing iteration budget -----------------
    // Full rescan re-matches the whole accumulated graph every iteration,
    // so its cost grows superlinearly in the budget; incremental search
    // tracks the per-iteration change.
    let mut g = Table::new(
        "LeNet enumeration vs iteration budget",
        &["iters", "e-nodes", "searched(last)", "full(s)", "incr(s)", "speedup"],
    );
    for iters in [2usize, 4, 6, 8] {
        let full = run("lenet", RuleSet::Paper, iters, 60_000, SearchMode::FullRescan);
        let incr = run("lenet", RuleSet::Paper, iters, 60_000, SearchMode::Incremental);
        assert_eq!(
            (full.nodes, full.classes, full.designs),
            (incr.nodes, incr.classes, incr.designs),
            "lenet@{iters}: engines enumerated different spaces"
        );
        g.row(&[
            iters.to_string(),
            incr.nodes.to_string(),
            incr.searched_last.to_string(),
            format!("{:.3}", full.secs),
            format!("{:.3}", incr.secs),
            format!("{:.2}x", full.secs / incr.secs.max(1e-9)),
        ]);
        csv_rows.push(vec![
            format!("lenet@{iters}"),
            incr.nodes.to_string(),
            format!("{:.4}", full.secs),
            format!("{:.4}", incr.secs),
        ]);
    }
    print!("{}", g.render());

    // ---- apply phase: wave-parallel staging at width 1 vs 4 -------------
    // Same saturation, only the apply fan-out differs: matches are cut
    // into conflict-free waves, right-hand sides are staged in parallel
    // against the frozen graph, and intents commit single-threaded in
    // stream order — so the enumerated spaces must be identical and only
    // the apply-phase wall-clock may move.
    let run_width = |workload: &str, rules: RuleSet, iters: usize, width: usize| -> (f64, RunnerReport) {
        let w = workload_by_name(workload).expect("known workload");
        let lowered = lower_default(&w.expr).expect("workload lowers");
        let mut runner = Runner::new(lowered, rules.rules())
            .with_limits(RunnerLimits {
                max_nodes: 60_000,
                track_designs: false,
                ..Default::default()
            })
            .with_apply_workers(width);
        let t0 = Instant::now();
        let rep = runner.run(iters);
        (t0.elapsed().as_secs_f64(), rep)
    };
    let mut a = Table::new(
        "apply phase: staged wave-parallel apply, width 1 vs 4 (identical spaces asserted)",
        &["workload", "e-nodes", "waves", "apply@1(s)", "apply@4(s)", "speedup", "total@4(s)"],
    );
    for &(name, rules, iters) in
        &[("lenet", RuleSet::Paper, 5usize), ("attn_block_mh4", RuleSet::All, 3)]
    {
        let (secs1, rep1) = run_width(name, rules, iters, 1);
        let (secs4, rep4) = run_width(name, rules, iters, 4);
        assert_eq!(
            (rep1.nodes, rep1.classes),
            (rep4.nodes, rep4.classes),
            "{name}: apply width changed the enumerated space"
        );
        let apply1 = rep1.phase_totals().1.as_secs_f64();
        let apply4 = rep4.phase_totals().1.as_secs_f64();
        let waves: usize = rep4.iterations.iter().map(|it| it.apply_waves).sum();
        a.row(&[
            name.to_string(),
            rep4.nodes.to_string(),
            waves.to_string(),
            format!("{apply1:.3}"),
            format!("{apply4:.3}"),
            format!("{:.2}x", apply1 / apply4.max(1e-9)),
            format!("{secs4:.3}"),
        ]);
        csv_rows.push(vec![
            format!("{name}-apply-width"),
            rep4.nodes.to_string(),
            format!("{apply1:.4}"),
            format!("{apply4:.4}"),
        ]);
        let _ = secs1;
    }
    print!("{}", a.render());

    let mut csv = Table::new("", &["case", "e_nodes", "full_seconds", "incremental_seconds"]);
    for r in csv_rows {
        csv.row(&r);
    }
    csv.write_csv("bench_results/saturation.csv").ok();
    println!("wrote bench_results/saturation.csv");
    // Soft wall-clock sanity: on a multi-iteration LeNet run the
    // incremental engine should not lose to the full rescan (it searches a
    // strict subset of the classes with the same merge discipline).
    let full = run("lenet", RuleSet::Paper, 6, 60_000, SearchMode::FullRescan);
    let incr = run("lenet", RuleSet::Paper, 6, 60_000, SearchMode::Incremental);
    println!(
        "lenet@6 check: full {:.3}s vs incremental {:.3}s ({:.2}x)",
        full.secs,
        incr.secs,
        full.secs / incr.secs.max(1e-9)
    );
    assert!(
        incr.secs <= full.secs * 1.15,
        "incremental engine regressed past noise vs full rescan \
         (full {:.3}s, incremental {:.3}s)",
        full.secs,
        incr.secs
    );
}
