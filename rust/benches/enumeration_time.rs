//! Bench E4 — enumeration cost: wall-clock to saturation or node budget
//! per workload, plus microbenchmarks of the e-graph substrate itself
//! (insert+rebuild throughput, e-matching throughput, extraction) — the
//! §Perf numbers for Layer 3.
//!
//! Run: `cargo bench --bench enumeration_time`

use hwsplit::bench_util::{bench, black_box};
use hwsplit::egraph::{EGraph, Runner, RunnerLimits};
use hwsplit::extract::{latency_cost, Extractor};
use hwsplit::ir::{parse_expr, Node, Op, RecExpr, Shape, Symbol};
use hwsplit::lower::lower_default;
use hwsplit::relay::all_workloads;
use hwsplit::report::Table;
use hwsplit::rewrites;

fn main() {
    // ---- end-to-end enumeration per workload ----
    let mut t = Table::new(
        "E4 enumeration cost (paper rules, 8 iters, 80k node budget)",
        &["workload", "lowered-nodes", "e-nodes", "e-classes", "designs-lb", "time", "stop"],
    );
    let mut csv_rows: Vec<Vec<String>> = vec![];
    for w in all_workloads() {
        let lowered = lower_default(&w.expr).expect("workload lowers");
        let n0 = lowered.len();
        let t0 = std::time::Instant::now();
        let mut runner = Runner::new(lowered, rewrites::paper_rules()).with_limits(
            RunnerLimits { max_nodes: 80_000, ..Default::default() },
        );
        let report = runner.run(8);
        let dt = t0.elapsed();
        t.row(&[
            w.name.to_string(),
            n0.to_string(),
            report.nodes.to_string(),
            report.classes.to_string(),
            format!("{:.3e}", report.designs_lower_bound),
            format!("{dt:.2?}"),
            format!("{:?}", report.stop),
        ]);
        csv_rows.push(vec![
            w.name.to_string(),
            report.nodes.to_string(),
            format!("{:.3}", dt.as_secs_f64()),
        ]);
    }
    print!("{}", t.render());
    let mut csv = Table::new("", &["workload", "e_nodes", "seconds"]);
    for r in csv_rows {
        csv.row(&r);
    }
    csv.write_csv("bench_results/enumeration_time.csv").ok();

    // ---- substrate microbenches (Layer-3 §Perf targets) ----
    println!("\n== e-graph substrate microbenchmarks ==");

    // Insert + congruence throughput: chains of relu nodes over fresh
    // inputs, then unions + rebuild.
    let r = bench("egraph insert 100k nodes", 1, 5, || {
        let mut eg = EGraph::new();
        let mut prev =
            eg.add(Node::leaf(Op::Input(Symbol::new("x"), Shape::new(&[4]))));
        for _ in 0..100_000 {
            prev = eg.add(Node::new(Op::Relu, vec![prev]));
        }
        black_box(eg.total_nodes());
    });
    let nodes_per_sec = 100_000.0 / r.median.as_secs_f64();
    println!("  -> {:.2}M e-nodes/s inserted (target >= 1M/s)", nodes_per_sec / 1e6);

    bench("union+rebuild 10k congruent pairs", 1, 5, || {
        let mut eg = EGraph::new();
        let mut lhs = vec![];
        let mut rhs = vec![];
        for i in 0..10_000 {
            let a = eg.add(Node::leaf(Op::Input(
                Symbol::new(&format!("a{i}")),
                Shape::new(&[4]),
            )));
            let b = eg.add(Node::leaf(Op::Input(
                Symbol::new(&format!("b{i}")),
                Shape::new(&[4]),
            )));
            lhs.push(eg.add(Node::new(Op::Relu, vec![a])));
            rhs.push(eg.add(Node::new(Op::Relu, vec![b])));
            eg.union(a, b);
        }
        eg.rebuild();
        for (l, r) in lhs.into_iter().zip(rhs) {
            assert_eq!(eg.find(l), eg.find(r));
        }
    });

    // E-matching throughput over a saturated mlp e-graph.
    let lowered = lower_default(&all_workloads()[4].expr).expect("workload lowers"); // mlp
    let mut runner = Runner::new(lowered, rewrites::paper_rules())
        .with_limits(RunnerLimits { max_nodes: 50_000, ..Default::default() });
    runner.run(6);
    let eg = runner.egraph;
    let nodes = eg.total_nodes();
    let rules = rewrites::paper_rules();
    let r = bench(&format!("search {} rules over {} nodes", rules.len(), nodes), 1, 10, || {
        let mut total = 0usize;
        for rule in &rules {
            total += rule.search(&eg).len();
        }
        black_box(total);
    });
    println!(
        "  -> {:.2}M node-rule visits/s",
        (nodes * rules.len()) as f64 / r.median.as_secs_f64() / 1e6
    );

    // Extraction at scale.
    let root = runner.root;
    bench(&format!("greedy extraction over {nodes} nodes"), 1, 10, || {
        let ex = Extractor::new(&eg, latency_cost);
        black_box(ex.extract(&eg, root).len());
    });

    // Parser/printer round-trip (tooling hot path).
    let big: RecExpr = lower_default(&all_workloads()[5].expr).expect("workload lowers"); // lenet
    let text = big.to_string();
    bench("parse+print lenet EngineIR", 3, 30, || {
        let e = parse_expr(&text).unwrap();
        black_box(e.to_string().len());
    });
}
