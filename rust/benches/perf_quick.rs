//! Bench — the CI quick-mode perf trajectory: tiny-budget runs of the
//! saturation engine (full-rescan vs incremental) and the extraction
//! serving layer (cold vs memoized), emitted as machine-readable
//! `bench_results.json` records `{workload, engine, wall_ms,
//! designs_per_sec}` so every CI run leaves a comparable perf data point
//! (uploaded as a workflow artifact — the `BENCH_*` trajectory stops being
//! empty).
//!
//! Budgets are deliberately tiny so the job costs seconds; set
//! `HWSPLIT_PERF_FULL=1` for locally meaningful numbers.
//!
//! Run: `cargo bench --bench perf_quick`

use hwsplit::bench_util::{snapshot_fixture, snapshot_fixture_path};
use hwsplit::egraph::{Runner, RunnerLimits, SearchMode};
use hwsplit::extract::{extract_designs, ExtractCache, ExtractOptions};
use hwsplit::lower::lower_default;
use hwsplit::par::default_workers;
use hwsplit::relay::workload_by_name;
use hwsplit::report::{JsonRecords, JsonValue};
use hwsplit::rewrites::RuleSet;
use hwsplit::session::Session;
use std::time::Instant;

fn record(
    out: &mut JsonRecords,
    workload: &str,
    engine: &str,
    wall_ms: f64,
    designs_per_sec: f64,
) {
    println!("{workload:<10} {engine:<24} {wall_ms:>10.2} ms {designs_per_sec:>14.1} designs/s");
    out.push(vec![
        ("workload".to_string(), JsonValue::Str(workload.to_string())),
        ("engine".to_string(), JsonValue::Str(engine.to_string())),
        ("wall_ms".to_string(), JsonValue::Num(wall_ms)),
        ("designs_per_sec".to_string(), JsonValue::Num(designs_per_sec)),
    ]);
}

fn main() {
    let full = std::env::var_os("HWSPLIT_PERF_FULL").is_some();
    // (workload, rules, iters, max_nodes) — tiny budgets by default.
    let cases: &[(&str, RuleSet, usize, usize)] = if full {
        &[
            ("relu128", RuleSet::Fig2, 16, 50_000),
            ("mlp", RuleSet::Paper, 6, 50_000),
            ("lenet", RuleSet::Paper, 5, 50_000),
            ("attn_block", RuleSet::All, 4, 50_000),
            ("attn_block_mh4", RuleSet::All, 3, 50_000),
            ("mobile_block", RuleSet::Paper, 5, 50_000),
            ("mobile_block_s2", RuleSet::Paper, 5, 50_000),
        ]
    } else {
        &[
            ("relu128", RuleSet::Fig2, 6, 8_000),
            ("mlp", RuleSet::Paper, 3, 8_000),
            ("attn_block", RuleSet::All, 2, 8_000),
            ("attn_block_mh4", RuleSet::All, 2, 8_000),
            ("mobile_block", RuleSet::Paper, 3, 8_000),
            ("mobile_block_s2", RuleSet::Paper, 3, 8_000),
        ]
    };
    let samples = if full { 64 } else { 16 };
    let workers = default_workers();

    let mut out = JsonRecords::new();
    for &(name, rules, iters, max_nodes) in cases {
        let w = workload_by_name(name).expect("known workload");
        let lowered = lower_default(&w.expr).expect("workload lowers");
        let limits =
            RunnerLimits { max_nodes, track_designs: false, ..Default::default() };

        // Saturation: full-rescan reference vs the incremental engine.
        // "designs/sec" here is the end-of-run distinct-design lower bound
        // over the wall-clock — the enumeration-side throughput proxy.
        let mut incremental_graph = None;
        for (mode, engine) in [
            (SearchMode::FullRescan, "saturate-full"),
            (SearchMode::Incremental, "saturate-incremental"),
        ] {
            let mut runner = Runner::new(lowered.clone(), rules.rules())
                .with_limits(limits.clone())
                .with_search_mode(mode);
            let t0 = Instant::now();
            let rep = runner.run(iters);
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            record(&mut out, name, engine, secs * 1e3, rep.designs_lower_bound / secs);
            if mode == SearchMode::Incremental {
                incremental_graph = Some((runner.egraph, runner.root));
            }
        }

        // Extraction: cold pass (solves every fixpoint) vs memoized repeat
        // (the second-query serving path). designs/sec counts requested
        // extractions.
        let (eg, root) = incremental_graph.expect("incremental run recorded");
        let cache = ExtractCache::new();
        let opts = ExtractOptions { samples, seed: 0, workers };
        for engine in ["extract-cold", "extract-memoized"] {
            let t0 = Instant::now();
            let set = extract_designs(&eg, root, &opts, &cache);
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            if engine == "extract-memoized" {
                assert_eq!(set.memo_misses, 0, "{name}: repeat pass must be fully memoized");
            }
            record(&mut out, name, engine, secs * 1e3, set.requested as f64 / secs);
        }
    }

    // Snapshot serving: the daemon's startup economics. Cold-load the
    // saturated attn_block_mh4 fixture from disk — built through the same
    // `bench_util::snapshot_fixture` helper the serving bench uses, with
    // this run's budget — instead of paying saturation again. "designs/sec"
    // is the snapshot's design lower bound over the load wall-clock.
    let (sname, srules) = ("attn_block_mh4", RuleSet::All);
    let (siters, snodes) = if full { (3, 50_000) } else { (2, 8_000) };
    let _ = snapshot_fixture(sname, srules, siters, snodes); // ensure on disk
    let spath = snapshot_fixture_path(sname, srules, siters, snodes);
    let t0 = Instant::now();
    let loaded = Session::load_snapshot(&spath).expect("snapshot fixture loads");
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    assert_eq!(loaded.enumeration_count(), 0, "cold load must not re-saturate");
    let designs = loaded
        .enumeration()
        .map(|en| en.report.designs_lower_bound)
        .unwrap_or(0.0);
    record(&mut out, sname, "snapshot-load", secs * 1e3, designs / secs);

    out.write("bench_results.json").expect("write bench_results.json");
    println!("wrote bench_results.json ({} records)", out.len());
}
