//! Bench — the CI quick-mode perf trajectory: tiny-budget runs of the
//! saturation engine (full-rescan vs incremental), the wave-parallel apply
//! phase (1 vs 4 workers), the cost-table solver (scratch vs incremental
//! re-relaxation) and the extraction serving layer (cold vs memoized),
//! emitted as machine-readable `bench_results.json` records `{workload,
//! engine, wall_ms, designs_per_sec, ...}` so every CI run leaves a
//! comparable perf data point (uploaded as a workflow artifact — the
//! `BENCH_*` trajectory stops being empty). Saturation rows additionally
//! carry `saturation_wall_ms` and the per-phase breakdown
//! (`search_ms`/`apply_ms`/`rebuild_ms`/`apply_waves`), so regressions are
//! attributable to a phase, not just a total.
//!
//! Two correctness gates ride along as hard asserts (CI fails on a
//! violation, not just a slowdown): the apply phase must leave the e-graph
//! identical for any worker count, and the incremental cost table must
//! agree bit-exactly with a from-scratch solve.
//!
//! Budgets are deliberately tiny so the job costs seconds; set
//! `HWSPLIT_PERF_FULL=1` for locally meaningful numbers.
//!
//! Run: `cargo bench --bench perf_quick`

use hwsplit::bench_util::{snapshot_fixture, snapshot_fixture_path};
use hwsplit::egraph::{Runner, RunnerLimits, SearchMode};
use hwsplit::extract::{
    costs_agree, extract_designs, CostKind, CostTable, ExtractCache, ExtractOptions,
};
use hwsplit::ir::{Node, Op};
use hwsplit::lower::lower_default;
use hwsplit::par::default_workers;
use hwsplit::relay::workload_by_name;
use hwsplit::report::{JsonRecords, JsonValue};
use hwsplit::rewrites::RuleSet;
use hwsplit::session::Session;
use std::time::Instant;

fn record(
    out: &mut JsonRecords,
    workload: &str,
    engine: &str,
    wall_ms: f64,
    designs_per_sec: f64,
    extra: &[(&str, f64)],
) {
    println!("{workload:<14} {engine:<24} {wall_ms:>10.2} ms {designs_per_sec:>14.1} designs/s");
    let mut fields = vec![
        ("workload".to_string(), JsonValue::Str(workload.to_string())),
        ("engine".to_string(), JsonValue::Str(engine.to_string())),
        ("wall_ms".to_string(), JsonValue::Num(wall_ms)),
        ("designs_per_sec".to_string(), JsonValue::Num(designs_per_sec)),
    ];
    for &(k, v) in extra {
        fields.push((k.to_string(), JsonValue::Num(v)));
    }
    out.push(fields);
}

/// The saturation breakdown columns: total wall plus summed per-phase
/// wall-clock and the wave count from the report.
fn saturation_extra(rep: &hwsplit::egraph::RunnerReport, wall_ms: f64) -> Vec<(&'static str, f64)> {
    let (search, apply, rebuild) = rep.phase_totals();
    let waves: usize = rep.iterations.iter().map(|i| i.apply_waves).sum();
    vec![
        ("saturation_wall_ms", wall_ms),
        ("search_ms", search.as_secs_f64() * 1e3),
        ("apply_ms", apply.as_secs_f64() * 1e3),
        ("rebuild_ms", rebuild.as_secs_f64() * 1e3),
        ("apply_waves", waves as f64),
    ]
}

fn main() {
    let full = std::env::var_os("HWSPLIT_PERF_FULL").is_some();
    // (workload, rules, iters, max_nodes) — tiny budgets by default.
    let cases: &[(&str, RuleSet, usize, usize)] = if full {
        &[
            ("relu128", RuleSet::Fig2, 16, 50_000),
            ("mlp", RuleSet::Paper, 6, 50_000),
            ("lenet", RuleSet::Paper, 5, 50_000),
            ("attn_block", RuleSet::All, 4, 50_000),
            ("attn_block_mh4", RuleSet::All, 3, 50_000),
            ("attn_block_gqa", RuleSet::All, 3, 50_000),
            ("mobile_block", RuleSet::Paper, 5, 50_000),
            ("mobile_block_s2", RuleSet::Paper, 5, 50_000),
        ]
    } else {
        &[
            ("relu128", RuleSet::Fig2, 6, 8_000),
            ("mlp", RuleSet::Paper, 3, 8_000),
            ("attn_block", RuleSet::All, 2, 8_000),
            ("attn_block_mh4", RuleSet::All, 2, 8_000),
            ("attn_block_gqa", RuleSet::All, 2, 8_000),
            ("mobile_block", RuleSet::Paper, 3, 8_000),
            ("mobile_block_s2", RuleSet::Paper, 3, 8_000),
        ]
    };
    let samples = if full { 64 } else { 16 };
    let workers = default_workers();

    let mut out = JsonRecords::new();
    for &(name, rules, iters, max_nodes) in cases {
        let w = workload_by_name(name).expect("known workload");
        let lowered = lower_default(&w.expr).expect("workload lowers");
        let limits =
            RunnerLimits { max_nodes, track_designs: false, ..Default::default() };

        // Saturation: full-rescan reference vs the incremental engine.
        // "designs/sec" here is the end-of-run distinct-design lower bound
        // over the wall-clock — the enumeration-side throughput proxy.
        let mut incremental_graph = None;
        for (mode, engine) in [
            (SearchMode::FullRescan, "saturate-full"),
            (SearchMode::Incremental, "saturate-incremental"),
        ] {
            let mut runner = Runner::new(lowered.clone(), rules.rules())
                .with_limits(limits.clone())
                .with_search_mode(mode);
            let t0 = Instant::now();
            let rep = runner.run(iters);
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            record(
                &mut out,
                name,
                engine,
                secs * 1e3,
                rep.designs_lower_bound / secs,
                &saturation_extra(&rep, secs * 1e3),
            );
            if mode == SearchMode::Incremental {
                incremental_graph = Some((runner.egraph, runner.root));
            }
        }

        // Extraction: cold pass (solves every fixpoint) vs memoized repeat
        // (the second-query serving path). designs/sec counts requested
        // extractions.
        let (mut eg, root) = incremental_graph.expect("incremental run recorded");
        let cache = ExtractCache::new();
        let opts = ExtractOptions { samples, seed: 0, workers };
        for engine in ["extract-cold", "extract-memoized"] {
            let t0 = Instant::now();
            let set = extract_designs(&eg, root, &opts, &cache);
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            if engine == "extract-memoized" {
                assert_eq!(set.memo_misses, 0, "{name}: repeat pass must be fully memoized");
            }
            record(&mut out, name, engine, secs * 1e3, set.requested as f64 / secs, &[]);
        }

        // Cost tables: from-scratch solve vs incremental re-relaxation
        // after a post-saturation mutation. `prev` is warmed on the
        // saturated graph, then two fresh parent nodes over the root bump
        // the epoch (dirty-log records); the incremental path re-relaxes
        // only the dirty ancestor closure. Bit-exact agreement is a hard
        // assert, so CI fails on divergence, not just on slowdown.
        // "designs/sec" is classes solved per second for these rows.
        let kind = CostKind::Latency;
        let prev = CostTable::build_kind(&eg, &kind);
        let since = eg.epoch();
        let r1 = eg.add(Node::new(Op::Relu, vec![root]));
        eg.add(Node::new(Op::Relu, vec![r1]));
        eg.rebuild();
        let dirty = eg.changed_since(since).expect("dirty log covers the mutation");
        let classes = eg.num_classes() as f64;
        let t0 = Instant::now();
        let scratch = CostTable::build_kind(&eg, &kind);
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        record(&mut out, name, "costtable-scratch", secs * 1e3, classes / secs, &[]);
        let t0 = Instant::now();
        let incr = CostTable::build_kind_incremental(&eg, &kind, &prev, &dirty);
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        record(&mut out, name, "costtable-incremental", secs * 1e3, classes / secs, &[]);
        assert!(
            costs_agree(&scratch, &incr, &eg),
            "{name}: incremental cost table diverged from scratch"
        );
    }

    // Parallel apply: the same saturation at apply width 1 vs 4. The
    // wave-partitioned apply phase stages against the frozen graph and
    // commits in stream order, so the e-graph must come out identical for
    // any width — node/class/design counts are asserted here (the
    // `engine_equiv` integration test checks full graph fingerprints);
    // the rows expose what the width buys in apply-phase wall-clock.
    let (pname, prules) = ("attn_block_mh4", RuleSet::All);
    let (piters, pnodes) = if full { (3, 50_000) } else { (2, 8_000) };
    let w = workload_by_name(pname).expect("known workload");
    let lowered = lower_default(&w.expr).expect("workload lowers");
    let mut baseline = None;
    for apply_workers in [1usize, 4] {
        let mut runner = Runner::new(lowered.clone(), prules.rules())
            .with_limits(RunnerLimits {
                max_nodes: pnodes,
                track_designs: false,
                ..Default::default()
            })
            .with_apply_workers(apply_workers);
        let t0 = Instant::now();
        let rep = runner.run(piters);
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        record(
            &mut out,
            pname,
            &format!("apply-workers-{apply_workers}"),
            secs * 1e3,
            rep.designs_lower_bound / secs,
            &saturation_extra(&rep, secs * 1e3),
        );
        match baseline {
            None => baseline = Some((rep.nodes, rep.classes, rep.designs_lower_bound)),
            Some((n, c, d)) => {
                assert_eq!(
                    (rep.nodes, rep.classes, rep.designs_lower_bound),
                    (n, c, d),
                    "{pname}: apply width changed the e-graph"
                );
            }
        }
    }

    // Snapshot serving: the daemon's startup economics. Cold-load the
    // saturated attn_block_mh4 fixture from disk — built through the same
    // `bench_util::snapshot_fixture` helper the serving bench uses, with
    // this run's budget — instead of paying saturation again. "designs/sec"
    // is the snapshot's design lower bound over the load wall-clock.
    let (sname, srules) = ("attn_block_mh4", RuleSet::All);
    let (siters, snodes) = if full { (3, 50_000) } else { (2, 8_000) };
    let _ = snapshot_fixture(sname, srules, siters, snodes); // ensure on disk
    let spath = snapshot_fixture_path(sname, srules, siters, snodes);
    let t0 = Instant::now();
    let loaded = Session::load_snapshot(&spath).expect("snapshot fixture loads");
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    assert_eq!(loaded.enumeration_count(), 0, "cold load must not re-saturate");
    let designs = loaded
        .enumeration()
        .map(|en| en.report.designs_lower_bound)
        .unwrap_or(0.0);
    record(&mut out, sname, "snapshot-load", secs * 1e3, designs / secs, &[]);

    // ONNX import: the real-model front door's overhead. Decode + map +
    // validate both checked-in fixtures repeatedly (the parse is
    // microseconds, so a single shot would just measure timer noise);
    // "designs/sec" is relay nodes built per second for these rows.
    let reps = if full { 500 } else { 50 };
    for fixture in ["mobilenet_slice", "attention_slice"] {
        let path = format!(
            "{}/rust/tests/fixtures/{fixture}.onnx",
            env!("CARGO_MANIFEST_DIR")
        );
        let bytes = std::fs::read(&path).expect("fixture on disk");
        let t0 = Instant::now();
        let mut nodes = 0usize;
        for _ in 0..reps {
            let w = hwsplit::import::import_onnx_bytes(&bytes, fixture)
                .expect("fixture imports with zero unsupported ops");
            nodes = w.expr.len();
        }
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        record(
            &mut out,
            fixture,
            "onnx-import",
            secs * 1e3 / reps as f64,
            (nodes * reps) as f64 / secs,
            &[("relay_nodes", nodes as f64), ("model_bytes", bytes.len() as f64)],
        );
    }

    out.write("bench_results.json").expect("write bench_results.json");
    println!("wrote bench_results.json ({} records)", out.len());
}
