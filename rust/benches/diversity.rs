//! Bench E2 — design diversity (paper §3: "a diverse set of designs should
//! include many design points which differ significantly from each other").
//!
//! For each workload: enumerate, sample designs, and report the spread of
//! structural features (engine count, instance count, schedule depth,
//! par degree, buffer bytes) plus the mean pairwise feature distance —
//! including the paper's two named extremes: designs that "instantiate an
//! engine for every kernel invocation" and designs with "complex software
//! schedules and very little hardware".
//!
//! Run: `cargo bench --bench diversity`

use hwsplit::egraph::RunnerLimits;
use hwsplit::relay::all_workloads;
use hwsplit::report::{fmt_f64, Table};
use hwsplit::rewrites::RuleSet;
use hwsplit::session::{Query, Session};

fn main() {
    let mut csv = Table::new(
        "diversity summary",
        &[
            "workload",
            "designs",
            "mean-dist",
            "min-engines",
            "max-engines",
            "max-depth",
            "max-instances",
            "min-instances",
        ],
    );
    for w in all_workloads() {
        let mut session = Session::builder()
            .workload(w.clone())
            .rules(RuleSet::Paper)
            .iters(5)
            .limits(RunnerLimits { max_nodes: 60_000, ..Default::default() })
            .build()
            .expect("workload lowers");
        let ex = session.query(&Query::new().samples(64)).expect("query");

        let stats: Vec<_> = ex.designs.iter().map(|d| &d.point.stats).collect();
        let mut dist = 0.0;
        let mut pairs = 0usize;
        for i in 0..stats.len() {
            for j in i + 1..stats.len() {
                dist += stats[i].distance(stats[j]);
                pairs += 1;
            }
        }
        let mean_dist = dist / pairs.max(1) as f64;
        let min_e = stats.iter().map(|s| s.engines).min().unwrap_or(0);
        let max_e = stats.iter().map(|s| s.engines).max().unwrap_or(0);
        let max_d = stats.iter().map(|s| s.sched_depth).max().unwrap_or(0);
        let max_i = stats.iter().map(|s| s.engine_instances).fold(0.0, f64::max);
        let min_i = stats.iter().map(|s| s.engine_instances).fold(f64::INFINITY, f64::min);

        let mut t = Table::new(
            &format!("E2 diversity: {} ({} distinct designs)", w.name, ex.designs.len()),
            &["feature", "min", "max"],
        );
        t.row(&["distinct engines".into(), min_e.to_string(), max_e.to_string()]);
        t.row(&["engine instances".into(), fmt_f64(min_i), fmt_f64(max_i)]);
        t.row(&[
            "sched depth".into(),
            stats.iter().map(|s| s.sched_depth).min().unwrap_or(0).to_string(),
            max_d.to_string(),
        ]);
        t.row(&[
            "buffer KiB".into(),
            fmt_f64(stats.iter().map(|s| s.buffer_bytes).fold(f64::INFINITY, f64::min) / 1024.0),
            fmt_f64(stats.iter().map(|s| s.buffer_bytes).fold(0.0, f64::max) / 1024.0),
        ]);
        print!("{}", t.render());
        println!("mean pairwise distance: {mean_dist:.3}\n");

        csv.row(&[
            w.name.to_string(),
            ex.designs.len().to_string(),
            format!("{mean_dist:.4}"),
            min_e.to_string(),
            max_e.to_string(),
            max_d.to_string(),
            fmt_f64(max_i),
            fmt_f64(min_i),
        ]);

        // Shape assertions: the sampled set must actually be diverse.
        if ex.designs.len() >= 8 {
            assert!(mean_dist > 0.2, "{}: designs too similar ({mean_dist:.3})", w.name);
            assert!(max_d > 0, "{}: no schedules sampled at all", w.name);
        }
    }
    csv.write_csv("bench_results/diversity.csv").ok();
    println!("wrote bench_results/diversity.csv");
}
