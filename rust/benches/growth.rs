//! Bench E1 — design-space growth: e-nodes, e-classes and the
//! distinct-design lower bound per rewrite iteration, for every workload.
//! This regenerates the paper's core claim that the e-graph comes to
//! represent "an exponential number of equivalent hardware-software
//! programs".
//!
//! Run: `cargo bench --bench growth`

use hwsplit::egraph::{Runner, RunnerLimits};
use hwsplit::lower::lower_default;
use hwsplit::relay::all_workloads;
use hwsplit::report::Table;
use hwsplit::rewrites;

fn main() {
    let mut csv = Table::new(
        "growth per iteration (all workloads)",
        &["workload", "iter", "e-nodes", "e-classes", "designs-lb", "ms"],
    );
    for w in all_workloads() {
        let lowered = lower_default(&w.expr).expect("workload lowers");
        let mut runner = Runner::new(lowered, rewrites::paper_rules()).with_limits(
            RunnerLimits { max_nodes: 80_000, ..Default::default() },
        );
        let report = runner.run(8);

        let mut t = Table::new(
            &format!("E1 growth: {}", w.name),
            &["iter", "e-nodes", "e-classes", "designs(lb)", "elapsed"],
        );
        for it in &report.iterations {
            t.row(&[
                it.iteration.to_string(),
                it.nodes.to_string(),
                it.classes.to_string(),
                format!("{:.3e}", it.designs_lower_bound),
                format!("{:.1?}", it.elapsed),
            ]);
            csv.row(&[
                w.name.to_string(),
                it.iteration.to_string(),
                it.nodes.to_string(),
                it.classes.to_string(),
                format!("{:.6e}", it.designs_lower_bound),
                format!("{:.3}", it.elapsed.as_secs_f64() * 1e3),
            ]);
        }
        print!("{}", t.render());
        println!("stop: {:?}\n", report.stop);

        // Shape assertion (the paper's claim): growth is super-linear —
        // the design count must exceed the e-node count by orders of
        // magnitude once a few iterations have run.
        if report.iterations.len() >= 3 {
            let last = report.iterations.last().unwrap();
            assert!(
                last.designs_lower_bound > last.nodes as f64,
                "{}: designs ({:.2e}) should exceed e-nodes ({}) — the compact-\
                 representation claim",
                w.name,
                last.designs_lower_bound,
                last.nodes
            );
            // And growth must be super-linear across iterations.
            let first = report
                .iterations
                .iter()
                .find(|it| it.designs_lower_bound > 1.0)
                .unwrap_or(last);
            assert!(
                last.designs_lower_bound >= 4.0 * first.designs_lower_bound
                    || report.stop == hwsplit::egraph::StopReason::Saturated,
                "{}: no growth",
                w.name
            );
        }
    }
    csv.write_csv("bench_results/growth.csv").ok();
    println!("wrote bench_results/growth.csv");
}
