//! Bench — the design-extraction serving layer: serial vs parallel sample
//! fan-out, cold vs memoized cost tables, and streaming vs collect-then-
//! filter frontier maintenance. The read side's pitch is that a query
//! against an already-enumerated session costs sampling + evaluation only —
//! and, past the first query, not even the extraction fixpoints; this bench
//! measures each rung and asserts the fast paths answer identically.
//!
//! Run: `cargo bench --bench extraction`

use hwsplit::cost::CostParams;
use hwsplit::egraph::{Runner, RunnerLimits};
use hwsplit::extract::{
    analyze_points, extract_designs, pareto_frontier, ExtractCache, ExtractOptions,
    ParetoFrontier,
};
use hwsplit::lower::lower_default;
use hwsplit::par::default_workers;
use hwsplit::relay::workload_by_name;
use hwsplit::report::Table;
use hwsplit::rewrites::RuleSet;
use std::time::Instant;

fn enumerated(workload: &str, iters: usize) -> (hwsplit::egraph::EGraph, hwsplit::egraph::Id) {
    let w = workload_by_name(workload).expect("known workload");
    let lowered = lower_default(&w.expr).expect("workload lowers");
    let mut runner = Runner::new(lowered, RuleSet::Paper.rules()).with_limits(RunnerLimits {
        max_nodes: 60_000,
        track_designs: false,
        ..Default::default()
    });
    runner.run(iters);
    (runner.egraph, runner.root)
}

fn main() {
    let samples = 64usize;
    let workers = default_workers();
    let mut t = Table::new(
        &format!("extraction: {samples} samples, serial vs parallel({workers}) vs memoized"),
        &["workload", "designs", "serial(s)", "parallel(s)", "memo(s)", "par-x", "memo-x"],
    );
    let mut csv_rows: Vec<Vec<String>> = vec![];
    for &(name, iters) in &[("relu128", 6), ("mlp", 5), ("lenet", 4)] {
        let (eg, root) = enumerated(name, iters);

        // Serial, cold cache.
        let t0 = Instant::now();
        let serial = extract_designs(
            &eg,
            root,
            &ExtractOptions { samples, seed: 0, workers: 1 },
            &ExtractCache::new(),
        );
        let serial_s = t0.elapsed().as_secs_f64();

        // Parallel, cold cache.
        let cache = ExtractCache::new();
        let t0 = Instant::now();
        let parallel =
            extract_designs(&eg, root, &ExtractOptions { samples, seed: 0, workers }, &cache);
        let parallel_s = t0.elapsed().as_secs_f64();

        // Parallel, warm memo (the second-query serving path).
        let t0 = Instant::now();
        let memoized =
            extract_designs(&eg, root, &ExtractOptions { samples, seed: 0, workers }, &cache);
        let memo_s = t0.elapsed().as_secs_f64();

        // Every rung answers identically.
        let strs = |set: &hwsplit::extract::ExtractedSet| {
            set.designs.iter().map(|(_, e)| e.to_string()).collect::<Vec<_>>()
        };
        assert_eq!(strs(&serial), strs(&parallel), "{name}: parallel diverged");
        assert_eq!(strs(&serial), strs(&memoized), "{name}: memoized diverged");
        assert_eq!(memoized.memo_misses, 0, "{name}: warm pass must rebuild nothing");

        t.row(&[
            name.to_string(),
            serial.designs.len().to_string(),
            format!("{serial_s:.4}"),
            format!("{parallel_s:.4}"),
            format!("{memo_s:.4}"),
            format!("{:.2}x", serial_s / parallel_s.max(1e-9)),
            format!("{:.2}x", serial_s / memo_s.max(1e-9)),
        ]);
        csv_rows.push(vec![
            name.to_string(),
            serial.designs.len().to_string(),
            format!("{serial_s:.5}"),
            format!("{parallel_s:.5}"),
            format!("{memo_s:.5}"),
        ]);

        // Frontier maintenance: streaming insert vs all-vs-all reference.
        let pts = analyze_points(&serial.designs, &CostParams::default(), workers);
        let t0 = Instant::now();
        let reference = pareto_frontier(&pts);
        let ref_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let mut streaming = ParetoFrontier::new();
        for p in &pts {
            streaming.insert(p.clone());
        }
        let streamed = streaming.into_sorted();
        let stream_s = t0.elapsed().as_secs_f64();
        assert_eq!(
            streamed.iter().map(|p| (p.cost.area, p.cost.latency)).collect::<Vec<_>>(),
            reference.iter().map(|p| (p.cost.area, p.cost.latency)).collect::<Vec<_>>(),
            "{name}: streaming frontier diverged"
        );
        println!(
            "{name}: frontier {} pts — reference {ref_s:.6}s, streaming {stream_s:.6}s",
            streamed.len()
        );
    }
    print!("{}", t.render());

    let mut csv = Table::new(
        "",
        &["workload", "designs", "serial_seconds", "parallel_seconds", "memoized_seconds"],
    );
    for r in csv_rows {
        csv.row(&r);
    }
    csv.write_csv("bench_results/extraction.csv").ok();
    println!("wrote bench_results/extraction.csv");
}
