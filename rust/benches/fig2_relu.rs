//! Bench F2 — reproduces **paper Figure 2**: the 128-bit-wide ReLU e-graph
//! after rewrite 1 (shrink engine + add loop) and rewrite 2 (parallelize
//! loop + add hardware), reporting the e-graph contents the figure draws
//! plus enumeration timing.
//!
//! Run: `cargo bench --bench fig2_relu`

use hwsplit::bench_util::bench;
use hwsplit::egraph::{EGraph, Runner};
use hwsplit::ir::{parse_expr, Op};
use hwsplit::report::Table;
use hwsplit::rewrites::{sched, split};

fn class_snapshot(eg: &EGraph, root: hwsplit::egraph::Id) -> Vec<String> {
    let mut v: Vec<String> =
        eg.class_nodes(root).map(|n| format!("{}", n.op)).collect();
    v.sort();
    v
}

fn main() {
    println!("== paper Fig. 2 reproduction ==\n");
    let src = "(invoke-relu (relu-engine 128) (input x [128]))";
    let expr = parse_expr(src).unwrap();
    println!("initial program: {src}");

    // --- Rewrite 1: shrink the ReLU unit, add a software loop. ---
    let mut eg = EGraph::new();
    let root = eg.add_expr(&expr);
    println!("\ninitial e-graph: {} e-nodes, {} e-classes", eg.total_nodes(), eg.num_classes());
    let r1 = split::split_relu(2);
    for (id, s) in r1.search(&eg) {
        r1.apply(&mut eg, id, &s);
    }
    eg.rebuild();
    println!(
        "after rewrite 1 (split-relu-x2): {} e-nodes, {} e-classes; root class = {:?}",
        eg.total_nodes(),
        eg.num_classes(),
        class_snapshot(&eg, root)
    );

    // --- Rewrite 2: parallelize the loop, instantiating more hardware. ---
    let r2 = sched::parallelize();
    for (id, s) in r2.search(&eg) {
        r2.apply(&mut eg, id, &s);
    }
    eg.rebuild();
    println!(
        "after rewrite 2 (parallelize):   {} e-nodes, {} e-classes; root class = {:?}",
        eg.total_nodes(),
        eg.num_classes(),
        class_snapshot(&eg, root)
    );
    let designs = hwsplit::egraph::count::designs(&eg, root, 64);
    println!("distinct designs represented: {designs}");
    assert!(designs >= 3.0, "Fig. 2 must represent >= 3 programs");

    // --- Saturation: run both rules to fixpoint (engines 4..128). ---
    let mut t = Table::new(
        "fig2 saturation (rules: split-relu-x2 + parallelize/serialize)",
        &["iter", "e-nodes", "e-classes", "designs(lb)"],
    );
    let mut runner = Runner::new(expr.clone(), hwsplit::rewrites::fig2_rules());
    let report = runner.run(12);
    for it in &report.iterations {
        t.row(&[
            it.iteration.to_string(),
            it.nodes.to_string(),
            it.classes.to_string(),
            format!("{:.3e}", it.designs_lower_bound),
        ]);
    }
    print!("\n{}", t.render());
    t.write_csv("bench_results/fig2_growth.csv").ok();

    // --- Timing: full Fig. 2 enumeration to saturation. ---
    bench("fig2 enumerate-to-saturation", 2, 10, || {
        let mut r = Runner::new(expr.clone(), hwsplit::rewrites::fig2_rules());
        let rep = r.run(12);
        assert!(rep.designs_lower_bound >= 3.0);
    });

    // Engine inventory after saturation: the hardware design points found.
    let mut widths: Vec<usize> = vec![];
    for class in runner.egraph.classes() {
        for n in runner.egraph.class_nodes(class.id) {
            if let Op::ReluEngine { w } = n.op {
                widths.push(w);
            }
        }
    }
    widths.sort();
    widths.dedup();
    println!("\nReLU engine widths represented: {widths:?}");
}
