//! Ablation — which rewrites buy what? (DESIGN.md design-choice ablation.)
//!
//! Runs the ffn and convblock workloads under increasing rule sets
//! (fig2 ⊂ paper ⊂ all) and under paper-minus-one-group variants, and
//! reports design-space size and the best achievable latency/area at a
//! fixed extraction budget. Shows each rewrite group's marginal value —
//! e.g. without `conv-as-im2col-mm` the conv workloads cannot share a
//! matmul engine and the area floor rises.
//!
//! Run: `cargo bench --bench ablation`

use hwsplit::egraph::{Rewrite, RunnerLimits};
use hwsplit::relay::workloads;
use hwsplit::report::{fmt_f64, Table};
use hwsplit::rewrites::RuleSet;
use hwsplit::session::{Query, Session};

fn run_variant(
    name: &str,
    workload: &hwsplit::relay::Workload,
    rules: Vec<Rewrite>,
    t: &mut Table,
) {
    let mut session = Session::builder()
        .workload(workload.clone())
        .custom_rules(rules)
        .iters(5)
        .limits(RunnerLimits { max_nodes: 30_000, ..Default::default() })
        .build()
        .expect("workload lowers");
    let ev = session.query(&Query::new().samples(32)).expect("query");
    let report = &session.enumerate().expect("enumerated").report;
    let best_lat =
        ev.designs.iter().map(|d| d.point.cost.latency).fold(f64::INFINITY, f64::min);
    let best_area =
        ev.designs.iter().map(|d| d.point.cost.area).fold(f64::INFINITY, f64::min);
    t.row(&[
        workload.name.to_string(),
        name.to_string(),
        report.nodes.to_string(),
        format!("{:.2e}", report.designs_lower_bound),
        fmt_f64(best_lat),
        fmt_f64(best_area),
    ]);
}

fn main() {
    let mut t = Table::new(
        "rewrite-set ablation (5 iters, 30k nodes, 32 samples)",
        &["workload", "rules", "e-nodes", "designs", "best-latency", "best-area"],
    );
    for w in [workloads::ffn_block(), workloads::convblock()] {
        run_variant("fig2-only", &w, RuleSet::Fig2.rules(), &mut t);
        run_variant("paper", &w, RuleSet::Paper.rules(), &mut t);
        run_variant("all(+ext)", &w, RuleSet::All.rules(), &mut t);

        // paper minus each group
        let no_par: Vec<Rewrite> = RuleSet::Paper
            .rules()
            .into_iter()
            .filter(|r| r.name != "parallelize" && r.name != "serialize")
            .collect();
        run_variant("paper - par", &w, no_par, &mut t);

        let no_im2col: Vec<Rewrite> = RuleSet::Paper
            .rules()
            .into_iter()
            .filter(|r| r.name != "conv-as-im2col-mm")
            .collect();
        run_variant("paper - im2col", &w, no_im2col, &mut t);

        let no_splits: Vec<Rewrite> = RuleSet::Paper
            .rules()
            .into_iter()
            .filter(|r| !r.name.starts_with("split-"))
            .collect();
        run_variant("paper - splits", &w, no_splits, &mut t);
    }
    print!("{}", t.render());
    t.write_csv("bench_results/ablation.csv").ok();
    println!("wrote bench_results/ablation.csv");
}
