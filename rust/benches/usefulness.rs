//! Bench E3 — design usefulness (paper §3: the set "should also include
//! many useful design points; that is, designs which could turn into
//! efficient hardware").
//!
//! Concretely: the area/latency Pareto frontier of the enumerated designs
//! versus the one-engine-per-kernel-type baseline (Hadjis & Olukotun
//! FPL'19 — the paper's §4 related work), plus simulator utilization for
//! every frontier point.
//!
//! Run: `cargo bench --bench usefulness`

use hwsplit::egraph::RunnerLimits;
use hwsplit::relay::all_workloads;
use hwsplit::report::{fmt_f64, Table};
use hwsplit::rewrites::RuleSet;
use hwsplit::session::{Backend, Query, Session};

fn main() {
    let mut csv = Table::new(
        "usefulness",
        &["workload", "design", "origin", "area", "latency", "sim_cycles", "util"],
    );
    for w in all_workloads() {
        let mut session = Session::builder()
            .workload(w.clone())
            .rules(RuleSet::Paper)
            .iters(5)
            .limits(RunnerLimits { max_nodes: 60_000, ..Default::default() })
            .build()
            .expect("workload lowers");
        let ex = session
            .query(&Query::new().backend(Backend::Sim).samples(64))
            .expect("query");
        let b = &ex.baseline.cost;

        let mut t = Table::new(
            &format!("E3 frontier vs baseline: {}", w.name),
            &["design", "area", "latency", "sim-cycles", "util%"],
        );
        for p in &ex.frontier {
            let sim =
                ex.designs.iter().find(|d| d.point.origin == p.origin).and_then(|d| d.sim.as_ref());
            t.row(&[
                p.origin.clone(),
                fmt_f64(p.cost.area),
                fmt_f64(p.cost.latency),
                sim.map(|s| fmt_f64(s.cycles)).unwrap_or_default(),
                sim.map(|s| format!("{:.0}", s.utilization * 100.0)).unwrap_or_default(),
            ]);
            csv.row(&[
                w.name.clone(),
                "frontier".into(),
                p.origin.clone(),
                fmt_f64(p.cost.area),
                fmt_f64(p.cost.latency),
                sim.map(|s| fmt_f64(s.cycles)).unwrap_or_default(),
                sim.map(|s| format!("{:.3}", s.utilization)).unwrap_or_default(),
            ]);
        }
        t.row(&[
            "BASELINE(FPL19)".into(),
            fmt_f64(b.area),
            fmt_f64(b.latency),
            String::new(),
            String::new(),
        ]);
        csv.row(&[
            w.name.clone(),
            "baseline".into(),
            "one-engine-per-kind".into(),
            fmt_f64(b.area),
            fmt_f64(b.latency),
            String::new(),
            String::new(),
        ]);
        print!("{}", t.render());
        println!("{}\n", ex.frontier_vs_baseline());

        // Shape assertions (who wins, roughly where):
        // 1. enumeration reaches strictly smaller area than the baseline
        //    (deep loops over small engines);
        let min_area =
            ex.designs.iter().map(|d| d.point.cost.area).fold(f64::INFINITY, f64::min);
        assert!(
            min_area < b.area,
            "{}: enumerated min area {min_area} !< baseline {}",
            w.name,
            b.area
        );
        // 2. the frontier is non-trivial (>= 2 points) for multi-op
        //    workloads — a single point would mean no real tradeoff found.
        if w.expr.len() > 3 {
            assert!(ex.frontier.len() >= 2, "{}: degenerate frontier", w.name);
        }
    }
    csv.write_csv("bench_results/usefulness.csv").ok();
    println!("wrote bench_results/usefulness.csv");
}
