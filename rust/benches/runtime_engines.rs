//! Bench E5 — PJRT engine runtime: per-engine invocation latency, compile
//! (cache-miss) cost, and end-to-end MLP inference through the composed
//! design — initial vs rewritten split. The Layer-3 hot-path numbers for
//! §Perf.
//!
//! Requires `make artifacts`; skips gracefully when artifacts are missing.
//!
//! Run: `cargo bench --bench runtime_engines`

use hwsplit::bench_util::{bench, black_box};
use hwsplit::egraph::Runner;
use hwsplit::extract::sample_design;
use hwsplit::ir::{Op, Shape};
use hwsplit::lower::lower_default;
use hwsplit::relay::workloads;
use hwsplit::rewrites;
use hwsplit::runtime::{default_artifact_dir, engine_out_shape, EngineRuntime, PjrtBackend};
use hwsplit::report::Table;
use hwsplit::tensor::{eval_expr, eval_expr_backend, Env, Tensor};

fn main() {
    let mut rt = match EngineRuntime::new(default_artifact_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP runtime benches: {e:#}");
            return;
        }
    };
    println!("artifact library: {} engines\n", rt.available().len());

    // ---- per-engine invocation latency ----
    let engines = [
        Op::ReluEngine { w: 128 },
        Op::AddEngine { w: 128 },
        Op::MmEngine { m: 1, k: 784, n: 128 },
        Op::MmEngine { m: 1, k: 128, n: 64 },
        Op::MmReluEngine { m: 1, k: 128, n: 64 },
        Op::ConvEngine { oh: 28, ow: 28, c: 1, k: 8, kh: 5, kw: 5, stride: 1 },
        Op::PoolEngine { oh: 14, ow: 14, c: 8, kh: 2, kw: 2, stride: 2 },
    ];
    let mut t = Table::new(
        "PJRT engine invocation latency",
        &["engine", "compile(first)", "median-invoke", "MFLOP/s-ish"],
    );
    for e in &engines {
        if !rt.has_engine(e) {
            println!("  (skip {e}: not in manifest)");
            continue;
        }
        let args = example_args(e);
        // First call includes compilation (cache miss).
        let t0 = std::time::Instant::now();
        rt.execute_engine(e, &args).unwrap();
        let compile = t0.elapsed();
        let r = bench(&format!("invoke {e}"), 5, 50, || {
            black_box(rt.execute_engine(e, &args).unwrap());
        });
        let flops = 2.0 * e.engine_macs() as f64;
        t.row(&[
            e.to_string(),
            format!("{compile:.2?}"),
            format!("{:?}", r.median),
            format!("{:.1}", flops / r.median.as_secs_f64() / 1e6),
        ]);
    }
    print!("\n{}", t.render());

    // ---- end-to-end MLP inference: initial vs split design ----
    let w = workloads::mlp();
    let initial = lower_default(&w.expr).expect("workload lowers");
    let mut runner = Runner::new(initial.clone(), rewrites::paper_rules());
    runner.run(4);
    let mut split = hwsplit::runtime::extract_covered(&runner.egraph, runner.root, &rt, true)
        .filter(|d| d.count(|op| op.is_sched()) > 0);
    if split.is_none() {
        for seed in 0..400u64 {
            let cand = sample_design(&runner.egraph, runner.root, seed);
            if cand.count(|op| op.is_sched()) > 0
                && cand.engines().iter().all(|e| rt.has_engine(e))
            {
                split = Some(cand);
                break;
            }
        }
    }

    let mut backend = PjrtBackend::new(rt);
    let mut csv = Table::new("", &["design", "median_us", "inf_per_s"]);
    for (name, design) in
        [("mlp-initial", Some(initial)), ("mlp-rewritten-split", split)]
    {
        let Some(design) = design else {
            println!("(no artifact-covered split design found)");
            continue;
        };
        let env0 = Env::random_for(&design, 42);
        // correctness first
        let want = eval_expr(&design, &mut env0.clone()).unwrap();
        let got = eval_expr_backend(&design, &mut env0.clone(), &mut backend).unwrap();
        assert!(got.allclose(&want, 1e-3), "numerics diverged for {name}");

        let r = bench(&format!("e2e inference {name}"), 3, 30, || {
            let mut env = env0.clone();
            black_box(eval_expr_backend(&design, &mut env, &mut backend).unwrap());
        });
        csv.row(&[
            name.into(),
            format!("{:.1}", r.median.as_secs_f64() * 1e6),
            format!("{:.1}", 1.0 / r.median.as_secs_f64()),
        ]);
    }
    print!("\n{}", csv.render());
    csv.write_csv("bench_results/runtime_engines.csv").ok();

    // Oracle-only comparison: how much does PJRT dispatch cost vs pure
    // Rust math for the same design?
    let design = lower_default(&w.expr).expect("workload lowers");
    let env0 = Env::random_for(&design, 42);
    bench("e2e inference mlp-initial (pure-Rust oracle)", 3, 30, || {
        let mut env = env0.clone();
        black_box(eval_expr(&design, &mut env).unwrap());
    });
}

fn example_args(e: &Op) -> Vec<Tensor> {
    let out = engine_out_shape(e);
    match *e {
        Op::MmEngine { m, k, n } | Op::MmReluEngine { m, k, n } => vec![
            Tensor::random(Shape::new(&[m, k]), 1),
            Tensor::random(Shape::new(&[k, n]), 2),
        ],
        Op::ReluEngine { w } => vec![Tensor::random(Shape::new(&[w]), 3)],
        Op::AddEngine { w } => vec![
            Tensor::random(Shape::new(&[w]), 4),
            Tensor::random(Shape::new(&[w]), 5),
        ],
        Op::ConvEngine { oh, ow, c, k, kh, kw, stride } => {
            let ih = (oh - 1) * stride + kh;
            let iw = (ow - 1) * stride + kw;
            vec![
                Tensor::random(Shape::new(&[c, ih, iw]), 6),
                Tensor::random(Shape::new(&[k, c, kh, kw]), 7),
            ]
        }
        Op::PoolEngine { oh, ow, c, kh, kw, stride } => {
            let ih = (oh - 1) * stride + kh;
            let iw = (ow - 1) * stride + kw;
            vec![Tensor::random(Shape::new(&[c, ih, iw]), 8)]
        }
        _ => vec![Tensor::zeros(out)],
    }
}
