//! Bench — the snapshot-serving trajectory: cold-loading a persisted
//! saturated e-graph vs re-saturating from scratch, and concurrent query
//! throughput against one shared loaded session (the `hwsplit serve` data
//! path, minus the socket), plus an overload run against a real TCP
//! daemon sized far below the offered load — proving degradation is
//! graceful: typed `busy` rejects, bounded p99 for what is admitted, zero
//! hangs. Results merge into `bench_results.json` next to the
//! `perf_quick` records as `{workload, engine, wall_ms, ...}` rows, with
//! `queries_per_sec` / `p50_ms` / `p99_ms` on the throughput row and
//! `offered` / `completed` / `rejected` on the overload row.
//!
//! Two more serving trajectories ride the same file: `serve-shards-N`
//! drives the identical mixed four-workload load through the multi-process
//! supervisor (`hwsplit::serve::shard`) at widths 1/2/4 — the shards-1 row
//! is the single-child baseline, so the aggregate `queries_per_sec` rows
//! read directly as the sharding speedup (the 2x-at-4-shards expectation
//! needs >= 4 cores; the ratio is reported either way) — and
//! `serve-delta-snapshot` times encoding+loading a v3 delta of a widened
//! rule set against re-encoding the full v2 snapshot, asserting the delta
//! is the smaller artifact.
//!
//! Budgets are deliberately tiny so the CI job costs seconds; set
//! `HWSPLIT_PERF_FULL=1` for locally meaningful numbers.
//!
//! Run: `cargo bench --bench serving`

use hwsplit::bench_util::{black_box, snapshot_fixture, snapshot_fixture_path};
use hwsplit::egraph::RunnerLimits;
use hwsplit::relay::workload_by_name;
use hwsplit::report::{JsonRecords, JsonValue};
use hwsplit::rewrites::RuleSet;
use hwsplit::serve::json::Json;
use hwsplit::serve::shard::{ShardConfig, ShardServer};
use hwsplit::serve::{percentile, ServeConfig, Server, SessionStore};
use hwsplit::session::{Objective, Query, Session};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

const WORKLOAD: &str = "attn_block_mh4";
const RULES: RuleSet = RuleSet::All;
const RESULTS: &str = "bench_results.json";
/// Engine labels this bench owns in `bench_results.json` (replaced on
/// every run; everything else in the file is preserved).
const OWNED_ENGINES: &[&str] = &[
    "serve-cold-load",
    "serve-resaturate",
    "serve-throughput",
    "serve-overload",
    "serve-shards-1",
    "serve-shards-2",
    "serve-shards-4",
    "serve-delta-snapshot",
];

fn main() {
    let full = std::env::var_os("HWSPLIT_PERF_FULL").is_some();
    let (iters, max_nodes) = if full { (3, 50_000) } else { (2, 8_000) };
    let samples = if full { 64 } else { 16 };
    let clients: usize = 8;
    let per_client: usize = if full { 32 } else { 6 };

    let mut rows: Vec<Vec<(String, JsonValue)>> = Vec::new();

    // --- Cold-load vs resaturate (the daemon's startup story) ------------
    let _ = snapshot_fixture(WORKLOAD, RULES, iters, max_nodes); // ensure on disk
    let path = snapshot_fixture_path(WORKLOAD, RULES, iters, max_nodes);

    let t0 = Instant::now();
    let session = Session::load_snapshot(&path).expect("snapshot fixture loads");
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(session.enumeration_count(), 0, "cold load must not re-saturate");

    let t0 = Instant::now();
    {
        let w = workload_by_name(WORKLOAD).expect("known workload");
        let mut fresh = Session::builder()
            .workload(w)
            .rules(RULES)
            .iters(iters)
            .limits(RunnerLimits { max_nodes, track_designs: false, ..Default::default() })
            .build()
            .expect("fresh session builds");
        fresh.enumerate().expect("fresh enumeration");
    }
    let resat_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "{WORKLOAD:<14} cold-load {cold_ms:>9.2} ms   resaturate {resat_ms:>9.2} ms   \
         (x{:.1})",
        resat_ms / cold_ms.max(1e-9)
    );
    rows.push(row(WORKLOAD, "serve-cold-load", cold_ms, &[]));
    rows.push(row(WORKLOAD, "serve-resaturate", resat_ms, &[]));

    // --- Concurrent query throughput over one shared session -------------
    // Warm the memo with each seed the clients will issue, so the timed
    // section measures the steady-state serving path (memoized extraction
    // + evaluation), like a long-running daemon — then fan out.
    for seed in 0..4u64 {
        let _ = session
            .answer_query(&Query::new().samples(samples).seed(seed))
            .expect("warmup query answers");
    }
    let session = Arc::new(session);
    let objectives =
        [Objective::Latency, Objective::Area, Objective::Balanced(0.5)];

    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(clients * per_client);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let session = &session;
                scope.spawn(move || {
                    let mut lat = Vec::with_capacity(per_client);
                    for i in 0..per_client {
                        let q = Query::new()
                            .objective(objectives[(c + i) % objectives.len()])
                            .samples(samples)
                            .seed((i % 4) as u64);
                        let t = Instant::now();
                        let ev = session.answer_query(&q).expect("query answers");
                        black_box(ev.designs.len());
                        lat.push(t.elapsed().as_secs_f64() * 1e3);
                    }
                    lat
                })
            })
            .collect();
        for h in handles {
            latencies.extend(h.join().expect("client thread"));
        }
    });
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    latencies.sort_by(f64::total_cmp);
    let served = latencies.len();
    let qps = served as f64 / wall;
    let p50 = percentile(&latencies, 50.0);
    let p99 = percentile(&latencies, 99.0);
    println!(
        "{WORKLOAD:<14} {clients} clients x {per_client} queries: \
         {qps:>8.1} queries/s   p50 {p50:.2} ms   p99 {p99:.2} ms"
    );
    rows.push(row(
        WORKLOAD,
        "serve-throughput",
        wall * 1e3,
        &[
            ("queries_per_sec", qps),
            ("p50_ms", p50),
            ("p99_ms", p99),
            ("clients", clients as f64),
            ("queries", served as f64),
        ],
    ));

    // --- Overload: offered load > capacity degrades gracefully -----------
    // A real TCP daemon sized tiny (2 workers, queue depth 2) under 16
    // concurrent one-shot clients. The contract under overload: every
    // connection gets an answer (a result or a typed `busy` — never a
    // hang), the admitted requests keep a bounded p99, and the excess
    // shows up as nonzero typed rejects instead of unbounded queueing.
    let mut store = SessionStore::new(2);
    store.register(&path).expect("fixture registers");
    let config = ServeConfig {
        workers: 2,
        queue_depth: 2,
        request_timeout_ms: 10_000,
        ..ServeConfig::default()
    };
    let server =
        Arc::new(Server::bind_with("127.0.0.1:0", Arc::new(store), config).expect("binds"));
    let addr = server.local_addr().expect("bound addr");
    let acceptor = {
        let server = server.clone();
        std::thread::spawn(move || server.run())
    };

    let request = format!("{{\"workload\":\"{WORKLOAD}\",\"samples\":{samples},\"seed\":0}}\n");
    // Pre-warm: the first query decodes the snapshot and fills the memo,
    // so the timed section measures steady-state overload behavior.
    assert!(one_shot(addr, &request).0, "pre-warm query must complete");

    let threads: usize = 16;
    let shots: usize = if full { 8 } else { 4 };
    let offered = threads * shots;
    let t0 = Instant::now();
    let mut admitted_lat: Vec<f64> = Vec::new();
    let mut rejected = 0usize;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let request = &request;
                scope.spawn(move || {
                    let mut lat = Vec::new();
                    let mut rej = 0usize;
                    for _ in 0..shots {
                        let (completed, ms) = one_shot(addr, request);
                        if completed {
                            lat.push(ms);
                        } else {
                            rej += 1;
                        }
                    }
                    (lat, rej)
                })
            })
            .collect();
        for h in handles {
            let (lat, rej) = h.join().expect("overload client");
            admitted_lat.extend(lat);
            rejected += rej;
        }
    });
    let overload_wall = t0.elapsed().as_secs_f64().max(1e-9);
    server.request_shutdown();
    acceptor.join().expect("accept loop joins").expect("accept loop ran clean");

    admitted_lat.sort_by(f64::total_cmp);
    let completed = admitted_lat.len();
    let overload_p99 = percentile(&admitted_lat, 99.0);
    let overload_qps = completed as f64 / overload_wall;
    assert_eq!(completed + rejected, offered, "every connection got an answer");
    assert!(completed > 0, "the admitted fraction must be served");
    assert!(rejected > 0, "offered load 4x capacity must produce typed rejects");
    assert!(overload_p99.is_finite(), "admitted requests keep a measurable p99");
    println!(
        "{WORKLOAD:<14} overload {threads}x{shots} vs 2+2 capacity: \
         {completed} completed, {rejected} rejected (typed busy), \
         p99 {overload_p99:.2} ms, {overload_qps:.1} queries/s"
    );
    rows.push(row(
        WORKLOAD,
        "serve-overload",
        overload_wall * 1e3,
        &[
            ("offered", offered as f64),
            ("completed", completed as f64),
            ("rejected", rejected as f64),
            ("queries_per_sec", overload_qps),
            ("p99_ms", overload_p99),
            ("workers", 2.0),
            ("queue_depth", 2.0),
        ],
    ));

    // --- Shard-parallel serving: aggregate throughput by shard width -----
    // Four workloads spread across child daemons; every width serves the
    // identical mixed load through the supervisor's router, so the rows
    // are directly comparable (shards-1 is one child process plus the same
    // router hop, not the in-process daemon above).
    let shard_cases: [(&str, RuleSet); 4] = [
        (WORKLOAD, RULES),
        ("relu128", RuleSet::Fig2),
        ("mlp", RuleSet::Paper),
        ("lenet", RuleSet::Paper),
    ];
    let shard_paths: Vec<String> = shard_cases
        .iter()
        .map(|&(name, rules)| {
            let _ = snapshot_fixture(name, rules, iters, max_nodes); // ensure on disk
            snapshot_fixture_path(name, rules, iters, max_nodes).to_string_lossy().into_owned()
        })
        .collect();
    let names: Vec<&str> = shard_cases.iter().map(|&(n, _)| n).collect();
    let routed_per_client: usize = if full { 16 } else { 4 };
    let mut shards1_qps = f64::NAN;
    for shards in [1usize, 2, 4] {
        let config = ShardConfig::new(env!("CARGO_BIN_EXE_hwsplit"), shards);
        let server = Arc::new(
            ShardServer::bind("127.0.0.1:0", &shard_paths, config).expect("supervisor binds"),
        );
        let addr = server.local_addr().expect("bound addr");
        let runner = {
            let server = server.clone();
            std::thread::spawn(move || server.run())
        };
        // Pre-warm every child (snapshot decode + memo fill for seed 0),
        // so the timed section measures steady-state routed serving.
        for &name in &names {
            let req = format!("{{\"workload\":\"{name}\",\"samples\":{samples},\"seed\":0}}\n");
            assert!(one_shot(addr, &req).0, "pre-warm query must complete");
        }
        let (shard_wall, mut lats) =
            routed_throughput(addr, clients, routed_per_client, &names, samples);
        server.request_shutdown();
        runner.join().expect("supervisor joins").expect("supervisor ran clean");
        lats.sort_by(f64::total_cmp);
        let qps = lats.len() as f64 / shard_wall;
        if shards == 1 {
            shards1_qps = qps;
        }
        let speedup = qps / shards1_qps.max(1e-9);
        let p50 = percentile(&lats, 50.0);
        let p99 = percentile(&lats, 99.0);
        println!(
            "{WORKLOAD:<14} shards-{shards} aggregate: {qps:>8.1} queries/s   \
             p50 {p50:.2} ms   p99 {p99:.2} ms   (x{speedup:.2} vs shards-1)"
        );
        let mut extra = vec![
            ("queries_per_sec", qps),
            ("p50_ms", p50),
            ("p99_ms", p99),
            ("shards", shards as f64),
            ("clients", clients as f64),
            ("queries", lats.len() as f64),
        ];
        if shards > 1 {
            extra.push(("speedup_vs_1", speedup));
        }
        rows.push(row(WORKLOAD, &format!("serve-shards-{shards}"), shard_wall * 1e3, &extra));
    }

    // --- Delta snapshot: persist the growth, not the world ----------------
    // Widen a Paper-rules base to the full rule set, then persist the
    // grown graph both ways. The delta must be the smaller artifact; the
    // row records encode/load wall-clock and byte sizes for both.
    let _ = snapshot_fixture(WORKLOAD, RuleSet::Paper, iters, max_nodes); // ensure on disk
    let base_path = snapshot_fixture_path(WORKLOAD, RuleSet::Paper, iters, max_nodes);
    let mut grown = Session::load_snapshot(&base_path).expect("base fixture loads");
    grown.extend_rules(RuleSet::All, 1).expect("rule set widens");
    let full_path = base_path.with_extension("full.hws");
    let delta_path = base_path.with_extension("delta.hws");

    let t0 = Instant::now();
    grown.save_snapshot(&full_path).expect("full re-encode saves");
    let full_encode_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    black_box(Session::load_snapshot(&full_path).expect("full loads").enumeration_count());
    let full_load_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t0 = Instant::now();
    grown.save_snapshot_delta(&delta_path, &base_path).expect("delta saves");
    let delta_encode_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    black_box(Session::load_snapshot(&delta_path).expect("delta chain loads").enumeration_count());
    let delta_load_ms = t0.elapsed().as_secs_f64() * 1e3;

    let full_bytes = std::fs::metadata(&full_path).expect("full meta").len();
    let delta_bytes = std::fs::metadata(&delta_path).expect("delta meta").len();
    assert!(
        delta_bytes < full_bytes,
        "delta ({delta_bytes} B) must beat the full re-encode ({full_bytes} B)"
    );
    println!(
        "{WORKLOAD:<14} delta-vs-full: encode {delta_encode_ms:.2} vs {full_encode_ms:.2} ms   \
         load {delta_load_ms:.2} vs {full_load_ms:.2} ms   \
         {delta_bytes} vs {full_bytes} bytes"
    );
    rows.push(row(
        WORKLOAD,
        "serve-delta-snapshot",
        delta_encode_ms + delta_load_ms,
        &[
            ("delta_encode_ms", delta_encode_ms),
            ("delta_load_ms", delta_load_ms),
            ("full_encode_ms", full_encode_ms),
            ("full_load_ms", full_load_ms),
            ("delta_bytes", delta_bytes as f64),
            ("full_bytes", full_bytes as f64),
        ],
    ));

    merge_into_results(RESULTS, rows);
    println!("merged {} serving records into {RESULTS}", OWNED_ENGINES.len());
}

/// Fan `clients` persistent connections at the router, each issuing
/// `per_client` queries round-robin across `names`. Returns the wall
/// clock (seconds) and per-query latencies (ms); any non-ok response
/// panics — a healthy sharded deployment answers everything.
fn routed_throughput(
    addr: SocketAddr,
    clients: usize,
    per_client: usize,
    names: &[&str],
    samples: usize,
) -> (f64, Vec<f64>) {
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(clients * per_client);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || {
                    let stream = TcpStream::connect(addr).expect("connects");
                    stream
                        .set_read_timeout(Some(Duration::from_secs(60)))
                        .expect("read timeout set");
                    let mut writer = stream.try_clone().expect("clones");
                    let mut reader = BufReader::new(stream);
                    let mut lat = Vec::with_capacity(per_client);
                    let mut line = String::new();
                    for i in 0..per_client {
                        let name = names[(c + i) % names.len()];
                        let req = format!(
                            "{{\"workload\":\"{name}\",\"samples\":{samples},\"seed\":{}}}\n",
                            i % 2
                        );
                        let t = Instant::now();
                        writer.write_all(req.as_bytes()).expect("writes");
                        line.clear();
                        reader.read_line(&mut line).expect("router answers");
                        assert!(line.contains("\"ok\":true"), "routed query failed: {line}");
                        lat.push(t.elapsed().as_secs_f64() * 1e3);
                    }
                    lat
                })
            })
            .collect();
        for h in handles {
            latencies.extend(h.join().expect("routed client"));
        }
    });
    (t0.elapsed().as_secs_f64().max(1e-9), latencies)
}

/// One connect → query → single response line → close. Returns
/// `(completed, latency_ms)`: `completed` is `false` for a typed `busy`
/// refusal. Anything else — garbage, a hang past the read timeout, an
/// unexpected error — panics, because an overloaded daemon must still
/// answer every connection in a typed way.
fn one_shot(addr: SocketAddr, request: &str) -> (bool, f64) {
    let t = Instant::now();
    let stream = TcpStream::connect(addr).expect("connects");
    stream.set_read_timeout(Some(Duration::from_secs(30))).expect("read timeout set");
    let mut writer = stream.try_clone().expect("clones");
    let mut reader = BufReader::new(stream);
    writer.write_all(request.as_bytes()).expect("writes");
    let mut line = String::new();
    reader.read_line(&mut line).expect("an overloaded daemon must still answer");
    let j = Json::parse(line.trim()).expect("response is valid JSON");
    let ms = t.elapsed().as_secs_f64() * 1e3;
    match (j.get("ok").and_then(Json::as_bool), j.get("code").and_then(Json::as_str)) {
        (Some(true), _) => (true, ms),
        (Some(false), Some("busy")) => (false, ms),
        _ => panic!("unexpected overload response: {line}"),
    }
}

/// One `bench_results.json` record: the shared `{workload, engine,
/// wall_ms}` shape plus any extra numeric fields.
fn row(
    workload: &str,
    engine: &str,
    wall_ms: f64,
    extra: &[(&str, f64)],
) -> Vec<(String, JsonValue)> {
    let mut rec = vec![
        ("workload".to_string(), JsonValue::Str(workload.to_string())),
        ("engine".to_string(), JsonValue::Str(engine.to_string())),
        ("wall_ms".to_string(), JsonValue::Num(wall_ms)),
    ];
    for &(k, v) in extra {
        rec.push((k.to_string(), JsonValue::Num(v)));
    }
    rec
}

/// Rewrite `bench_results.json` preserving every record whose `engine`
/// this bench does not own (`JsonRecords::write` truncates, so records
/// from `perf_quick` must be carried over), then appending `new_rows`.
fn merge_into_results(path: &str, new_rows: Vec<Vec<(String, JsonValue)>>) {
    let mut out = JsonRecords::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(parsed) = Json::parse(&text) {
            if let Some(records) = parsed.as_array() {
                for rec in records {
                    let engine = rec.get("engine").and_then(Json::as_str).unwrap_or("");
                    if OWNED_ENGINES.contains(&engine) {
                        continue;
                    }
                    if let Json::Obj(fields) = rec {
                        out.push(
                            fields.iter().map(|(k, v)| (k.clone(), to_value(v))).collect(),
                        );
                    }
                }
            }
        }
    }
    for rec in new_rows {
        out.push(rec);
    }
    out.write(path).expect("write bench_results.json");
}

/// Re-encode a parsed scalar for the record writer. Records only ever
/// hold strings and numbers; anything else round-trips as its display
/// form so no data is silently dropped.
fn to_value(j: &Json) -> JsonValue {
    match j {
        Json::Str(s) => JsonValue::Str(s.clone()),
        Json::Num(v) => JsonValue::Num(*v),
        Json::Bool(b) => JsonValue::Str(b.to_string()),
        Json::Null => JsonValue::Num(f64::NAN), // renders as null again
        other => JsonValue::Str(format!("{other:?}")),
    }
}
