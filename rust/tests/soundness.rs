//! Integration: rewrite soundness by differential testing.
//!
//! For every workload and every rule set: lower, enumerate, sample designs
//! from the e-graph, and check that each extracted design computes exactly
//! the same function as the original Relay graph on random inputs. This is
//! the repo's strongest end-to-end guarantee: if any rewrite, the e-graph,
//! extraction, or the evaluator were unsound, some sampled design would
//! diverge.

use hwsplit::rewrites::RuleSet;
use hwsplit::egraph::{Runner, RunnerLimits};
use hwsplit::extract::{sample_design, Extractor};
use hwsplit::lower::lower_default;
use hwsplit::prop;
use hwsplit::relay::all_workloads;
use hwsplit::tensor::{eval_expr, Env};

fn check_workload(name: &str, rules: RuleSet, iters: usize, samples: u64) {
    let w = all_workloads().into_iter().find(|w| w.name == name).unwrap();
    let lowered = lower_default(&w.expr).expect("workload lowers");
    let mut runner = Runner::new(lowered, rules.rules())
        .with_limits(RunnerLimits { max_nodes: 40_000, ..Default::default() });
    runner.run(iters);
    let (eg, root) = (&runner.egraph, runner.root);

    let want = eval_expr(&w.expr, &mut Env::random_for(&w.expr, 77)).unwrap();
    // Relative tolerance: deep split designs legally reassociate f32 sums
    // (sched-reduce), so error scales with output magnitude.
    let tol = 1e-4_f32.max(1e-5 * want.data.iter().fold(0.0f32, |m, v| m.max(v.abs())));
    // Greedy extractions.
    type CostFn = fn(
        &hwsplit::egraph::EGraph,
        &hwsplit::ir::Node,
        &dyn Fn(hwsplit::egraph::Id) -> f64,
    ) -> f64;
    let costs: [(&str, CostFn); 3] = [
        ("latency", hwsplit::extract::latency_cost),
        ("area", hwsplit::extract::area_cost),
        ("size", hwsplit::extract::size_cost),
    ];
    for (tag, cost) in costs {
        let d = Extractor::new(eg, cost).extract(eg, root);
        d.typecheck().unwrap_or_else(|e| panic!("{name}/{tag}: ill-typed: {e}"));
        let got = eval_expr(&d, &mut Env::random_for(&d, 77)).unwrap();
        assert!(
            want.allclose(&got, tol),
            "{name}/{tag} diverged: {:?}",
            want.max_abs_diff(&got)
        );
    }
    // Random samples.
    for seed in 0..samples {
        let d = sample_design(eg, root, seed);
        d.typecheck().unwrap_or_else(|e| panic!("{name}/sample{seed}: ill-typed: {e}"));
        let got = eval_expr(&d, &mut Env::random_for(&d, 77)).unwrap();
        assert!(
            want.allclose(&got, tol),
            "{name}/sample{seed} diverged: {:?}\n{d}",
            want.max_abs_diff(&got)
        );
    }
}

#[test]
fn relu128_paper_rules_sound() {
    check_workload("relu128", RuleSet::Paper, 8, 24);
}

#[test]
fn convblock_paper_rules_sound() {
    check_workload("convblock", RuleSet::Paper, 4, 12);
}

#[test]
fn ffn_block_all_rules_sound() {
    check_workload("ffn_block", RuleSet::All, 4, 12);
}

#[test]
fn resnet_block_paper_rules_sound() {
    check_workload("resnet_block", RuleSet::Paper, 3, 8);
}

#[test]
fn mlp_all_rules_sound() {
    check_workload("mlp", RuleSet::All, 4, 10);
}

#[test]
fn lenet_paper_rules_sound() {
    check_workload("lenet", RuleSet::Paper, 3, 6);
}

/// Transformer block: matmul/softmax/affine-layernorm/gelu reifications
/// and the mm/gelu/emul splits applied to them stay semantics-preserving.
#[test]
fn attn_block_all_rules_sound() {
    check_workload("attn_block", RuleSet::All, 2, 6);
}

/// Multi-head transformer block: head packing (batched transposes +
/// reshapes), the batch-matmul loop lowering, rank-3 softmax, and the
/// head-axis `split-bmm-batch[-par]` tilings all preserve semantics under
/// saturation — every sampled design still computes 4-head attention.
#[test]
fn attn_block_mh4_all_rules_sound() {
    check_workload("attn_block_mh4", RuleSet::All, 2, 6);
}

/// Grouped-query transformer block: both query-head groups batch-matmul
/// against the SAME shared K/V pack, so the lowered graph holds one K/V
/// subtree with two consumers. Head-axis tilings and everything downstream
/// must stay semantics-preserving when rewrites fire inside that shared
/// subtree (a change there affects both groups at once).
#[test]
fn attn_block_gqa_all_rules_sound() {
    check_workload("attn_block_gqa", RuleSet::All, 2, 6);
}

/// Depthwise-separable block: dwconv reification + channel/row splits.
#[test]
fn mobile_block_paper_rules_sound() {
    check_workload("mobile_block", RuleSet::Paper, 3, 8);
}

/// Stride-2 downsampling block: `split-dwconv-oh`'s halo slices must stay
/// sound when the engine stride is 2, not just 1.
#[test]
fn mobile_block_s2_paper_rules_sound() {
    check_workload("mobile_block_s2", RuleSet::Paper, 3, 8);
}

/// Property: the `split-dwconv-oh` halo math — input chunk length
/// `(ohc-1)*stride + kh`, chunk start `i*ohc*stride` — is exact for
/// stride ∈ {1, 2} across output heights and kernel sizes: every design in
/// the 2-element space (whole engine / row-split loop) evaluates
/// identically.
#[test]
fn dwconv_oh_halo_sound_under_stride() {
    use hwsplit::egraph::EGraph;
    use hwsplit::rewrites::split::split_dwconv_oh;
    for &(oh, kh, stride) in &[(8usize, 3usize, 1usize), (8, 3, 2), (4, 3, 2), (8, 5, 2), (6, 3, 2)]
    {
        let (c, ow, kw) = (4usize, oh, kh);
        let ih = (oh - 1) * stride + kh;
        let iw = (ow - 1) * stride + kw;
        let src = format!(
            "(invoke-dw-conv (dw-conv-engine {oh} {ow} {c} {kh} {kw} {stride}) \
               (input x [{c} {ih} {iw}]) (weight w [{c} {kh} {kw}]))"
        );
        let e = hwsplit::ir::parse_expr(&src).unwrap();
        let want = eval_expr(&e, &mut Env::random_for(&e, 21)).unwrap();
        let mut eg = EGraph::new();
        let root = eg.add_expr(&e);
        let rule = split_dwconv_oh(2);
        let mut applied = 0;
        for (id, s) in rule.search(&eg) {
            if rule.apply(&mut eg, id, &s) {
                applied += 1;
            }
        }
        eg.rebuild();
        assert_eq!(applied, 1, "oh={oh} kh={kh} s={stride}: split must fire");
        for seed in 0..6 {
            let d = sample_design(&eg, root, seed);
            d.typecheck()
                .unwrap_or_else(|e| panic!("oh={oh} kh={kh} s={stride}: ill-typed: {e}"));
            let got = eval_expr(&d, &mut Env::random_for(&d, 21)).unwrap();
            assert!(
                want.allclose(&got, 1e-5),
                "oh={oh} kh={kh} s={stride} seed={seed}: halo math diverged: {:?}\n{d}",
                want.max_abs_diff(&got)
            );
        }
    }
}

/// Property: random rule subsets on random workloads stay sound.
#[test]
fn random_rule_subsets_sound() {
    prop::check("random-rule-subsets", 6, |rng| {
        let all = hwsplit::rewrites::all_rules();
        let workloads = all_workloads();
        let w = &workloads[rng.below(workloads.len())];
        // Pick a random half of the rules.
        let rules: Vec<_> = all.into_iter().filter(|_| rng.f64() < 0.5).collect();
        if rules.is_empty() {
            return;
        }
        let lowered = lower_default(&w.expr).expect("workload lowers");
        let mut runner = Runner::new(lowered, rules)
            .with_limits(RunnerLimits { max_nodes: 15_000, ..Default::default() });
        runner.run(3);
        let want = eval_expr(&w.expr, &mut Env::random_for(&w.expr, 5)).unwrap();
        let tol =
            1e-4_f32.max(1e-5 * want.data.iter().fold(0.0f32, |m, v| m.max(v.abs())));
        for seed in 0..4 {
            let d = sample_design(&runner.egraph, runner.root, seed);
            let got = eval_expr(&d, &mut Env::random_for(&d, 5)).unwrap();
            assert!(want.allclose(&got, tol), "{} diverged under subset", w.name);
        }
    });
}

/// Property: structural e-graph invariants hold after arbitrary interleaved
/// rewrite/rebuild sequences (canonical class ids, live children, memo
/// pointing at live classes).
#[test]
fn egraph_invariants_under_random_rewriting() {
    prop::check("egraph-invariants", 8, |rng| {
        let workloads = all_workloads();
        let w = &workloads[rng.below(workloads.len())];
        let lowered = lower_default(&w.expr).expect("workload lowers");
        let all = hwsplit::rewrites::all_rules();
        let mut eg = hwsplit::egraph::EGraph::new();
        eg.add_expr(&lowered);
        // Random interleaving of single-rule application rounds.
        for _ in 0..rng.range(2, 5) {
            let rule = &all[rng.below(all.len())];
            let mut matches = rule.search(&eg);
            matches.truncate(500);
            for (id, s) in matches {
                rule.apply(&mut eg, id, &s);
            }
            if rng.f64() < 0.7 {
                eg.rebuild();
                eg.check_invariants();
            }
        }
        eg.rebuild();
        eg.check_invariants();
    });
}

/// Property: the design-count lower bound never decreases across rewrite
/// iterations (the e-graph only gains equivalences).
#[test]
fn design_count_is_monotone() {
    let w = all_workloads().into_iter().find(|w| w.name == "convblock").unwrap();
    let lowered = lower_default(&w.expr).expect("workload lowers");
    let mut runner = Runner::new(lowered, RuleSet::Paper.rules())
        .with_limits(RunnerLimits { max_nodes: 20_000, ..Default::default() });
    let report = runner.run(5);
    let counts: Vec<f64> = report.iterations.iter().map(|i| i.designs_lower_bound).collect();
    for pair in counts.windows(2) {
        assert!(pair[1] >= pair[0], "design count regressed: {counts:?}");
    }
}
