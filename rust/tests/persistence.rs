//! Snapshot round-trip properties: a session saved to disk and loaded in
//! a fresh `Session` answers queries **bit-identically** with **zero
//! re-saturation** and a **warm extraction memo** — across workloads and
//! extraction worker counts — and damaged files surface as typed errors,
//! never panics. The v3 delta format rides the same contract: a delta
//! resolved against its base answers identically to a full re-encode (in
//! fewer bytes), and every way the chain can break — truncation, a bit
//! flip, a rewritten or missing base — is a typed corruption error.

use hwsplit::error::Error;
use hwsplit::persist;
use hwsplit::rewrites::RuleSet;
use hwsplit::session::{Evaluation, Objective, Query, Session};
use std::fmt::Write as _;
use std::path::PathBuf;

/// Per-test scratch file under the OS temp dir (unique per process, so
/// parallel test binaries never collide).
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hwsplit-persistence-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir.join(name)
}

/// The round-trip workload matrix: small budgets, one rule set each.
const CASES: &[(&str, RuleSet, usize, usize)] = &[
    ("relu128", RuleSet::Fig2, 4, 8_000),
    ("lenet", RuleSet::Paper, 3, 8_000),
    ("attn_block_mh4", RuleSet::All, 2, 8_000),
    ("attn_block_gqa", RuleSet::All, 2, 8_000),
    ("mobile_block_s2", RuleSet::Paper, 3, 8_000),
];

fn build_session(name: &str, rules: RuleSet, iters: usize, max_nodes: usize) -> Session {
    Session::builder()
        .workload(hwsplit::relay::workload_by_name(name).expect("known workload"))
        .rules(rules)
        .iters(iters)
        .limits(hwsplit::egraph::RunnerLimits {
            max_nodes,
            track_designs: false,
            ..Default::default()
        })
        .build()
        .expect("session builds")
}

/// The query batch every round-trip case answers: mixed objectives, two
/// seeds — enough to exercise greedy + sampled cost tables.
fn batch() -> Vec<Query> {
    vec![
        Query::new().objective(Objective::Latency).samples(6).seed(0),
        Query::new().objective(Objective::Area).samples(6).seed(0),
        Query::new().objective(Objective::Balanced(0.5)).samples(6).seed(9),
    ]
}

/// Canonical timing-free rendering of a batch answer, for bit-identity
/// comparison across processes/sessions (wall-clock fields excluded; all
/// design identities, costs, frontier points and memo-relevant counts
/// included).
fn canon(evals: &[Evaluation]) -> String {
    let mut s = String::new();
    for ev in evals {
        let _ = writeln!(
            s,
            "workload={} objective={:?} backend={:?} requested={} distinct={}",
            ev.workload, ev.objective, ev.backend, ev.extract.requested, ev.extract.distinct
        );
        let _ = writeln!(s, "baseline={:?}", ev.baseline.cost);
        for d in &ev.designs {
            let _ = writeln!(s, "design [{}] {} {:?}", d.point.origin, d.point.expr, d.point.cost);
        }
        for p in &ev.frontier {
            let _ = writeln!(s, "frontier {} {:?}", p.expr, p.cost);
        }
    }
    s
}

#[test]
fn save_load_roundtrip_is_bit_identical_across_workloads_and_workers() {
    for &(name, rules, iters, max_nodes) in CASES {
        for workers in [1usize, 4] {
            let path = scratch(&format!("{name}-w{workers}.hws"));

            let mut original = build_session(name, rules, iters, max_nodes);
            original.set_extract_workers(workers);
            let expected = canon(&original.run_queries(&batch()).expect("original answers"));
            original.save_snapshot(&path).expect("snapshot saves");
            assert_eq!(original.enumeration_count(), 1, "{name}: one enumeration on save side");

            let mut loaded = Session::load_snapshot(&path).expect("snapshot loads");
            loaded.set_extract_workers(workers);
            let answers = loaded.run_queries(&batch()).expect("loaded session answers");
            assert_eq!(
                canon(&answers),
                expected,
                "{name} (workers={workers}): loaded answers must be bit-identical"
            );
            assert_eq!(
                loaded.enumeration_count(),
                0,
                "{name}: a loaded session must never re-run fixpoint enumeration"
            );
            for ev in &answers {
                assert_eq!(
                    ev.extract.memo_misses, 0,
                    "{name} (workers={workers}): every cost table the batch needs was \
                     persisted, so the loaded memo must serve all of them"
                );
                assert!(ev.extract.memo_hits > 0, "{name}: hits must register");
            }
        }
    }
}

#[test]
fn loaded_session_epoch_keeps_new_seeds_cacheable() {
    // A seed the save side never touched: first query solves its tables
    // (misses), the repeat is fully memoized — proving the persisted graph
    // epoch and cache epoch agree (a mismatch would invalidate the memo on
    // every query).
    let path = scratch("epoch.hws");
    let mut original = build_session("relu128", RuleSet::Fig2, 4, 8_000);
    original.save_snapshot(&path).expect("snapshot saves");

    let mut loaded = Session::load_snapshot(&path).expect("snapshot loads");
    let fresh = Query::new().samples(5).seed(123);
    let first = loaded.query(&fresh).expect("first answer");
    assert!(first.extract.memo_misses > 0, "unseen seed must solve tables once");
    let second = loaded.query(&fresh).expect("second answer");
    assert_eq!(second.extract.memo_misses, 0, "repeat must be fully memoized");
    assert_eq!(second.extract.memo_hits, first.extract.memo_hits + first.extract.memo_misses);
    assert_eq!(loaded.enumeration_count(), 0);
}

#[test]
fn snapshot_header_peek_matches_session() {
    let path = scratch("peek.hws");
    let mut s = build_session("lenet", RuleSet::Paper, 2, 8_000);
    s.save_snapshot(&path).expect("snapshot saves");

    let meta = persist::peek_header(&path).expect("header peeks");
    assert_eq!(meta.workload, "lenet");
    assert_eq!(meta.format_version, persist::FORMAT_VERSION);
    assert_eq!(
        meta.workload_fingerprint,
        persist::workload_fingerprint(&s.workload().expr.to_string())
    );
    assert!(meta.payload_len > 0);
}

#[test]
fn truncated_snapshots_are_corrupt_errors_not_panics() {
    let path = scratch("trunc-src.hws");
    let mut s = build_session("relu128", RuleSet::Fig2, 4, 8_000);
    s.save_snapshot(&path).expect("snapshot saves");
    let bytes = std::fs::read(&path).expect("snapshot reads");

    for cut in [0, 3, 9, 20, bytes.len() / 2, bytes.len() - 1] {
        let p = scratch(&format!("trunc-{cut}.hws"));
        std::fs::write(&p, &bytes[..cut]).expect("truncated write");
        match Session::load_snapshot(&p) {
            Err(Error::SnapshotCorrupt(msg)) => {
                assert!(!msg.is_empty(), "corrupt error should say what broke")
            }
            other => panic!("cut at {cut}: expected SnapshotCorrupt, got {other:?}"),
        }
    }
}

#[test]
fn bad_magic_and_future_version_are_typed_errors() {
    let path = scratch("damage-src.hws");
    let mut s = build_session("relu128", RuleSet::Fig2, 4, 8_000);
    s.save_snapshot(&path).expect("snapshot saves");
    let bytes = std::fs::read(&path).expect("snapshot reads");

    let mut wrong_magic = bytes.clone();
    wrong_magic[0] ^= 0xFF;
    let p = scratch("bad-magic.hws");
    std::fs::write(&p, &wrong_magic).expect("write");
    assert!(matches!(Session::load_snapshot(&p), Err(Error::SnapshotCorrupt(_))));

    let mut future = bytes.clone();
    future[8..12].copy_from_slice(&99u32.to_le_bytes());
    let p = scratch("future-version.hws");
    std::fs::write(&p, &future).expect("write");
    match Session::load_snapshot(&p) {
        Err(Error::SnapshotVersion { found, supported }) => {
            assert_eq!(found, 99);
            assert_eq!(supported, persist::DELTA_FORMAT_VERSION);
        }
        other => panic!("expected SnapshotVersion, got {other:?}"),
    }

    // Payload bit-flip: caught by the checksum before any decode runs.
    let mut flipped = bytes;
    let last = flipped.len() - 1;
    flipped[last] ^= 0x01;
    let p = scratch("bit-flip.hws");
    std::fs::write(&p, &flipped).expect("write");
    assert!(matches!(Session::load_snapshot(&p), Err(Error::SnapshotCorrupt(_))));
}

#[test]
fn delta_snapshot_chain_answers_identically_to_a_full_snapshot() {
    let base_path = scratch("delta-base.hws");
    let mut base = build_session("relu128", RuleSet::Fig2, 4, 8_000);
    base.save_snapshot(&base_path).expect("base saves");

    // Grow a loaded copy, then persist the growth twice: as a full v2
    // re-encode and as a v3 delta against the base file.
    let mut grown = Session::load_snapshot(&base_path).expect("base loads");
    let added = grown.extend_rules(RuleSet::Paper, 2).expect("rule set widens");
    assert!(added > 0, "Paper must add rules beyond Fig2");
    let expected = canon(&grown.run_queries(&batch()).expect("grown answers"));
    let full_path = scratch("delta-full.hws");
    grown.save_snapshot(&full_path).expect("full re-encode saves");
    let delta_path = scratch("delta-delta.hws");
    grown.save_snapshot_delta(&delta_path, &base_path).expect("delta saves");

    // The delta is the point: smaller than re-encoding the world.
    let full_len = std::fs::metadata(&full_path).expect("full meta").len();
    let delta_len = std::fs::metadata(&delta_path).expect("delta meta").len();
    assert!(delta_len < full_len, "delta ({delta_len} B) must beat full ({full_len} B)");

    // Header peek sees the chain without decoding the payload…
    let meta = persist::peek_header(&delta_path).expect("delta header peeks");
    assert_eq!(meta.format_version, persist::DELTA_FORMAT_VERSION);
    assert_eq!(meta.workload, "relu128");
    assert!(meta.base_fingerprint.is_some(), "v3 headers carry the base fingerprint");
    let delta_bytes = std::fs::read(&delta_path).expect("delta reads");
    let named = persist::delta_base_name(&delta_bytes).expect("delta names its base");
    assert_eq!(named, "delta-base.hws");

    // …and resolving it answers bit-identically to the full re-encode,
    // with zero re-saturation either way.
    for path in [&full_path, &delta_path] {
        let mut loaded = Session::load_snapshot(path).expect("chain loads");
        assert_eq!(
            canon(&loaded.run_queries(&batch()).expect("loaded answers")),
            expected,
            "{}: loaded answers must be bit-identical",
            path.display()
        );
        assert_eq!(loaded.enumeration_count(), 0, "{}", path.display());
    }
}

#[test]
fn damaged_delta_chains_are_corrupt_errors_not_panics() {
    let base_path = scratch("chain-base.hws");
    let mut base = build_session("relu128", RuleSet::Fig2, 4, 8_000);
    base.save_snapshot(&base_path).expect("base saves");
    let mut grown = Session::load_snapshot(&base_path).expect("base loads");
    grown.extend_rules(RuleSet::Paper, 1).expect("rule set widens");
    let delta_path = scratch("chain-delta.hws");
    grown.save_snapshot_delta(&delta_path, &base_path).expect("delta saves");
    let base_bytes = std::fs::read(&base_path).expect("base reads");
    let delta_bytes = std::fs::read(&delta_path).expect("delta reads");

    // Truncation anywhere in the delta file is typed corruption.
    for cut in [0, 3, 9, 20, delta_bytes.len() / 2, delta_bytes.len() - 1] {
        let p = scratch(&format!("chain-trunc-{cut}.hws"));
        std::fs::write(&p, &delta_bytes[..cut]).expect("truncated write");
        match Session::load_snapshot(&p) {
            Err(Error::SnapshotCorrupt(msg)) => {
                assert!(!msg.is_empty(), "corrupt error should say what broke")
            }
            other => panic!("cut at {cut}: expected SnapshotCorrupt, got {other:?}"),
        }
    }

    // A payload bit-flip fails the delta's own checksum.
    let mut flipped = delta_bytes.clone();
    let last = flipped.len() - 1;
    flipped[last] ^= 0x01;
    let p = scratch("chain-flip.hws");
    std::fs::write(&p, &flipped).expect("write");
    match Session::load_snapshot(&p) {
        Err(Error::SnapshotCorrupt(msg)) => assert!(msg.contains("checksum"), "{msg}"),
        other => panic!("expected SnapshotCorrupt, got {other:?}"),
    }

    // A rewritten base no longer matches the delta's base fingerprint:
    // stale chains are refused, not silently mis-resolved.
    let bad_dir = scratch("chain-badbase");
    std::fs::create_dir_all(&bad_dir).expect("dir");
    let mut bad_base = base_bytes.clone();
    let last = bad_base.len() - 1;
    bad_base[last] ^= 0x01;
    std::fs::write(bad_dir.join("chain-base.hws"), &bad_base).expect("write");
    std::fs::write(bad_dir.join("chain-delta.hws"), &delta_bytes).expect("write");
    match Session::load_snapshot(bad_dir.join("chain-delta.hws")) {
        Err(Error::SnapshotCorrupt(msg)) => assert!(msg.contains("base fingerprint"), "{msg}"),
        other => panic!("expected SnapshotCorrupt, got {other:?}"),
    }

    // A missing base is typed too, naming the file the chain wanted.
    let lone_dir = scratch("chain-nobase");
    std::fs::create_dir_all(&lone_dir).expect("dir");
    std::fs::write(lone_dir.join("chain-delta.hws"), &delta_bytes).expect("write");
    match Session::load_snapshot(lone_dir.join("chain-delta.hws")) {
        Err(Error::SnapshotCorrupt(msg)) => assert!(msg.contains("unreadable"), "{msg}"),
        other => panic!("expected SnapshotCorrupt, got {other:?}"),
    }
}

#[test]
fn missing_file_is_an_io_error() {
    match Session::load_snapshot(scratch("does-not-exist.hws")) {
        Err(Error::Io(msg)) => assert!(!msg.is_empty()),
        other => panic!("expected Io, got {other:?}"),
    }
}
