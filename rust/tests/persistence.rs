//! Snapshot round-trip properties: a session saved to disk and loaded in
//! a fresh `Session` answers queries **bit-identically** with **zero
//! re-saturation** and a **warm extraction memo** — across workloads and
//! extraction worker counts — and damaged files surface as typed errors,
//! never panics.

use hwsplit::error::Error;
use hwsplit::persist;
use hwsplit::rewrites::RuleSet;
use hwsplit::session::{Evaluation, Objective, Query, Session};
use std::fmt::Write as _;
use std::path::PathBuf;

/// Per-test scratch file under the OS temp dir (unique per process, so
/// parallel test binaries never collide).
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hwsplit-persistence-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir.join(name)
}

/// The round-trip workload matrix: small budgets, one rule set each.
const CASES: &[(&str, RuleSet, usize, usize)] = &[
    ("relu128", RuleSet::Fig2, 4, 8_000),
    ("lenet", RuleSet::Paper, 3, 8_000),
    ("attn_block_mh4", RuleSet::All, 2, 8_000),
    ("attn_block_gqa", RuleSet::All, 2, 8_000),
    ("mobile_block_s2", RuleSet::Paper, 3, 8_000),
];

fn build_session(name: &str, rules: RuleSet, iters: usize, max_nodes: usize) -> Session {
    Session::builder()
        .workload(hwsplit::relay::workload_by_name(name).expect("known workload"))
        .rules(rules)
        .iters(iters)
        .limits(hwsplit::egraph::RunnerLimits {
            max_nodes,
            track_designs: false,
            ..Default::default()
        })
        .build()
        .expect("session builds")
}

/// The query batch every round-trip case answers: mixed objectives, two
/// seeds — enough to exercise greedy + sampled cost tables.
fn batch() -> Vec<Query> {
    vec![
        Query::new().objective(Objective::Latency).samples(6).seed(0),
        Query::new().objective(Objective::Area).samples(6).seed(0),
        Query::new().objective(Objective::Balanced(0.5)).samples(6).seed(9),
    ]
}

/// Canonical timing-free rendering of a batch answer, for bit-identity
/// comparison across processes/sessions (wall-clock fields excluded; all
/// design identities, costs, frontier points and memo-relevant counts
/// included).
fn canon(evals: &[Evaluation]) -> String {
    let mut s = String::new();
    for ev in evals {
        let _ = writeln!(
            s,
            "workload={} objective={:?} backend={:?} requested={} distinct={}",
            ev.workload, ev.objective, ev.backend, ev.extract.requested, ev.extract.distinct
        );
        let _ = writeln!(s, "baseline={:?}", ev.baseline.cost);
        for d in &ev.designs {
            let _ = writeln!(s, "design [{}] {} {:?}", d.point.origin, d.point.expr, d.point.cost);
        }
        for p in &ev.frontier {
            let _ = writeln!(s, "frontier {} {:?}", p.expr, p.cost);
        }
    }
    s
}

#[test]
fn save_load_roundtrip_is_bit_identical_across_workloads_and_workers() {
    for &(name, rules, iters, max_nodes) in CASES {
        for workers in [1usize, 4] {
            let path = scratch(&format!("{name}-w{workers}.hws"));

            let mut original = build_session(name, rules, iters, max_nodes);
            original.set_extract_workers(workers);
            let expected = canon(&original.run_queries(&batch()).expect("original answers"));
            original.save_snapshot(&path).expect("snapshot saves");
            assert_eq!(original.enumeration_count(), 1, "{name}: one enumeration on save side");

            let mut loaded = Session::load_snapshot(&path).expect("snapshot loads");
            loaded.set_extract_workers(workers);
            let answers = loaded.run_queries(&batch()).expect("loaded session answers");
            assert_eq!(
                canon(&answers),
                expected,
                "{name} (workers={workers}): loaded answers must be bit-identical"
            );
            assert_eq!(
                loaded.enumeration_count(),
                0,
                "{name}: a loaded session must never re-run fixpoint enumeration"
            );
            for ev in &answers {
                assert_eq!(
                    ev.extract.memo_misses, 0,
                    "{name} (workers={workers}): every cost table the batch needs was \
                     persisted, so the loaded memo must serve all of them"
                );
                assert!(ev.extract.memo_hits > 0, "{name}: hits must register");
            }
        }
    }
}

#[test]
fn loaded_session_epoch_keeps_new_seeds_cacheable() {
    // A seed the save side never touched: first query solves its tables
    // (misses), the repeat is fully memoized — proving the persisted graph
    // epoch and cache epoch agree (a mismatch would invalidate the memo on
    // every query).
    let path = scratch("epoch.hws");
    let mut original = build_session("relu128", RuleSet::Fig2, 4, 8_000);
    original.save_snapshot(&path).expect("snapshot saves");

    let mut loaded = Session::load_snapshot(&path).expect("snapshot loads");
    let fresh = Query::new().samples(5).seed(123);
    let first = loaded.query(&fresh).expect("first answer");
    assert!(first.extract.memo_misses > 0, "unseen seed must solve tables once");
    let second = loaded.query(&fresh).expect("second answer");
    assert_eq!(second.extract.memo_misses, 0, "repeat must be fully memoized");
    assert_eq!(second.extract.memo_hits, first.extract.memo_hits + first.extract.memo_misses);
    assert_eq!(loaded.enumeration_count(), 0);
}

#[test]
fn snapshot_header_peek_matches_session() {
    let path = scratch("peek.hws");
    let mut s = build_session("lenet", RuleSet::Paper, 2, 8_000);
    s.save_snapshot(&path).expect("snapshot saves");

    let meta = persist::peek_header(&path).expect("header peeks");
    assert_eq!(meta.workload, "lenet");
    assert_eq!(meta.format_version, persist::FORMAT_VERSION);
    assert_eq!(
        meta.workload_fingerprint,
        persist::workload_fingerprint(&s.workload().expr.to_string())
    );
    assert!(meta.payload_len > 0);
}

#[test]
fn truncated_snapshots_are_corrupt_errors_not_panics() {
    let path = scratch("trunc-src.hws");
    let mut s = build_session("relu128", RuleSet::Fig2, 4, 8_000);
    s.save_snapshot(&path).expect("snapshot saves");
    let bytes = std::fs::read(&path).expect("snapshot reads");

    for cut in [0, 3, 9, 20, bytes.len() / 2, bytes.len() - 1] {
        let p = scratch(&format!("trunc-{cut}.hws"));
        std::fs::write(&p, &bytes[..cut]).expect("truncated write");
        match Session::load_snapshot(&p) {
            Err(Error::SnapshotCorrupt(msg)) => {
                assert!(!msg.is_empty(), "corrupt error should say what broke")
            }
            other => panic!("cut at {cut}: expected SnapshotCorrupt, got {other:?}"),
        }
    }
}

#[test]
fn bad_magic_and_future_version_are_typed_errors() {
    let path = scratch("damage-src.hws");
    let mut s = build_session("relu128", RuleSet::Fig2, 4, 8_000);
    s.save_snapshot(&path).expect("snapshot saves");
    let bytes = std::fs::read(&path).expect("snapshot reads");

    let mut wrong_magic = bytes.clone();
    wrong_magic[0] ^= 0xFF;
    let p = scratch("bad-magic.hws");
    std::fs::write(&p, &wrong_magic).expect("write");
    assert!(matches!(Session::load_snapshot(&p), Err(Error::SnapshotCorrupt(_))));

    let mut future = bytes.clone();
    future[8..12].copy_from_slice(&99u32.to_le_bytes());
    let p = scratch("future-version.hws");
    std::fs::write(&p, &future).expect("write");
    match Session::load_snapshot(&p) {
        Err(Error::SnapshotVersion { found, supported }) => {
            assert_eq!(found, 99);
            assert_eq!(supported, persist::FORMAT_VERSION);
        }
        other => panic!("expected SnapshotVersion, got {other:?}"),
    }

    // Payload bit-flip: caught by the checksum before any decode runs.
    let mut flipped = bytes;
    let last = flipped.len() - 1;
    flipped[last] ^= 0x01;
    let p = scratch("bit-flip.hws");
    std::fs::write(&p, &flipped).expect("write");
    assert!(matches!(Session::load_snapshot(&p), Err(Error::SnapshotCorrupt(_))));
}

#[test]
fn missing_file_is_an_io_error() {
    match Session::load_snapshot(scratch("does-not-exist.hws")) {
        Err(Error::Io(msg)) => assert!(!msg.is_empty()),
        other => panic!("expected Io, got {other:?}"),
    }
}
