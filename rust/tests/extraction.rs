//! Integration tests for the parallel, memoized, streaming extraction
//! layer — the ISSUE-3 acceptance properties as executable checks:
//!
//! * parallel `extract_designs` is **bit-identical** across
//!   `extract_workers ∈ {1, 2, 4}` (property-tested over seeds/sample
//!   counts on `relu128`, plain on LeNet);
//! * the streaming Pareto frontier equals the collect-then-filter
//!   reference, both on random cost clouds (property) and on real
//!   LeNet / `relu128` query results;
//! * a second `Query` against an unchanged session performs **zero**
//!   extractor fixpoint rebuilds, observed via the memo hit-rate stat.

use hwsplit::cost::{DesignCost, DesignStats};
use hwsplit::egraph::{Runner, RunnerLimits};
use hwsplit::extract::{
    extract_designs, pareto_frontier, DesignPoint, ExtractCache, ExtractOptions, ParetoFrontier,
};
use hwsplit::ir::parse_expr;
use hwsplit::prop;
use hwsplit::relay::{workloads, Workload};
use hwsplit::rewrites::RuleSet;
use hwsplit::session::{Objective, Query, Session};

/// Enumerate one workload with small budgets, once, for direct
/// extract-layer tests.
fn enumerated(w: &Workload, iters: usize) -> (hwsplit::egraph::EGraph, hwsplit::egraph::Id) {
    let lowered = hwsplit::lower::lower_default(&w.expr).expect("workload lowers");
    let mut runner = Runner::new(lowered, RuleSet::Paper.rules()).with_limits(RunnerLimits {
        max_nodes: 30_000,
        track_designs: false,
        ..Default::default()
    });
    runner.run(iters);
    (runner.egraph, runner.root)
}

fn rendered(
    eg: &hwsplit::egraph::EGraph,
    root: hwsplit::egraph::Id,
    opts: &ExtractOptions,
) -> Vec<(String, String)> {
    let cache = ExtractCache::new();
    extract_designs(eg, root, opts, &cache)
        .designs
        .into_iter()
        .map(|(origin, e)| (origin, e.to_string()))
        .collect()
}

/// Property: the extracted design set is bit-identical for any worker
/// count, over random seeds and sample counts (relu128).
#[test]
fn prop_parallel_extraction_is_bit_identical_across_worker_counts() {
    let (eg, root) = enumerated(&workloads::relu128(), 5);
    prop::check("extract-worker-equivalence", 12, |rng| {
        let samples = rng.range(1, 24);
        let seed = rng.next_u64();
        let base = rendered(&eg, root, &ExtractOptions { samples, seed, workers: 1 });
        for workers in [2usize, 4] {
            let got = rendered(&eg, root, &ExtractOptions { samples, seed, workers });
            assert_eq!(got, base, "workers={workers} diverged (seed {seed:#x})");
        }
    });
}

/// The same equivalence on LeNet — a deep multi-engine e-graph.
#[test]
fn lenet_extraction_is_bit_identical_across_worker_counts() {
    let (eg, root) = enumerated(&workloads::lenet(), 3);
    let opts = |workers| ExtractOptions { samples: 12, seed: 7, workers };
    let base = rendered(&eg, root, &opts(1));
    assert!(base.len() >= 3, "LeNet must yield a diverse set");
    assert_eq!(rendered(&eg, root, &opts(2)), base);
    assert_eq!(rendered(&eg, root, &opts(4)), base);
}

/// Property: streaming insert-with-eviction equals the collect-then-filter
/// reference on random cost clouds (ties and duplicates included).
#[test]
fn prop_streaming_frontier_equals_reference_filter() {
    let expr = parse_expr("(invoke-relu (relu-engine 8) (input x [8]))").unwrap();
    prop::check("streaming-frontier-equivalence", 60, |rng| {
        let n = rng.range(1, 50);
        let points: Vec<DesignPoint> = (0..n)
            .map(|i| DesignPoint {
                expr: expr.clone(),
                cost: DesignCost {
                    // Coarse grid so ties and duplicates actually occur.
                    area: (rng.below(10) + 1) as f64,
                    latency: (rng.below(10) + 1) as f64,
                    ..Default::default()
                },
                stats: DesignStats::default(),
                origin: format!("p{i}"),
            })
            .collect();
        let mut streaming = ParetoFrontier::new();
        let mut sizes = Vec::new();
        for p in &points {
            streaming.insert(p.clone());
            sizes.push(streaming.len());
        }
        // Sizes are recorded per round and never exceed the running count.
        for (i, s) in sizes.iter().enumerate() {
            assert!(*s >= 1 && *s <= i + 1);
        }
        let key = |ps: &[DesignPoint]| {
            ps.iter()
                .map(|p| (p.cost.area, p.cost.latency, p.origin.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(key(&streaming.into_sorted()), key(&pareto_frontier(&points)));
    });
}

/// The streamed frontier a real query reports equals the reference filter
/// over its evaluated designs — on relu128 and LeNet.
#[test]
fn query_frontier_equals_reference_on_relu128_and_lenet() {
    for (w, iters) in [(workloads::relu128(), 4), (workloads::lenet(), 3)] {
        let mut s = Session::builder()
            .workload(w)
            .rules(RuleSet::Paper)
            .iters(iters)
            .limits(RunnerLimits { max_nodes: 30_000, ..Default::default() })
            .build()
            .unwrap();
        let ev = s.query(&Query::new().samples(16)).unwrap();
        let reference =
            pareto_frontier(&ev.designs.iter().map(|d| d.point.clone()).collect::<Vec<_>>());
        let key = |ps: &[DesignPoint]| {
            ps.iter()
                .map(|p| (p.cost.area, p.cost.latency, p.origin.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(key(&ev.frontier), key(&reference), "{}", ev.workload);
        assert_eq!(ev.extract.frontier_size(), ev.frontier.len());
    }
}

/// THE memo acceptance property: the second query against an unchanged
/// session rebuilds zero extractor fixpoints — every cost table is served
/// from the session memo — and still answers identically.
#[test]
fn second_query_performs_zero_fixpoint_rebuilds() {
    let mut s = Session::builder()
        .workload(workloads::ffn_block())
        .rules(RuleSet::Paper)
        .iters(4)
        .limits(RunnerLimits { max_nodes: 30_000, ..Default::default() })
        .build()
        .unwrap();
    let q = |o: Objective| Query::new().objective(o).samples(12).seed(5);

    let cold = s.query(&q(Objective::Latency)).unwrap();
    assert_eq!(
        cold.extract.memo_misses,
        12 + 2,
        "cold query solves one fixpoint per sample plus the greedy endpoints"
    );
    assert_eq!(cold.extract.memo_hits, 0);

    let warm = s.query(&q(Objective::Area)).unwrap();
    assert_eq!(warm.extract.memo_misses, 0, "unchanged session must not rebuild");
    assert_eq!(warm.extract.memo_hits, 12 + 2);
    assert!((warm.extract.memo_hit_rate() - 1.0).abs() < 1e-12);
    assert_eq!(s.enumeration_count(), 1);

    // Same design identities, re-ranked.
    let keys = |ev: &hwsplit::session::Evaluation| {
        ev.designs.iter().map(|d| d.point.expr.to_string()).collect::<Vec<_>>()
    };
    assert_eq!(keys(&cold), keys(&warm));
}

/// ISSUE-5 acceptance, space side: on `attn_block_mh4` the head-axis
/// tilings fire during saturation, and the latency-greedy extraction
/// (which always prefers `sched-par` over `sched-loop`) lands on a design
/// that parallelizes along the leading (head) axis.
#[test]
fn attn_block_mh4_head_axis_splits_enter_the_space() {
    use hwsplit::extract::{latency_cost, Extractor};
    use hwsplit::ir::Op;
    let w = workloads::attn_block_mh4();
    let lowered = hwsplit::lower::lower_default(&w.expr).expect("workload lowers");
    let mut runner = Runner::new(lowered, RuleSet::All.rules()).with_limits(RunnerLimits {
        max_nodes: 30_000,
        track_designs: false,
        ..Default::default()
    });
    let report = runner.run(2);
    let fired = |name: &str| -> usize {
        let ri = report
            .rule_names
            .iter()
            .position(|n| n == name)
            .unwrap_or_else(|| panic!("rule {name} not in the set"));
        report
            .iterations
            .iter()
            .map(|it| it.per_rule.get(ri).map_or(0, |r| r.applied))
            .sum()
    };
    assert!(
        fired("split-bmm-batch-x2") >= 1,
        "head tiling never applied:\n{}",
        report.rule_table()
    );
    assert!(
        fired("split-bmm-batch-par-x2") >= 1,
        "parallel head tiling never applied:\n{}",
        report.rule_table()
    );
    // The latency-greedy design parallelizes a leading-axis schedule (the
    // head loop of the batch-matmuls and/or the per-head softmax sweep).
    let d = Extractor::new(&runner.egraph, latency_cost).extract(&runner.egraph, runner.root);
    d.typecheck().expect("greedy design well-typed");
    assert!(
        d.count(|op| matches!(op, Op::SchedPar { axis: 0, extent } if *extent >= 2)) >= 1,
        "latency-greedy design has no head-axis parallelism:\n{d}"
    );
}

/// ISSUE-5 acceptance, serving side: `attn_block_mh4` extracts a ≥2-point
/// Pareto frontier; every evaluated design round-trips print→parse; and
/// the frontier matches-or-dominates the single-head initial design's
/// area at equal budget (the per-head 16x32x16 score engines are 4x
/// smaller than the fused 16x128x16 one, and the splits shrink them
/// further).
#[test]
fn attn_block_mh4_frontier_roundtrips_and_undercuts_single_head_area() {
    use hwsplit::cost::{cost_of, CostParams};
    let mut s = Session::builder()
        .workload(workloads::attn_block_mh4())
        .rules(RuleSet::All)
        .iters(2)
        .limits(RunnerLimits { max_nodes: 30_000, ..Default::default() })
        .build()
        .unwrap();
    let ev = s.query(&Query::new().samples(16)).unwrap();
    assert!(ev.designs.len() >= 3, "too few designs");
    assert!(ev.frontier.len() >= 2, "trivial frontier ({} points)", ev.frontier.len());
    for d in &ev.designs {
        let txt = d.point.expr.to_string();
        let back = parse_expr(&txt).unwrap_or_else(|e| panic!("reparse failed: {e}\n{txt}"));
        assert_eq!(back.to_string(), txt, "print→parse round-trip");
    }
    let single_head = hwsplit::lower::lower_default(&workloads::attn_block().expr).unwrap();
    let sh_initial = cost_of(&single_head, &CostParams::default());
    assert!(
        ev.frontier.iter().any(|p| p.cost.area <= sh_initial.area),
        "no multi-head frontier point at or below the single-head initial area \
         ({} vs {:?})",
        sh_initial.area,
        ev.frontier.iter().map(|p| p.cost.area).collect::<Vec<_>>()
    );
}

/// `run_queries` shares one extraction pass across a batch and leaves the
/// memo warm for follow-up queries.
#[test]
fn batched_queries_share_extraction_and_warm_the_memo() {
    let mut s = Session::builder()
        .workload(workloads::relu128())
        .rules(RuleSet::Paper)
        .iters(4)
        .limits(RunnerLimits { max_nodes: 30_000, ..Default::default() })
        .build()
        .unwrap();
    let batch = [
        Query::new().objective(Objective::Latency).samples(8),
        Query::new().objective(Objective::Area).samples(8),
    ];
    let evs = s.run_queries(&batch).unwrap();
    assert_eq!(evs.len(), 2);
    assert_eq!(s.enumeration_count(), 1);
    // The batch reports one shared pass...
    assert_eq!(evs[0].extract.memo_misses, 8 + 2);
    assert_eq!(evs[1].extract.memo_misses, 8 + 2, "shared pass is reported verbatim");
    // ...and a later lone query finds everything memoized.
    let after = s.query(&Query::new().samples(8)).unwrap();
    assert_eq!(after.extract.memo_misses, 0);
    // Batched answers equal the sequential ones.
    let mut s2 = Session::builder()
        .workload(workloads::relu128())
        .rules(RuleSet::Paper)
        .iters(4)
        .limits(RunnerLimits { max_nodes: 30_000, ..Default::default() })
        .build()
        .unwrap();
    for (q, batched) in batch.iter().zip(&evs) {
        let solo = s2.query(q).unwrap();
        let keys = |ev: &hwsplit::session::Evaluation| {
            ev.designs.iter().map(|d| d.point.expr.to_string()).collect::<Vec<_>>()
        };
        assert_eq!(keys(&solo), keys(batched));
        assert_eq!(
            solo.best().unwrap().point.expr.to_string(),
            batched.best().unwrap().point.expr.to_string()
        );
    }
}
