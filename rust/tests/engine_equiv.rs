//! Engine equivalence: the incremental, parallel saturation engine (the
//! default) must enumerate exactly the same space as the full-rescan
//! reference path under the [`SimpleScheduler`] — equal e-class count,
//! e-node count, and distinct-design lower bound — on the in-tree
//! workloads, for any search-worker count.
//!
//! Fresh loop-variable symbols make the two runs' e-graphs *isomorphic*
//! rather than identical (a split applied at the same point in both runs
//! draws different names from the global counter), so the tests compare
//! structure-determined quantities, never symbol-dependent text.

use hwsplit::egraph::{Runner, RunnerLimits, SearchMode, StopReason};
use hwsplit::lower::lower_default;
use hwsplit::prop;
use hwsplit::relay::workload_by_name;
use hwsplit::rewrites::RuleSet;

#[derive(Debug, PartialEq)]
struct Outcome {
    stop: StopReason,
    classes: usize,
    nodes: usize,
    designs: f64,
    iterations: usize,
}

fn enumerate(
    workload: &str,
    rules: RuleSet,
    iters: usize,
    max_nodes: usize,
    mode: SearchMode,
    workers: usize,
) -> Outcome {
    let w = workload_by_name(workload).expect("known workload");
    let lowered = lower_default(&w.expr).expect("workload lowers");
    let mut runner = Runner::new(lowered, rules.rules())
        .with_limits(RunnerLimits { max_nodes, ..Default::default() })
        .with_search_mode(mode)
        .with_search_workers(workers);
    let rep = runner.run(iters);
    Outcome {
        stop: rep.stop,
        classes: rep.classes,
        nodes: rep.nodes,
        designs: rep.designs_lower_bound,
        iterations: rep.iterations.len(),
    }
}

/// The acceptance workload pair: LeNet (the heaviest in-tree network) and
/// the quickstart workload (relu128 under the Fig. 2 rules).

#[test]
fn lenet_incremental_matches_full_rescan() {
    let reference =
        enumerate("lenet", RuleSet::Paper, 3, 20_000, SearchMode::FullRescan, 1);
    for workers in [1, 4] {
        let incremental =
            enumerate("lenet", RuleSet::Paper, 3, 20_000, SearchMode::Incremental, workers);
        assert_eq!(incremental, reference, "workers={workers}");
    }
    assert!(reference.nodes > 100, "enumeration must actually grow the graph");
}

#[test]
fn quickstart_incremental_matches_full_rescan_to_saturation() {
    let reference =
        enumerate("relu128", RuleSet::Fig2, 16, 50_000, SearchMode::FullRescan, 1);
    let incremental =
        enumerate("relu128", RuleSet::Fig2, 16, 50_000, SearchMode::Incremental, 4);
    assert_eq!(incremental, reference);
    assert_eq!(
        reference.stop,
        StopReason::Saturated,
        "the quickstart space is finite and must saturate under both engines"
    );
    assert!(reference.designs >= 3.0, "Fig. 2 yields at least three designs");
}

/// Property: equivalence holds across random iteration budgets, rule sets
/// and worker counts on both acceptance workloads.
#[test]
fn incremental_engine_equivalence_property() {
    prop::check("incremental-equivalence", 5, |rng| {
        let (workload, rules) = *rng.choose(&[
            ("relu128", RuleSet::Fig2),
            ("relu128", RuleSet::Paper),
            ("lenet", RuleSet::Paper),
        ]);
        let iters = rng.range(2, 4);
        let workers = rng.range(1, 8);
        let reference =
            enumerate(workload, rules, iters, 15_000, SearchMode::FullRescan, 1);
        let incremental =
            enumerate(workload, rules, iters, 15_000, SearchMode::Incremental, workers);
        assert_eq!(
            incremental, reference,
            "{workload}/{rules:?} iters={iters} workers={workers}"
        );
    });
}

/// The incremental engine's whole point: after the first iteration it
/// searches far fewer classes than live in the graph.
#[test]
fn incremental_search_narrows_after_first_iteration() {
    let w = workload_by_name("lenet").unwrap();
    let lowered = lower_default(&w.expr).unwrap();
    let mut runner = Runner::new(lowered, RuleSet::Paper.rules())
        .with_limits(RunnerLimits { max_nodes: 20_000, ..Default::default() });
    let rep = runner.run(3);
    assert!(rep.iterations.len() >= 2, "need at least two iterations");
    let it1 = &rep.iterations[1];
    assert!(
        it1.searched_classes < it1.classes,
        "iteration 1 searched {} of {} classes — not incremental",
        it1.searched_classes,
        it1.classes
    );
}
