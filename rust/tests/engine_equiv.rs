//! Engine equivalence: the incremental, parallel saturation engine (the
//! default) must enumerate exactly the same space as the full-rescan
//! reference path under the [`SimpleScheduler`] — equal e-class count,
//! e-node count, and distinct-design lower bound — on the in-tree
//! workloads, for any search-worker count.
//!
//! Fresh loop-variable symbols make the two runs' e-graphs *isomorphic*
//! rather than identical (a split applied at the same point in both runs
//! draws different names from the global counter), so the tests compare
//! structure-determined quantities, never symbol-dependent text.

use hwsplit::egraph::{Runner, RunnerLimits, SearchMode, StopReason};
use hwsplit::lower::lower_default;
use hwsplit::prop;
use hwsplit::relay::workload_by_name;
use hwsplit::rewrites::RuleSet;

#[derive(Debug, PartialEq)]
struct Outcome {
    stop: StopReason,
    classes: usize,
    nodes: usize,
    designs: f64,
    iterations: usize,
}

fn enumerate(
    workload: &str,
    rules: RuleSet,
    iters: usize,
    max_nodes: usize,
    mode: SearchMode,
    workers: usize,
) -> Outcome {
    let w = workload_by_name(workload).expect("known workload");
    let lowered = lower_default(&w.expr).expect("workload lowers");
    let mut runner = Runner::new(lowered, rules.rules())
        .with_limits(RunnerLimits { max_nodes, ..Default::default() })
        .with_search_mode(mode)
        .with_search_workers(workers);
    let rep = runner.run(iters);
    Outcome {
        stop: rep.stop,
        classes: rep.classes,
        nodes: rep.nodes,
        designs: rep.designs_lower_bound,
        iterations: rep.iterations.len(),
    }
}

/// The acceptance workload pair: LeNet (the heaviest in-tree network) and
/// the quickstart workload (relu128 under the Fig. 2 rules).

#[test]
fn lenet_incremental_matches_full_rescan() {
    let reference =
        enumerate("lenet", RuleSet::Paper, 3, 20_000, SearchMode::FullRescan, 1);
    for workers in [1, 4] {
        let incremental =
            enumerate("lenet", RuleSet::Paper, 3, 20_000, SearchMode::Incremental, workers);
        assert_eq!(incremental, reference, "workers={workers}");
    }
    assert!(reference.nodes > 100, "enumeration must actually grow the graph");
}

#[test]
fn quickstart_incremental_matches_full_rescan_to_saturation() {
    let reference =
        enumerate("relu128", RuleSet::Fig2, 16, 50_000, SearchMode::FullRescan, 1);
    let incremental =
        enumerate("relu128", RuleSet::Fig2, 16, 50_000, SearchMode::Incremental, 4);
    assert_eq!(incremental, reference);
    assert_eq!(
        reference.stop,
        StopReason::Saturated,
        "the quickstart space is finite and must saturate under both engines"
    );
    assert!(reference.designs >= 3.0, "Fig. 2 yields at least three designs");
}

/// Property: equivalence holds across random iteration budgets, rule sets
/// and worker counts on both acceptance workloads.
#[test]
fn incremental_engine_equivalence_property() {
    prop::check("incremental-equivalence", 5, |rng| {
        let (workload, rules) = *rng.choose(&[
            ("relu128", RuleSet::Fig2),
            ("relu128", RuleSet::Paper),
            ("lenet", RuleSet::Paper),
        ]);
        let iters = rng.range(2, 4);
        let workers = rng.range(1, 8);
        let reference =
            enumerate(workload, rules, iters, 15_000, SearchMode::FullRescan, 1);
        let incremental =
            enumerate(workload, rules, iters, 15_000, SearchMode::Incremental, workers);
        assert_eq!(
            incremental, reference,
            "{workload}/{rules:?} iters={iters} workers={workers}"
        );
    });
}

// ---------------------------------------------------------------------
// Parallel apply determinism
// ---------------------------------------------------------------------
//
// Unlike the engine-mode comparison above, apply *width* must be truly
// invisible: staging fans out against the frozen graph but intents commit
// single-threaded in stream order, and staged fresh loop-variable names
// are derived from (iteration, stream index) rather than a global
// counter — so the e-graphs are bit-identical, not merely isomorphic.

/// A complete structural rendering of the e-graph: epoch, then every live
/// class in id order with its type and its e-nodes in member order. Equal
/// fingerprints mean the same classes holding the same nodes in the same
/// slots after the same mutation history.
fn fingerprint(eg: &hwsplit::egraph::EGraph) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "epoch={} classes={} nodes={}",
        eg.epoch(),
        eg.num_classes(),
        eg.total_nodes()
    );
    let mut classes: Vec<_> = eg.classes().collect();
    classes.sort_by_key(|c| c.id);
    for c in classes {
        let _ = writeln!(s, "class {:?} ty={:?}", c.id, c.ty);
        for n in eg.class_nodes(c.id) {
            let _ = writeln!(s, "  {n:?}");
        }
    }
    s
}

/// Every report field except wall-clock durations (those legitimately
/// vary run to run; nothing else may).
fn canon_report(r: &hwsplit::egraph::RunnerReport) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "stop={:?} nodes={} classes={} designs={}",
        r.stop, r.nodes, r.classes, r.designs_lower_bound
    );
    let _ = writeln!(s, "rules={:?}", r.rule_names);
    for it in &r.iterations {
        let _ = writeln!(
            s,
            "iter {} nodes={} classes={} applied={} unions={} designs={} searched={} waves={}",
            it.iteration,
            it.nodes,
            it.classes,
            it.applied,
            it.unions_total,
            it.designs_lower_bound,
            it.searched_classes,
            it.apply_waves
        );
        for pr in &it.per_rule {
            let _ = writeln!(s, "  {pr:?}");
        }
    }
    s
}

fn saturate_at_width(
    workload: &str,
    rules: RuleSet,
    iters: usize,
    apply_workers: usize,
) -> (String, String) {
    let w = workload_by_name(workload).expect("known workload");
    let lowered = lower_default(&w.expr).expect("workload lowers");
    let mut runner = Runner::new(lowered, rules.rules())
        .with_limits(RunnerLimits { max_nodes: 12_000, ..Default::default() })
        .with_apply_workers(apply_workers);
    let rep = runner.run(iters);
    (fingerprint(&runner.egraph), canon_report(&rep))
}

fn check_apply_widths(workload: &str, rules: RuleSet, iters: usize) {
    let (fp1, rep1) = saturate_at_width(workload, rules, iters, 1);
    for workers in [2usize, 4] {
        let (fp, rep) = saturate_at_width(workload, rules, iters, workers);
        assert_eq!(fp, fp1, "{workload}: e-graph differs at apply-workers={workers}");
        assert_eq!(rep, rep1, "{workload}: report differs at apply-workers={workers}");
    }
}

#[test]
fn lenet_is_bit_identical_across_apply_widths() {
    check_apply_widths("lenet", RuleSet::Paper, 3);
}

#[test]
fn attn_block_mh4_is_bit_identical_across_apply_widths() {
    check_apply_widths("attn_block_mh4", RuleSet::All, 2);
}

/// Session-level: widths 1 and 4 must serve identical designs and an
/// identical Pareto frontier (Debug-rendered identities and costs; timing
/// fields excluded by construction).
#[test]
fn served_frontiers_are_identical_across_apply_widths() {
    use hwsplit::session::{Objective, Query, Session};
    use std::fmt::Write as _;
    let serve = |apply_workers: usize| -> String {
        let mut session = Session::builder()
            .workload(workload_by_name("attn_block_mh4").expect("known workload"))
            .rules(RuleSet::All)
            .iters(2)
            .limits(RunnerLimits {
                max_nodes: 8_000,
                track_designs: false,
                ..Default::default()
            })
            .apply_workers(apply_workers)
            .build()
            .expect("session builds");
        let ev = session
            .query(&Query::new().objective(Objective::Latency).samples(8).seed(3))
            .expect("query answers");
        let mut s = String::new();
        for d in &ev.designs {
            let _ = writeln!(s, "design [{}] {} {:?}", d.point.origin, d.point.expr, d.point.cost);
        }
        for p in &ev.frontier {
            let _ = writeln!(s, "frontier {} {:?}", p.expr, p.cost);
        }
        s
    };
    assert_eq!(serve(1), serve(4), "served designs/frontier differ across apply widths");
}

// ---------------------------------------------------------------------
// Asymmetric padding (total pad_h/pad_w)
// ---------------------------------------------------------------------
//
// `conv2d_sym(stride, p)` is sugar for a TOTAL per-dim pad of `2p`; the
// enumeration engine must not be able to tell the two spellings apart.

fn saturate_expr(expr: hwsplit::ir::RecExpr) -> (String, String) {
    let lowered = lower_default(&expr).expect("workload lowers");
    let mut runner = Runner::new(lowered, RuleSet::Paper.rules())
        .with_limits(RunnerLimits { max_nodes: 12_000, ..Default::default() });
    let rep = runner.run(3);
    (fingerprint(&runner.egraph), canon_report(&rep))
}

/// Symmetric sugar vs explicit total pads: identical relay terms, hence
/// bit-identical saturated e-graphs and iteration reports.
#[test]
fn symmetric_pad_sugar_saturates_bit_identically_to_explicit_total_pads() {
    use hwsplit::relay::GraphBuilder;
    let build = |explicit: bool| {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[3, 16, 16]);
        let w = b.weight("w", &[8, 3, 3, 3]);
        let c = if explicit {
            b.conv2d(x, w, 1, 2, 2) // total 2 per dim = 1 before + 1 after
        } else {
            b.conv2d_sym(x, w, 1, 1)
        };
        b.relu(c);
        b.finish()
    };
    assert_eq!(build(false), build(true), "sugar must desugar to total pads");
    let (fp_sym, rep_sym) = saturate_expr(build(false));
    let (fp_exp, rep_exp) = saturate_expr(build(true));
    assert_eq!(fp_sym, fp_exp, "e-graphs differ between pad spellings");
    assert_eq!(rep_sym, rep_exp, "iteration reports differ between pad spellings");
}

/// A genuinely asymmetric pad (pad_h ≠ pad_w, both odd totals, so the
/// floor-before/ceil-after split is exercised on both axes) must lower,
/// type-check, saturate and evaluate like any other conv.
#[test]
fn asymmetric_pad_enumerates_and_evaluates() {
    use hwsplit::ir::{Shape, Ty};
    use hwsplit::relay::GraphBuilder;
    use hwsplit::tensor::{eval_expr, Env};
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[3, 14, 10]);
    let w = b.weight("w", &[4, 3, 3, 3]);
    let c = b.conv2d(x, w, 2, 1, 3); // out: (14+1-3)/2+1=7, (10+3-3)/2+1=6
    b.relu(c);
    let expr = b.finish();
    assert_eq!(expr.typecheck().unwrap(), Ty::Tensor(Shape::new(&[4, 7, 6])));
    let out = eval_expr(&expr, &mut Env::random_for(&expr, 9)).expect("evaluates");
    assert_eq!(out.shape, Shape::new(&[4, 7, 6]));
    assert!(out.data.iter().all(|v| v.is_finite()));

    let lowered = lower_default(&expr).expect("asymmetric conv lowers");
    let mut runner = Runner::new(lowered, RuleSet::Paper.rules())
        .with_limits(RunnerLimits { max_nodes: 12_000, ..Default::default() });
    let rep = runner.run(3);
    assert!(rep.nodes > 50, "asymmetric conv must still grow a design space");
    assert!(rep.designs_lower_bound >= 2.0, "expected at least two designs");
}

/// The incremental engine's whole point: after the first iteration it
/// searches far fewer classes than live in the graph.
#[test]
fn incremental_search_narrows_after_first_iteration() {
    let w = workload_by_name("lenet").unwrap();
    let lowered = lower_default(&w.expr).unwrap();
    let mut runner = Runner::new(lowered, RuleSet::Paper.rules())
        .with_limits(RunnerLimits { max_nodes: 20_000, ..Default::default() });
    let rep = runner.run(3);
    assert!(rep.iterations.len() >= 2, "need at least two iterations");
    let it1 = &rep.iterations[1];
    assert!(
        it1.searched_classes < it1.classes,
        "iteration 1 searched {} of {} classes — not incremental",
        it1.searched_classes,
        it1.classes
    );
}
