//! Integration tests for the `Session` API — the crate's core economic
//! claim as executable checks:
//!
//! * enumeration runs **exactly once** no matter how many queries (with
//!   different objectives, backends, sample counts, cost params) are
//!   issued;
//! * evaluation backends are interchangeable views of the same design set
//!   and agree on functional outputs;
//! * the Pareto frontier invariant (only mutually non-dominated points)
//!   holds property-style over random cost clouds.

use hwsplit::cost::{CostParams, DesignCost, DesignStats};
use hwsplit::egraph::RunnerLimits;
use hwsplit::error::Error;
use hwsplit::extract::{pareto_frontier, DesignPoint};
use hwsplit::ir::parse_expr;
use hwsplit::prop;
use hwsplit::relay::workloads;
use hwsplit::rewrites::RuleSet;
use hwsplit::session::{Backend, Objective, Query, Session};
use hwsplit::tensor::{eval_expr, Env};

fn small_session(w: hwsplit::relay::Workload) -> Session {
    Session::builder()
        .workload(w)
        .rules(RuleSet::Paper)
        .iters(4)
        .workers(4)
        .limits(RunnerLimits { max_nodes: 30_000, ..Default::default() })
        .build()
        .unwrap()
}

/// THE acceptance property: a second (and third, and fourth) query with a
/// different objective / backend / sample count answers from the cached
/// e-graph — the rewrite runner executed exactly once.
#[test]
fn session_enumerates_exactly_once_across_queries() {
    let mut s = small_session(workloads::ffn_block());
    assert_eq!(s.enumeration_count(), 0, "building a session must not enumerate");

    let fast = s.query(&Query::new().objective(Objective::Latency).samples(12)).unwrap();
    assert_eq!(s.enumeration_count(), 1, "first query pays enumeration");

    // Same samples as `fast` so both objectives rank the identical design
    // set; `simmed` varies the sample count to show that also re-queries
    // cheaply.
    let small = s.query(&Query::new().objective(Objective::Area).samples(12)).unwrap();
    let simmed = s.query(&Query::new().backend(Backend::Sim).samples(8)).unwrap();
    let cheap_dram = s
        .query(&Query::new().params(CostParams { dram_bw: 1.0, ..Default::default() }))
        .unwrap();
    assert_eq!(
        s.enumeration_count(),
        1,
        "changed objective/backend/samples/params must not re-enumerate"
    );

    // All four queries answered from the same space, nontrivially.
    for ev in [&fast, &small, &simmed, &cheap_dram] {
        assert!(ev.designs.len() >= 3);
        assert!(!ev.frontier.is_empty());
    }
    // And the objectives genuinely rank differently.
    let f = fast.best().unwrap().point.cost.clone();
    let a = small.best().unwrap().point.cost.clone();
    assert!(f.latency <= a.latency);
    assert!(a.area <= f.area);
}

/// Ported from the removed `coordinator::explore` shim tests: the
/// enumerated set must contain a smaller-area design than the one-engine-
/// per-kernel-type baseline (a deep loop over a narrow engine).
#[test]
fn relu128_frontier_beats_baseline_somewhere() {
    let mut s = small_session(workloads::relu128());
    let ev = s.query(&Query::new().backend(Backend::Sim).samples(12)).unwrap();
    let b = &ev.baseline.cost;
    assert!(
        ev.designs.iter().any(|d| d.point.cost.area < b.area),
        "no smaller-than-baseline design found: {}",
        ev.frontier_vs_baseline()
    );
}

/// Acceptance for the registry-era workloads: `attn_block` and
/// `mobile_block` enumerate under the full rule set and extract a
/// non-trivial Pareto frontier (≥2 mutually non-dominated area/latency
/// trade-offs), all from designs that still compute the workload.
#[test]
fn new_workloads_enumerate_nontrivial_frontiers() {
    for w in [workloads::attn_block(), workloads::mobile_block(), workloads::mobile_block_s2()] {
        let name = w.name.clone();
        let mut s = Session::builder()
            .workload(w)
            .rules(RuleSet::All)
            .iters(3)
            .workers(4)
            .limits(RunnerLimits { max_nodes: 30_000, ..Default::default() })
            .build()
            .unwrap();
        let ev = s.query(&Query::new().samples(16)).unwrap();
        assert!(ev.designs.len() >= 3, "{name}: too few designs");
        assert!(
            ev.frontier.len() >= 2,
            "{name}: trivial frontier ({} points)",
            ev.frontier.len()
        );
    }
}

/// Backend-equivalence smoke test: the same query on Analytic, Interp and
/// Sim extracts the same design set (extraction is deterministic given the
/// seed), and the Interp outputs prove every design computes the workload's
/// function — i.e. the backends are different *measurements* of the same
/// designs, not different designs.
#[test]
fn backends_agree_on_design_set_and_functional_outputs() {
    let w = workloads::ffn_block();
    let mut s = small_session(w.clone());
    let q = |b: Backend| Query::new().backend(b).samples(10).seed(7);
    let analytic = s.query(&q(Backend::Analytic)).unwrap();
    let interp = s.query(&q(Backend::Interp)).unwrap();
    let sim = s.query(&q(Backend::Sim)).unwrap();
    assert_eq!(s.enumeration_count(), 1);

    // Identical design sets across backends.
    let keys = |ev: &hwsplit::session::Evaluation| {
        ev.designs.iter().map(|d| d.point.expr.to_string()).collect::<Vec<_>>()
    };
    assert_eq!(keys(&analytic), keys(&interp));
    assert_eq!(keys(&analytic), keys(&sim));

    // Channel shape: analytic adds nothing, interp adds outputs, sim adds
    // reports.
    assert!(analytic.designs.iter().all(|d| d.sim.is_none() && d.output.is_none()));
    assert!(interp.designs.iter().all(|d| d.output.is_some()));
    assert!(sim.designs.iter().all(|d| d.sim.as_ref().is_some_and(|r| r.cycles > 0.0)));

    // Functional agreement: every design's interp output equals the
    // workload oracle under the query seed.
    let want = eval_expr(&w.expr, &mut Env::random_for(&w.expr, 7)).unwrap();
    for d in &interp.designs {
        let got = d.output.as_ref().unwrap();
        assert!(want.allclose(got, 1e-4), "{} diverged from the workload", d.point.origin);
    }

    // And analytic cost agrees with itself across backends (same designs,
    // same params → same DesignPoint costs).
    for (a, s_) in analytic.designs.iter().zip(&sim.designs) {
        assert_eq!(a.point.cost, s_.point.cost);
    }
}

/// In a stub (no `pjrt` feature) build, a Pjrt-backend query fails with
/// the typed `Unsupported` error and the session stays usable.
#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_query_unsupported_is_typed_and_nonfatal() {
    let mut s = small_session(workloads::relu128());
    let err = s.query(&Query::new().backend(Backend::Pjrt).samples(4)).unwrap_err();
    assert!(matches!(err, Error::Unsupported(_)), "{err}");
    // The failed query still paid (and cached) enumeration; a later query
    // on a supported backend answers fine.
    let ok = s.query(&Query::new().samples(4)).unwrap();
    assert!(!ok.designs.is_empty());
    assert_eq!(s.enumeration_count(), 1);
}

/// Property: `pareto_frontier` returns exactly the non-dominated subset —
/// no frontier point is dominated by any input point, and every
/// non-dominated input cost appears on the frontier.
#[test]
fn prop_pareto_frontier_is_exactly_the_nondominated_set() {
    let expr = parse_expr("(invoke-relu (relu-engine 8) (input x [8]))").unwrap();
    prop::check("pareto-frontier-nondominated", 50, |rng| {
        let n = rng.range(1, 40);
        let points: Vec<DesignPoint> = (0..n)
            .map(|i| DesignPoint {
                expr: expr.clone(),
                cost: DesignCost {
                    // Coarse grid so ties and duplicates actually occur.
                    area: (rng.below(12) + 1) as f64,
                    latency: (rng.below(12) + 1) as f64,
                    ..Default::default()
                },
                stats: DesignStats::default(),
                origin: format!("p{i}"),
            })
            .collect();
        let frontier = pareto_frontier(&points);
        assert!(!frontier.is_empty(), "nonempty input must yield a frontier");

        // 1. No frontier point is dominated by any input point.
        for f in &frontier {
            for p in &points {
                assert!(
                    !p.cost.dominates(&f.cost),
                    "frontier point ({}, {}) dominated by ({}, {})",
                    f.cost.area,
                    f.cost.latency,
                    p.cost.area,
                    p.cost.latency
                );
            }
        }
        // 2. Mutual non-domination inside the frontier, and no duplicate
        //    (area, latency) pairs.
        for (i, a) in frontier.iter().enumerate() {
            for (j, b) in frontier.iter().enumerate() {
                if i != j {
                    assert!(!a.cost.dominates(&b.cost));
                    assert!(
                        a.cost.area != b.cost.area || a.cost.latency != b.cost.latency,
                        "duplicate frontier point"
                    );
                }
            }
        }
        // 3. Completeness: every non-dominated input cost is represented.
        for p in &points {
            let dominated = points.iter().any(|q| q.cost.dominates(&p.cost));
            if !dominated {
                assert!(
                    frontier.iter().any(|f| f.cost.area == p.cost.area
                        && f.cost.latency == p.cost.latency),
                    "non-dominated ({}, {}) missing from frontier",
                    p.cost.area,
                    p.cost.latency
                );
            }
        }
        // 4. Sorted by area.
        for w in frontier.windows(2) {
            assert!(w[0].cost.area <= w[1].cost.area);
        }
    });
}

/// The builder surfaces configuration mistakes as typed errors.
#[test]
fn builder_and_parsers_return_typed_errors() {
    assert!(matches!(
        Session::builder().build().unwrap_err(),
        Error::InvalidConfig(_)
    ));
    assert!(matches!(
        "warp-drive".parse::<Backend>().unwrap_err(),
        Error::UnknownBackend(_)
    ));
    assert!(matches!(
        "bogus".parse::<RuleSet>().unwrap_err(),
        Error::UnknownRuleSet(_)
    ));
}
