//! Integration: the full pipeline stages against each other.
//!
//! * Fig. 1 golden: Relay conv reified to engine + schedule + storage;
//! * Fig. 2 golden: the exact three programs of the paper's figure coexist
//!   in one e-class;
//! * analytic cost model vs the simulator (they must agree on ordering);
//! * PJRT runtime vs the oracle on a full workload design (needs
//!   `make artifacts`; skips otherwise);
//! * property: parser/printer round-trips on every enumerated sample.

use hwsplit::cost::{cost_of, CostParams};
use hwsplit::egraph::{Runner, RunnerLimits};
use hwsplit::extract::sample_design;
use hwsplit::ir::{parse_expr, Op};
use hwsplit::lower::{lower, lower_default, LowerOptions};
use hwsplit::relay::workloads;
use hwsplit::rewrites::{self, RuleSet};
use hwsplit::runtime::{default_artifact_dir, EngineRuntime, PjrtBackend};
use hwsplit::session::{Backend, Query, Session};
use hwsplit::sim::{simulate, SimConfig};
use hwsplit::tensor::{eval_expr, eval_expr_backend, Env};

/// Paper Fig. 1: `nn.conv2d` reified into a concrete engine instantiation
/// with explicit storage.
#[test]
fn fig1_conv2d_reification_golden() {
    let w = workloads::convblock();
    let lo = lower(&w.expr, LowerOptions { buffers: true }).unwrap();
    let txt = lo.to_string();
    assert!(txt.contains("(conv-engine 16 16 3 8 3 3 1)"), "engine instantiation: {txt}");
    assert!(txt.contains("(buffer sram (invoke-conv"), "output storage: {txt}");
    assert!(txt.contains("(pad2d 2 2"), "total padding made explicit: {txt}");
    // And it still computes conv+bias+relu.
    let a = eval_expr(&w.expr, &mut Env::random_for(&w.expr, 3)).unwrap();
    let b = eval_expr(&lo, &mut Env::random_for(&lo, 3)).unwrap();
    assert!(a.allclose(&b, 1e-4));
}

/// Paper Fig. 2: after rewrite 1 and rewrite 2, the three programs of the
/// figure (whole engine / loop over half engine / parallel half engines)
/// are all members of the same e-class.
#[test]
fn fig2_three_programs_share_one_eclass() {
    let expr = parse_expr("(invoke-relu (relu-engine 128) (input x [128]))").unwrap();
    let mut runner = Runner::new(expr, rewrites::fig2_rules());
    runner.run(4);
    let eg = &runner.egraph;
    let root = eg.find_ref(runner.root);
    let kinds: Vec<&Op> = eg.class_nodes(root).map(|n| &n.op).collect();
    assert!(kinds.iter().any(|op| matches!(op, Op::InvokeRelu)), "original member");
    assert!(
        kinds.iter().any(|op| matches!(op, Op::SchedLoop { extent: 2, .. })),
        "rewrite-1 member (loop)"
    );
    assert!(
        kinds.iter().any(|op| matches!(op, Op::SchedPar { extent: 2, .. })),
        "rewrite-2 member (par)"
    );
}

/// The analytic model and the simulator must agree on the Fig. 2 ordering
/// (they are independent implementations of the same hardware story).
#[test]
fn cost_model_and_simulator_agree_on_orderings() {
    let whole = parse_expr("(invoke-relu (relu-engine 128) (input x [128]))").unwrap();
    let looped = parse_expr(
        "(sched-loop i 0 4 (invoke-relu (relu-engine 32) \
          (slice 0 32 (imul (lvar i) 32) (input x [128]))))",
    )
    .unwrap();
    let parred = parse_expr(
        "(sched-par i 0 4 (invoke-relu (relu-engine 32) \
          (slice 0 32 (imul (lvar i) 32) (input x [128]))))",
    )
    .unwrap();
    let p = CostParams::default();
    let cfg = SimConfig::default();
    let (cw, cl, cp) = (cost_of(&whole, &p), cost_of(&looped, &p), cost_of(&parred, &p));
    let (sw, sl, sp) = (
        simulate(&whole, &cfg).cycles,
        simulate(&looped, &cfg).cycles,
        simulate(&parred, &cfg).cycles,
    );
    // Latency ordering: loop slowest in both models.
    assert!(cl.latency > cw.latency && sl > sw);
    assert!(cp.latency < cl.latency && sp < sl);
    // Area ordering: loop smallest, par == whole-ish.
    assert!(cl.area < cw.area);
}

/// Full-stack: an enumerated LeNet design runs its engine invocations on
/// PJRT-compiled Pallas kernels and matches the oracle bit-for-bit-ish.
#[test]
fn pjrt_executes_enumerated_mlp_design() {
    let Ok(rt) = EngineRuntime::new(default_artifact_dir()) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let w = workloads::mlp();
    let initial = lower_default(&w.expr).expect("workload lowers");
    let mut runner = Runner::new(initial.clone(), rewrites::paper_rules());
    runner.run(3);

    // The initial design always has full artifact coverage.
    let mut backend = PjrtBackend::new(rt);
    let env = Env::random_for(&initial, 9);
    let want = eval_expr(&initial, &mut env.clone()).unwrap();
    let got = eval_expr_backend(&initial, &mut env.clone(), &mut backend).unwrap();
    assert!(got.allclose(&want, 1e-3), "initial: {:?}", got.max_abs_diff(&want));

    // And a rewritten design with schedules, via constrained extraction.
    let cand =
        hwsplit::runtime::extract_covered(&runner.egraph, runner.root, &backend.runtime, true)
            .expect("an artifact-covered design must exist (the initial one is covered)");
    assert!(
        cand.count(|op| op.is_sched()) > 0,
        "area-leaning covered extraction should pick a split design"
    );
    let env = Env::random_for(&cand, 9);
    let want = eval_expr(&cand, &mut env.clone()).unwrap();
    let got = eval_expr_backend(&cand, &mut env.clone(), &mut backend).unwrap();
    assert!(got.allclose(&want, 1e-3), "split design diverged:\n{cand}");
}

/// Parser/printer round-trip holds for arbitrary enumerated designs, not
/// just hand-written ones.
#[test]
fn printer_parser_roundtrip_on_sampled_designs() {
    let w = workloads::convblock();
    let lowered = lower_default(&w.expr).expect("workload lowers");
    let mut runner = Runner::new(lowered, rewrites::paper_rules())
        .with_limits(RunnerLimits { max_nodes: 20_000, ..Default::default() });
    runner.run(4);
    for seed in 0..10 {
        let d = sample_design(&runner.egraph, runner.root, seed);
        let text = d.to_string();
        let back = parse_expr(&text).unwrap_or_else(|e| panic!("reparse failed: {e}\n{text}"));
        assert_eq!(back.to_string(), text);
    }
}

/// The session end-to-end on a conv workload: frontier non-empty, baseline
/// computed, and all sim utilizations sane.
#[test]
fn session_pipeline_invariants() {
    let w = workloads::convblock();
    let mut session = Session::builder()
        .workload(w)
        .rules(RuleSet::Paper)
        .iters(4)
        .limits(RunnerLimits { max_nodes: 25_000, ..Default::default() })
        .build()
        .unwrap();
    let ex = session.query(&Query::new().backend(Backend::Sim).samples(16)).unwrap();
    assert!(!ex.frontier.is_empty());
    assert!(ex.baseline.cost.area > 0.0);
    for d in &ex.designs {
        let sim = d.sim.as_ref().expect("sim backend reports for every design");
        assert!(sim.cycles > 0.0);
        assert!((0.0..=1.0).contains(&sim.utilization));
        assert!(d.point.cost.latency.is_finite());
    }
}
