//! Sharded-serving properties, pinned end-to-end against real child
//! processes (`env!("CARGO_BIN_EXE_hwsplit")`): routed responses are
//! byte-identical to a single-process daemon answering the same requests
//! (wall-clock `latency_ms` aside), `stats` counters aggregate as exact
//! sums with the router-only fields appended, `reload`/`shutdown`
//! broadcast to every shard, and a killed child is restarted by the
//! supervisor — with typed `busy` answers (never hangs) while it is down
//! and working queries again once it is back.

use hwsplit::egraph::RunnerLimits;
use hwsplit::relay::workload_by_name;
use hwsplit::rewrites::RuleSet;
use hwsplit::serve::json::Json;
use hwsplit::serve::shard::{ShardConfig, ShardServer};
use hwsplit::serve::{Server, SessionStore};
use hwsplit::session::Session;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hwsplit-sharded-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir.join(name)
}

fn build_session(name: &str, rules: RuleSet, iters: usize) -> Session {
    Session::builder()
        .workload(workload_by_name(name).expect("known workload"))
        .rules(rules)
        .iters(iters)
        .limits(RunnerLimits { max_nodes: 8_000, track_designs: false, ..Default::default() })
        .build()
        .expect("session builds")
}

/// Two small snapshots — enough workloads for two non-empty shards.
fn two_workload_snapshots(tag: &str) -> Vec<String> {
    [("relu128", RuleSet::Fig2, 4), ("mlp", RuleSet::Paper, 2)]
        .into_iter()
        .map(|(name, rules, iters)| {
            let path = scratch(&format!("{tag}-{name}.hws"));
            build_session(name, rules, iters).save_snapshot(&path).expect("snapshot saves");
            path.to_string_lossy().into_owned()
        })
        .collect()
}

fn bind_sharded(snapshots: &[String], shards: usize) -> (Arc<ShardServer>, SocketAddr) {
    let config = ShardConfig::new(env!("CARGO_BIN_EXE_hwsplit"), shards);
    let server =
        Arc::new(ShardServer::bind("127.0.0.1:0", snapshots, config).expect("supervisor binds"));
    let addr = server.local_addr().expect("bound addr");
    (server, addr)
}

/// One line-oriented wire client returning raw response lines, so tests
/// can compare routed and direct responses byte-for-byte.
struct Wire {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Wire {
    fn connect(addr: SocketAddr) -> Wire {
        let stream = TcpStream::connect(addr).expect("connects");
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .expect("never hang a test on a dead daemon");
        Wire { reader: BufReader::new(stream.try_clone().expect("clones")), writer: stream }
    }

    fn send(&mut self, req: &str) -> String {
        writeln!(self.writer, "{req}").expect("writes");
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("reads a response line");
        line.trim_end().to_string()
    }

    fn send_json(&mut self, req: &str) -> Json {
        Json::parse(&self.send(req)).expect("response is valid JSON")
    }
}

/// Query responses end with a wall-clock `latency_ms` field — the only
/// non-deterministic bytes. Strip it; everything before must match.
fn strip_latency(resp: &str) -> String {
    match resp.rfind(",\"latency_ms\":") {
        Some(i) if resp.ends_with('}') => format!("{}}}", &resp[..i]),
        _ => resp.to_string(),
    }
}

#[test]
fn routed_responses_are_byte_identical_to_single_process() {
    let snapshots = two_workload_snapshots("bytes");

    // The baseline: one in-process daemon owning both workloads.
    let mut store = SessionStore::new(4);
    for path in &snapshots {
        store.register(path).expect("registers");
    }
    let direct_server = Arc::new(Server::bind("127.0.0.1:0", Arc::new(store)).expect("binds"));
    let direct_addr = direct_server.local_addr().expect("bound addr");
    let direct_acceptor = {
        let server = direct_server.clone();
        std::thread::spawn(move || server.run())
    };

    // The subject: a 2-shard supervisor over the same snapshot files.
    let (sharded, sharded_addr) = bind_sharded(&snapshots, 2);
    assert_eq!(sharded.shard_count(), 2);
    let runner = {
        let server = sharded.clone();
        std::thread::spawn(move || server.run())
    };

    let mut direct = Wire::connect(direct_addr);
    let mut routed = Wire::connect(sharded_addr);

    // Successful queries: every workload × objective × seed answers ok and
    // byte-equal once the trailing latency field is stripped.
    for workload in ["relu128", "mlp"] {
        for objective in ["latency", "area", "balanced"] {
            for seed in [0, 1] {
                let req = format!(
                    "{{\"cmd\":\"query\",\"workload\":\"{workload}\",\
                     \"objective\":\"{objective}\",\"samples\":5,\"seed\":{seed}}}"
                );
                let a = direct.send(&req);
                let b = routed.send(&req);
                assert!(a.contains("\"ok\":true"), "direct must answer ok: {a}");
                assert!(b.contains("\"latency_ms\":"), "routed answers carry latency: {b}");
                assert_eq!(strip_latency(&a), strip_latency(&b), "req {req}");
            }
        }
    }

    // Error and control responses carry no wall-clock fields: exact bytes.
    for req in [
        "{\"cmd\":\"ping\"}",
        "this is not json",
        "{\"cmd\":\"frobnicate\"}",
        "{\"cmd\":\"query\",\"workload\":\"nope\"}",
        "{\"cmd\":\"query\"}",
        "{\"cmd\":\"query\",\"workload\":\"relu128\",\"objective\":\"bogus\"}",
    ] {
        assert_eq!(direct.send(req), routed.send(req), "req {req}");
    }

    assert!(direct.send("{\"cmd\":\"shutdown\"}").contains("\"shutting_down\":true"));
    direct_acceptor.join().expect("direct accept loop joins").expect("ran clean");
    assert!(routed.send("{\"cmd\":\"shutdown\"}").contains("\"shutting_down\":true"));
    runner.join().expect("supervisor joins").expect("supervisor ran clean");
}

#[test]
fn stats_aggregate_exactly_and_reload_broadcasts_to_every_shard() {
    let snapshots = two_workload_snapshots("stats");
    let (server, addr) = bind_sharded(&snapshots, 2);
    let runner = {
        let server = server.clone();
        std::thread::spawn(move || server.run())
    };
    let mut client = Wire::connect(addr);

    // Known traffic: three served queries split 2/1 across the workloads,
    // plus two errors (both rendered and counted by shard 0).
    for req in [
        "{\"cmd\":\"query\",\"workload\":\"relu128\",\"samples\":4,\"seed\":0}",
        "{\"cmd\":\"query\",\"workload\":\"relu128\",\"samples\":4,\"seed\":1}",
        "{\"cmd\":\"query\",\"workload\":\"mlp\",\"samples\":4,\"seed\":0}",
    ] {
        let resp = client.send_json(req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "req {req}");
    }
    for req in ["this is not json", "{\"cmd\":\"query\",\"workload\":\"nope\"}"] {
        let resp = client.send_json(req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "req {req}");
    }

    // Aggregated stats: counters are exact sums, router fields appended.
    let stats = client.send_json("{\"cmd\":\"stats\"}");
    assert_eq!(stats.get("served").and_then(Json::as_u64), Some(3));
    assert_eq!(stats.get("errors").and_then(Json::as_u64), Some(2));
    assert_eq!(stats.get("rejected").and_then(Json::as_u64), Some(0));
    assert_eq!(stats.get("timeouts").and_then(Json::as_u64), Some(0));
    assert_eq!(stats.get("queue_depth").and_then(Json::as_u64), Some(0));
    assert_eq!(stats.get("cached_sessions").and_then(Json::as_u64), Some(2));
    assert_eq!(stats.get("generation").and_then(Json::as_u64), Some(0));
    assert_eq!(stats.get("workloads").and_then(Json::as_str), Some("mlp,relu128"));
    assert_eq!(stats.get("served_by_workload").and_then(Json::as_str), Some("mlp=1,relu128=2"));
    assert_eq!(stats.get("shards").and_then(Json::as_u64), Some(2));
    assert_eq!(stats.get("restarts").and_then(Json::as_u64), Some(0));
    assert_eq!(stats.get("router_errors").and_then(Json::as_u64), Some(0));
    assert_eq!(stats.get("shard_generations").and_then(Json::as_str), Some("0,0"));
    let pids: Vec<String> = server.shard_pids().iter().map(|p| p.to_string()).collect();
    assert_eq!(stats.get("shard_pids").and_then(Json::as_str), Some(pids.join(",").as_str()));

    // Reload broadcasts: both shards swap their resident workload, and the
    // aggregate mirrors the single-process shape (union + min generation).
    let reload = client.send_json("{\"cmd\":\"reload\"}");
    assert_eq!(reload.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(reload.get("reloaded").and_then(Json::as_str), Some("mlp,relu128"));
    assert_eq!(reload.get("generation").and_then(Json::as_u64), Some(1));
    let stats = client.send_json("{\"cmd\":\"stats\"}");
    assert_eq!(stats.get("reloads").and_then(Json::as_u64), Some(2), "one reload per shard");
    assert_eq!(stats.get("generation").and_then(Json::as_u64), Some(1));
    assert_eq!(stats.get("shard_generations").and_then(Json::as_str), Some("1,1"));
    // The swapped sessions still answer.
    let resp = client.send_json("{\"cmd\":\"query\",\"workload\":\"mlp\",\"samples\":4}");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "post-reload query");

    // Shutdown broadcasts too: acknowledged on the wire, children reaped,
    // supervisor joins clean.
    assert!(client.send("{\"cmd\":\"shutdown\"}").contains("\"shutting_down\":true"));
    runner.join().expect("supervisor joins").expect("supervisor ran clean");
}

#[test]
fn killed_shard_is_restarted_and_serves_again() {
    let snapshots = two_workload_snapshots("restart");
    let (server, addr) = bind_sharded(&snapshots, 2);
    let runner = {
        let server = server.clone();
        std::thread::spawn(move || server.run())
    };

    // Warm up both shards, then kill the one owning relu128.
    let mut client = Wire::connect(addr);
    for workload in ["relu128", "mlp"] {
        let req = format!("{{\"cmd\":\"query\",\"workload\":\"{workload}\",\"samples\":4}}");
        let resp = client.send_json(&req);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "warm-up {workload}");
    }
    let target = server.shard_of("relu128").expect("relu128 is routed");
    let pid_before = server.shard_pids()[target];
    server.kill_shard(target).expect("fault injection");

    // Until the health loop restarts it, failures must be typed busy with
    // a retry hint — never a hang; eventually the query succeeds again.
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut recovered = false;
    while Instant::now() < deadline {
        let mut probe = Wire::connect(addr);
        let resp = probe.send_json("{\"cmd\":\"query\",\"workload\":\"relu128\",\"samples\":4}");
        if resp.get("ok").and_then(Json::as_bool) == Some(true) {
            recovered = true;
            break;
        }
        assert_eq!(
            resp.get("code").and_then(Json::as_str),
            Some("busy"),
            "mid-restart failures must be typed busy: {resp:?}"
        );
        assert!(resp.get("retry_after_ms").and_then(Json::as_u64).unwrap_or(0) >= 10);
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(recovered, "the supervisor must restart the killed shard");
    assert!(server.restarts() >= 1, "the restart is counted");
    assert_ne!(server.shard_pids()[target], pid_before, "a fresh child was spawned");

    // The untouched shard served throughout, and the router kept its
    // failures out of the per-shard sums.
    let resp = client.send_json("{\"cmd\":\"query\",\"workload\":\"mlp\",\"samples\":4}");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "other shard unaffected");
    let stats = client.send_json("{\"cmd\":\"stats\"}");
    assert!(stats.get("restarts").and_then(Json::as_u64).unwrap_or(0) >= 1, "{stats:?}");

    server.request_shutdown();
    runner.join().expect("supervisor joins").expect("supervisor ran clean");
}
