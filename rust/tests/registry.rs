//! Registry-driven exhaustive operator tests: for EVERY [`OpKind`], the
//! spec's exemplar term must parse, print back identically, type-check to
//! the declared golden type, and — for tensor-valued exemplars — evaluate,
//! lower (leaving no Relay ops behind) and cost to finite numbers.
//!
//! This is the "an op can't ship half-wired" guarantee: registering an
//! operator in `ir::spec` without a working shape rule, eval kernel,
//! printer/parser schema, lowering template, or cost hook fails here by
//! construction, for the op's own exemplar.

use hwsplit::cost::{cost_of, CostParams};
use hwsplit::ir::spec::{self, ExemplarTy};
use hwsplit::ir::{parse_expr, OpKind, Shape, Ty};
use hwsplit::lower::lower_default;
use hwsplit::tensor::{eval_expr, Env};

#[test]
fn every_opkind_has_a_spec_in_order() {
    let specs = spec::all_specs();
    assert_eq!(specs.len(), OpKind::ALL.len());
    for (&kind, s) in OpKind::ALL.iter().zip(specs) {
        assert_eq!(s.kind, kind);
    }
}

/// Print→parse round-trip golden, per op.
#[test]
fn exemplar_print_parse_roundtrip() {
    for &kind in OpKind::ALL {
        let s = spec::of(kind);
        let e = parse_expr(s.exemplar)
            .unwrap_or_else(|err| panic!("{kind:?}: exemplar fails to parse: {err}"));
        assert_eq!(
            e.to_string(),
            s.exemplar,
            "{kind:?}: print(parse(exemplar)) is not the exemplar"
        );
    }
}

/// Shape-inference golden, per op.
#[test]
fn exemplar_shape_inference_golden() {
    for &kind in OpKind::ALL {
        let s = spec::of(kind);
        let e = parse_expr(s.exemplar).unwrap();
        let ty = e
            .typecheck()
            .unwrap_or_else(|err| panic!("{kind:?}: exemplar fails inference: {err}"));
        match s.exemplar_ty {
            ExemplarTy::Index => assert_eq!(ty, Ty::Index, "{kind:?}"),
            ExemplarTy::Engine => {
                assert!(matches!(ty, Ty::Engine(_)), "{kind:?}: expected engine, got {ty:?}")
            }
            ExemplarTy::Tensor(dims) => {
                assert_eq!(ty, Ty::Tensor(Shape::new(dims)), "{kind:?}")
            }
        }
    }
}

/// Tensor-valued exemplars run the whole pipeline: evaluate (eval kernel
/// wired), lower (no Relay op survives reification), and cost (the analytic
/// model prices the lowered design without panicking).
#[test]
fn tensor_exemplars_evaluate_lower_and_cost() {
    for &kind in OpKind::ALL {
        let s = spec::of(kind);
        let ExemplarTy::Tensor(dims) = s.exemplar_ty else { continue };
        let e = parse_expr(s.exemplar).unwrap();

        let mut env = Env::random_for(&e, 7);
        let out = eval_expr(&e, &mut env)
            .unwrap_or_else(|err| panic!("{kind:?}: exemplar fails to evaluate: {err}"));
        assert_eq!(out.shape, Shape::new(dims), "{kind:?}: eval shape");
        assert!(out.data.iter().all(|v| v.is_finite()), "{kind:?}: non-finite eval");

        let lo = lower_default(&e)
            .unwrap_or_else(|err| panic!("{kind:?}: exemplar fails to lower: {err}"));
        // GlobalAvgPool deliberately has no engine form yet; everything
        // else must fully reify.
        if kind != OpKind::GlobalAvgPool {
            assert_eq!(
                lo.count(|op| op.is_relay()),
                0,
                "{kind:?}: Relay ops survive lowering"
            );
        }
        // Lowering preserves semantics on the exemplar.
        let mut env2 = Env::random_for(&lo, 7);
        let lowered_out = eval_expr(&lo, &mut env2)
            .unwrap_or_else(|err| panic!("{kind:?}: lowered exemplar fails eval: {err}"));
        assert!(
            out.allclose(&lowered_out, 1e-4),
            "{kind:?}: lowering changed semantics: {:?}",
            out.max_abs_diff(&lowered_out)
        );

        let cost = cost_of(&lo, &CostParams::default());
        assert!(
            cost.latency.is_finite() && cost.latency >= 0.0 && cost.area >= 0.0,
            "{kind:?}: bad cost {cost:?}"
        );
    }
}
