//! Registry-driven exhaustive operator tests: for EVERY [`OpKind`], the
//! spec's exemplar term must parse, print back identically, type-check to
//! the declared golden type, and — for tensor-valued exemplars — evaluate,
//! lower (leaving no Relay ops behind) and cost to finite numbers.
//!
//! This is the "an op can't ship half-wired" guarantee: registering an
//! operator in `ir::spec` without a working shape rule, eval kernel,
//! printer/parser schema, lowering template, or cost hook fails here by
//! construction, for the op's own exemplar.

use hwsplit::cost::{cost_of, CostParams};
use hwsplit::ir::spec::{self, ExemplarTy};
use hwsplit::ir::{parse_expr, OpKind, Shape, Ty};
use hwsplit::lower::lower_default;
use hwsplit::tensor::{eval_expr, Env};

#[test]
fn every_opkind_has_a_spec_in_order() {
    let specs = spec::all_specs();
    assert_eq!(specs.len(), OpKind::ALL.len());
    for (&kind, s) in OpKind::ALL.iter().zip(specs) {
        assert_eq!(s.kind, kind);
    }
}

/// Print→parse round-trip golden, per op.
#[test]
fn exemplar_print_parse_roundtrip() {
    for &kind in OpKind::ALL {
        let s = spec::of(kind);
        let e = parse_expr(s.exemplar)
            .unwrap_or_else(|err| panic!("{kind:?}: exemplar fails to parse: {err}"));
        assert_eq!(
            e.to_string(),
            s.exemplar,
            "{kind:?}: print(parse(exemplar)) is not the exemplar"
        );
    }
}

/// Shape-inference golden, per op.
#[test]
fn exemplar_shape_inference_golden() {
    for &kind in OpKind::ALL {
        let s = spec::of(kind);
        let e = parse_expr(s.exemplar).unwrap();
        let ty = e
            .typecheck()
            .unwrap_or_else(|err| panic!("{kind:?}: exemplar fails inference: {err}"));
        match s.exemplar_ty {
            ExemplarTy::Index => assert_eq!(ty, Ty::Index, "{kind:?}"),
            ExemplarTy::Engine => {
                assert!(matches!(ty, Ty::Engine(_)), "{kind:?}: expected engine, got {ty:?}")
            }
            ExemplarTy::Tensor(dims) => {
                assert_eq!(ty, Ty::Tensor(Shape::new(dims)), "{kind:?}")
            }
        }
    }
}

/// Per ROADMAP: rewrite coverage per engine kind is registry-driven, not
/// hand-maintained. Every Engine-class op either declares its
/// split-rewrite family (`OpSpec::split_family`) — which must resolve to
/// at least one registered rule — or sits on the explicit exemption list
/// below. A new engine with neither fails here by construction.
#[test]
fn every_engine_has_a_split_rule_or_documented_exemption() {
    use hwsplit::ir::spec::OpClass;
    let rules = hwsplit::rewrites::all_rules();
    let mut exempt = Vec::new();
    for s in spec::all_specs() {
        if s.class != OpClass::Engine {
            continue;
        }
        match s.split_family {
            Some(prefix) => assert!(
                rules.iter().any(|r| r.name.starts_with(prefix)),
                "{:?}: declared split family '{prefix}' has no registered rule",
                s.kind
            ),
            None => exempt.push(s.kind),
        }
    }
    // Row-coupled normalization engines only: softmax/layernorm cannot
    // split along their width (the row statistics couple every lane).
    assert_eq!(
        exempt,
        vec![OpKind::SoftmaxEngine, OpKind::LayerNormEngine],
        "unexpected split exemptions"
    );
}

/// The rectangular-pooling satellite: a non-square `kh`×`kw` window goes
/// through parse/print, shape inference, eval, lowering and cost — and the
/// pool engine prices `kh*kw` windows, not `k²`.
#[test]
fn rectangular_pool_window_end_to_end() {
    use hwsplit::ir::Op;
    let src = "(maxpool2d 2 4 2 (input x [3 8 8]))";
    let e = parse_expr(src).unwrap();
    assert_eq!(e.to_string(), src);
    assert_eq!(e.typecheck().unwrap(), Ty::Tensor(Shape::new(&[3, 4, 3])));
    let mut env = Env::random_for(&e, 9);
    let out = eval_expr(&e, &mut env).unwrap();
    assert_eq!(out.shape, Shape::new(&[3, 4, 3]));
    let lo = lower_default(&e).unwrap();
    assert!(lo.to_string().contains("(pool-engine 4 3 3 2 4 2)"), "{lo}");
    let got = eval_expr(&lo, &mut Env::random_for(&lo, 9)).unwrap();
    assert!(out.allclose(&got, 1e-5));
    let cost = cost_of(&lo, &CostParams::default());
    assert!(cost.latency.is_finite() && cost.area > 0.0);
    let rect = Op::PoolEngine { oh: 4, ow: 3, c: 3, kh: 2, kw: 4, stride: 2 };
    let sq = Op::PoolEngine { oh: 4, ow: 3, c: 3, kh: 2, kw: 2, stride: 2 };
    assert_eq!(rect.engine_macs(), 2 * sq.engine_macs());
}

/// Tensor-valued exemplars run the whole pipeline: evaluate (eval kernel
/// wired), lower (no Relay op survives reification), and cost (the analytic
/// model prices the lowered design without panicking).
#[test]
fn tensor_exemplars_evaluate_lower_and_cost() {
    for &kind in OpKind::ALL {
        let s = spec::of(kind);
        let ExemplarTy::Tensor(dims) = s.exemplar_ty else { continue };
        let e = parse_expr(s.exemplar).unwrap();

        let mut env = Env::random_for(&e, 7);
        let out = eval_expr(&e, &mut env)
            .unwrap_or_else(|err| panic!("{kind:?}: exemplar fails to evaluate: {err}"));
        assert_eq!(out.shape, Shape::new(dims), "{kind:?}: eval shape");
        assert!(out.data.iter().all(|v| v.is_finite()), "{kind:?}: non-finite eval");

        let lo = lower_default(&e)
            .unwrap_or_else(|err| panic!("{kind:?}: exemplar fails to lower: {err}"));
        // GlobalAvgPool deliberately has no engine form yet; everything
        // else must fully reify.
        if kind != OpKind::GlobalAvgPool {
            assert_eq!(
                lo.count(|op| op.is_relay()),
                0,
                "{kind:?}: Relay ops survive lowering"
            );
        }
        // Lowering preserves semantics on the exemplar.
        let mut env2 = Env::random_for(&lo, 7);
        let lowered_out = eval_expr(&lo, &mut env2)
            .unwrap_or_else(|err| panic!("{kind:?}: lowered exemplar fails eval: {err}"));
        assert!(
            out.allclose(&lowered_out, 1e-4),
            "{kind:?}: lowering changed semantics: {:?}",
            out.max_abs_diff(&lowered_out)
        );

        let cost = cost_of(&lo, &CostParams::default());
        assert!(
            cost.latency.is_finite() && cost.latency >= 0.0 && cost.area >= 0.0,
            "{kind:?}: bad cost {cost:?}"
        );
    }
}
