//! Serving-layer properties: many threads sharing one loaded session get
//! answers identical to a serial baseline, the LRU session store never
//! exceeds its residency bound, and the TCP daemon survives concurrent
//! clients, malformed requests and a clean shutdown.

use hwsplit::egraph::RunnerLimits;
use hwsplit::relay::workload_by_name;
use hwsplit::rewrites::RuleSet;
use hwsplit::serve::json::Json;
use hwsplit::serve::{Server, SessionStore};
use hwsplit::session::{Evaluation, Objective, Query, Session};
use std::fmt::Write as _;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hwsplit-serving-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir.join(name)
}

fn build_session(name: &str, rules: RuleSet, iters: usize) -> Session {
    Session::builder()
        .workload(workload_by_name(name).expect("known workload"))
        .rules(rules)
        .iters(iters)
        .limits(RunnerLimits { max_nodes: 8_000, track_designs: false, ..Default::default() })
        .build()
        .expect("session builds")
}

/// Timing-free canonical answer rendering (same idea as the persistence
/// tests: identity, costs, frontier — no wall-clock).
fn canon(ev: &Evaluation) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "objective={:?} requested={}", ev.objective, ev.extract.requested);
    for d in &ev.designs {
        let _ = writeln!(s, "design [{}] {} {:?}", d.point.origin, d.point.expr, d.point.cost);
    }
    for p in &ev.frontier {
        let _ = writeln!(s, "frontier {} {:?}", p.expr, p.cost);
    }
    s
}

const OBJECTIVES: [Objective; 3] =
    [Objective::Latency, Objective::Area, Objective::Balanced(0.5)];

#[test]
fn eight_concurrent_clients_match_the_serial_baseline() {
    let mut session = build_session("relu128", RuleSet::Fig2, 4);
    session.enumerate().expect("enumerates");

    // 8 mixed-objective, mixed-seed queries: answer serially first…
    let queries: Vec<Query> = (0..8)
        .map(|i| {
            Query::new()
                .objective(OBJECTIVES[i % OBJECTIVES.len()])
                .samples(6)
                .seed((i % 2) as u64)
        })
        .collect();
    let serial: Vec<String> = queries
        .iter()
        .map(|q| canon(&session.answer_query(q).expect("serial answer")))
        .collect();

    // …then concurrently, one thread per query, all sharing the session.
    let session = Arc::new(session);
    let concurrent: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = queries
            .iter()
            .map(|q| {
                let session = &session;
                scope.spawn(move || canon(&session.answer_query(q).expect("parallel answer")))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    assert_eq!(concurrent, serial, "concurrent answers must match the serial baseline");
    assert_eq!(session.enumeration_count(), 1, "the graph is enumerated exactly once");
}

#[test]
fn session_store_lru_never_exceeds_its_bound() {
    // Three real snapshots, residency bounded at two.
    let mut store = SessionStore::new(2);
    for (name, rules) in
        [("relu128", RuleSet::Fig2), ("mlp", RuleSet::Paper), ("mobile_block", RuleSet::Paper)]
    {
        let path = scratch(&format!("lru-{name}.hws"));
        build_session(name, rules, 2).save_snapshot(&path).expect("snapshot saves");
        assert_eq!(store.register(&path).expect("registers"), name);
    }
    assert_eq!(store.workloads(), vec!["mlp", "mobile_block", "relu128"]);

    let store = Arc::new(store);
    // Touch every workload, repeatedly and out of order; the cache must
    // never hold more than two sessions.
    for name in ["relu128", "mlp", "mobile_block", "relu128", "mobile_block", "mlp"] {
        let session = store.get(name).expect("loads from snapshot");
        assert!(session.enumeration().is_some(), "{name}: loaded ready-to-serve");
        assert_eq!(session.enumeration_count(), 0, "{name}: no re-saturation on load");
        assert!(store.cached_count() <= 2, "{name}: LRU bound exceeded");
    }
    // mlp was touched last, so it must be resident; a repeat get is a
    // cache hit (same Arc).
    let a = store.get("mlp").expect("resident");
    let b = store.get("mlp").expect("resident");
    assert!(Arc::ptr_eq(&a, &b), "repeat get must hit the cache");

    assert!(
        matches!(store.get("nonexistent"), Err(hwsplit::Error::UnknownWorkload(_))),
        "unregistered workloads are typed errors"
    );
}

#[test]
fn tcp_daemon_serves_concurrent_clients_with_error_isolation() {
    // One snapshot-backed store behind a real TCP server on an OS-picked
    // port.
    let path = scratch("daemon-relu128.hws");
    build_session("relu128", RuleSet::Fig2, 4).save_snapshot(&path).expect("snapshot saves");
    let mut store = SessionStore::new(4);
    store.register(&path).expect("registers");

    let server = Arc::new(Server::bind("127.0.0.1:0", Arc::new(store)).expect("binds"));
    let addr = server.local_addr().expect("bound addr");
    let acceptor = {
        let server = server.clone();
        std::thread::spawn(move || server.run())
    };

    let clients = 8;
    let per_client = 3;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            handles.push(scope.spawn(move || {
                let stream = TcpStream::connect(addr).expect("connects");
                let mut reader = BufReader::new(stream.try_clone().expect("clones"));
                let mut writer = stream;
                let mut line = String::new();

                for i in 0..per_client {
                    let obj = ["latency", "area", "balanced"][(c + i) % 3];
                    writeln!(
                        writer,
                        "{{\"cmd\":\"query\",\"workload\":\"relu128\",\
                         \"objective\":\"{obj}\",\"samples\":5,\"seed\":{}}}",
                        i % 2
                    )
                    .expect("writes");
                    line.clear();
                    reader.read_line(&mut line).expect("reads");
                    let j = Json::parse(line.trim()).expect("valid response json");
                    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{line}");
                    assert_eq!(j.get("workload").and_then(Json::as_str), Some("relu128"));
                    assert_eq!(j.get("objective").and_then(Json::as_str), Some(obj));
                    assert!(j.get("designs").and_then(Json::as_u64).unwrap_or(0) >= 2, "{line}");
                }

                // A malformed line errors this request only…
                writeln!(writer, "this is not json").expect("writes");
                line.clear();
                reader.read_line(&mut line).expect("reads");
                let j = Json::parse(line.trim()).expect("error response is still json");
                assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false), "{line}");
                assert!(j.get("error").and_then(Json::as_str).is_some(), "{line}");

                // …and an unknown workload likewise; the connection lives on.
                writeln!(writer, "{{\"cmd\":\"query\",\"workload\":\"nope\"}}").expect("writes");
                line.clear();
                reader.read_line(&mut line).expect("reads");
                assert!(line.contains("\"ok\":false"), "{line}");

                writeln!(writer, "{{\"cmd\":\"ping\"}}").expect("writes");
                line.clear();
                reader.read_line(&mut line).expect("reads");
                assert!(line.contains("\"pong\":true"), "{line}");
            }));
        }
        for h in handles {
            h.join().expect("client thread");
        }
    });

    // Stats reflect every client: served queries and isolated errors.
    let stream = TcpStream::connect(addr).expect("connects");
    let mut reader = BufReader::new(stream.try_clone().expect("clones"));
    let mut writer = stream;
    let mut line = String::new();
    writeln!(writer, "{{\"cmd\":\"stats\"}}").expect("writes");
    reader.read_line(&mut line).expect("reads");
    let j = Json::parse(line.trim()).expect("stats json");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{line}");
    assert_eq!(
        j.get("served").and_then(Json::as_u64),
        Some((clients * per_client) as u64),
        "{line}"
    );
    assert_eq!(j.get("errors").and_then(Json::as_u64), Some(2 * clients as u64), "{line}");
    assert_eq!(j.get("cached_sessions").and_then(Json::as_u64), Some(1), "{line}");
    assert_eq!(j.get("workloads").and_then(Json::as_str), Some("relu128"), "{line}");

    // Graceful shutdown: the request is acknowledged and the accept loop
    // exits.
    line.clear();
    writeln!(writer, "{{\"cmd\":\"shutdown\"}}").expect("writes");
    reader.read_line(&mut line).expect("reads");
    assert!(line.contains("\"shutting_down\":true"), "{line}");
    acceptor.join().expect("accept loop joins").expect("accept loop ran clean");
}
