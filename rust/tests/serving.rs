//! Serving-layer properties: many threads sharing one loaded session get
//! answers identical to a serial baseline, the LRU session store never
//! exceeds its residency bound, and the TCP daemon survives concurrent
//! clients, malformed requests and a clean shutdown. The hardening layer
//! is pinned here too: queue saturation answers typed `busy` (never a
//! hang), over-deadline requests answer typed `timeout` with exact
//! counters, hot reload swaps sessions under in-flight queries, the
//! legacy path refuses connections past its hard cap, and
//! `docs/serving.md` is cross-checked against the protocol enums so no
//! command or error code ships undocumented.

use hwsplit::egraph::RunnerLimits;
use hwsplit::relay::workload_by_name;
use hwsplit::rewrites::RuleSet;
use hwsplit::serve::json::Json;
use hwsplit::serve::{Command, ErrorCode, ServeConfig, Server, SessionStore};
use hwsplit::session::{Evaluation, Objective, Query, Session};
use std::fmt::Write as _;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hwsplit-serving-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir.join(name)
}

fn build_session(name: &str, rules: RuleSet, iters: usize) -> Session {
    Session::builder()
        .workload(workload_by_name(name).expect("known workload"))
        .rules(rules)
        .iters(iters)
        .limits(RunnerLimits { max_nodes: 8_000, track_designs: false, ..Default::default() })
        .build()
        .expect("session builds")
}

/// Timing-free canonical answer rendering (same idea as the persistence
/// tests: identity, costs, frontier — no wall-clock).
fn canon(ev: &Evaluation) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "objective={:?} requested={}", ev.objective, ev.extract.requested);
    for d in &ev.designs {
        let _ = writeln!(s, "design [{}] {} {:?}", d.point.origin, d.point.expr, d.point.cost);
    }
    for p in &ev.frontier {
        let _ = writeln!(s, "frontier {} {:?}", p.expr, p.cost);
    }
    s
}

const OBJECTIVES: [Objective; 3] =
    [Objective::Latency, Objective::Area, Objective::Balanced(0.5)];

#[test]
fn eight_concurrent_clients_match_the_serial_baseline() {
    let mut session = build_session("relu128", RuleSet::Fig2, 4);
    session.enumerate().expect("enumerates");

    // 8 mixed-objective, mixed-seed queries: answer serially first…
    let queries: Vec<Query> = (0..8)
        .map(|i| {
            Query::new()
                .objective(OBJECTIVES[i % OBJECTIVES.len()])
                .samples(6)
                .seed((i % 2) as u64)
        })
        .collect();
    let serial: Vec<String> = queries
        .iter()
        .map(|q| canon(&session.answer_query(q).expect("serial answer")))
        .collect();

    // …then concurrently, one thread per query, all sharing the session.
    let session = Arc::new(session);
    let concurrent: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = queries
            .iter()
            .map(|q| {
                let session = &session;
                scope.spawn(move || canon(&session.answer_query(q).expect("parallel answer")))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    assert_eq!(concurrent, serial, "concurrent answers must match the serial baseline");
    assert_eq!(session.enumeration_count(), 1, "the graph is enumerated exactly once");
}

#[test]
fn session_store_lru_never_exceeds_its_bound() {
    // Three real snapshots, residency bounded at two.
    let mut store = SessionStore::new(2);
    for (name, rules) in
        [("relu128", RuleSet::Fig2), ("mlp", RuleSet::Paper), ("mobile_block", RuleSet::Paper)]
    {
        let path = scratch(&format!("lru-{name}.hws"));
        build_session(name, rules, 2).save_snapshot(&path).expect("snapshot saves");
        assert_eq!(store.register(&path).expect("registers"), name);
    }
    assert_eq!(store.workloads(), vec!["mlp", "mobile_block", "relu128"]);

    let store = Arc::new(store);
    // Touch every workload, repeatedly and out of order; the cache must
    // never hold more than two sessions.
    for name in ["relu128", "mlp", "mobile_block", "relu128", "mobile_block", "mlp"] {
        let session = store.get(name).expect("loads from snapshot");
        assert!(session.enumeration().is_some(), "{name}: loaded ready-to-serve");
        assert_eq!(session.enumeration_count(), 0, "{name}: no re-saturation on load");
        assert!(store.cached_count() <= 2, "{name}: LRU bound exceeded");
    }
    // mlp was touched last, so it must be resident; a repeat get is a
    // cache hit (same Arc).
    let a = store.get("mlp").expect("resident");
    let b = store.get("mlp").expect("resident");
    assert!(Arc::ptr_eq(&a, &b), "repeat get must hit the cache");

    assert!(
        matches!(store.get("nonexistent"), Err(hwsplit::Error::UnknownWorkload(_))),
        "unregistered workloads are typed errors"
    );
}

#[test]
fn tcp_daemon_serves_concurrent_clients_with_error_isolation() {
    // One snapshot-backed store behind a real TCP server on an OS-picked
    // port.
    let path = scratch("daemon-relu128.hws");
    build_session("relu128", RuleSet::Fig2, 4).save_snapshot(&path).expect("snapshot saves");
    let mut store = SessionStore::new(4);
    store.register(&path).expect("registers");

    let server = Arc::new(Server::bind("127.0.0.1:0", Arc::new(store)).expect("binds"));
    let addr = server.local_addr().expect("bound addr");
    let acceptor = {
        let server = server.clone();
        std::thread::spawn(move || server.run())
    };

    let clients = 8;
    let per_client = 3;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            handles.push(scope.spawn(move || {
                let stream = TcpStream::connect(addr).expect("connects");
                let mut reader = BufReader::new(stream.try_clone().expect("clones"));
                let mut writer = stream;
                let mut line = String::new();

                for i in 0..per_client {
                    let obj = ["latency", "area", "balanced"][(c + i) % 3];
                    writeln!(
                        writer,
                        "{{\"cmd\":\"query\",\"workload\":\"relu128\",\
                         \"objective\":\"{obj}\",\"samples\":5,\"seed\":{}}}",
                        i % 2
                    )
                    .expect("writes");
                    line.clear();
                    reader.read_line(&mut line).expect("reads");
                    let j = Json::parse(line.trim()).expect("valid response json");
                    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{line}");
                    assert_eq!(j.get("workload").and_then(Json::as_str), Some("relu128"));
                    assert_eq!(j.get("objective").and_then(Json::as_str), Some(obj));
                    assert!(j.get("designs").and_then(Json::as_u64).unwrap_or(0) >= 2, "{line}");
                }

                // A malformed line errors this request only…
                writeln!(writer, "this is not json").expect("writes");
                line.clear();
                reader.read_line(&mut line).expect("reads");
                let j = Json::parse(line.trim()).expect("error response is still json");
                assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false), "{line}");
                assert!(j.get("error").and_then(Json::as_str).is_some(), "{line}");

                // …and an unknown workload likewise; the connection lives on.
                writeln!(writer, "{{\"cmd\":\"query\",\"workload\":\"nope\"}}").expect("writes");
                line.clear();
                reader.read_line(&mut line).expect("reads");
                assert!(line.contains("\"ok\":false"), "{line}");

                writeln!(writer, "{{\"cmd\":\"ping\"}}").expect("writes");
                line.clear();
                reader.read_line(&mut line).expect("reads");
                assert!(line.contains("\"pong\":true"), "{line}");
            }));
        }
        for h in handles {
            h.join().expect("client thread");
        }
    });

    // Stats reflect every client: served queries and isolated errors.
    let stream = TcpStream::connect(addr).expect("connects");
    let mut reader = BufReader::new(stream.try_clone().expect("clones"));
    let mut writer = stream;
    let mut line = String::new();
    writeln!(writer, "{{\"cmd\":\"stats\"}}").expect("writes");
    reader.read_line(&mut line).expect("reads");
    let j = Json::parse(line.trim()).expect("stats json");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{line}");
    assert_eq!(
        j.get("served").and_then(Json::as_u64),
        Some((clients * per_client) as u64),
        "{line}"
    );
    assert_eq!(j.get("errors").and_then(Json::as_u64), Some(2 * clients as u64), "{line}");
    assert_eq!(j.get("cached_sessions").and_then(Json::as_u64), Some(1), "{line}");
    assert_eq!(j.get("workloads").and_then(Json::as_str), Some("relu128"), "{line}");

    // Graceful shutdown: the request is acknowledged and the accept loop
    // exits.
    line.clear();
    writeln!(writer, "{{\"cmd\":\"shutdown\"}}").expect("writes");
    reader.read_line(&mut line).expect("reads");
    assert!(line.contains("\"shutting_down\":true"), "{line}");
    acceptor.join().expect("accept loop joins").expect("accept loop ran clean");
}

/// One line-oriented protocol client (request out, JSON response in).
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connects");
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .expect("never hang a test on a dead daemon");
        Client { reader: BufReader::new(stream.try_clone().expect("clones")), writer: stream }
    }

    fn read_response(&mut self) -> Json {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("reads a response line");
        Json::parse(line.trim()).expect("response is valid JSON")
    }

    fn send(&mut self, req: &str) -> Json {
        writeln!(self.writer, "{req}").expect("writes");
        self.read_response()
    }
}

fn snapshot_backed_store(tag: &str, max_sessions: usize) -> (SessionStore, PathBuf) {
    let path = scratch(&format!("{tag}-relu128.hws"));
    build_session("relu128", RuleSet::Fig2, 4).save_snapshot(&path).expect("snapshot saves");
    let mut store = SessionStore::new(max_sessions);
    store.register(&path).expect("registers");
    (store, path)
}

#[test]
fn queue_saturation_yields_typed_busy_never_a_hang() {
    let (store, _path) = snapshot_backed_store("busy", 4);
    let config = ServeConfig {
        workers: 1,
        queue_depth: 1,
        request_timeout_ms: 30_000,
        ..ServeConfig::default()
    };
    let server =
        Arc::new(Server::bind_with("127.0.0.1:0", Arc::new(store), config).expect("binds"));
    let addr = server.local_addr().expect("bound addr");
    let acceptor = {
        let server = server.clone();
        std::thread::spawn(move || server.run())
    };

    // A: confirmed owned by the single worker (ping round-trips).
    let mut a = Client::connect(addr);
    assert_eq!(a.send(r#"{"cmd":"ping"}"#).get("pong").and_then(Json::as_bool), Some(true));

    // B: accepted, sits in the single queue slot (the worker is on A).
    let mut b = Client::connect(addr);
    std::thread::sleep(Duration::from_millis(200)); // let the acceptor enqueue B

    // C: queue full — immediate typed busy with a retry hint, then close.
    let mut c = Client::connect(addr);
    let busy = c.read_response();
    assert_eq!(busy.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(busy.get("code").and_then(Json::as_str), Some("busy"));
    assert!(busy.get("retry_after_ms").and_then(Json::as_u64).unwrap_or(0) >= 10);
    assert!(busy.get("error").and_then(Json::as_str).unwrap_or("").contains("busy"));

    // The held connection still works, and the counters are exact.
    let stats = a.send(r#"{"cmd":"stats"}"#);
    assert_eq!(stats.get("rejected").and_then(Json::as_u64), Some(1), "exactly one refusal");
    assert_eq!(stats.get("queue_depth").and_then(Json::as_u64), Some(1), "B is still queued");
    assert_eq!(stats.get("errors").and_then(Json::as_u64), Some(0));

    // Freeing the worker drains the queue: B gets served.
    drop(a);
    assert_eq!(b.send(r#"{"cmd":"ping"}"#).get("pong").and_then(Json::as_bool), Some(true));
    assert!(b.send(r#"{"cmd":"shutdown"}"#).get("shutting_down").is_some());
    acceptor.join().expect("accept loop joins").expect("accept loop ran clean");
}

#[test]
fn over_deadline_request_is_a_typed_timeout_with_exact_counters() {
    // Cold store + a 4096-sample query against a 1 ms budget: snapshot
    // decode plus extraction cannot finish inside it, so the cooperative
    // phase checks must trip.
    let (store, _path) = snapshot_backed_store("timeout", 4);
    let config = ServeConfig { workers: 1, request_timeout_ms: 1, ..ServeConfig::default() };
    let server =
        Arc::new(Server::bind_with("127.0.0.1:0", Arc::new(store), config).expect("binds"));
    let addr = server.local_addr().expect("bound addr");
    let acceptor = {
        let server = server.clone();
        std::thread::spawn(move || server.run())
    };

    let mut client = Client::connect(addr);
    let resp = client.send(r#"{"cmd":"query","workload":"relu128","samples":4096}"#);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(resp.get("code").and_then(Json::as_str), Some("timeout"));
    assert_eq!(resp.get("timeout_ms").and_then(Json::as_u64), Some(1));
    assert!(resp.get("error").and_then(Json::as_str).unwrap_or("").contains("deadline"));

    // Exactly one counter moved — a timeout is not an error or a reject.
    let stats = client.send(r#"{"cmd":"stats"}"#);
    assert_eq!(stats.get("timeouts").and_then(Json::as_u64), Some(1));
    assert_eq!(stats.get("errors").and_then(Json::as_u64), Some(0));
    assert_eq!(stats.get("served").and_then(Json::as_u64), Some(0));
    assert_eq!(stats.get("rejected").and_then(Json::as_u64), Some(0));

    client.send(r#"{"cmd":"shutdown"}"#);
    acceptor.join().expect("accept loop joins").expect("accept loop ran clean");
}

#[test]
fn reload_swaps_sessions_under_in_flight_queries() {
    let (store, _path) = snapshot_backed_store("reload", 4);
    let store = Arc::new(store);
    assert_eq!(store.generation(), 0);

    let before = store.get("relu128").expect("loads");
    let q = Query::new().samples(6).seed(1);
    let baseline = canon(&before.answer_query(&q).expect("answers"));

    // Queries on the old Arc race the swap; both must succeed.
    let in_flight = {
        let session = before.clone();
        let q = q.clone();
        std::thread::spawn(move || canon(&session.answer_query(&q).expect("in-flight answer")))
    };
    let reloaded = store.reload().expect("reload succeeds");
    assert_eq!(reloaded, vec!["relu128".to_string()]);
    assert_eq!(store.generation(), 1);
    assert_eq!(in_flight.join().expect("in-flight thread"), baseline);

    // The store now serves a *different* session with identical answers.
    let after = store.get("relu128").expect("resident");
    assert!(!Arc::ptr_eq(&before, &after), "reload must swap the resident session");
    assert_eq!(canon(&after.answer_query(&q).expect("answers")), baseline);
    assert_eq!(after.enumeration_count(), 0, "reload never re-saturates");
}

#[test]
fn reload_command_and_marker_file_trigger_hot_swap() {
    let (store, _path) = snapshot_backed_store("marker", 4);
    let marker = scratch("reload-marker");
    let config = ServeConfig {
        workers: 2,
        reload_marker: Some(marker.clone()),
        ..ServeConfig::default()
    };
    let server =
        Arc::new(Server::bind_with("127.0.0.1:0", Arc::new(store), config).expect("binds"));
    let addr = server.local_addr().expect("bound addr");
    let acceptor = {
        let server = server.clone();
        std::thread::spawn(move || server.run())
    };

    // Make the workload resident, then reload over the wire.
    let mut client = Client::connect(addr);
    let q = client.send(r#"{"workload":"relu128","samples":4}"#);
    assert_eq!(q.get("ok").and_then(Json::as_bool), Some(true), "warm-up query");
    let r = client.send(r#"{"cmd":"reload"}"#);
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(r.get("reloaded").and_then(Json::as_str), Some("relu128"));
    assert_eq!(r.get("generation").and_then(Json::as_u64), Some(1));

    // Touching the marker file reloads on the next accepted connection.
    std::fs::write(&marker, b"bump").expect("touches marker");
    let mut second = Client::connect(addr);
    assert_eq!(
        second.send(r#"{"cmd":"ping"}"#).get("pong").and_then(Json::as_bool),
        Some(true)
    );
    let stats = second.send(r#"{"cmd":"stats"}"#);
    assert_eq!(stats.get("reloads").and_then(Json::as_u64), Some(2), "wire + marker");
    assert_eq!(stats.get("generation").and_then(Json::as_u64), Some(2));
    // The swapped session still answers.
    let q = second.send(r#"{"workload":"relu128","samples":4}"#);
    assert_eq!(q.get("ok").and_then(Json::as_bool), Some(true), "post-reload query");

    second.send(r#"{"cmd":"shutdown"}"#);
    acceptor.join().expect("accept loop joins").expect("accept loop ran clean");
}

#[test]
fn legacy_path_refuses_connections_past_its_hard_cap() {
    let (store, _path) = snapshot_backed_store("legacy", 4);
    // workers: 0 selects thread-per-connection — now with a hard cap.
    let config = ServeConfig { workers: 0, max_connections: 1, ..ServeConfig::default() };
    let server =
        Arc::new(Server::bind_with("127.0.0.1:0", Arc::new(store), config).expect("binds"));
    let addr = server.local_addr().expect("bound addr");
    let acceptor = {
        let server = server.clone();
        std::thread::spawn(move || server.run())
    };

    // A occupies the only slot (ping proves its handler is live).
    let mut a = Client::connect(addr);
    assert_eq!(a.send(r#"{"cmd":"ping"}"#).get("pong").and_then(Json::as_bool), Some(true));

    // B is over the cap: typed busy, not an unbounded thread.
    let mut b = Client::connect(addr);
    let busy = b.read_response();
    assert_eq!(busy.get("code").and_then(Json::as_str), Some("busy"));
    assert!(busy.get("retry_after_ms").and_then(Json::as_u64).is_some());

    server.request_shutdown();
    acceptor.join().expect("accept loop joins").expect("accept loop ran clean");
}

#[test]
fn docs_serving_md_documents_every_command_and_error_code() {
    let doc = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/docs/serving.md"));
    for cmd in Command::ALL {
        let needle = format!("\"cmd\":\"{}\"", cmd.name());
        assert!(
            doc.contains(&needle),
            "docs/serving.md must document the '{}' command (missing {needle})",
            cmd.name()
        );
    }
    for code in ErrorCode::ALL {
        let needle = format!("\"code\":\"{}\"", code.name());
        assert!(
            doc.contains(&needle),
            "docs/serving.md must document the '{}' error code (missing {needle})",
            code.name()
        );
    }
    // The knobs that define the serving contract are named too.
    for flag in
        ["--serve-workers", "--queue-depth", "--request-timeout-ms", "--reload-marker"]
    {
        assert!(doc.contains(flag), "docs/serving.md must document {flag}");
    }
}
