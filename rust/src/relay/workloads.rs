//! The benchmark workload library: the ML inference programs whose
//! hardware–software design spaces the experiments enumerate.
//!
//! Sizes are chosen so that (a) every dimension is power-of-two-friendly for
//! the halving/splitting rewrites, and (b) e-graph saturation at the default
//! budgets finishes interactively. `relu128` is the paper's own Fig. 2
//! running example.
//!
//! The suite spans three workload families:
//!
//! * **classic CNN/MLP** — `relu128`, `convblock`, `resnet_block`, `mlp`,
//!   `lenet`: dense/conv/pool/relu, the paper's original territory;
//! * **transformer** — `ffn_block` (dense+residual), `attn_block`
//!   (single-head attention + GELU FFN + affine layernorm, BERT-tiny
//!   shapes: seq 16, hidden 128, FFN 512) and `attn_block_mh4` (the same
//!   block with 4-head attention: Q/K/V packed as rank-3 `(heads, ·, ·)`
//!   tensors routed through `batch-matmul`, so the head axis is a
//!   first-class split/parallelization dimension) and `attn_block_gqa`
//!   (grouped-query attention: 4 Q heads sharing 2 K/V heads, one K/V
//!   subtree with two `batch-matmul` consumers) using `matmul`/
//!   `batch-matmul`/`transpose`/`softmax`/`layernorm`/`gelu`/`emul`;
//! * **mobile CNN** — `mobile_block`, a MobileNet-style depthwise-separable
//!   unit (`dwconv2d` 3×3 + pointwise 1×1 conv), and `mobile_block_s2`,
//!   its stride-2 downsampling variant (exercises the halo math of
//!   `split-dwconv-oh` under stride > 1).

use super::GraphBuilder;
use crate::ir::RecExpr;

/// A named workload: a Relay-level operator graph plus metadata.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub description: String,
    pub expr: RecExpr,
}

/// Paper Fig. 2: a single 128-wide ReLU kernel invocation.
pub fn relu128() -> Workload {
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[128]);
    b.relu(x);
    Workload {
        name: "relu128".to_string(),
        description: "Fig. 2 running example: one 128-wide ReLU".to_string(),
        expr: b.finish(),
    }
}

/// A 3-layer MLP (MNIST-shaped): 784 -> 128 -> 64 -> 10.
pub fn mlp() -> Workload {
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[1, 784]);
    let h1 = b.dense_layer(x, "fc1", 128, true);
    let h2 = b.dense_layer(h1, "fc2", 64, true);
    b.dense_layer(h2, "fc3", 10, false);
    Workload {
        name: "mlp".to_string(),
        description: "3-layer MLP 784-128-64-10 (dense + bias + relu)".to_string(),
        expr: b.finish(),
    }
}

/// A LeNet-style CNN on 1×28×28 input.
pub fn lenet() -> Workload {
    let mut b = GraphBuilder::new();
    let x = b.input("img", &[1, 28, 28]);
    let c1 = b.conv_relu(x, "c1", 8, 5, 1, 4); // (8,28,28)
    let p1 = b.maxpool2d(c1, 2, 2); // (8,14,14)
    let c2 = b.conv_relu(p1, "c2", 16, 5, 1, 0); // (16,10,10)
    let p2 = b.maxpool2d(c2, 2, 2); // (16,5,5)
    let f = b.flatten(p2); // (1,400)
    let d1 = b.dense_layer(f, "fc1", 120, true);
    let d2 = b.dense_layer(d1, "fc2", 84, true);
    b.dense_layer(d2, "fc3", 10, false);
    Workload {
        name: "lenet".to_string(),
        description: "LeNet-style CNN: 2x(conv+relu+pool) + 3 dense layers".to_string(),
        expr: b.finish(),
    }
}

/// A single conv block (the unit the paper's Fig. 1 reifies).
pub fn convblock() -> Workload {
    let mut b = GraphBuilder::new();
    let x = b.input("img", &[3, 16, 16]);
    b.conv_relu(x, "c1", 8, 3, 1, 2);
    Workload {
        name: "convblock".to_string(),
        description: "One 3x3 conv (3->8 ch, 16x16, pad 1) + bias + relu — Fig. 1's unit".to_string(),
        expr: b.finish(),
    }
}

/// A residual block: two 3×3 convs with a skip connection.
pub fn resnet_block() -> Workload {
    let mut b = GraphBuilder::new();
    let x = b.input("img", &[8, 16, 16]);
    let c1 = b.conv_relu(x, "c1", 8, 3, 1, 2);
    let w2 = b.weight("c2_w", &[8, 8, 3, 3]);
    let c2 = b.conv2d(c1, w2, 1, 2, 2);
    let s = b.add(c2, x);
    b.relu(s);
    Workload {
        name: "resnet_block".to_string(),
        description: "Residual block: conv-relu-conv + skip add + relu (8ch, 16x16)".to_string(),
        expr: b.finish(),
    }
}

/// A transformer-style feed-forward block: two dense layers + residual.
pub fn ffn_block() -> Workload {
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[1, 64]);
    let h = b.dense_layer(x, "up", 256, true);
    let d = b.dense_layer(h, "down", 64, false);
    let s = b.add(d, x);
    b.relu(s);
    Workload {
        name: "ffn_block".to_string(),
        description: "Transformer FFN: dense 64->256->64 + residual add".to_string(),
        expr: b.finish(),
    }
}

/// A transformer encoder block with single-head attention (BERT-tiny
/// shapes: seq 16, hidden 128, FFN 512): Q/K/V projections, softmax
/// attention, output projection, residual + affine layernorm, GELU FFN,
/// residual + affine layernorm.
pub fn attn_block() -> Workload {
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[16, 128]);
    let ctx = b.attention(x, "attn");
    let proj = b.dense_layer(ctx, "attn_o", 128, false);
    let r1 = b.add(proj, x);
    let n1 = b.layer_norm(r1, "ln1");
    let up = b.dense_layer(n1, "ffn_up", 512, false);
    let act = b.gelu(up);
    let down = b.dense_layer(act, "ffn_down", 128, false);
    let r2 = b.add(down, n1);
    b.layer_norm(r2, "ln2");
    Workload {
        name: "attn_block".to_string(),
        description: "BERT-tiny encoder block: 1-head attention + GELU FFN + affine layernorm (16x128)".to_string(),
        expr: b.finish(),
    }
}

/// The same encoder block with 4-head attention: per-head Q/K/V packed as
/// rank-3 `(4, 16, 32)` tensors, scores and context routed through
/// `batch-matmul` (which lowers to a head-axis `sched-loop` the
/// `split-bmm-batch` / `parallelize` rewrites act on).
pub fn attn_block_mh4() -> Workload {
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[16, 128]);
    let ctx = b.attention_mh(x, "attn", 4);
    let proj = b.dense_layer(ctx, "attn_o", 128, false);
    let r1 = b.add(proj, x);
    let n1 = b.layer_norm(r1, "ln1");
    let up = b.dense_layer(n1, "ffn_up", 512, false);
    let act = b.gelu(up);
    let down = b.dense_layer(act, "ffn_down", 128, false);
    let r2 = b.add(down, n1);
    b.layer_norm(r2, "ln2");
    Workload {
        name: "attn_block_mh4".to_string(),
        description: "BERT-tiny encoder block: 4-head attention (batch-matmul over heads) + GELU FFN + affine layernorm (16x128)".to_string(),
        expr: b.finish(),
    }
}

/// The grouped-query variant of the encoder block: 4 query heads share 2
/// K/V heads. K and V are projected ONCE and both query-head groups
/// batch-matmul against the same rank-3 `(2, ·, ·)` K/V pack, so the
/// e-graph holds one shared K/V subtree with two `batch-matmul`
/// consumers — extraction must weigh replicating engines for the private
/// Q paths against the shared K/V work, a trade-off `attn_block_mh4`
/// (fully private heads) does not expose. The per-group output
/// projections live inside `attention_gqa`, so the residual adds its
/// summed output directly.
pub fn attn_block_gqa() -> Workload {
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[16, 128]);
    let ctx = b.attention_gqa(x, "attn", 4, 2);
    let r1 = b.add(ctx, x);
    let n1 = b.layer_norm(r1, "ln1");
    let up = b.dense_layer(n1, "ffn_up", 512, false);
    let act = b.gelu(up);
    let down = b.dense_layer(act, "ffn_down", 128, false);
    let r2 = b.add(down, n1);
    b.layer_norm(r2, "ln2");
    Workload {
        name: "attn_block_gqa".to_string(),
        description: "BERT-tiny encoder block: grouped-query attention (4 Q heads, 2 shared K/V heads) + GELU FFN + affine layernorm (16x128)".to_string(),
        expr: b.finish(),
    }
}

/// A MobileNet-style depthwise-separable block: 3×3 depthwise conv
/// (+bias+relu) followed by a 1×1 pointwise conv (+bias+relu) that doubles
/// the channels.
pub fn mobile_block() -> Workload {
    let mut b = GraphBuilder::new();
    let x = b.input("img", &[16, 14, 14]);
    let dw = b.dwconv_relu(x, "dw", 3, 1, 2); // (16,14,14)
    let pw_w = b.weight("pw_w", &[32, 16, 1, 1]);
    let pw_b = b.weight("pw_b", &[32]);
    let pw = b.conv2d(dw, pw_w, 1, 0, 0); // (32,14,14)
    let pw = b.bias_add(pw, pw_b);
    b.relu(pw);
    Workload {
        name: "mobile_block".to_string(),
        description: "MobileNet depthwise-separable block: 3x3 dwconv + 1x1 conv (16->32ch, 14x14)".to_string(),
        expr: b.finish(),
    }
}

/// The stride-2 MobileNet downsampling block: 3×3 depthwise conv with
/// stride 2 (+bias+relu) halving the spatial dims, then the 1×1 pointwise
/// conv doubling the channels. The 8×8 output keeps `split-dwconv-oh`'s
/// stride-2 halo slices power-of-two divisible.
pub fn mobile_block_s2() -> Workload {
    let mut b = GraphBuilder::new();
    let x = b.input("img", &[16, 15, 15]);
    let dw = b.dwconv_relu(x, "dw", 3, 2, 2); // (16,8,8)
    let pw_w = b.weight("pw_w", &[32, 16, 1, 1]);
    let pw_b = b.weight("pw_b", &[32]);
    let pw = b.conv2d(dw, pw_w, 1, 0, 0); // (32,8,8)
    let pw = b.bias_add(pw, pw_b);
    b.relu(pw);
    Workload {
        name: "mobile_block_s2".to_string(),
        description: "MobileNet stride-2 downsampling block: 3x3/s2 dwconv + 1x1 conv (16->32ch, 15x15->8x8)".to_string(),
        expr: b.finish(),
    }
}

/// All workloads, in rough size order.
pub fn all_workloads() -> Vec<Workload> {
    vec![
        relu128(),
        convblock(),
        ffn_block(),
        resnet_block(),
        mlp(),
        lenet(),
        mobile_block(),
        mobile_block_s2(),
        attn_block(),
        attn_block_mh4(),
        attn_block_gqa(),
    ]
}

/// The CLI names of every workload (for error messages and docs). Kept as
/// a static list so error Display paths don't pay graph construction;
/// `workload_names_match_constructors` pins it to [`all_workloads`].
pub fn workload_names() -> &'static [&'static str] {
    &[
        "relu128",
        "convblock",
        "ffn_block",
        "resnet_block",
        "mlp",
        "lenet",
        "mobile_block",
        "mobile_block_s2",
        "attn_block",
        "attn_block_mh4",
        "attn_block_gqa",
    ]
}

/// Look up a workload by CLI name: the static library first, then the
/// process-global dynamic registry (imported models).
pub fn workload_by_name(name: &str) -> Option<Workload> {
    all_workloads()
        .into_iter()
        .find(|w| w.name == name)
        .or_else(|| registered_workload(name))
}

// ---------------------------------------------------------------------
// Dynamic workload registry (imported models)
// ---------------------------------------------------------------------

use std::collections::HashMap;
use std::sync::RwLock;

static REGISTERED: RwLock<Option<HashMap<String, Workload>>> = RwLock::new(None);

/// Register a dynamically-built workload (an imported ONNX model, a
/// snapshot-embedded graph) so `workload_by_name`, error suggestions and
/// snapshot loading see it exactly like a built-in. Re-registering a name
/// replaces the previous entry; built-in names cannot be shadowed
/// (`workload_by_name` checks the static library first).
pub fn register_workload(w: Workload) {
    let mut guard = REGISTERED.write().unwrap();
    guard.get_or_insert_with(HashMap::new).insert(w.name.clone(), w);
}

/// A dynamically-registered workload by name.
pub fn registered_workload(name: &str) -> Option<Workload> {
    REGISTERED.read().unwrap().as_ref()?.get(name).cloned()
}

/// Names of every dynamically-registered workload (sorted, for stable
/// error messages).
pub fn registered_names() -> Vec<String> {
    let mut v: Vec<String> = match REGISTERED.read().unwrap().as_ref() {
        Some(m) => m.keys().cloned().collect(),
        None => Vec::new(),
    };
    v.sort();
    v
}

/// Every name `workload_by_name` resolves: the static library plus the
/// dynamic registry — the list error suggestions must print.
pub fn known_workload_names() -> Vec<String> {
    let mut v: Vec<String> = workload_names().iter().map(|s| s.to_string()).collect();
    v.extend(registered_names());
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Shape, Ty};
    use crate::tensor::{eval_expr, Env};

    #[test]
    fn all_workloads_typecheck() {
        for w in all_workloads() {
            let ty = w.expr.typecheck().unwrap_or_else(|e| panic!("{}: {e}", w.name));
            assert!(matches!(ty, Ty::Tensor(_)), "{}", w.name);
        }
    }

    #[test]
    fn all_workloads_evaluate() {
        for w in all_workloads() {
            let mut env = Env::random_for(&w.expr, 1);
            let out = eval_expr(&w.expr, &mut env).unwrap_or_else(|e| panic!("{}: {e}", w.name));
            assert!(out.data.iter().all(|v| v.is_finite()), "{}", w.name);
        }
    }

    #[test]
    fn classifier_shapes() {
        assert_eq!(
            mlp().expr.typecheck().unwrap(),
            Ty::Tensor(Shape::new(&[1, 10]))
        );
        assert_eq!(
            lenet().expr.typecheck().unwrap(),
            Ty::Tensor(Shape::new(&[1, 10]))
        );
    }

    #[test]
    fn lookup_by_name() {
        assert!(workload_by_name("lenet").is_some());
        assert!(workload_by_name("attn_block").is_some());
        assert!(workload_by_name("attn_block_mh4").is_some());
        assert!(workload_by_name("attn_block_gqa").is_some());
        assert!(workload_by_name("mobile_block").is_some());
        assert!(workload_by_name("mobile_block_s2").is_some());
        assert!(workload_by_name("nope").is_none());
    }

    #[test]
    fn attn_block_shape_and_ops() {
        let w = attn_block();
        assert_eq!(w.expr.typecheck().unwrap(), Ty::Tensor(Shape::new(&[16, 128])));
        use crate::ir::Op;
        assert!(w.expr.count(|op| matches!(op, Op::Matmul)) >= 2, "QK^T and PV matmuls");
        assert_eq!(w.expr.count(|op| matches!(op, Op::Softmax)), 1);
        assert_eq!(w.expr.count(|op| matches!(op, Op::LayerNorm)), 2);
        assert_eq!(w.expr.count(|op| matches!(op, Op::Gelu)), 1);
        assert_eq!(w.expr.count(|op| matches!(op, Op::Transpose)), 1);
    }

    #[test]
    fn attn_block_mh4_shape_and_ops() {
        let w = attn_block_mh4();
        assert_eq!(w.expr.typecheck().unwrap(), Ty::Tensor(Shape::new(&[16, 128])));
        use crate::ir::Op;
        assert_eq!(
            w.expr.count(|op| matches!(op, Op::BatchMatmul)),
            2,
            "per-head QK^T and PV batch-matmuls"
        );
        assert_eq!(w.expr.count(|op| matches!(op, Op::Softmax)), 1);
        assert_eq!(w.expr.count(|op| matches!(op, Op::LayerNorm)), 2);
        // Affine layernorm: gamma/beta weights exist per norm.
        assert_eq!(
            w.expr.count(|op| matches!(op, Op::Weight(s, _) if s.as_str().ends_with("_g"))),
            2
        );
        // Packing/unpacking uses batched + 2-D transposes and reshapes.
        assert!(w.expr.count(|op| matches!(op, Op::Transpose)) >= 4);
        assert!(w.expr.count(|op| matches!(op, Op::Reshape(_))) >= 4);
    }

    #[test]
    fn attn_block_gqa_shape_and_ops() {
        let w = attn_block_gqa();
        assert_eq!(w.expr.typecheck().unwrap(), Ty::Tensor(Shape::new(&[16, 128])));
        use crate::ir::Op;
        assert_eq!(
            w.expr.count(|op| matches!(op, Op::BatchMatmul)),
            4,
            "QK^T and PV batch-matmuls per query-head group"
        );
        assert_eq!(w.expr.count(|op| matches!(op, Op::Softmax)), 2, "one per group");
        // Shared K/V: exactly one K and one V projection weight, but TWO
        // per-group Q and output projection weights.
        let weights = |suffix: &str| {
            w.expr
                .count(|op| matches!(op, Op::Weight(s, _) if s.as_str().starts_with("attn_") && s.as_str().ends_with(suffix)))
        };
        assert_eq!(weights("k_w"), 1);
        assert_eq!(weights("v_w"), 1);
        assert_eq!(weights("q0_w") + weights("q1_w"), 2);
        assert_eq!(weights("o0_w") + weights("o1_w"), 2);
    }

    #[test]
    fn mobile_block_s2_shape_and_ops() {
        let w = mobile_block_s2();
        assert_eq!(w.expr.typecheck().unwrap(), Ty::Tensor(Shape::new(&[32, 8, 8])));
        use crate::ir::Op;
        assert_eq!(
            w.expr.count(|op| matches!(op, Op::DepthwiseConv2d { stride: 2, .. })),
            1
        );
        assert_eq!(w.expr.count(|op| matches!(op, Op::Conv2d { .. })), 1);
    }

    #[test]
    fn mobile_block_shape_and_ops() {
        let w = mobile_block();
        assert_eq!(w.expr.typecheck().unwrap(), Ty::Tensor(Shape::new(&[32, 14, 14])));
        use crate::ir::Op;
        assert_eq!(w.expr.count(|op| matches!(op, Op::DepthwiseConv2d { .. })), 1);
        assert_eq!(w.expr.count(|op| matches!(op, Op::Conv2d { .. })), 1);
    }

    #[test]
    fn workload_names_match_constructors() {
        let built: Vec<String> = all_workloads().into_iter().map(|w| w.name).collect();
        assert_eq!(workload_names(), built.as_slice());
    }

    #[test]
    fn workloads_have_distinct_names() {
        let names: Vec<String> = all_workloads().into_iter().map(|w| w.name).collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }

    #[test]
    fn dynamic_registry_resolves_and_lists() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8]);
        b.relu(x);
        register_workload(Workload {
            name: "test_dynamic_wl".to_string(),
            description: "registry test".to_string(),
            expr: b.finish(),
        });
        assert!(workload_by_name("test_dynamic_wl").is_some());
        assert!(registered_names().contains(&"test_dynamic_wl".to_string()));
        assert!(known_workload_names().contains(&"test_dynamic_wl".to_string()));
        // Built-ins stay first-class and un-shadowable.
        assert!(known_workload_names().contains(&"relu128".to_string()));
    }
}
