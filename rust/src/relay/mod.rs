//! The Relay-like frontend.
//!
//! The paper starts from workloads "written in Relay, … the intermediate
//! representation used by the TVM compiler", which it consumes purely as a
//! graph of operator calls. This module provides exactly that surface: a
//! typed builder for operator graphs over the Relay-level subset of
//! [`crate::ir::Op`] (`conv2d`, `dense`, `relu`, …) plus a library of
//! benchmark workloads ([`workloads`]).
//!
//! A "Relay program" here *is* an EngineIR [`RecExpr`] that happens to use
//! only Relay-level ops — which is what lets [`crate::lower`] reify it
//! incrementally and lets the e-graph hold half-lowered hybrids.

pub mod workloads;

pub use workloads::{
    all_workloads, known_workload_names, register_workload, registered_names,
    registered_workload, workload_by_name, workload_names, Workload,
};

use crate::egraph::Id;
use crate::ir::{infer_ty, ConstData, Op, RecExpr, Shape, Symbol, Ty};

/// Total SAME padding for one spatial dim: the smallest pad making
/// `out = ceil(in / stride)` (ONNX `SAME_UPPER`). The padded extent is
/// `(out-1)*stride + k`, so the window sweep always tiles exactly.
pub fn same_pad(input: usize, k: usize, stride: usize) -> usize {
    let out = input.div_ceil(stride);
    ((out - 1) * stride + k).saturating_sub(input)
}

/// A typed builder for Relay-level operator graphs. Every method checks
/// shapes eagerly (via the EngineIR type checker), so a workload that
/// builds is well-formed by construction.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    expr: RecExpr,
    /// Per-slot types, maintained incrementally as nodes are pushed (the
    /// same values `expr.types()` would recompute from scratch).
    tys: Vec<Ty>,
}

impl GraphBuilder {
    pub fn new() -> Self {
        GraphBuilder::default()
    }

    fn push(&mut self, op: Op, children: &[Id]) -> Id {
        // Eager validation: infer just the new node against its
        // already-validated children. The prefix is well-typed by
        // induction, so this catches authoring bugs at the exact offending
        // layer in O(1) per push instead of re-typechecking the whole
        // prefix (O(n²) over a build).
        let child_tys: Vec<Ty> =
            children.iter().map(|&c| self.tys[c.index()].clone()).collect();
        match infer_ty(&op, &child_tys) {
            Ok(ty) => self.tys.push(ty),
            Err(e) => panic!("GraphBuilder produced ill-typed graph: {e}"),
        }
        self.expr.add_op(op, children)
    }

    /// Workload input tensor.
    pub fn input(&mut self, name: &str, dims: &[usize]) -> Id {
        self.push(Op::Input(Symbol::new(name), Shape::new(dims)), &[])
    }

    /// Trained parameter.
    pub fn weight(&mut self, name: &str, dims: &[usize]) -> Id {
        self.push(Op::Weight(Symbol::new(name), Shape::new(dims)), &[])
    }

    /// 2-D convolution. `pad_h`/`pad_w` are the TOTAL padding per spatial
    /// dim (split floor-before/ceil-after); the old symmetric per-side
    /// `pad: p` is `conv2d_sym(x, w, stride, p)`.
    pub fn conv2d(&mut self, x: Id, w: Id, stride: usize, pad_h: usize, pad_w: usize) -> Id {
        self.push(Op::Conv2d { stride, pad_h, pad_w }, &[x, w])
    }

    /// Legacy symmetric padding: `p` zeros on each of the four sides,
    /// i.e. `pad_h = pad_w = 2p` total.
    pub fn conv2d_sym(&mut self, x: Id, w: Id, stride: usize, p: usize) -> Id {
        self.conv2d(x, w, stride, 2 * p, 2 * p)
    }

    /// SAME-padded convolution: pads are computed from the input shape so
    /// `out = ceil(in / stride)` per spatial dim (ONNX `SAME_UPPER`).
    pub fn conv2d_same(&mut self, x: Id, w: Id, stride: usize) -> Id {
        let xs = self.shape_of(x);
        let ws = self.shape_of(w);
        let pad_h = same_pad(xs.dim(1), ws.dim(2), stride);
        let pad_w = same_pad(xs.dim(2), ws.dim(3), stride);
        self.conv2d(x, w, stride, pad_h, pad_w)
    }

    pub fn dense(&mut self, x: Id, w: Id) -> Id {
        self.push(Op::Dense, &[x, w])
    }

    pub fn relu(&mut self, x: Id) -> Id {
        self.push(Op::Relu, &[x])
    }

    pub fn bias_add(&mut self, x: Id, b: Id) -> Id {
        self.push(Op::BiasAdd, &[x, b])
    }

    pub fn add(&mut self, x: Id, y: Id) -> Id {
        self.push(Op::EAdd, &[x, y])
    }

    /// Elementwise (Hadamard) multiply.
    pub fn emul(&mut self, x: Id, y: Id) -> Id {
        self.push(Op::Emul, &[x, y])
    }

    /// Square-window max pooling (the common case).
    pub fn maxpool2d(&mut self, x: Id, k: usize, stride: usize) -> Id {
        self.maxpool2d_rect(x, k, k, stride)
    }

    /// Rectangular-window max pooling.
    pub fn maxpool2d_rect(&mut self, x: Id, kh: usize, kw: usize, stride: usize) -> Id {
        self.push(Op::MaxPool2d { kh, kw, stride }, &[x])
    }

    pub fn flatten(&mut self, x: Id) -> Id {
        self.push(Op::Flatten, &[x])
    }

    /// Global average pooling: rank-3 `[C, H, W]` → rank-1 `[C]`.
    pub fn global_avg_pool(&mut self, x: Id) -> Id {
        self.push(Op::GlobalAvgPool, &[x])
    }

    /// General matmul of two computed tensors (attention scores etc.).
    pub fn matmul(&mut self, a: Id, b: Id) -> Id {
        self.push(Op::Matmul, &[a, b])
    }

    pub fn batch_matmul(&mut self, a: Id, b: Id) -> Id {
        self.push(Op::BatchMatmul, &[a, b])
    }

    /// Transpose of the trailing two axes (rank 2 or 3).
    pub fn transpose(&mut self, x: Id) -> Id {
        self.push(Op::Transpose, &[x])
    }

    /// Reshape to a static shape (same element count).
    pub fn reshape(&mut self, x: Id, dims: &[usize]) -> Id {
        self.push(Op::Reshape(Shape::new(dims)), &[x])
    }

    pub fn softmax(&mut self, x: Id) -> Id {
        self.push(Op::Softmax, &[x])
    }

    /// Affine layer normalization with learned `{name}_g` / `{name}_b`
    /// scale and shift parameters over the last axis.
    pub fn layer_norm(&mut self, x: Id, name: &str) -> Id {
        let s = self.shape_of(x);
        let n = s.dim(s.rank() - 1);
        let g = self.weight(&format!("{name}_g"), &[n]);
        let b = self.weight(&format!("{name}_b"), &[n]);
        self.push(Op::LayerNorm, &[x, g, b])
    }

    pub fn gelu(&mut self, x: Id) -> Id {
        self.push(Op::Gelu, &[x])
    }

    /// Depthwise 2-D convolution; `pad_h`/`pad_w` are TOTAL padding per
    /// spatial dim, as in [`Self::conv2d`].
    pub fn depthwise_conv2d(
        &mut self,
        x: Id,
        w: Id,
        stride: usize,
        pad_h: usize,
        pad_w: usize,
    ) -> Id {
        self.push(Op::DepthwiseConv2d { stride, pad_h, pad_w }, &[x, w])
    }

    /// Legacy symmetric padding for depthwise conv (`pad_h = pad_w = 2p`).
    pub fn depthwise_conv2d_sym(&mut self, x: Id, w: Id, stride: usize, p: usize) -> Id {
        self.depthwise_conv2d(x, w, stride, 2 * p, 2 * p)
    }

    /// SAME-padded depthwise convolution (ONNX `SAME_UPPER`).
    pub fn depthwise_conv2d_same(&mut self, x: Id, w: Id, stride: usize) -> Id {
        let xs = self.shape_of(x);
        let ws = self.shape_of(w);
        let pad_h = same_pad(xs.dim(1), ws.dim(1), stride);
        let pad_w = same_pad(xs.dim(2), ws.dim(2), stride);
        self.depthwise_conv2d(x, w, stride, pad_h, pad_w)
    }

    /// Inline constant tensor (imported initializers, scale factors).
    pub fn constant(&mut self, dims: &[usize], values: &[f32]) -> Id {
        self.push(Op::Constant(ConstData::new(Shape::new(dims), values)), &[])
    }

    /// Broadcast a rank-1 tensor to `dims` (channel-wise for rank 3,
    /// row-wise for rank 2).
    pub fn bcast(&mut self, x: Id, dims: &[usize]) -> Id {
        self.push(Op::Bcast(Shape::new(dims)), &[x])
    }

    /// Multiply every element by a compile-time scalar — `1/√dh` attention
    /// scaling and friends — via a broadcast `const` and `emul`.
    pub fn scale(&mut self, x: Id, factor: f32) -> Id {
        let s = self.shape_of(x);
        // `bcast` replicates a rank-1 tensor (channel-wise for rank 3,
        // row-wise for rank 2); a uniform fill makes it a scalar scale.
        let n = match s.rank() {
            3 | 1 => s.dim(0),
            2 => s.dim(1),
            r => panic!("scale on rank {r}"),
        };
        let c = self.constant(&[n], &vec![factor; n]);
        let b = self.push(Op::Bcast(s), &[c]);
        self.emul(x, b)
    }

    /// Shape of an already-built node (for layer helpers).
    pub fn shape_of(&self, id: Id) -> Shape {
        match &self.tys[id.index()] {
            Ty::Tensor(s) => s.clone(),
            other => panic!("node {id:?} is not a tensor: {other:?}"),
        }
    }

    // ---- compound layers -------------------------------------------------

    /// `relu(conv(x) + bias)` — the standard conv block. `pad` is the
    /// TOTAL padding applied to both spatial dims.
    pub fn conv_relu(
        &mut self,
        x: Id,
        name: &str,
        out_ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Id {
        let in_ch = self.shape_of(x).dim(0);
        let w = self.weight(&format!("{name}_w"), &[out_ch, in_ch, k, k]);
        let b = self.weight(&format!("{name}_b"), &[out_ch]);
        let c = self.conv2d(x, w, stride, pad, pad);
        let c = self.bias_add(c, b);
        self.relu(c)
    }

    /// `relu(x @ W + b)` (or without relu for logits).
    pub fn dense_layer(&mut self, x: Id, name: &str, out: usize, relu: bool) -> Id {
        let in_dim = self.shape_of(x).dim(1);
        let w = self.weight(&format!("{name}_w"), &[in_dim, out]);
        let b = self.weight(&format!("{name}_b"), &[out]);
        let d = self.dense(x, w);
        let d = self.bias_add(d, b);
        if relu {
            self.relu(d)
        } else {
            d
        }
    }

    /// `relu(dwconv(x) + bias)` — the depthwise half of a separable block.
    /// `pad` is the TOTAL padding applied to both spatial dims.
    pub fn dwconv_relu(&mut self, x: Id, name: &str, k: usize, stride: usize, pad: usize) -> Id {
        let ch = self.shape_of(x).dim(0);
        let w = self.weight(&format!("{name}_w"), &[ch, k, k]);
        let b = self.weight(&format!("{name}_b"), &[ch]);
        let c = self.depthwise_conv2d(x, w, stride, pad, pad);
        let c = self.bias_add(c, b);
        self.relu(c)
    }

    /// Single-head scaled-dot-product-shaped attention (unscaled — the
    /// scale constant is cost-irrelevant and EngineIR has no scalar-mul):
    /// `softmax(Q Kᵀ) V` with learned Q/K/V projections.
    pub fn attention(&mut self, x: Id, name: &str) -> Id {
        let q = self.dense_layer(x, &format!("{name}_q"), self.shape_of(x).dim(1), false);
        let k = self.dense_layer(x, &format!("{name}_k"), self.shape_of(x).dim(1), false);
        let v = self.dense_layer(x, &format!("{name}_v"), self.shape_of(x).dim(1), false);
        let kt = self.transpose(k);
        let scores = self.matmul(q, kt);
        let probs = self.softmax(scores);
        self.matmul(probs, v)
    }

    /// Pack a `(S, H)` projection into per-head rank-3 form. Row-major
    /// layout makes the head axis contiguous only after transposing:
    /// `(S,H) -> (H,S) -> reshape (heads, dh, S)`; the optional batched
    /// transpose then yields `(heads, S, dh)`.
    fn pack_heads(&mut self, p: Id, heads: usize, seq_major: bool) -> Id {
        let s = self.shape_of(p);
        let (seq, h) = (s.dim(0), s.dim(1));
        let dh = h / heads;
        let t = self.transpose(p); // (H, S)
        let r = self.reshape(t, &[heads, dh, seq]); // (heads, dh, S)
        if seq_major {
            self.transpose(r) // (heads, S, dh)
        } else {
            r
        }
    }

    /// Multi-head scaled-dot-product-shaped attention (unscaled, like
    /// [`Self::attention`]): Q/K/V projections packed as rank-3
    /// `(heads, ·, ·)` tensors, per-head `softmax(Q_h K_hᵀ) V_h` routed
    /// through `batch-matmul` (whose loop lowering the head-split rewrites
    /// act on), heads re-concatenated along the feature axis. `heads` must
    /// divide the hidden dimension.
    pub fn attention_mh(&mut self, x: Id, name: &str, heads: usize) -> Id {
        let s = self.shape_of(x);
        let (seq, h) = (s.dim(0), s.dim(1));
        assert_eq!(h % heads, 0, "heads must divide hidden dim");
        let q = self.dense_layer(x, &format!("{name}_q"), h, false);
        let k = self.dense_layer(x, &format!("{name}_k"), h, false);
        let v = self.dense_layer(x, &format!("{name}_v"), h, false);
        let qp = self.pack_heads(q, heads, true); // (heads, S, dh)
        let kp = self.pack_heads(k, heads, false); // (heads, dh, S) = K_hᵀ
        let vp = self.pack_heads(v, heads, true); // (heads, S, dh)
        let scores = self.batch_matmul(qp, kp); // (heads, S, S)
        let probs = self.softmax(scores);
        let ctx = self.batch_matmul(probs, vp); // (heads, S, dh)
        // Unpack: (heads, S, dh) -> (heads, dh, S) -> (H, S) -> (S, H),
        // which is exactly concat-over-heads along the feature axis.
        let cb = self.transpose(ctx);
        let cr = self.reshape(cb, &[h, seq]);
        self.transpose(cr)
    }

    /// Grouped-query attention (GQA, unscaled like [`Self::attention`]):
    /// `q_heads` query heads share `kv_heads` K/V heads. The K and V
    /// projections are built ONCE and packed as rank-3 `(kv_heads, ·, ·)`
    /// tensors; the query heads form `q_heads / kv_heads` groups of
    /// `kv_heads` heads each, and every group batch-matmuls against the
    /// SAME K/V pack — the graph genuinely shares one K/V subtree across
    /// multiple `batch-matmul` consumers, which is what makes GQA's
    /// design space differ from `attention_mh`'s. Each group's context is
    /// unpacked and sent through its own output projection; group outputs
    /// are summed (concat-then-project with a block-partitioned weight).
    pub fn attention_gqa(&mut self, x: Id, name: &str, q_heads: usize, kv_heads: usize) -> Id {
        let s = self.shape_of(x);
        let (seq, h) = (s.dim(0), s.dim(1));
        assert_eq!(q_heads % kv_heads, 0, "kv_heads must divide q_heads");
        assert_eq!(h % q_heads, 0, "q_heads must divide hidden dim");
        let dh = h / q_heads;
        let kv_dim = kv_heads * dh;
        let k = self.dense_layer(x, &format!("{name}_k"), kv_dim, false);
        let v = self.dense_layer(x, &format!("{name}_v"), kv_dim, false);
        let kp = self.pack_heads(k, kv_heads, false); // (kv_heads, dh, S) = K_hᵀ
        let vp = self.pack_heads(v, kv_heads, true); // (kv_heads, S, dh)
        let mut out = None;
        for g in 0..q_heads / kv_heads {
            let q = self.dense_layer(x, &format!("{name}_q{g}"), kv_dim, false);
            let qp = self.pack_heads(q, kv_heads, true); // (kv_heads, S, dh)
            let scores = self.batch_matmul(qp, kp); // (kv_heads, S, S)
            let probs = self.softmax(scores);
            let ctx = self.batch_matmul(probs, vp); // (kv_heads, S, dh)
            let cb = self.transpose(ctx); // (kv_heads, dh, S)
            let cr = self.reshape(cb, &[kv_dim, seq]);
            let cu = self.transpose(cr); // (S, kv_dim)
            let proj = self.dense_layer(cu, &format!("{name}_o{g}"), h, false);
            out = Some(match out {
                None => proj,
                Some(acc) => self.add(acc, proj),
            });
        }
        out.expect("q_heads must be positive")
    }

    /// Finish, returning the operator graph rooted at the last-added node.
    pub fn finish(self) -> RecExpr {
        assert!(!self.expr.is_empty(), "empty workload");
        self.expr
    }

    /// Finish with an explicit root (must be the last node added).
    pub fn finish_at(self, root: Id) -> RecExpr {
        assert_eq!(root, self.expr.root(), "root must be the final node");
        self.expr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_checks_shapes_eagerly() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 10]);
        let w = b.weight("w", &[10, 4]);
        let d = b.dense(x, w);
        assert_eq!(b.shape_of(d), Shape::new(&[1, 4]));
    }

    #[test]
    #[should_panic(expected = "ill-typed")]
    fn builder_rejects_bad_dense() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 10]);
        let w = b.weight("w", &[11, 4]);
        b.dense(x, w);
    }

    #[test]
    fn conv_relu_layer_shapes() {
        let mut b = GraphBuilder::new();
        let x = b.input("img", &[3, 32, 32]);
        let y = b.conv_relu(x, "c1", 8, 3, 1, 2);
        assert_eq!(b.shape_of(y), Shape::new(&[8, 32, 32]));
    }

    #[test]
    fn same_pad_matches_onnx_semantics() {
        // 112×112 stride-2 k3: out = ceil(112/2) = 56, total pad 1.
        assert_eq!(same_pad(112, 3, 2), 1);
        // stride-1 k3 keeps size with total pad 2.
        assert_eq!(same_pad(14, 3, 1), 2);
        // already-tiling input needs no pad.
        assert_eq!(same_pad(8, 2, 2), 0);
        let mut b = GraphBuilder::new();
        let x = b.input("img", &[3, 112, 112]);
        let w = b.weight("w", &[8, 3, 3, 3]);
        let y = b.conv2d_same(x, w, 2);
        assert_eq!(b.shape_of(y), Shape::new(&[8, 56, 56]));
    }

    #[test]
    fn scale_multiplies_elementwise() {
        use crate::tensor::{eval_expr, Env};
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4, 8]);
        let y = b.scale(x, 0.25);
        let e = b.finish_at(y);
        let env = Env::random_for(&e, 7);
        let got = eval_expr(&e, &mut env.clone()).unwrap();
        let want = env.tensors[&crate::ir::Symbol::new("x")].clone();
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w * 0.25).abs() < 1e-6);
        }
    }

    #[test]
    fn incremental_types_match_full_typecheck() {
        // The builder's per-push inference must agree with the from-scratch
        // pass on a deep graph (this used to be re-run per push, O(n²)).
        let mut b = GraphBuilder::new();
        let mut x = b.input("x", &[1, 32]);
        for i in 0..40 {
            x = b.dense_layer(x, &format!("fc{i}"), 32, i % 2 == 0);
        }
        let cached = b.tys.clone();
        let e = b.finish_at(x);
        assert_eq!(e.types().unwrap(), cached);
    }

    #[test]
    fn single_head_attention_is_mh_with_one_head() {
        // attention_mh(·, 1) must compute exactly attention(·): the head
        // packing degenerates to transposes/reshapes that cancel. Same
        // weight names, so Env::random_for binds identical parameters.
        use crate::tensor::{eval_expr, Env};
        let build = |mh: bool| {
            let mut b = GraphBuilder::new();
            let x = b.input("x", &[4, 8]);
            let y = if mh { b.attention_mh(x, "a", 1) } else { b.attention(x, "a") };
            b.finish_at(y)
        };
        let sh = build(false);
        let mh = build(true);
        assert_eq!(mh.typecheck().unwrap(), sh.typecheck().unwrap());
        let a = eval_expr(&sh, &mut Env::random_for(&sh, 23)).unwrap();
        let b = eval_expr(&mh, &mut Env::random_for(&mh, 23)).unwrap();
        assert!(a.allclose(&b, 1e-5), "diff {:?}", a.max_abs_diff(&b));
    }

    #[test]
    fn multi_head_attention_partitions_features() {
        // With block-diagonal-free random weights the 2-head result must
        // equal hand-computed per-head attention over feature halves.
        use crate::ir::Shape;
        use crate::tensor::{eval_expr, Env, Tensor};
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4, 8]);
        let y = b.attention_mh(x, "a", 2);
        let e = b.finish_at(y);
        let env = Env::random_for(&e, 31);
        let got = eval_expr(&e, &mut env.clone()).unwrap();

        // Reference: dense projections + per-head softmax(QKᵀ)V.
        let g = |n: &str| env.tensors[&crate::ir::Symbol::new(n)].clone();
        let proj = |w: &str, bias: &str| g("x").matmul(&g(w)).bias_add(&g(bias));
        let (q, k, v) = (proj("a_q_w", "a_q_b"), proj("a_k_w", "a_k_b"), proj("a_v_w", "a_v_b"));
        let mut parts = Vec::new();
        for h in 0..2 {
            let qh = q.slice_ax(1, h * 4, 4);
            let kh = k.slice_ax(1, h * 4, 4);
            let vh = v.slice_ax(1, h * 4, 4);
            let probs = qh.matmul(&kh.transpose_last()).softmax_last();
            parts.push(probs.matmul(&vh));
        }
        let want = Tensor::concat_ax(1, &parts);
        assert_eq!(got.shape, Shape::new(&[4, 8]));
        assert!(got.allclose(&want, 1e-5), "diff {:?}", got.max_abs_diff(&want));
    }

    #[test]
    fn grouped_query_attention_shares_kv_across_groups() {
        // 4 query heads over 2 shared K/V heads: group g's head j must
        // attend against K/V head j (the SAME K/V slices for both groups).
        // Reference-computed per group from the bound projections.
        use crate::tensor::{eval_expr, Env, Tensor};
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4, 8]);
        let y = b.attention_gqa(x, "a", 4, 2);
        let e = b.finish_at(y);
        let env = Env::random_for(&e, 47);
        let got = eval_expr(&e, &mut env.clone()).unwrap();

        let g = |n: &str| env.tensors[&crate::ir::Symbol::new(n)].clone();
        let proj = |w: &str, bias: &str| g("x").matmul(&g(w)).bias_add(&g(bias));
        let (k, v) = (proj("a_k_w", "a_k_b"), proj("a_v_w", "a_v_b"));
        let mut want: Option<Tensor> = None;
        for grp in 0..2 {
            let q = proj(&format!("a_q{grp}_w"), &format!("a_q{grp}_b"));
            let mut parts = Vec::new();
            for h in 0..2 {
                let qh = q.slice_ax(1, h * 2, 2);
                let kh = k.slice_ax(1, h * 2, 2);
                let vh = v.slice_ax(1, h * 2, 2);
                let probs = qh.matmul(&kh.transpose_last()).softmax_last();
                parts.push(probs.matmul(&vh));
            }
            let ctx = Tensor::concat_ax(1, &parts);
            let o = ctx
                .matmul(&g(&format!("a_o{grp}_w")))
                .bias_add(&g(&format!("a_o{grp}_b")));
            want = Some(match want {
                None => o,
                Some(acc) => acc.eadd(&o),
            });
        }
        let want = want.unwrap();
        assert_eq!(got.shape, Shape::new(&[4, 8]));
        assert!(got.allclose(&want, 1e-5), "diff {:?}", got.max_abs_diff(&want));
    }

    #[test]
    fn affine_layer_norm_creates_params() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[2, 8]);
        let y = b.layer_norm(x, "ln");
        let e = b.finish_at(y);
        assert_eq!(e.count(|op| matches!(op, Op::Weight(..))), 2);
        assert_eq!(e.typecheck().unwrap(), crate::ir::Ty::Tensor(Shape::new(&[2, 8])));
    }

    #[test]
    fn dense_layer_roundtrip_text() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 16]);
        let y = b.dense_layer(x, "fc", 4, true);
        let e = b.finish_at(y);
        let txt = e.to_string();
        let back = crate::ir::parse_expr(&txt).unwrap();
        assert_eq!(back.to_string(), txt);
    }
}
