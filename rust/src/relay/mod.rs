//! The Relay-like frontend.
//!
//! The paper starts from workloads "written in Relay, … the intermediate
//! representation used by the TVM compiler", which it consumes purely as a
//! graph of operator calls. This module provides exactly that surface: a
//! typed builder for operator graphs over the Relay-level subset of
//! [`crate::ir::Op`] (`conv2d`, `dense`, `relu`, …) plus a library of
//! benchmark workloads ([`workloads`]).
//!
//! A "Relay program" here *is* an EngineIR [`RecExpr`] that happens to use
//! only Relay-level ops — which is what lets [`crate::lower`] reify it
//! incrementally and lets the e-graph hold half-lowered hybrids.

pub mod workloads;

pub use workloads::{all_workloads, workload_by_name, workload_names, Workload};

use crate::egraph::Id;
use crate::ir::{infer_ty, Op, RecExpr, Shape, Symbol, Ty};

/// A typed builder for Relay-level operator graphs. Every method checks
/// shapes eagerly (via the EngineIR type checker), so a workload that
/// builds is well-formed by construction.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    expr: RecExpr,
    /// Per-slot types, maintained incrementally as nodes are pushed (the
    /// same values `expr.types()` would recompute from scratch).
    tys: Vec<Ty>,
}

impl GraphBuilder {
    pub fn new() -> Self {
        GraphBuilder::default()
    }

    fn push(&mut self, op: Op, children: &[Id]) -> Id {
        // Eager validation: infer just the new node against its
        // already-validated children. The prefix is well-typed by
        // induction, so this catches authoring bugs at the exact offending
        // layer in O(1) per push instead of re-typechecking the whole
        // prefix (O(n²) over a build).
        let child_tys: Vec<Ty> =
            children.iter().map(|&c| self.tys[c.index()].clone()).collect();
        match infer_ty(&op, &child_tys) {
            Ok(ty) => self.tys.push(ty),
            Err(e) => panic!("GraphBuilder produced ill-typed graph: {e}"),
        }
        self.expr.add_op(op, children)
    }

    /// Workload input tensor.
    pub fn input(&mut self, name: &str, dims: &[usize]) -> Id {
        self.push(Op::Input(Symbol::new(name), Shape::new(dims)), &[])
    }

    /// Trained parameter.
    pub fn weight(&mut self, name: &str, dims: &[usize]) -> Id {
        self.push(Op::Weight(Symbol::new(name), Shape::new(dims)), &[])
    }

    pub fn conv2d(&mut self, x: Id, w: Id, stride: usize, pad: usize) -> Id {
        self.push(Op::Conv2d { stride, pad }, &[x, w])
    }

    pub fn dense(&mut self, x: Id, w: Id) -> Id {
        self.push(Op::Dense, &[x, w])
    }

    pub fn relu(&mut self, x: Id) -> Id {
        self.push(Op::Relu, &[x])
    }

    pub fn bias_add(&mut self, x: Id, b: Id) -> Id {
        self.push(Op::BiasAdd, &[x, b])
    }

    pub fn add(&mut self, x: Id, y: Id) -> Id {
        self.push(Op::EAdd, &[x, y])
    }

    pub fn maxpool2d(&mut self, x: Id, k: usize, stride: usize) -> Id {
        self.push(Op::MaxPool2d { k, stride }, &[x])
    }

    pub fn flatten(&mut self, x: Id) -> Id {
        self.push(Op::Flatten, &[x])
    }

    /// General matmul of two computed tensors (attention scores etc.).
    pub fn matmul(&mut self, a: Id, b: Id) -> Id {
        self.push(Op::Matmul, &[a, b])
    }

    pub fn batch_matmul(&mut self, a: Id, b: Id) -> Id {
        self.push(Op::BatchMatmul, &[a, b])
    }

    pub fn transpose(&mut self, x: Id) -> Id {
        self.push(Op::Transpose, &[x])
    }

    pub fn softmax(&mut self, x: Id) -> Id {
        self.push(Op::Softmax, &[x])
    }

    pub fn layer_norm(&mut self, x: Id) -> Id {
        self.push(Op::LayerNorm, &[x])
    }

    pub fn gelu(&mut self, x: Id) -> Id {
        self.push(Op::Gelu, &[x])
    }

    pub fn depthwise_conv2d(&mut self, x: Id, w: Id, stride: usize, pad: usize) -> Id {
        self.push(Op::DepthwiseConv2d { stride, pad }, &[x, w])
    }

    /// Shape of an already-built node (for layer helpers).
    pub fn shape_of(&self, id: Id) -> Shape {
        match &self.tys[id.index()] {
            Ty::Tensor(s) => s.clone(),
            other => panic!("node {id:?} is not a tensor: {other:?}"),
        }
    }

    // ---- compound layers -------------------------------------------------

    /// `relu(conv(x) + bias)` — the standard conv block.
    pub fn conv_relu(
        &mut self,
        x: Id,
        name: &str,
        out_ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Id {
        let in_ch = self.shape_of(x).dim(0);
        let w = self.weight(&format!("{name}_w"), &[out_ch, in_ch, k, k]);
        let b = self.weight(&format!("{name}_b"), &[out_ch]);
        let c = self.conv2d(x, w, stride, pad);
        let c = self.bias_add(c, b);
        self.relu(c)
    }

    /// `relu(x @ W + b)` (or without relu for logits).
    pub fn dense_layer(&mut self, x: Id, name: &str, out: usize, relu: bool) -> Id {
        let in_dim = self.shape_of(x).dim(1);
        let w = self.weight(&format!("{name}_w"), &[in_dim, out]);
        let b = self.weight(&format!("{name}_b"), &[out]);
        let d = self.dense(x, w);
        let d = self.bias_add(d, b);
        if relu {
            self.relu(d)
        } else {
            d
        }
    }

    /// `relu(dwconv(x) + bias)` — the depthwise half of a separable block.
    pub fn dwconv_relu(&mut self, x: Id, name: &str, k: usize, stride: usize, pad: usize) -> Id {
        let ch = self.shape_of(x).dim(0);
        let w = self.weight(&format!("{name}_w"), &[ch, k, k]);
        let b = self.weight(&format!("{name}_b"), &[ch]);
        let c = self.depthwise_conv2d(x, w, stride, pad);
        let c = self.bias_add(c, b);
        self.relu(c)
    }

    /// Single-head scaled-dot-product-shaped attention (unscaled — the
    /// scale constant is cost-irrelevant and EngineIR has no scalar-mul):
    /// `softmax(Q Kᵀ) V` with learned Q/K/V projections.
    pub fn attention(&mut self, x: Id, name: &str) -> Id {
        let q = self.dense_layer(x, &format!("{name}_q"), self.shape_of(x).dim(1), false);
        let k = self.dense_layer(x, &format!("{name}_k"), self.shape_of(x).dim(1), false);
        let v = self.dense_layer(x, &format!("{name}_v"), self.shape_of(x).dim(1), false);
        let kt = self.transpose(k);
        let scores = self.matmul(q, kt);
        let probs = self.softmax(scores);
        self.matmul(probs, v)
    }

    /// Finish, returning the operator graph rooted at the last-added node.
    pub fn finish(self) -> RecExpr {
        assert!(!self.expr.is_empty(), "empty workload");
        self.expr
    }

    /// Finish with an explicit root (must be the last node added).
    pub fn finish_at(self, root: Id) -> RecExpr {
        assert_eq!(root, self.expr.root(), "root must be the final node");
        self.expr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_checks_shapes_eagerly() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 10]);
        let w = b.weight("w", &[10, 4]);
        let d = b.dense(x, w);
        assert_eq!(b.shape_of(d), Shape::new(&[1, 4]));
    }

    #[test]
    #[should_panic(expected = "ill-typed")]
    fn builder_rejects_bad_dense() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 10]);
        let w = b.weight("w", &[11, 4]);
        b.dense(x, w);
    }

    #[test]
    fn conv_relu_layer_shapes() {
        let mut b = GraphBuilder::new();
        let x = b.input("img", &[3, 32, 32]);
        let y = b.conv_relu(x, "c1", 8, 3, 1, 1);
        assert_eq!(b.shape_of(y), Shape::new(&[8, 32, 32]));
    }

    #[test]
    fn incremental_types_match_full_typecheck() {
        // The builder's per-push inference must agree with the from-scratch
        // pass on a deep graph (this used to be re-run per push, O(n²)).
        let mut b = GraphBuilder::new();
        let mut x = b.input("x", &[1, 32]);
        for i in 0..40 {
            x = b.dense_layer(x, &format!("fc{i}"), 32, i % 2 == 0);
        }
        let cached = b.tys.clone();
        let e = b.finish_at(x);
        assert_eq!(e.types().unwrap(), cached);
    }

    #[test]
    fn dense_layer_roundtrip_text() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 16]);
        let y = b.dense_layer(x, "fc", 4, true);
        let e = b.finish_at(y);
        let txt = e.to_string();
        let back = crate::ir::parse_expr(&txt).unwrap();
        assert_eq!(back.to_string(), txt);
    }
}
