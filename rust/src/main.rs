//! `hwsplit` CLI: the leader entrypoint for enumeration, exploration,
//! simulation and PJRT execution.
//!
//! ```text
//! hwsplit workloads
//! hwsplit lower     --workload convblock
//! hwsplit fig2
//! hwsplit enumerate --workload mlp --iters 8 --rules paper
//! hwsplit explore   --workload lenet --samples 64 --iters 6
//!                   [--model net.onnx]
//!                   [--backend analytic|interp|sim|pjrt]
//!                   [--objective latency|area|balanced] [--csv dir]
//!                   [--snapshot-out file.hws] [--snapshot-in file.hws]
//!                   [--extend-rules paper|all] [--snapshot-delta-out file.hws]
//! hwsplit serve     --snapshots a.hws,b.hws [--port 7878] [--max-sessions 4]
//!                   [--shards N] [--serve-workers N] [--queue-depth 64]
//!                   [--request-timeout-ms 10000] [--max-connections 256]
//!                   [--reload-marker FILE]
//! hwsplit snapshot-info file.hws
//! hwsplit simulate  --workload mlp [--seed 3]
//! hwsplit run       --workload mlp [--design split] [--artifacts DIR]
//! ```
//!
//! `explore` builds a [`Session`] (enumerate once) and issues one query;
//! as a library the same session answers many queries — see the crate docs.
//! `--model net.onnx` imports a real exported model through
//! [`hwsplit::import`] instead of naming a built-in workload; unsupported
//! ops are reported all at once (op type, node name, attributes).
//! `--snapshot-out` persists the saturated e-graph (+ warm cost tables) and
//! `--snapshot-in` / `serve` answer from it with zero re-saturation.
//! `--extend-rules` re-saturates a loaded snapshot under a wider rule set,
//! and `--snapshot-delta-out` persists just the growth as a v3 delta
//! against the `--snapshot-in` base. `serve --shards N` runs the
//! supervisor/router described in [`hwsplit::serve::shard`].

use hwsplit::egraph::{Runner, RunnerLimits, SchedulerSpec, SearchMode};
use hwsplit::extract::{sample_design, Extractor};
use hwsplit::ir::{parse_expr, print::pretty, RecExpr};
use hwsplit::lower::lower_default;
use hwsplit::relay::{all_workloads, workload_by_name};
use hwsplit::report::{fmt_f64, Table};
use hwsplit::rewrites::{self, RuleSet};
use hwsplit::runtime::{EngineRuntime, PjrtBackend};
use hwsplit::serve::shard::{ShardConfig, ShardServer};
use hwsplit::serve::{ServeConfig, Server, SessionStore};
use hwsplit::session::{Backend, Objective, Query, Session};
use hwsplit::sim::{simulate, SimConfig};
use hwsplit::tensor::{eval_expr, eval_expr_backend, Env};
use std::time::Instant;

/// Minimal flag parser: `--key value` pairs after the subcommand; a `--key`
/// immediately followed by another `--flag` (or nothing) is a bare boolean
/// flag.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                match argv.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        flags.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    _ => {
                        flags.insert(key.to_string(), String::new());
                        i += 1;
                    }
                }
            } else {
                i += 1;
            }
        }
        Args { flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Bare boolean flag (`--full-rescan`).
    fn flag(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Parse a typed flag via `FromStr`, exiting with the typed error on
    /// bad input (rule sets, backends, objectives all share this path).
    fn typed<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(raw) => raw.parse().unwrap_or_else(|e| {
                eprintln!("--{key}: {e}");
                std::process::exit(2);
            }),
        }
    }
}

fn workload_or_die(args: &Args) -> hwsplit::relay::Workload {
    let name = args.get("workload").unwrap_or("relu128");
    workload_by_name(name).unwrap_or_else(|| {
        eprintln!("{}", hwsplit::Error::UnknownWorkload(name.to_string()));
        std::process::exit(2);
    })
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    match cmd {
        "workloads" => cmd_workloads(),
        "lower" => cmd_lower(&args),
        "fig2" => cmd_fig2(),
        "enumerate" => cmd_enumerate(&args),
        "explore" => cmd_explore(&args),
        "serve" => cmd_serve(&args),
        "snapshot-info" => cmd_snapshot_info(&argv[1..]),
        "simulate" => cmd_simulate(&args),
        "run" => cmd_run(&args),
        _ => {
            println!("{}", include_str!("usage.txt"));
        }
    }
}

fn cmd_workloads() {
    let mut t = Table::new("workloads", &["name", "ops", "description"]);
    for w in all_workloads() {
        t.row(&[w.name.into(), w.expr.len().to_string(), w.description.into()]);
    }
    print!("{}", t.render());
}

fn cmd_lower(args: &Args) {
    let w = workload_or_die(args);
    println!("-- Relay-level operator graph ({}):\n", w.name);
    println!("{}", pretty(&w.expr));
    let lo = lower_default(&w.expr).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    println!("-- EngineIR after reification (paper Fig. 1):\n");
    println!("{}", pretty(&lo));
    let engines = lo.engines();
    println!("-- {} engine declarations:", engines.len());
    for e in engines {
        println!("   {e}");
    }
}

/// The paper's Fig. 2, replayed exactly: one 128-wide ReLU, rewrite 1
/// (shrink engine + loop), rewrite 2 (parallelize loop).
fn cmd_fig2() {
    let expr = parse_expr("(invoke-relu (relu-engine 128) (input x [128]))").unwrap();
    println!("initial program (one 128-wide ReLU engine):\n  {expr}\n");

    let mut runner = Runner::new(expr, rewrites::fig2_rules());
    let report = runner.run(8);
    println!("{}", report.table());

    println!("representative members of the root e-class:");
    let eg = &runner.egraph;
    for (i, seed) in [0u64, 2, 5, 9].iter().enumerate() {
        let d = sample_design(eg, runner.root, *seed);
        println!("  [{}] {}", i, d);
    }
    let best = Extractor::new(eg, hwsplit::extract::latency_cost).extract(eg, runner.root);
    println!("\nlatency-greedy extraction:\n  {best}");
}

fn cmd_enumerate(args: &Args) {
    let w = workload_or_die(args);
    let rules: RuleSet = args.typed("rules", RuleSet::Paper);
    let iters = args.usize("iters", 8);
    let max_nodes = args.usize("max-nodes", 200_000);
    let scheduler: SchedulerSpec = args.typed("scheduler", SchedulerSpec::Simple);
    let lo = lower_default(&w.expr).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    println!("workload {} lowered to {} EngineIR nodes", w.name, lo.len());
    let limits = RunnerLimits { max_nodes, ..Default::default() };
    let mut runner = Runner::new(lo, rules.rules())
        .with_scheduler(scheduler.build(&limits))
        .with_limits(limits)
        .with_search_mode(if args.flag("full-rescan") {
            SearchMode::FullRescan
        } else {
            SearchMode::Incremental
        });
    if let Some(workers) = args.get("search-workers").and_then(|v| v.parse().ok()) {
        runner = runner.with_search_workers(workers);
    }
    if let Some(workers) = args.get("apply-workers").and_then(|v| v.parse().ok()) {
        runner = runner.with_apply_workers(workers);
    }
    let t0 = Instant::now();
    let report = runner.run(iters);
    println!("{}", report.table());
    println!("{}", report.rule_table());
    println!(
        "designs(lower bound) = {} in {:.2?}",
        fmt_f64(report.designs_lower_bound),
        t0.elapsed()
    );
}

fn cmd_explore(args: &Args) {
    let backend: Backend = args.typed("backend", Backend::Sim);
    let objective: Objective = args.typed("objective", Objective::Latency);
    let t0 = Instant::now();
    // `--snapshot-in` resumes from a persisted enumeration (workload +
    // rules come from the snapshot; queries run with zero re-saturation);
    // otherwise build a session and enumerate here.
    let mut session = if let Some(path) = args.get("snapshot-in") {
        let mut s = Session::load_snapshot(path).unwrap_or_else(|e| {
            eprintln!("{path}: {e}");
            std::process::exit(2);
        });
        if let Some(workers) = args.get("workers").and_then(|v| v.parse().ok()) {
            s.set_workers(workers);
        }
        if let Some(workers) = args.get("extract-workers").and_then(|v| v.parse().ok()) {
            s.set_extract_workers(workers);
        }
        println!("loaded snapshot {path} (workload: {})", s.workload().name);
        s
    } else {
        // `--model net.onnx` imports a real model as the workload; it is
        // registered so error suggestions and later lookups know the name.
        let w = if let Some(model) = args.get("model") {
            if args.get("workload").is_some() {
                eprintln!("--workload and --model are mutually exclusive; pick one");
                std::process::exit(2);
            }
            let w = hwsplit::import::import_onnx(model).unwrap_or_else(|e| {
                eprintln!("--model {model}: {e}");
                std::process::exit(2);
            });
            hwsplit::relay::register_workload(w.clone());
            println!(
                "imported {model} as workload '{}' ({} relay nodes)",
                w.name,
                w.expr.len()
            );
            w
        } else {
            workload_or_die(args)
        };
        let limits = RunnerLimits {
            max_nodes: args.usize("max-nodes", 100_000),
            ..Default::default()
        };
        let scheduler: SchedulerSpec = args.typed("scheduler", SchedulerSpec::Simple);
        let mut builder = Session::builder()
            .workload(w)
            .rules(args.typed("rules", RuleSet::Paper))
            .iters(args.usize("iters", 6))
            .scheduler(scheduler.build(&limits))
            .track_designs(args.flag("track-designs"))
            .limits(limits);
        if let Some(workers) = args.get("workers").and_then(|v| v.parse().ok()) {
            builder = builder.workers(workers);
        }
        if let Some(workers) = args.get("search-workers").and_then(|v| v.parse().ok()) {
            builder = builder.search_workers(workers);
        }
        if let Some(workers) = args.get("apply-workers").and_then(|v| v.parse().ok()) {
            builder = builder.apply_workers(workers);
        }
        if let Some(workers) = args.get("extract-workers").and_then(|v| v.parse().ok()) {
            builder = builder.extract_workers(workers);
        }
        builder.build().unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        })
    };
    // `--extend-rules SET`: widen a loaded snapshot's rule set and
    // re-saturate incrementally (rules already present are skipped); pair
    // with `--snapshot-delta-out` to persist just the growth.
    if let Some(set) = args.get("extend-rules") {
        if args.get("snapshot-in").is_none() {
            eprintln!("--extend-rules needs --snapshot-in (it re-saturates a loaded snapshot)");
            std::process::exit(2);
        }
        let rules: RuleSet = set.parse().unwrap_or_else(|e| {
            eprintln!("--extend-rules: {e}");
            std::process::exit(2);
        });
        let iters = args.usize("extend-iters", 4);
        let added = session.extend_rules(rules, iters).unwrap_or_else(|e| {
            eprintln!("--extend-rules {set}: {e}");
            std::process::exit(2);
        });
        println!("extended rule set with {added} new rules");
    }
    let w = session.workload().clone();
    let samples = args.usize("samples", 64);

    // Batched mode: `--objectives latency,area` answers every objective
    // against ONE shared design sample set (one extraction pass, memoized
    // cost tables) via `Session::run_queries`.
    if let Some(list) = args.get("objectives") {
        if args.get("objective").is_some() {
            eprintln!("--objective and --objectives are mutually exclusive; pick one");
            std::process::exit(2);
        }
        let objectives: Vec<Objective> = list
            .split(',')
            .map(|s| {
                s.trim().parse().unwrap_or_else(|e| {
                    eprintln!("--objectives: {e}");
                    std::process::exit(2);
                })
            })
            .collect();
        let queries: Vec<Query> = objectives
            .iter()
            .map(|&o| Query::new().objective(o).backend(backend).samples(samples))
            .collect();
        let evs = session.run_queries(&queries).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
        println!(
            "{}",
            session.enumerate().expect("enumerated by the batch").report.table()
        );
        if let Some(first) = evs.first() {
            println!("{}", first.extract.line());
        }
        let mut t = Table::new(
            &format!("batched queries for {} (backend: {backend})", w.name),
            &["objective", "best", "area", "latency", "frontier"],
        );
        for ev in &evs {
            let best = ev.best().expect("nonempty design set");
            t.row(&[
                format!("{:?}", ev.objective),
                best.point.origin.clone(),
                fmt_f64(best.point.cost.area),
                fmt_f64(best.point.cost.latency),
                ev.frontier.len().to_string(),
            ]);
        }
        print!("{}", t.render());
        println!("explored in {:.2?}", t0.elapsed());
        if let Some(dir) = args.get("csv") {
            t.write_csv(format!("{dir}/{}_objectives.csv", w.name)).expect("write csv");
            println!("wrote CSV to {dir}/");
        }
        maybe_save_snapshot(args, &mut session);
        return;
    }

    let ev = session
        .query(&Query::new().objective(objective).backend(backend).samples(samples))
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
    println!(
        "{}",
        session.enumerate().expect("enumerated by the query").report.table()
    );
    println!("{}", ev.extract.line());

    let mut t = Table::new(
        &format!("designs for {} (backend: {})", w.name, ev.backend),
        &["origin", "area", "latency", "sim-cycles", "util%", "engines", "depth", "pars"],
    );
    for d in &ev.designs {
        t.row(&[
            d.point.origin.clone(),
            fmt_f64(d.point.cost.area),
            fmt_f64(d.point.cost.latency),
            d.sim.as_ref().map(|s| fmt_f64(s.cycles)).unwrap_or_default(),
            d.sim
                .as_ref()
                .map(|s| format!("{:.0}", s.utilization * 100.0))
                .unwrap_or_default(),
            d.point.stats.engines.to_string(),
            d.point.stats.sched_depth.to_string(),
            d.point.stats.pars.to_string(),
        ]);
    }
    print!("{}", t.render());

    let mut f = Table::new("Pareto frontier (area vs latency)", &["origin", "area", "latency"]);
    for p in &ev.frontier {
        f.row(&[p.origin.clone(), fmt_f64(p.cost.area), fmt_f64(p.cost.latency)]);
    }
    print!("{}", f.render());
    if let Some(best) = ev.best() {
        println!(
            "best ({:?}): {} area={} latency={}",
            ev.objective,
            best.point.origin,
            fmt_f64(best.point.cost.area),
            fmt_f64(best.point.cost.latency)
        );
    }
    println!("{}", ev.frontier_vs_baseline());
    println!("explored in {:.2?}", t0.elapsed());

    if let Some(dir) = args.get("csv") {
        t.write_csv(format!("{dir}/{}_designs.csv", w.name)).expect("write csv");
        f.write_csv(format!("{dir}/{}_frontier.csv", w.name)).expect("write csv");
        println!("wrote CSVs to {dir}/");
    }
    maybe_save_snapshot(args, &mut session);
}

/// `--snapshot-out FILE`: persist the session's enumerated space — run
/// *after* the queries so every cost table they solved ships in the
/// snapshot and loaders start warm. `--snapshot-delta-out FILE` persists
/// a v3 delta against the `--snapshot-in` base instead of re-encoding
/// the whole graph.
fn maybe_save_snapshot(args: &Args, session: &mut Session) {
    if let Some(path) = args.get("snapshot-out") {
        session.save_snapshot(path).unwrap_or_else(|e| {
            eprintln!("--snapshot-out {path}: {e}");
            std::process::exit(2);
        });
        println!("wrote snapshot to {path}");
    }
    if let Some(path) = args.get("snapshot-delta-out") {
        let Some(base) = args.get("snapshot-in") else {
            eprintln!("--snapshot-delta-out needs --snapshot-in as the delta base");
            std::process::exit(2);
        };
        session.save_snapshot_delta(path, base).unwrap_or_else(|e| {
            eprintln!("--snapshot-delta-out {path}: {e}");
            std::process::exit(2);
        });
        println!("wrote delta snapshot to {path} (base {base})");
    }
}

/// `hwsplit serve`: load snapshots, answer line-delimited JSON queries over
/// TCP until a client sends `{"cmd":"shutdown"}`. Wire protocol spec:
/// `docs/serving.md`; architecture: [`hwsplit::serve`].
fn cmd_serve(args: &Args) {
    let snapshots = args.get("snapshots").unwrap_or_else(|| {
        eprintln!("serve needs --snapshots FILE[,FILE...] (write them with explore --snapshot-out)");
        std::process::exit(2);
    });
    let port = args.usize("port", 7878);
    let host = args.get("host").unwrap_or("127.0.0.1");
    let shards = args.usize("shards", 1);
    if shards >= 2 {
        cmd_serve_sharded(args, snapshots, shards, host, port);
        return;
    }
    let defaults = ServeConfig::default();
    let config = ServeConfig {
        workers: args.usize("serve-workers", defaults.workers),
        queue_depth: args.usize("queue-depth", defaults.queue_depth).max(1),
        request_timeout_ms: args.usize("request-timeout-ms", defaults.request_timeout_ms as usize)
            as u64,
        max_connections: args.usize("max-connections", defaults.max_connections).max(1),
        reload_marker: args.get("reload-marker").map(std::path::PathBuf::from),
    };
    let mut store = SessionStore::new(args.usize("max-sessions", 4));
    for path in snapshots.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        match store.register(path) {
            Ok(workload) => println!("registered workload '{workload}' from {path}"),
            Err(e) => {
                eprintln!("{path}: {e}");
                std::process::exit(2);
            }
        }
    }
    let server =
        Server::bind_with(&format!("{host}:{port}"), std::sync::Arc::new(store), config.clone())
            .unwrap_or_else(|e| {
                eprintln!("bind {host}:{port}: {e}");
                std::process::exit(2);
            });
    let mode = if config.workers == 0 {
        format!("legacy thread-per-connection, cap {}", config.max_connections)
    } else {
        format!("{} workers, queue depth {}", config.workers, config.queue_depth)
    };
    println!(
        "hwsplit serve listening on {} ({} workloads registered; {mode}; request timeout {} ms)",
        server.local_addr().expect("bound socket has an address"),
        snapshots.split(',').filter(|p| !p.trim().is_empty()).count(),
        config.request_timeout_ms,
    );
    server.run().unwrap_or_else(|e| {
        eprintln!("serve: {e}");
        std::process::exit(1);
    });
    let s = server.stats().summary();
    println!(
        "shut down after {} queries ({} errors, {} rejected, {} timeouts), \
         {:.1} queries/sec, p50 {:.2} ms, p99 {:.2} ms",
        s.served, s.errors, s.rejected, s.timeouts, s.queries_per_sec, s.p50_ms, s.p99_ms
    );
}

/// `serve --shards N`: supervisor mode. Partition the snapshots across N
/// child daemons of this same binary and route requests by workload —
/// topology and semantics in [`hwsplit::serve::shard`] / `docs/serving.md`.
fn cmd_serve_sharded(args: &Args, snapshots: &str, shards: usize, host: &str, port: usize) {
    let program = std::env::current_exe().unwrap_or_else(|e| {
        eprintln!("serve --shards: cannot locate own binary: {e}");
        std::process::exit(2);
    });
    let mut config = ShardConfig::new(program, shards);
    config.host = host.to_string();
    config.request_timeout_ms = args.usize("request-timeout-ms", 10_000) as u64;
    // Per-daemon knobs are forwarded so every child shares them.
    let forwarded = [
        "serve-workers",
        "queue-depth",
        "request-timeout-ms",
        "max-connections",
        "max-sessions",
        "reload-marker",
    ];
    for flag in forwarded {
        if let Some(v) = args.get(flag) {
            config.child_args.push(format!("--{flag}"));
            config.child_args.push(v.to_string());
        }
    }
    let paths: Vec<String> = snapshots
        .split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(String::from)
        .collect();
    let server = ShardServer::bind(&format!("{host}:{port}"), &paths, config).unwrap_or_else(|e| {
        eprintln!("serve --shards {shards}: {e}");
        std::process::exit(2);
    });
    for path in &paths {
        let shard = hwsplit::persist::peek_header(path)
            .ok()
            .and_then(|m| server.shard_of(&m.workload));
        if let Some(shard) = shard {
            println!("registered {path} on shard {shard}");
        }
    }
    println!(
        "hwsplit serve listening on {} (router over {} shards; {} workloads registered; \
         request timeout {} ms)",
        server.local_addr().expect("bound socket has an address"),
        server.shard_count(),
        paths.len(),
        args.usize("request-timeout-ms", 10_000),
    );
    server.run().unwrap_or_else(|e| {
        eprintln!("serve: {e}");
        std::process::exit(1);
    });
    println!(
        "router shut down ({} shard restarts, {} router errors)",
        server.restarts(),
        server.router_errors()
    );
}

/// `snapshot-info FILE`: print a snapshot's header metadata without
/// decoding (or even reading) its payload.
fn cmd_snapshot_info(argv: &[String]) {
    let Some(path) = argv.iter().find(|a| !a.starts_with("--")) else {
        eprintln!("snapshot-info needs a snapshot file path");
        std::process::exit(2);
    };
    let meta = hwsplit::persist::peek_header(path).unwrap_or_else(|e| {
        eprintln!("{path}: {e}");
        std::process::exit(2);
    });
    let kind = if meta.base_fingerprint.is_some() { "delta" } else { "full" };
    println!("snapshot:             {path}");
    println!("format version:       {} ({kind})", meta.format_version);
    println!("workload:             {}", meta.workload);
    println!("workload fingerprint: {:#018x}", meta.workload_fingerprint);
    println!("rule-set hash:        {:#018x}", meta.ruleset_hash);
    if let Some(base) = meta.base_fingerprint {
        println!("base fingerprint:     {base:#018x}");
    }
    println!("payload:              {} bytes", meta.payload_len);
}

fn cmd_simulate(args: &Args) {
    let w = workload_or_die(args);
    let lo = lower_default(&w.expr).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let seed = args.usize("seed", 0);
    let design = if args.get("seed").is_some() {
        let mut runner = Runner::new(lo.clone(), rewrites::paper_rules());
        runner.run(args.usize("iters", 5));
        sample_design(&runner.egraph, runner.root, seed as u64)
    } else {
        lo
    };
    println!("design:\n{}", pretty(&design));
    let rep = simulate(&design, &SimConfig::default());
    println!("sim: {}", rep.line());
    let mut t = Table::new("engine activity", &["engine", "instances", "busy-cycles"]);
    for (op, busy) in &rep.engine_busy {
        t.row(&[
            op.to_string(),
            rep.engine_instances.get(op).copied().unwrap_or(0).to_string(),
            fmt_f64(*busy),
        ]);
    }
    print!("{}", t.render());
}

/// End-to-end: execute a design for the workload with engine invocations on
/// PJRT-compiled Pallas kernels, validating against the Rust oracle.
fn cmd_run(args: &Args) {
    let w = workload_or_die(args);
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(hwsplit::runtime::default_artifact_dir);
    let rt = EngineRuntime::new(&dir).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let lo = lower_default(&w.expr).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let design: RecExpr = match args.get("design").unwrap_or("initial") {
        "initial" => lo,
        "split" => {
            // Enumerate, then extract a design constrained to engines with
            // artifacts (prefer a genuinely rewritten one).
            let mut runner = Runner::new(lo.clone(), rewrites::paper_rules());
            runner.run(4);
            hwsplit::runtime::extract_covered(&runner.egraph, runner.root, &rt, true)
                .filter(|d| d.count(|op| op.is_sched()) > 0)
                .or_else(|| {
                    (0..200u64)
                        .map(|s| sample_design(&runner.egraph, runner.root, s))
                        .find(|c| {
                            c.count(|op| op.is_sched()) > 0
                                && c.engines().iter().all(|e| rt.has_engine(e))
                        })
                })
                .unwrap_or(lo)
        }
        other => {
            eprintln!("unknown --design '{other}' (initial|split)");
            std::process::exit(2);
        }
    };
    println!("design ({} nodes, {} engines):", design.len(), design.engines().len());
    println!("{}", pretty(&design));

    let mut env = Env::random_for(&design, 42);
    let want = eval_expr(&design, &mut env.clone()).expect("oracle eval");
    let mut backend = PjrtBackend::new(rt);
    let t0 = Instant::now();
    let got = eval_expr_backend(&design, &mut env, &mut backend).unwrap_or_else(|e| {
        eprintln!("PJRT execution failed: {e}");
        std::process::exit(1);
    });
    let dt = t0.elapsed();
    let diff = got.max_abs_diff(&want).unwrap_or(f32::INFINITY);
    println!(
        "PJRT inference: {:.2?} ({} engine calls, {} executables compiled)",
        dt,
        backend.pjrt_calls,
        backend.runtime.compiled()
    );
    println!("max |PJRT - oracle| = {diff:.3e}");
    assert!(diff < 1e-3, "numerics diverged");
    println!("OK");
}
