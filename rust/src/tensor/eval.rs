//! The EngineIR evaluator: executes any (well-typed) EngineIR term on
//! concrete data. This defines the language's *semantics*; every rewrite in
//! [`crate::rewrites`] is differential-tested with it (LHS ≡ RHS on random
//! inputs), and the PJRT runtime is validated against it end-to-end.
//!
//! Schedules evaluate numerically identically whether sequential
//! (`sched-loop`) or parallel (`sched-par`) — they differ only in cost —
//! which is exactly the paper's "functional equivalence across splits".
//!
//! The evaluator owns only the language's *structural* features: index
//! arithmetic, leaf binding, slicing, schedule iteration/reduction, and
//! storage transparency. All per-op compute — Relay kernels, data-layout
//! transforms, and the [`Oracle`]'s engine semantics — dispatches through
//! the [`crate::ir::spec`] registry, so new ops need no evaluator changes.

use super::Tensor;
use crate::egraph::Id;
use crate::ir::{Op, OpClass, OpKind, RecExpr, Symbol};
use std::collections::HashMap;

/// Evaluation failure (unbound names, ill-formed programs the type checker
/// would also reject).
#[derive(Debug, Clone)]
pub enum EvalError {
    UnboundTensor(Symbol),
    UnboundLVar(Symbol),
    NotAnIndex(Id),
    NotATensor(Id),
    Backend(String),
}

impl std::fmt::Display for EvalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EvalError::UnboundTensor(s) => write!(f, "unbound tensor '{s}'"),
            EvalError::UnboundLVar(s) => write!(f, "unbound loop variable '{s}'"),
            EvalError::NotAnIndex(id) => write!(f, "expected an index expression at {id:?}"),
            EvalError::NotATensor(id) => {
                write!(f, "expected a tensor at {id:?} (engines have no value)")
            }
            EvalError::Backend(msg) => write!(f, "engine backend: {msg}"),
        }
    }
}

impl std::error::Error for EvalError {}

/// How engine invocations execute. The default [`Oracle`] computes them
/// with the pure-Rust tensor ops; [`crate::runtime::PjrtBackend`] routes
/// them to AOT-compiled Pallas kernels on the PJRT CPU client. Everything
/// *around* the invocations — schedules, slices, buffers — always runs in
/// Rust: that is the software side of the hardware–software split.
pub trait EngineBackend {
    fn invoke(&mut self, engine: &Op, kind: OpKind, args: &[Tensor])
        -> Result<Tensor, EvalError>;
}

/// Reference backend: engine semantics via the registry's invoke kernels.
#[derive(Debug, Default, Clone, Copy)]
pub struct Oracle;

impl EngineBackend for Oracle {
    fn invoke(
        &mut self,
        engine: &Op,
        kind: OpKind,
        args: &[Tensor],
    ) -> Result<Tensor, EvalError> {
        match kind.spec().invoke_eval {
            Some(kernel) => kernel(engine, args),
            None => Err(EvalError::Backend(format!("not an invoke kind: {kind:?}"))),
        }
    }
}

/// Binding environment: named workload tensors plus the enclosing schedule
/// loop variables.
#[derive(Debug, Clone, Default)]
pub struct Env {
    pub tensors: HashMap<Symbol, Tensor>,
    loops: Vec<(Symbol, i64)>,
}

impl Env {
    pub fn new() -> Self {
        Env::default()
    }

    /// Bind every `input`/`weight` leaf of `expr` to a deterministic random
    /// tensor derived from its name — the standard differential-test setup.
    pub fn random_for(expr: &RecExpr, seed: u64) -> Self {
        let mut env = Env::new();
        for node in expr.nodes() {
            if let Op::Input(name, sh) | Op::Weight(name, sh) = &node.op {
                let mut h = seed;
                for b in name.as_str().bytes() {
                    h = h.wrapping_mul(0x100000001b3).wrapping_add(b as u64);
                }
                env.tensors.insert(*name, Tensor::random(sh.clone(), h));
            }
        }
        env
    }

    pub fn bind(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(Symbol::new(name), t);
    }

    fn lvar(&self, s: Symbol) -> Option<i64> {
        self.loops.iter().rev().find(|(v, _)| *v == s).map(|&(_, i)| i)
    }
}

enum Value {
    Tensor(Tensor),
    Index(i64),
}

struct Evaluator<'a, 'b> {
    expr: &'a RecExpr,
    /// Per-slot free loop variables, for memo keys.
    free: Vec<Vec<Symbol>>,
    /// Memo: (slot, values of its free lvars) -> tensor.
    memo: HashMap<(usize, Vec<i64>), Tensor>,
    backend: &'b mut dyn EngineBackend,
}


impl<'a, 'b> Evaluator<'a, 'b> {
    fn eval(&mut self, id: Id, env: &mut Env) -> Result<Value, EvalError> {
        let slot = id.index();
        let node = self.expr.node(id).clone();

        // Memo lookup (tensors only; index exprs are cheap).
        let key: Option<(usize, Vec<i64>)> = {
            let vals: Option<Vec<i64>> =
                self.free[slot].iter().map(|&s| env.lvar(s)).collect();
            vals.map(|v| (slot, v))
        };
        if let Some(k) = &key {
            if let Some(t) = self.memo.get(k) {
                return Ok(Value::Tensor(t.clone()));
            }
        }

        let value = self.eval_node(&node, env)?;
        if let (Some(k), Value::Tensor(t)) = (key, &value) {
            self.memo.insert(k, t.clone());
        }
        Ok(value)
    }

    fn tensor(&mut self, id: Id, env: &mut Env) -> Result<Tensor, EvalError> {
        match self.eval(id, env)? {
            Value::Tensor(t) => Ok(t),
            Value::Index(_) => Err(EvalError::NotATensor(id)),
        }
    }

    fn index(&mut self, id: Id, env: &mut Env) -> Result<i64, EvalError> {
        match self.eval(id, env)? {
            Value::Index(i) => Ok(i),
            Value::Tensor(_) => Err(EvalError::NotAnIndex(id)),
        }
    }

    fn eval_node(&mut self, node: &crate::ir::Node, env: &mut Env) -> Result<Value, EvalError> {
        use Value::*;
        let c = &node.children;
        let spec = node.op.spec();
        Ok(match spec.class {
            // ---- structural core: index arithmetic ----
            OpClass::Index => match &node.op {
                Op::Int(v) => Index(*v),
                Op::LVar(s) => Index(env.lvar(*s).ok_or(EvalError::UnboundLVar(*s))?),
                Op::IMul => Index(self.index(c[0], env)? * self.index(c[1], env)?),
                Op::IAdd => Index(self.index(c[0], env)? + self.index(c[1], env)?),
                _ => unreachable!(),
            },

            // ---- leaves: environment lookup ----
            OpClass::Leaf => match &node.op {
                Op::Input(name, _) | Op::Weight(name, _) => Tensor(
                    env.tensors.get(name).cloned().ok_or(EvalError::UnboundTensor(*name))?,
                ),
                // Constants carry their own data — no environment binding.
                Op::Constant(c) => {
                    Tensor(super::Tensor::new(c.shape().clone(), c.values()))
                }
                _ => unreachable!(),
            },

            // Engines have no runtime value; invocations ignore slot 0's
            // "value" and use the engine op's semantics directly.
            OpClass::Engine => return Err(EvalError::NotATensor(Id::from_index(0))),

            OpClass::Invoke => {
                let engine = self.expr.node(c[0]).op.clone();
                let mut args = Vec::with_capacity(c.len() - 1);
                for &a in &c[1..] {
                    args.push(self.tensor(a, env)?);
                }
                Tensor(self.backend.invoke(&engine, node.op.kind(), &args)?)
            }

            // ---- structural core: schedules bind loop variables ----
            OpClass::Sched => match &node.op {
                Op::SchedLoop { var, axis, extent } | Op::SchedPar { var, axis, extent } => {
                    let mut parts = Vec::with_capacity(*extent);
                    for i in 0..*extent {
                        env.loops.push((*var, i as i64));
                        let t = self.tensor(c[0], env);
                        env.loops.pop();
                        parts.push(t?);
                    }
                    Tensor(super::Tensor::concat_ax(*axis, &parts))
                }
                Op::SchedReduce { var, extent } => {
                    let mut acc: Option<super::Tensor> = None;
                    for i in 0..*extent {
                        env.loops.push((*var, i as i64));
                        let t = self.tensor(c[0], env);
                        env.loops.pop();
                        let t = t?;
                        acc = Some(match acc {
                            None => t,
                            Some(a) => a.eadd(&t),
                        });
                    }
                    Tensor(acc.expect("zero-extent reduce"))
                }
                _ => unreachable!(),
            },

            // ---- compute & layout: registry kernels ----
            // SliceAx is the one data op with a dynamic *index* child; it
            // stays structural. Everything else evaluates its child tensors
            // and calls the spec's reference kernel.
            OpClass::Relay | OpClass::Data => {
                if let Op::SliceAx { axis, len } = &node.op {
                    let start = self.index(c[0], env)?;
                    let x = self.tensor(c[1], env)?;
                    Tensor(x.slice_ax(
                        *axis,
                        usize::try_from(start).expect("negative slice"),
                        *len,
                    ))
                } else {
                    let kernel = spec.eval.ok_or_else(|| {
                        EvalError::Backend(format!("no eval kernel for {}", node.op))
                    })?;
                    let mut args = Vec::with_capacity(c.len());
                    for &a in c {
                        args.push(self.tensor(a, env)?);
                    }
                    Tensor(kernel(&node.op, &args)?)
                }
            }

            // Buffers are semantically transparent (cost-only).
            OpClass::Storage => Tensor(self.tensor(c[0], env)?),
        })
    }
}

/// Evaluate `expr` (rooted at its last slot) under `env` with the oracle
/// backend.
pub fn eval_expr(expr: &RecExpr, env: &mut Env) -> Result<Tensor, EvalError> {
    eval_expr_backend(expr, env, &mut Oracle)
}

/// Evaluate with a custom engine backend (e.g. PJRT-compiled kernels).
pub fn eval_expr_backend(
    expr: &RecExpr,
    env: &mut Env,
    backend: &mut dyn EngineBackend,
) -> Result<Tensor, EvalError> {
    let mut ev = Evaluator { expr, free: expr.free_lvars(), memo: HashMap::new(), backend };
    ev.tensor(expr.root(), env)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parse_expr;

    fn eval(src: &str, seed: u64) -> Tensor {
        let e = parse_expr(src).unwrap();
        e.typecheck().unwrap_or_else(|err| panic!("{src}: {err}"));
        let mut env = Env::random_for(&e, seed);
        eval_expr(&e, &mut env).unwrap()
    }

    #[test]
    fn invoke_equals_relay_relu() {
        let a = eval("(relu (input x [128]))", 1);
        let b = eval("(invoke-relu (relu-engine 128) (input x [128]))", 1);
        assert!(a.allclose(&b, 0.0));
    }

    /// Paper Fig. 2, rewrite 1: whole-engine vs loop-over-half-engine.
    #[test]
    fn fig2_loop_split_preserves_semantics() {
        let whole = eval("(invoke-relu (relu-engine 128) (input x [128]))", 2);
        let split = eval(
            "(sched-loop i0 0 2 (invoke-relu (relu-engine 64) \
               (slice 0 64 (imul (lvar i0) 64) (input x [128]))))",
            2,
        );
        assert!(whole.allclose(&split, 0.0));
    }

    /// Paper Fig. 2, rewrite 2: loop and par are numerically identical.
    #[test]
    fn fig2_par_equals_loop() {
        let l = eval(
            "(sched-loop i0 0 2 (invoke-relu (relu-engine 64) \
               (slice 0 64 (imul (lvar i0) 64) (input x [128]))))",
            3,
        );
        let p = eval(
            "(sched-par i0 0 2 (invoke-relu (relu-engine 64) \
               (slice 0 64 (imul (lvar i0) 64) (input x [128]))))",
            3,
        );
        assert!(l.allclose(&p, 0.0));
    }

    #[test]
    fn sched_reduce_matches_full_matmul() {
        let full = eval("(dense (input a [4 16]) (weight b [16 4]))", 4);
        let split = eval(
            "(sched-reduce r0 2 (invoke-mm (mm-engine 4 8 4) \
               (slice 1 8 (imul (lvar r0) 8) (input a [4 16])) \
               (slice 0 8 (imul (lvar r0) 8) (weight b [16 4]))))",
            4,
        );
        assert!(full.allclose(&split, 1e-5));
    }

    #[test]
    fn nested_loops_compose() {
        // Split 128 -> 2 x (2 x 32).
        let whole = eval("(invoke-relu (relu-engine 128) (input x [128]))", 5);
        let nested = eval(
            "(sched-loop a 0 2 (sched-loop b 0 2 (invoke-relu (relu-engine 32) \
               (slice 0 32 (iadd (imul (lvar a) 64) (imul (lvar b) 32)) (input x [128])))))",
            5,
        );
        assert!(whole.allclose(&nested, 0.0));
    }

    #[test]
    fn conv_engine_row_split() {
        // Full conv vs 2-way output-row split with halo slices.
        let full = eval(
            "(invoke-conv (conv-engine 6 6 3 4 3 3 1) (input x [3 8 8]) (weight w [4 3 3 3]))",
            6,
        );
        let split = eval(
            "(sched-loop i 1 3 (invoke-conv (conv-engine 2 6 3 4 3 3 1) \
               (slice 1 4 (imul (lvar i) 2) (input x [3 8 8])) (weight w [4 3 3 3])))",
            6,
        );
        assert!(full.allclose(&split, 1e-5), "{:?}", full.max_abs_diff(&split));
    }

    #[test]
    fn invoke_equals_relay_new_ops() {
        // Each new engine's oracle kernel matches its Relay op.
        let a = eval("(softmax (input x [16]))", 11);
        let b = eval("(invoke-softmax (softmax-engine 16) (input x [16]))", 11);
        assert!(a.allclose(&b, 0.0));
        // The layernorm ENGINE is non-affine; the relay op's affine form
        // with unit gamma / zero beta must agree with it. EngineIR has no
        // constant-tensor literal, so compare through the tensor oracle.
        let e = parse_expr("(invoke-layernorm (layernorm-engine 16) (input x [16]))").unwrap();
        let mut env = Env::random_for(&e, 12);
        let x = env.tensors.values().next().unwrap().clone();
        let b = eval_expr(&e, &mut env).unwrap();
        assert!(x.layernorm_last(1e-5).allclose(&b, 0.0));
        let a = eval("(gelu (input x [16]))", 13);
        let b = eval("(invoke-gelu (gelu-engine 16) (input x [16]))", 13);
        assert!(a.allclose(&b, 0.0));
        let a = eval("(dwconv2d 1 0 0 (input x [3 6 6]) (weight w [3 3 3]))", 14);
        let b = eval(
            "(invoke-dw-conv (dw-conv-engine 4 4 3 3 3 1) (input x [3 6 6]) (weight w [3 3 3]))",
            14,
        );
        assert!(a.allclose(&b, 0.0));
    }

    #[test]
    fn dwconv_engine_channel_split() {
        // Depthwise channels are independent: 2-way channel split is exact.
        let full = eval(
            "(invoke-dw-conv (dw-conv-engine 4 4 4 3 3 1) (input x [4 6 6]) (weight w [4 3 3]))",
            15,
        );
        let split = eval(
            "(sched-loop ch 0 2 (invoke-dw-conv (dw-conv-engine 4 4 2 3 3 1) \
               (slice 0 2 (imul (lvar ch) 2) (input x [4 6 6])) \
               (slice 0 2 (imul (lvar ch) 2) (weight w [4 3 3]))))",
            15,
        );
        assert!(full.allclose(&split, 0.0));
    }

    #[test]
    fn buffers_are_transparent() {
        let a = eval("(relu (input x [16]))", 7);
        let b = eval("(buffer sram (relu (input x [16])))", 7);
        let c = eval("(dbl-buffer dram (relu (input x [16])))", 7);
        assert!(a.allclose(&b, 0.0));
        assert!(a.allclose(&c, 0.0));
    }

    #[test]
    fn unbound_tensor_errors() {
        let e = parse_expr("(relu (input nope [4]))").unwrap();
        let mut env = Env::new();
        assert!(matches!(eval_expr(&e, &mut env), Err(EvalError::UnboundTensor(_))));
    }

    #[test]
    fn memo_consistency_under_loops() {
        // The same sliced subtree evaluated at different loop indices must
        // NOT be memo-confused (free-lvar keying).
        let split = eval(
            "(sched-loop i0 0 2 (invoke-relu (relu-engine 64) \
               (slice 0 64 (imul (lvar i0) 64) (input x [128]))))",
            8,
        );
        let whole = eval("(relu (input x [128]))", 8);
        assert!(whole.allclose(&split, 0.0));
    }
}
