//! Pure-Rust tensor math: the *numeric oracle* for the whole system.
//!
//! Everything else that computes — the EngineIR evaluator ([`eval`]), the
//! PJRT-executed Pallas kernels ([`crate::runtime`]), the simulator's
//! functional mode — is differential-tested against these straightforward,
//! obviously-correct loops.

pub mod eval;

pub use eval::{eval_expr, eval_expr_backend, Env, EngineBackend, EvalError, Oracle};

use crate::ir::Shape;
use std::fmt;

/// A dense row-major `f32` tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Shape,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{}(", self.shape)?;
        let n = self.data.len().min(8);
        for (i, v) in self.data[..n].iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v:.3}")?;
        }
        if self.data.len() > n {
            write!(f, ", …")?;
        }
        write!(f, ")")
    }
}

impl Tensor {
    pub fn new(shape: Shape, data: Vec<f32>) -> Self {
        assert_eq!(shape.numel(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn zeros(shape: Shape) -> Self {
        let n = shape.numel();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Deterministic pseudo-random tensor (for differential tests): values
    /// in [-1, 1) derived from `seed` via a splitmix-style hash.
    pub fn random(shape: Shape, seed: u64) -> Self {
        let n = shape.numel();
        let mut data = Vec::with_capacity(n);
        let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
        for _ in 0..n {
            s ^= s >> 30;
            s = s.wrapping_mul(0xbf58476d1ce4e5b9);
            s ^= s >> 27;
            s = s.wrapping_mul(0x94d049bb133111eb);
            s ^= s >> 31;
            let v = (s >> 11) as f64 / (1u64 << 53) as f64; // [0,1)
            data.push((v * 2.0 - 1.0) as f32);
            s = s.wrapping_add(0x9e3779b97f4a7c15);
        }
        Tensor { shape, data }
    }

    pub fn rank(&self) -> usize {
        self.shape.rank()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    #[inline]
    fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.rank()];
        for i in (0..self.rank().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape.dim(i + 1);
        }
        s
    }

    /// Element access by multi-index (bounds-checked; test/oracle use only).
    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.rank());
        let strides = self.strides();
        let off: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        self.data[off]
    }

    /// Max absolute difference; `None` if shapes differ.
    pub fn max_abs_diff(&self, other: &Tensor) -> Option<f32> {
        if self.shape != other.shape {
            return None;
        }
        Some(
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max),
        )
    }

    /// Allclose with absolute tolerance.
    pub fn allclose(&self, other: &Tensor, tol: f32) -> bool {
        self.max_abs_diff(other).is_some_and(|d| d <= tol)
    }

    // ------------------------------------------------------------------
    // Operators (each mirrors one `infer` rule in `ir::shape`)
    // ------------------------------------------------------------------

    /// `(m,k) @ (k,n) -> (m,n)`.
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(b.rank(), 2);
        let (m, k) = (self.shape.dim(0), self.shape.dim(1));
        let (k2, n) = (b.shape.dim(0), b.shape.dim(1));
        assert_eq!(k, k2, "matmul inner dims");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &b.data[p * n..(p + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Tensor::new(Shape::new(&[m, n]), out)
    }

    pub fn relu(&self) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&v| v.max(0.0)).collect(),
        }
    }

    pub fn eadd(&self, b: &Tensor) -> Tensor {
        assert_eq!(self.shape, b.shape, "eadd shapes");
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
        }
    }

    /// Elementwise (Hadamard) multiply.
    pub fn emul(&self, b: &Tensor) -> Tensor {
        assert_eq!(self.shape, b.shape, "emul shapes");
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&b.data).map(|(x, y)| x * y).collect(),
        }
    }

    /// Bias add: rank-3 `x` gets `b` along dim 0; rank-2 along dim 1.
    pub fn bias_add(&self, b: &Tensor) -> Tensor {
        assert_eq!(b.rank(), 1);
        let mut out = self.clone();
        match self.rank() {
            3 => {
                let (c, h, w) = (self.shape.dim(0), self.shape.dim(1), self.shape.dim(2));
                assert_eq!(b.numel(), c);
                for ci in 0..c {
                    for i in 0..h * w {
                        out.data[ci * h * w + i] += b.data[ci];
                    }
                }
            }
            2 => {
                let (m, n) = (self.shape.dim(0), self.shape.dim(1));
                assert_eq!(b.numel(), n);
                for i in 0..m {
                    for j in 0..n {
                        out.data[i * n + j] += b.data[j];
                    }
                }
            }
            r => panic!("bias_add on rank {r}"),
        }
        out
    }

    /// Valid 2-D convolution (pre-padded input): `x:(C,H,W), w:(K,C,KH,KW)`.
    pub fn conv2d(&self, w: &Tensor, stride: usize) -> Tensor {
        assert_eq!(self.rank(), 3);
        assert_eq!(w.rank(), 4);
        let (c, h, wd) = (self.shape.dim(0), self.shape.dim(1), self.shape.dim(2));
        let (kout, cin, kh, kw) = (w.shape.dim(0), w.shape.dim(1), w.shape.dim(2), w.shape.dim(3));
        assert_eq!(c, cin, "conv channels");
        let oh = (h - kh) / stride + 1;
        let ow = (wd - kw) / stride + 1;
        let mut out = vec![0.0f32; kout * oh * ow];
        for ko in 0..kout {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for ci in 0..c {
                        for dy in 0..kh {
                            let iy = oy * stride + dy;
                            let xbase = ci * h * wd + iy * wd + ox * stride;
                            let wbase = ((ko * cin + ci) * kh + dy) * kw;
                            for dx in 0..kw {
                                acc += self.data[xbase + dx] * w.data[wbase + dx];
                            }
                        }
                    }
                    out[(ko * oh + oy) * ow + ox] = acc;
                }
            }
        }
        Tensor::new(Shape::new(&[kout, oh, ow]), out)
    }

    /// Max pooling over `(C,H,W)` with a rectangular `kh`×`kw` window.
    pub fn maxpool2d(&self, kh: usize, kw: usize, stride: usize) -> Tensor {
        assert_eq!(self.rank(), 3);
        let (c, h, w) = (self.shape.dim(0), self.shape.dim(1), self.shape.dim(2));
        let oh = (h - kh) / stride + 1;
        let ow = (w - kw) / stride + 1;
        let mut out = vec![f32::NEG_INFINITY; c * oh * ow];
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..kh {
                        for dx in 0..kw {
                            m = m.max(
                                self.data
                                    [ci * h * w + (oy * stride + dy) * w + (ox * stride + dx)],
                            );
                        }
                    }
                    out[(ci * oh + oy) * ow + ox] = m;
                }
            }
        }
        Tensor::new(Shape::new(&[c, oh, ow]), out)
    }

    pub fn reshape(&self, shape: Shape) -> Tensor {
        assert_eq!(shape.numel(), self.numel(), "reshape numel");
        Tensor { shape, data: self.data.clone() }
    }

    /// Broadcast a rank-1 tensor to `shape` (dim 0 of rank-3, dim 1 of
    /// rank-2, identity for rank-1) — mirrors `Op::Bcast`.
    pub fn bcast(&self, shape: Shape) -> Tensor {
        assert_eq!(self.rank(), 1);
        match shape.rank() {
            1 => {
                assert_eq!(shape.dim(0), self.numel());
                Tensor { shape, data: self.data.clone() }
            }
            2 => {
                let (m, n) = (shape.dim(0), shape.dim(1));
                assert_eq!(n, self.numel());
                let mut data = Vec::with_capacity(m * n);
                for _ in 0..m {
                    data.extend_from_slice(&self.data);
                }
                Tensor { shape, data }
            }
            3 => {
                let (c, h, w) = (shape.dim(0), shape.dim(1), shape.dim(2));
                assert_eq!(c, self.numel());
                let mut data = Vec::with_capacity(c * h * w);
                for ci in 0..c {
                    data.extend(std::iter::repeat(self.data[ci]).take(h * w));
                }
                Tensor { shape, data }
            }
            r => panic!("bcast to rank {r}"),
        }
    }

    /// Zero-pad H and W of `(C,H,W)`. `pad_h`/`pad_w` are the TOTAL padding
    /// per spatial dim, split `floor(p/2)` before / `ceil(p/2)` after
    /// (ONNX `SAME_UPPER`); a symmetric pad of `p` per side is `2p` total.
    pub fn pad2d(&self, pad_h: usize, pad_w: usize) -> Tensor {
        assert_eq!(self.rank(), 3);
        let (c, h, w) = (self.shape.dim(0), self.shape.dim(1), self.shape.dim(2));
        let (nh, nw) = (h + pad_h, w + pad_w);
        let (top, left) = (pad_h / 2, pad_w / 2);
        let mut out = vec![0.0f32; c * nh * nw];
        for ci in 0..c {
            for y in 0..h {
                let src = &self.data[ci * h * w + y * w..ci * h * w + (y + 1) * w];
                let dst = ci * nh * nw + (y + top) * nw + left;
                out[dst..dst + w].copy_from_slice(src);
            }
        }
        Tensor::new(Shape::new(&[c, nh, nw]), out)
    }

    /// im2col: `(C,H,W) -> (C*KH*KW, OH*OW)` patch matrix, matching
    /// `Op::Im2Col` — column j holds the receptive field of output pixel j.
    /// Kernels are rectangular (`kh`×`kw`).
    pub fn im2col(&self, kh: usize, kw: usize, stride: usize) -> Tensor {
        assert_eq!(self.rank(), 3);
        let (c, h, w) = (self.shape.dim(0), self.shape.dim(1), self.shape.dim(2));
        let oh = (h - kh) / stride + 1;
        let ow = (w - kw) / stride + 1;
        let rows = c * kh * kw;
        let cols = oh * ow;
        let mut out = vec![0.0f32; rows * cols];
        for ci in 0..c {
            for dy in 0..kh {
                for dx in 0..kw {
                    let r = (ci * kh + dy) * kw + dx;
                    for oy in 0..oh {
                        for ox in 0..ow {
                            out[r * cols + oy * ow + ox] =
                                self.data[ci * h * w + (oy * stride + dy) * w + ox * stride + dx];
                        }
                    }
                }
            }
        }
        Tensor::new(Shape::new(&[rows, cols]), out)
    }

    /// Depthwise (channel multiplier 1) valid convolution:
    /// `x:(C,H,W), w:(C,KH,KW) -> (C,OH,OW)` — each channel convolved with
    /// its own rectangular kernel.
    pub fn depthwise_conv2d(&self, w: &Tensor, stride: usize) -> Tensor {
        assert_eq!(self.rank(), 3);
        assert_eq!(w.rank(), 3);
        let (c, h, wd) = (self.shape.dim(0), self.shape.dim(1), self.shape.dim(2));
        let (c2, kh, kw) = (w.shape.dim(0), w.shape.dim(1), w.shape.dim(2));
        assert_eq!(c, c2, "depthwise channels");
        let oh = (h - kh) / stride + 1;
        let ow = (wd - kw) / stride + 1;
        let mut out = vec![0.0f32; c * oh * ow];
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for dy in 0..kh {
                        let iy = oy * stride + dy;
                        let xbase = ci * h * wd + iy * wd + ox * stride;
                        let wbase = (ci * kh + dy) * kw;
                        for dx in 0..kw {
                            acc += self.data[xbase + dx] * w.data[wbase + dx];
                        }
                    }
                    out[(ci * oh + oy) * ow + ox] = acc;
                }
            }
        }
        Tensor::new(Shape::new(&[c, oh, ow]), out)
    }

    /// Transpose of the trailing two axes: `(m,n) -> (n,m)` for rank 2,
    /// `(b,m,n) -> (b,n,m)` for rank 3 (batched).
    pub fn transpose_last(&self) -> Tensor {
        let r = self.rank();
        assert!(r == 2 || r == 3, "transpose_last on rank {r}");
        let b = if r == 3 { self.shape.dim(0) } else { 1 };
        let (m, n) = (self.shape.dim(r - 2), self.shape.dim(r - 1));
        let mut out = vec![0.0f32; b * m * n];
        for bi in 0..b {
            let base = bi * m * n;
            for i in 0..m {
                for j in 0..n {
                    out[base + j * m + i] = self.data[base + i * n + j];
                }
            }
        }
        let shape = if r == 3 {
            Shape::new(&[b, n, m])
        } else {
            Shape::new(&[n, m])
        };
        Tensor::new(shape, out)
    }

    /// Batched matmul `(B,M,K) @ (B,K,N) -> (B,M,N)`.
    pub fn batch_matmul(&self, b: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 3);
        assert_eq!(b.rank(), 3);
        let (bt, m, k) = (self.shape.dim(0), self.shape.dim(1), self.shape.dim(2));
        let (bt2, k2, n) = (b.shape.dim(0), b.shape.dim(1), b.shape.dim(2));
        assert_eq!(bt, bt2, "batch dims");
        assert_eq!(k, k2, "batch-matmul inner dims");
        let mut out = Vec::with_capacity(bt * m * n);
        for bi in 0..bt {
            let a = Tensor::new(
                Shape::new(&[m, k]),
                self.data[bi * m * k..(bi + 1) * m * k].to_vec(),
            );
            let bb = Tensor::new(
                Shape::new(&[k, n]),
                b.data[bi * k * n..(bi + 1) * k * n].to_vec(),
            );
            out.extend_from_slice(&a.matmul(&bb).data);
        }
        Tensor::new(Shape::new(&[bt, m, n]), out)
    }

    /// Numerically-stable softmax over the last axis (any rank; leading
    /// axes are treated as independent rows).
    pub fn softmax_last(&self) -> Tensor {
        let last = self.shape.dim(self.rank() - 1);
        let rows = self.numel() / last;
        let mut out = self.data.clone();
        for r in 0..rows {
            let row = &mut out[r * last..(r + 1) * last];
            let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
        Tensor { shape: self.shape.clone(), data: out }
    }

    /// Layer normalization over the last axis (population variance,
    /// non-affine): `(x - mean) / sqrt(var + eps)`.
    pub fn layernorm_last(&self, eps: f32) -> Tensor {
        let last = self.shape.dim(self.rank() - 1);
        let rows = self.numel() / last;
        let mut out = self.data.clone();
        for r in 0..rows {
            let row = &mut out[r * last..(r + 1) * last];
            let mean = row.iter().sum::<f32>() / last as f32;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / last as f32;
            let inv = 1.0 / (var + eps).sqrt();
            for v in row.iter_mut() {
                *v = (*v - mean) * inv;
            }
        }
        Tensor { shape: self.shape.clone(), data: out }
    }

    /// Affine layer normalization over the last axis:
    /// `gamma ⊙ norm(x) + beta`, broadcast per row. `gamma`/`beta` are
    /// rank 1 of the last-axis length.
    pub fn layernorm_affine_last(&self, gamma: &Tensor, beta: &Tensor, eps: f32) -> Tensor {
        let last = self.shape.dim(self.rank() - 1);
        assert_eq!(gamma.shape, Shape::new(&[last]), "gamma shape");
        assert_eq!(beta.shape, Shape::new(&[last]), "beta shape");
        let mut out = self.layernorm_last(eps);
        let rows = self.numel() / last;
        for r in 0..rows {
            let row = &mut out.data[r * last..(r + 1) * last];
            for (j, v) in row.iter_mut().enumerate() {
                *v = *v * gamma.data[j] + beta.data[j];
            }
        }
        out
    }

    /// Elementwise GELU, tanh approximation:
    /// `0.5·x·(1 + tanh(√(2/π)·(x + 0.044715·x³)))`.
    pub fn gelu(&self) -> Tensor {
        const C: f32 = 0.797_884_6; // sqrt(2/pi)
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .map(|&x| 0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh()))
                .collect(),
        }
    }

    /// Global average pool `(C,H,W) -> (C,)`.
    pub fn gap(&self) -> Tensor {
        assert_eq!(self.rank(), 3);
        let (c, h, w) = (self.shape.dim(0), self.shape.dim(1), self.shape.dim(2));
        let mut out = Vec::with_capacity(c);
        for ci in 0..c {
            let s: f32 = self.data[ci * h * w..(ci + 1) * h * w].iter().sum();
            out.push(s / (h * w) as f32);
        }
        Tensor::new(Shape::new(&[c]), out)
    }

    /// Slice `len` elements starting at `start` along `axis`.
    pub fn slice_ax(&self, axis: usize, start: usize, len: usize) -> Tensor {
        assert!(axis < self.rank());
        assert!(start + len <= self.shape.dim(axis), "slice OOB");
        let outer: usize = self.shape.0[..axis].iter().product();
        let mid = self.shape.dim(axis);
        let inner: usize = self.shape.0[axis + 1..].iter().product();
        let mut out = Vec::with_capacity(outer * len * inner);
        for o in 0..outer {
            let base = (o * mid + start) * inner;
            out.extend_from_slice(&self.data[base..base + len * inner]);
        }
        Tensor::new(self.shape.with_dim(axis, len), out)
    }

    /// Concatenate along `axis` (all other dims equal).
    pub fn concat_ax(axis: usize, parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let first = &parts[0];
        let total: usize = parts.iter().map(|p| p.shape.dim(axis)).sum();
        for p in parts {
            for d in 0..first.rank() {
                if d != axis {
                    assert_eq!(p.shape.dim(d), first.shape.dim(d), "concat dims");
                }
            }
        }
        let outer: usize = first.shape.0[..axis].iter().product();
        let inner: usize = first.shape.0[axis + 1..].iter().product();
        let mut out = Vec::with_capacity(outer * total * inner);
        for o in 0..outer {
            for p in parts {
                let mid = p.shape.dim(axis);
                let base = o * mid * inner;
                out.extend_from_slice(&p.data[base..base + mid * inner]);
            }
        }
        Tensor::new(first.shape.with_dim(axis, total), out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(d: &[usize]) -> Shape {
        Shape::new(d)
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::random(s(&[3, 3]), 1);
        let mut eye = Tensor::zeros(s(&[3, 3]));
        for i in 0..3 {
            eye.data[i * 3 + i] = 1.0;
        }
        assert!(a.matmul(&eye).allclose(&a, 1e-6));
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::new(s(&[2, 2]), vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(s(&[2, 2]), vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn relu_clamps() {
        let t = Tensor::new(s(&[4]), vec![-1.0, 0.0, 2.0, -3.0]);
        assert_eq!(t.relu().data, vec![0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn conv_matches_im2col_matmul() {
        // The algebraic identity behind rewrite R4.
        let x = Tensor::random(s(&[3, 8, 8]), 7);
        let w = Tensor::random(s(&[4, 3, 3, 3]), 8);
        let direct = x.conv2d(&w, 1);
        let col = x.im2col(3, 3, 1); // (27, 36)
        let wmat = w.reshape(s(&[4, 27]));
        let viamm = wmat.matmul(&col).reshape(s(&[4, 6, 6]));
        assert!(direct.allclose(&viamm, 1e-4), "diff={:?}", direct.max_abs_diff(&viamm));
    }

    #[test]
    fn conv_stride_2() {
        let x = Tensor::random(s(&[2, 7, 7]), 3);
        let w = Tensor::random(s(&[3, 2, 3, 3]), 4);
        let y = x.conv2d(&w, 2);
        assert_eq!(y.shape, s(&[3, 3, 3]));
        // spot-check one output against a hand loop
        let mut acc = 0.0;
        for ci in 0..2 {
            for dy in 0..3 {
                for dx in 0..3 {
                    acc += x.at(&[ci, 2 + dy, 4 + dx]) * w.at(&[1, ci, dy, dx]);
                }
            }
        }
        assert!((y.at(&[1, 1, 2]) - acc).abs() < 1e-5);
    }

    #[test]
    fn pad_then_conv_keeps_size() {
        let x = Tensor::random(s(&[2, 6, 6]), 11);
        let w = Tensor::random(s(&[2, 2, 3, 3]), 12);
        let padded = x.pad2d(2, 2).conv2d(&w, 1);
        assert_eq!(padded.shape, s(&[2, 6, 6]));
    }

    #[test]
    fn asymmetric_pad_splits_floor_before_ceil_after() {
        // pad_h=3 on H=2: 1 zero-row above, 2 below; pad_w=1 on W=2: 0
        // left, 1 right (SAME_UPPER: floor(p/2) before, ceil(p/2) after).
        let x = Tensor::new(s(&[1, 2, 2]), vec![1.0, 2.0, 3.0, 4.0]);
        let p = x.pad2d(3, 1);
        assert_eq!(p.shape, s(&[1, 5, 3]));
        assert_eq!(p.at(&[0, 0, 0]), 0.0);
        assert_eq!(p.at(&[0, 1, 0]), 1.0);
        assert_eq!(p.at(&[0, 1, 1]), 2.0);
        assert_eq!(p.at(&[0, 1, 2]), 0.0);
        assert_eq!(p.at(&[0, 2, 0]), 3.0);
        assert_eq!(p.at(&[0, 2, 1]), 4.0);
        assert_eq!(p.at(&[0, 3, 0]), 0.0);
        assert_eq!(p.at(&[0, 4, 2]), 0.0);
    }

    #[test]
    fn slice_concat_roundtrip() {
        let x = Tensor::random(s(&[4, 6]), 5);
        for axis in 0..2 {
            let n = x.shape.dim(axis);
            let a = x.slice_ax(axis, 0, n / 2);
            let b = x.slice_ax(axis, n / 2, n - n / 2);
            let back = Tensor::concat_ax(axis, &[a, b]);
            assert!(back.allclose(&x, 0.0));
        }
    }

    #[test]
    fn maxpool_known() {
        let x = Tensor::new(s(&[1, 2, 2]), vec![1.0, 5.0, 3.0, 2.0]);
        assert_eq!(x.maxpool2d(2, 2, 2).data, vec![5.0]);
    }

    #[test]
    fn maxpool_rectangular_window() {
        // 1x2 window, stride 1: row-wise pairwise max.
        let x = Tensor::new(s(&[1, 2, 3]), vec![1.0, 5.0, 3.0, 2.0, 0.0, 4.0]);
        let y = x.maxpool2d(1, 2, 1);
        assert_eq!(y.shape, s(&[1, 2, 2]));
        assert_eq!(y.data, vec![5.0, 5.0, 2.0, 4.0]);
        // 2x1 window: column-wise pairwise max.
        let y = x.maxpool2d(2, 1, 1);
        assert_eq!(y.shape, s(&[1, 1, 3]));
        assert_eq!(y.data, vec![2.0, 5.0, 4.0]);
    }

    #[test]
    fn emul_known_values() {
        let a = Tensor::new(s(&[4]), vec![1.0, -2.0, 3.0, 0.5]);
        let b = Tensor::new(s(&[4]), vec![2.0, 2.0, -1.0, 4.0]);
        assert_eq!(a.emul(&b).data, vec![2.0, -4.0, -3.0, 2.0]);
    }

    #[test]
    fn bias_add_both_ranks() {
        let x3 = Tensor::zeros(s(&[2, 2, 2]));
        let b = Tensor::new(s(&[2]), vec![1.0, 2.0]);
        let y = x3.bias_add(&b);
        assert_eq!(y.data, vec![1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0]);
        let x2 = Tensor::zeros(s(&[2, 2]));
        let y2 = x2.bias_add(&b);
        assert_eq!(y2.data, vec![1.0, 2.0, 1.0, 2.0]);
    }

    #[test]
    fn gap_averages() {
        let x = Tensor::new(s(&[2, 1, 2]), vec![1.0, 3.0, 10.0, 20.0]);
        assert_eq!(x.gap().data, vec![2.0, 15.0]);
    }

    #[test]
    fn bcast_rank3() {
        let b = Tensor::new(s(&[2]), vec![1.0, 2.0]);
        let y = b.bcast(s(&[2, 1, 2]));
        assert_eq!(y.data, vec![1.0, 1.0, 2.0, 2.0]);
    }

    #[test]
    fn rect_conv_matches_im2col_matmul() {
        // The R4 identity must hold for rectangular kernels too.
        let x = Tensor::random(s(&[2, 8, 8]), 17);
        let w = Tensor::random(s(&[4, 2, 3, 1]), 18);
        let direct = x.conv2d(&w, 1);
        let col = x.im2col(3, 1, 1); // (6, 48)
        let wmat = w.reshape(s(&[4, 6]));
        let viamm = wmat.matmul(&col).reshape(s(&[4, 6, 8]));
        assert!(direct.allclose(&viamm, 1e-4));
    }

    #[test]
    fn depthwise_matches_grouped_direct() {
        // Depthwise == per-channel 1-in-1-out convs.
        let x = Tensor::random(s(&[3, 6, 6]), 21);
        let w = Tensor::random(s(&[3, 3, 3]), 22);
        let got = x.depthwise_conv2d(&w, 1);
        assert_eq!(got.shape, s(&[3, 4, 4]));
        for ci in 0..3 {
            let xc = x.slice_ax(0, ci, 1);
            let wc = w.slice_ax(0, ci, 1).reshape(s(&[1, 1, 3, 3]));
            let want = xc.conv2d(&wc, 1);
            let gc = got.slice_ax(0, ci, 1);
            assert!(gc.allclose(&want, 1e-5), "channel {ci}");
        }
    }

    #[test]
    fn transpose_involution() {
        let x = Tensor::random(s(&[3, 5]), 9);
        let t = x.transpose_last();
        assert_eq!(t.shape, s(&[5, 3]));
        assert_eq!(t.at(&[2, 1]), x.at(&[1, 2]));
        assert!(t.transpose_last().allclose(&x, 0.0));
    }

    #[test]
    fn batched_transpose_matches_per_slice() {
        let x = Tensor::random(s(&[4, 3, 5]), 19);
        let t = x.transpose_last();
        assert_eq!(t.shape, s(&[4, 5, 3]));
        for bi in 0..4 {
            let want = x.slice_ax(0, bi, 1).reshape(s(&[3, 5])).transpose_last();
            let got = t.slice_ax(0, bi, 1).reshape(s(&[5, 3]));
            assert!(got.allclose(&want, 0.0), "batch {bi}");
        }
        assert!(t.transpose_last().allclose(&x, 0.0));
    }

    #[test]
    fn batch_matmul_matches_per_slice() {
        let a = Tensor::random(s(&[2, 3, 4]), 31);
        let b = Tensor::random(s(&[2, 4, 5]), 32);
        let y = a.batch_matmul(&b);
        assert_eq!(y.shape, s(&[2, 3, 5]));
        for bi in 0..2 {
            let ai = a.slice_ax(0, bi, 1).reshape(s(&[3, 4]));
            let bbi = b.slice_ax(0, bi, 1).reshape(s(&[4, 5]));
            let want = ai.matmul(&bbi);
            let got = y.slice_ax(0, bi, 1).reshape(s(&[3, 5]));
            assert!(got.allclose(&want, 1e-5), "batch {bi}");
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::random(s(&[4, 8]), 41);
        let y = x.softmax_last();
        for r in 0..4 {
            let sum: f32 = y.data[r * 8..(r + 1) * 8].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {r}: {sum}");
            assert!(y.data[r * 8..(r + 1) * 8].iter().all(|&v| v >= 0.0));
        }
        // Invariant to per-row shifts (numerical stability path).
        let shifted = Tensor {
            shape: x.shape.clone(),
            data: x.data.iter().map(|v| v + 100.0).collect(),
        };
        assert!(shifted.softmax_last().allclose(&y, 1e-5));
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = Tensor::random(s(&[2, 16]), 51);
        let y = x.layernorm_last(1e-5);
        for r in 0..2 {
            let row = &y.data[r * 16..(r + 1) * 16];
            let mean: f32 = row.iter().sum::<f32>() / 16.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-5, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
        }
    }

    #[test]
    fn layernorm_affine_scales_and_shifts() {
        let x = Tensor::random(s(&[2, 16]), 61);
        let gamma = Tensor::random(s(&[16]), 62);
        let beta = Tensor::random(s(&[16]), 63);
        let got = x.layernorm_affine_last(&gamma, &beta, 1e-5);
        let norm = x.layernorm_last(1e-5);
        for r in 0..2 {
            for j in 0..16 {
                let want = norm.data[r * 16 + j] * gamma.data[j] + beta.data[j];
                assert!((got.data[r * 16 + j] - want).abs() < 1e-6);
            }
        }
        // Unit gamma, zero beta reduces to the non-affine form.
        let ones = Tensor::new(s(&[16]), vec![1.0; 16]);
        let zeros = Tensor::zeros(s(&[16]));
        assert!(x.layernorm_affine_last(&ones, &zeros, 1e-5).allclose(&norm, 0.0));
    }

    #[test]
    fn gelu_fixed_points() {
        let x = Tensor::new(s(&[3]), vec![0.0, 10.0, -10.0]);
        let y = x.gelu();
        assert!(y.data[0].abs() < 1e-6);
        assert!((y.data[1] - 10.0).abs() < 1e-3, "gelu(10) ≈ 10");
        assert!(y.data[2].abs() < 1e-3, "gelu(-10) ≈ 0");
    }

    #[test]
    fn random_is_deterministic() {
        let a = Tensor::random(s(&[16]), 42);
        let b = Tensor::random(s(&[16]), 42);
        assert_eq!(a.data, b.data);
        let c = Tensor::random(s(&[16]), 43);
        assert_ne!(a.data, c.data);
    }
}
