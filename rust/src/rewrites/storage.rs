//! Storage rewrites: where intermediates live (paper §2's third component).
//!
//! Semantically all of these are identity — buffers are transparent to the
//! evaluator — but each choice lands at a different point in the
//! area/latency space: SRAM buffers cost area but are fast, DRAM is free
//! area but slow, and double-buffering doubles the storage to overlap
//! producer and consumer (pipelining).

use crate::egraph::Rewrite;
use crate::ir::{BufKind, Node, Op, OpKind};

/// `(buffer sram x)` ⇒ `(buffer dram x)`.
pub fn sram_to_dram() -> Rewrite {
    Rewrite::node_scan("sram-to-dram", OpKind::Buffer, |eg, _, s| {
        let n = s.node.as_ref().unwrap();
        match n.op {
            Op::Buffer { kind: BufKind::Sram } => {
                Some(eg.add(Node::new(Op::Buffer { kind: BufKind::Dram }, n.children.clone())))
            }
            _ => None,
        }
    })
}

/// `(buffer dram x)` ⇒ `(buffer sram x)`.
pub fn dram_to_sram() -> Rewrite {
    Rewrite::node_scan("dram-to-sram", OpKind::Buffer, |eg, _, s| {
        let n = s.node.as_ref().unwrap();
        match n.op {
            Op::Buffer { kind: BufKind::Dram } => {
                Some(eg.add(Node::new(Op::Buffer { kind: BufKind::Sram }, n.children.clone())))
            }
            _ => None,
        }
    })
}

/// `(buffer k x)` ⇒ `(dbl-buffer k x)` — pipeline the producer/consumer.
pub fn double_buffer() -> Rewrite {
    Rewrite::node_scan("double-buffer", OpKind::Buffer, |eg, _, s| {
        let n = s.node.as_ref().unwrap();
        let kind = match n.op {
            Op::Buffer { kind } => kind,
            _ => return None,
        };
        Some(eg.add(Node::new(Op::DblBuffer { kind }, n.children.clone())))
    })
}

/// `(dbl-buffer k x)` ⇒ `(buffer k x)`.
pub fn undouble_buffer() -> Rewrite {
    Rewrite::node_scan("undouble-buffer", OpKind::DblBuffer, |eg, _, s| {
        let n = s.node.as_ref().unwrap();
        let kind = match n.op {
            Op::DblBuffer { kind } => kind,
            _ => return None,
        };
        Some(eg.add(Node::new(Op::Buffer { kind }, n.children.clone())))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::Runner;
    use crate::ir::parse_expr;

    #[test]
    fn storage_choices_multiply_designs() {
        // One buffered invoke: sram/dram x single/double = 4 storage
        // variants of the same program.
        let e = parse_expr("(buffer sram (invoke-relu (relu-engine 4) (input x [4])))")
            .unwrap();
        let mut runner = Runner::new(
            e,
            vec![sram_to_dram(), dram_to_sram(), double_buffer(), undouble_buffer()],
        );
        let rep = runner.run(10);
        assert_eq!(rep.stop, crate::egraph::StopReason::Saturated);
        assert_eq!(rep.designs_lower_bound, 4.0);
    }
}
