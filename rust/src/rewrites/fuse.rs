//! Engine-sharing and engine-fusion rewrites.
//!
//! `conv-as-im2col-mm` (R4) is the classic *cross-kernel engine sharing*
//! move: a convolution engine call becomes a matmul engine call over the
//! im2col patch matrix — after which the hashconsed `mm-engine` may be the
//! same physical unit a `dense` layer already uses (the paper's motivation
//! for exploring "more complex (but potentially more profitable) splits"
//! than one-engine-per-kernel-type).
//!
//! `fuse-mm-relu` (R7, extension) goes the other way: specialize two
//! engines into one fused unit, removing a buffer round-trip.

use super::engine_of;
use crate::egraph::{Rewrite};
use crate::ir::{Node, Op, OpKind, Shape, Symbol};

/// `(invoke-conv (conv-engine oh ow c k kh kw s) x w)` ⇒
/// `(reshape [k oh ow] (invoke-mm (mm-engine k c*kh*kw oh*ow)
///     (reshape [k c*kh*kw] w) (im2col kh kw s x)))`
pub fn conv_as_im2col_mm() -> Rewrite {
    Rewrite::node_scan("conv-as-im2col-mm", OpKind::InvokeConv, |eg, _, s| {
        let n = s.node.as_ref().unwrap();
        let (oh, ow, c, k, kh, kw, stride) = match engine_of(eg, n)? {
            Op::ConvEngine { oh, ow, c, k, kh, kw, stride } => (oh, ow, c, k, kh, kw, stride),
            _ => return None,
        };
        let ckk = c * kh * kw;
        let wmat = eg.add(Node::new(Op::Reshape(Shape::new(&[k, ckk])), vec![n.children[2]]));
        let col = eg.add(Node::new(Op::Im2Col { kh, kw, stride }, vec![n.children[1]]));
        let e = eg.add(Node::leaf(Op::MmEngine { m: k, k: ckk, n: oh * ow }));
        let mm = eg.add(Node::new(Op::InvokeMm, vec![e, wmat, col]));
        Some(eg.add(Node::new(Op::Reshape(Shape::new(&[k, oh, ow])), vec![mm])))
    })
}

/// Fuse `invoke-relu ∘ (reshape) ∘ (buffer) ∘ invoke-mm` into a single
/// `invoke-mm-relu` on a fused engine. Walks through at most one reshape
/// and one buffer (the shapes the lowering produces).
///
/// `node_scan_deep(…, 3, …)`: the applier peels up to three class levels
/// below the matched relu (`find_in_class` through reshape/buffer to the
/// mm), so the incremental engine re-offers the relu whenever any class in
/// that window changes.
pub fn fuse_mm_relu() -> Rewrite {
    Rewrite::node_scan_deep("fuse-mm-relu", OpKind::InvokeRelu, 3, |eg, _, s| {
        let n = s.node.as_ref().unwrap();
        // Peel: relu's input may be reshape(buffer(mm)) / buffer(mm) /
        // reshape(mm) / mm.
        let mut cur = n.children[1];
        let mut reshaped = false;
        for _ in 0..3 {
            if let Some(mm) = super::find_in_class(eg, cur, OpKind::InvokeMm) {
                let (m, k, nn) = match engine_of(eg, &mm)? {
                    Op::MmEngine { m, k, n } => (m, k, n),
                    _ => return None,
                };
                let e = eg.add(Node::leaf(Op::MmReluEngine { m, k, n: nn }));
                let fused =
                    eg.add(Node::new(Op::InvokeMmRelu, vec![e, mm.children[1], mm.children[2]]));
                // Rebuild the same view the relu had of the data.
                return Some(if reshaped {
                    eg.add(Node::new(Op::Reshape(Shape::new(&[m * nn])), vec![fused]))
                } else {
                    fused
                });
            }
            if let Some(rs) = super::find_in_class(eg, cur, OpKind::Reshape) {
                reshaped = true;
                cur = rs.children[0];
                continue;
            }
            if let Some(buf) = super::find_in_class(eg, cur, OpKind::Buffer) {
                cur = buf.children[0];
                continue;
            }
            break;
        }
        None
    })
}

/// Split a fused mm-relu engine along M (elementwise epilogue splits freely;
/// K must NOT be split — relu(a+b) ≠ relu(a)+relu(b), so no such rule
/// exists, and the soundness tests check it stays that way).
pub fn split_mmrelu_m(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-mmrelu-m-x{factor}"),
        OpKind::InvokeMmRelu,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let (m, k, nn) = match engine_of(eg, n)? {
                Op::MmReluEngine { m, k, n } => (m, k, n),
                _ => return None,
            };
            if m % factor != 0 || m < 2 {
                return None;
            }
            let chunk = m / factor;
            let var = Symbol::fresh("fm");
            let sa = super::slice_for_loop(eg, var, 0, chunk, chunk, n.children[1]);
            let e = eg.add(Node::leaf(Op::MmReluEngine { m: chunk, k, n: nn }));
            let inv = eg.add(Node::new(Op::InvokeMmRelu, vec![e, sa, n.children[2]]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 0, extent: factor }, vec![inv])))
        },
    )
}

/// Split a fused mm-relu engine along N.
pub fn split_mmrelu_n(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-mmrelu-n-x{factor}"),
        OpKind::InvokeMmRelu,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let (m, k, nn) = match engine_of(eg, n)? {
                Op::MmReluEngine { m, k, n } => (m, k, n),
                _ => return None,
            };
            if nn % factor != 0 || nn / factor < super::split::MIN_DIM {
                return None;
            }
            let chunk = nn / factor;
            let var = Symbol::fresh("fn");
            let sb = super::slice_for_loop(eg, var, 1, chunk, chunk, n.children[2]);
            let e = eg.add(Node::leaf(Op::MmReluEngine { m, k, n: chunk }));
            let inv = eg.add(Node::new(Op::InvokeMmRelu, vec![e, n.children[1], sb]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 1, extent: factor }, vec![inv])))
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::EGraph;
    use crate::ir::parse_expr;

    fn apply_once(src: &str, rule: Rewrite) -> (EGraph, crate::egraph::Id, usize) {
        let e = parse_expr(src).unwrap();
        let mut eg = EGraph::new();
        let root = eg.add_expr(&e);
        let mut applied = 0;
        for (id, s) in rule.search(&eg) {
            if rule.apply(&mut eg, id, &s) {
                applied += 1;
            }
        }
        eg.rebuild();
        (eg, root, applied)
    }

    #[test]
    fn im2col_rewrite_fires_and_introduces_mm_engine() {
        let (eg, _, applied) = apply_once(
            "(invoke-conv (conv-engine 6 6 3 4 3 3 1) (input x [3 8 8]) (weight w [4 3 3 3]))",
            conv_as_im2col_mm(),
        );
        assert_eq!(applied, 1);
        let mut found = false;
        for class in eg.classes() {
            for n in eg.class_nodes(class.id) {
                if n.op == (Op::MmEngine { m: 4, k: 27, n: 36 }) {
                    found = true;
                }
            }
        }
        assert!(found, "expected (mm-engine 4 27 36)");
    }

    #[test]
    fn fuse_fires_through_buffer_and_reshape() {
        // The exact shape `lower` produces for relu(dense(x,w)) (no bias).
        let src = "(invoke-relu (relu-engine 32) (reshape [32] (buffer sram \
                     (invoke-mm (mm-engine 4 8 8) (input a [4 8]) (weight w [8 8])))))";
        let (eg, root, applied) = apply_once(src, fuse_mm_relu());
        assert_eq!(applied, 1);
        // Root class should now reach an invoke-mm-relu behind a reshape.
        let reshapes: Vec<_> = eg
            .class_nodes(root)
            .filter(|n| n.op.kind() == OpKind::Reshape)
            .cloned()
            .collect();
        let fused = reshapes.iter().any(|rs| {
            eg.class_nodes(rs.children[0]).any(|n| n.op.kind() == OpKind::InvokeMmRelu)
        });
        assert!(fused);
    }

    #[test]
    fn fuse_fires_direct() {
        let src = "(invoke-relu (relu-engine 32) (reshape [32] \
                     (invoke-mm (mm-engine 4 8 8) (input a [4 8]) (weight w [8 8]))))";
        let (_, _, applied) = apply_once(src, fuse_mm_relu());
        assert_eq!(applied, 1);
    }

    #[test]
    fn mmrelu_splits_fire() {
        let src = "(invoke-mm-relu (mm-relu-engine 4 8 8) (input a [4 8]) (weight w [8 8]))";
        let (_, _, a1) = apply_once(src, split_mmrelu_m(2));
        let (_, _, a2) = apply_once(src, split_mmrelu_n(2));
        assert_eq!((a1, a2), (1, 1));
    }
}
