//! Schedule rewrites: the software side of the split.
//!
//! `parallelize` is paper Fig. 2 rewrite 2 — "we can parallelize a software
//! for loop by instantiating more hardware": a `sched-loop` (one engine,
//! time-multiplexed) becomes a `sched-par` (extent-many engine instances).
//! `serialize` is its inverse; having both makes every schedule class
//! contain both design points, which is how the e-graph holds the whole
//! time/space-multiplexing spectrum at once.

use crate::egraph::{Rewrite};
use crate::ir::{Node, Op, OpKind};

/// `(sched-loop v a f body)` ⇒ `(sched-par v a f body)`.
pub fn parallelize() -> Rewrite {
    Rewrite::node_scan("parallelize", OpKind::SchedLoop, |eg, _, s| {
        let n = s.node.as_ref().unwrap();
        let (var, axis, extent) = match n.op {
            Op::SchedLoop { var, axis, extent } => (var, axis, extent),
            _ => return None,
        };
        Some(eg.add(Node::new(Op::SchedPar { var, axis, extent }, n.children.clone())))
    })
}

/// `(sched-par v a f body)` ⇒ `(sched-loop v a f body)`.
pub fn serialize() -> Rewrite {
    Rewrite::node_scan("serialize", OpKind::SchedPar, |eg, _, s| {
        let n = s.node.as_ref().unwrap();
        let (var, axis, extent) = match n.op {
            Op::SchedPar { var, axis, extent } => (var, axis, extent),
            _ => return None,
        };
        Some(eg.add(Node::new(Op::SchedLoop { var, axis, extent }, n.children.clone())))
    })
}

/// Reorder two directly nested sequential loops over *different* axes:
/// `(sched-loop v1 a1 f1 (sched-loop v2 a2 f2 B))` ⇒ swapped order.
/// Valid because block-concatenation along distinct axes commutes.
///
/// `node_scan_deep(…, 1, …)`: the applier peeks one level down (the body
/// class's nodes, via `find_in_class`), so the incremental engine must
/// re-offer an outer loop whenever its body class changes.
pub fn loop_reorder() -> Rewrite {
    Rewrite::node_scan_deep("loop-reorder", OpKind::SchedLoop, 1, |eg, _, s| {
        let outer = s.node.as_ref().unwrap();
        let (v1, a1, f1) = match outer.op {
            Op::SchedLoop { var, axis, extent } => (var, axis, extent),
            _ => return None,
        };
        // Find a directly nested sched-loop over a different axis.
        let inner = super::find_in_class(eg, outer.children[0], OpKind::SchedLoop)?;
        let (v2, a2, f2) = match inner.op {
            Op::SchedLoop { var, axis, extent } => (var, axis, extent),
            _ => return None,
        };
        if a1 == a2 {
            return None;
        }
        let body = inner.children[0];
        let new_inner =
            eg.add(Node::new(Op::SchedLoop { var: v1, axis: a1, extent: f1 }, vec![body]));
        Some(eg.add(Node::new(Op::SchedLoop { var: v2, axis: a2, extent: f2 }, vec![new_inner])))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{EGraph, Runner};
    use crate::ir::parse_expr;
    use crate::tensor::{eval_expr, Env};

    const LOOPED: &str = "(sched-loop i0 0 2 (invoke-relu (relu-engine 64) \
        (slice 0 64 (imul (lvar i0) 64) (input x [128]))))";

    #[test]
    fn parallelize_reaches_par_form() {
        let e = parse_expr(LOOPED).unwrap();
        let mut eg = EGraph::new();
        let root = eg.add_expr(&e);
        let rw = parallelize();
        for (id, s) in rw.search(&eg) {
            rw.apply(&mut eg, id, &s);
        }
        eg.rebuild();
        assert!(eg.class_nodes(root).any(|n| matches!(n.op, Op::SchedPar { .. })));
    }

    #[test]
    fn loop_par_roundtrip_is_stable() {
        let e = parse_expr(LOOPED).unwrap();
        let mut runner = Runner::new(e, vec![parallelize(), serialize()]);
        let report = runner.run(10);
        assert_eq!(report.stop, crate::egraph::StopReason::Saturated);
        // loop + par variants -> exactly 2 designs for this program.
        assert_eq!(report.designs_lower_bound, 2.0);
    }

    #[test]
    fn loop_reorder_swaps_axes_and_preserves_semantics() {
        // 2-D relu-ish schedule over a matrix: loop rows then cols.
        let src = "(sched-loop r 0 2 (sched-loop c 1 2 \
            (slice 1 2 (imul (lvar c) 2) (slice 0 2 (imul (lvar r) 2) (input x [4 4])))))";
        let e = parse_expr(src).unwrap();
        let mut eg = EGraph::new();
        let root = eg.add_expr(&e);
        let rw = loop_reorder();
        let matches = rw.search(&eg);
        assert!(!matches.is_empty());
        for (id, s) in matches {
            rw.apply(&mut eg, id, &s);
        }
        eg.rebuild();
        // The class now holds a loop whose outer axis is 1.
        let has_swapped =
            eg.class_nodes(root).any(|n| matches!(n.op, Op::SchedLoop { axis: 1, .. }));
        assert!(has_swapped);

        // Differential check of the textual swap.
        let swapped = "(sched-loop c 1 2 (sched-loop r 0 2 \
            (slice 1 2 (imul (lvar c) 2) (slice 0 2 (imul (lvar r) 2) (input x [4 4])))))";
        let e1 = parse_expr(src).unwrap();
        let e2 = parse_expr(swapped).unwrap();
        let a = eval_expr(&e1, &mut Env::random_for(&e1, 9)).unwrap();
        let b = eval_expr(&e2, &mut Env::random_for(&e2, 9)).unwrap();
        assert!(a.allclose(&b, 0.0));
    }
}
