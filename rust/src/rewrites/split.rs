//! Engine-splitting rewrites — paper Fig. 2 rewrite 1, generalized to every
//! engine kind and every divisible dimension.
//!
//! Shape of every rule: an invocation of a big engine is equivalent to a
//! software schedule (`sched-loop`) over `factor` invocations of a smaller
//! engine on slices of the operands. K-dimension and channel splits produce
//! partial sums, so they use `sched-reduce` instead.
//!
//! These rules cannot be written as static pattern→template pairs: the RHS
//! engine parameters are *computed* (`w/factor`, halo sizes
//! `(oh/f-1)*stride+kh`, …), which is exactly why the rewrite module uses
//! dynamic node-scan appliers.

use super::{engine_of, slice_for_loop};
use crate::egraph::{ApplyGraph, Id, Rewrite, Subst};
use crate::ir::{in_dim, Node, Op, OpKind, Shape, Symbol};

/// Smallest engine dimension worth creating: splits below this are declined
/// (they bloat the space without adding interesting hardware points).
pub const MIN_DIM: usize = 4;

/// `(invoke-relu (relu-engine w) x)` ⇒
/// `(sched-loop i 0 f (invoke-relu (relu-engine w/f) (slice 0 w/f (imul (lvar i) w/f) x)))`
pub fn split_relu(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-relu-x{factor}"),
        OpKind::InvokeRelu,
        move |eg: &mut ApplyGraph, _id: Id, s: &Subst| {
            let n = s.node.as_ref().unwrap();
            let w = match engine_of(eg, n)? {
                Op::ReluEngine { w } => w,
                _ => return None,
            };
            if w % factor != 0 || w / factor < MIN_DIM {
                return None;
            }
            let chunk = w / factor;
            let var = eg.fresh_var("i");
            let slice = slice_for_loop(eg, var, 0, chunk, chunk, n.children[1]);
            let e = eg.add(Node::leaf(Op::ReluEngine { w: chunk }));
            let inv = eg.add(Node::new(Op::InvokeRelu, vec![e, slice]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 0, extent: factor }, vec![inv])))
        },
    )
}

/// Same shape as [`split_relu`] for the vector adder (slices both inputs).
pub fn split_add(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-add-x{factor}"),
        OpKind::InvokeAdd,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let w = match engine_of(eg, n)? {
                Op::AddEngine { w } => w,
                _ => return None,
            };
            if w % factor != 0 || w / factor < MIN_DIM {
                return None;
            }
            let chunk = w / factor;
            let var = eg.fresh_var("i");
            let sa = slice_for_loop(eg, var, 0, chunk, chunk, n.children[1]);
            let sb = slice_for_loop(eg, var, 0, chunk, chunk, n.children[2]);
            let e = eg.add(Node::leaf(Op::AddEngine { w: chunk }));
            let inv = eg.add(Node::new(Op::InvokeAdd, vec![e, sa, sb]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 0, extent: factor }, vec![inv])))
        },
    )
}

/// Split matmul along M: loop over row blocks of `a`.
pub fn split_mm_m(factor: usize) -> Rewrite {
    Rewrite::node_scan(&format!("split-mm-m-x{factor}"), OpKind::InvokeMm, move |eg, _, s| {
        let n = s.node.as_ref().unwrap();
        let (m, k, nn) = match engine_of(eg, n)? {
            Op::MmEngine { m, k, n } => (m, k, n),
            _ => return None,
        };
        // M is the batch-ish dim and legitimately tiny (often 1): allow any
        // divisible split down to single rows.
        if m % factor != 0 || m < 2 {
            return None;
        }
        let chunk = m / factor;
        let var = eg.fresh_var("m");
        let sa = slice_for_loop(eg, var, 0, chunk, chunk, n.children[1]);
        let e = eg.add(Node::leaf(Op::MmEngine { m: chunk, k, n: nn }));
        let inv = eg.add(Node::new(Op::InvokeMm, vec![e, sa, n.children[2]]));
        Some(eg.add(Node::new(Op::SchedLoop { var, axis: 0, extent: factor }, vec![inv])))
    })
}

/// Split matmul along N: loop over column blocks of `b`.
pub fn split_mm_n(factor: usize) -> Rewrite {
    Rewrite::node_scan(&format!("split-mm-n-x{factor}"), OpKind::InvokeMm, move |eg, _, s| {
        let n = s.node.as_ref().unwrap();
        let (m, k, nn) = match engine_of(eg, n)? {
            Op::MmEngine { m, k, n } => (m, k, n),
            _ => return None,
        };
        if nn % factor != 0 || nn / factor < MIN_DIM {
            return None;
        }
        let chunk = nn / factor;
        let var = eg.fresh_var("n");
        let sb = slice_for_loop(eg, var, 1, chunk, chunk, n.children[2]);
        let e = eg.add(Node::leaf(Op::MmEngine { m, k, n: chunk }));
        let inv = eg.add(Node::new(Op::InvokeMm, vec![e, n.children[1], sb]));
        Some(eg.add(Node::new(Op::SchedLoop { var, axis: 1, extent: factor }, vec![inv])))
    })
}

/// Split matmul along K (the reduction dim): partial products summed by a
/// `sched-reduce`.
pub fn split_mm_k(factor: usize) -> Rewrite {
    Rewrite::node_scan(&format!("split-mm-k-x{factor}"), OpKind::InvokeMm, move |eg, _, s| {
        let n = s.node.as_ref().unwrap();
        let (m, k, nn) = match engine_of(eg, n)? {
            Op::MmEngine { m, k, n } => (m, k, n),
            _ => return None,
        };
        if k % factor != 0 || k / factor < MIN_DIM {
            return None;
        }
        let chunk = k / factor;
        let var = eg.fresh_var("k");
        let sa = slice_for_loop(eg, var, 1, chunk, chunk, n.children[1]);
        let sb = slice_for_loop(eg, var, 0, chunk, chunk, n.children[2]);
        let e = eg.add(Node::leaf(Op::MmEngine { m, k: chunk, n: nn }));
        let inv = eg.add(Node::new(Op::InvokeMm, vec![e, sa, sb]));
        Some(eg.add(Node::new(Op::SchedReduce { var, extent: factor }, vec![inv])))
    })
}

/// Split a conv engine along output rows (with halo on the input slice).
pub fn split_conv_oh(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-conv-oh-x{factor}"),
        OpKind::InvokeConv,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let (oh, ow, c, k, kh, kw, stride) = match engine_of(eg, n)? {
                Op::ConvEngine { oh, ow, c, k, kh, kw, stride } => (oh, ow, c, k, kh, kw, stride),
                _ => return None,
            };
            if oh % factor != 0 || oh / factor < 1 || oh / factor == oh {
                return None;
            }
            let ohc = oh / factor;
            // Input rows per output chunk (the halo): (ohc-1)*stride + kh.
            let in_rows = in_dim(ohc, kh, stride);
            let var = eg.fresh_var("r");
            // Row chunk i starts at input row i*ohc*stride.
            let sx = slice_for_loop(eg, var, 1, ohc * stride, in_rows, n.children[1]);
            let e = eg.add(Node::leaf(Op::ConvEngine { oh: ohc, ow, c, k, kh, kw, stride }));
            let inv = eg.add(Node::new(Op::InvokeConv, vec![e, sx, n.children[2]]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 1, extent: factor }, vec![inv])))
        },
    )
}

/// Split a conv engine along output columns (halo along W).
pub fn split_conv_ow(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-conv-ow-x{factor}"),
        OpKind::InvokeConv,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let (oh, ow, c, k, kh, kw, stride) = match engine_of(eg, n)? {
                Op::ConvEngine { oh, ow, c, k, kh, kw, stride } => (oh, ow, c, k, kh, kw, stride),
                _ => return None,
            };
            if ow % factor != 0 || ow / factor < 1 || ow / factor == ow {
                return None;
            }
            let owc = ow / factor;
            // Input cols per output chunk: the halo is kw wide (was kh
            // before kernels went rectangular — a latent square-kernel bug).
            let in_cols = in_dim(owc, kw, stride);
            let var = eg.fresh_var("q");
            let sx = slice_for_loop(eg, var, 2, owc * stride, in_cols, n.children[1]);
            let e = eg.add(Node::leaf(Op::ConvEngine { oh, ow: owc, c, k, kh, kw, stride }));
            let inv = eg.add(Node::new(Op::InvokeConv, vec![e, sx, n.children[2]]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 2, extent: factor }, vec![inv])))
        },
    )
}

/// Split a conv engine along output channels (slice the weights).
pub fn split_conv_k(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-conv-k-x{factor}"),
        OpKind::InvokeConv,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let (oh, ow, c, k, kh, kw, stride) = match engine_of(eg, n)? {
                Op::ConvEngine { oh, ow, c, k, kh, kw, stride } => (oh, ow, c, k, kh, kw, stride),
                _ => return None,
            };
            if k % factor != 0 || k / factor < 1 || k / factor == k {
                return None;
            }
            let kc = k / factor;
            let var = eg.fresh_var("g");
            let sw = slice_for_loop(eg, var, 0, kc, kc, n.children[2]);
            let e = eg.add(Node::leaf(Op::ConvEngine { oh, ow, c, k: kc, kh, kw, stride }));
            let inv = eg.add(Node::new(Op::InvokeConv, vec![e, n.children[1], sw]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 0, extent: factor }, vec![inv])))
        },
    )
}

/// Split a conv engine along *input* channels: partial sums reduced.
pub fn split_conv_c(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-conv-c-x{factor}"),
        OpKind::InvokeConv,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let (oh, ow, c, k, kh, kw, stride) = match engine_of(eg, n)? {
                Op::ConvEngine { oh, ow, c, k, kh, kw, stride } => (oh, ow, c, k, kh, kw, stride),
                _ => return None,
            };
            if c % factor != 0 || c / factor < 1 || c / factor == c {
                return None;
            }
            let cc = c / factor;
            let var = eg.fresh_var("c");
            let sx = slice_for_loop(eg, var, 0, cc, cc, n.children[1]);
            let sw = slice_for_loop(eg, var, 1, cc, cc, n.children[2]);
            let e = eg.add(Node::leaf(Op::ConvEngine { oh, ow, c: cc, k, kh, kw, stride }));
            let inv = eg.add(Node::new(Op::InvokeConv, vec![e, sx, sw]));
            Some(eg.add(Node::new(Op::SchedReduce { var, extent: factor }, vec![inv])))
        },
    )
}

/// Split a pool engine along channels (pooling is channelwise).
pub fn split_pool_c(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-pool-c-x{factor}"),
        OpKind::InvokePool,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let (oh, ow, c, kh, kw, stride) = match engine_of(eg, n)? {
                Op::PoolEngine { oh, ow, c, kh, kw, stride } => (oh, ow, c, kh, kw, stride),
                _ => return None,
            };
            if c % factor != 0 || c / factor < 1 || c / factor == c {
                return None;
            }
            let cc = c / factor;
            let var = eg.fresh_var("pc");
            let sx = slice_for_loop(eg, var, 0, cc, cc, n.children[1]);
            let e = eg.add(Node::leaf(Op::PoolEngine { oh, ow, c: cc, kh, kw, stride }));
            let inv = eg.add(Node::new(Op::InvokePool, vec![e, sx]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 0, extent: factor }, vec![inv])))
        },
    )
}

/// Split a pool engine along output rows (`kh` halo slices, like conv).
pub fn split_pool_oh(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-pool-oh-x{factor}"),
        OpKind::InvokePool,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let (oh, ow, c, kh, kw, stride) = match engine_of(eg, n)? {
                Op::PoolEngine { oh, ow, c, kh, kw, stride } => (oh, ow, c, kh, kw, stride),
                _ => return None,
            };
            if oh % factor != 0 || oh / factor < 1 || oh / factor == oh {
                return None;
            }
            let ohc = oh / factor;
            let in_rows = in_dim(ohc, kh, stride);
            let var = eg.fresh_var("pr");
            let sx = slice_for_loop(eg, var, 1, ohc * stride, in_rows, n.children[1]);
            let e = eg.add(Node::leaf(Op::PoolEngine { oh: ohc, ow, c, kh, kw, stride }));
            let inv = eg.add(Node::new(Op::InvokePool, vec![e, sx]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 1, extent: factor }, vec![inv])))
        },
    )
}

/// Split a pool engine along output columns (`kw` halo slices — only
/// correct now that the engine carries a rectangular window).
pub fn split_pool_ow(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-pool-ow-x{factor}"),
        OpKind::InvokePool,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let (oh, ow, c, kh, kw, stride) = match engine_of(eg, n)? {
                Op::PoolEngine { oh, ow, c, kh, kw, stride } => (oh, ow, c, kh, kw, stride),
                _ => return None,
            };
            if ow % factor != 0 || ow / factor < 1 || ow / factor == ow {
                return None;
            }
            let owc = ow / factor;
            let in_cols = in_dim(owc, kw, stride);
            let var = eg.fresh_var("pq");
            let sx = slice_for_loop(eg, var, 2, owc * stride, in_cols, n.children[1]);
            let e = eg.add(Node::leaf(Op::PoolEngine { oh, ow: owc, c, kh, kw, stride }));
            let inv = eg.add(Node::new(Op::InvokePool, vec![e, sx]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 2, extent: factor }, vec![inv])))
        },
    )
}

/// Same shape as [`split_relu`] for the vector GELU unit.
pub fn split_gelu(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-gelu-x{factor}"),
        OpKind::InvokeGelu,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let w = match engine_of(eg, n)? {
                Op::GeluEngine { w } => w,
                _ => return None,
            };
            if w % factor != 0 || w / factor < MIN_DIM {
                return None;
            }
            let chunk = w / factor;
            let var = eg.fresh_var("gl");
            let slice = slice_for_loop(eg, var, 0, chunk, chunk, n.children[1]);
            let e = eg.add(Node::leaf(Op::GeluEngine { w: chunk }));
            let inv = eg.add(Node::new(Op::InvokeGelu, vec![e, slice]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 0, extent: factor }, vec![inv])))
        },
    )
}

/// Split a depthwise-conv engine along channels (channels are fully
/// independent in depthwise conv: slice both `x` and `w` along dim 0).
/// Note: softmax/layernorm engines have NO width split — normalization
/// couples the whole row, so no such rule exists (their row *loops* still
/// parallelize via `sched::parallelize`).
pub fn split_dwconv_c(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-dwconv-c-x{factor}"),
        OpKind::InvokeDwConv,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let (oh, ow, c, kh, kw, stride) = match engine_of(eg, n)? {
                Op::DwConvEngine { oh, ow, c, kh, kw, stride } => (oh, ow, c, kh, kw, stride),
                _ => return None,
            };
            if c % factor != 0 || c / factor < 1 || c / factor == c {
                return None;
            }
            let cc = c / factor;
            let var = eg.fresh_var("dc");
            let sx = slice_for_loop(eg, var, 0, cc, cc, n.children[1]);
            let sw = slice_for_loop(eg, var, 0, cc, cc, n.children[2]);
            let e = eg.add(Node::leaf(Op::DwConvEngine { oh, ow, c: cc, kh, kw, stride }));
            let inv = eg.add(Node::new(Op::InvokeDwConv, vec![e, sx, sw]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 0, extent: factor }, vec![inv])))
        },
    )
}

/// Same shape as [`split_add`] for the vector elementwise-multiply unit
/// (slices both inputs).
pub fn split_emul(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-emul-x{factor}"),
        OpKind::InvokeEmul,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let w = match engine_of(eg, n)? {
                Op::EmulEngine { w } => w,
                _ => return None,
            };
            if w % factor != 0 || w / factor < MIN_DIM {
                return None;
            }
            let chunk = w / factor;
            let var = eg.fresh_var("em");
            let sa = slice_for_loop(eg, var, 0, chunk, chunk, n.children[1]);
            let sb = slice_for_loop(eg, var, 0, chunk, chunk, n.children[2]);
            let e = eg.add(Node::leaf(Op::EmulEngine { w: chunk }));
            let inv = eg.add(Node::new(Op::InvokeEmul, vec![e, sa, sb]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 0, extent: factor }, vec![inv])))
        },
    )
}

/// Split a depthwise-conv engine along output rows (halo slices, like
/// [`split_conv_oh`]).
pub fn split_dwconv_oh(factor: usize) -> Rewrite {
    Rewrite::node_scan(
        &format!("split-dwconv-oh-x{factor}"),
        OpKind::InvokeDwConv,
        move |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let (oh, ow, c, kh, kw, stride) = match engine_of(eg, n)? {
                Op::DwConvEngine { oh, ow, c, kh, kw, stride } => (oh, ow, c, kh, kw, stride),
                _ => return None,
            };
            if oh % factor != 0 || oh / factor < 1 || oh / factor == oh {
                return None;
            }
            let ohc = oh / factor;
            let in_rows = in_dim(ohc, kh, stride);
            let var = eg.fresh_var("dr");
            let sx = slice_for_loop(eg, var, 1, ohc * stride, in_rows, n.children[1]);
            let e = eg.add(Node::leaf(Op::DwConvEngine { oh: ohc, ow, c, kh, kw, stride }));
            let inv = eg.add(Node::new(Op::InvokeDwConv, vec![e, sx, n.children[2]]));
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 1, extent: factor }, vec![inv])))
        },
    )
}

// ---------------------------------------------------------------------
// Head/batch-axis splitting of the canonical batch-matmul loop
// ---------------------------------------------------------------------

/// One operand of the canonical per-slice matmul body:
/// `(reshape SH (slice AXIS LEN START SRC))` where `START` is either
/// `(imul (lvar v) CHUNK)` (an untiled loop) or, in *canonical iadd form*,
/// `(iadd OFFSET (imul (lvar v) CHUNK))` with `OFFSET` independent of `v`
/// (a loop that previous tilings already re-indexed).
struct SliceMapOperand {
    reshape_sh: Shape,
    axis: usize,
    len: usize,
    chunk: usize,
    /// The `v`-independent addend of an iadd-form start (`None` for the
    /// plain `imul` form).
    offset: Option<Id>,
    src: Id,
}

/// The `CHUNK` of an `(imul (lvar v) CHUNK)` member of class `cls`, if any.
fn imul_lvar_chunk(eg: &ApplyGraph, cls: Id, v: Symbol) -> Option<usize> {
    for st in eg.class_nodes(cls) {
        if !matches!(st.op, Op::IMul) {
            continue;
        }
        let lv_ok = eg.class_nodes(st.children[0]).any(|n| matches!(n.op, Op::LVar(s) if s == v));
        if !lv_ok {
            continue;
        }
        let chunk = eg.class_nodes(st.children[1]).find_map(|n| match n.op {
            Op::Int(c) if c >= 0 => Some(c as usize),
            _ => None,
        });
        if chunk.is_some() {
            return chunk;
        }
    }
    None
}

/// True when class `cls` is recognizably independent of loop variable `v`:
/// an int literal, an `imul` of some *other* loop variable, or an `iadd` of
/// such terms — exactly the start-offset shapes canonical tilings build.
/// Referencing an offset that secretly depends on `v` would leave `v` free
/// in the rewritten body, so unrecognized shapes decline the match.
fn start_independent_of(eg: &ApplyGraph, cls: Id, v: Symbol, depth: usize) -> bool {
    if depth == 0 {
        return false;
    }
    eg.class_nodes(cls).any(|n| match n.op {
        Op::Int(_) => true,
        Op::IMul => eg
            .class_nodes(n.children[0])
            .any(|l| matches!(l.op, Op::LVar(s) if s != v)),
        Op::IAdd => {
            start_independent_of(eg, n.children[0], v, depth - 1)
                && start_independent_of(eg, n.children[1], v, depth - 1)
        }
        _ => false,
    })
}

/// Match the slice-map operand chain rooted at class `cls`, parameterized
/// by loop variable `v`. Every level scans the class's e-nodes for the
/// canonical member, so the match survives class growth — including the
/// iadd-form starts earlier tilings of the same loop nest produced.
fn slice_map_operand(eg: &ApplyGraph, cls: Id, v: Symbol) -> Option<SliceMapOperand> {
    for r in eg.class_nodes(cls) {
        let Op::Reshape(sh) = &r.op else { continue };
        for sl in eg.class_nodes(r.children[0]) {
            let Op::SliceAx { axis, len } = &sl.op else { continue };
            let (axis, len) = (*axis, *len);
            let start = sl.children[0];
            // Untiled form: start = (imul (lvar v) chunk).
            if let Some(chunk) = imul_lvar_chunk(eg, start, v) {
                return Some(SliceMapOperand {
                    reshape_sh: sh.clone(),
                    axis,
                    len,
                    chunk,
                    offset: None,
                    src: sl.children[1],
                });
            }
            // Canonical iadd form: start = (iadd offset (imul (lvar v) chunk)).
            for st in eg.class_nodes(start) {
                if !matches!(st.op, Op::IAdd) {
                    continue;
                }
                let Some(chunk) = imul_lvar_chunk(eg, st.children[1], v) else { continue };
                if !start_independent_of(eg, st.children[0], v, 4) {
                    continue;
                }
                return Some(SliceMapOperand {
                    reshape_sh: sh.clone(),
                    axis,
                    len,
                    chunk,
                    offset: Some(st.children[0]),
                    src: sl.children[1],
                });
            }
        }
    }
    None
}

/// Rebuild one operand chain with the tiled start in canonical iadd form:
/// `(iadd OFFSET' (imul (lvar inner) chunk))` where `OFFSET'` folds any
/// pre-existing offset with the new outer term
/// `(imul (lvar outer) inner_extent*chunk)`. Keeping the start
/// right-leaning with the innermost variable outermost in the iadd is what
/// lets [`slice_map_operand`] re-match the inner loop for further tiling.
fn tiled_operand(
    eg: &mut ApplyGraph,
    op: &SliceMapOperand,
    outer: Symbol,
    inner: Symbol,
    inner_extent: usize,
) -> Id {
    let lo = eg.add(Node::leaf(Op::LVar(outer)));
    let co = eg.add(Node::leaf(Op::Int((inner_extent * op.chunk) as i64)));
    let so = eg.add(Node::new(Op::IMul, vec![lo, co]));
    let offset = match op.offset {
        None => so,
        Some(off) => eg.add(Node::new(Op::IAdd, vec![off, so])),
    };
    let li = eg.add(Node::leaf(Op::LVar(inner)));
    let ci = eg.add(Node::leaf(Op::Int(op.chunk as i64)));
    let si = eg.add(Node::new(Op::IMul, vec![li, ci]));
    let start = eg.add(Node::new(Op::IAdd, vec![offset, si]));
    let sl = eg.add(Node::new(Op::SliceAx { axis: op.axis, len: op.len }, vec![start, op.src]));
    eg.add(Node::new(Op::Reshape(op.reshape_sh.clone()), vec![sl]))
}

/// Tile the canonical batch-matmul loop along its batch/head axis.
///
/// `lo_bmm` reifies `batch-matmul` as
/// `(sched-loop v a B (reshape … (invoke-mm e (reshape … (slice … (imul (lvar v) c) A))
///                                            (reshape … (slice … (imul (lvar v) c) B)))))`
/// — one mm engine time-multiplexed over the batch. This rule splits that
/// loop `factor` ways: an outer schedule of `factor` iterations over an
/// inner loop of `B/factor`, with slice starts re-indexed to
/// `outer·(B/factor)·c + inner·c`. On `attn_block_mh{h}` the batch axis IS
/// the head axis, so with `parallelize` (or the `-par` variant below,
/// which emits the parallel outer schedule directly) extraction can trade
/// head-parallel area against latency.
///
/// `node_scan_deep(…, 6, …)`: the applier descends body → reshape →
/// invoke-mm → operand reshape → slice → start → lvar/int.
fn split_bmm_batch_impl(factor: usize, par: bool) -> Rewrite {
    let name = if par {
        format!("split-bmm-batch-par-x{factor}")
    } else {
        format!("split-bmm-batch-x{factor}")
    };
    Rewrite::node_scan_deep(&name, OpKind::SchedLoop, 6, move |eg, _, s| {
        let lp = s.node.as_ref().unwrap();
        let (v, axis, extent) = match lp.op {
            Op::SchedLoop { var, axis, extent } => (var, axis, extent),
            _ => return None,
        };
        // Inner extents of 1 add nothing; require a real tile both ways.
        if factor < 2 || extent % factor != 0 || extent / factor < 2 {
            return None;
        }
        // Locate the canonical per-slice invoke-mm body.
        let mut found = None;
        'search: for back in eg.class_nodes(lp.children[0]) {
            let Op::Reshape(back_sh) = &back.op else { continue };
            for inv in eg.class_nodes(back.children[0]) {
                if !matches!(inv.op, Op::InvokeMm) {
                    continue;
                }
                let a = slice_map_operand(eg, inv.children[1], v);
                let b = slice_map_operand(eg, inv.children[2], v);
                if let (Some(a), Some(b)) = (a, b) {
                    found = Some((back_sh.clone(), inv.children[0], a, b));
                    break 'search;
                }
            }
        }
        let (back_sh, engine, a, b) = found?;
        let inner_extent = extent / factor;
        let outer_v = eg.fresh_var("hb");
        let inner_v = eg.fresh_var("hh");
        let ra = tiled_operand(eg, &a, outer_v, inner_v, inner_extent);
        let rb = tiled_operand(eg, &b, outer_v, inner_v, inner_extent);
        let inv = eg.add(Node::new(Op::InvokeMm, vec![engine, ra, rb]));
        let back = eg.add(Node::new(Op::Reshape(back_sh), vec![inv]));
        let inner = eg.add(Node::new(
            Op::SchedLoop { var: inner_v, axis, extent: inner_extent },
            vec![back],
        ));
        let outer = if par {
            Op::SchedPar { var: outer_v, axis, extent: factor }
        } else {
            Op::SchedLoop { var: outer_v, axis, extent: factor }
        };
        Some(eg.add(Node::new(outer, vec![inner])))
    })
}

/// `split-bmm-batch-x{f}`: sequential outer tile (see
/// [`split_bmm_batch_impl`]).
pub fn split_bmm_batch(factor: usize) -> Rewrite {
    split_bmm_batch_impl(factor, false)
}

/// `split-bmm-batch-par-x{f}`: the head-axis `sched-par` variant — the
/// outer tile runs `factor` engine instances concurrently.
pub fn split_bmm_batch_par(factor: usize) -> Rewrite {
    split_bmm_batch_impl(factor, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{EGraph, Runner};
    use crate::ir::parse_expr;

    /// Apply one rule once to a seed program and return the e-graph.
    fn apply_once(src: &str, rule: Rewrite) -> (EGraph, Id, usize) {
        let e = parse_expr(src).unwrap();
        let mut eg = EGraph::new();
        let root = eg.add_expr(&e);
        let matches = rule.search(&eg);
        let mut applied = 0;
        for (id, s) in matches {
            if rule.apply(&mut eg, id, &s) {
                applied += 1;
            }
        }
        eg.rebuild();
        (eg, root, applied)
    }

    #[test]
    fn split_relu_fires_and_adds_schedule() {
        let (eg, root, applied) = apply_once(
            "(invoke-relu (relu-engine 128) (input x [128]))",
            split_relu(2),
        );
        assert_eq!(applied, 1);
        // The root class now also contains a sched-loop node.
        let has_loop = eg.class_nodes(root).any(|n| matches!(n.op, Op::SchedLoop { .. }));
        assert!(has_loop);
    }

    #[test]
    fn split_relu_declines_non_divisible() {
        let (_, _, applied) =
            apply_once("(invoke-relu (relu-engine 127) (input x [127]))", split_relu(2));
        assert_eq!(applied, 0);
    }

    #[test]
    fn split_relu_declines_below_min() {
        let (_, _, applied) =
            apply_once("(invoke-relu (relu-engine 4) (input x [4]))", split_relu(2));
        assert_eq!(applied, 0);
    }

    #[test]
    fn splits_iterate_to_all_power_of_two_engines() {
        let e = parse_expr("(invoke-relu (relu-engine 64) (input x [64]))").unwrap();
        let mut runner = Runner::new(e, vec![split_relu(2)]);
        runner.run(8);
        // Engines 64, 32, 16, 8, 4 should all exist as e-nodes.
        let mut widths: Vec<usize> = vec![];
        for class in runner.egraph.classes() {
            for n in runner.egraph.class_nodes(class.id) {
                if let Op::ReluEngine { w } = n.op {
                    widths.push(w);
                }
            }
        }
        widths.sort();
        widths.dedup();
        assert_eq!(widths, vec![4, 8, 16, 32, 64]);
    }

    #[test]
    fn mm_k_split_uses_reduce() {
        let (eg, root, applied) = apply_once(
            "(invoke-mm (mm-engine 4 16 4) (input a [4 16]) (weight b [16 4]))",
            split_mm_k(2),
        );
        assert_eq!(applied, 1);
        let has_reduce = eg.class_nodes(root).any(|n| matches!(n.op, Op::SchedReduce { .. }));
        assert!(has_reduce);
    }

    #[test]
    fn conv_splits_fire() {
        let src =
            "(invoke-conv (conv-engine 8 8 4 8 3 3 1) (input x [4 10 10]) (weight w [8 4 3 3]))";
        for (rule, expect) in [
            (split_conv_oh(2), 1),
            (split_conv_ow(2), 1),
            (split_conv_k(2), 1),
            (split_conv_c(2), 1),
        ] {
            let name = rule.name.clone();
            let (_, _, applied) = apply_once(src, rule);
            assert_eq!(applied, expect, "{name}");
        }
    }

    #[test]
    fn pool_splits_fire() {
        let src = "(invoke-pool (pool-engine 4 4 8 2 2 2) (input x [8 8 8]))";
        let (_, _, a1) = apply_once(src, split_pool_c(2));
        let (_, _, a2) = apply_once(src, split_pool_oh(2));
        let (_, _, a3) = apply_once(src, split_pool_ow(2));
        assert_eq!((a1, a2, a3), (1, 1, 1));
    }

    #[test]
    fn rect_pool_ow_split_uses_kw_halo() {
        // 2x4 window, stride 1: a W split needs kw=4 halo columns, so an
        // 8-wide output needs (4-1)*1+4 = 7 input columns per half.
        let src = "(invoke-pool (pool-engine 8 8 3 2 4 1) (input x [3 9 11]))";
        let (eg, root, applied) = apply_once(src, split_pool_ow(2));
        assert_eq!(applied, 1);
        let has_loop =
            eg.class_nodes(root).any(|n| matches!(n.op, Op::SchedLoop { axis: 2, .. }));
        assert!(has_loop);
    }

    #[test]
    fn emul_split_fires_and_declines_below_min() {
        let src = "(invoke-emul (emul-engine 32) (input x [32]) (input y [32]))";
        let (eg, root, a1) = apply_once(src, split_emul(2));
        assert_eq!(a1, 1);
        assert!(eg.class_nodes(root).any(|n| matches!(n.op, Op::SchedLoop { .. })));
        let (_, _, a2) =
            apply_once("(invoke-emul (emul-engine 4) (input x [4]) (input y [4]))", split_emul(2));
        assert_eq!(a2, 0);
    }

    /// The canonical batch-matmul loop (as `lo_bmm` emits it) for a
    /// 4-batch 4x8 @ 8x8 product.
    const BMM_LOOP: &str = "(sched-loop b 0 4 (reshape [1 4 8] (invoke-mm (mm-engine 4 8 8) \
        (reshape [4 8] (slice 0 1 (imul (lvar b) 1) (input qa [4 4 8]))) \
        (reshape [8 8] (slice 0 1 (imul (lvar b) 1) (input kb [4 8 8]))))))";

    #[test]
    fn bmm_batch_split_tiles_the_head_loop() {
        let (eg, root, applied) = apply_once(BMM_LOOP, split_bmm_batch(2));
        assert_eq!(applied, 1);
        // The root class gains an outer 2-tile whose body is an inner
        // 2-loop over the re-indexed slices.
        let outer = eg
            .class_nodes(root)
            .find(|n| matches!(n.op, Op::SchedLoop { extent: 2, .. }))
            .expect("outer tile");
        let inner_ok = eg
            .class_nodes(outer.children[0])
            .any(|n| matches!(n.op, Op::SchedLoop { extent: 2, .. }));
        assert!(inner_ok, "inner tile");
    }

    #[test]
    fn bmm_batch_par_split_emits_parallel_outer_tile() {
        let (eg, root, applied) = apply_once(BMM_LOOP, split_bmm_batch_par(2));
        assert_eq!(applied, 1);
        assert!(eg.class_nodes(root).any(|n| matches!(n.op, Op::SchedPar { extent: 2, .. })));
    }

    #[test]
    fn bmm_batch_tiling_is_semantics_preserving() {
        // The textual form of the rule's RHS computes the same batched
        // product: start re-indexing o*(B/f)*c + i*c walks the same blocks.
        use crate::tensor::{eval_expr, Env};
        let e = parse_expr(BMM_LOOP).unwrap();
        let want = eval_expr(&e, &mut Env::random_for(&e, 7)).unwrap();
        let tiled = "(sched-loop o 0 2 (sched-loop i 0 2 (reshape [1 4 8] (invoke-mm (mm-engine 4 8 8) \
            (reshape [4 8] (slice 0 1 (iadd (imul (lvar o) 2) (imul (lvar i) 1)) (input qa [4 4 8]))) \
            (reshape [8 8] (slice 0 1 (iadd (imul (lvar o) 2) (imul (lvar i) 1)) (input kb [4 8 8])))))))";
        let t = parse_expr(tiled).unwrap();
        assert_eq!(t.typecheck().unwrap(), e.typecheck().unwrap());
        let got = eval_expr(&t, &mut Env::random_for(&t, 7)).unwrap();
        assert!(want.allclose(&got, 1e-6), "{:?}", want.max_abs_diff(&got));
    }

    #[test]
    fn bmm_batch_split_declines_tiny_and_non_canonical_loops() {
        // Batch 2 would leave an inner extent of 1: decline.
        let small = "(sched-loop b 0 2 (reshape [1 4 8] (invoke-mm (mm-engine 4 8 8) \
            (reshape [4 8] (slice 0 1 (imul (lvar b) 1) (input qa [2 4 8]))) \
            (reshape [8 8] (slice 0 1 (imul (lvar b) 1) (input kb [2 8 8]))))))";
        let (_, _, a1) = apply_once(small, split_bmm_batch(2));
        assert_eq!(a1, 0);
        // A row-wise loop with no per-slice invoke-mm body: decline.
        let rows = "(sched-loop r 0 4 (reshape [1 8] (invoke-relu (relu-engine 8) \
            (reshape [8] (slice 0 1 (imul (lvar r) 1) (input x [4 8]))))))";
        let (_, _, a2) = apply_once(rows, split_bmm_batch(2));
        assert_eq!(a2, 0);
    }

    #[test]
    fn gelu_split_fires_and_declines_below_min() {
        let (_, _, a1) =
            apply_once("(invoke-gelu (gelu-engine 32) (input x [32]))", split_gelu(2));
        assert_eq!(a1, 1);
        let (_, _, a2) =
            apply_once("(invoke-gelu (gelu-engine 4) (input x [4]))", split_gelu(2));
        assert_eq!(a2, 0);
    }

    #[test]
    fn dwconv_splits_fire() {
        let src = "(invoke-dw-conv (dw-conv-engine 8 8 4 3 3 1) \
                     (input x [4 10 10]) (weight w [4 3 3]))";
        let (_, _, a1) = apply_once(src, split_dwconv_c(2));
        let (_, _, a2) = apply_once(src, split_dwconv_oh(2));
        assert_eq!((a1, a2), (1, 1));
    }

    #[test]
    fn rect_conv_ow_split_uses_kw_halo() {
        // 3x1 kernel: a W split needs only kw=1 halo columns, so an
        // 8-wide output over an 8-wide input splits into 2x4 exactly.
        let src = "(invoke-conv (conv-engine 8 8 4 8 3 1 1) \
                     (input x [4 10 8]) (weight w [8 4 3 1]))";
        let (eg, root, applied) = apply_once(src, split_conv_ow(2));
        assert_eq!(applied, 1);
        let has_loop = eg.class_nodes(root).any(|n| matches!(n.op, Op::SchedLoop { .. }));
        assert!(has_loop);
    }

    /// The canonical 8-batch loop — deep enough for two levels of tiling.
    const BMM_LOOP8: &str = "(sched-loop b 0 8 (reshape [1 4 8] (invoke-mm (mm-engine 4 8 8) \
        (reshape [4 8] (slice 0 1 (imul (lvar b) 1) (input qa [8 4 8]))) \
        (reshape [8 8] (slice 0 1 (imul (lvar b) 1) (input kb [8 8 8]))))))";

    #[test]
    fn bmm_batch_factor4_tiles_eight_heads() {
        let (eg, root, applied) = apply_once(BMM_LOOP8, split_bmm_batch(4));
        assert_eq!(applied, 1);
        let outer = eg
            .class_nodes(root)
            .find(|n| matches!(n.op, Op::SchedLoop { extent: 4, .. }))
            .expect("outer 4-tile");
        assert!(eg
            .class_nodes(outer.children[0])
            .any(|n| matches!(n.op, Op::SchedLoop { extent: 2, .. })));
    }

    #[test]
    fn bmm_batch_split_rematches_iadd_starts() {
        // A once-tiled inner loop (iadd-form slice starts, as tiled_operand
        // emits them) must still match, so deeper tilings compose.
        let once_tiled = "(sched-loop i 0 4 (reshape [1 4 8] (invoke-mm (mm-engine 4 8 8) \
            (reshape [4 8] (slice 0 1 (iadd (imul (lvar o) 4) (imul (lvar i) 1)) (input qa [8 4 8]))) \
            (reshape [8 8] (slice 0 1 (iadd (imul (lvar o) 4) (imul (lvar i) 1)) (input kb [8 8 8]))))))";
        let (eg, root, applied) = apply_once(once_tiled, split_bmm_batch(2));
        assert_eq!(applied, 1, "iadd-form starts must stay re-matchable");
        // The re-tiled start folds the old offset: offset' = o*4 + outer*2.
        let outer = eg
            .class_nodes(root)
            .find(|n| matches!(n.op, Op::SchedLoop { extent: 2, .. }))
            .expect("outer tile");
        assert!(eg
            .class_nodes(outer.children[0])
            .any(|n| matches!(n.op, Op::SchedLoop { extent: 2, .. })));
    }

    #[test]
    fn bmm_batch_two_level_tiling_is_semantics_preserving() {
        // Textual form of tiling BMM_LOOP8 twice (x2 then x2 on the inner
        // loop, offsets folded the way tiled_operand does): same product.
        use crate::tensor::{eval_expr, Env};
        let e = parse_expr(BMM_LOOP8).unwrap();
        let want = eval_expr(&e, &mut Env::random_for(&e, 11)).unwrap();
        let twice = "(sched-loop o 0 2 (sched-loop m 0 2 (sched-loop i 0 2 \
            (reshape [1 4 8] (invoke-mm (mm-engine 4 8 8) \
            (reshape [4 8] (slice 0 1 (iadd (iadd (imul (lvar o) 4) (imul (lvar m) 2)) (imul (lvar i) 1)) (input qa [8 4 8]))) \
            (reshape [8 8] (slice 0 1 (iadd (iadd (imul (lvar o) 4) (imul (lvar m) 2)) (imul (lvar i) 1)) (input kb [8 8 8]))))))))";
        let t = parse_expr(twice).unwrap();
        assert_eq!(t.typecheck().unwrap(), e.typecheck().unwrap());
        let got = eval_expr(&t, &mut Env::random_for(&t, 11)).unwrap();
        assert!(want.allclose(&got, 1e-6), "{:?}", want.max_abs_diff(&got));
    }
}
