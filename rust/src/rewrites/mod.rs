//! The hardware–software split rewrite library (paper §2, Fig. 2).
//!
//! Every rule is semantics-preserving (differential-tested against the
//! evaluator in [`crate::tensor`]) and *moves the hardware–software split*:
//!
//! | group | rules | direction |
//! |---|---|---|
//! | [`split`] | `split-{relu,add}-x{2,4}`, `split-mm-{m,n,k}-x2`, `split-conv-{oh,ow,k,c}-x2`, `split-pool-{c,oh}-x2` | smaller hardware, more software (Fig. 2 rewrite 1, generalized) |
//! | [`sched`] | `parallelize`, `serialize`, `loop-reorder` | trade time-multiplexing for hardware replication (Fig. 2 rewrite 2) |
//! | [`fuse`] | `conv-as-im2col-mm`, `fuse-mm-relu` | share/merge engines across op types |
//! | [`storage`] | `sram-to-dram`, `dram-to-sram`, `double-buffer`, `undouble-buffer` | storage choices |
//!
//! Rule-set entry points: [`fig2_rules`] (the paper's two rewrites,
//! verbatim), [`paper_rules`] (everything §2 describes), [`all_rules`]
//! (plus the extensions).

pub mod fuse;
pub mod sched;
pub mod split;
pub mod storage;

use crate::egraph::{EGraph, Id, Rewrite};
use crate::ir::{Node, Op, OpKind};

/// The two rewrites of paper Fig. 2, restricted to ReLU: engine halving and
/// loop parallelization. Used by the Fig. 2 reproduction bench/example.
pub fn fig2_rules() -> Vec<Rewrite> {
    vec![split::split_relu(2), sched::parallelize(), sched::serialize()]
}

/// The full rewrite set the paper's §2 describes: splitting every engine
/// kind along every dimension, loop⇄parallel, conv-via-matmul engine
/// sharing, and storage reification choices.
pub fn paper_rules() -> Vec<Rewrite> {
    let mut rules = vec![
        split::split_relu(2),
        split::split_add(2),
        split::split_mm_m(2),
        split::split_mm_n(2),
        split::split_mm_k(2),
        split::split_conv_oh(2),
        split::split_conv_ow(2),
        split::split_conv_k(2),
        split::split_conv_c(2),
        split::split_pool_c(2),
        split::split_pool_oh(2),
        sched::parallelize(),
        sched::serialize(),
        fuse::conv_as_im2col_mm(),
        storage::sram_to_dram(),
        storage::dram_to_sram(),
    ];
    rules.push(split::split_relu(4));
    rules.push(split::split_add(4));
    rules
}

/// Everything: paper rules plus the extension rewrites (fused engines,
/// loop reordering, double buffering).
pub fn all_rules() -> Vec<Rewrite> {
    let mut rules = paper_rules();
    rules.extend([
        fuse::fuse_mm_relu(),
        fuse::split_mmrelu_m(2),
        fuse::split_mmrelu_n(2),
        sched::loop_reorder(),
        storage::double_buffer(),
        storage::undouble_buffer(),
    ]);
    rules
}

/// Look up rules by name (CLI `--rules a,b,c` support).
pub fn rules_by_names(names: &[&str]) -> Vec<Rewrite> {
    let all = all_rules();
    names
        .iter()
        .map(|n| {
            all.iter()
                .find(|r| r.name == *n)
                .unwrap_or_else(|| panic!("unknown rule '{n}'"))
                .clone()
        })
        .collect()
}

// ---------------------------------------------------------------------
// Shared applier helpers
// ---------------------------------------------------------------------

/// The engine op of an invocation node's first child (via the class type —
/// every class of engine type has exactly one engine signature).
pub(crate) fn engine_of(eg: &EGraph, invoke: &Node) -> Option<Op> {
    eg.ty(invoke.children[0]).engine().cloned()
}

/// Find an e-node of `kind` inside class `id`.
pub(crate) fn find_in_class(eg: &EGraph, id: Id, kind: OpKind) -> Option<Node> {
    eg.class(id).nodes.iter().find(|n| n.op.kind() == kind).cloned()
}

/// Build `(slice axis len (imul (lvar var) chunk) x)` — the canonical
/// schedule-indexed slice used by all split rewrites.
pub(crate) fn slice_for_loop(
    eg: &mut EGraph,
    var: crate::ir::Symbol,
    axis: usize,
    chunk_stride: usize,
    len: usize,
    x: Id,
) -> Id {
    let lv = eg.add(Node::leaf(Op::LVar(var)));
    let c = eg.add(Node::leaf(Op::Int(chunk_stride as i64)));
    let start = eg.add(Node::new(Op::IMul, vec![lv, c]));
    eg.add(Node::new(Op::SliceAx { axis, len }, vec![start, x]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::Runner;
    use crate::ir::parse_expr;

    #[test]
    fn rule_names_are_unique() {
        let rules = all_rules();
        let mut names: Vec<&str> = rules.iter().map(|r| r.name.as_str()).collect();
        names.sort();
        let before = names.len();
        names.dedup();
        assert_eq!(before, names.len(), "duplicate rule names");
    }

    #[test]
    fn rules_by_names_resolves() {
        let rs = rules_by_names(&["parallelize", "split-relu-x2"]);
        assert_eq!(rs.len(), 2);
    }

    #[test]
    #[should_panic(expected = "unknown rule")]
    fn rules_by_names_rejects_unknown() {
        rules_by_names(&["not-a-rule"]);
    }

    /// The paper's headline: Fig. 2 rules on the Fig. 2 program yield
    /// multiple equivalent designs.
    #[test]
    fn fig2_enumerates_at_least_three_designs() {
        let e = parse_expr("(invoke-relu (relu-engine 128) (input x [128]))").unwrap();
        let mut runner = Runner::new(e, fig2_rules());
        let report = runner.run(8);
        // 1 original + loop version + par version at minimum; nested splits
        // multiply further.
        assert!(
            report.designs_lower_bound >= 3.0,
            "got {}",
            report.designs_lower_bound
        );
    }
}
