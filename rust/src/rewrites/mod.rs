//! The hardware–software split rewrite library (paper §2, Fig. 2).
//!
//! Every rule is semantics-preserving (differential-tested against the
//! evaluator in [`crate::tensor`]) and *moves the hardware–software split*:
//!
//! | group | rules | direction |
//! |---|---|---|
//! | [`split`] | `split-{relu,add}-x{2,4}`, `split-{emul,gelu}-x2`, `split-mm-{m,n,k}-x2`, `split-conv-{oh,ow,k,c}-x2`, `split-pool-{c,oh,ow}-x2`, `split-dwconv-{c,oh}-x2`, `split-bmm-batch[-par]-x{2,4}` | smaller hardware, more software (Fig. 2 rewrite 1, generalized; the bmm-batch rules tile the head axis of the canonical batch-matmul loop, emitting canonical `iadd`-offset slice starts so tilings compose) |
//! | [`sched`] | `parallelize`, `serialize`, `loop-reorder` | trade time-multiplexing for hardware replication (Fig. 2 rewrite 2) |
//! | [`fuse`] | `conv-as-im2col-mm`, `fuse-mm-relu` | share/merge engines across op types |
//! | [`storage`] | `sram-to-dram`, `dram-to-sram`, `double-buffer`, `undouble-buffer` | storage choices |
//!
//! Rule-set entry points: [`fig2_rules`] (the paper's two rewrites,
//! verbatim), [`paper_rules`] (everything §2 describes), [`all_rules`]
//! (plus the extensions).
//!
//! Authoring note for the incremental engine: appliers that inspect other
//! classes' *nodes* (not just the matched node and child types) must
//! declare how deep they look via [`Rewrite::node_scan_deep`] — see
//! [`sched::loop_reorder`] and [`fuse::fuse_mm_relu`]. Fairness between
//! rules is the [`crate::egraph::Scheduler`]'s job, not the rule author's.

pub mod fuse;
pub mod sched;
pub mod split;
pub mod storage;

use crate::egraph::{ApplyGraph, Id, Rewrite};
use crate::error::Error;
use crate::ir::{Node, Op, OpKind};

/// Which rewrite set to enumerate with. Parsed from CLI/env strings via
/// [`std::str::FromStr`] (`"fig2" | "paper" | "all"`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuleSet {
    /// Only paper Fig. 2's two rewrites (ReLU split + parallelize).
    Fig2,
    /// Everything §2 describes.
    Paper,
    /// Paper + extensions (fusion, loop reorder, double buffering).
    All,
}

impl RuleSet {
    pub fn rules(self) -> Vec<Rewrite> {
        match self {
            RuleSet::Fig2 => fig2_rules(),
            RuleSet::Paper => paper_rules(),
            RuleSet::All => all_rules(),
        }
    }

    #[deprecated(note = "use the std::str::FromStr impl: `s.parse::<RuleSet>()`")]
    pub fn parse(s: &str) -> Option<Self> {
        s.parse().ok()
    }
}

impl std::str::FromStr for RuleSet {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self, Error> {
        match s {
            "fig2" => Ok(RuleSet::Fig2),
            "paper" => Ok(RuleSet::Paper),
            "all" => Ok(RuleSet::All),
            other => Err(Error::UnknownRuleSet(other.to_string())),
        }
    }
}

/// The two rewrites of paper Fig. 2, restricted to ReLU: engine halving and
/// loop parallelization. Used by the Fig. 2 reproduction bench/example.
pub fn fig2_rules() -> Vec<Rewrite> {
    vec![split::split_relu(2), sched::parallelize(), sched::serialize()]
}

/// The full rewrite set the paper's §2 describes: splitting every engine
/// kind along every dimension, loop⇄parallel, conv-via-matmul engine
/// sharing, and storage reification choices.
pub fn paper_rules() -> Vec<Rewrite> {
    let mut rules = vec![
        split::split_relu(2),
        split::split_add(2),
        split::split_mm_m(2),
        split::split_mm_n(2),
        split::split_mm_k(2),
        split::split_conv_oh(2),
        split::split_conv_ow(2),
        split::split_conv_k(2),
        split::split_conv_c(2),
        split::split_pool_c(2),
        split::split_pool_oh(2),
        split::split_pool_ow(2),
        split::split_gelu(2),
        split::split_emul(2),
        split::split_dwconv_c(2),
        split::split_dwconv_oh(2),
        sched::parallelize(),
        sched::serialize(),
        fuse::conv_as_im2col_mm(),
        storage::sram_to_dram(),
        storage::dram_to_sram(),
    ];
    rules.push(split::split_relu(4));
    rules.push(split::split_add(4));
    rules
}

/// Everything: paper rules plus the extension rewrites (fused engines,
/// loop reordering, double buffering).
pub fn all_rules() -> Vec<Rewrite> {
    let mut rules = paper_rules();
    rules.extend([
        fuse::fuse_mm_relu(),
        fuse::split_mmrelu_m(2),
        fuse::split_mmrelu_n(2),
        split::split_bmm_batch(2),
        split::split_bmm_batch_par(2),
        split::split_bmm_batch(4),
        split::split_bmm_batch_par(4),
        sched::loop_reorder(),
        storage::double_buffer(),
        storage::undouble_buffer(),
    ]);
    rules
}

/// Look up rules by name (CLI `--rules a,b,c` support). Unknown names are
/// a typed error, not a panic — callers surface them to the user.
pub fn rules_by_names(names: &[&str]) -> Result<Vec<Rewrite>, Error> {
    let all = all_rules();
    names
        .iter()
        .map(|n| {
            all.iter()
                .find(|r| r.name == *n)
                .cloned()
                .ok_or_else(|| Error::UnknownRule(n.to_string()))
        })
        .collect()
}

// ---------------------------------------------------------------------
// Shared applier helpers
// ---------------------------------------------------------------------

/// The engine op of an invocation node's first child (via the class type —
/// every class of engine type has exactly one engine signature).
pub(crate) fn engine_of(eg: &ApplyGraph, invoke: &Node) -> Option<Op> {
    eg.ty(invoke.children[0]).engine().cloned()
}

/// Find an e-node of `kind` inside class `id`.
pub(crate) fn find_in_class(eg: &ApplyGraph, id: Id, kind: OpKind) -> Option<Node> {
    eg.class_nodes(id).find(|n| n.op.kind() == kind).cloned()
}

/// Build `(slice axis len (imul (lvar var) chunk) x)` — the canonical
/// schedule-indexed slice used by all split rewrites.
pub(crate) fn slice_for_loop(
    eg: &mut ApplyGraph,
    var: crate::ir::Symbol,
    axis: usize,
    chunk_stride: usize,
    len: usize,
    x: Id,
) -> Id {
    let lv = eg.add(Node::leaf(Op::LVar(var)));
    let c = eg.add(Node::leaf(Op::Int(chunk_stride as i64)));
    let start = eg.add(Node::new(Op::IMul, vec![lv, c]));
    eg.add(Node::new(Op::SliceAx { axis, len }, vec![start, x]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::Runner;
    use crate::ir::parse_expr;

    #[test]
    fn rule_names_are_unique() {
        let rules = all_rules();
        let mut names: Vec<&str> = rules.iter().map(|r| r.name.as_str()).collect();
        names.sort();
        let before = names.len();
        names.dedup();
        assert_eq!(before, names.len(), "duplicate rule names");
    }

    #[test]
    fn rules_by_names_resolves() {
        let rs = rules_by_names(&["parallelize", "split-relu-x2"]).unwrap();
        assert_eq!(rs.len(), 2);
    }

    #[test]
    fn rules_by_names_rejects_unknown_with_typed_error() {
        let err = rules_by_names(&["not-a-rule"]).unwrap_err();
        assert!(matches!(err, Error::UnknownRule(ref n) if n == "not-a-rule"), "{err}");
    }

    #[test]
    fn ruleset_from_str_roundtrip() {
        assert_eq!("fig2".parse::<RuleSet>().unwrap(), RuleSet::Fig2);
        assert_eq!("paper".parse::<RuleSet>().unwrap(), RuleSet::Paper);
        assert_eq!("all".parse::<RuleSet>().unwrap(), RuleSet::All);
        assert!(matches!(
            "bogus".parse::<RuleSet>().unwrap_err(),
            Error::UnknownRuleSet(ref n) if n == "bogus"
        ));
    }

    /// The paper's headline: Fig. 2 rules on the Fig. 2 program yield
    /// multiple equivalent designs.
    #[test]
    fn fig2_enumerates_at_least_three_designs() {
        let e = parse_expr("(invoke-relu (relu-engine 128) (input x [128]))").unwrap();
        let mut runner = Runner::new(e, fig2_rules());
        let report = runner.run(8);
        // 1 original + loop version + par version at minimum; nested splits
        // multiply further.
        assert!(
            report.designs_lower_bound >= 3.0,
            "got {}",
            report.designs_lower_bound
        );
    }

    /// The backoff scheduler delays explosive rules (exponentially growing
    /// ban windows) but must not shrink the enumerated space: both engines,
    /// run to saturation, land on the same closure.
    #[test]
    fn fig2_backoff_scheduler_reaches_same_space() {
        use crate::egraph::{BackoffScheduler, RunnerLimits, Scheduler, StopReason};
        let run = |scheduler: Option<Box<dyn Scheduler>>| {
            let e = parse_expr("(invoke-relu (relu-engine 128) (input x [128]))").unwrap();
            let mut runner = Runner::new(e, fig2_rules())
                .with_limits(RunnerLimits { max_iters: 200, ..Default::default() });
            if let Some(s) = scheduler {
                runner = runner.with_scheduler(s);
            }
            runner.run(200)
        };
        let plain = run(None);
        let backoff = run(Some(Box::new(BackoffScheduler::new(8, 1))));
        assert_eq!(plain.stop, StopReason::Saturated);
        assert_eq!(backoff.stop, StopReason::Saturated);
        assert_eq!(backoff.designs_lower_bound, plain.designs_lower_bound);
        assert_eq!(backoff.nodes, plain.nodes);
        assert_eq!(backoff.classes, plain.classes);
    }
}
