//! Relay → EngineIR reification (paper Fig. 1).
//!
//! Each Relay-level operator call is converted to a call to a hardware
//! engine *instantiated with concrete parameters* matching the call, and
//! each converted call is given an explicit storage buffer for its output —
//! exactly the paper's lowering. The result is the **initial design point**:
//! one dedicated full-size engine per call site, no software schedule. The
//! rewrite library then moves work from hardware into software (and back)
//! starting from here.
//!
//! | Relay op | reified form |
//! |---|---|
//! | `dense x w` | `buffer (invoke-mm (mm-engine m k n) x w)` |
//! | `relu x` | `buffer (reshape (invoke-relu (relu-engine numel) (reshape x)))` |
//! | `bias-add x b` | `buffer (reshape (invoke-add (add-engine numel) (reshape x) (reshape (bcast b))))` |
//! | `eadd x y` | `buffer (reshape (invoke-add …))` |
//! | `conv2d s p x w` | `buffer (invoke-conv (conv-engine oh ow c k kh s) (pad2d p x) w)` |
//! | `maxpool2d k s x` | `buffer (invoke-pool (pool-engine oh ow c k s) x)` |
//! | `flatten x` | `reshape x` |

use crate::egraph::Id;
use crate::error::Error;
use crate::ir::{in_dim, Node, Op, RecExpr, Shape, Ty};

/// Lowering options.
#[derive(Debug, Clone, Copy)]
pub struct LowerOptions {
    /// Wrap each engine invocation's output in an explicit `(buffer sram …)`
    /// (the paper's "explicit storage buffer for its output"). Disable for
    /// minimal textbook examples like Fig. 2.
    pub buffers: bool,
}

impl Default for LowerOptions {
    fn default() -> Self {
        LowerOptions { buffers: true }
    }
}

/// Reify a Relay-level graph into EngineIR. Non-Relay nodes pass through
/// unchanged, so partially-lowered inputs are fine (idempotent).
///
/// Errors with [`Error::Type`] if the input fails inference, or
/// [`Error::Lower`] if a Relay op has a non-tensor child where the
/// reification rules require one.
pub fn lower(expr: &RecExpr, opts: LowerOptions) -> Result<RecExpr, Error> {
    let tys = expr.types()?;
    let mut out = RecExpr::new();
    let mut map: Vec<Id> = Vec::with_capacity(expr.len());

    for (slot, node) in expr.nodes().iter().enumerate() {
        let kids: Vec<Id> = node.children.iter().map(|c| map[c.index()]).collect();
        let shape_of = |i: usize| -> Result<&Shape, Error> {
            match &tys[expr.nodes()[slot].children[i].index()] {
                Ty::Tensor(s) => Ok(s),
                other => Err(Error::Lower {
                    op: node.op.to_string(),
                    detail: format!("expected tensor child {i}, got {other:?}"),
                }),
            }
        };
        let my_shape = || -> Result<&Shape, Error> {
            match &tys[slot] {
                Ty::Tensor(s) => Ok(s),
                other => Err(Error::Lower {
                    op: node.op.to_string(),
                    detail: format!("expected tensor node, got {other:?}"),
                }),
            }
        };

        let new_id = match &node.op {
            Op::Dense => {
                let (x, w) = (shape_of(0)?, shape_of(1)?);
                let (m, k, n) = (x.dim(0), x.dim(1), w.dim(1));
                let e = out.add_leaf(Op::MmEngine { m, k, n });
                let inv = out.add_op(Op::InvokeMm, &[e, kids[0], kids[1]]);
                buffered(&mut out, inv, opts)
            }
            Op::Relu => {
                let s = my_shape()?.clone();
                let numel = s.numel();
                let e = out.add_leaf(Op::ReluEngine { w: numel });
                let xin = flat(&mut out, kids[0], shape_of(0)?);
                let inv = out.add_op(Op::InvokeRelu, &[e, xin]);
                let backed = unflat(&mut out, inv, &s);
                buffered(&mut out, backed, opts)
            }
            Op::EAdd => {
                let s = my_shape()?.clone();
                let numel = s.numel();
                let e = out.add_leaf(Op::AddEngine { w: numel });
                let a = flat(&mut out, kids[0], shape_of(0)?);
                let b = flat(&mut out, kids[1], shape_of(1)?);
                let inv = out.add_op(Op::InvokeAdd, &[e, a, b]);
                let backed = unflat(&mut out, inv, &s);
                buffered(&mut out, backed, opts)
            }
            Op::BiasAdd => {
                let s = my_shape()?.clone();
                let numel = s.numel();
                let e = out.add_leaf(Op::AddEngine { w: numel });
                let a = flat(&mut out, kids[0], shape_of(0)?);
                let bb = out.add_op(Op::Bcast(s.clone()), &[kids[1]]);
                let b = flat_shape(&mut out, bb, &s);
                let inv = out.add_op(Op::InvokeAdd, &[e, a, b]);
                let backed = unflat(&mut out, inv, &s);
                buffered(&mut out, backed, opts)
            }
            Op::Conv2d { stride, pad } => {
                let x = shape_of(0)?.clone();
                let w = shape_of(1)?.clone();
                let o = my_shape()?.clone();
                let (c, k, kh) = (x.dim(0), w.dim(0), w.dim(2));
                let (oh, ow) = (o.dim(1), o.dim(2));
                debug_assert_eq!(in_dim(oh, kh, *stride), x.dim(1) + 2 * pad);
                let e = out.add_leaf(Op::ConvEngine { oh, ow, c, k, kh, stride: *stride });
                let xin = if *pad > 0 {
                    out.add_op(Op::Pad2d { pad: *pad }, &[kids[0]])
                } else {
                    kids[0]
                };
                let inv = out.add_op(Op::InvokeConv, &[e, xin, kids[1]]);
                buffered(&mut out, inv, opts)
            }
            Op::MaxPool2d { k, stride } => {
                let x = shape_of(0)?;
                let o = my_shape()?.clone();
                let e = out.add_leaf(Op::PoolEngine {
                    oh: o.dim(1),
                    ow: o.dim(2),
                    c: x.dim(0),
                    k: *k,
                    stride: *stride,
                });
                let inv = out.add_op(Op::InvokePool, &[e, kids[0]]);
                buffered(&mut out, inv, opts)
            }
            Op::Flatten => {
                let s = my_shape()?.clone();
                out.add_op(Op::Reshape(s), &[kids[0]])
            }
            // Everything else (leaves, already-reified forms, index math)
            // passes through structurally.
            other => out.add(Node::new(other.clone(), kids)),
        };
        map.push(new_id);
    }
    Ok(out)
}

/// Reify with default options.
pub fn lower_default(expr: &RecExpr) -> Result<RecExpr, Error> {
    lower(expr, LowerOptions::default())
}

fn buffered(out: &mut RecExpr, id: Id, opts: LowerOptions) -> Id {
    if opts.buffers {
        out.add_op(Op::Buffer { kind: crate::ir::BufKind::Sram }, &[id])
    } else {
        id
    }
}

/// Reshape `id` (of shape `s`) to rank-1 unless it already is.
fn flat(out: &mut RecExpr, id: Id, s: &Shape) -> Id {
    if s.rank() == 1 {
        id
    } else {
        out.add_op(Op::Reshape(Shape::new(&[s.numel()])), &[id])
    }
}

fn flat_shape(out: &mut RecExpr, id: Id, s: &Shape) -> Id {
    flat(out, id, s)
}

/// Reshape rank-1 `id` back to `s` unless `s` is rank-1.
fn unflat(out: &mut RecExpr, id: Id, s: &Shape) -> Id {
    if s.rank() == 1 {
        id
    } else {
        out.add_op(Op::Reshape(s.clone()), &[id])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::all_workloads;
    use crate::tensor::{eval_expr, Env};

    #[test]
    fn lowered_workloads_typecheck_with_same_type() {
        for w in all_workloads() {
            let lo = lower_default(&w.expr).unwrap();
            let t0 = w.expr.typecheck().unwrap();
            let t1 = lo.typecheck().unwrap_or_else(|e| panic!("{}: {e}", w.name));
            assert_eq!(t0, t1, "{}", w.name);
        }
    }

    #[test]
    fn lowering_preserves_semantics() {
        for w in all_workloads() {
            let lo = lower_default(&w.expr).unwrap();
            let mut env1 = Env::random_for(&w.expr, 42);
            let mut env2 = Env::random_for(&lo, 42);
            let a = eval_expr(&w.expr, &mut env1).unwrap();
            let b = eval_expr(&lo, &mut env2).unwrap();
            assert!(
                a.allclose(&b, 1e-4),
                "{}: max diff {:?}",
                w.name,
                a.max_abs_diff(&b)
            );
        }
    }

    #[test]
    fn lowering_reifies_every_relay_op() {
        for w in all_workloads() {
            let lo = lower_default(&w.expr).unwrap();
            let relay_left = lo.count(|op| op.is_relay());
            assert_eq!(relay_left, 0, "{} still has relay ops after lowering", w.name);
        }
    }

    #[test]
    fn one_engine_per_call_site_initially() {
        // convblock = conv + bias-add + relu -> 3 invokes, 3 engines (all
        // distinct kinds/params here).
        let w = crate::relay::workloads::convblock();
        let lo = lower_default(&w.expr).unwrap();
        assert_eq!(lo.count(|op| op.is_invoke()), 3);
        assert_eq!(lo.engines().len(), 3);
        // paper: "each converted call will be given an explicit storage
        // buffer for its output"
        assert_eq!(lo.count(|op| matches!(op, Op::Buffer { .. })), 3);
    }

    #[test]
    fn lowering_is_idempotent() {
        let w = crate::relay::workloads::mlp();
        let lo = lower_default(&w.expr).unwrap();
        let lo2 = lower_default(&lo).unwrap();
        assert_eq!(lo.to_string(), lo2.to_string());
    }

    #[test]
    fn fig1_shape_conv_reification() {
        // The paper's Fig. 1: nn.conv2d reified into engine + storage.
        let w = crate::relay::workloads::convblock();
        let lo = lower(&w.expr, LowerOptions { buffers: true }).unwrap();
        let txt = lo.to_string();
        assert!(txt.contains("(conv-engine 16 16 3 8 3 1)"), "{txt}");
        assert!(txt.contains("(buffer sram (invoke-conv"), "{txt}");
    }

    #[test]
    fn lowering_ill_typed_input_is_a_typed_error() {
        // dense with mismatched inner dims: inference fails, lower must
        // return Error::Type, not panic.
        let e = crate::ir::parse_expr("(dense (input x [1 10]) (weight w [11 4]))").unwrap();
        match lower_default(&e) {
            Err(crate::error::Error::Type(_)) => {}
            other => panic!("expected Error::Type, got {other:?}"),
        }
    }
}
