//! Relay → EngineIR reification (paper Fig. 1).
//!
//! Each Relay-level operator call is converted to a call to a hardware
//! engine *instantiated with concrete parameters* matching the call, and
//! each converted call is given an explicit storage buffer for its output —
//! exactly the paper's lowering. The result is the **initial design point**:
//! one dedicated full-size engine per call site, no software schedule. The
//! rewrite library then moves work from hardware into software (and back)
//! starting from here.
//!
//! The per-op reification templates live in each op's
//! [`crate::ir::spec::OpSpec::lower`] entry; this module provides the
//! traversal and the [`LowerCtx`] the templates build against. Ops without
//! a template (engines, schedules, data movement, already-reified forms)
//! pass through structurally, so partially-lowered inputs are fine
//! (idempotent).
//!
//! | Relay op | reified form |
//! |---|---|
//! | `dense x w` / `matmul a b` | `buffer (invoke-mm (mm-engine m k n) a b)` |
//! | `batch-matmul a b` | `buffer (sched-loop b (reshape (invoke-mm …slices…)))` |
//! | `relu x` / `gelu x` | `buffer (reshape (invoke-* (…-engine numel) (reshape x)))` |
//! | `bias-add x b` / `eadd x y` / `emul x y` | `buffer (reshape (invoke-{add,emul} ({add,emul}-engine numel) …))` |
//! | `conv2d s ph pw x w` | `buffer (invoke-conv (conv-engine oh ow c k kh kw s) (pad2d ph pw x) w)` — `ph`/`pw` are TOTAL per-dim pads, split floor-before/ceil-after |
//! | `dwconv2d s ph pw x w` | `buffer (invoke-dw-conv (dw-conv-engine oh ow c kh kw s) (pad2d ph pw x) w)` |
//! | `maxpool2d kh kw s x` | `buffer (invoke-pool (pool-engine oh ow c kh kw s) x)` |
//! | `softmax x` | rank-1: direct invoke; rank-2: `sched-loop` over rows; rank-3: nested `sched-loop`s (leading axis, then rows) |
//! | `layernorm x g b` | the softmax row schedule on `layernorm-engine`, then a numel-wide `invoke-emul`/`invoke-add` affine tail over broadcast `g`/`b` |
//! | `flatten x` | `reshape x` |

use crate::egraph::Id;
use crate::error::Error;
use crate::ir::{Node, Op, RecExpr, Shape, Symbol, Ty};

/// Lowering options.
#[derive(Debug, Clone, Copy)]
pub struct LowerOptions {
    /// Wrap each engine invocation's output in an explicit `(buffer sram …)`
    /// (the paper's "explicit storage buffer for its output"). Disable for
    /// minimal textbook examples like Fig. 2.
    pub buffers: bool,
}

impl Default for LowerOptions {
    fn default() -> Self {
        LowerOptions { buffers: true }
    }
}

/// Per-node reification context handed to the registry's lowering
/// templates: typed access to the original node plus builders over the
/// output expression.
pub struct LowerCtx<'a> {
    out: &'a mut RecExpr,
    node: &'a Node,
    tys: &'a [Ty],
    slot: usize,
    /// The node's children, already mapped into the output expression.
    kids: &'a [Id],
    opts: LowerOptions,
}

impl LowerCtx<'_> {
    /// The op being reified.
    pub fn op(&self) -> &Op {
        &self.node.op
    }

    /// Output-expression id of original child `i`.
    pub fn kid(&self, i: usize) -> Id {
        self.kids[i]
    }

    /// Shape of original child `i` (errors on non-tensor children).
    pub fn child_shape(&self, i: usize) -> Result<Shape, Error> {
        match &self.tys[self.node.children[i].index()] {
            Ty::Tensor(s) => Ok(s.clone()),
            other => Err(Error::Lower {
                op: self.node.op.to_string(),
                detail: format!("expected tensor child {i}, got {other:?}"),
            }),
        }
    }

    /// Shape of the node being reified.
    pub fn out_shape(&self) -> Result<Shape, Error> {
        match &self.tys[self.slot] {
            Ty::Tensor(s) => Ok(s.clone()),
            other => Err(Error::Lower {
                op: self.node.op.to_string(),
                detail: format!("expected tensor node, got {other:?}"),
            }),
        }
    }

    /// A lowering error for this op.
    pub fn lower_err(&self, detail: impl Into<String>) -> Error {
        Error::Lower { op: self.node.op.to_string(), detail: detail.into() }
    }

    /// Append `op` applied to `kids` to the output expression.
    pub fn add(&mut self, op: Op, kids: &[Id]) -> Id {
        self.out.add_op(op, kids)
    }

    /// Append a leaf op (engine declarations, literals).
    pub fn add_leaf(&mut self, op: Op) -> Id {
        self.out.add_leaf(op)
    }

    /// Wrap `id` in `(buffer sram …)` when buffers are enabled.
    pub fn buffered(&mut self, id: Id) -> Id {
        if self.opts.buffers {
            self.add(Op::Buffer { kind: crate::ir::BufKind::Sram }, &[id])
        } else {
            id
        }
    }

    /// Reshape `id` (of shape `s`) to rank-1 unless it already is.
    pub fn flat(&mut self, id: Id, s: &Shape) -> Id {
        if s.rank() == 1 {
            id
        } else {
            self.add(Op::Reshape(Shape::new(&[s.numel()])), &[id])
        }
    }

    /// Reshape rank-1 `id` back to `s` unless `s` is rank-1.
    pub fn unflat(&mut self, id: Id, s: &Shape) -> Id {
        if s.rank() == 1 {
            id
        } else {
            self.add(Op::Reshape(s.clone()), &[id])
        }
    }

    /// `(slice axis len (imul (lvar var) chunk) x)` — the schedule-indexed
    /// slice shape shared by loop-emitting lowerings (and, on the e-graph
    /// side, by the split rewrites).
    pub fn loop_slice(
        &mut self,
        var: Symbol,
        axis: usize,
        chunk_stride: usize,
        len: usize,
        x: Id,
    ) -> Id {
        let lv = self.add_leaf(Op::LVar(var));
        let c = self.add_leaf(Op::Int(chunk_stride as i64));
        let start = self.add(Op::IMul, &[lv, c]);
        self.add(Op::SliceAx { axis, len }, &[start, x])
    }
}

/// Reify a Relay-level graph into EngineIR via the registry's lowering
/// templates. Non-Relay nodes pass through unchanged, so partially-lowered
/// inputs are fine (idempotent).
///
/// Errors with [`Error::Type`] if the input fails inference, or
/// [`Error::Lower`] if a Relay op has a non-tensor child where the
/// reification rules require one.
pub fn lower(expr: &RecExpr, opts: LowerOptions) -> Result<RecExpr, Error> {
    let tys = expr.types()?;
    let mut out = RecExpr::new();
    let mut map: Vec<Id> = Vec::with_capacity(expr.len());

    for (slot, node) in expr.nodes().iter().enumerate() {
        let kids: Vec<Id> = node.children.iter().map(|c| map[c.index()]).collect();
        let new_id = match node.op.spec().lower {
            Some(template) => {
                let mut cx =
                    LowerCtx { out: &mut out, node, tys: &tys, slot, kids: &kids, opts };
                template(&mut cx)?
            }
            // Everything else (leaves, already-reified forms, index math)
            // passes through structurally.
            None => out.add(Node::new(node.op.clone(), kids)),
        };
        map.push(new_id);
    }
    Ok(out)
}

/// Reify with default options.
pub fn lower_default(expr: &RecExpr) -> Result<RecExpr, Error> {
    lower(expr, LowerOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::all_workloads;
    use crate::tensor::{eval_expr, Env};

    #[test]
    fn lowered_workloads_typecheck_with_same_type() {
        for w in all_workloads() {
            let lo = lower_default(&w.expr).unwrap();
            let t0 = w.expr.typecheck().unwrap();
            let t1 = lo.typecheck().unwrap_or_else(|e| panic!("{}: {e}", w.name));
            assert_eq!(t0, t1, "{}", w.name);
        }
    }

    #[test]
    fn lowering_preserves_semantics() {
        for w in all_workloads() {
            let lo = lower_default(&w.expr).unwrap();
            let mut env1 = Env::random_for(&w.expr, 42);
            let mut env2 = Env::random_for(&lo, 42);
            let a = eval_expr(&w.expr, &mut env1).unwrap();
            let b = eval_expr(&lo, &mut env2).unwrap();
            assert!(
                a.allclose(&b, 1e-4),
                "{}: max diff {:?}",
                w.name,
                a.max_abs_diff(&b)
            );
        }
    }

    #[test]
    fn lowering_reifies_every_relay_op() {
        for w in all_workloads() {
            let lo = lower_default(&w.expr).unwrap();
            let relay_left = lo.count(|op| op.is_relay());
            assert_eq!(relay_left, 0, "{} still has relay ops after lowering", w.name);
        }
    }

    #[test]
    fn one_engine_per_call_site_initially() {
        // convblock = conv + bias-add + relu -> 3 invokes, 3 engines (all
        // distinct kinds/params here).
        let w = crate::relay::workloads::convblock();
        let lo = lower_default(&w.expr).unwrap();
        assert_eq!(lo.count(|op| op.is_invoke()), 3);
        assert_eq!(lo.engines().len(), 3);
        // paper: "each converted call will be given an explicit storage
        // buffer for its output"
        assert_eq!(lo.count(|op| matches!(op, Op::Buffer { .. })), 3);
    }

    #[test]
    fn lowering_is_idempotent() {
        let w = crate::relay::workloads::mlp();
        let lo = lower_default(&w.expr).unwrap();
        let lo2 = lower_default(&lo).unwrap();
        assert_eq!(lo.to_string(), lo2.to_string());
    }

    #[test]
    fn fig1_shape_conv_reification() {
        // The paper's Fig. 1: nn.conv2d reified into engine + storage.
        let w = crate::relay::workloads::convblock();
        let lo = lower(&w.expr, LowerOptions { buffers: true }).unwrap();
        let txt = lo.to_string();
        assert!(txt.contains("(conv-engine 16 16 3 8 3 3 1)"), "{txt}");
        assert!(txt.contains("(buffer sram (invoke-conv"), "{txt}");
    }

    #[test]
    fn rowwise_lowering_emits_schedule() {
        // softmax over a matrix becomes a per-row sched-loop the schedule
        // rewrites (parallelize) can immediately act on.
        let e = crate::ir::parse_expr("(softmax (input x [4 8]))").unwrap();
        let lo = lower_default(&e).unwrap();
        let txt = lo.to_string();
        assert!(txt.contains("(sched-loop"), "{txt}");
        assert!(txt.contains("(softmax-engine 8)"), "{txt}");
        assert_eq!(lo.typecheck().unwrap(), e.typecheck().unwrap());
        // and rank-1 softmax invokes directly, no schedule
        let e1 = crate::ir::parse_expr("(softmax (input x [8]))").unwrap();
        let lo1 = lower_default(&e1).unwrap();
        assert_eq!(lo1.count(|op| op.is_sched()), 0);
    }

    #[test]
    fn affine_layernorm_lowers_norm_plus_emul_add_tail() {
        let e = crate::ir::parse_expr(
            "(layernorm (input x [4 8]) (weight g [8]) (weight b [8]))",
        )
        .unwrap();
        let lo = lower_default(&e).unwrap();
        let txt = lo.to_string();
        assert!(txt.contains("(layernorm-engine 8)"), "{txt}");
        assert!(txt.contains("(emul-engine 32)"), "{txt}");
        assert!(txt.contains("(add-engine 32)"), "{txt}");
        assert!(txt.contains("(sched-loop"), "{txt}");
        assert_eq!(lo.typecheck().unwrap(), e.typecheck().unwrap());
        // Semantics: norm * gamma + beta, exactly.
        let a = eval_expr(&e, &mut Env::random_for(&e, 33)).unwrap();
        let b = eval_expr(&lo, &mut Env::random_for(&lo, 33)).unwrap();
        assert!(a.allclose(&b, 1e-5), "{:?}", a.max_abs_diff(&b));
    }

    #[test]
    fn rank3_softmax_lowers_to_nested_row_schedule() {
        // Per-head attention scores: (heads, rows, width) -> outer loop
        // over heads, inner loop over rows, one width-wide row engine.
        let e = crate::ir::parse_expr("(softmax (input s [4 6 8]))").unwrap();
        let lo = lower_default(&e).unwrap();
        let txt = lo.to_string();
        assert!(txt.contains("(softmax-engine 8)"), "{txt}");
        assert_eq!(lo.count(|op| matches!(op, crate::ir::Op::SchedLoop { .. })), 2, "{txt}");
        assert_eq!(lo.typecheck().unwrap(), e.typecheck().unwrap());
        let a = eval_expr(&e, &mut Env::random_for(&e, 34)).unwrap();
        let b = eval_expr(&lo, &mut Env::random_for(&lo, 34)).unwrap();
        assert!(a.allclose(&b, 1e-5), "{:?}", a.max_abs_diff(&b));
    }

    #[test]
    fn batch_matmul_lowers_to_batch_loop() {
        let e = crate::ir::parse_expr("(batch-matmul (input a [2 4 8]) (input b [2 8 4]))")
            .unwrap();
        let lo = lower_default(&e).unwrap();
        let txt = lo.to_string();
        assert!(txt.contains("(sched-loop"), "{txt}");
        assert!(txt.contains("(mm-engine 4 8 4)"), "{txt}");
        assert_eq!(lo.typecheck().unwrap(), e.typecheck().unwrap());
    }

    #[test]
    fn lowering_ill_typed_input_is_a_typed_error() {
        // dense with mismatched inner dims: inference fails, lower must
        // return Error::Type, not panic.
        let e = crate::ir::parse_expr("(dense (input x [1 10]) (weight w [11 4]))").unwrap();
        match lower_default(&e) {
            Err(crate::error::Error::Type(_)) => {}
            other => panic!("expected Error::Type, got {other:?}"),
        }
    }
}
