//! The PJRT engine runtime: loads the AOT-compiled Pallas engine kernels
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from the Rust hot path via the `xla` crate's PJRT CPU client.
//!
//! Python is **never** on this path: artifacts are HLO text on disk; the
//! runtime compiles each one once (lazily, cached) and then serves engine
//! invocations as pure in-process calls.
//!
//! Two entry points:
//!
//! * [`EngineRuntime`] — name-indexed engine executor (compile cache,
//!   literal marshalling);
//! * [`PjrtBackend`] — adapts the runtime to the evaluator's
//!   [`EngineBackend`] trait, so *any extracted design* can run its
//!   invocations on real compiled kernels while the Rust side plays the
//!   software schedule (slices, loops, buffers) — the hardware–software
//!   split, executed literally.
//!
//! The `xla` bindings are not vendored in this build environment, so the
//! real executor is gated behind **both** the `pjrt` and `xla-runtime`
//! cargo features: `pjrt` alone selects all the PJRT wiring with a **stub**
//! executor of identical API whose constructor returns
//! [`Error::Unsupported`] (this is the configuration the CI feature matrix
//! builds), and `xla-runtime` — which additionally requires vendoring the
//! `xla` crate, see the manifest — swaps in the real implementation. Every
//! consumer (the CLI `run` command, the e2e example, the runtime bench,
//! `Backend::Pjrt` session queries) degrades to a clean typed error or a
//! skip instead of failing to link.

use crate::error::Error;
use crate::ir::{Op, Shape};
use crate::tensor::EngineBackend;
use std::path::PathBuf;

#[cfg(all(feature = "pjrt", feature = "xla-runtime"))]
mod pjrt_impl;
#[cfg(all(feature = "pjrt", feature = "xla-runtime"))]
pub use pjrt_impl::EngineRuntime;

#[cfg(not(all(feature = "pjrt", feature = "xla-runtime")))]
mod stub_impl;
#[cfg(not(all(feature = "pjrt", feature = "xla-runtime")))]
pub use stub_impl::EngineRuntime;

/// Locate the artifacts directory: `$HWSPLIT_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("HWSPLIT_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The artifact base name for an engine declaration (the naming contract
/// with `python/compile/aot.py`). **Every** Engine-class op maps to
/// `Some(..)` — `tests/registry.rs` pins this, so a new engine can't ship
/// silently unrunnable on PJRT. Non-engine ops return `None`; whether a
/// *specific instantiation* is runnable still depends on the artifact
/// library (`extract_covered` steers around missing instantiations and
/// `PjrtBackend` falls back to the oracle, or errors in strict mode).
pub fn artifact_name(op: &Op) -> Option<String> {
    Some(match *op {
        Op::MmEngine { m, k, n } => format!("mm_{m}x{k}x{n}"),
        Op::MmReluEngine { m, k, n } => format!("mmrelu_{m}x{k}x{n}"),
        Op::ReluEngine { w } => format!("relu_{w}"),
        Op::AddEngine { w } => format!("add_{w}"),
        Op::EmulEngine { w } => format!("emul_{w}"),
        Op::GeluEngine { w } => format!("gelu_{w}"),
        Op::SoftmaxEngine { w } => format!("softmax_{w}"),
        Op::LayerNormEngine { w } => format!("layernorm_{w}"),
        Op::ConvEngine { oh, ow, c, k, kh, kw, stride } => {
            format!("conv_{oh}x{ow}x{c}x{k}x{kh}x{kw}x{stride}")
        }
        Op::PoolEngine { oh, ow, c, kh, kw, stride } => {
            format!("pool_{oh}x{ow}x{c}x{kh}x{kw}x{stride}")
        }
        Op::DwConvEngine { oh, ow, c, kh, kw, stride } => {
            format!("dwconv_{oh}x{ow}x{c}x{kh}x{kw}x{stride}")
        }
        _ => return None,
    })
}

/// Output shape of one engine invocation (from the registry's engine spec,
/// which mirrors `ir::shape::infer`).
pub fn engine_out_shape(engine: &Op) -> Shape {
    match engine.spec().engine {
        Some(e) => (e.out_shape)(engine),
        None => panic!("not an engine: {engine}"),
    }
}

/// Build the typed error every runtime failure reports.
#[allow(dead_code)] // only used by the real impl under --features pjrt
pub(crate) fn runtime_err(detail: impl Into<String>) -> Error {
    Error::Backend { backend: "pjrt", detail: detail.into() }
}

/// Extract a design whose engines are all covered by the artifact library:
/// the usual greedy cost plus a prohibitive penalty on uncovered engine
/// declarations. With `prefer_small` the cost leans toward smaller engines
/// and deeper schedules (a genuinely *rewritten* design), otherwise toward
/// latency. Returns `None` if no fully-covered design exists in the
/// e-graph.
pub fn extract_covered(
    eg: &crate::egraph::EGraph,
    root: crate::egraph::Id,
    rt: &EngineRuntime,
    prefer_small: bool,
) -> Option<crate::ir::RecExpr> {
    let ex = crate::extract::Extractor::new(eg, |eg2, node, child| {
        let base = if prefer_small {
            crate::extract::area_cost(eg2, node, child)
        } else {
            crate::extract::latency_cost(eg2, node, child)
        };
        if node.op.is_engine() && !rt.has_engine(&node.op) {
            base + 1e12
        } else {
            base
        }
    });
    let d = ex.extract(eg, root);
    if d.engines().iter().all(|e| rt.has_engine(e)) {
        Some(d)
    } else {
        None
    }
}

/// [`EngineBackend`] adapter: designs evaluate with their invocations on
/// PJRT. With `fallback_to_oracle`, engines missing from the manifest run
/// on the Rust oracle instead (useful for exploring designs whose engine
/// library has not been AOT-built yet); in strict mode they error.
pub struct PjrtBackend {
    pub runtime: EngineRuntime,
    pub fallback_to_oracle: bool,
    /// Invocations served by PJRT vs the oracle (metrics).
    pub pjrt_calls: u64,
    pub oracle_calls: u64,
}

impl PjrtBackend {
    pub fn new(runtime: EngineRuntime) -> Self {
        PjrtBackend { runtime, fallback_to_oracle: false, pjrt_calls: 0, oracle_calls: 0 }
    }

    pub fn with_fallback(mut self) -> Self {
        self.fallback_to_oracle = true;
        self
    }
}

impl EngineBackend for PjrtBackend {
    fn invoke(
        &mut self,
        engine: &Op,
        kind: crate::ir::OpKind,
        args: &[crate::tensor::Tensor],
    ) -> Result<crate::tensor::Tensor, crate::tensor::EvalError> {
        if self.runtime.has_engine(engine) {
            self.pjrt_calls += 1;
            self.runtime
                .execute_engine(engine, args)
                .map_err(|e| crate::tensor::EvalError::Backend(e.to_string()))
        } else if self.fallback_to_oracle {
            self.oracle_calls += 1;
            crate::tensor::Oracle.invoke(engine, kind, args)
        } else {
            Err(crate::tensor::EvalError::Backend(format!(
                "no artifact for engine {engine} (run `make artifacts` or extend aot.py's \
                 DEFAULT_SPECS)"
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;

    /// Artifacts are a build product; tests that need them skip when absent
    /// (and always skip in stub builds, where `new` returns a typed error).
    #[allow(dead_code)] // only exercised by the pjrt-gated tests below
    fn runtime() -> Option<EngineRuntime> {
        EngineRuntime::new(default_artifact_dir()).ok()
    }

    #[test]
    fn artifact_names_match_contract() {
        assert_eq!(
            artifact_name(&Op::MmEngine { m: 1, k: 784, n: 128 }).unwrap(),
            "mm_1x784x128"
        );
        assert_eq!(artifact_name(&Op::ReluEngine { w: 128 }).unwrap(), "relu_128");
        assert_eq!(
            artifact_name(&Op::ConvEngine {
                oh: 28,
                ow: 28,
                c: 1,
                k: 8,
                kh: 5,
                kw: 5,
                stride: 1
            })
            .unwrap(),
            "conv_28x28x1x8x5x5x1"
        );
        assert_eq!(artifact_name(&Op::Relu), None);
        // Row/vector engines and depthwise conv have kernel contracts too.
        assert_eq!(artifact_name(&Op::GeluEngine { w: 8 }).unwrap(), "gelu_8");
        assert_eq!(artifact_name(&Op::EmulEngine { w: 16 }).unwrap(), "emul_16");
        assert_eq!(artifact_name(&Op::SoftmaxEngine { w: 16 }).unwrap(), "softmax_16");
        assert_eq!(artifact_name(&Op::LayerNormEngine { w: 128 }).unwrap(), "layernorm_128");
        assert_eq!(
            artifact_name(&Op::PoolEngine { oh: 14, ow: 14, c: 8, kh: 2, kw: 4, stride: 2 })
                .unwrap(),
            "pool_14x14x8x2x4x2"
        );
        assert_eq!(
            artifact_name(&Op::DwConvEngine { oh: 8, ow: 8, c: 16, kh: 3, kw: 3, stride: 2 })
                .unwrap(),
            "dwconv_8x8x16x3x3x2"
        );
    }

    /// Every Engine-class op kind has an artifact-name contract: the
    /// registry exemplar of each engine maps to `Some(..)`. There are no
    /// exemptions — an engine that can't name its artifact can't run on
    /// PJRT, silently, which is exactly the bug class this pins away.
    #[test]
    fn every_engine_kind_has_an_artifact_name() {
        use crate::ir::spec::{self, OpClass};
        for s in spec::all_specs() {
            if s.class != OpClass::Engine {
                continue;
            }
            let e = crate::ir::parse_expr(s.exemplar).unwrap();
            let op = &e.node(e.root()).op;
            assert!(
                artifact_name(op).is_some(),
                "{:?}: engine has no artifact_name contract",
                s.kind
            );
        }
    }

    #[test]
    fn engine_out_shapes() {
        assert_eq!(
            engine_out_shape(&Op::MmEngine { m: 2, k: 3, n: 4 }),
            Shape::new(&[2, 4])
        );
        assert_eq!(
            engine_out_shape(&Op::PoolEngine { oh: 5, ow: 5, c: 16, kh: 2, kw: 2, stride: 2 }),
            Shape::new(&[16, 5, 5])
        );
    }

    #[cfg(not(all(feature = "pjrt", feature = "xla-runtime")))]
    #[test]
    fn stub_runtime_reports_typed_unsupported_error() {
        let err = EngineRuntime::new(default_artifact_dir()).unwrap_err();
        assert!(matches!(err, Error::Unsupported(_)), "{err}");
        assert!(err.to_string().contains("pjrt"));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_relu_matches_oracle() {
        use crate::tensor::Tensor;
        let Some(mut rt) = runtime() else { return };
        let x = Tensor::random(Shape::new(&[128]), 7);
        let engine = Op::ReluEngine { w: 128 };
        if !rt.has_engine(&engine) {
            return;
        }
        let got = rt.execute_engine(&engine, &[x.clone()]).unwrap();
        assert!(got.allclose(&x.relu(), 1e-6));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn design_runs_on_pjrt_and_matches_oracle_eval() {
        use crate::ir::parse_expr;
        use crate::tensor::{eval_expr, eval_expr_backend, Env};
        let Some(rt) = runtime() else { return };
        // A split design: loop over relu-64 (both engines in the manifest).
        let src = "(sched-loop i0 0 2 (invoke-relu (relu-engine 64) \
                    (slice 0 64 (imul (lvar i0) 64) (input x [128]))))";
        let e = parse_expr(src).unwrap();
        let mut backend = PjrtBackend::new(rt);
        if !backend.runtime.has_engine(&Op::ReluEngine { w: 64 }) {
            return;
        }
        let mut env = Env::random_for(&e, 11);
        let got = eval_expr_backend(&e, &mut env.clone(), &mut backend).unwrap();
        let want = eval_expr(&e, &mut env).unwrap();
        assert!(got.allclose(&want, 1e-5));
        assert_eq!(backend.pjrt_calls, 2);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_mm_matches_oracle() {
        use crate::tensor::Tensor;
        let Some(mut rt) = runtime() else { return };
        let engine = Op::MmEngine { m: 1, k: 128, n: 64 };
        if !rt.has_engine(&engine) {
            return;
        }
        let a = Tensor::random(Shape::new(&[1, 128]), 1);
        let b = Tensor::random(Shape::new(&[128, 64]), 2);
        let got = rt.execute_engine(&engine, &[a.clone(), b.clone()]).unwrap();
        assert!(got.allclose(&a.matmul(&b), 1e-4), "{:?}", got.max_abs_diff(&a.matmul(&b)));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn strict_mode_errors_on_missing_engine() {
        use crate::ir::parse_expr;
        use crate::tensor::{eval_expr_backend, Env};
        let Some(rt) = runtime() else { return };
        let e = parse_expr("(invoke-relu (relu-engine 77) (input x [77]))").unwrap();
        let mut backend = PjrtBackend::new(rt);
        let mut env = Env::random_for(&e, 1);
        let err = eval_expr_backend(&e, &mut env, &mut backend);
        assert!(err.is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn fallback_mode_uses_oracle() {
        use crate::ir::parse_expr;
        use crate::tensor::{eval_expr_backend, Env};
        let Some(rt) = runtime() else { return };
        let e = parse_expr("(invoke-relu (relu-engine 77) (input x [77]))").unwrap();
        let mut backend = PjrtBackend::new(rt).with_fallback();
        let mut env = Env::random_for(&e, 1);
        let out = eval_expr_backend(&e, &mut env, &mut backend).unwrap();
        assert_eq!(out.shape, Shape::new(&[77]));
        assert_eq!(backend.oracle_calls, 1);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn executable_cache_compiles_once() {
        use crate::tensor::Tensor;
        let Some(mut rt) = runtime() else { return };
        let engine = Op::ReluEngine { w: 128 };
        if !rt.has_engine(&engine) {
            return;
        }
        let x = Tensor::random(Shape::new(&[128]), 3);
        rt.execute_engine(&engine, &[x.clone()]).unwrap();
        rt.execute_engine(&engine, &[x]).unwrap();
        assert_eq!(rt.compiled(), 1);
        assert_eq!(rt.calls["relu_128"], 2);
    }
}
