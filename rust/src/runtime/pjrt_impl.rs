//! The real PJRT-backed engine runtime (requires the `pjrt` feature and an
//! `xla` dependency — see the crate manifest). Loads, compiles (once) and
//! executes AOT engine artifacts.

use super::{artifact_name, engine_out_shape, runtime_err};
use crate::error::Error;
use crate::ir::{Op, Shape};
use crate::tensor::Tensor;
use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};

/// Loads, compiles (once) and executes AOT engine artifacts.
pub struct EngineRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    available: HashSet<String>,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions served per artifact (metrics).
    pub calls: HashMap<String, u64>,
}

impl EngineRuntime {
    /// Open the runtime over an artifact directory (reads `manifest.txt`).
    pub fn new(dir: impl AsRef<Path>) -> Result<Self, Error> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.txt");
        let listing = std::fs::read_to_string(&manifest).map_err(|e| {
            runtime_err(format!("reading {manifest:?} — run `make artifacts` first: {e}"))
        })?;
        let available: HashSet<String> =
            listing.lines().map(|l| l.trim().to_string()).filter(|l| !l.is_empty()).collect();
        let client =
            xla::PjRtClient::cpu().map_err(|e| runtime_err(format!("PJRT cpu client: {e:?}")))?;
        Ok(EngineRuntime { client, dir, available, cache: HashMap::new(), calls: HashMap::new() })
    }

    /// Open over the default directory.
    pub fn open_default() -> Result<Self, Error> {
        Self::new(super::default_artifact_dir())
    }

    /// Artifact names listed in the manifest.
    pub fn available(&self) -> &HashSet<String> {
        &self.available
    }

    /// True if the engine declaration has a compiled artifact available.
    pub fn has_engine(&self, op: &Op) -> bool {
        artifact_name(op).is_some_and(|n| self.available.contains(&n))
    }

    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable, Error> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| runtime_err("artifact path not utf-8"))?,
            )
            .map_err(|e| runtime_err(format!("loading {path:?}: {e:?}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| runtime_err(format!("compiling {name}: {e:?}")))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Number of artifacts compiled so far (cache size).
    pub fn compiled(&self) -> usize {
        self.cache.len()
    }

    /// Execute artifact `name` on `inputs`, expecting `out_shape` back.
    pub fn execute_named(
        &mut self,
        name: &str,
        inputs: &[Tensor],
        out_shape: &Shape,
    ) -> Result<Tensor, Error> {
        *self.calls.entry(name.to_string()).or_insert(0) += 1;
        let exe = self.executable(name)?;
        let mut literals = Vec::with_capacity(inputs.len());
        for t in inputs {
            let dims: Vec<i64> = t.shape.0.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| runtime_err(format!("reshape literal: {e:?}")))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| runtime_err(format!("executing {name}: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| runtime_err(format!("fetching result of {name}: {e:?}")))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| runtime_err(format!("untuple {name}: {e:?}")))?;
        let data = out
            .to_vec::<f32>()
            .map_err(|e| runtime_err(format!("download {name}: {e:?}")))?;
        if data.len() != out_shape.numel() {
            return Err(runtime_err(format!(
                "{name}: output has {} elems, expected {} ({out_shape})",
                data.len(),
                out_shape.numel()
            )));
        }
        Ok(Tensor::new(out_shape.clone(), data))
    }

    /// Execute an engine invocation.
    pub fn execute_engine(&mut self, engine: &Op, inputs: &[Tensor]) -> Result<Tensor, Error> {
        let name =
            artifact_name(engine).ok_or_else(|| runtime_err(format!("not an engine: {engine}")))?;
        let out_shape = engine_out_shape(engine);
        self.execute_named(&name, inputs, &out_shape)
    }
}
