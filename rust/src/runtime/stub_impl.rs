//! Stub engine runtime for builds without the full `pjrt` + `xla-runtime`
//! feature pair: identical API, but construction fails with a typed
//! [`Error::Unsupported`] so every consumer can detect the missing
//! capability and skip or report cleanly.

use super::artifact_name;
use crate::error::Error;
use crate::ir::{Op, Shape};
use crate::tensor::Tensor;
use std::collections::{HashMap, HashSet};
use std::path::Path;

const UNSUPPORTED: &str = "PJRT engine runtime is not compiled into this build \
     (rebuild with `--features pjrt,xla-runtime` and a vendored `xla` dependency)";

/// API-compatible stand-in for the PJRT-backed [`EngineRuntime`]. Never
/// constructible: [`EngineRuntime::new`] always returns
/// [`Error::Unsupported`].
pub struct EngineRuntime {
    available: HashSet<String>,
    /// Executions served per artifact (metrics).
    pub calls: HashMap<String, u64>,
}

impl EngineRuntime {
    /// Always fails in stub builds.
    pub fn new(_dir: impl AsRef<Path>) -> Result<Self, Error> {
        Err(Error::Unsupported(UNSUPPORTED.into()))
    }

    /// Always fails in stub builds.
    pub fn open_default() -> Result<Self, Error> {
        Self::new(super::default_artifact_dir())
    }

    /// Artifact names listed in the manifest.
    pub fn available(&self) -> &HashSet<String> {
        &self.available
    }

    /// True if the engine declaration has a compiled artifact available.
    pub fn has_engine(&self, op: &Op) -> bool {
        artifact_name(op).is_some_and(|n| self.available.contains(&n))
    }

    /// Number of artifacts compiled so far (cache size).
    pub fn compiled(&self) -> usize {
        0
    }

    /// Unreachable in practice (no instance can exist), kept for API parity.
    pub fn execute_named(
        &mut self,
        _name: &str,
        _inputs: &[Tensor],
        _out_shape: &Shape,
    ) -> Result<Tensor, Error> {
        Err(Error::Unsupported(UNSUPPORTED.into()))
    }

    /// Unreachable in practice (no instance can exist), kept for API parity.
    pub fn execute_engine(&mut self, _engine: &Op, _inputs: &[Tensor]) -> Result<Tensor, Error> {
        Err(Error::Unsupported(UNSUPPORTED.into()))
    }
}
