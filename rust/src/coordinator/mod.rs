//! Compatibility shim over the [`crate::session`] subsystem.
//!
//! The original one-shot exploration pipeline lived here: every call to
//! [`explore`] re-lowered the workload, re-enumerated the e-graph, and
//! evaluated with a hard-wired analytic-model+simulator pair. That shape is
//! exactly what the paper argues *against* paying repeatedly, so the crate
//! now fronts a reusable [`Session`](crate::session::Session) — enumerate
//! once, answer many queries — with pluggable evaluation
//! [`Backend`](crate::session::Backend)s.
//!
//! Everything here is kept so old callers keep compiling: [`explore`] is a
//! deprecated one-shot wrapper (build session → one `Sim` query → dismantle
//! into the old [`Exploration`] struct), and the config/result types map
//! 1:1 onto their session equivalents.

use crate::cost::{Baseline, CostParams};
use crate::egraph::{EGraph, Id, RunnerLimits, RunnerReport};
use crate::extract::DesignPoint;
use crate::ir::RecExpr;
use crate::relay::Workload;
use crate::session::{Backend, Query, Session};
use crate::sim::SimReport;

// Moved: `RuleSet` now lives with the rewrite library; `parallel_map` with
// the session worker pool. Re-exported so existing imports keep working.
pub use crate::rewrites::RuleSet;
pub use crate::session::parallel_map;

/// Exploration configuration (the one-shot equivalent of a
/// [`Session`] + [`Query`] pair).
#[derive(Debug, Clone)]
pub struct ExploreConfig {
    pub iters: usize,
    pub samples: usize,
    pub workers: usize,
    pub rules: RuleSet,
    pub limits: RunnerLimits,
    pub params: CostParams,
}

impl Default for ExploreConfig {
    fn default() -> Self {
        ExploreConfig {
            iters: 8,
            samples: 64,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            rules: RuleSet::Paper,
            limits: RunnerLimits::default(),
            params: CostParams::default(),
        }
    }
}

/// One evaluated design point: analytic cost + simulator report.
#[derive(Debug, Clone)]
pub struct EvaluatedDesign {
    pub point: DesignPoint,
    pub sim: SimReport,
}

/// The result of one exploration.
#[derive(Debug)]
pub struct Exploration {
    pub workload: String,
    pub lowered: RecExpr,
    pub report: RunnerReport,
    pub egraph: EGraph,
    pub root: Id,
    pub designs: Vec<EvaluatedDesign>,
    pub frontier: Vec<DesignPoint>,
    pub baseline: Baseline,
}

/// Run the full pipeline for one workload, once.
///
/// Deprecated: every call pays lowering + enumeration again. Build a
/// [`Session`] and issue [`Query`]s instead — the e-graph is enumerated
/// once and shared across queries.
#[deprecated(since = "0.2.0", note = "use session::Session + Query (enumerate once, query many)")]
pub fn explore(workload: &Workload, cfg: &ExploreConfig) -> Exploration {
    let mut session = Session::builder()
        .workload(workload.clone())
        .rules(cfg.rules)
        .iters(cfg.iters)
        .workers(cfg.workers)
        .limits(cfg.limits.clone())
        .build()
        .unwrap_or_else(|e| panic!("explore({}): {e}", workload.name));
    let ev = session
        .query(
            &Query::new()
                .backend(Backend::Sim)
                .samples(cfg.samples)
                .params(cfg.params.clone()),
        )
        .unwrap_or_else(|e| panic!("explore({}): {e}", workload.name));
    let (lowered, en) = session.into_parts().expect("session was enumerated by the query");
    Exploration {
        workload: workload.name.to_string(),
        lowered,
        report: en.report,
        egraph: en.egraph,
        root: en.root,
        designs: ev
            .designs
            .into_iter()
            .map(|d| EvaluatedDesign {
                sim: d.sim.expect("Sim backend reports for every design"),
                point: d.point,
            })
            .collect(),
        frontier: ev.frontier,
        baseline: ev.baseline,
    }
}

impl Exploration {
    /// Experiment E3 summary: does the enumerated frontier dominate the
    /// baseline point, and from which side?
    pub fn frontier_vs_baseline(&self) -> String {
        crate::session::frontier_vs_baseline_summary(&self.frontier, &self.baseline.cost)
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::relay::workloads;
    use crate::tensor::{eval_expr, Env};

    fn small_cfg() -> ExploreConfig {
        ExploreConfig {
            iters: 4,
            samples: 12,
            workers: 4,
            rules: RuleSet::Paper,
            limits: RunnerLimits { max_nodes: 30_000, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(8, (0..100).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    /// The deprecated one-shot shim must behave exactly like the old
    /// pipeline: designs + frontier + baseline, all semantically the
    /// workload.
    #[test]
    fn explore_ffn_end_to_end() {
        let w = workloads::ffn_block();
        let ex = explore(&w, &small_cfg());
        assert!(ex.report.designs_lower_bound > 1.0, "enumeration found nothing");
        assert!(ex.designs.len() >= 3, "need diverse designs");
        assert!(!ex.frontier.is_empty());
        // Every sampled design is semantically the workload.
        let want = eval_expr(&w.expr, &mut Env::random_for(&w.expr, 5)).unwrap();
        for d in ex.designs.iter().take(6) {
            let got = eval_expr(&d.point.expr, &mut Env::random_for(&d.point.expr, 5)).unwrap();
            assert!(want.allclose(&got, 1e-4), "{} diverged", d.point.origin);
        }
    }

    #[test]
    fn explore_relu128_frontier_beats_baseline_somewhere() {
        let w = workloads::relu128();
        let ex = explore(&w, &small_cfg());
        let b = &ex.baseline.cost;
        // The enumerated set must contain a smaller-area design than the
        // baseline (deep loop over a narrow engine).
        assert!(
            ex.designs.iter().any(|d| d.point.cost.area < b.area),
            "no smaller-than-baseline design found: {}",
            ex.frontier_vs_baseline()
        );
    }
}
