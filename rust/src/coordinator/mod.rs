//! The design-space-exploration coordinator: Rust owns the whole loop.
//!
//! One exploration = lower the workload → enumerate with rewrites (the
//! search phase is fanned out across threads per rule) → sample candidate
//! designs → evaluate each with the analytic model *and* the simulator on
//! a worker pool → reduce to the Pareto frontier and compare against the
//! one-engine-per-kernel-type baseline.
//!
//! No async runtime is required (and none is in the vendored dep set):
//! exploration is a batch pipeline, so scoped OS threads + channels are the
//! right tool. The e-graph is read-shared (`&EGraph`) during parallel
//! search/extraction and mutated only in the single-threaded apply phase —
//! the same discipline the rewrite `Runner` uses.

use crate::cost::{analyze, baseline, Baseline, CostParams};
use crate::egraph::{EGraph, Id, Rewrite, Runner, RunnerLimits, RunnerReport};
use crate::extract::{pareto_frontier, sample_design, DesignPoint, Extractor};
use crate::ir::RecExpr;
use crate::lower::lower_default;
use crate::relay::Workload;
use crate::rewrites;
use crate::sim::{simulate, SimConfig, SimReport};

/// Which rewrite set to enumerate with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuleSet {
    /// Only paper Fig. 2's two rewrites (ReLU split + parallelize).
    Fig2,
    /// Everything §2 describes.
    Paper,
    /// Paper + extensions (fusion, loop reorder, double buffering).
    All,
}

impl RuleSet {
    pub fn rules(self) -> Vec<Rewrite> {
        match self {
            RuleSet::Fig2 => rewrites::fig2_rules(),
            RuleSet::Paper => rewrites::paper_rules(),
            RuleSet::All => rewrites::all_rules(),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "fig2" => RuleSet::Fig2,
            "paper" => RuleSet::Paper,
            "all" => RuleSet::All,
            _ => return None,
        })
    }
}

/// Exploration configuration.
#[derive(Debug, Clone)]
pub struct ExploreConfig {
    pub iters: usize,
    pub samples: usize,
    pub workers: usize,
    pub rules: RuleSet,
    pub limits: RunnerLimits,
    pub params: CostParams,
}

impl Default for ExploreConfig {
    fn default() -> Self {
        ExploreConfig {
            iters: 8,
            samples: 64,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            rules: RuleSet::Paper,
            limits: RunnerLimits::default(),
            params: CostParams::default(),
        }
    }
}

/// One evaluated design point: analytic cost + simulator report.
#[derive(Debug, Clone)]
pub struct EvaluatedDesign {
    pub point: DesignPoint,
    pub sim: SimReport,
}

/// The result of one exploration.
#[derive(Debug)]
pub struct Exploration {
    pub workload: String,
    pub lowered: RecExpr,
    pub report: RunnerReport,
    pub egraph: EGraph,
    pub root: Id,
    pub designs: Vec<EvaluatedDesign>,
    pub frontier: Vec<DesignPoint>,
    pub baseline: Baseline,
}

fn vlog(phase: &str, t0: std::time::Instant) {
    if std::env::var_os("HWSPLIT_VERBOSE").is_some() {
        eprintln!("[explore] {phase}: {:.2?}", t0.elapsed());
    }
}

/// Run the full pipeline for one workload.
pub fn explore(workload: &Workload, cfg: &ExploreConfig) -> Exploration {
    // 1. Reify (paper Fig. 1).
    let lowered = lower_default(&workload.expr);

    // 2. Enumerate (paper Fig. 2 & §2).
    let t0 = std::time::Instant::now();
    let mut runner =
        Runner::new(lowered.clone(), cfg.rules.rules()).with_limits(cfg.limits.clone());
    let report = runner.run(cfg.iters);
    let (egraph, root) = (runner.egraph, runner.root);
    vlog("enumerate", t0);

    // 3. Sample candidate designs (greedy endpoints + randomized costs),
    //    extracting in parallel — extraction only reads the e-graph.
    let t0 = std::time::Instant::now();
    let mut exprs: Vec<(String, RecExpr)> = Vec::new();
    exprs.push((
        "greedy-latency".into(),
        Extractor::new(&egraph, crate::extract::latency_cost).extract(&egraph, root),
    ));
    exprs.push((
        "greedy-area".into(),
        Extractor::new(&egraph, crate::extract::area_cost).extract(&egraph, root),
    ));
    vlog("greedy extraction", t0);
    let t0 = std::time::Instant::now();
    let sampled: Vec<(String, RecExpr)> = parallel_map(
        cfg.workers,
        (0..cfg.samples).collect(),
        |seed: &usize| (format!("sample-{seed}"), sample_design(&egraph, root, *seed as u64)),
    );
    exprs.extend(sampled);
    vlog("sampling", t0);
    // Deduplicate structurally identical designs.
    let t0 = std::time::Instant::now();
    let mut seen = std::collections::HashSet::new();
    exprs.retain(|(_, e)| seen.insert(e.to_string()));
    vlog("dedup", t0);

    // 4. Evaluate each design (analytic + simulator) on the worker pool.
    let t0 = std::time::Instant::now();
    let params = cfg.params.clone();
    let designs: Vec<EvaluatedDesign> = parallel_map(cfg.workers, exprs, |(origin, expr)| {
        let (cost, stats) = analyze(expr, &params);
        let sim = simulate(expr, &SimConfig { params: params.clone() });
        EvaluatedDesign {
            point: DesignPoint { expr: expr.clone(), cost, stats, origin: origin.clone() },
            sim,
        }
    });
    vlog("evaluate", t0);

    // 5. Reduce.
    let frontier = pareto_frontier(&designs.iter().map(|d| d.point.clone()).collect::<Vec<_>>());
    let base = baseline(&lowered, &cfg.params);

    Exploration {
        workload: workload.name.to_string(),
        lowered,
        report,
        egraph,
        root,
        designs,
        frontier,
        baseline: base,
    }
}

/// Scoped-thread parallel map preserving input order.
pub fn parallel_map<T: Send + Sync, R: Send>(
    workers: usize,
    items: Vec<T>,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    if items.is_empty() {
        return Vec::new();
    }
    let workers = workers.clamp(1, items.len());
    let results: Vec<std::sync::Mutex<Option<R>>> =
        items.iter().map(|_| std::sync::Mutex::new(None)).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results.into_iter().map(|m| m.into_inner().unwrap().unwrap()).collect()
}

impl Exploration {
    /// Experiment E3 summary: does the enumerated frontier dominate the
    /// baseline point, and from which side?
    pub fn frontier_vs_baseline(&self) -> String {
        let b = &self.baseline.cost;
        let dominating =
            self.frontier.iter().filter(|p| p.cost.dominates(b)).count();
        let smaller = self
            .frontier
            .iter()
            .filter(|p| p.cost.area < b.area)
            .count();
        let faster = self
            .frontier
            .iter()
            .filter(|p| p.cost.latency < b.latency)
            .count();
        format!(
            "baseline(area={:.1}, lat={:.1}) | frontier: {} points, {} dominate baseline, \
             {} smaller-area, {} lower-latency",
            b.area,
            b.latency,
            self.frontier.len(),
            dominating,
            smaller,
            faster
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::workloads;
    use crate::tensor::{eval_expr, Env};

    fn small_cfg() -> ExploreConfig {
        ExploreConfig {
            iters: 4,
            samples: 12,
            workers: 4,
            rules: RuleSet::Paper,
            limits: RunnerLimits { max_nodes: 30_000, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(8, (0..100).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn explore_ffn_end_to_end() {
        let w = workloads::ffn_block();
        let ex = explore(&w, &small_cfg());
        assert!(ex.report.designs_lower_bound > 1.0, "enumeration found nothing");
        assert!(ex.designs.len() >= 3, "need diverse designs");
        assert!(!ex.frontier.is_empty());
        // Every sampled design is semantically the workload.
        let want = eval_expr(&w.expr, &mut Env::random_for(&w.expr, 5)).unwrap();
        for d in ex.designs.iter().take(6) {
            let got = eval_expr(&d.point.expr, &mut Env::random_for(&d.point.expr, 5)).unwrap();
            assert!(want.allclose(&got, 1e-4), "{} diverged", d.point.origin);
        }
    }

    #[test]
    fn explore_relu128_frontier_beats_baseline_somewhere() {
        let w = workloads::relu128();
        let ex = explore(&w, &small_cfg());
        let b = &ex.baseline.cost;
        // The enumerated set must contain a smaller-area design than the
        // baseline (deep loop over a narrow engine).
        assert!(
            ex.designs.iter().any(|d| d.point.cost.area < b.area),
            "no smaller-than-baseline design found: {}",
            ex.frontier_vs_baseline()
        );
    }
}
