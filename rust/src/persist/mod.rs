//! Snapshot persistence: a saturated e-graph on disk, ready to serve.
//!
//! The paper's economics are "enumerate once, query many" — but without
//! persistence the amortization dies with the process: every CLI run pays
//! saturation again. This module snapshots a [`Session`]'s enumerated
//! state — the [`EGraph`] (nodes, union-find, class data, **epoch**), the
//! runner report, and every solved [`CostTable`] in the extraction memo —
//! into a versioned, zero-dependency binary format, so a fresh process can
//! load it and answer queries **bit-identically** with zero re-saturation
//! and zero fixpoint rebuilds ([`Session::load_snapshot`] restores the
//! graph epoch verbatim, so the epoch-keyed [`ExtractCache`] stays warm).
//!
//! Snapshots are the serving daemon's unit of deployment: `hwsplit serve`
//! registers one workload per file (via [`peek_header`], no payload
//! decode), lazily loads sessions on first query, and **hot-reloads** a
//! re-written file in place — [`crate::serve::SessionStore::reload`]
//! re-decodes resident snapshots and atomically swaps them without
//! dropping in-flight connections, so a fleet can roll new enumerations
//! with zero downtime (see `docs/serving.md`).
//!
//! ## File layout
//!
//! ```text
//! magic               8  b"HWSPLIT\0"
//! format version      u32
//! workload name       str         (cheap to peek — serving discovers the
//! workload fingerprint u64         workload per file without decoding the
//! rule-set hash       u64          payload)
//! payload length      u64
//! payload checksum    u64         (FxHash over the payload bytes)
//! payload             …           lowered text, rule names, e-graph raw
//!                                 parts, root, report summary, cost tables
//! ```
//!
//! Version 2 (current full format) encodes each e-node body exactly once,
//! in the arena section; class member lists and parent back-edges are
//! `u32` arena indices. Version 1 files (which re-encoded every class
//! member in full) are still readable — see [`FORMAT_VERSION`].
//!
//! Version 3 files are **deltas**: the header gains a base-fingerprint
//! `u64` (FxHash of the entire base file's bytes) between the rule-set
//! hash and the payload length, and the payload stores the base's file
//! name plus only the union-find entries, arena nodes, class slots, and
//! cost-table rows that differ from the base — so re-persisting after an
//! extended-rule-set re-saturation writes KBs instead of re-encoding the
//! world. [`read_snapshot`] resolves the base as a **sibling file** of
//! the delta and validates its fingerprint before overlaying; chains are
//! exactly one level deep (a delta's base must be a full snapshot). Full
//! snapshots keep writing version 2 — see [`DELTA_FORMAT_VERSION`].
//!
//! Version 4 files are full snapshots of **imported** workloads (ONNX
//! models registered at runtime, not in the static library): the layout is
//! exactly version 2 except the payload begins with the printed workload
//! source and its description, so a fresh process — which has no
//! constructor for the workload — can re-register it from the file alone.
//! Static-library workloads keep writing version 2; the embedded source is
//! fingerprint-checked against the header on load. See
//! [`EMBED_FORMAT_VERSION`].
//!
//! All integers are little-endian. Strings are `u32` length + UTF-8 bytes.
//! Operators are encoded **through the registry** ([`crate::ir::spec`]):
//! spec name + attribute values per the spec's schema — no per-op code, so
//! new registry entries persist for free. Symbols are stored as strings
//! and re-interned on load (intern ids are process-local).
//!
//! Every malformed input surfaces as a typed error instead of a panic:
//! [`Error::SnapshotCorrupt`] (bad magic, truncation, checksum or payload
//! decode failure), [`Error::SnapshotVersion`] (readable header, newer
//! format), [`Error::Io`] (filesystem).
//!
//! [`Session`]: crate::session::Session
//! [`Session::load_snapshot`]: crate::session::Session::load_snapshot

use crate::egraph::graph::EGraphParts;
use crate::egraph::{EClass, EGraph, Id, NodeId, RunnerReport, StopReason};
use crate::error::{Error, Result};
use crate::extract::{CacheExport, CostKind, CostTable, ExtractCache};
use crate::fx::{FxHashMap, FxHasher};
use crate::ir::spec::{AttrKind, AttrVal};
use crate::ir::{parse_expr, spec, BufKind, EngineSig, Node, Op, RecExpr, Shape, Symbol, Ty};
use std::hash::Hasher as _;
use std::io::{Read as _, Write as _};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

/// First 8 bytes of every snapshot file.
pub const MAGIC: &[u8; 8] = b"HWSPLIT\0";

/// The snapshot format this build writes. Version 2 is arena-aware: node
/// bodies are encoded once (the arena section) and classes reference them
/// by `u32` arena index, instead of re-encoding every class member in full
/// as version 1 did. Cost-table cache entries also carry a per-entry epoch
/// (v1 stored one cache-wide epoch). Version 1 files remain readable —
/// the decoder maps their duplicated class nodes back onto arena slots by
/// content.
pub const FORMAT_VERSION: u32 = 2;

/// The **delta** snapshot format: version 3 files encode a diff against a
/// full (v1/v2) base file, identified by an FxHash fingerprint of the
/// base's bytes in the header. Written by [`write_snapshot_delta`]; read
/// transparently by [`read_snapshot`], which resolves the base as a
/// sibling file. Deltas never serve as bases themselves — a chain is
/// exactly one level deep.
pub const DELTA_FORMAT_VERSION: u32 = 3;

/// The **embedded-workload** snapshot format: version 4 files are full
/// (v2-layout) snapshots whose payload is prefixed with the workload's
/// printed Relay source and description. Written only for workloads that
/// are not in the static library ([`crate::relay::workload_by_name`] would
/// miss them in a fresh process) — i.e. imported models; the loader
/// re-registers the embedded definition so the snapshot is self-contained.
pub const EMBED_FORMAT_VERSION: u32 = 4;

/// FxHash of a byte string (the checksum / fingerprint primitive — the
/// in-tree [`FxHasher`] is seed-free and therefore process-stable).
fn fx_bytes(bytes: &[u8]) -> u64 {
    let mut h = FxHasher::default();
    h.write(bytes);
    h.finish()
}

/// Fingerprint of a workload definition (its printed Relay expression):
/// detects a snapshot written against a *different* definition of the same
/// workload name.
pub fn workload_fingerprint(workload_src: &str) -> u64 {
    fx_bytes(workload_src.as_bytes())
}

/// Order-sensitive hash of a rule-name list.
pub fn ruleset_hash(names: &[String]) -> u64 {
    let mut h = FxHasher::default();
    for n in names {
        h.write(n.as_bytes());
        h.write_u8(b'\n');
    }
    h.finish()
}

/// Cheap header metadata, readable without decoding (or even reading) the
/// payload — serving uses this to map snapshot files to workloads.
#[derive(Debug, Clone)]
pub struct SnapshotMeta {
    pub format_version: u32,
    /// Workload name the snapshot was written for.
    pub workload: String,
    /// [`workload_fingerprint`] of the writing process's workload source.
    pub workload_fingerprint: u64,
    /// [`ruleset_hash`] of the rule names the space was enumerated with.
    pub ruleset_hash: u64,
    /// For delta snapshots (format version [`DELTA_FORMAT_VERSION`]): the
    /// FxHash fingerprint of the base file's bytes. `None` for full
    /// snapshots.
    pub base_fingerprint: Option<u64>,
    /// Payload size in bytes.
    pub payload_len: u64,
}

/// Read just the header of a snapshot file.
pub fn peek_header(path: impl AsRef<Path>) -> Result<SnapshotMeta> {
    // The header is tiny; read a bounded prefix instead of the whole file.
    let mut f = std::fs::File::open(path.as_ref())?;
    let mut buf = vec![0u8; 4096];
    let mut filled = 0;
    while filled < buf.len() {
        let n = f.read(&mut buf[filled..])?;
        if n == 0 {
            break;
        }
        filled += n;
    }
    buf.truncate(filled);
    let mut dec = Dec::new(&buf);
    let (meta, _checksum) = decode_header(&mut dec)?;
    Ok(meta)
}

// ---------------------------------------------------------------------
// Encode
// ---------------------------------------------------------------------

/// Everything one snapshot persists, borrowed from the writing session.
pub(crate) struct SnapshotParts<'a> {
    pub workload_name: &'a str,
    /// Printed workload source (fingerprinted into the header).
    pub workload_src: String,
    /// `Some(description)` marks an **imported** workload (absent from the
    /// static library): the snapshot is written as
    /// [`EMBED_FORMAT_VERSION`] with the source and this description
    /// embedded in the payload. `None` writes the usual v2 full snapshot.
    pub workload_description: Option<String>,
    pub lowered: &'a RecExpr,
    pub rule_names: Vec<String>,
    pub egraph: &'a EGraph,
    pub root: Id,
    pub report: &'a RunnerReport,
    pub cache: &'a ExtractCache,
}

/// Encode a snapshot into bytes (header + checksummed payload).
pub(crate) fn encode_snapshot(parts: &SnapshotParts) -> Vec<u8> {
    let mut p = Enc::default();
    if let Some(desc) = &parts.workload_description {
        // v4: self-contained imported workload — source + description first.
        p.str(&parts.workload_src);
        p.str(desc);
    }
    p.str(&parts.lowered.to_string());
    p.u32(parts.rule_names.len() as u32);
    for name in &parts.rule_names {
        p.str(name);
    }
    encode_egraph(&mut p, parts.egraph);
    p.id(parts.root);
    encode_report(&mut p, parts.report);
    encode_cache(&mut p, &parts.cache.export());
    let payload = p.buf;

    let mut out = Enc::default();
    out.buf.extend_from_slice(MAGIC);
    out.u32(if parts.workload_description.is_some() {
        EMBED_FORMAT_VERSION
    } else {
        FORMAT_VERSION
    });
    out.str(parts.workload_name);
    out.u64(workload_fingerprint(&parts.workload_src));
    out.u64(ruleset_hash(&parts.rule_names));
    out.u64(payload.len() as u64);
    out.u64(fx_bytes(&payload));
    out.buf.extend_from_slice(&payload);
    out.buf
}

/// Encode + write to `path`, creating parent directories as needed.
pub(crate) fn write_snapshot(path: impl AsRef<Path>, parts: &SnapshotParts) -> Result<()> {
    let bytes = encode_snapshot(parts);
    if let Some(dir) = path.as_ref().parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::fs::File::create(path.as_ref())?;
    f.write_all(&bytes)?;
    Ok(())
}

/// Encode a **delta** snapshot (format [`DELTA_FORMAT_VERSION`]) against a
/// full base file's bytes: only the union-find entries, arena nodes,
/// class slots, and cost-table rows that differ from the decoded base are
/// written, plus the base's file name and fingerprint so the reader can
/// resolve and validate the chain.
///
/// The applicability gate is the e-graph's mutation log
/// ([`EGraph::changed_since`]): a graph whose log no longer reaches back
/// to the base epoch was not grown in-place from this base, so callers
/// must write a full snapshot instead. The log only *gates* — unions drop
/// the loser's slot and rebuilds rewrite parent back-edges without
/// logging those slots, so the encoder diffs the full raw parts
/// structurally rather than trusting the log's slot list.
pub(crate) fn encode_snapshot_delta(
    parts: &SnapshotParts,
    base_bytes: &[u8],
    base_name: &str,
) -> Result<Vec<u8>> {
    {
        let mut bd = Dec::new(base_bytes);
        let (bmeta, _) = decode_header(&mut bd)?;
        if bmeta.format_version == DELTA_FORMAT_VERSION {
            // Chains resolve exactly one level: a delta's base must be full.
            return Err(Error::InvalidConfig("delta base must be a full snapshot".into()));
        }
    }
    let base = decode_snapshot(base_bytes)?;
    if base.meta.workload != parts.workload_name {
        return Err(Error::InvalidConfig(format!(
            "delta base is for workload '{}', not '{}'",
            base.meta.workload, parts.workload_name
        )));
    }
    if base.meta.workload_fingerprint != workload_fingerprint(&parts.workload_src) {
        return Err(Error::InvalidConfig("delta base has a different workload definition".into()));
    }
    let base_epoch = base.egraph.epoch();
    if parts.egraph.epoch() < base_epoch || parts.egraph.changed_since(base_epoch).is_none() {
        // The mutation log no longer reaches back to the base epoch: this
        // graph was not grown in-place from the base, write a full snapshot.
        return Err(Error::InvalidConfig("graph was not grown from this delta base".into()));
    }
    let cur = parts.egraph.to_parts();
    let old = base.egraph.to_parts();
    if cur.parents.len() < old.parents.len() || cur.arena.len() < old.arena.len() {
        return Err(Error::InvalidConfig("graph is smaller than the delta base".into()));
    }

    let mut p = Enc::default();
    p.str(base_name);
    p.u64(base_epoch);
    // Base dimensions, re-checked at decode time: a delta is only valid
    // against the exact graph it was diffed from.
    p.u64(old.parents.len() as u64);
    p.u64(old.arena.len() as u64);
    p.str(&parts.lowered.to_string());
    p.u32(parts.rule_names.len() as u32);
    for name in &parts.rule_names {
        p.str(name);
    }
    encode_egraph_delta(&mut p, &cur, &old);
    p.id(parts.root);
    encode_report(&mut p, parts.report);
    encode_cache_delta(&mut p, &parts.cache.export(), &base.cache.export());
    let payload = p.buf;

    let mut out = Enc::default();
    out.buf.extend_from_slice(MAGIC);
    out.u32(DELTA_FORMAT_VERSION);
    out.str(parts.workload_name);
    out.u64(workload_fingerprint(&parts.workload_src));
    out.u64(ruleset_hash(&parts.rule_names));
    out.u64(fx_bytes(base_bytes));
    out.u64(payload.len() as u64);
    out.u64(fx_bytes(&payload));
    out.buf.extend_from_slice(&payload);
    Ok(out.buf)
}

/// Encode a delta against the full snapshot at `base_path` and write it to
/// `path`, creating parent directories as needed. The delta stores the
/// base's *file name* (not its path): [`read_snapshot`] resolves the base
/// as a sibling of the delta file, so the pair deploys as a unit.
pub(crate) fn write_snapshot_delta(
    path: impl AsRef<Path>,
    base_path: impl AsRef<Path>,
    parts: &SnapshotParts,
) -> Result<()> {
    let base_bytes = std::fs::read(base_path.as_ref())?;
    let base_name = base_path
        .as_ref()
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| Error::InvalidConfig("delta base path has no UTF-8 file name".into()))?;
    let bytes = encode_snapshot_delta(parts, &base_bytes, base_name)?;
    if let Some(dir) = path.as_ref().parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::fs::File::create(path.as_ref())?;
    f.write_all(&bytes)?;
    Ok(())
}

/// The e-graph diff: changed slots below the base lengths as explicit
/// `(index, value)` pairs, appended slots in order (their count is implied
/// by the new totals), and the small transient sections (pending list,
/// dirty log heads, epoch) whole — they are a few ids each.
fn encode_egraph_delta(e: &mut Enc, cur: &EGraphParts, old: &EGraphParts) {
    let base_n = old.parents.len();
    let base_arena = old.arena.len();
    e.u64(cur.parents.len() as u64);
    let changed: Vec<usize> = (0..base_n).filter(|&i| cur.parents[i] != old.parents[i]).collect();
    e.u32(changed.len() as u32);
    for i in changed {
        e.u32(i as u32);
        e.u32(cur.parents[i]);
    }
    for &par in &cur.parents[base_n..] {
        e.u32(par);
    }
    e.u64(cur.arena.len() as u64);
    let changed: Vec<usize> = (0..base_arena).filter(|&i| cur.arena[i] != old.arena[i]).collect();
    e.u32(changed.len() as u32);
    for i in changed {
        e.u32(i as u32);
        e.node(&cur.arena[i]);
    }
    for node in &cur.arena[base_arena..] {
        e.node(node);
    }
    let changed: Vec<usize> =
        (0..base_n).filter(|&i| !class_slot_eq(&cur.classes[i], &old.classes[i])).collect();
    e.u32(changed.len() as u32);
    for i in changed {
        e.u32(i as u32);
        encode_class_slot(e, &cur.classes[i]);
    }
    for class in &cur.classes[base_n..] {
        encode_class_slot(e, class);
    }
    e.u32(cur.pending.len() as u32);
    for &id in &cur.pending {
        e.id(id);
    }
    e.u64(cur.n_unions as u64);
    e.u8(cur.dirty as u8);
    e.u32(cur.dirty_classes.len() as u32);
    for &id in &cur.dirty_classes {
        e.id(id);
    }
    e.u32(cur.merged_roots.len() as u32);
    for &id in &cur.merged_roots {
        e.id(id);
    }
    e.u64(cur.epoch);
}

/// Structural equality of two class slots ([`EClass`] derives no
/// `PartialEq` — equality is only meaningful per-field here, for diffing).
fn class_slot_eq(a: &Option<EClass>, b: &Option<EClass>) -> bool {
    match (a, b) {
        (None, None) => true,
        (Some(a), Some(b)) => {
            a.id == b.id && a.ty == b.ty && a.node_ids == b.node_ids && a.parents == b.parents
        }
        _ => false,
    }
}

/// The cost-table diff. The delta's table list is authoritative (kinds the
/// base solved but the current cache dropped — LRU-evicted sampled tables
/// — simply don't appear); each table is either whole (mode 0, a kind the
/// base never solved) or upserts + removals against the base's table of
/// the same kind (mode 1).
fn encode_cache_delta(e: &mut Enc, cur: &CacheExport, base: &CacheExport) {
    e.u32(cur.tables.len() as u32);
    for (kind, epoch, table) in &cur.tables {
        e.kind(kind);
        e.u64(*epoch);
        let base_table = base.tables.iter().find(|(k, _, _)| k == kind).map(|(_, _, t)| t);
        match base_table {
            None => {
                e.u8(0);
                encode_table_entries(e, table);
            }
            Some(bt) => {
                e.u8(1);
                let mut upserts: Vec<(&Id, &(f64, Node))> = table
                    .raw_entries()
                    .iter()
                    .filter(|(id, (cost, node))| {
                        bt.raw_entries().get(id).map_or(true, |(bc, bn)| {
                            bc.to_bits() != cost.to_bits() || bn != node
                        })
                    })
                    .collect();
                upserts.sort_by_key(|(id, _)| **id);
                e.u64(upserts.len() as u64);
                for (id, (cost, node)) in upserts {
                    e.id(*id);
                    e.f64(*cost);
                    e.node(node);
                }
                let mut removed: Vec<Id> = bt
                    .raw_entries()
                    .keys()
                    .filter(|id| !table.raw_entries().contains_key(id))
                    .copied()
                    .collect();
                removed.sort_unstable();
                e.u32(removed.len() as u32);
                for id in removed {
                    e.id(id);
                }
            }
        }
    }
    e.u32(cur.sampled_order.len() as u32);
    for kind in &cur.sampled_order {
        e.kind(kind);
    }
}

fn encode_egraph(e: &mut Enc, eg: &EGraph) {
    let parts = eg.to_parts();
    e.u64(parts.parents.len() as u64);
    for &p in &parts.parents {
        e.u32(p);
    }
    e.u64(parts.arena.len() as u64);
    for n in &parts.arena {
        e.node(n);
    }
    debug_assert_eq!(parts.classes.len(), parts.parents.len());
    for class in &parts.classes {
        encode_class_slot(e, class);
    }
    e.u32(parts.pending.len() as u32);
    for &id in &parts.pending {
        e.id(id);
    }
    e.u64(parts.n_unions as u64);
    e.u8(parts.dirty as u8);
    e.u32(parts.dirty_classes.len() as u32);
    for &id in &parts.dirty_classes {
        e.id(id);
    }
    e.u32(parts.merged_roots.len() as u32);
    for &id in &parts.merged_roots {
        e.id(id);
    }
    e.u64(parts.epoch);
}

/// One class slot in the v2 layout: presence byte, then id/ty/member arena
/// indices/parent back-edges — each node body is in the file exactly once
/// (the arena section). Shared by the full encoder and the delta differ so
/// the two layouts cannot drift.
fn encode_class_slot(e: &mut Enc, class: &Option<EClass>) {
    match class {
        None => e.u8(0),
        Some(c) => {
            e.u8(1);
            e.id(c.id);
            e.ty(&c.ty);
            e.u32(c.node_ids.len() as u32);
            for &nid in &c.node_ids {
                e.u32(nid.index() as u32);
            }
            e.u32(c.parents.len() as u32);
            for &(nid, pid) in &c.parents {
                e.u32(nid.index() as u32);
                e.id(pid);
            }
        }
    }
}

fn encode_report(e: &mut Enc, r: &RunnerReport) {
    e.u8(match r.stop {
        StopReason::Saturated => 0,
        StopReason::IterLimit => 1,
        StopReason::NodeLimit => 2,
        StopReason::TimeLimit => 3,
    });
    e.u64(r.nodes as u64);
    e.u64(r.classes as u64);
    e.f64(r.designs_lower_bound);
    e.u64(r.elapsed.as_nanos().min(u64::MAX as u128) as u64);
    e.u32(r.rule_names.len() as u32);
    for n in &r.rule_names {
        e.str(n);
    }
    // Per-iteration stats are growth-experiment data, not serving state:
    // deliberately not persisted (loads restore an empty iteration list).
}

fn encode_cache(e: &mut Enc, export: &CacheExport) {
    e.u32(export.tables.len() as u32);
    for (kind, epoch, table) in &export.tables {
        e.kind(kind);
        e.u64(*epoch);
        encode_table_entries(e, table);
    }
    e.u32(export.sampled_order.len() as u32);
    for kind in &export.sampled_order {
        e.kind(kind);
    }
}

/// One cost table's entries, sorted by class id — snapshot bytes must not
/// depend on HashMap iteration order.
fn encode_table_entries(e: &mut Enc, table: &CostTable) {
    let mut entries: Vec<(&Id, &(f64, Node))> = table.raw_entries().iter().collect();
    entries.sort_by_key(|(id, _)| **id);
    e.u64(entries.len() as u64);
    for (id, (cost, node)) in entries {
        e.id(*id);
        e.f64(*cost);
        e.node(node);
    }
}

/// Little-endian byte sink.
#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn id(&mut self, id: Id) {
        self.u32(id.index() as u32);
    }

    fn shape(&mut self, s: &Shape) {
        self.u32(s.0.len() as u32);
        for &d in &s.0 {
            self.u64(d as u64);
        }
    }

    fn ty(&mut self, ty: &Ty) {
        match ty {
            Ty::Index => self.u8(0),
            Ty::Tensor(shape) => {
                self.u8(1);
                self.shape(shape);
            }
            Ty::Engine(sig) => {
                self.u8(2);
                self.op(&sig.0);
            }
        }
    }

    /// Registry-driven operator encoding: spec name + schema'd attributes.
    fn op(&mut self, op: &Op) {
        let spec = op.spec();
        self.str(spec.name);
        let attrs = (spec.attrs_of)(op);
        debug_assert_eq!(attrs.len(), spec.attrs.len(), "attr schema drift for {}", spec.name);
        for attr in attrs {
            match attr {
                AttrVal::U(v) => self.u64(v as u64),
                AttrVal::I(v) => self.u64(v as u64),
                AttrVal::Sym(s) => self.str(s.as_str()),
                AttrVal::Sh(s) => self.shape(&s),
                AttrVal::Buf(b) => self.u8(match b {
                    BufKind::Sram => 0,
                    BufKind::Dram => 1,
                }),
                AttrVal::F32s(v) => {
                    self.u64(v.len() as u64);
                    for x in v {
                        self.u32(x.to_bits());
                    }
                }
            }
        }
    }

    fn node(&mut self, n: &Node) {
        self.op(&n.op);
        self.u32(n.children.len() as u32);
        for &c in &n.children {
            self.id(c);
        }
    }

    fn kind(&mut self, k: &CostKind) {
        match k {
            CostKind::Latency => self.u8(0),
            CostKind::Area => self.u8(1),
            CostKind::Size => self.u8(2),
            CostKind::Sampled(seed) => {
                self.u8(3);
                self.u64(*seed);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Decode
// ---------------------------------------------------------------------

/// A decoded snapshot, ready for [`crate::session::Session::load_snapshot`]
/// to validate against the live workload/rule libraries.
pub(crate) struct LoadedSnapshot {
    pub meta: SnapshotMeta,
    /// For [`EMBED_FORMAT_VERSION`] files: the embedded workload source
    /// (fingerprint-checked against the header) and description, so the
    /// loader can re-register an imported workload in a fresh process.
    pub workload_src: Option<String>,
    pub workload_description: Option<String>,
    pub lowered: RecExpr,
    pub rule_names: Vec<String>,
    pub egraph: EGraph,
    pub root: Id,
    pub report: RunnerReport,
    pub cache: ExtractCache,
}

/// Read + decode a snapshot file. A delta file (format
/// [`DELTA_FORMAT_VERSION`]) is resolved transparently: its base is read
/// from the sibling file it names, fingerprint-validated, and overlaid —
/// callers see one [`LoadedSnapshot`] either way.
pub(crate) fn read_snapshot(path: impl AsRef<Path>) -> Result<LoadedSnapshot> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)?;
    let mut dec = Dec::new(&bytes);
    let (meta, _) = decode_header(&mut dec)?;
    if meta.format_version != DELTA_FORMAT_VERSION {
        return decode_snapshot(&bytes);
    }
    let base_name = delta_base_name(&bytes)?;
    let base_path = match path.parent() {
        Some(dir) if !dir.as_os_str().is_empty() => dir.join(&base_name),
        _ => PathBuf::from(&base_name),
    };
    let base_bytes = std::fs::read(&base_path).map_err(|e| {
        Error::SnapshotCorrupt(format!("delta base '{}' is unreadable: {e}", base_path.display()))
    })?;
    decode_snapshot_delta(&bytes, &base_bytes)
}

/// The base file name a delta snapshot resolves against: the first payload
/// field, returned only after the payload checksum verifies.
pub fn delta_base_name(bytes: &[u8]) -> Result<String> {
    let mut dec = Dec::new(bytes);
    let (meta, checksum) = decode_header(&mut dec)?;
    if meta.format_version != DELTA_FORMAT_VERSION {
        return Err(corrupt("not a delta snapshot"));
    }
    let payload = dec.take(meta.payload_len as usize, "payload")?;
    if fx_bytes(payload) != checksum {
        return Err(corrupt("payload checksum mismatch"));
    }
    Dec::new(payload).str("base file name")
}

/// Decode a snapshot from bytes. Every structural defect — truncation, bad
/// magic, checksum mismatch, out-of-range ids, unknown operators — returns
/// [`Error::SnapshotCorrupt`]; an unreadable format version returns
/// [`Error::SnapshotVersion`].
pub(crate) fn decode_snapshot(bytes: &[u8]) -> Result<LoadedSnapshot> {
    let mut dec = Dec::new(bytes);
    let (meta, checksum) = decode_header(&mut dec)?;
    if meta.format_version == DELTA_FORMAT_VERSION {
        return Err(corrupt("delta snapshot needs its base file; load it by path"));
    }
    let payload = dec.take(meta.payload_len as usize, "payload")?;
    if !dec.at_end() {
        return Err(corrupt("trailing bytes after payload"));
    }
    if fx_bytes(payload) != checksum {
        return Err(corrupt("payload checksum mismatch"));
    }
    let mut p = Dec::new(payload);
    let (workload_src, workload_description) = if meta.format_version == EMBED_FORMAT_VERSION {
        let src = p.str("embedded workload source")?;
        if workload_fingerprint(&src) != meta.workload_fingerprint {
            return Err(corrupt("embedded workload source does not match the header fingerprint"));
        }
        let desc = p.str("embedded workload description")?;
        (Some(src), Some(desc))
    } else {
        (None, None)
    };
    let lowered_text = p.str("lowered program")?;
    let lowered = parse_expr(&lowered_text)
        .map_err(|e| corrupt(&format!("stored lowered program does not parse: {e}")))?;
    let n_rules = p.u32("rule count")?;
    let mut rule_names = Vec::with_capacity(n_rules as usize);
    for _ in 0..n_rules {
        rule_names.push(p.str("rule name")?);
    }
    if ruleset_hash(&rule_names) != meta.ruleset_hash {
        return Err(corrupt("rule-set hash does not match the stored rule names"));
    }
    let (egraph, n_classes) = decode_egraph(&mut p, meta.format_version)?;
    let root = p.class_id("root", n_classes)?;
    let report = decode_report(&mut p)?;
    let cache = decode_cache(&mut p, meta.format_version, n_classes)?;
    if !p.at_end() {
        return Err(corrupt("trailing bytes inside payload"));
    }
    Ok(LoadedSnapshot {
        meta,
        workload_src,
        workload_description,
        lowered,
        rule_names,
        egraph,
        root,
        report,
        cache,
    })
}

/// Decode a delta snapshot by overlaying it onto its base file's bytes.
/// On top of the usual corruption checks, the chain itself is validated:
/// the base's fingerprint must match the delta header, the base must be a
/// full snapshot (one-level chains), and the delta's recorded base epoch
/// and dimensions must match the decoded base exactly.
pub(crate) fn decode_snapshot_delta(bytes: &[u8], base_bytes: &[u8]) -> Result<LoadedSnapshot> {
    let mut dec = Dec::new(bytes);
    let (meta, checksum) = decode_header(&mut dec)?;
    if meta.format_version != DELTA_FORMAT_VERSION {
        return Err(corrupt("not a delta snapshot"));
    }
    let payload = dec.take(meta.payload_len as usize, "payload")?;
    if !dec.at_end() {
        return Err(corrupt("trailing bytes after payload"));
    }
    if fx_bytes(payload) != checksum {
        return Err(corrupt("payload checksum mismatch"));
    }
    let base_fp = meta.base_fingerprint.expect("v3 headers carry a base fingerprint");
    if fx_bytes(base_bytes) != base_fp {
        return Err(corrupt("base fingerprint mismatch (wrong or rewritten base file)"));
    }
    {
        let mut bd = Dec::new(base_bytes);
        let (bmeta, _) = decode_header(&mut bd)?;
        if bmeta.format_version == DELTA_FORMAT_VERSION {
            return Err(corrupt("delta chained on a delta base (chains resolve one level)"));
        }
    }
    let base = decode_snapshot(base_bytes)?;
    if base.meta.workload != meta.workload {
        return Err(corrupt("delta and base disagree on the workload"));
    }
    let mut p = Dec::new(payload);
    // The base name was already consumed by the caller to find the file;
    // re-read it here to keep one sequential payload cursor.
    let _base_name = p.str("base file name")?;
    let base_epoch = p.u64("base epoch")?;
    if base.egraph.epoch() != base_epoch {
        return Err(corrupt("delta was written against a different base epoch"));
    }
    let base_parts = base.egraph.to_parts();
    let decl_n = p.u64("base class count")? as usize;
    let decl_arena = p.u64("base arena length")? as usize;
    if decl_n != base_parts.parents.len() || decl_arena != base_parts.arena.len() {
        return Err(corrupt("delta was written against a different base graph"));
    }
    let lowered_text = p.str("lowered program")?;
    let lowered = parse_expr(&lowered_text)
        .map_err(|e| corrupt(&format!("stored lowered program does not parse: {e}")))?;
    let n_rules = p.u32("rule count")?;
    let mut rule_names = Vec::with_capacity(n_rules as usize);
    for _ in 0..n_rules {
        rule_names.push(p.str("rule name")?);
    }
    if ruleset_hash(&rule_names) != meta.ruleset_hash {
        return Err(corrupt("rule-set hash does not match the stored rule names"));
    }
    let (egraph, n_classes) = decode_egraph_delta(&mut p, base_parts)?;
    let root = p.class_id("root", n_classes)?;
    let report = decode_report(&mut p)?;
    let cache = decode_cache_delta(&mut p, &base.cache.export(), n_classes)?;
    if !p.at_end() {
        return Err(corrupt("trailing bytes inside payload"));
    }
    // A delta on an embedded-workload (v4) base inherits the base's
    // definition — the delta payload never re-embeds it.
    Ok(LoadedSnapshot {
        meta,
        workload_src: base.workload_src,
        workload_description: base.workload_description,
        lowered,
        rule_names,
        egraph,
        root,
        report,
        cache,
    })
}

/// Overlay a delta's e-graph diff onto the decoded base parts (see
/// [`encode_egraph_delta`] for the section layout).
fn decode_egraph_delta(p: &mut Dec, base: EGraphParts) -> Result<(EGraph, usize)> {
    let base_n = base.parents.len();
    let base_arena = base.arena.len();
    let n = p.u64("class count")? as usize;
    if n < base_n {
        return Err(corrupt("delta shrinks the union-find"));
    }
    let mut parents = base.parents;
    let n_changed = p.u32("changed union-find count")?;
    for _ in 0..n_changed {
        let idx = p.u32("union-find index")? as usize;
        if idx >= base_n {
            return Err(corrupt("changed union-find index out of range"));
        }
        let par = p.u32("union-find parent")?;
        if par as usize >= n {
            return Err(corrupt("union-find parent out of range"));
        }
        parents[idx] = par;
    }
    for _ in base_n..n {
        let par = p.u32("union-find parent")?;
        if par as usize >= n {
            return Err(corrupt("union-find parent out of range"));
        }
        parents.push(par);
    }
    let arena_len = p.u64("arena length")? as usize;
    if arena_len < base_arena {
        return Err(corrupt("delta shrinks the arena"));
    }
    let mut arena = base.arena;
    let n_changed = p.u32("changed arena count")?;
    for _ in 0..n_changed {
        let idx = p.u32("arena index")? as usize;
        if idx >= base_arena {
            return Err(corrupt("changed arena index out of range"));
        }
        arena[idx] = p.node("arena node", n)?;
    }
    for _ in base_arena..arena_len {
        arena.push(p.node("arena node", n)?);
    }
    let mut classes = base.classes;
    classes.resize(n, None);
    let n_changed = p.u32("changed class count")?;
    for _ in 0..n_changed {
        let slot = p.u32("class slot")? as usize;
        if slot >= base_n {
            return Err(corrupt("changed class slot out of range"));
        }
        classes[slot] = decode_class_slot(p, slot, n, arena_len)?;
    }
    for (slot, class) in classes.iter_mut().enumerate().take(n).skip(base_n) {
        *class = decode_class_slot(p, slot, n, arena_len)?;
    }
    let n_pending = p.u32("pending count")?;
    let mut pending = Vec::with_capacity(n_pending as usize);
    for _ in 0..n_pending {
        pending.push(p.class_id("pending id", n)?);
    }
    let n_unions = p.u64("union count")? as usize;
    let dirty = p.u8("dirty flag")? != 0;
    let n_dirty = p.u32("dirty-class count")?;
    let mut dirty_classes = Vec::with_capacity(n_dirty as usize);
    for _ in 0..n_dirty {
        dirty_classes.push(p.class_id("dirty class id", n)?);
    }
    let n_merged = p.u32("merged-root count")?;
    let mut merged_roots = Vec::with_capacity(n_merged as usize);
    for _ in 0..n_merged {
        merged_roots.push(p.class_id("merged root id", n)?);
    }
    let epoch = p.u64("epoch")?;
    let eg = EGraph::from_parts(EGraphParts {
        parents,
        classes,
        arena,
        pending,
        n_unions,
        dirty,
        dirty_classes,
        merged_roots,
        epoch,
    });
    Ok((eg, n))
}

/// Overlay a delta's cost-table diff onto the base's exported cache (see
/// [`encode_cache_delta`] for the section layout).
fn decode_cache_delta(p: &mut Dec, base: &CacheExport, n_classes: usize) -> Result<ExtractCache> {
    let n_tables = p.u32("cache table count")?;
    let mut tables = Vec::with_capacity(n_tables as usize);
    for _ in 0..n_tables {
        let kind = p.kind()?;
        let epoch = p.u64("cache table epoch")?;
        let table = match p.u8("cost-table mode")? {
            0 => CostTable::from_raw(decode_table_entries(p, n_classes)?),
            1 => {
                let bt = base
                    .tables
                    .iter()
                    .find(|(k, _, _)| *k == kind)
                    .map(|(_, _, t)| t)
                    .ok_or_else(|| corrupt("cost-table diff has no base table of its kind"))?;
                let mut best = bt.raw_entries().clone();
                let n_up = p.u64("cost-table upsert count")? as usize;
                for _ in 0..n_up {
                    let id = p.class_id("cost-table class id", n_classes)?;
                    let cost = p.f64("cost-table cost")?;
                    let node = p.node("cost-table node", n_classes)?;
                    best.insert(id, (cost, node));
                }
                let n_rm = p.u32("cost-table removal count")?;
                for _ in 0..n_rm {
                    let id = p.class_id("cost-table removed id", n_classes)?;
                    best.remove(&id);
                }
                CostTable::from_raw(best)
            }
            _ => return Err(corrupt("unknown cost-table mode")),
        };
        tables.push((kind, epoch, Arc::new(table)));
    }
    let n_order = p.u32("sampled-order count")?;
    let mut sampled_order = Vec::with_capacity(n_order as usize);
    for _ in 0..n_order {
        sampled_order.push(p.kind()?);
    }
    Ok(ExtractCache::import(CacheExport { tables, sampled_order }))
}

fn decode_header(dec: &mut Dec) -> Result<(SnapshotMeta, u64)> {
    let magic = dec.take(MAGIC.len(), "magic")?;
    if magic != MAGIC {
        return Err(corrupt("bad magic (not a hwsplit snapshot)"));
    }
    let format_version = dec.u32("format version")?;
    if !(1..=EMBED_FORMAT_VERSION).contains(&format_version) {
        return Err(Error::SnapshotVersion {
            found: format_version,
            supported: EMBED_FORMAT_VERSION,
        });
    }
    let workload = dec.str("workload name")?;
    let workload_fingerprint = dec.u64("workload fingerprint")?;
    let ruleset_hash = dec.u64("rule-set hash")?;
    let base_fingerprint = if format_version == DELTA_FORMAT_VERSION {
        Some(dec.u64("base fingerprint")?)
    } else {
        None
    };
    let payload_len = dec.u64("payload length")?;
    let checksum = dec.u64("payload checksum")?;
    Ok((
        SnapshotMeta {
            format_version,
            workload,
            workload_fingerprint,
            ruleset_hash,
            base_fingerprint,
            payload_len,
        },
        checksum,
    ))
}

fn decode_egraph(p: &mut Dec, version: u32) -> Result<(EGraph, usize)> {
    let n = p.u64("class count")? as usize;
    let mut parents = Vec::with_capacity(n);
    for _ in 0..n {
        let par = p.u32("union-find parent")?;
        if par as usize >= n {
            return Err(corrupt("union-find parent out of range"));
        }
        parents.push(par);
    }
    let arena_len = p.u64("arena length")? as usize;
    let mut arena = Vec::with_capacity(arena_len);
    for _ in 0..arena_len {
        arena.push(p.node("arena node", n)?);
    }
    // v1 files re-encode every class member in full; map those bodies back
    // onto arena slots by content. A body whose arena copy drifted (v1
    // canonicalized class nodes and arena entries on different schedules)
    // is appended — parent back-edges index the original slots, which
    // append never moves.
    let mut by_content: FxHashMap<Node, NodeId> = FxHashMap::default();
    if version == 1 {
        for (i, node) in arena.iter().enumerate() {
            by_content.entry(node.clone()).or_insert_with(|| NodeId::from_index(i));
        }
    }
    let mut classes: Vec<Option<EClass>> = Vec::with_capacity(n);
    for slot in 0..n {
        if version != 1 {
            classes.push(decode_class_slot(p, slot, n, arena_len)?);
            continue;
        }
        if p.u8("class presence")? == 0 {
            classes.push(None);
            continue;
        }
        let id = p.class_id("class id", n)?;
        if id.index() != slot {
            return Err(corrupt("class id does not match its slot"));
        }
        let ty = p.ty()?;
        let n_nodes = p.u32("class node count")?;
        let mut node_ids = Vec::with_capacity(n_nodes as usize);
        for _ in 0..n_nodes {
            let node = p.node("class node", n)?;
            let nid = match by_content.entry(node.clone()) {
                std::collections::hash_map::Entry::Occupied(o) => *o.get(),
                std::collections::hash_map::Entry::Vacant(v) => {
                    let nid = NodeId::from_index(arena.len());
                    arena.push(node);
                    *v.insert(nid)
                }
            };
            node_ids.push(nid);
        }
        let n_parents = p.u32("class parent count")?;
        let mut cparents = Vec::with_capacity(n_parents as usize);
        for _ in 0..n_parents {
            let arena_idx = p.u32("parent arena index")?;
            if arena_idx as usize >= arena_len {
                return Err(corrupt("parent arena index out of range"));
            }
            let pid = p.class_id("parent class id", n)?;
            cparents.push((NodeId::from_index(arena_idx as usize), pid));
        }
        classes.push(Some(EClass { id, node_ids, parents: cparents, ty }));
    }
    let n_pending = p.u32("pending count")?;
    let mut pending = Vec::with_capacity(n_pending as usize);
    for _ in 0..n_pending {
        pending.push(p.class_id("pending id", n)?);
    }
    let n_unions = p.u64("union count")? as usize;
    let dirty = p.u8("dirty flag")? != 0;
    let n_dirty = p.u32("dirty-class count")?;
    let mut dirty_classes = Vec::with_capacity(n_dirty as usize);
    for _ in 0..n_dirty {
        dirty_classes.push(p.class_id("dirty class id", n)?);
    }
    let n_merged = p.u32("merged-root count")?;
    let mut merged_roots = Vec::with_capacity(n_merged as usize);
    for _ in 0..n_merged {
        merged_roots.push(p.class_id("merged root id", n)?);
    }
    let epoch = p.u64("epoch")?;
    let eg = EGraph::from_parts(EGraphParts {
        parents,
        classes,
        arena,
        pending,
        n_unions,
        dirty,
        dirty_classes,
        merged_roots,
        epoch,
    });
    Ok((eg, n))
}

/// Decode one class slot in the v2 layout (arena-index members) — the
/// counterpart of [`encode_class_slot`], shared by the full reader and the
/// delta overlay. `n` bounds class ids, `arena_len` bounds arena indices.
fn decode_class_slot(
    p: &mut Dec,
    slot: usize,
    n: usize,
    arena_len: usize,
) -> Result<Option<EClass>> {
    if p.u8("class presence")? == 0 {
        return Ok(None);
    }
    let id = p.class_id("class id", n)?;
    if id.index() != slot {
        return Err(corrupt("class id does not match its slot"));
    }
    let ty = p.ty()?;
    let n_nodes = p.u32("class node count")?;
    let mut node_ids = Vec::with_capacity(n_nodes as usize);
    for _ in 0..n_nodes {
        let raw = p.u32("class node id")? as usize;
        if raw >= arena_len {
            return Err(corrupt("class node id out of range"));
        }
        node_ids.push(NodeId::from_index(raw));
    }
    let n_parents = p.u32("class parent count")?;
    let mut cparents = Vec::with_capacity(n_parents as usize);
    for _ in 0..n_parents {
        let arena_idx = p.u32("parent arena index")?;
        if arena_idx as usize >= arena_len {
            return Err(corrupt("parent arena index out of range"));
        }
        let pid = p.class_id("parent class id", n)?;
        cparents.push((NodeId::from_index(arena_idx as usize), pid));
    }
    Ok(Some(EClass { id, node_ids, parents: cparents, ty }))
}

fn decode_report(p: &mut Dec) -> Result<RunnerReport> {
    let stop = match p.u8("stop reason")? {
        0 => StopReason::Saturated,
        1 => StopReason::IterLimit,
        2 => StopReason::NodeLimit,
        3 => StopReason::TimeLimit,
        _ => return Err(corrupt("unknown stop reason")),
    };
    let nodes = p.u64("report nodes")? as usize;
    let classes = p.u64("report classes")? as usize;
    let designs_lower_bound = p.f64("designs lower bound")?;
    let elapsed = Duration::from_nanos(p.u64("report elapsed")?);
    let n_rules = p.u32("report rule count")?;
    let mut rule_names = Vec::with_capacity(n_rules as usize);
    for _ in 0..n_rules {
        rule_names.push(p.str("report rule name")?);
    }
    Ok(RunnerReport {
        stop,
        iterations: Vec::new(),
        nodes,
        classes,
        designs_lower_bound,
        elapsed,
        rule_names,
    })
}

fn decode_cache(p: &mut Dec, version: u32, n_classes: usize) -> Result<ExtractCache> {
    // v1 stored one cache-wide epoch before the tables; v2 tags each entry.
    let global_epoch = if version == 1 { Some(p.u64("cache epoch")?) } else { None };
    let n_tables = p.u32("cache table count")?;
    let mut tables = Vec::with_capacity(n_tables as usize);
    for _ in 0..n_tables {
        let kind = p.kind()?;
        let epoch = match global_epoch {
            Some(e) => e,
            None => p.u64("cache table epoch")?,
        };
        let best = decode_table_entries(p, n_classes)?;
        tables.push((kind, epoch, Arc::new(CostTable::from_raw(best))));
    }
    let n_order = p.u32("sampled-order count")?;
    let mut sampled_order = Vec::with_capacity(n_order as usize);
    for _ in 0..n_order {
        sampled_order.push(p.kind()?);
    }
    Ok(ExtractCache::import(CacheExport { tables, sampled_order }))
}

fn decode_table_entries(p: &mut Dec, n_classes: usize) -> Result<FxHashMap<Id, (f64, Node)>> {
    let n_entries = p.u64("cost-table entry count")? as usize;
    let mut best: FxHashMap<Id, (f64, Node)> =
        FxHashMap::with_capacity_and_hasher(n_entries, Default::default());
    for _ in 0..n_entries {
        let id = p.class_id("cost-table class id", n_classes)?;
        let cost = p.f64("cost-table cost")?;
        let node = p.node("cost-table node", n_classes)?;
        best.insert(id, (cost, node));
    }
    Ok(best)
}

fn corrupt(msg: &str) -> Error {
    Error::SnapshotCorrupt(msg.to_string())
}

/// Bounds-checked little-endian byte source: every read names what it was
/// reading, so truncation errors say *where* the file ran out.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    fn at_end(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or_else(|| corrupt("length overflow"))?;
        if end > self.buf.len() {
            return Err(corrupt(&format!("truncated while reading {what}")));
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let len = self.u32(what)? as usize;
        let bytes = self.take(len, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| corrupt(&format!("non-UTF-8 string for {what}")))
    }

    /// An [`Id`] that must index into a graph of `bound` classes.
    fn class_id(&mut self, what: &str, bound: usize) -> Result<Id> {
        let raw = self.u32(what)?;
        if raw as usize >= bound {
            return Err(corrupt(&format!("{what} out of range")));
        }
        Ok(Id::from_index(raw as usize))
    }

    fn ty(&mut self) -> Result<Ty> {
        Ok(match self.u8("type tag")? {
            0 => Ty::Index,
            1 => Ty::Tensor(self.shape()?),
            2 => Ty::Engine(EngineSig(self.op()?)),
            _ => return Err(corrupt("unknown type tag")),
        })
    }

    fn shape(&mut self) -> Result<Shape> {
        let rank = self.u32("shape rank")? as usize;
        if rank > 64 {
            return Err(corrupt("implausible shape rank"));
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(self.u64("shape dim")? as usize);
        }
        Ok(Shape(dims))
    }

    /// Registry-driven operator decoding: look the spec up by name, read
    /// attributes per its schema, rebuild through `from_attrs`.
    fn op(&mut self) -> Result<Op> {
        let name = self.str("op name")?;
        let spec = spec::by_name(&name)
            .ok_or_else(|| corrupt(&format!("unknown operator '{name}'")))?;
        let mut attrs = Vec::with_capacity(spec.attrs.len());
        for &(_, kind) in spec.attrs {
            attrs.push(match kind {
                AttrKind::U => AttrVal::U(self.u64("op attr")? as usize),
                AttrKind::I => AttrVal::I(self.u64("op attr")? as i64),
                AttrKind::Sym => AttrVal::Sym(Symbol::new(&self.str("op attr")?)),
                AttrKind::Sh => AttrVal::Sh(self.shape()?),
                AttrKind::Buf => AttrVal::Buf(match self.u8("op attr")? {
                    0 => BufKind::Sram,
                    1 => BufKind::Dram,
                    _ => return Err(corrupt("unknown buffer kind")),
                }),
                AttrKind::F32s => {
                    let len = self.u64("f32 tensor length")? as usize;
                    // Bound before allocating: each element costs 4 bytes,
                    // so the remaining buffer caps the plausible length.
                    if len > self.buf.len().saturating_sub(self.pos) / 4 {
                        return Err(corrupt("truncated while reading f32 tensor"));
                    }
                    let mut v = Vec::with_capacity(len);
                    for _ in 0..len {
                        v.push(f32::from_bits(self.u32("f32 tensor element")?));
                    }
                    AttrVal::F32s(v)
                }
            });
        }
        (spec.from_attrs)(&attrs)
            .ok_or_else(|| corrupt(&format!("invalid attributes for operator '{name}'")))
    }

    fn node(&mut self, what: &str, bound: usize) -> Result<Node> {
        let op = self.op()?;
        let n = self.u32(what)? as usize;
        if op.arity().map_or(false, |a| a != n) {
            return Err(corrupt(&format!("arity mismatch for {what}")));
        }
        let mut children = Vec::with_capacity(n);
        for _ in 0..n {
            children.push(self.class_id(what, bound)?);
        }
        Ok(Node::new(op, children))
    }

    fn kind(&mut self) -> Result<CostKind> {
        Ok(match self.u8("cost kind")? {
            0 => CostKind::Latency,
            1 => CostKind::Area,
            2 => CostKind::Size,
            3 => CostKind::Sampled(self.u64("sampled seed")?),
            _ => return Err(corrupt("unknown cost kind")),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::Runner;
    use crate::rewrites;

    fn snapshot_bytes() -> Vec<u8> {
        let expr = parse_expr("(invoke-relu (relu-engine 128) (input x [128]))").unwrap();
        let mut runner = Runner::new(expr.clone(), rewrites::fig2_rules());
        let report = runner.run(6);
        let cache = ExtractCache::new();
        // Warm a few tables so the cache section is non-trivial.
        let opts = crate::extract::ExtractOptions { samples: 4, seed: 0, workers: 2 };
        crate::extract::extract_designs(&runner.egraph, runner.root, &opts, &cache);
        let rule_names: Vec<String> =
            rewrites::fig2_rules().iter().map(|r| r.name.clone()).collect();
        encode_snapshot(&SnapshotParts {
            workload_name: "fig2",
            workload_src: expr.to_string(),
            workload_description: None,
            lowered: &expr,
            rule_names,
            egraph: &runner.egraph,
            root: runner.root,
            report: &report,
            cache: &cache,
        })
    }

    #[test]
    fn encode_decode_roundtrip_preserves_graph_and_cache() {
        let bytes = snapshot_bytes();
        let snap = decode_snapshot(&bytes).expect("roundtrip decodes");
        assert_eq!(snap.meta.workload, "fig2");
        assert_eq!(snap.meta.format_version, FORMAT_VERSION);
        snap.egraph.check_invariants();
        // Cache carries the graph epoch, so it is warm against the loaded
        // graph: a repeat extraction pays zero fixpoint rebuilds.
        let opts = crate::extract::ExtractOptions { samples: 4, seed: 0, workers: 2 };
        let set =
            crate::extract::extract_designs(&snap.egraph, snap.root, &opts, &snap.cache);
        assert_eq!(set.memo_misses, 0, "loaded cache must be warm");
        assert_eq!(set.memo_hits, 6);
    }

    /// Encode in the legacy v1 layout — full node bodies per class, one
    /// cache-wide epoch — exercising the reader's back-compat path.
    fn encode_snapshot_v1(parts: &SnapshotParts) -> Vec<u8> {
        let mut p = Enc::default();
        p.str(&parts.lowered.to_string());
        p.u32(parts.rule_names.len() as u32);
        for name in &parts.rule_names {
            p.str(name);
        }
        let gp = parts.egraph.to_parts();
        p.u64(gp.parents.len() as u64);
        for &par in &gp.parents {
            p.u32(par);
        }
        p.u64(gp.arena.len() as u64);
        for n in &gp.arena {
            p.node(n);
        }
        for class in &gp.classes {
            match class {
                None => p.u8(0),
                Some(c) => {
                    p.u8(1);
                    p.id(c.id);
                    p.ty(&c.ty);
                    p.u32(c.node_ids.len() as u32);
                    for &nid in &c.node_ids {
                        p.node(&gp.arena[nid.index()]);
                    }
                    p.u32(c.parents.len() as u32);
                    for &(nid, pid) in &c.parents {
                        p.u32(nid.index() as u32);
                        p.id(pid);
                    }
                }
            }
        }
        p.u32(gp.pending.len() as u32);
        for &id in &gp.pending {
            p.id(id);
        }
        p.u64(gp.n_unions as u64);
        p.u8(gp.dirty as u8);
        p.u32(gp.dirty_classes.len() as u32);
        for &id in &gp.dirty_classes {
            p.id(id);
        }
        p.u32(gp.merged_roots.len() as u32);
        for &id in &gp.merged_roots {
            p.id(id);
        }
        p.u64(gp.epoch);
        p.id(parts.root);
        encode_report(&mut p, parts.report);
        let export = parts.cache.export();
        p.u64(parts.egraph.epoch());
        p.u32(export.tables.len() as u32);
        for (kind, _, table) in &export.tables {
            p.kind(kind);
            let mut entries: Vec<(&Id, &(f64, Node))> = table.raw_entries().iter().collect();
            entries.sort_by_key(|(id, _)| **id);
            p.u64(entries.len() as u64);
            for (id, (cost, node)) in entries {
                p.id(*id);
                p.f64(*cost);
                p.node(node);
            }
        }
        p.u32(export.sampled_order.len() as u32);
        for kind in &export.sampled_order {
            p.kind(kind);
        }
        let payload = p.buf;
        let mut out = Enc::default();
        out.buf.extend_from_slice(MAGIC);
        out.u32(1);
        out.str(parts.workload_name);
        out.u64(workload_fingerprint(&parts.workload_src));
        out.u64(ruleset_hash(&parts.rule_names));
        out.u64(payload.len() as u64);
        out.u64(fx_bytes(&payload));
        out.buf.extend_from_slice(&payload);
        out.buf
    }

    #[test]
    fn v1_snapshots_remain_readable_and_serve_identically() {
        let expr = parse_expr("(invoke-relu (relu-engine 128) (input x [128]))").unwrap();
        let mut runner = Runner::new(expr.clone(), rewrites::fig2_rules());
        let report = runner.run(6);
        let cache = ExtractCache::new();
        let opts = crate::extract::ExtractOptions { samples: 4, seed: 0, workers: 2 };
        crate::extract::extract_designs(&runner.egraph, runner.root, &opts, &cache);
        let parts = SnapshotParts {
            workload_name: "fig2",
            workload_src: expr.to_string(),
            workload_description: None,
            lowered: &expr,
            rule_names: rewrites::fig2_rules().iter().map(|r| r.name.clone()).collect(),
            egraph: &runner.egraph,
            root: runner.root,
            report: &report,
            cache: &cache,
        };
        let v1 = decode_snapshot(&encode_snapshot_v1(&parts)).expect("v1 decodes");
        let v2 = decode_snapshot(&encode_snapshot(&parts)).expect("v2 decodes");
        assert_eq!(v1.meta.format_version, 1);
        assert_eq!(v2.meta.format_version, FORMAT_VERSION);
        v1.egraph.check_invariants();
        // Both decodes answer queries identically, with warm caches.
        let serve = |snap: &LoadedSnapshot| {
            let set =
                crate::extract::extract_designs(&snap.egraph, snap.root, &opts, &snap.cache);
            assert_eq!(set.memo_misses, 0, "loaded cache must be warm");
            set.designs.iter().map(|(o, e)| (o.clone(), e.to_string())).collect::<Vec<_>>()
        };
        assert_eq!(serve(&v1), serve(&v2));
    }

    #[test]
    fn encoding_is_deterministic() {
        // Stable bytes: HashMap iteration order must not leak into the
        // file (cost tables and entries are explicitly ordered).
        assert_eq!(snapshot_bytes(), snapshot_bytes());
    }

    #[test]
    fn bad_magic_is_corrupt_not_panic() {
        let mut bytes = snapshot_bytes();
        bytes[0] = b'X';
        assert!(matches!(decode_snapshot(&bytes), Err(Error::SnapshotCorrupt(_))));
    }

    #[test]
    fn future_version_is_a_version_error() {
        let mut bytes = snapshot_bytes();
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        match decode_snapshot(&bytes) {
            Err(Error::SnapshotVersion { found: 99, supported }) => {
                assert_eq!(supported, EMBED_FORMAT_VERSION)
            }
            other => panic!("expected SnapshotVersion, got {other:?}"),
        }
    }

    #[test]
    fn truncation_and_bitflips_are_corrupt_not_panic() {
        let bytes = snapshot_bytes();
        // Truncations at a spread of byte offsets.
        for cut in [0, 4, 11, bytes.len() / 2, bytes.len() - 1] {
            match decode_snapshot(&bytes[..cut]) {
                Err(Error::SnapshotCorrupt(_)) => {}
                other => panic!("cut at {cut}: expected SnapshotCorrupt, got {other:?}"),
            }
        }
        // A payload bitflip must fail the checksum.
        let mut flipped = bytes.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x40;
        assert!(matches!(decode_snapshot(&flipped), Err(Error::SnapshotCorrupt(_))));
    }

    #[test]
    fn peek_header_reads_meta_without_payload() {
        let bytes = snapshot_bytes();
        let dir = std::env::temp_dir().join("hwsplit_persist_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("peek.hws");
        std::fs::write(&path, &bytes).unwrap();
        let meta = peek_header(&path).unwrap();
        assert_eq!(meta.workload, "fig2");
        assert_eq!(meta.base_fingerprint, None);
        assert!(meta.payload_len > 0);
        let _ = std::fs::remove_file(&path);
    }

    /// Saturate with the fig2 rules, snapshot that as the base, then
    /// extend the rule set (`split-relu-x4`) and re-saturate **in place**
    /// — the exact workflow deltas exist for. Returns the base bytes, the
    /// delta of the extended graph against it, and a full re-encode of
    /// the same extended graph.
    fn delta_fixture() -> (Vec<u8>, Vec<u8>, Vec<u8>) {
        let expr = parse_expr("(invoke-relu (relu-engine 128) (input x [128]))").unwrap();
        let opts = crate::extract::ExtractOptions { samples: 4, seed: 0, workers: 2 };
        let mut runner = Runner::new(expr.clone(), rewrites::fig2_rules());
        let base_report = runner.run(6);
        let base_cache = ExtractCache::new();
        crate::extract::extract_designs(&runner.egraph, runner.root, &opts, &base_cache);
        let base_names: Vec<String> =
            rewrites::fig2_rules().iter().map(|r| r.name.clone()).collect();
        let base_bytes = encode_snapshot(&SnapshotParts {
            workload_name: "fig2",
            workload_src: expr.to_string(),
            workload_description: None,
            lowered: &expr,
            rule_names: base_names,
            egraph: &runner.egraph,
            root: runner.root,
            report: &base_report,
            cache: &base_cache,
        });

        let mut ext_rules = rewrites::fig2_rules();
        ext_rules.push(rewrites::split::split_relu(4));
        let ext_names: Vec<String> = ext_rules.iter().map(|r| r.name.clone()).collect();
        let mut ext = Runner::from_egraph(runner.egraph, runner.root, ext_rules);
        let ext_report = ext.run(4);
        let ext_cache = ExtractCache::new();
        crate::extract::extract_designs(&ext.egraph, ext.root, &opts, &ext_cache);
        let parts = SnapshotParts {
            workload_name: "fig2",
            workload_src: expr.to_string(),
            workload_description: None,
            lowered: &expr,
            rule_names: ext_names,
            egraph: &ext.egraph,
            root: ext.root,
            report: &ext_report,
            cache: &ext_cache,
        };
        let full_bytes = encode_snapshot(&parts);
        let delta_bytes =
            encode_snapshot_delta(&parts, &base_bytes, "base.hws").expect("delta encodes");
        (base_bytes, delta_bytes, full_bytes)
    }

    fn reencode(s: &LoadedSnapshot) -> Vec<u8> {
        encode_snapshot(&SnapshotParts {
            workload_name: &s.meta.workload,
            workload_src: s.lowered.to_string(),
            workload_description: None,
            lowered: &s.lowered,
            rule_names: s.rule_names.clone(),
            egraph: &s.egraph,
            root: s.root,
            report: &s.report,
            cache: &s.cache,
        })
    }

    #[test]
    fn delta_overlay_is_bit_identical_to_full_snapshot() {
        let (base, delta, full) = delta_fixture();
        // The delta encodes only the diff, so it beats the full re-encode.
        assert!(delta.len() < full.len(), "delta {} >= full {}", delta.len(), full.len());
        assert_eq!(delta_base_name(&delta).unwrap(), "base.hws");
        let via_delta = decode_snapshot_delta(&delta, &base).expect("delta decodes");
        let direct = decode_snapshot(&full).expect("full decodes");
        assert_eq!(via_delta.meta.format_version, DELTA_FORMAT_VERSION);
        via_delta.egraph.check_invariants();
        // Bit-identical restored state: the encoder is deterministic, so
        // byte equality of the re-encodes is state equality of the loads.
        assert_eq!(reencode(&via_delta), reencode(&direct));
    }

    #[test]
    fn delta_corruption_matrix_is_typed_errors() {
        let (base, delta, _full) = delta_fixture();
        // Truncations at a spread of byte offsets.
        for cut in [0, 4, 11, delta.len() / 2, delta.len() - 1] {
            match decode_snapshot_delta(&delta[..cut], &base) {
                Err(Error::SnapshotCorrupt(_)) => {}
                other => panic!("cut at {cut}: expected SnapshotCorrupt, got {other:?}"),
            }
        }
        // A rewritten base file fails the fingerprint in the delta header.
        let mut wrong_base = base.clone();
        let last = wrong_base.len() - 1;
        wrong_base[last] ^= 0x01;
        match decode_snapshot_delta(&delta, &wrong_base) {
            Err(Error::SnapshotCorrupt(msg)) => assert!(msg.contains("fingerprint")),
            other => panic!("expected fingerprint mismatch, got {other:?}"),
        }
        // A delta chained on a delta is rejected even when the fingerprint
        // is made to match (header offset 36 = after magic, version,
        // "fig2", workload fingerprint, and rule-set hash).
        let mut chained = delta.clone();
        chained[36..44].copy_from_slice(&fx_bytes(&delta).to_le_bytes());
        match decode_snapshot_delta(&chained, &delta) {
            Err(Error::SnapshotCorrupt(msg)) => assert!(msg.contains("chain")),
            other => panic!("expected chain rejection, got {other:?}"),
        }
        // Loading a delta without its base is corrupt, not a panic.
        assert!(matches!(decode_snapshot(&delta), Err(Error::SnapshotCorrupt(_))));
    }

    #[test]
    fn delta_encode_gates_reject_foreign_bases() {
        let (base, delta, full) = delta_fixture();
        // A graph *loaded* from the extended snapshot has a mutation log
        // starting at its own epoch — it cannot attest it grew from the
        // older base, so the encoder refuses and demands a full snapshot.
        let loaded = decode_snapshot(&full).unwrap();
        let parts = SnapshotParts {
            workload_name: &loaded.meta.workload,
            workload_src: loaded.lowered.to_string(),
            workload_description: None,
            lowered: &loaded.lowered,
            rule_names: loaded.rule_names.clone(),
            egraph: &loaded.egraph,
            root: loaded.root,
            report: &loaded.report,
            cache: &loaded.cache,
        };
        assert!(matches!(
            encode_snapshot_delta(&parts, &base, "base.hws"),
            Err(Error::InvalidConfig(_))
        ));
        // A delta never serves as a base (chains are one level deep).
        assert!(matches!(
            encode_snapshot_delta(&parts, &delta, "delta.hws"),
            Err(Error::InvalidConfig(_))
        ));
    }

    #[test]
    fn embedded_workload_snapshots_roundtrip_as_v4() {
        let expr = parse_expr("(invoke-relu (relu-engine 128) (input x [128]))").unwrap();
        let mut runner = Runner::new(expr.clone(), rewrites::fig2_rules());
        let report = runner.run(6);
        let cache = ExtractCache::new();
        let src = "(relu (input x [128]))".to_string();
        let bytes = encode_snapshot(&SnapshotParts {
            workload_name: "imported_model",
            workload_src: src.clone(),
            workload_description: Some("imported from model.onnx".to_string()),
            lowered: &expr,
            rule_names: rewrites::fig2_rules().iter().map(|r| r.name.clone()).collect(),
            egraph: &runner.egraph,
            root: runner.root,
            report: &report,
            cache: &cache,
        });
        let snap = decode_snapshot(&bytes).expect("v4 decodes");
        assert_eq!(snap.meta.format_version, EMBED_FORMAT_VERSION);
        assert_eq!(snap.workload_src.as_deref(), Some(src.as_str()));
        assert_eq!(snap.workload_description.as_deref(), Some("imported from model.onnx"));
        assert_eq!(snap.meta.workload_fingerprint, workload_fingerprint(&src));
        snap.egraph.check_invariants();

        // Corrupting the embedded source must fail the fingerprint check.
        // The source string starts right after the payload checksum; its
        // bytes are inside the checksummed payload, so flip the header
        // fingerprint instead to isolate the source-vs-header check.
        let mut flipped = bytes.clone();
        // Header: magic(8) + version(4) + name(4 + 14) = offset 30 for the
        // workload fingerprint.
        flipped[30] ^= 0x01;
        assert!(matches!(decode_snapshot(&flipped), Err(Error::SnapshotCorrupt(_))));
    }

    #[test]
    fn constant_ops_persist_through_the_registry_codec() {
        use crate::ir::ConstData;
        let mut e = Enc::default();
        let op = Op::Constant(ConstData::new(Shape::new(&[2, 2]), &[1.5, -0.25, 0.0, 3.5]));
        e.node(&Node::new(op.clone(), vec![]));
        let mut d = Dec::new(&e.buf);
        let back = d.node("const node", 1).expect("const decodes");
        assert!(d.at_end());
        assert_eq!(back.op, op);
        assert!(back.children.is_empty());
    }
}
