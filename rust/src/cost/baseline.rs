//! The related-work baseline: **one engine per kernel type** (Hadjis &
//! Olukotun, FPL'19 — the paper's §4 comparison point).
//!
//! Their compiler instantiates exactly one hardware engine for each *type*
//! of kernel in the workload, sized for the largest call, and time-
//! multiplexes every call of that type through it. The paper's pitch is
//! that rewrite-based enumeration finds "more complex (but potentially
//! more profitable) splits" than this; experiment E3 measures exactly that
//! by comparing the enumerated Pareto frontier against this point.

use super::{engine_area, engine_cycles, CostParams, DesignCost};
use crate::ir::{Op, OpKind, RecExpr, Ty};
use std::collections::HashMap;

/// Per-kind shared engine chosen by the baseline, plus its call count.
#[derive(Debug, Clone)]
pub struct BaselineEngine {
    pub engine: Op,
    pub calls: usize,
}

/// The baseline design summary.
#[derive(Debug, Clone)]
pub struct Baseline {
    pub engines: Vec<BaselineEngine>,
    pub cost: DesignCost,
}

fn kind_key(op: &Op) -> OpKind {
    op.kind()
}

/// Merge two engines of the same kind into the elementwise-max-parameter
/// engine (the baseline's "sized for the largest call") — the merge rule
/// lives in the engine's registry spec.
fn max_engine(a: &Op, b: &Op) -> Op {
    match a.spec().engine {
        Some(e) if a.kind() == b.kind() => (e.merge_max)(a, b),
        _ => a.clone(),
    }
}

/// Engine I/O element count for one (maximal) invocation.
fn engine_io(op: &Op) -> f64 {
    match op.spec().engine {
        Some(e) => (e.io)(op),
        None => 0.0,
    }
}

/// Build the one-engine-per-kernel-type baseline for a lowered workload.
pub fn baseline(lowered: &RecExpr, p: &CostParams) -> Baseline {
    let tys = lowered.types().expect("baseline: lowered must typecheck");
    // Group call sites by engine kind; size each shared engine to the max.
    let mut shared: HashMap<OpKind, (Op, usize)> = HashMap::new();
    let mut sram_bytes = 0.0;
    for (slot, node) in lowered.nodes().iter().enumerate() {
        if node.op.is_invoke() {
            let engine = lowered.node(node.children[0]).op.clone();
            shared
                .entry(kind_key(&engine))
                .and_modify(|(e, c)| {
                    *e = max_engine(e, &engine);
                    *c += 1;
                })
                .or_insert((engine, 1));
        }
        if matches!(node.op, Op::Buffer { kind: crate::ir::BufKind::Sram })
            || matches!(node.op, Op::DblBuffer { kind: crate::ir::BufKind::Sram })
        {
            if let Ty::Tensor(s) = &tys[slot] {
                sram_bytes += s.numel() as f64 * 4.0;
            }
        }
    }

    let mut engines: Vec<BaselineEngine> = shared
        .into_values()
        .map(|(engine, calls)| BaselineEngine { engine, calls })
        .collect();
    engines.sort_by_key(|b| format!("{}", b.engine));

    let mut area = sram_bytes * p.sram_byte_area;
    let mut latency = 0.0;
    let mut energy = 0.0;
    let mut engine_area_total = 0.0;
    for be in &engines {
        engine_area_total += engine_area(&be.engine, p);
        // Every call streams through the (oversized) shared engine.
        let per_call = engine_cycles(&be.engine, engine_io(&be.engine), p);
        latency += be.calls as f64 * per_call;
        energy += be.calls as f64 * be.engine.engine_macs() as f64 * p.e_mac;
    }
    area += engine_area_total;
    // Buffer read/write traffic, as in the analytic model.
    latency += 2.0 * (sram_bytes / 4.0) / p.sram_bw;

    Baseline {
        engines,
        cost: DesignCost {
            area,
            latency,
            energy,
            engine_area: engine_area_total,
            sram_area: sram_bytes * p.sram_byte_area,
            dram_traffic: 0.0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::lower_default;
    use crate::relay::workloads;

    #[test]
    fn mlp_baseline_has_two_engine_types_plus_relu() {
        // mlp lowers to mm + add + relu invokes -> 3 kinds.
        let lo = lower_default(&workloads::mlp().expr).unwrap();
        let b = baseline(&lo, &CostParams::default());
        assert_eq!(b.engines.len(), 3);
        let mm = b.engines.iter().find(|e| matches!(e.engine, Op::MmEngine { .. })).unwrap();
        // Shared mm engine sized to the largest call: 1x784x128.
        assert_eq!(mm.engine, Op::MmEngine { m: 1, k: 784, n: 128 });
        assert_eq!(mm.calls, 3);
    }

    #[test]
    fn lenet_baseline_covers_all_kinds() {
        let lo = lower_default(&workloads::lenet().expr).unwrap();
        let b = baseline(&lo, &CostParams::default());
        let kinds: Vec<OpKind> = b.engines.iter().map(|e| e.engine.kind()).collect();
        assert!(kinds.contains(&OpKind::ConvEngine));
        assert!(kinds.contains(&OpKind::PoolEngine));
        assert!(kinds.contains(&OpKind::MmEngine));
        assert!(b.cost.area > 0.0 && b.cost.latency > 0.0);
    }

    #[test]
    fn baseline_area_at_most_initial_design() {
        // Sharing engines can only reduce engine area vs one-per-call-site
        // (per kind the baseline keeps the max engine only).
        let lo = lower_default(&workloads::mlp().expr).unwrap();
        let b = baseline(&lo, &CostParams::default());
        let (init, _) = crate::cost::analyze(&lo, &CostParams::default());
        assert!(b.cost.engine_area <= init.engine_area + 1e-9);
    }
}
