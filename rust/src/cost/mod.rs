//! Analytic hardware cost models over concrete EngineIR designs.
//!
//! The paper's evaluation needs designs ranked by whether they "could turn
//! into efficient hardware" (§3 *usefulness*) and spread over the split
//! spectrum (§3 *diversity*). This module provides:
//!
//! * [`CostParams`] — technology constants (per-MAC area, port widths,
//!   memory bandwidths), loosely calibrated to an FPGA-class substrate;
//! * [`cost_of`] — area / latency / energy of a design (a [`RecExpr`]);
//! * [`DesignStats`] — structural diversity features (distinct engines,
//!   schedule depth, parallel degree, buffer bytes).
//!
//! Model shape (deliberately simple, monotone, and documented — the paper's
//! claims are about *relative* orderings, not absolute LUT counts):
//!
//! * an engine is **spatial**: its area is proportional to its MAC count,
//!   and one invocation streams its operands through fixed-width ports, so
//!   `cycles ≈ startup + io_elems / port_width`;
//! * `sched-loop` time-multiplexes one engine instance (`extent ×` body
//!   cycles + per-iteration control overhead); `sched-par` replicates the
//!   engine (`max` of bodies ≈ body cycles + a merge term) and multiplies
//!   *area*;
//! * `sched-reduce` is a sequential dependency chain with an accumulate;
//! * buffers cost SRAM area and read+write traffic; DRAM buffers are
//!   area-free but slow; double buffers overlap producer/consumer (half
//!   visible traffic latency, double storage area);
//! * un-reified Relay ops fall back to "host execution" with a large
//!   penalty — enumerated designs that leave work in software-on-host are
//!   legal but rarely *useful*.

pub mod baseline;

pub use baseline::{baseline, Baseline, BaselineEngine};

use crate::fx::FxHashMap;
use crate::ir::spec::AreaClass;
use crate::ir::{BufKind, Op, OpClass, RecExpr, Shape, Ty};

/// Technology / substrate constants. `PartialEq` so query batching can
/// recognize "same params" and share evaluated design sets.
#[derive(Debug, Clone, PartialEq)]
pub struct CostParams {
    /// Area units per multiply-accumulate of a matmul/conv engine.
    pub mac_area: f64,
    /// Area units per lane of elementwise engines (relu/add/pool compare).
    pub lane_area: f64,
    /// Area units per byte of SRAM buffer.
    pub sram_byte_area: f64,
    /// Elements per cycle through an engine's streaming ports.
    pub port_width: f64,
    /// Engine invocation startup cycles (control, pipeline fill).
    pub startup: f64,
    /// Per-iteration loop control overhead, cycles.
    pub loop_overhead: f64,
    /// Elements per cycle to/from SRAM buffers.
    pub sram_bw: f64,
    /// Elements per cycle to/from DRAM.
    pub dram_bw: f64,
    /// Cycles per MAC when an op is left un-reified (host fallback).
    pub host_penalty: f64,
    /// Energy per MAC (pJ-ish arbitrary units).
    pub e_mac: f64,
    /// Energy per element moved through SRAM.
    pub e_sram: f64,
    /// Energy per element moved through DRAM.
    pub e_dram: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            mac_area: 1.0,
            lane_area: 0.1,
            sram_byte_area: 0.01,
            port_width: 16.0,
            startup: 4.0,
            loop_overhead: 2.0,
            sram_bw: 32.0,
            dram_bw: 4.0,
            host_penalty: 100.0,
            e_mac: 1.0,
            e_sram: 0.5,
            e_dram: 8.0,
        }
    }
}

/// Unit area of one instance of an engine declaration (registry-driven:
/// the engine's MAC count priced at its spec's area class).
pub fn engine_area(op: &Op, p: &CostParams) -> f64 {
    match op.spec().engine {
        Some(e) => {
            let unit = match e.area {
                AreaClass::Mac => p.mac_area,
                AreaClass::Lane => p.lane_area,
            };
            (e.macs)(op) as f64 * unit
        }
        None => 0.0,
    }
}

/// Cycles for one invocation of an engine (streaming model).
pub fn engine_cycles(op: &Op, io_elems: f64, p: &CostParams) -> f64 {
    let _ = op;
    p.startup + io_elems / p.port_width
}

/// Full cost breakdown of one concrete design.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DesignCost {
    /// Engine area + SRAM area (arbitrary units).
    pub area: f64,
    /// End-to-end cycles for one inference.
    pub latency: f64,
    /// Energy estimate.
    pub energy: f64,
    /// Engine area alone.
    pub engine_area: f64,
    /// SRAM buffer area alone.
    pub sram_area: f64,
    /// Total DRAM element traffic.
    pub dram_traffic: f64,
}

impl DesignCost {
    /// Scalar objective: weighted geometric blend used by guided extraction.
    pub fn scalar(&self, area_weight: f64) -> f64 {
        self.latency * (1.0 - area_weight) + self.area * area_weight
    }

    /// Pareto dominance on (area, latency).
    pub fn dominates(&self, other: &DesignCost) -> bool {
        (self.area <= other.area && self.latency < other.latency)
            || (self.area < other.area && self.latency <= other.latency)
    }
}

/// Structural diversity features of a design (experiment E2).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DesignStats {
    /// Distinct engine declarations.
    pub engines: usize,
    /// Total engine instances after `sched-par` replication.
    pub engine_instances: f64,
    /// Engine invocation sites.
    pub invokes: usize,
    /// Maximum schedule nesting depth.
    pub sched_depth: usize,
    /// Number of schedule nodes that are loops / pars / reduces.
    pub loops: usize,
    pub pars: usize,
    pub reduces: usize,
    /// Bytes of SRAM buffering.
    pub buffer_bytes: f64,
    /// Relay ops left un-reified.
    pub unreified: usize,
}

impl DesignStats {
    /// L1 distance in a normalized feature space — "how different are two
    /// design points" for the diversity experiment.
    pub fn distance(&self, other: &DesignStats) -> f64 {
        let f = |a: f64, b: f64| {
            let m = a.max(b).max(1.0);
            (a - b).abs() / m
        };
        f(self.engines as f64, other.engines as f64)
            + f(self.engine_instances, other.engine_instances)
            + f(self.invokes as f64, other.invokes as f64)
            + f(self.sched_depth as f64, other.sched_depth as f64)
            + f(self.loops as f64, other.loops as f64)
            + f(self.pars as f64, other.pars as f64)
            + f(self.buffer_bytes, other.buffer_bytes)
    }
}

struct Analyzer<'a> {
    expr: &'a RecExpr,
    tys: Vec<Ty>,
    p: &'a CostParams,
    /// engine op -> max concurrent instances demanded (par replication).
    /// Fx-hashed: `analyze` runs once per extracted design per query, so
    /// this map is on the serving layer's hot path.
    instances: FxHashMap<Op, f64>,
    sram_bytes: f64,
    dram_traffic: f64,
    energy: f64,
    stats: DesignStats,
    /// Per-slot free loop variables: loop-invariant subtrees (empty set)
    /// are *hoisted* — priced once, not once per consumer iteration.
    free: Vec<Vec<crate::ir::Symbol>>,
    hoisted: f64,
    visited: Vec<bool>,
}

impl<'a> Analyzer<'a> {
    fn shape(&self, id: crate::egraph::Id) -> &Shape {
        match &self.tys[id.index()] {
            Ty::Tensor(s) => s,
            _ => panic!("cost: expected tensor"),
        }
    }

    /// Latency contribution of the subtree at its consumption site.
    /// Loop-invariant subtrees are priced once into `self.hoisted`
    /// (producer materializes before the consuming schedule runs) and
    /// contribute 0 at each use — without this, a shared producer inside a
    /// consumer loop would be (mis)priced once per iteration, and nested
    /// layers would compound exponentially.
    fn walk(&mut self, id: crate::egraph::Id, par_mult: f64, depth: usize) -> f64 {
        let slot = id.index();
        if self.free[slot].is_empty() {
            if !self.visited[slot] {
                self.visited[slot] = true;
                // A hoisted producer executes ONCE regardless of how deep
                // inside consumer `sched-par`s it is referenced, so it
                // demands exactly one engine instance (par_mult = 1).
                let lat = self.walk_node(id, 1.0, depth);
                self.hoisted += lat;
            }
            return 0.0;
        }
        self.walk_node(id, par_mult, depth)
    }

    /// Price one node (see [`Self::walk`] for the hoisting wrapper).
    /// Dispatch is by registry *class*: the open categories (invokes, data
    /// movement, unreified Relay ops) price themselves from their spec, so
    /// new ops need no arm here.
    fn walk_node(&mut self, id: crate::egraph::Id, par_mult: f64, depth: usize) -> f64 {
        let node = self.expr.node(id).clone();
        let c = &node.children;
        let spec = node.op.spec();
        match &node.op {
            // Scalars, leaves and engine declarations are free here (engine
            // area is accounted at invocation sites).
            op if matches!(
                op.class(),
                OpClass::Index | OpClass::Leaf | OpClass::Engine
            ) =>
            {
                0.0
            }

            op if op.is_invoke() => {
                let engine = self.expr.node(c[0]).op.clone();
                let inst = self.instances.entry(engine.clone()).or_insert(0.0);
                *inst = inst.max(par_mult);
                self.stats.invokes += 1;

                // Operand latencies (operands stream in sequence with the
                // invocation in the simple model: sum).
                let mut lat = 0.0;
                let mut io: f64 = self.shape(id).numel() as f64; // output
                for &arg in &c[1..] {
                    lat += self.walk(arg, par_mult, depth);
                    io += self.shape(arg).numel() as f64;
                }
                self.energy += engine.engine_macs() as f64 * self.p.e_mac;
                lat + engine_cycles(&engine, io, self.p)
            }

            Op::SchedLoop { extent, .. } => {
                self.stats.loops += 1;
                self.stats.sched_depth = self.stats.sched_depth.max(depth + 1);
                let body = self.walk(c[0], par_mult, depth + 1);
                *extent as f64 * (body + self.p.loop_overhead)
            }
            Op::SchedPar { extent, .. } => {
                self.stats.pars += 1;
                self.stats.sched_depth = self.stats.sched_depth.max(depth + 1);
                let body = self.walk(c[0], par_mult * *extent as f64, depth + 1);
                // Concurrent bodies + a log-depth merge network.
                body + (*extent as f64).log2().ceil() * self.p.loop_overhead
            }
            Op::SchedReduce { extent, .. } => {
                self.stats.reduces += 1;
                self.stats.sched_depth = self.stats.sched_depth.max(depth + 1);
                let body = self.walk(c[0], par_mult, depth + 1);
                let out = self.shape(id).numel() as f64;
                let acc = out / self.p.port_width;
                *extent as f64 * (body + self.p.loop_overhead) + (*extent as f64 - 1.0) * acc
            }

            // Data movement: free addressing (slice/reshape/bcast) or a
            // materializing layout transform (pad2d/im2col/transpose),
            // per the spec's `data_traffic` flag. Index children price 0.
            op if matches!(op.class(), OpClass::Data) => {
                let mut lat = 0.0;
                for &arg in c {
                    lat += self.walk(arg, par_mult, depth);
                }
                if spec.data_traffic {
                    let out = self.shape(id).numel() as f64;
                    self.energy += out * self.p.e_sram;
                    lat + out / self.p.sram_bw
                } else {
                    lat
                }
            }

            Op::Buffer { kind } | Op::DblBuffer { kind } => {
                let elems = self.shape(id).numel() as f64;
                let bytes = elems * 4.0;
                let dbl = matches!(node.op, Op::DblBuffer { .. });
                let lat = self.walk(c[0], par_mult, depth);
                match kind {
                    BufKind::Sram => {
                        self.sram_bytes += bytes * if dbl { 2.0 } else { 1.0 } * par_mult;
                        self.stats.buffer_bytes += bytes * if dbl { 2.0 } else { 1.0 };
                        self.energy += 2.0 * elems * self.p.e_sram;
                        // write+read; double-buffering overlaps one side.
                        lat + (if dbl { 1.0 } else { 2.0 }) * elems / self.p.sram_bw
                    }
                    BufKind::Dram => {
                        self.dram_traffic += 2.0 * elems;
                        self.energy += 2.0 * elems * self.p.e_dram;
                        lat + (if dbl { 1.0 } else { 2.0 }) * elems / self.p.dram_bw
                    }
                }
            }

            // Un-reified Relay compute: host fallback, work model from the
            // op's spec (`host_work`, default output-element count).
            op => {
                debug_assert!(matches!(op.class(), OpClass::Relay), "unpriced op {op}");
                self.stats.unreified += 1;
                let mut lat = 0.0;
                for &arg in c {
                    lat += self.walk(arg, par_mult, depth);
                }
                let out = self.shape(id).clone();
                let child_shapes: Vec<&Shape> = c.iter().map(|&a| self.shape(a)).collect();
                let work = match spec.host_work {
                    Some(f) => f(op, &out, &child_shapes),
                    None => out.numel() as f64,
                };
                lat + work * self.p.host_penalty
            }
        }
    }
}

/// Compute the full cost breakdown and diversity stats of a design.
pub fn analyze(expr: &RecExpr, p: &CostParams) -> (DesignCost, DesignStats) {
    let tys = expr.types().expect("cost: design must be well-typed");
    let mut a = Analyzer {
        expr,
        tys,
        p,
        instances: Default::default(),
        sram_bytes: 0.0,
        dram_traffic: 0.0,
        energy: 0.0,
        stats: DesignStats::default(),
        free: expr.free_lvars(),
        hoisted: 0.0,
        visited: vec![false; expr.len()],
    };
    let residual = a.walk(expr.root(), 1.0, 0);
    // The root is loop-invariant, so its full latency lands in `hoisted`.
    let latency = a.hoisted + residual;

    let mut engine_area_total = 0.0;
    for (op, inst) in &a.instances {
        engine_area_total += engine_area(op, p) * inst;
    }
    a.stats.engines = a.instances.len();
    a.stats.engine_instances = a.instances.values().sum();

    let sram_area = a.sram_bytes * p.sram_byte_area;
    let cost = DesignCost {
        area: engine_area_total + sram_area,
        latency,
        energy: a.energy,
        engine_area: engine_area_total,
        sram_area,
        dram_traffic: a.dram_traffic,
    };
    (cost, a.stats)
}

/// Cost only (convenience).
pub fn cost_of(expr: &RecExpr, p: &CostParams) -> DesignCost {
    analyze(expr, p).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parse_expr;

    fn c(src: &str) -> (DesignCost, DesignStats) {
        analyze(&parse_expr(src).unwrap(), &CostParams::default())
    }

    const WHOLE: &str = "(invoke-relu (relu-engine 128) (input x [128]))";
    const LOOPED: &str = "(sched-loop i0 0 2 (invoke-relu (relu-engine 64) \
        (slice 0 64 (imul (lvar i0) 64) (input x [128]))))";
    const PARRED: &str = "(sched-par i0 0 2 (invoke-relu (relu-engine 64) \
        (slice 0 64 (imul (lvar i0) 64) (input x [128]))))";

    /// The paper's Fig. 2 economics: looping halves hardware but costs
    /// time; parallelizing buys the time back with more hardware.
    #[test]
    fn fig2_cost_ordering() {
        let (whole, _) = c(WHOLE);
        let (looped, _) = c(LOOPED);
        let (parred, _) = c(PARRED);
        // Area: looped (one 64-wide engine) < whole (one 128-wide)
        //       and parred (two 64-wide) == whole.
        assert!(looped.area < whole.area, "{} vs {}", looped.area, whole.area);
        assert!((parred.area - whole.area).abs() < 1e-9);
        // Latency: looped > whole; parred < looped.
        assert!(looped.latency > whole.latency);
        assert!(parred.latency < looped.latency);
    }

    #[test]
    fn par_replicates_instances() {
        let (_, s_loop) = c(LOOPED);
        let (_, s_par) = c(PARRED);
        assert_eq!(s_loop.engine_instances, 1.0);
        assert_eq!(s_par.engine_instances, 2.0);
    }

    #[test]
    fn sram_buffer_adds_area_dram_adds_traffic() {
        let (sram, _) = c("(buffer sram (invoke-relu (relu-engine 16) (input x [16])))");
        let (dram, _) = c("(buffer dram (invoke-relu (relu-engine 16) (input x [16])))");
        assert!(sram.sram_area > 0.0);
        assert_eq!(dram.sram_area, 0.0);
        assert!(dram.dram_traffic > 0.0);
        assert!(dram.latency > sram.latency, "DRAM must be slower");
        assert!(dram.area < sram.area, "DRAM must be cheaper in area");
    }

    #[test]
    fn double_buffer_trades_area_for_latency() {
        let (single, _) = c("(buffer sram (invoke-relu (relu-engine 16) (input x [16])))");
        let (double, _) = c("(dbl-buffer sram (invoke-relu (relu-engine 16) (input x [16])))");
        assert!(double.area > single.area);
        assert!(double.latency < single.latency);
    }

    #[test]
    fn unreified_relay_pays_host_penalty() {
        let (relay, _) = c("(relu (input x [128]))");
        let (engine, _) = c(WHOLE);
        assert!(relay.latency > 10.0 * engine.latency);
    }

    #[test]
    fn engine_sharing_shrinks_area() {
        // Two invocations of the SAME engine declaration cost one engine of
        // area (time-multiplexed) but twice the invocation latency.
        let one = "(invoke-relu (relu-engine 64) (input x [64]))";
        let two = "(invoke-relu (relu-engine 64) (invoke-relu (relu-engine 64) (input x [64])))";
        let (a, sa) = c(one);
        let (b, sb) = c(two);
        assert_eq!(sa.engines, 1);
        assert_eq!(sb.engines, 1);
        assert_eq!(sb.invokes, 2);
        assert!((a.area - b.area).abs() < 1e-9, "shared engine = same area");
        assert!(b.latency > a.latency);
    }

    #[test]
    fn dominance_is_strict() {
        let a = DesignCost { area: 1.0, latency: 1.0, ..Default::default() };
        let b = DesignCost { area: 2.0, latency: 2.0, ..Default::default() };
        assert!(a.dominates(&b));
        assert!(!b.dominates(&a));
        assert!(!a.dominates(&a));
    }

    #[test]
    fn stats_distance_symmetric_zero_on_self() {
        let (_, s1) = c(LOOPED);
        let (_, s2) = c(PARRED);
        assert_eq!(s1.distance(&s1), 0.0);
        assert!((s1.distance(&s2) - s2.distance(&s1)).abs() < 1e-12);
        assert!(s1.distance(&s2) > 0.0);
    }
}
