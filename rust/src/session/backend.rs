//! Pluggable evaluation backends: every way this crate can "run" a design
//! sits behind one [`Backend`] selector / [`Evaluator`] trait, so a
//! [`super::Session`] query picks its evaluator the same way it picks its
//! objective.
//!
//! | backend | what it measures | result fields |
//! |---|---|---|
//! | [`Backend::Analytic`] | closed-form area/latency/energy model | (cost is always computed) |
//! | [`Backend::Interp`] | functional output via the pure-Rust tensor evaluator | `output` |
//! | [`Backend::Sim`] | cycle-approximate schedule playout with engine contention | `sim` |
//! | [`Backend::Pjrt`] | functional output with invocations on AOT-compiled Pallas kernels | `output` |
//!
//! `Pjrt` needs the `pjrt` cargo feature + built artifacts; without them the
//! evaluator constructor returns a typed error and callers degrade
//! gracefully.

use crate::cost::CostParams;
use crate::error::Error;
use crate::ir::RecExpr;
use crate::sim::{simulate, SimConfig, SimReport};
use crate::tensor::{eval_expr, eval_expr_backend, Env, Tensor};

/// Which evaluation backend a [`super::Query`] runs designs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Closed-form analytic cost model only (fastest; always available).
    Analytic,
    /// The pure-Rust EngineIR evaluator — produces functional outputs
    /// (the semantics oracle).
    Interp,
    /// The cycle-approximate accelerator simulator (usefulness oracle).
    Sim,
    /// The PJRT runtime: engine invocations on AOT-compiled Pallas
    /// kernels, software schedule in Rust. Requires `--features pjrt`.
    Pjrt,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Analytic => "analytic",
            Backend::Interp => "interp",
            Backend::Sim => "sim",
            Backend::Pjrt => "pjrt",
        }
    }

    /// Whether per-design evaluators are cheap and isolated enough to run
    /// one per work item on the worker pool. The PJRT runtime holds a
    /// process-wide client and a compile cache, so it evaluates serially
    /// through one evaluator instead.
    pub(crate) fn parallel_safe(self) -> bool {
        !matches!(self, Backend::Pjrt)
    }

    /// Construct the evaluator for this backend.
    pub fn evaluator(self) -> Result<Box<dyn Evaluator>, Error> {
        Ok(match self {
            Backend::Analytic => Box::new(AnalyticEval),
            Backend::Interp => Box::new(InterpEval),
            Backend::Sim => Box::new(SimEval),
            Backend::Pjrt => Box::new(PjrtEval::open()?),
        })
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Backend {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self, Error> {
        match s {
            "analytic" => Ok(Backend::Analytic),
            "interp" => Ok(Backend::Interp),
            "sim" => Ok(Backend::Sim),
            "pjrt" => Ok(Backend::Pjrt),
            other => Err(Error::UnknownBackend(other.to_string())),
        }
    }
}

/// What one backend run of one design produced, beyond the analytic cost
/// (which every design point carries regardless of backend).
#[derive(Debug, Clone, Default)]
pub struct BackendReport {
    /// Simulator report ([`Backend::Sim`]).
    pub sim: Option<SimReport>,
    /// Functional output tensor ([`Backend::Interp`] / [`Backend::Pjrt`]).
    pub output: Option<Tensor>,
}

/// One evaluation backend. Implementations are stateful (`&mut self`) so
/// runtimes can keep compile caches across designs. (No `Send` bound:
/// parallel evaluation constructs one evaluator per worker-local design,
/// so evaluators never cross threads — which keeps non-`Send` runtime
/// clients usable.)
pub trait Evaluator {
    fn backend(&self) -> Backend;

    /// Evaluate one concrete design. `seed` derives the input tensors for
    /// functional backends, so the same seed across designs (and across
    /// backends) yields directly comparable outputs.
    fn evaluate(
        &mut self,
        expr: &RecExpr,
        params: &CostParams,
        seed: u64,
    ) -> Result<BackendReport, Error>;
}

/// Analytic model only — the cost is computed for every design point
/// anyway, so this backend adds nothing per design.
struct AnalyticEval;

impl Evaluator for AnalyticEval {
    fn backend(&self) -> Backend {
        Backend::Analytic
    }

    fn evaluate(
        &mut self,
        _expr: &RecExpr,
        _params: &CostParams,
        _seed: u64,
    ) -> Result<BackendReport, Error> {
        Ok(BackendReport::default())
    }
}

/// Pure-Rust functional evaluation (the `tensor` oracle).
struct InterpEval;

impl Evaluator for InterpEval {
    fn backend(&self) -> Backend {
        Backend::Interp
    }

    fn evaluate(
        &mut self,
        expr: &RecExpr,
        _params: &CostParams,
        seed: u64,
    ) -> Result<BackendReport, Error> {
        let out = eval_expr(expr, &mut Env::random_for(expr, seed))?;
        Ok(BackendReport { output: Some(out), ..Default::default() })
    }
}

/// Cycle-approximate simulation.
struct SimEval;

impl Evaluator for SimEval {
    fn backend(&self) -> Backend {
        Backend::Sim
    }

    fn evaluate(
        &mut self,
        expr: &RecExpr,
        params: &CostParams,
        _seed: u64,
    ) -> Result<BackendReport, Error> {
        let sim = simulate(expr, &SimConfig { params: params.clone() });
        Ok(BackendReport { sim: Some(sim), ..Default::default() })
    }
}

/// PJRT execution: invocations on compiled kernels, schedule in Rust.
/// Engines missing from the artifact manifest fall back to the oracle so
/// arbitrary enumerated designs stay evaluable.
struct PjrtEval {
    backend: crate::runtime::PjrtBackend,
}

impl PjrtEval {
    fn open() -> Result<Self, Error> {
        let rt = crate::runtime::EngineRuntime::open_default()?;
        Ok(PjrtEval { backend: crate::runtime::PjrtBackend::new(rt).with_fallback() })
    }
}

impl Evaluator for PjrtEval {
    fn backend(&self) -> Backend {
        Backend::Pjrt
    }

    fn evaluate(
        &mut self,
        expr: &RecExpr,
        _params: &CostParams,
        seed: u64,
    ) -> Result<BackendReport, Error> {
        let out =
            eval_expr_backend(expr, &mut Env::random_for(expr, seed), &mut self.backend)?;
        Ok(BackendReport { output: Some(out), ..Default::default() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parse_expr;

    #[test]
    fn backend_from_str_roundtrip() {
        for b in [Backend::Analytic, Backend::Interp, Backend::Sim, Backend::Pjrt] {
            assert_eq!(b.name().parse::<Backend>().unwrap(), b);
        }
        assert!(matches!(
            "verilog".parse::<Backend>().unwrap_err(),
            Error::UnknownBackend(ref n) if n == "verilog"
        ));
    }

    #[test]
    fn interp_and_sim_report_their_channels() {
        let e = parse_expr("(invoke-relu (relu-engine 16) (input x [16]))").unwrap();
        let p = CostParams::default();
        let r = Backend::Interp.evaluator().unwrap().evaluate(&e, &p, 1).unwrap();
        assert!(r.output.is_some() && r.sim.is_none());
        let r = Backend::Sim.evaluator().unwrap().evaluate(&e, &p, 1).unwrap();
        assert!(r.sim.is_some() && r.output.is_none());
        let r = Backend::Analytic.evaluator().unwrap().evaluate(&e, &p, 1).unwrap();
        assert!(r.sim.is_none() && r.output.is_none());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_unavailable_is_typed() {
        let err = Backend::Pjrt.evaluator().unwrap_err();
        assert!(matches!(err, Error::Unsupported(_)), "{err}");
    }
}
