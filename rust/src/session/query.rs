//! Queries and their results: what you ask a [`super::Session`] once the
//! design space is enumerated. A query is cheap relative to enumeration —
//! extraction + evaluation over the shared read-only e-graph — so changing
//! the objective, the sample count, the cost parameters or the backend and
//! asking again is the intended usage pattern.

use super::backend::{Backend, BackendReport};
use crate::cost::{Baseline, CostParams, DesignCost};
use crate::extract::{DesignPoint, ExtractReport};
use crate::sim::SimReport;
use crate::tensor::Tensor;
use std::time::{Duration, Instant};

/// What "best" means for a query.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Minimize end-to-end latency.
    Latency,
    /// Minimize area.
    Area,
    /// Minimize `latency·(1-w) + area·w` for the given weight in `[0,1]`.
    Balanced(f64),
}

impl Objective {
    /// Scalar score (lower is better) of one design cost.
    pub fn score(&self, c: &DesignCost) -> f64 {
        match self {
            Objective::Latency => c.latency,
            Objective::Area => c.area,
            Objective::Balanced(w) => c.scalar(*w),
        }
    }
}

impl std::str::FromStr for Objective {
    type Err = crate::error::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "latency" => Ok(Objective::Latency),
            "area" => Ok(Objective::Area),
            "balanced" => Ok(Objective::Balanced(0.5)),
            other => Err(crate::error::Error::InvalidConfig(format!(
                "unknown objective '{other}' (expected latency | area | balanced)"
            ))),
        }
    }
}

/// One question against an enumerated design space. Builder-style:
///
/// ```ignore
/// Query::new().objective(Objective::Latency).samples(256).backend(Backend::Sim)
/// ```
#[derive(Debug, Clone)]
pub struct Query {
    pub objective: Objective,
    /// Randomized-extraction sample count (greedy endpoints are added on
    /// top).
    pub samples: usize,
    /// Base seed for sampled extraction *and* for the input tensors of
    /// functional backends.
    pub seed: u64,
    pub backend: Backend,
    pub params: CostParams,
    /// Optional wall-clock deadline. Extraction and evaluation check it
    /// cooperatively at phase boundaries and return [`Error::Timeout`]
    /// instead of running past it — the serving daemon derives one from
    /// `--request-timeout-ms` at request receipt. `None` (the default)
    /// means no deadline.
    ///
    /// [`Error::Timeout`]: crate::error::Error::Timeout
    pub deadline: Option<Instant>,
}

impl Default for Query {
    fn default() -> Self {
        Query {
            objective: Objective::Latency,
            samples: 64,
            seed: 0,
            backend: Backend::Analytic,
            params: CostParams::default(),
            deadline: None,
        }
    }
}

impl Query {
    pub fn new() -> Self {
        Query::default()
    }

    pub fn objective(mut self, o: Objective) -> Self {
        self.objective = o;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn backend(mut self, b: Backend) -> Self {
        self.backend = b;
        self
    }

    pub fn params(mut self, p: CostParams) -> Self {
        self.params = p;
        self
    }

    /// Absolute deadline for answering this query.
    pub fn deadline(mut self, at: Instant) -> Self {
        self.deadline = Some(at);
        self
    }

    /// Relative deadline: `budget` of wall-clock from now.
    pub fn deadline_in(self, budget: Duration) -> Self {
        self.deadline(Instant::now() + budget)
    }

    /// Cooperative deadline check, shared by every query-answering phase
    /// (and by the serving daemon): `Err(Error::Timeout)` once the
    /// deadline has passed, `Ok(())` otherwise (including when no
    /// deadline is set).
    pub fn check_deadline(&self, phase: &'static str) -> Result<(), crate::error::Error> {
        match self.deadline {
            Some(d) if Instant::now() > d => Err(crate::error::Error::Timeout { phase }),
            _ => Ok(()),
        }
    }
}

/// One design evaluated by a query's backend.
#[derive(Debug, Clone)]
pub struct EvaluatedDesign {
    pub point: DesignPoint,
    /// Simulator report when the query ran on [`Backend::Sim`].
    pub sim: Option<SimReport>,
    /// Functional output when the query ran on [`Backend::Interp`] or
    /// [`Backend::Pjrt`].
    pub output: Option<Tensor>,
}

impl EvaluatedDesign {
    pub(crate) fn new(point: DesignPoint, report: BackendReport) -> Self {
        EvaluatedDesign { point, sim: report.sim, output: report.output }
    }
}

/// The answer to one [`Query`]: evaluated designs, the area/latency Pareto
/// frontier among them (streamed — see [`crate::extract::ParetoFrontier`]),
/// the one-engine-per-kernel-type baseline under the query's cost
/// parameters, and the extraction-side run stats (throughput, memo hit
/// rate, frontier trajectory). In a [`super::Session::run_queries`] batch,
/// `extract` describes the shared extraction pass the batch reused.
#[derive(Debug)]
pub struct Evaluation {
    pub workload: String,
    pub backend: Backend,
    pub objective: Objective,
    pub designs: Vec<EvaluatedDesign>,
    pub frontier: Vec<DesignPoint>,
    pub baseline: Baseline,
    pub extract: ExtractReport,
}

impl Evaluation {
    /// The best design under this query's objective.
    pub fn best(&self) -> Option<&EvaluatedDesign> {
        self.designs.iter().min_by(|a, b| {
            self.objective
                .score(&a.point.cost)
                .total_cmp(&self.objective.score(&b.point.cost))
        })
    }

    /// Experiment E3 summary: does the enumerated frontier dominate the
    /// baseline point, and from which side?
    pub fn frontier_vs_baseline(&self) -> String {
        frontier_vs_baseline_summary(&self.frontier, &self.baseline.cost)
    }
}

/// Shared E3 summary formatter.
pub fn frontier_vs_baseline_summary(frontier: &[DesignPoint], b: &DesignCost) -> String {
    let dominating = frontier.iter().filter(|p| p.cost.dominates(b)).count();
    let smaller = frontier.iter().filter(|p| p.cost.area < b.area).count();
    let faster = frontier.iter().filter(|p| p.cost.latency < b.latency).count();
    format!(
        "baseline(area={:.1}, lat={:.1}) | frontier: {} points, {} dominate baseline, \
         {} smaller-area, {} lower-latency",
        b.area,
        b.latency,
        frontier.len(),
        dominating,
        smaller,
        faster
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_scores() {
        let c = DesignCost { area: 10.0, latency: 100.0, ..Default::default() };
        assert_eq!(Objective::Latency.score(&c), 100.0);
        assert_eq!(Objective::Area.score(&c), 10.0);
        assert_eq!(Objective::Balanced(0.5).score(&c), 55.0);
    }

    #[test]
    fn query_builder_chains() {
        let q = Query::new()
            .objective(Objective::Area)
            .samples(7)
            .seed(3)
            .backend(Backend::Sim);
        assert_eq!(q.objective, Objective::Area);
        assert_eq!(q.samples, 7);
        assert_eq!(q.seed, 3);
        assert_eq!(q.backend, Backend::Sim);
    }

    #[test]
    fn deadline_check_is_none_by_default_and_trips_when_past() {
        let q = Query::new();
        assert!(q.deadline.is_none());
        assert!(q.check_deadline("extract").is_ok());
        let generous = Query::new().deadline_in(Duration::from_secs(3600));
        assert!(generous.check_deadline("extract").is_ok());
        let expired = Query::new().deadline(Instant::now() - Duration::from_millis(1));
        let err = expired.check_deadline("evaluate").unwrap_err();
        assert!(matches!(err, crate::error::Error::Timeout { phase: "evaluate" }), "{err}");
    }

    #[test]
    fn objective_from_str() {
        assert_eq!("latency".parse::<Objective>().unwrap(), Objective::Latency);
        assert_eq!("area".parse::<Objective>().unwrap(), Objective::Area);
        assert!("speed".parse::<Objective>().is_err());
    }
}
