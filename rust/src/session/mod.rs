//! The primary API: a reusable, backend-pluggable design-space-exploration
//! **session**.
//!
//! The paper's economic argument is that the e-graph makes the
//! hardware–software design space *cheap to re-query*: the expensive step —
//! enumerating every split with rewrites — happens once, and then many
//! different designs can be extracted and evaluated from the same
//! structure. [`Session`] is that shape as an API:
//!
//! ```no_run
//! use hwsplit::session::{Backend, Objective, Query, Session};
//! use hwsplit::relay::workloads;
//! use hwsplit::rewrites::RuleSet;
//!
//! let mut session = Session::builder()
//!     .workload(workloads::mlp())
//!     .rules(RuleSet::All)
//!     .build()?;
//!
//! // First query enumerates (once, lazily) then extracts + evaluates.
//! let fast = session.query(&Query::new().objective(Objective::Latency).samples(256))?;
//! // Re-querying with a different objective / backend / cost params only
//! // re-runs extraction + evaluation on the shared read-only e-graph.
//! let small = session.query(&Query::new().objective(Objective::Area).backend(Backend::Sim))?;
//! assert_eq!(session.enumeration_count(), 1);
//! # let _ = (fast, small);
//! # Ok::<(), hwsplit::Error>(())
//! ```
//!
//! Evaluation backends are pluggable ([`Backend`]): the analytic cost
//! model, the pure-Rust interpreter, the cycle-approximate simulator, and
//! (with `--features pjrt`) the PJRT runtime executing AOT-compiled Pallas
//! kernels.
//!
//! Threading: the enumeration *commit* step mutates the e-graph
//! single-threaded, but everything else — rule search, the apply phase's
//! wave-parallel staging of rewrite right-hand sides, extraction and
//! evaluation — only reads, fanning out across the same scoped worker pool
//! ([`parallel_map`], shared via [`crate::par`]). Enumeration knobs:
//! [`SessionBuilder::scheduler`] picks the rule-fairness policy,
//! [`SessionBuilder::search_workers`] / [`SessionBuilder::apply_workers`]
//! size the search and apply pools (bit-identical results for any width),
//! and [`SessionBuilder::track_designs`] opts back in to per-iteration
//! design counting (off by default here — sessions enumerate once and
//! query, they don't plot growth curves).
//!
//! The read side is parallel, memoized and streaming (see
//! [`crate::extract`]): sampled extractions fan out over
//! [`SessionBuilder::extract_workers`] (bit-identical results for any
//! width), the per-cost-function extraction fixpoints are memoized in a
//! session-owned [`crate::extract::ExtractCache`] — so a repeat query pays
//! **zero** fixpoint rebuilds, pinned by the memo stats in
//! [`Evaluation`]'s `extract` report — and the Pareto frontier
//! is maintained incrementally as evaluated designs stream in.
//! [`Session::run_queries`] answers a whole batch of queries against one
//! shared design sample set.

mod backend;
mod query;

pub use backend::{Backend, BackendReport, Evaluator};
pub use query::{
    frontier_vs_baseline_summary, EvaluatedDesign, Evaluation, Objective, Query,
};

pub use crate::rewrites::RuleSet;

use crate::cost::baseline;
use crate::egraph::{EGraph, Id, Rewrite, Runner, RunnerLimits, RunnerReport, Scheduler};
use crate::error::Error;
use crate::extract::{
    analyze_points, extract_designs, DesignPoint, ExtractCache, ExtractOptions, ExtractReport,
    ExtractedSet, ParetoFrontier,
};
use crate::ir::RecExpr;
use crate::lower::{lower, LowerOptions};
pub use crate::par::parallel_map;
use crate::par::default_workers;
use crate::persist;
use crate::relay::Workload;
use std::path::Path;

/// The enumerated design space: the e-graph after rewriting, its root
/// class, and the growth report. Shared read-only by every query.
#[derive(Debug)]
pub struct Enumeration {
    pub egraph: EGraph,
    pub root: Id,
    pub report: RunnerReport,
}

/// Configures and creates a [`Session`]. Obtain via [`Session::builder`].
#[derive(Debug, Default)]
pub struct SessionBuilder {
    workload: Option<Workload>,
    rules: Option<RuleSet>,
    custom_rules: Option<Vec<Rewrite>>,
    iters: Option<usize>,
    workers: Option<usize>,
    search_workers: Option<usize>,
    apply_workers: Option<usize>,
    extract_workers: Option<usize>,
    scheduler: Option<Box<dyn Scheduler>>,
    track_designs: Option<bool>,
    limits: Option<RunnerLimits>,
    lower_opts: Option<LowerOptions>,
}

impl SessionBuilder {
    /// The workload to explore (required).
    pub fn workload(mut self, w: Workload) -> Self {
        self.workload = Some(w);
        self
    }

    /// Which rewrite set to enumerate with (default: [`RuleSet::Paper`]).
    pub fn rules(mut self, rules: RuleSet) -> Self {
        self.rules = Some(rules);
        self
    }

    /// Enumerate with an explicit rule list instead of a named set (used by
    /// the ablation bench to knock out rule groups).
    pub fn custom_rules(mut self, rules: Vec<Rewrite>) -> Self {
        self.custom_rules = Some(rules);
        self
    }

    /// Rewrite iteration budget (default 8; further bounded by `limits`).
    pub fn iters(mut self, iters: usize) -> Self {
        self.iters = Some(iters);
        self
    }

    /// Worker-pool width for extraction/evaluation (default: available
    /// parallelism). Also the enumeration search phase's default width
    /// unless [`SessionBuilder::search_workers`] overrides it.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers);
        self
    }

    /// Worker-pool width for the enumeration search phase specifically
    /// (default: the [`SessionBuilder::workers`] setting). Results are
    /// deterministic for any width.
    pub fn search_workers(mut self, workers: usize) -> Self {
        self.search_workers = Some(workers);
        self
    }

    /// Worker-pool width for the enumeration apply phase's staging fan-out
    /// (default: the [`SessionBuilder::workers`] setting). Intents are
    /// committed in deterministic stream order, so the resulting e-graph is
    /// bit-identical for any width.
    pub fn apply_workers(mut self, workers: usize) -> Self {
        self.apply_workers = Some(workers);
        self
    }

    /// Worker-pool width for the extraction sample fan-out specifically
    /// (default: the [`SessionBuilder::workers`] setting). The extracted
    /// design set is bit-identical for any width.
    pub fn extract_workers(mut self, workers: usize) -> Self {
        self.extract_workers = Some(workers);
        self
    }

    /// Rule scheduler for enumeration (default: the engine's
    /// [`crate::egraph::SimpleScheduler`] built from the limits'
    /// `max_matches_per_rule`). Pass e.g.
    /// `Box::new(BackoffScheduler::default())` for egg-style backoff.
    pub fn scheduler(mut self, scheduler: Box<dyn Scheduler>) -> Self {
        self.scheduler = Some(scheduler);
        self
    }

    /// Recompute the distinct-design lower bound after every enumeration
    /// iteration. Off by default in the session path — it is an
    /// `O(nodes × rounds)` fixpoint per iteration that only growth
    /// experiments read; the final count in the report is always computed.
    /// When set, this takes precedence over `RunnerLimits::track_designs`
    /// in [`SessionBuilder::limits`].
    pub fn track_designs(mut self, on: bool) -> Self {
        self.track_designs = Some(on);
        self
    }

    /// Enumeration budgets (node/time/match caps). One caveat: sessions
    /// control per-iteration design counting themselves (off unless
    /// [`SessionBuilder::track_designs`] opts in), so the
    /// `RunnerLimits::track_designs` field of a limits struct passed here
    /// is ignored — `..Default::default()` would otherwise silently drag
    /// in the bare-`Runner` default of `true`.
    pub fn limits(mut self, limits: RunnerLimits) -> Self {
        self.limits = Some(limits);
        self
    }

    /// Lowering options (default: buffered reification, the paper's Fig. 1).
    pub fn lower_opts(mut self, opts: LowerOptions) -> Self {
        self.lower_opts = Some(opts);
        self
    }

    /// Lower the workload and produce a session. Enumeration has NOT run
    /// yet — it happens lazily on the first query (or an explicit
    /// [`Session::enumerate`]).
    pub fn build(self) -> Result<Session, Error> {
        let workload = self
            .workload
            .ok_or_else(|| Error::InvalidConfig("session has no workload".into()))?;
        let rules = match (self.custom_rules, self.rules) {
            (Some(_), Some(_)) => {
                return Err(Error::InvalidConfig(
                    "set either rules(RuleSet) or custom_rules(Vec<Rewrite>), not both".into(),
                ))
            }
            (Some(custom), None) => custom,
            (None, set) => set.unwrap_or(RuleSet::Paper).rules(),
        };
        let lowered = lower(&workload.expr, self.lower_opts.unwrap_or_default())?;
        // Worker widths are ≥ 1 (0 would be meaningless; the pool also
        // clamps, this just keeps the session's own bookkeeping sane).
        let workers = self.workers.unwrap_or_else(default_workers).max(1);
        // Sessions enumerate once and answer queries; per-iteration design
        // counting is a growth-experiment concern, so the session path
        // controls it via the builder flag (default off) rather than the
        // limits field — `RunnerLimits::default()` says `true` for bare
        // `Runner`s, which would silently opt every session in. See
        // `SessionBuilder::limits`.
        let mut limits = self.limits.unwrap_or_default();
        limits.track_designs = self.track_designs.unwrap_or(false);
        Ok(Session {
            workload,
            lowered,
            rules,
            iters: self.iters.unwrap_or(8),
            workers,
            search_workers: self.search_workers.unwrap_or(workers).max(1),
            apply_workers: self.apply_workers.unwrap_or(workers).max(1),
            extract_workers: self.extract_workers.unwrap_or(workers).max(1),
            scheduler: self.scheduler,
            limits,
            enumerated: None,
            enumerations: 0,
            extract_cache: ExtractCache::new(),
        })
    }
}

fn vlog(phase: &str, t0: std::time::Instant) {
    if std::env::var_os("HWSPLIT_VERBOSE").is_some() {
        eprintln!("[session] {phase}: {:.2?}", t0.elapsed());
    }
}

/// A reusable exploration session: owns the lowered workload and the
/// (lazily built, cached) enumerated e-graph, and answers repeated
/// [`Query`]s against it. See the module docs for the usage pattern.
#[derive(Debug)]
pub struct Session {
    workload: Workload,
    lowered: RecExpr,
    rules: Vec<Rewrite>,
    iters: usize,
    workers: usize,
    search_workers: usize,
    apply_workers: usize,
    extract_workers: usize,
    scheduler: Option<Box<dyn Scheduler>>,
    limits: RunnerLimits,
    enumerated: Option<Enumeration>,
    enumerations: usize,
    /// Memo of solved extraction cost tables, shared read-only across
    /// queries (and across the extraction worker pool); self-invalidates on
    /// graph-epoch change, which for a session means never after
    /// enumeration — so every query past the first pays zero fixpoint
    /// rebuilds for seeds it has seen.
    extract_cache: ExtractCache,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Shorthand for a default-configured session on one workload.
    pub fn new(workload: Workload) -> Result<Self, Error> {
        Session::builder().workload(workload).build()
    }

    /// The workload this session explores.
    pub fn workload(&self) -> &Workload {
        &self.workload
    }

    /// The reified (EngineIR) initial design.
    pub fn lowered(&self) -> &RecExpr {
        &self.lowered
    }

    /// How many times rewrite enumeration has actually run. Stays at 1 no
    /// matter how many queries are issued — the test suite pins this.
    pub fn enumeration_count(&self) -> usize {
        self.enumerations
    }

    /// Run rewrite enumeration if it has not run yet; return the cached
    /// [`Enumeration`] either way.
    pub fn enumerate(&mut self) -> Result<&Enumeration, Error> {
        if self.enumerated.is_none() {
            let t0 = std::time::Instant::now();
            let mut runner = Runner::new(self.lowered.clone(), self.rules.clone())
                .with_limits(self.limits.clone())
                .with_search_workers(self.search_workers)
                .with_apply_workers(self.apply_workers);
            if let Some(scheduler) = self.scheduler.take() {
                runner = runner.with_scheduler(scheduler);
            }
            let report = runner.run(self.iters);
            self.enumerated =
                Some(Enumeration { egraph: runner.egraph, root: runner.root, report });
            self.enumerations += 1;
            vlog("enumerate", t0);
        }
        Ok(self.enumerated.as_ref().expect("just enumerated"))
    }

    /// Answer one query: extract candidate designs from the (shared,
    /// read-only) e-graph — parallel sample fan-out, cost fixpoints served
    /// from the session memo — and evaluate them on the query's backend.
    /// The first call triggers enumeration; subsequent calls — with
    /// different objectives, sample counts, cost parameters or backends —
    /// reuse both the e-graph and every cost table already solved.
    pub fn query(&mut self, q: &Query) -> Result<Evaluation, Error> {
        let set = self.extract(q.samples, q.seed)?;
        self.answer(q, &set)
    }

    /// Answer a batch of queries against **one shared design sample set**:
    /// the extraction pass runs once per distinct `(samples, seed)` pair —
    /// once total for the common batch that varies only objective, backend
    /// or cost params — and analysis + backend evaluation run once per
    /// distinct `(samples, seed, backend, params)`, so a batch that varies
    /// only the *objective* (which affects ranking, not measurement) pays
    /// extraction AND evaluation exactly once. Mixed-seed batches still
    /// share every cost-table fixpoint through the session memo. Results
    /// are identical to issuing the queries one by one.
    pub fn run_queries(&mut self, queries: &[Query]) -> Result<Vec<Evaluation>, Error> {
        type SetKey = (usize, u64);
        type EvalKey = (SetKey, Backend, crate::cost::CostParams);
        // Each query's evaluation identity, precomputed so the last user of
        // a shared evaluation can take it by move instead of cloning.
        let ekeys: Vec<EvalKey> = queries
            .iter()
            .map(|q| ((q.samples, q.seed), q.backend, q.params.clone()))
            .collect();
        let mut sets: Vec<(SetKey, ExtractedSet)> = Vec::new();
        let mut evals: Vec<(EvalKey, Vec<EvaluatedDesign>)> = Vec::new();
        let mut out = Vec::with_capacity(queries.len());
        for (i, q) in queries.iter().enumerate() {
            let key = (q.samples, q.seed);
            if !sets.iter().any(|(k, _)| *k == key) {
                let set = self.extract(q.samples, q.seed)?;
                sets.push((key, set));
            }
            let set = &sets.iter().find(|(k, _)| *k == key).expect("inserted above").1;
            if !evals.iter().any(|(k, _)| *k == ekeys[i]) {
                let designs = self.evaluate_set(q, set)?;
                evals.push((ekeys[i].clone(), designs));
            }
            let pos = evals.iter().position(|(k, _)| *k == ekeys[i]).expect("inserted above");
            let designs = if ekeys[i + 1..].contains(&ekeys[i]) {
                evals[pos].1.clone()
            } else {
                evals.swap_remove(pos).1
            };
            out.push(self.finish(q, set, designs));
        }
        Ok(out)
    }

    /// The shared extraction pass (enumerating first if needed): greedy
    /// endpoints + seeded samples over the worker pool, fixpoints through
    /// the session memo.
    fn extract(&mut self, samples: usize, seed: u64) -> Result<ExtractedSet, Error> {
        self.enumerate()?;
        let en = self.enumerated.as_ref().expect("enumerated above");
        let t0 = std::time::Instant::now();
        let opts = ExtractOptions { samples, seed, workers: self.extract_workers };
        let set = extract_designs(&en.egraph, en.root, &opts, &self.extract_cache);
        vlog("extract", t0);
        Ok(set)
    }

    /// Analyze + evaluate one extracted set under one query, streaming the
    /// Pareto frontier as evaluated designs arrive.
    fn answer(&self, q: &Query, set: &ExtractedSet) -> Result<Evaluation, Error> {
        let designs = self.evaluate_set(q, set)?;
        Ok(self.finish(q, set, designs))
    }

    /// The measurement half of a query: analyze the shared design set under
    /// the query's cost params, then run its backend. Depends on
    /// `(backend, params, seed)` but NOT the objective, so batches share it.
    fn evaluate_set(&self, q: &Query, set: &ExtractedSet) -> Result<Vec<EvaluatedDesign>, Error> {
        let t0 = std::time::Instant::now();
        let points = analyze_points(&set.designs, &q.params, self.extract_workers);
        let designs = evaluate_all(q, points, self.workers)?;
        vlog("evaluate", t0);
        Ok(designs)
    }

    /// The ranking half of a query: stream the Pareto frontier over the
    /// evaluated designs (dominated-point eviction per insert, trajectory
    /// into the report) and assemble the [`Evaluation`].
    fn finish(&self, q: &Query, set: &ExtractedSet, designs: Vec<EvaluatedDesign>) -> Evaluation {
        let mut frontier = ParetoFrontier::new();
        let mut frontier_sizes = Vec::with_capacity(designs.len());
        for d in &designs {
            frontier.insert(d.point.clone());
            frontier_sizes.push(frontier.len());
        }
        let extract = ExtractReport {
            requested: set.requested,
            distinct: set.designs.len(),
            memo_hits: set.memo_hits,
            memo_misses: set.memo_misses,
            elapsed: set.elapsed,
            frontier_sizes,
        };
        let base = baseline(&self.lowered, &q.params);
        Evaluation {
            workload: self.workload.name.to_string(),
            backend: q.backend,
            objective: q.objective,
            designs,
            frontier: frontier.into_sorted(),
            baseline: base,
            extract,
        }
    }

    /// The cached enumeration, if it has run (or was loaded from a
    /// snapshot). Serving and benches use this to reach the shared
    /// read-only e-graph without forcing enumeration.
    pub fn enumeration(&self) -> Option<&Enumeration> {
        self.enumerated.as_ref()
    }

    /// Answer one query through `&self` — the serving path. Requires an
    /// already-enumerated session ([`Session::enumerate`] or
    /// [`Session::load_snapshot`]): with enumeration done, every remaining
    /// phase (extraction, analysis, evaluation, ranking) only *reads* the
    /// e-graph, so an `Arc<Session>` can answer queries from many threads
    /// concurrently — cost-table fixpoints are shared through the
    /// internally-synchronized session memo. Results are identical to
    /// [`Session::query`].
    ///
    /// Deadline-aware ([`Query::deadline`]): the deadline is checked
    /// cooperatively before extraction, between extraction and
    /// evaluation, and per design inside evaluation, so an over-budget
    /// request returns [`Error::Timeout`] at the next phase boundary
    /// instead of holding a serving worker indefinitely.
    pub fn answer_query(&self, q: &Query) -> Result<Evaluation, Error> {
        let en = self.enumerated.as_ref().ok_or_else(|| {
            Error::InvalidConfig(
                "answer_query needs an enumerated session: call enumerate() first \
                 or load a snapshot"
                    .into(),
            )
        })?;
        q.check_deadline("extract")?;
        let t0 = std::time::Instant::now();
        let opts =
            ExtractOptions { samples: q.samples, seed: q.seed, workers: self.extract_workers };
        let set = extract_designs(&en.egraph, en.root, &opts, &self.extract_cache);
        vlog("extract", t0);
        q.check_deadline("analyze")?;
        self.answer(q, &set)
    }

    /// Persist the enumerated design space (enumerating first if needed):
    /// the saturated e-graph with its epoch, the growth report, and every
    /// cost-table fixpoint currently memoized — so a loading process starts
    /// not just enumerated but *warm*. See [`crate::persist`] for the
    /// format and [`Session::load_snapshot`] for the inverse.
    pub fn save_snapshot(&mut self, path: impl AsRef<Path>) -> Result<(), Error> {
        self.enumerate()?;
        let en = self.enumerated.as_ref().expect("just enumerated");
        persist::write_snapshot(
            path,
            &persist::SnapshotParts {
                workload_name: &self.workload.name,
                workload_src: self.workload.expr.to_string(),
                workload_description: self.embedded_description(),
                lowered: &self.lowered,
                rule_names: self.rules.iter().map(|r| r.name.clone()).collect(),
                egraph: &en.egraph,
                root: en.root,
                report: &en.report,
                cache: &self.extract_cache,
            },
        )
    }

    /// Load a session from a snapshot written by [`Session::save_snapshot`].
    ///
    /// The loaded session is enumerated (queries run immediately, zero
    /// re-saturation — [`Session::enumeration_count`] stays 0, which the
    /// round-trip tests pin) and *warm*: the persisted cost tables carry
    /// the graph epoch, so a query the writing process already answered
    /// pays zero fixpoint rebuilds here too, and answers **bit-identically**
    /// (sampled-extraction noise is process-stable by construction).
    ///
    /// Validation: the workload must exist in this build's library or the
    /// process's dynamic registry ([`Error::UnknownWorkload`]) with an
    /// unchanged definition, and every persisted rule name must resolve
    /// ([`Error::UnknownRule`]) — a snapshot from a drifted build is
    /// rejected, not misanswered. A v4 snapshot of an **imported** workload
    /// carries its own definition: the loader parses the embedded source,
    /// registers it ([`crate::relay::register_workload`]), and proceeds —
    /// the file is self-contained.
    pub fn load_snapshot(path: impl AsRef<Path>) -> Result<Session, Error> {
        let snap = persist::read_snapshot(path)?;
        let workload = match crate::relay::workload_by_name(&snap.meta.workload) {
            Some(w) => w,
            None => {
                let src = snap
                    .workload_src
                    .clone()
                    .ok_or_else(|| Error::UnknownWorkload(snap.meta.workload.clone()))?;
                let w = Workload {
                    name: snap.meta.workload.clone(),
                    description: snap.workload_description.clone().unwrap_or_default(),
                    expr: crate::ir::parse_expr(&src)?,
                };
                crate::relay::register_workload(w.clone());
                w
            }
        };
        if persist::workload_fingerprint(&workload.expr.to_string())
            != snap.meta.workload_fingerprint
        {
            return Err(Error::SnapshotCorrupt(format!(
                "snapshot was written against a different definition of workload '{}'",
                workload.name
            )));
        }
        let names: Vec<&str> = snap.rule_names.iter().map(|s| s.as_str()).collect();
        let rules = crate::rewrites::rules_by_names(&names)?;
        let workers = default_workers().max(1);
        let limits = RunnerLimits { track_designs: false, ..Default::default() };
        Ok(Session {
            workload,
            lowered: snap.lowered,
            rules,
            iters: 0, // enumeration already ran in the writing process
            workers,
            search_workers: workers,
            apply_workers: workers,
            extract_workers: workers,
            scheduler: None,
            limits,
            enumerated: Some(Enumeration {
                egraph: snap.egraph,
                root: snap.root,
                report: snap.report,
            }),
            // Zero: this process never re-saturates (the tests pin it).
            enumerations: 0,
            extract_cache: snap.cache,
        })
    }

    /// Extend the enumerated design space with additional rules **in
    /// place**: re-saturate the existing e-graph (enumerating first if
    /// needed) with the union of the current rule list and `set`, instead
    /// of enumerating from scratch. Returns how many rules were actually
    /// new; zero means the set brought nothing and the graph is untouched.
    /// The epoch-keyed extract cache stays: queries after a graph-changing
    /// extension re-solve their fixpoints, a no-op extension stays warm.
    ///
    /// This is the delta-snapshot workflow (see
    /// [`Session::save_snapshot_delta`]): load or save a full base,
    /// extend, persist only the diff.
    pub fn extend_rules(&mut self, set: RuleSet, iters: usize) -> Result<usize, Error> {
        self.enumerate()?;
        let new_rules: Vec<Rewrite> = set
            .rules()
            .into_iter()
            .filter(|r| !self.rules.iter().any(|have| have.name == r.name))
            .collect();
        if new_rules.is_empty() {
            return Ok(0);
        }
        let added = new_rules.len();
        let t0 = std::time::Instant::now();
        let en = self.enumerated.take().expect("enumerated above");
        let mut rules = self.rules.clone();
        rules.extend(new_rules);
        // An already-committed (or snapshot-restored) graph carries no
        // dirty backlog for the incremental matcher — `from_egraph`
        // defaults to a full rescan so the new rules see every class.
        let mut runner = Runner::from_egraph(en.egraph, en.root, rules.clone())
            .with_limits(self.limits.clone())
            .with_search_workers(self.search_workers)
            .with_apply_workers(self.apply_workers);
        let report = runner.run(iters);
        self.rules = rules;
        self.enumerated = Some(Enumeration { egraph: runner.egraph, root: runner.root, report });
        self.enumerations += 1;
        vlog("extend", t0);
        Ok(added)
    }

    /// Persist the enumerated design space as a **delta** against an
    /// existing full snapshot file (see [`crate::persist`], format v3):
    /// only the e-graph slots and cost-table rows that differ from the
    /// base are written, so re-persisting after [`Session::extend_rules`]
    /// writes KBs instead of re-encoding the world. The base must be the
    /// snapshot this session's graph was grown from — the encoder checks
    /// that through the graph's mutation log and refuses otherwise. The
    /// delta records the base's *file name*: keep the pair as siblings,
    /// and [`Session::load_snapshot`] resolves and fingerprint-validates
    /// the chain transparently.
    pub fn save_snapshot_delta(
        &mut self,
        path: impl AsRef<Path>,
        base_path: impl AsRef<Path>,
    ) -> Result<(), Error> {
        self.enumerate()?;
        let en = self.enumerated.as_ref().expect("just enumerated");
        persist::write_snapshot_delta(
            path,
            base_path,
            &persist::SnapshotParts {
                workload_name: &self.workload.name,
                workload_src: self.workload.expr.to_string(),
                workload_description: self.embedded_description(),
                lowered: &self.lowered,
                rule_names: self.rules.iter().map(|r| r.name.clone()).collect(),
                egraph: &en.egraph,
                root: en.root,
                report: &en.report,
                cache: &self.extract_cache,
            },
        )
    }

    /// What snapshots embed for this workload: `Some(description)` — which
    /// selects the self-contained v4 format — iff the workload is absent
    /// from the static library (i.e. it was imported/registered at
    /// runtime, so a fresh loading process has no constructor for it).
    fn embedded_description(&self) -> Option<String> {
        if crate::relay::workload_names().contains(&self.workload.name.as_str()) {
            None
        } else {
            Some(self.workload.description.clone())
        }
    }

    /// Resize the evaluation worker pool (snapshot loads default to the
    /// machine's parallelism; the CLI overrides through this).
    pub fn set_workers(&mut self, workers: usize) {
        self.workers = workers.max(1);
    }

    /// Resize the extraction fan-out pool. Results are bit-identical for
    /// any width.
    pub fn set_extract_workers(&mut self, workers: usize) {
        self.extract_workers = workers.max(1);
    }

    /// Dismantle the session into its lowered expression and enumeration
    /// (enumerating first if needed), for callers that want to own the
    /// e-graph after querying.
    pub fn into_parts(mut self) -> Result<(RecExpr, Enumeration), Error> {
        self.enumerate()?;
        Ok((self.lowered, self.enumerated.expect("just enumerated")))
    }
}

/// Evaluate analyzed design points on the query's backend. Parallel-safe
/// backends get one evaluator per design on the pool; the PJRT runtime
/// evaluates serially through its shared compile cache. Each design
/// re-checks the query deadline before evaluating, so an over-budget
/// request fails between designs rather than after the whole set.
fn evaluate_all(
    q: &Query,
    points: Vec<DesignPoint>,
    workers: usize,
) -> Result<Vec<EvaluatedDesign>, Error> {
    if q.backend.parallel_safe() {
        parallel_map(workers, points, |p| -> Result<EvaluatedDesign, Error> {
            q.check_deadline("evaluate")?;
            let report = q.backend.evaluator()?.evaluate(&p.expr, &q.params, q.seed)?;
            Ok(EvaluatedDesign::new(p.clone(), report))
        })
        .into_iter()
        .collect()
    } else {
        let mut ev = q.backend.evaluator()?;
        points
            .into_iter()
            .map(|p| {
                q.check_deadline("evaluate")?;
                let report = ev.evaluate(&p.expr, &q.params, q.seed)?;
                Ok(EvaluatedDesign::new(p, report))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::workloads;

    fn small_session(w: Workload) -> Session {
        Session::builder()
            .workload(w)
            .rules(RuleSet::Paper)
            .iters(4)
            .workers(4)
            .limits(RunnerLimits { max_nodes: 30_000, ..Default::default() })
            .build()
            .unwrap()
    }

    #[test]
    fn builder_requires_workload() {
        let err = Session::builder().build().unwrap_err();
        assert!(matches!(err, Error::InvalidConfig(_)), "{err}");
    }

    #[test]
    fn builder_rejects_conflicting_rule_configs() {
        let err = Session::builder()
            .workload(workloads::relu128())
            .rules(RuleSet::Fig2)
            .custom_rules(crate::rewrites::fig2_rules())
            .build()
            .unwrap_err();
        assert!(matches!(err, Error::InvalidConfig(_)), "{err}");
    }

    #[test]
    fn enumeration_is_lazy_and_cached() {
        let mut s = small_session(workloads::relu128());
        assert_eq!(s.enumeration_count(), 0, "build must not enumerate");
        s.enumerate().unwrap();
        s.enumerate().unwrap();
        assert_eq!(s.enumeration_count(), 1);
    }

    #[test]
    fn session_skips_per_iteration_design_counts_by_default() {
        let mut s = small_session(workloads::relu128());
        let en = s.enumerate().unwrap();
        assert!(
            en.report.iterations.iter().all(|it| it.designs_lower_bound.is_nan()),
            "session enumeration must not pay the per-iteration design fixpoint"
        );
        // The end-of-run count is still there for reporting.
        assert!(en.report.designs_lower_bound >= 1.0);
    }

    #[test]
    fn track_designs_opt_in_restores_growth_curve() {
        let mut s = Session::builder()
            .workload(workloads::relu128())
            .rules(RuleSet::Fig2)
            .iters(3)
            .track_designs(true)
            .build()
            .unwrap();
        let en = s.enumerate().unwrap();
        assert!(en.report.iterations.iter().all(|it| !it.designs_lower_bound.is_nan()));
    }

    #[test]
    fn enumeration_is_deterministic_across_search_widths() {
        let enumerate = |search_workers: usize| {
            let mut s = Session::builder()
                .workload(workloads::ffn_block())
                .rules(RuleSet::Paper)
                .iters(4)
                .search_workers(search_workers)
                .limits(RunnerLimits { max_nodes: 30_000, ..Default::default() })
                .build()
                .unwrap();
            s.enumerate().unwrap();
            let en = s.enumerated.as_ref().unwrap();
            (en.egraph.num_classes(), en.egraph.total_nodes(), en.report.designs_lower_bound)
        };
        let one = enumerate(1);
        assert_eq!(enumerate(4), one);
        assert_eq!(enumerate(16), one);
    }

    #[test]
    fn query_returns_designs_and_frontier() {
        let mut s = small_session(workloads::ffn_block());
        let ev = s.query(&Query::new().samples(12)).unwrap();
        assert!(ev.designs.len() >= 3, "need diverse designs");
        assert!(!ev.frontier.is_empty());
        assert!(ev.baseline.cost.area > 0.0);
        assert!(ev.best().is_some());
    }

    #[test]
    fn second_query_serves_from_the_cost_table_memo() {
        let mut s = small_session(workloads::relu128());
        let q1 = s.query(&Query::new().objective(Objective::Latency).samples(10)).unwrap();
        assert!(q1.extract.memo_misses > 0, "cold query must solve fixpoints");
        // Different objective, same sample set: zero fixpoint rebuilds.
        let q2 = s.query(&Query::new().objective(Objective::Area).samples(10)).unwrap();
        assert_eq!(q2.extract.memo_misses, 0, "warm query must not rebuild extractors");
        assert_eq!(q2.extract.memo_hits, 12); // 10 samples + 2 greedy endpoints
        assert_eq!(s.enumeration_count(), 1);
    }

    #[test]
    fn run_queries_shares_one_sample_set() {
        let mut s = small_session(workloads::relu128());
        let batch = [
            Query::new().objective(Objective::Latency).samples(10),
            Query::new().objective(Objective::Area).samples(10),
            Query::new().objective(Objective::Balanced(0.5)).samples(10),
        ];
        let evs = s.run_queries(&batch).unwrap();
        assert_eq!(evs.len(), 3);
        assert_eq!(s.enumeration_count(), 1);
        // One extraction pass: every evaluation reports the same pass and
        // the same design identity set.
        let keys = |ev: &Evaluation| {
            ev.designs.iter().map(|d| d.point.expr.to_string()).collect::<Vec<_>>()
        };
        for ev in &evs[1..] {
            assert_eq!(keys(ev), keys(&evs[0]));
            assert_eq!(ev.extract.memo_misses, evs[0].extract.memo_misses);
        }
        // A follow-up single query on the same sample set is fully warm.
        let after = s.query(&Query::new().samples(10)).unwrap();
        assert_eq!(after.extract.memo_misses, 0);
    }

    #[test]
    fn extraction_is_deterministic_across_extract_widths() {
        let render = |extract_workers: usize| {
            let mut s = Session::builder()
                .workload(workloads::relu128())
                .rules(RuleSet::Paper)
                .iters(4)
                .extract_workers(extract_workers)
                .limits(RunnerLimits { max_nodes: 30_000, ..Default::default() })
                .build()
                .unwrap();
            let ev = s.query(&Query::new().samples(16)).unwrap();
            ev.designs.iter().map(|d| d.point.expr.to_string()).collect::<Vec<_>>()
        };
        let one = render(1);
        assert!(one.len() >= 3);
        assert_eq!(render(2), one);
        assert_eq!(render(4), one);
    }

    #[test]
    fn streamed_frontier_matches_reference_filter() {
        let mut s = small_session(workloads::ffn_block());
        let ev = s.query(&Query::new().samples(16)).unwrap();
        let reference = crate::extract::pareto_frontier(
            &ev.designs.iter().map(|d| d.point.clone()).collect::<Vec<_>>(),
        );
        let key = |ps: &[DesignPoint]| {
            ps.iter().map(|p| (p.cost.area, p.cost.latency, p.origin.clone())).collect::<Vec<_>>()
        };
        assert_eq!(key(&ev.frontier), key(&reference));
        // The recorded trajectory ends at the final frontier size.
        assert_eq!(ev.extract.frontier_size(), ev.frontier.len());
        assert_eq!(ev.extract.frontier_sizes.len(), ev.designs.len());
    }

    #[test]
    fn extend_rules_and_delta_snapshot_roundtrip() {
        let dir = std::env::temp_dir().join("hwsplit_session_delta_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let base_path = dir.join("relu128.hws");
        let delta_path = dir.join("relu128.d1.hws");
        let mut writer = Session::builder()
            .workload(workloads::relu128())
            .rules(RuleSet::Fig2)
            .iters(4)
            .build()
            .unwrap();
        writer.save_snapshot(&base_path).unwrap();
        // Load the base, grow it in place with the wider rule set.
        let mut s = Session::load_snapshot(&base_path).unwrap();
        let added = s.extend_rules(RuleSet::Paper, 4).unwrap();
        assert!(added > 0, "paper set must bring rules fig2 lacks");
        // A set the session already covers is a no-op.
        assert_eq!(s.extend_rules(RuleSet::Fig2, 4).unwrap(), 0);
        s.save_snapshot_delta(&delta_path, &base_path).unwrap();
        // The delta chain loads like any snapshot and answers queries
        // identically to the in-memory extended session.
        let mut loaded = Session::load_snapshot(&delta_path).unwrap();
        assert_eq!(loaded.enumeration_count(), 0);
        let q = Query::new().samples(8);
        let key = |ev: &Evaluation| {
            ev.designs.iter().map(|d| d.point.expr.to_string()).collect::<Vec<_>>()
        };
        assert_eq!(key(&loaded.query(&q).unwrap()), key(&s.query(&q).unwrap()));
        let _ = std::fs::remove_file(&base_path);
        let _ = std::fs::remove_file(&delta_path);
    }

    #[test]
    fn imported_workload_snapshot_is_self_contained() {
        let dir = std::env::temp_dir().join("hwsplit_session_import_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("imported.hws");
        let mut b = crate::relay::GraphBuilder::new();
        let x = b.input("x", &[64]);
        b.relu(x);
        let w = Workload {
            name: "session_test_imported".to_string(),
            description: "session import roundtrip test".to_string(),
            expr: b.finish(),
        };
        let mut writer =
            Session::builder().workload(w).rules(RuleSet::Fig2).iters(4).build().unwrap();
        writer.save_snapshot(&path).unwrap();
        // The workload is not in the static library, so the loader must be
        // served entirely by the snapshot's embedded (v4) definition.
        let mut loaded = Session::load_snapshot(&path).unwrap();
        assert_eq!(loaded.workload().name, "session_test_imported");
        assert_eq!(loaded.workload().description, "session import roundtrip test");
        assert_eq!(loaded.enumeration_count(), 0);
        let ev = loaded.query(&Query::new().samples(8)).unwrap();
        assert!(!ev.designs.is_empty());
        // The loader registered the definition for this process, so error
        // suggestions and repeat lookups now see it.
        assert!(crate::relay::registered_workload("session_test_imported").is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn objectives_rank_differently() {
        let mut s = small_session(workloads::relu128());
        let fast = s.query(&Query::new().objective(Objective::Latency).samples(16)).unwrap();
        let small = s.query(&Query::new().objective(Objective::Area).samples(16)).unwrap();
        assert_eq!(s.enumeration_count(), 1);
        let f = fast.best().unwrap();
        let a = small.best().unwrap();
        assert!(f.point.cost.latency <= a.point.cost.latency);
        assert!(a.point.cost.area <= f.point.cost.area);
    }
}
