//! A fast, non-cryptographic hasher (the `FxHasher` algorithm used by the
//! Rust compiler) implemented in-tree so the hot e-graph paths don't depend
//! on an external crate. Drop-in for the `rustc_hash` API surface we use:
//! [`FxHashMap`], [`FxHashSet`], `HashMap::default()` construction.

use std::hash::{BuildHasherDefault, Hasher};

/// `HashMap` keyed with [`FxHasher`].
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, BuildHasherDefault<FxHasher>>;

/// `HashSet` keyed with [`FxHasher`].
pub type FxHashSet<T> = std::collections::HashSet<T, BuildHasherDefault<FxHasher>>;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-and-rotate hash: one multiply per 8 input bytes. Weak against
/// adversarial keys, excellent for the small structural keys (`Id`s,
/// e-nodes) this crate hashes billions of times.
#[derive(Debug, Clone, Copy, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rest.len()].copy_from_slice(rest);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u16(&mut self, v: u16) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_spreading() {
        let h = |bytes: &[u8]| {
            let mut hasher = FxHasher::default();
            hasher.write(bytes);
            hasher.finish()
        };
        assert_eq!(h(b"hello"), h(b"hello"));
        assert_ne!(h(b"hello"), h(b"hellp"));
        assert_ne!(h(b"ab"), h(b"ba"));
    }

    #[test]
    fn map_and_set_work() {
        let mut m: FxHashMap<u32, u32> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(i, i * 2);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&500], 1000);
        let s: FxHashSet<u32> = (0..100).collect();
        assert!(s.contains(&99));
    }
}
