//! Rewrites: a searcher (pattern or e-node scan) plus an applier that builds
//! the equivalent right-hand side directly into the e-graph.
//!
//! Two searcher styles:
//!
//! * **Pattern** — generic e-matching ([`super::matcher`]); used by
//!   multi-level structural rules (e.g. fusing `invoke-relu ∘ invoke-mm`).
//! * **NodeScan** — iterate e-nodes of one [`OpKind`]; used by rules that
//!   must *compute* new scalar parameters (splitting a `(relu-engine 128)`
//!   into a loop over `(relu-engine 64)` needs `128/2`), which plain
//!   pattern/template rewriting cannot express.
//!
//! Appliers return the id of the newly built equivalent class (or `None` to
//! decline); the [`super::Runner`] unions it with the matched class.

use super::graph::EGraph;
use super::matcher;
use super::pattern::{Pattern, Subst};
use super::Id;
use crate::ir::OpKind;
use std::sync::Arc;

/// Applier callback: build the RHS for a match, returning its class.
pub type Applier = Arc<dyn Fn(&mut EGraph, Id, &Subst) -> Option<Id> + Send + Sync>;

enum Searcher {
    Pattern(Pattern),
    NodeScan(OpKind),
}

/// A named, semantics-preserving rewrite rule.
pub struct Rewrite {
    pub name: String,
    searcher: Searcher,
    applier: Applier,
}

impl std::fmt::Debug for Rewrite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Rewrite({})", self.name)
    }
}

impl Clone for Rewrite {
    fn clone(&self) -> Self {
        Rewrite {
            name: self.name.clone(),
            searcher: match &self.searcher {
                Searcher::Pattern(p) => Searcher::Pattern(p.clone()),
                Searcher::NodeScan(k) => Searcher::NodeScan(*k),
            },
            applier: Arc::clone(&self.applier),
        }
    }
}

impl Rewrite {
    /// A pattern-searched rewrite.
    pub fn pattern(
        name: &str,
        pat: Pattern,
        applier: impl Fn(&mut EGraph, Id, &Subst) -> Option<Id> + Send + Sync + 'static,
    ) -> Self {
        Rewrite { name: name.into(), searcher: Searcher::Pattern(pat), applier: Arc::new(applier) }
    }

    /// A node-scan rewrite over all e-nodes of `kind`. The applier receives
    /// the matched node via `subst.node`.
    pub fn node_scan(
        name: &str,
        kind: OpKind,
        applier: impl Fn(&mut EGraph, Id, &Subst) -> Option<Id> + Send + Sync + 'static,
    ) -> Self {
        Rewrite {
            name: name.into(),
            searcher: Searcher::NodeScan(kind),
            applier: Arc::new(applier),
        }
    }

    /// Find all matches in the current e-graph (no mutation).
    pub fn search(&self, eg: &EGraph) -> Vec<(Id, Subst)> {
        match &self.searcher {
            Searcher::Pattern(p) => matcher::search(eg, p),
            Searcher::NodeScan(kind) => {
                let mut out = Vec::new();
                for class in eg.classes() {
                    for node in &class.nodes {
                        if node.op.kind() == *kind {
                            let subst = Subst { node: Some(node.clone()), ..Default::default() };
                            out.push((class.id, subst));
                        }
                    }
                }
                out
            }
        }
    }

    /// Apply to one match; returns true if the union changed the e-graph.
    pub fn apply(&self, eg: &mut EGraph, class: Id, subst: &Subst) -> bool {
        if let Some(rhs) = (self.applier)(eg, class, subst) {
            let (_, changed) = eg.union(class, rhs);
            changed
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{parse_expr, Node, Op};

    /// A toy rewrite: eadd(x, y) => eadd(y, x).
    fn commute() -> Rewrite {
        Rewrite::node_scan("commute-eadd", OpKind::EAdd, |eg, _id, subst| {
            let n = subst.node.as_ref().unwrap();
            let swapped = Node::new(Op::EAdd, vec![n.children[1], n.children[0]]);
            Some(eg.add(swapped))
        })
    }

    #[test]
    fn node_scan_applies_and_saturates() {
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut eg = EGraph::new();
        let root = eg.add_expr(&e);
        let rw = commute();

        let matches = rw.search(&eg);
        assert_eq!(matches.len(), 1);
        for (id, s) in matches {
            rw.apply(&mut eg, id, &s);
        }
        eg.rebuild();
        // Both orders now live in the root class.
        assert_eq!(eg.class(root).nodes.len(), 2);

        // Re-applying discovers the swapped node but unions are no-ops.
        let matches = rw.search(&eg);
        assert_eq!(matches.len(), 2);
        let changed: Vec<bool> =
            matches.into_iter().map(|(id, s)| rw.apply(&mut eg, id, &s)).collect();
        assert!(changed.iter().all(|&c| !c));
    }

    #[test]
    fn declining_applier_changes_nothing() {
        let rw = Rewrite::node_scan("never", OpKind::EAdd, |_, _, _| None);
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut eg = EGraph::new();
        eg.add_expr(&e);
        let before = eg.total_nodes();
        for (id, s) in rw.search(&eg) {
            rw.apply(&mut eg, id, &s);
        }
        assert_eq!(eg.total_nodes(), before);
    }
}
