//! Rewrites: a searcher (pattern or e-node scan) plus an applier that builds
//! the equivalent right-hand side directly into the e-graph.
//!
//! Two searcher styles:
//!
//! * **Pattern** — generic e-matching ([`super::matcher`]); used by
//!   multi-level structural rules (e.g. fusing `invoke-relu ∘ invoke-mm`).
//! * **NodeScan** — iterate e-nodes of one [`OpKind`]; used by rules that
//!   must *compute* new scalar parameters (splitting a `(relu-engine 128)`
//!   into a loop over `(relu-engine 64)` needs `128/2`), which plain
//!   pattern/template rewriting cannot express.
//!
//! Appliers return the id of the newly built equivalent class (or `None` to
//! decline); the [`super::Runner`] unions it with the matched class.

use super::graph::EGraph;
use super::matcher;
use super::pattern::{Pattern, Subst};
use super::Id;
use crate::ir::OpKind;
use std::sync::Arc;

/// Applier callback: build the RHS for a match, returning its class.
pub type Applier = Arc<dyn Fn(&mut EGraph, Id, &Subst) -> Option<Id> + Send + Sync>;

enum Searcher {
    Pattern(Pattern),
    /// Scan e-nodes of one kind. The `usize` is the applier's *look-down
    /// depth*: how many child levels below the matched node the applier
    /// inspects other classes' **nodes** (via `find_in_class`-style peeks).
    /// 0 for appliers that only read the matched node and child *types*.
    NodeScan(OpKind, usize),
}

/// A named, semantics-preserving rewrite rule.
pub struct Rewrite {
    pub name: String,
    searcher: Searcher,
    applier: Applier,
}

impl std::fmt::Debug for Rewrite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Rewrite({})", self.name)
    }
}

impl Clone for Rewrite {
    fn clone(&self) -> Self {
        Rewrite {
            name: self.name.clone(),
            searcher: match &self.searcher {
                Searcher::Pattern(p) => Searcher::Pattern(p.clone()),
                Searcher::NodeScan(k, d) => Searcher::NodeScan(*k, *d),
            },
            applier: Arc::clone(&self.applier),
        }
    }
}

impl Rewrite {
    /// A pattern-searched rewrite.
    pub fn pattern(
        name: &str,
        pat: Pattern,
        applier: impl Fn(&mut EGraph, Id, &Subst) -> Option<Id> + Send + Sync + 'static,
    ) -> Self {
        Rewrite { name: name.into(), searcher: Searcher::Pattern(pat), applier: Arc::new(applier) }
    }

    /// A node-scan rewrite over all e-nodes of `kind`. The applier receives
    /// the matched node via `subst.node`, and must only read that node and
    /// its child classes' *types* (which are immutable). Appliers that peek
    /// at other classes' nodes must declare it via [`Rewrite::node_scan_deep`].
    pub fn node_scan(
        name: &str,
        kind: OpKind,
        applier: impl Fn(&mut EGraph, Id, &Subst) -> Option<Id> + Send + Sync + 'static,
    ) -> Self {
        Rewrite::node_scan_deep(name, kind, 0, applier)
    }

    /// Like [`Rewrite::node_scan`], but for appliers that inspect the
    /// e-nodes of classes up to `look_down` child levels below the matched
    /// node (e.g. `find_in_class` on a child to locate a nested schedule).
    /// The incremental engine uses this to re-offer a match whenever any
    /// class the applier can see changes — under-declaring `look_down`
    /// loses enumerations relative to a full rescan.
    pub fn node_scan_deep(
        name: &str,
        kind: OpKind,
        look_down: usize,
        applier: impl Fn(&mut EGraph, Id, &Subst) -> Option<Id> + Send + Sync + 'static,
    ) -> Self {
        Rewrite {
            name: name.into(),
            searcher: Searcher::NodeScan(kind, look_down),
            applier: Arc::new(applier),
        }
    }

    /// How many parent hops above a changed e-class a *new* match of this
    /// rule can be rooted. The incremental engine widens its dirty work
    /// list by this many ancestor levels per rule (see
    /// [`super::graph::EGraph::with_ancestors`]).
    pub fn ancestor_levels(&self) -> usize {
        match &self.searcher {
            Searcher::Pattern(p) => p.depth(),
            Searcher::NodeScan(_, look_down) => *look_down,
        }
    }

    /// Find all matches in the current e-graph (no mutation).
    pub fn search(&self, eg: &EGraph) -> Vec<(Id, Subst)> {
        self.search_classes(eg, &eg.class_ids())
    }

    /// Find matches rooted at the given classes only (no mutation; `&self`
    /// e-graph access only, so shards of this call can run on a scoped
    /// worker pool against the shared frozen graph). Match order is
    /// deterministic: input class order, then node order within a class.
    pub fn search_classes(&self, eg: &EGraph, ids: &[Id]) -> Vec<(Id, Subst)> {
        match &self.searcher {
            Searcher::Pattern(p) => matcher::search_classes(eg, p, ids),
            Searcher::NodeScan(kind, _) => {
                let mut out = Vec::new();
                for &id in ids {
                    let id = eg.find_ref(id);
                    for node in &eg.class(id).nodes {
                        if node.op.kind() == *kind {
                            let subst = Subst { node: Some(node.clone()), ..Default::default() };
                            out.push((id, subst));
                        }
                    }
                }
                out
            }
        }
    }

    /// Apply to one match. `Some(changed)` when the applier fired (built an
    /// RHS that was unioned in; `changed` says whether that union did
    /// anything), `None` when it declined. The distinction matters to the
    /// runner: fired applications are memoized and never replayed, declines
    /// are retried whenever the match is re-offered (a declining applier
    /// may succeed later once e.g. a child class gains a schedule node).
    pub fn try_apply(&self, eg: &mut EGraph, class: Id, subst: &Subst) -> Option<bool> {
        let rhs = (self.applier)(eg, class, subst)?;
        let (_, changed) = eg.union(class, rhs);
        Some(changed)
    }

    /// Apply to one match; returns true if the union changed the e-graph.
    pub fn apply(&self, eg: &mut EGraph, class: Id, subst: &Subst) -> bool {
        self.try_apply(eg, class, subst).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{parse_expr, Node, Op};

    /// A toy rewrite: eadd(x, y) => eadd(y, x).
    fn commute() -> Rewrite {
        Rewrite::node_scan("commute-eadd", OpKind::EAdd, |eg, _id, subst| {
            let n = subst.node.as_ref().unwrap();
            let swapped = Node::new(Op::EAdd, vec![n.children[1], n.children[0]]);
            Some(eg.add(swapped))
        })
    }

    #[test]
    fn node_scan_applies_and_saturates() {
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut eg = EGraph::new();
        let root = eg.add_expr(&e);
        let rw = commute();

        let matches = rw.search(&eg);
        assert_eq!(matches.len(), 1);
        for (id, s) in matches {
            rw.apply(&mut eg, id, &s);
        }
        eg.rebuild();
        // Both orders now live in the root class.
        assert_eq!(eg.class(root).nodes.len(), 2);

        // Re-applying discovers the swapped node but unions are no-ops.
        let matches = rw.search(&eg);
        assert_eq!(matches.len(), 2);
        let changed: Vec<bool> =
            matches.into_iter().map(|(id, s)| rw.apply(&mut eg, id, &s)).collect();
        assert!(changed.iter().all(|&c| !c));
    }

    #[test]
    fn declining_applier_changes_nothing() {
        let rw = Rewrite::node_scan("never", OpKind::EAdd, |_, _, _| None);
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut eg = EGraph::new();
        eg.add_expr(&e);
        let before = eg.total_nodes();
        for (id, s) in rw.search(&eg) {
            rw.apply(&mut eg, id, &s);
        }
        assert_eq!(eg.total_nodes(), before);
    }
}
