//! Rewrites: a searcher (pattern or e-node scan) plus an applier that builds
//! the equivalent right-hand side through an [`ApplyGraph`].
//!
//! Two searcher styles:
//!
//! * **Pattern** — generic e-matching ([`super::matcher`]); used by
//!   multi-level structural rules (e.g. fusing `invoke-relu ∘ invoke-mm`).
//! * **NodeScan** — iterate e-nodes of one [`OpKind`]; used by rules that
//!   must *compute* new scalar parameters (splitting a `(relu-engine 128)`
//!   into a loop over `(relu-engine 64)` needs `128/2`), which plain
//!   pattern/template rewriting cannot express.
//!
//! Appliers return the id of the newly built equivalent class (or `None` to
//! decline); the [`super::Runner`] unions it with the matched class.
//!
//! ## The two application modes
//!
//! [`ApplyGraph`] is the applier's only view of the e-graph, and it comes
//! in two flavors:
//!
//! * **Direct** — a `&mut EGraph`; `add` inserts immediately. Used by the
//!   legacy [`Rewrite::try_apply`]/[`Rewrite::apply`] entry points (tests,
//!   one-off drivers).
//! * **Staged** — a `&EGraph` *frozen* snapshot plus a local scratch arena.
//!   `add` canonicalizes against the frozen union-find, probes the frozen
//!   hashcons, and otherwise records the node locally, handing back a
//!   stage-local id. The runner fans staging across worker threads (the
//!   graph is only read), then replays each intent's node list through the
//!   real `EGraph::add` single-threaded in deterministic match order — so
//!   the committed e-graph is bit-identical for any `--apply-workers`.
//!
//! Appliers observe the same API either way: `add`, `ty`, `class_nodes`,
//! and `fresh_var` (which in staged mode mints *deterministic* names from
//! the match's position in the stream instead of a global counter — the
//! other half of the bit-identity guarantee).

use super::graph::EGraph;
use super::matcher;
use super::pattern::{Pattern, Subst};
use super::Id;
use crate::fx::FxHashMap;
use crate::ir::{infer_ty_ref, Node, OpKind, Symbol, Ty};
use std::sync::Arc;

/// Applier callback: build the RHS for a match, returning its class.
pub type Applier = Arc<dyn Fn(&mut ApplyGraph, Id, &Subst) -> Option<Id> + Send + Sync>;

/// The applier's view of the e-graph: either a live mutable graph or a
/// frozen graph plus stage-local scratch (see the module docs).
pub enum ApplyGraph<'a> {
    Direct(&'a mut EGraph),
    Staged(Stage<'a>),
}

impl<'a> ApplyGraph<'a> {
    /// Insert an e-node (hash-consed; staged mode defers the real insert
    /// to the commit replay).
    pub fn add(&mut self, node: Node) -> Id {
        match self {
            ApplyGraph::Direct(eg) => eg.add(node),
            ApplyGraph::Staged(s) => s.add(node),
        }
    }

    /// Type of `id`'s class (stage-local ids resolve to their inferred ty).
    pub fn ty(&self, id: Id) -> &Ty {
        match self {
            ApplyGraph::Direct(eg) => eg.ty(id),
            ApplyGraph::Staged(s) => s.ty(id),
        }
    }

    /// The e-nodes of `id`'s class. A stage-local class holds exactly the
    /// one node staged for it.
    pub fn class_nodes(&self, id: Id) -> Box<dyn Iterator<Item = &Node> + '_> {
        match self {
            ApplyGraph::Direct(eg) => Box::new(eg.class_nodes(id)),
            ApplyGraph::Staged(s) => s.class_nodes(id),
        }
    }

    /// Look up a node's class without inserting (stage-local nodes
    /// included in staged mode).
    pub fn lookup(&self, node: &Node) -> Option<Id> {
        match self {
            ApplyGraph::Direct(eg) => eg.lookup_ref(node),
            ApplyGraph::Staged(s) => s.lookup(node),
        }
    }

    /// Mint a fresh loop-variable symbol. Staged mode derives the name
    /// deterministically from the match's stream position (worker-count
    /// independent); direct mode falls back to the global counter.
    pub fn fresh_var(&mut self, prefix: &str) -> Symbol {
        match self {
            ApplyGraph::Direct(_) => Symbol::fresh(prefix),
            ApplyGraph::Staged(s) => s.fresh_var(prefix),
        }
    }
}

/// Scratch state for one staged application: local nodes (ids `>= base`),
/// a local hashcons, and the deterministic fresh-name tag.
pub struct Stage<'a> {
    eg: &'a EGraph,
    /// Ids below this are frozen-graph classes; at or above, stage-local.
    base: usize,
    /// Stage-local nodes in `add` order, with their inferred types.
    /// Children are frozen-canonical (base) or stage-local ids.
    nodes: Vec<(Node, Ty)>,
    memo: FxHashMap<Node, Id>,
    /// Position tag `"{iteration}_{match_index}"` baked into fresh names.
    tag: String,
    fresh_k: usize,
}

impl<'a> Stage<'a> {
    pub(crate) fn new(eg: &'a EGraph, tag: String) -> Self {
        Stage {
            eg,
            base: eg.id_count(),
            nodes: Vec::new(),
            memo: FxHashMap::default(),
            tag,
            fresh_k: 0,
        }
    }

    fn add(&mut self, mut node: Node) -> Id {
        let mut has_local = false;
        for c in &mut node.children {
            if c.index() < self.base {
                *c = self.eg.find_ref(*c);
            } else {
                has_local = true;
            }
        }
        // Nodes whose children all exist in the frozen graph may already be
        // hash-consed there; stage-local children can't be (their ids are
        // not valid in the base graph).
        if !has_local {
            if let Some(id) = self.eg.lookup_ref(&node) {
                return id;
            }
        }
        if let Some(&id) = self.memo.get(&node) {
            return id;
        }
        let ty = {
            let child_tys: Vec<&Ty> = node.children.iter().map(|&c| self.ty(c)).collect();
            infer_ty_ref(&node.op, &child_tys).unwrap_or_else(|e| {
                panic!("ill-typed e-node {}: {e}", node.op);
            })
        };
        let id = Id::from_index(self.base + self.nodes.len());
        self.memo.insert(node.clone(), id);
        self.nodes.push((node, ty));
        id
    }

    fn ty(&self, id: Id) -> &Ty {
        if id.index() < self.base {
            self.eg.ty(id)
        } else {
            &self.nodes[id.index() - self.base].1
        }
    }

    fn lookup(&self, node: &Node) -> Option<Id> {
        let mut n = node.clone();
        let mut has_local = false;
        for c in &mut n.children {
            if c.index() < self.base {
                *c = self.eg.find_ref(*c);
            } else {
                has_local = true;
            }
        }
        if !has_local {
            if let Some(id) = self.eg.lookup_ref(&n) {
                return Some(id);
            }
        }
        self.memo.get(&n).copied()
    }

    fn class_nodes(&self, id: Id) -> Box<dyn Iterator<Item = &Node> + '_> {
        if id.index() < self.base {
            Box::new(self.eg.class_nodes(id))
        } else {
            Box::new(std::iter::once(&self.nodes[id.index() - self.base].0))
        }
    }

    fn fresh_var(&mut self, prefix: &str) -> Symbol {
        let k = self.fresh_k;
        self.fresh_k += 1;
        Symbol::new(&format!("{prefix}_{}_{k}", self.tag))
    }
}

/// The outcome of staging one match: the nodes to replay (in `add` order)
/// and the applier's returned class. Committing means re-adding each node
/// (remapping stage-local child ids through the ids the real adds return)
/// and unioning the mapped `result` with the match root.
pub(crate) struct ApplyIntent {
    pub base: usize,
    pub nodes: Vec<Node>,
    pub result: Id,
}

impl ApplyIntent {
    /// Replay this intent into the live graph. Returns the mapped result
    /// class (the caller unions it with the match root).
    pub fn commit(self, eg: &mut EGraph) -> Id {
        let mut local: Vec<Id> = Vec::with_capacity(self.nodes.len());
        for node in self.nodes {
            let mapped =
                node.map_children(
                    |c| if c.index() < self.base { c } else { local[c.index() - self.base] },
                );
            local.push(eg.add(mapped));
        }
        if self.result.index() < self.base {
            self.result
        } else {
            local[self.result.index() - self.base]
        }
    }
}

enum Searcher {
    Pattern(Pattern),
    /// Scan e-nodes of one kind. The `usize` is the applier's *look-down
    /// depth*: how many child levels below the matched node the applier
    /// inspects other classes' **nodes** (via `find_in_class`-style peeks).
    /// 0 for appliers that only read the matched node and child *types*.
    NodeScan(OpKind, usize),
}

/// A named, semantics-preserving rewrite rule.
pub struct Rewrite {
    pub name: String,
    searcher: Searcher,
    applier: Applier,
}

impl std::fmt::Debug for Rewrite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Rewrite({})", self.name)
    }
}

impl Clone for Rewrite {
    fn clone(&self) -> Self {
        Rewrite {
            name: self.name.clone(),
            searcher: match &self.searcher {
                Searcher::Pattern(p) => Searcher::Pattern(p.clone()),
                Searcher::NodeScan(k, d) => Searcher::NodeScan(*k, *d),
            },
            applier: Arc::clone(&self.applier),
        }
    }
}

impl Rewrite {
    /// A pattern-searched rewrite.
    pub fn pattern(
        name: &str,
        pat: Pattern,
        applier: impl Fn(&mut ApplyGraph, Id, &Subst) -> Option<Id> + Send + Sync + 'static,
    ) -> Self {
        Rewrite { name: name.into(), searcher: Searcher::Pattern(pat), applier: Arc::new(applier) }
    }

    /// A node-scan rewrite over all e-nodes of `kind`. The applier receives
    /// the matched node via `subst.node`, and must only read that node and
    /// its child classes' *types* (which are immutable). Appliers that peek
    /// at other classes' nodes must declare it via [`Rewrite::node_scan_deep`].
    pub fn node_scan(
        name: &str,
        kind: OpKind,
        applier: impl Fn(&mut ApplyGraph, Id, &Subst) -> Option<Id> + Send + Sync + 'static,
    ) -> Self {
        Rewrite::node_scan_deep(name, kind, 0, applier)
    }

    /// Like [`Rewrite::node_scan`], but for appliers that inspect the
    /// e-nodes of classes up to `look_down` child levels below the matched
    /// node (e.g. `find_in_class` on a child to locate a nested schedule).
    /// The incremental engine uses this to re-offer a match whenever any
    /// class the applier can see changes — under-declaring `look_down`
    /// loses enumerations relative to a full rescan.
    pub fn node_scan_deep(
        name: &str,
        kind: OpKind,
        look_down: usize,
        applier: impl Fn(&mut ApplyGraph, Id, &Subst) -> Option<Id> + Send + Sync + 'static,
    ) -> Self {
        Rewrite {
            name: name.into(),
            searcher: Searcher::NodeScan(kind, look_down),
            applier: Arc::new(applier),
        }
    }

    /// How many parent hops above a changed e-class a *new* match of this
    /// rule can be rooted. The incremental engine widens its dirty work
    /// list by this many ancestor levels per rule (see
    /// [`super::graph::EGraph::with_ancestors`]).
    pub fn ancestor_levels(&self) -> usize {
        match &self.searcher {
            Searcher::Pattern(p) => p.depth(),
            Searcher::NodeScan(_, look_down) => *look_down,
        }
    }

    /// Find all matches in the current e-graph (no mutation).
    pub fn search(&self, eg: &EGraph) -> Vec<(Id, Subst)> {
        self.search_classes(eg, &eg.class_ids())
    }

    /// Find matches rooted at the given classes only (no mutation; `&self`
    /// e-graph access only, so shards of this call can run on a scoped
    /// worker pool against the shared frozen graph). Match order is
    /// deterministic: input class order, then node order within a class.
    pub fn search_classes(&self, eg: &EGraph, ids: &[Id]) -> Vec<(Id, Subst)> {
        match &self.searcher {
            Searcher::Pattern(p) => matcher::search_classes(eg, p, ids),
            Searcher::NodeScan(kind, _) => {
                let mut out = Vec::new();
                for &id in ids {
                    let id = eg.find_ref(id);
                    for node in eg.class_nodes(id) {
                        if node.op.kind() == *kind {
                            let subst = Subst { node: Some(node.clone()), ..Default::default() };
                            out.push((id, subst));
                        }
                    }
                }
                out
            }
        }
    }

    /// Stage one match against the frozen graph: run the applier against a
    /// [`Stage`], returning the intent to commit later (or `None` when the
    /// applier declined). `tag` is the deterministic fresh-name seed
    /// (iteration + match index). `&self` graph access only — safe to fan
    /// across worker threads.
    pub(crate) fn stage(
        &self,
        eg: &EGraph,
        class: Id,
        subst: &Subst,
        tag: String,
    ) -> Option<ApplyIntent> {
        let mut g = ApplyGraph::Staged(Stage::new(eg, tag));
        let result = (self.applier)(&mut g, class, subst)?;
        let ApplyGraph::Staged(stage) = g else { unreachable!() };
        Some(ApplyIntent {
            base: stage.base,
            nodes: stage.nodes.into_iter().map(|(n, _)| n).collect(),
            result,
        })
    }

    /// Apply to one match. `Some(changed)` when the applier fired (built an
    /// RHS that was unioned in; `changed` says whether that union did
    /// anything), `None` when it declined. The distinction matters to the
    /// runner: fired applications are memoized and never replayed, declines
    /// are retried whenever the match is re-offered (a declining applier
    /// may succeed later once e.g. a child class gains a schedule node).
    pub fn try_apply(&self, eg: &mut EGraph, class: Id, subst: &Subst) -> Option<bool> {
        let mut g = ApplyGraph::Direct(eg);
        let rhs = (self.applier)(&mut g, class, subst)?;
        let (_, changed) = eg.union(class, rhs);
        Some(changed)
    }

    /// Apply to one match; returns true if the union changed the e-graph.
    pub fn apply(&self, eg: &mut EGraph, class: Id, subst: &Subst) -> bool {
        self.try_apply(eg, class, subst).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{parse_expr, Node, Op};

    /// A toy rewrite: eadd(x, y) => eadd(y, x).
    fn commute() -> Rewrite {
        Rewrite::node_scan("commute-eadd", OpKind::EAdd, |g, _id, subst| {
            let n = subst.node.as_ref().unwrap();
            let swapped = Node::new(Op::EAdd, vec![n.children[1], n.children[0]]);
            Some(g.add(swapped))
        })
    }

    #[test]
    fn node_scan_applies_and_saturates() {
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut eg = EGraph::new();
        let root = eg.add_expr(&e);
        let rw = commute();

        let matches = rw.search(&eg);
        assert_eq!(matches.len(), 1);
        for (id, s) in matches {
            rw.apply(&mut eg, id, &s);
        }
        eg.rebuild();
        // Both orders now live in the root class.
        assert_eq!(eg.class(root).len(), 2);

        // Re-applying discovers the swapped node but unions are no-ops.
        let matches = rw.search(&eg);
        assert_eq!(matches.len(), 2);
        let changed: Vec<bool> =
            matches.into_iter().map(|(id, s)| rw.apply(&mut eg, id, &s)).collect();
        assert!(changed.iter().all(|&c| !c));
    }

    #[test]
    fn declining_applier_changes_nothing() {
        let rw = Rewrite::node_scan("never", OpKind::EAdd, |_, _, _| None);
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut eg = EGraph::new();
        eg.add_expr(&e);
        let before = eg.total_nodes();
        for (id, s) in rw.search(&eg) {
            rw.apply(&mut eg, id, &s);
        }
        assert_eq!(eg.total_nodes(), before);
    }

    #[test]
    fn staged_apply_commits_to_same_graph_as_direct() {
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let rw = commute();

        let mut direct = EGraph::new();
        let droot = direct.add_expr(&e);
        for (id, s) in rw.search(&direct) {
            rw.apply(&mut direct, id, &s);
        }
        direct.rebuild();

        let mut staged = EGraph::new();
        let sroot = staged.add_expr(&e);
        let matches = rw.search(&staged);
        let intents: Vec<(Id, ApplyIntent)> = matches
            .iter()
            .enumerate()
            .filter_map(|(i, (id, s))| {
                rw.stage(&staged, *id, s, format!("0_{i}")).map(|it| (*id, it))
            })
            .collect();
        for (root, intent) in intents {
            let rhs = intent.commit(&mut staged);
            staged.union(root, rhs);
        }
        staged.rebuild();

        assert_eq!(direct.class(droot).len(), staged.class(sroot).len());
        assert_eq!(direct.num_classes(), staged.num_classes());
        assert_eq!(direct.total_nodes(), staged.total_nodes());
    }

    #[test]
    fn staged_add_hits_frozen_hashcons() {
        // Staging a node that already exists returns the frozen id and
        // records nothing to replay.
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut eg = EGraph::new();
        let root = eg.add_expr(&e);
        let existing = eg.class_nodes(root).next().unwrap().clone();
        let rw = Rewrite::node_scan("noop", OpKind::EAdd, move |g, _, _| {
            Some(g.add(existing.clone()))
        });
        let (id, s) = rw.search(&eg).pop().unwrap();
        let intent = rw.stage(&eg, id, &s, "0_0".to_string()).unwrap();
        assert!(intent.nodes.is_empty());
        assert_eq!(intent.result, root);
    }
}
