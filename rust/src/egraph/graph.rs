//! The e-graph proper: hashcons + e-classes + deferred congruence closure,
//! with a shape/type analysis on every class.
//!
//! Node storage is **arena-interned** (see [`super::intern`]): every
//! inserted node body lives once in `arena`, and classes, parent back-edges
//! and the hashcons all reference it by [`NodeId`]. `add` performs zero
//! node clones on both the hit and miss paths, and `rebuild`'s parent
//! re-canonicalization mutates arena slots in place instead of cloning.

use super::intern::{node_hash, NodeId, NodeTable};
use super::unionfind::UnionFind;
use super::Id;
use crate::fx::FxHashMap as HashMap;
use crate::ir::{infer_ty_ref, Node, RecExpr, Ty};

/// An equivalence class of e-nodes, all computing the same value.
#[derive(Debug, Clone)]
pub struct EClass {
    /// Canonical id (valid as of the last rebuild).
    pub id: Id,
    /// The e-nodes in this class, as arena indices — resolve through
    /// [`EGraph::class_nodes`] / [`EGraph::node`]. Children are canonical
    /// as of the last rebuild; use [`EGraph::find`] when chasing them after
    /// unions.
    pub(crate) node_ids: Vec<NodeId>,
    /// Parent e-nodes (as arena indices) and the class each was memoized
    /// into — the congruence-closure back-edges.
    pub(crate) parents: Vec<(NodeId, Id)>,
    /// Analysis data: the type (index / tensor shape / engine signature).
    /// Every member of a class must agree — this is the semantic guardrail
    /// that catches broken rewrites at union time.
    pub ty: Ty,
}

impl EClass {
    /// Number of e-nodes in this class.
    pub fn len(&self) -> usize {
        self.node_ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.node_ids.is_empty()
    }
}

/// Owned raw parts of an [`EGraph`] — the exact mutable state the snapshot
/// codec persists. Derived state (hashcons memo, live counters) is absent
/// by design; [`EGraph::from_parts`] recomputes it.
#[derive(Debug, Clone)]
pub(crate) struct EGraphParts {
    /// Union-find parent array (`parents[i] == i` marks a root).
    pub parents: Vec<u32>,
    pub classes: Vec<Option<EClass>>,
    pub arena: Vec<Node>,
    pub pending: Vec<Id>,
    pub n_unions: usize,
    pub dirty: bool,
    pub dirty_classes: Vec<Id>,
    pub merged_roots: Vec<Id>,
    pub epoch: u64,
}

/// The e-graph. See the module docs of [`crate::egraph`].
#[derive(Debug, Clone, Default)]
pub struct EGraph {
    uf: UnionFind,
    classes: Vec<Option<EClass>>, // indexed by Id; None once merged away
    /// Hashcons: node content → class, content-compared through the arena.
    memo: NodeTable,
    /// Arena of all inserted node bodies — the single owning store;
    /// classes, parent back-edges and the memo reference it by [`NodeId`].
    arena: Vec<Node>,
    /// Classes whose parents must be re-canonicalized (deferred congruence).
    pending: Vec<Id>,
    /// Cumulative union count (a cheap "how much did rewrites do" metric).
    pub n_unions: usize,
    /// True when `union` has run since the last `rebuild`.
    dirty: bool,
    /// Classes that gained e-nodes (fresh inserts or union merges) since
    /// the last [`EGraph::take_dirty`] — the incremental matcher's work
    /// list. May hold stale/duplicate ids; `take_dirty` canonicalizes.
    dirty_classes: Vec<Id>,
    /// Ids that stopped being canonical (the losing root of each union)
    /// since the last [`EGraph::take_merged_roots`] — consumers holding
    /// canonical ids use this to invalidate selectively.
    merged_roots: Vec<Id>,
    /// Append-only `(epoch, class)` mutation log backing
    /// [`EGraph::changed_since`] — the non-draining channel incremental
    /// read-side consumers (the cost-table cache) use, independent of the
    /// runner-drained `dirty_classes`.
    dirty_log: Vec<(u64, Id)>,
    /// Epoch before which `dirty_log` has no records (0 for fresh graphs;
    /// the load-time epoch for snapshot-restored ones).
    dirty_log_base: u64,
    /// Live class count, maintained by `add`/`union` so per-iteration stats
    /// don't rescan the arena. `num_classes` debug-asserts it against the
    /// scan.
    live_classes: usize,
    /// Live node count across classes (duplicates included until `rebuild`
    /// compacts them, exactly like the scan it replaces).
    live_nodes: usize,
    /// Monotone mutation counter: bumped on every genuine insert and every
    /// effective union — including the congruence unions `rebuild`'s
    /// repair performs (they route through `union` and move canonical
    /// ids). Read-side caches (the extraction cost-table memo) key on this
    /// to detect that the graph they snapshotted is unchanged; only
    /// hashcons hits, no-op unions and `rebuild`'s final compaction (which
    /// dedups without changing the represented term set) leave it alone.
    epoch: u64,
}

impl EGraph {
    pub fn new() -> Self {
        EGraph::default()
    }

    /// Canonical id of `id`.
    #[inline]
    pub fn find(&mut self, id: Id) -> Id {
        self.uf.find(id)
    }

    /// Canonical id without path compression (for `&self` contexts).
    #[inline]
    pub fn find_ref(&self, id: Id) -> Id {
        self.uf.find_immutable(id)
    }

    /// Number of live e-classes. O(1): a live counter maintained by
    /// `add`/`union`; debug builds assert it against the full scan.
    pub fn num_classes(&self) -> usize {
        debug_assert_eq!(
            self.live_classes,
            self.classes.iter().filter(|c| c.is_some()).count(),
            "live class counter diverged from scan"
        );
        self.live_classes
    }

    /// Total number of e-nodes across live classes. O(1): a live counter
    /// maintained by `add`/`rebuild`; debug builds assert it against the
    /// full scan. Like the scan it replaces, this includes not-yet-deduped
    /// duplicates between a `union` and the next `rebuild`.
    pub fn total_nodes(&self) -> usize {
        debug_assert_eq!(
            self.live_nodes,
            self.classes.iter().flatten().map(|c| c.node_ids.len()).sum::<usize>(),
            "live node counter diverged from scan"
        );
        self.live_nodes
    }

    /// O(1) proxy for [`Self::total_nodes`]: the hashcons size (exact after
    /// a rebuild, slight overcount between unions). Use in hot loops.
    pub fn approx_nodes(&self) -> usize {
        self.memo.len()
    }

    /// Total ids ever allocated (live + merged-away). Stage-local ids start
    /// here: any id `>=` this value cannot name a frozen-graph class.
    pub(crate) fn id_count(&self) -> usize {
        self.classes.len()
    }

    /// The mutation epoch: changes iff an insert or an effective union —
    /// explicit or via `rebuild`'s congruence repair — happened since the
    /// value was last read. Hashcons hits and no-op unions leave it
    /// untouched.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The class of (canonical) `id`.
    pub fn class(&self, id: Id) -> &EClass {
        let id = self.find_ref(id);
        self.classes[id.index()].as_ref().expect("stale class id")
    }

    fn class_mut(&mut self, id: Id) -> &mut EClass {
        let id = self.uf.find(id);
        self.classes[id.index()].as_mut().expect("stale class id")
    }

    /// Iterate over live classes.
    pub fn classes(&self) -> impl Iterator<Item = &EClass> {
        self.classes.iter().flatten()
    }

    /// Ids of all live classes (snapshot; safe to mutate while iterating).
    pub fn class_ids(&self) -> Vec<Id> {
        self.classes.iter().flatten().map(|c| c.id).collect()
    }

    /// The e-nodes of `id`'s class, resolved through the arena.
    pub fn class_nodes(&self, id: Id) -> impl Iterator<Item = &Node> + '_ {
        self.class(id).node_ids.iter().map(|nid| &self.arena[nid.index()])
    }

    /// The interned body of one e-node.
    pub fn node(&self, nid: NodeId) -> &Node {
        &self.arena[nid.index()]
    }

    /// Type of `id`'s class.
    pub fn ty(&self, id: Id) -> &Ty {
        &self.class(id).ty
    }

    /// Look up a node without inserting it.
    pub fn lookup(&mut self, node: &Node) -> Option<Id> {
        let mut n = node.clone();
        for c in &mut n.children {
            *c = self.uf.find(*c);
        }
        self.memo.get(node_hash(&n), &n, &self.arena).map(|id| self.uf.find(id))
    }

    /// Look up a node without inserting it (`&self`-only: no path
    /// compression — the staged-apply path probes the frozen graph from
    /// worker threads through this).
    pub fn lookup_ref(&self, node: &Node) -> Option<Id> {
        let mut n = node.clone();
        for c in &mut n.children {
            *c = self.find_ref(*c);
        }
        self.memo.get(node_hash(&n), &n, &self.arena).map(|id| self.find_ref(id))
    }

    /// Insert an e-node (children must be existing class ids), returning its
    /// class. Hash-consing makes this idempotent; this is where the paper's
    /// "identical engine declarations are one piece of hardware" property
    /// comes from.
    pub fn add(&mut self, mut node: Node) -> Id {
        // Canonicalize in place — `add` owns the node, no clone needed.
        for c in &mut node.children {
            *c = self.uf.find(*c);
        }
        let h = node_hash(&node);
        if let Some(id) = self.memo.get(h, &node, &self.arena) {
            return self.uf.find(id);
        }
        // Compute the analysis before allocating the class (by reference:
        // cloning child types would allocate per child on the hot path).
        let ty = {
            let child_tys: Vec<&Ty> =
                node.children.iter().map(|&c| &self.class(c).ty).collect();
            infer_ty_ref(&node.op, &child_tys).unwrap_or_else(|e| {
                panic!("ill-typed e-node {}: {e}", node.op);
            })
        };

        let id = self.uf.make_set();
        debug_assert_eq!(id.index(), self.classes.len());
        let nid = NodeId::from_index(self.arena.len());
        for &c in &node.children {
            self.class_mut(c).parents.push((nid, id));
        }
        self.classes.push(Some(EClass { id, node_ids: vec![nid], parents: vec![], ty }));
        // The node moves into the arena — its single owning store.
        self.arena.push(node);
        self.memo.insert(h, nid, id, &self.arena);
        self.live_classes += 1;
        self.live_nodes += 1;
        self.epoch += 1;
        self.dirty_classes.push(id);
        self.dirty_log.push((self.epoch, id));
        id
    }

    /// Insert a whole expression; returns the root's class.
    pub fn add_expr(&mut self, expr: &RecExpr) -> Id {
        let mut map: Vec<Id> = Vec::with_capacity(expr.len());
        for node in expr.nodes() {
            let n = node.map_children(|c| map[c.index()]);
            map.push(self.add(n));
        }
        *map.last().expect("empty expr")
    }

    /// Assert `a` and `b` compute the same value. Returns the surviving
    /// canonical id and whether anything changed. Congruence repair is
    /// deferred to [`EGraph::rebuild`].
    pub fn union(&mut self, a: Id, b: Id) -> (Id, bool) {
        let ra = self.uf.find(a);
        let rb = self.uf.find(b);
        if ra == rb {
            return (ra, false);
        }
        // Analysis guardrail: merging classes of different type means a
        // rewrite produced a semantically different program.
        let ta = &self.classes[ra.index()].as_ref().unwrap().ty;
        let tb = &self.classes[rb.index()].as_ref().unwrap().ty;
        assert_eq!(
            ta, tb,
            "union of incompatible classes: {ta:?} vs {tb:?} — a rewrite is unsound"
        );

        let keep = self.uf.union(ra, rb);
        let merge = if keep == ra { rb } else { ra };
        let merged = self.classes[merge.index()].take().expect("double merge");
        let kept = self.classes[keep.index()].as_mut().expect("lost keeper");
        kept.node_ids.extend(merged.node_ids);
        kept.parents.extend(merged.parents);
        self.n_unions += 1;
        self.live_classes -= 1;
        self.epoch += 1;
        self.dirty = true;
        self.dirty_classes.push(keep);
        self.dirty_log.push((self.epoch, keep));
        self.merged_roots.push(merge);
        self.pending.push(keep);
        (keep, true)
    }

    /// Restore the congruence invariant after a batch of unions, and
    /// re-canonicalize + dedup the touched classes. Must be called before
    /// matching again; the [`super::Runner`] does this once per iteration.
    pub fn rebuild(&mut self) -> usize {
        let mut repairs = 0;
        while let Some(id) = self.pending.pop() {
            let id = self.uf.find(id);
            if self.classes[id.index()].is_none() {
                continue;
            }
            repairs += 1;
            self.repair(id);
        }
        // Compact: dedup every class's node list so matching and counting
        // see each distinct node once. (Arena content is already canonical:
        // every node with a merged child sits in that child's parents list,
        // which `repair` re-canonicalized in place.)
        if self.dirty {
            self.compact();
            self.dirty = false;
        }
        repairs
    }

    fn repair(&mut self, id: Id) {
        let parents = std::mem::take(&mut self.class_mut(id).parents);
        let mut new_parents: Vec<(NodeId, Id)> = Vec::with_capacity(parents.len());
        // Content-dedup for the rebuilt parent list: hash → indices into
        // `new_parents`, compared through the arena (no node clones).
        let mut dedup: HashMap<u64, Vec<usize>> =
            HashMap::with_capacity_and_hasher(parents.len(), Default::default());
        for (nid, pid) in parents {
            // The parent node's memo entry may be keyed by stale content;
            // remove it before mutating the arena slot.
            let stale_h = node_hash(&self.arena[nid.index()]);
            self.memo.remove(stale_h, &self.arena[nid.index()], &self.arena);
            // Re-canonicalize the arena slot *in place* — this is the
            // rebuild path's zero-clone payoff.
            {
                let node = &mut self.arena[nid.index()];
                let uf = &mut self.uf;
                for c in &mut node.children {
                    *c = uf.find(*c);
                }
            }
            let h = node_hash(&self.arena[nid.index()]);
            let pid = self.uf.find(pid);
            let mut entry = (nid, pid);
            if let Some(existing) = self.memo.get(h, &self.arena[nid.index()], &self.arena) {
                let existing = self.uf.find(existing);
                if existing != pid {
                    // Congruence: same op, same (canonical) children, two
                    // classes -> they must be equal.
                    let (keep, _) = self.union(existing, pid);
                    entry = (nid, keep);
                } else {
                    self.memo.insert(h, nid, pid, &self.arena);
                }
            } else {
                self.memo.insert(h, nid, pid, &self.arena);
            }
            // Dedup content-equal parent entries (last wins, matching the
            // historical map semantics).
            let bucket = dedup.entry(h).or_default();
            let slot = bucket.iter().copied().find(|&i| {
                self.arena[new_parents[i].0.index()] == self.arena[entry.0.index()]
            });
            match slot {
                Some(i) => new_parents[i] = entry,
                None => {
                    bucket.push(new_parents.len());
                    new_parents.push(entry);
                }
            }
        }
        let id = self.uf.find(id);
        self.class_mut(id).parents = new_parents;
    }

    fn compact(&mut self) {
        let ids = self.class_ids();
        let mut dedup: HashMap<u64, Vec<NodeId>> = HashMap::default();
        for id in ids {
            let id = self.uf.find(id);
            let node_ids = std::mem::take(&mut self.class_mut(id).node_ids);
            dedup.clear();
            let before = node_ids.len();
            let mut kept: Vec<NodeId> = Vec::with_capacity(before);
            for nid in node_ids {
                debug_assert!(
                    self.arena[nid.index()]
                        .children
                        .iter()
                        .all(|&c| self.find_ref(c) == c),
                    "compact saw a non-canonical node that repair missed"
                );
                let h = node_hash(&self.arena[nid.index()]);
                let bucket = dedup.entry(h).or_default();
                if bucket
                    .iter()
                    .any(|&k| self.arena[k.index()] == self.arena[nid.index()])
                {
                    continue; // duplicate content, preserve first-seen order
                }
                bucket.push(nid);
                kept.push(nid);
            }
            self.live_nodes -= before - kept.len();
            self.class_mut(id).node_ids = kept;
        }
    }

    /// Drain the dirty set: the canonical, deduplicated, ascending ids of
    /// every class that gained e-nodes (fresh inserts or union merges)
    /// since the previous drain. Freshly built graphs report every class
    /// dirty, so an incremental consumer's first round is a full search.
    /// Call after [`EGraph::rebuild`] so the returned ids are canonical.
    pub fn take_dirty(&mut self) -> Vec<Id> {
        let mut out = std::mem::take(&mut self.dirty_classes);
        for id in &mut out {
            *id = self.uf.find(*id);
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Drain the ids that stopped being canonical (each union's losing
    /// root) since the previous drain. A consumer that caches canonical
    /// ids only needs to re-canonicalize entries mentioning one of these —
    /// everything else is still canonical. Unsorted, may repeat ids that
    /// were never canonical from the consumer's viewpoint (classes created
    /// and merged within one round); both are harmless for invalidation.
    pub fn take_merged_roots(&mut self) -> Vec<Id> {
        std::mem::take(&mut self.merged_roots)
    }

    /// The canonical ids of every class that changed (gained nodes or won a
    /// union) after mutation epoch `since`, or `None` when the graph's
    /// mutation log does not reach back that far (snapshot-restored graphs
    /// only log post-load changes). Unlike [`EGraph::take_dirty`] this is
    /// `&self`-only and non-draining — many read-side consumers can ask
    /// independently. Sorted ascending, deduplicated.
    pub fn changed_since(&self, since: u64) -> Option<Vec<Id>> {
        if since < self.dirty_log_base {
            return None;
        }
        let start = self.dirty_log.partition_point(|&(e, _)| e <= since);
        let mut out: Vec<Id> =
            self.dirty_log[start..].iter().map(|&(_, id)| self.find_ref(id)).collect();
        out.sort_unstable();
        out.dedup();
        Some(out)
    }

    /// `seeds` plus every class reachable by walking parent back-edges up
    /// to `levels` hops — i.e. every class where a pattern that reaches
    /// `levels` deep could root a *new* match after the seed classes
    /// changed. Returns canonical ids, ascending, deduplicated. Stale seed
    /// ids are resolved to their canonical (live) class first.
    pub fn with_ancestors(&self, seeds: &[Id], levels: usize) -> Vec<Id> {
        let mut seen: HashMap<Id, ()> =
            HashMap::with_capacity_and_hasher(seeds.len() * 2, Default::default());
        let mut frontier: Vec<Id> = Vec::with_capacity(seeds.len());
        for &id in seeds {
            let id = self.find_ref(id);
            if seen.insert(id, ()).is_none() {
                frontier.push(id);
            }
        }
        for _ in 0..levels {
            let mut next = Vec::new();
            for &id in &frontier {
                for &(_, pid) in &self.class(id).parents {
                    let pid = self.find_ref(pid);
                    if seen.insert(pid, ()).is_none() {
                        next.push(pid);
                    }
                }
            }
            if next.is_empty() {
                break;
            }
            frontier = next;
        }
        let mut out: Vec<Id> = seen.into_keys().collect();
        out.sort_unstable();
        out
    }

    /// Dismantle into owned raw parts for the snapshot codec. The memo, the
    /// live counters and the mutation log are **not** part of the raw form:
    /// memo and counters are derived state [`EGraph::from_parts`]
    /// reconstructs from the classes, and the log is a transient read-side
    /// channel (restored graphs report `changed_since` coverage only from
    /// the load epoch forward).
    pub(crate) fn to_parts(&self) -> EGraphParts {
        EGraphParts {
            parents: self.uf.raw_parents().to_vec(),
            classes: self.classes.clone(),
            arena: self.arena.clone(),
            pending: self.pending.clone(),
            n_unions: self.n_unions,
            dirty: self.dirty,
            dirty_classes: self.dirty_classes.clone(),
            merged_roots: self.merged_roots.clone(),
            epoch: self.epoch,
        }
    }

    /// Rebuild an e-graph from raw parts (snapshot load). Derived state —
    /// the hashcons memo and the live class/node counters — is recomputed
    /// from the classes; everything else (union-find, arena, counters,
    /// **epoch**) is restored verbatim, so epoch-keyed read caches built
    /// against the saved graph stay valid against the loaded one. The
    /// caller (the snapshot decoder) is responsible for structural bounds
    /// checks; this constructor only re-derives.
    pub(crate) fn from_parts(parts: EGraphParts) -> Self {
        let mut memo = NodeTable::with_capacity(parts.arena.len());
        let mut live_classes = 0;
        let mut live_nodes = 0;
        for class in parts.classes.iter().flatten() {
            live_classes += 1;
            live_nodes += class.node_ids.len();
            for &nid in &class.node_ids {
                let h = node_hash(&parts.arena[nid.index()]);
                memo.insert(h, nid, class.id, &parts.arena);
            }
        }
        EGraph {
            uf: UnionFind::from_raw(parts.parents),
            classes: parts.classes,
            memo,
            arena: parts.arena,
            pending: parts.pending,
            n_unions: parts.n_unions,
            dirty: parts.dirty,
            dirty_classes: parts.dirty_classes,
            merged_roots: parts.merged_roots,
            dirty_log: Vec::new(),
            dirty_log_base: parts.epoch,
            live_classes,
            live_nodes,
            epoch: parts.epoch,
        }
    }

    /// Quick structural sanity check used by tests and debug assertions:
    /// every node's children are live canonical classes, and the memo maps
    /// every canonical node to its canonical class.
    pub fn check_invariants(&self) {
        for class in self.classes() {
            assert_eq!(self.find_ref(class.id), class.id, "class id not canonical");
            for &nid in &class.node_ids {
                for &c in &self.arena[nid.index()].children {
                    let c = self.find_ref(c);
                    assert!(
                        self.classes[c.index()].is_some(),
                        "dangling child {c:?} in class {:?}",
                        class.id
                    );
                }
            }
        }
        for (nid, id) in self.memo.iter() {
            let node = &self.arena[nid.index()];
            let canon_ok = node.children.iter().all(|&c| self.find_ref(c) == c);
            if canon_ok {
                let id = self.find_ref(id);
                assert!(self.classes[id.index()].is_some(), "memo points at dead class");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Op, Shape, Symbol};

    fn input(name: &str, dims: &[usize]) -> Node {
        Node::leaf(Op::Input(Symbol::new(name), Shape::new(dims)))
    }

    #[test]
    fn hashcons_dedups() {
        let mut eg = EGraph::new();
        let a = eg.add(input("x", &[4]));
        let b = eg.add(input("x", &[4]));
        assert_eq!(a, b);
        assert_eq!(eg.num_classes(), 1);
    }

    #[test]
    fn engines_share_by_structure() {
        let mut eg = EGraph::new();
        let e1 = eg.add(Node::leaf(Op::MmEngine { m: 16, k: 16, n: 16 }));
        let e2 = eg.add(Node::leaf(Op::MmEngine { m: 16, k: 16, n: 16 }));
        let e3 = eg.add(Node::leaf(Op::MmEngine { m: 16, k: 16, n: 8 }));
        assert_eq!(e1, e2);
        assert_ne!(e1, e3);
    }

    #[test]
    fn union_then_congruence() {
        // relu(x) and relu(y): unioning x=y must merge the relus.
        let mut eg = EGraph::new();
        let x = eg.add(input("x", &[4]));
        let y = eg.add(input("y", &[4]));
        let rx = eg.add(Node::new(Op::Relu, vec![x]));
        let ry = eg.add(Node::new(Op::Relu, vec![y]));
        assert_ne!(eg.find(rx), eg.find(ry));
        eg.union(x, y);
        eg.rebuild();
        assert_eq!(eg.find(rx), eg.find(ry));
        eg.check_invariants();
    }

    #[test]
    fn congruence_cascades() {
        // deep chain: relu^3(x), relu^3(y); union x=y merges all levels.
        let mut eg = EGraph::new();
        let x = eg.add(input("x", &[4]));
        let y = eg.add(input("y", &[4]));
        let (mut cx, mut cy) = (x, y);
        let mut tops = vec![];
        for _ in 0..3 {
            cx = eg.add(Node::new(Op::Relu, vec![cx]));
            cy = eg.add(Node::new(Op::Relu, vec![cy]));
            tops.push((cx, cy));
        }
        eg.union(x, y);
        eg.rebuild();
        for (a, b) in tops {
            assert_eq!(eg.find(a), eg.find(b));
        }
        eg.check_invariants();
    }

    #[test]
    #[should_panic(expected = "unsound")]
    fn union_rejects_shape_mismatch() {
        let mut eg = EGraph::new();
        let a = eg.add(input("a", &[4]));
        let b = eg.add(input("b", &[8]));
        eg.union(a, b);
    }

    #[test]
    fn add_expr_roundtrip() {
        let e = crate::ir::parse::parse_expr(
            "(invoke-relu (relu-engine 128) (input x [128]))",
        )
        .unwrap();
        let mut eg = EGraph::new();
        let root = eg.add_expr(&e);
        assert_eq!(eg.num_classes(), 3);
        assert_eq!(eg.ty(root), &Ty::Tensor(Shape::new(&[128])));
    }

    #[test]
    fn class_nodes_resolve_through_arena() {
        let mut eg = EGraph::new();
        let x = eg.add(input("x", &[4]));
        let y = eg.add(input("y", &[4]));
        eg.union(x, y);
        eg.rebuild();
        let ops: Vec<String> =
            eg.class_nodes(x).map(|n| n.op.to_string()).collect();
        assert_eq!(ops.len(), 2, "merged class holds both distinct inputs");
        assert_eq!(eg.class(x).len(), 2);
    }

    #[test]
    fn dirty_set_tracks_gains_and_drains() {
        let mut eg = EGraph::new();
        let x = eg.add(input("x", &[4]));
        let y = eg.add(input("y", &[4]));
        let rx = eg.add(Node::new(Op::Relu, vec![x]));
        // Fresh graph: every class is dirty.
        assert_eq!(eg.take_dirty(), {
            let mut v = vec![x, y, rx];
            v.sort_unstable();
            v
        });
        // Nothing changed since: dirty set is empty.
        assert!(eg.take_dirty().is_empty());
        // A union dirties the surviving class (canonicalized).
        eg.union(x, y);
        eg.rebuild();
        let d = eg.take_dirty();
        assert_eq!(d, vec![eg.find_ref(x)]);
        // A hashcons hit adds nothing.
        eg.add(input("x", &[4]));
        assert!(eg.take_dirty().is_empty());
    }

    #[test]
    fn changed_since_is_nondraining_and_epoch_scoped() {
        let mut eg = EGraph::new();
        let e0 = eg.epoch();
        let x = eg.add(input("x", &[4]));
        let y = eg.add(input("y", &[4]));
        let mid = eg.epoch();
        let rx = eg.add(Node::new(Op::Relu, vec![x]));
        // Full-history query sees all three classes; repeatable (&self).
        let all = eg.changed_since(e0).unwrap();
        assert_eq!(all, {
            let mut v = vec![x, y, rx];
            v.sort_unstable();
            v
        });
        assert_eq!(eg.changed_since(e0).unwrap(), all);
        // Mid-epoch query sees only later mutations.
        assert_eq!(eg.changed_since(mid).unwrap(), vec![rx]);
        assert!(eg.changed_since(eg.epoch()).unwrap().is_empty());
        // Unions log the surviving class, canonicalized at read time.
        eg.union(x, y);
        eg.rebuild();
        let after = eg.changed_since(mid).unwrap();
        assert!(after.contains(&eg.find_ref(x)));
        // A restored graph's log doesn't reach back before the load epoch.
        let restored = EGraph::from_parts(eg.to_parts());
        assert_eq!(restored.changed_since(restored.epoch()), Some(vec![]));
        assert_eq!(restored.changed_since(e0), None);
    }

    #[test]
    fn merged_roots_drain_reports_losing_ids() {
        let mut eg = EGraph::new();
        let x = eg.add(input("x", &[4]));
        let y = eg.add(input("y", &[4]));
        let rx = eg.add(Node::new(Op::Relu, vec![x]));
        let ry = eg.add(Node::new(Op::Relu, vec![y]));
        assert!(eg.take_merged_roots().is_empty());
        eg.union(x, y);
        eg.rebuild(); // congruence also merges rx/ry
        let mut merged = eg.take_merged_roots();
        merged.sort_unstable();
        // Losers: y (explicit union) and the relu class that lost the
        // congruence union.
        assert_eq!(merged.len(), 2);
        assert!(merged.contains(&y));
        assert!(merged.contains(&rx.max(ry)));
        assert!(eg.take_merged_roots().is_empty());
    }

    #[test]
    fn with_ancestors_walks_parent_levels() {
        // relu(relu(relu(x))): ancestors of {x} at level k.
        let mut eg = EGraph::new();
        let x = eg.add(input("x", &[4]));
        let r1 = eg.add(Node::new(Op::Relu, vec![x]));
        let r2 = eg.add(Node::new(Op::Relu, vec![r1]));
        let r3 = eg.add(Node::new(Op::Relu, vec![r2]));
        assert_eq!(eg.with_ancestors(&[x], 0), vec![x]);
        assert_eq!(eg.with_ancestors(&[x], 1), vec![x, r1]);
        assert_eq!(eg.with_ancestors(&[x], 2), vec![x, r1, r2]);
        // Levels past the top are harmless.
        assert_eq!(eg.with_ancestors(&[x], 10), vec![x, r1, r2, r3]);
    }

    #[test]
    fn live_counters_match_scans_through_rewriting() {
        let mut eg = EGraph::new();
        let x = eg.add(input("x", &[4]));
        let y = eg.add(input("y", &[4]));
        let rx = eg.add(Node::new(Op::Relu, vec![x]));
        let ry = eg.add(Node::new(Op::Relu, vec![y]));
        assert_eq!(eg.num_classes(), 4);
        assert_eq!(eg.total_nodes(), 4);
        eg.union(x, y);
        // Pre-rebuild: one class merged away, nodes moved (with a duplicate
        // pending compaction) — the debug asserts inside the accessors
        // check counter == scan at every step.
        assert_eq!(eg.num_classes(), 3);
        assert_eq!(eg.total_nodes(), 4);
        eg.rebuild();
        // Congruence merged the relus and compaction deduped their nodes;
        // the input class keeps both (distinct) input e-nodes.
        assert_eq!(eg.find(rx), eg.find(ry));
        assert_eq!(eg.num_classes(), 2);
        assert_eq!(eg.total_nodes(), 3);
    }

    #[test]
    fn epoch_tracks_genuine_mutations_only() {
        let mut eg = EGraph::new();
        let e0 = eg.epoch();
        let x = eg.add(input("x", &[4]));
        let y = eg.add(input("y", &[4]));
        let after_adds = eg.epoch();
        assert!(after_adds > e0);
        // Hashcons hit: nothing new is represented.
        eg.add(input("x", &[4]));
        assert_eq!(eg.epoch(), after_adds);
        // Effective union bumps; replayed (no-op) union does not.
        eg.union(x, y);
        let after_union = eg.epoch();
        assert!(after_union > after_adds);
        // A rebuild with no congruence to repair (leaf classes only) and
        // only compaction to do leaves the epoch alone.
        eg.rebuild();
        let after_rebuild = eg.epoch();
        assert_eq!(after_rebuild, after_union);
        eg.union(x, y);
        assert_eq!(eg.epoch(), after_rebuild);
    }

    #[test]
    fn epoch_bumps_on_congruence_unions_during_rebuild() {
        // relu(x) / relu(y): unioning x=y leaves congruence for rebuild to
        // repair; that repair unions the relu classes and must bump the
        // epoch (canonical ids move, so caches keyed on it must refresh).
        let mut eg = EGraph::new();
        let x = eg.add(input("x", &[4]));
        let y = eg.add(input("y", &[4]));
        let rx = eg.add(Node::new(Op::Relu, vec![x]));
        let ry = eg.add(Node::new(Op::Relu, vec![y]));
        eg.union(x, y);
        let before_rebuild = eg.epoch();
        eg.rebuild();
        assert!(eg.epoch() > before_rebuild);
        assert_eq!(eg.find(rx), eg.find(ry));
    }

    #[test]
    fn raw_parts_roundtrip_preserves_state_and_rebuilds_memo() {
        let mut eg = EGraph::new();
        let x = eg.add(input("x", &[4]));
        let y = eg.add(input("y", &[4]));
        let rx = eg.add(Node::new(Op::Relu, vec![x]));
        let ry = eg.add(Node::new(Op::Relu, vec![y]));
        eg.union(x, y);
        eg.rebuild();
        let mut back = EGraph::from_parts(eg.to_parts());
        back.check_invariants();
        assert_eq!(back.epoch(), eg.epoch());
        assert_eq!(back.num_classes(), eg.num_classes());
        assert_eq!(back.total_nodes(), eg.total_nodes());
        assert_eq!(back.n_unions, eg.n_unions);
        assert_eq!(back.find(rx), eg.find_ref(rx));
        assert_eq!(back.find(ry), eg.find_ref(ry));
        // The rebuilt memo hash-conses: re-adding an existing node is a hit
        // (no epoch bump), and the pending dirty set carried over verbatim.
        let before = back.epoch();
        assert_eq!(back.add(input("x", &[4])), back.find(x));
        assert_eq!(back.epoch(), before);
        assert_eq!(back.take_dirty(), eg.take_dirty());
    }

    #[test]
    fn rebuild_is_idempotent() {
        let mut eg = EGraph::new();
        let x = eg.add(input("x", &[4]));
        let y = eg.add(input("y", &[4]));
        eg.union(x, y);
        assert!(eg.rebuild() > 0);
        assert_eq!(eg.rebuild(), 0);
    }
}
