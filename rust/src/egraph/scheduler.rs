//! Rule scheduling: per-iteration fairness between rewrite rules.
//!
//! The search phase asks the scheduler two questions per rule per
//! iteration: *may this rule search at all?* ([`Scheduler::can_search`] —
//! banned rules skip the search entirely, which is cheaper than searching
//! and discarding) and *which of its matches survive?*
//! ([`Scheduler::filter_matches`]). Two implementations ship:
//!
//! * [`SimpleScheduler`] — truncate each rule's match list to a fixed
//!   per-iteration cap. This is exactly the engine's historical
//!   `max_matches_per_rule` behavior, kept as the reference semantics the
//!   equivalence tests pin.
//! * [`BackoffScheduler`] — egg-style exponential backoff: a rule that
//!   overflows its match budget contributes *nothing* this iteration and
//!   sits out an exponentially growing ban window. Unlike prefix
//!   truncation (which permanently favors matches in low-numbered
//!   e-classes), backoff lets explosive rules participate fully in the
//!   iterations where they do run, so cheap rules aren't starved and the
//!   sampled space is less biased toward the front of the class table.
//!
//! The runner re-offers un-searched work to banned rules when their window
//! expires (see `rule_backlog` in [`super::runner`]), so under the
//! incremental engine a ban delays — never drops — a rule's matches.

use super::pattern::Subst;
use super::rewrite::Rewrite;
use super::runner::RunnerLimits;
use super::Id;
use crate::error::Error;

/// Decides, per iteration, which rules search and which matches survive.
///
/// Implementations are stateful (ban windows, budgets); the runner owns
/// the scheduler and calls it from the single-threaded phase boundaries,
/// never from search workers.
pub trait Scheduler: std::fmt::Debug + Send + Sync {
    /// May `rule` search this iteration? Returning false skips the search
    /// phase for the rule; the runner banks the skipped work and re-offers
    /// it when this returns true again.
    fn can_search(&mut self, iteration: usize, rule_idx: usize, rule: &Rewrite) -> bool {
        let _ = (iteration, rule_idx, rule);
        true
    }

    /// Inspect — and possibly truncate or drop — a rule's matches for this
    /// iteration. Called once per searchable rule, after the (parallel)
    /// search phase has merged its shards in deterministic order.
    fn filter_matches(
        &mut self,
        iteration: usize,
        rule_idx: usize,
        rule: &Rewrite,
        matches: Vec<(Id, Subst)>,
    ) -> Vec<(Id, Subst)>;
}

/// The reference scheduler: cap each rule at `match_limit` matches per
/// iteration by prefix truncation — the engine's historical
/// `max_matches_per_rule` semantics, preserved for tests and as the
/// baseline the equivalence suite compares against.
#[derive(Debug, Clone)]
pub struct SimpleScheduler {
    pub match_limit: usize,
}

impl SimpleScheduler {
    pub fn new(match_limit: usize) -> Self {
        SimpleScheduler { match_limit }
    }
}

impl Default for SimpleScheduler {
    fn default() -> Self {
        SimpleScheduler::new(RunnerLimits::default().max_matches_per_rule)
    }
}

impl Scheduler for SimpleScheduler {
    fn filter_matches(
        &mut self,
        _iteration: usize,
        _rule_idx: usize,
        _rule: &Rewrite,
        mut matches: Vec<(Id, Subst)>,
    ) -> Vec<(Id, Subst)> {
        if matches.len() > self.match_limit {
            matches.truncate(self.match_limit);
        }
        matches
    }
}

/// Per-rule backoff state.
#[derive(Debug, Clone, Copy, Default)]
struct RuleBackoff {
    times_banned: u32,
    banned_until: usize,
}

/// Egg-style exponential-backoff scheduler: a rule whose match count
/// exceeds `match_limit << times_banned` is banned for
/// `ban_length << times_banned` iterations and contributes no matches this
/// round. See the module docs for why this beats prefix truncation.
#[derive(Debug, Clone)]
pub struct BackoffScheduler {
    pub match_limit: usize,
    pub ban_length: usize,
    stats: Vec<RuleBackoff>,
}

impl BackoffScheduler {
    pub fn new(match_limit: usize, ban_length: usize) -> Self {
        BackoffScheduler { match_limit: match_limit.max(1), ban_length, stats: Vec::new() }
    }

    fn stat(&mut self, rule_idx: usize) -> &mut RuleBackoff {
        if self.stats.len() <= rule_idx {
            self.stats.resize(rule_idx + 1, RuleBackoff::default());
        }
        &mut self.stats[rule_idx]
    }
}

impl Default for BackoffScheduler {
    /// egg's defaults: 1000 matches, 5-iteration base ban.
    fn default() -> Self {
        BackoffScheduler::new(1000, 5)
    }
}

impl Scheduler for BackoffScheduler {
    fn can_search(&mut self, iteration: usize, rule_idx: usize, _rule: &Rewrite) -> bool {
        iteration >= self.stat(rule_idx).banned_until
    }

    fn filter_matches(
        &mut self,
        iteration: usize,
        rule_idx: usize,
        _rule: &Rewrite,
        matches: Vec<(Id, Subst)>,
    ) -> Vec<(Id, Subst)> {
        let limit = self.match_limit;
        let ban_length = self.ban_length;
        let s = self.stat(rule_idx);
        let threshold = limit.checked_shl(s.times_banned).unwrap_or(usize::MAX);
        if matches.len() > threshold {
            let ban = ban_length.checked_shl(s.times_banned).unwrap_or(usize::MAX);
            s.banned_until = iteration.saturating_add(ban).saturating_add(1);
            s.times_banned = s.times_banned.saturating_add(1);
            return Vec::new();
        }
        matches
    }
}

/// A named scheduler configuration, parseable from CLI / builder strings
/// (`"simple"` / `"backoff"`). [`SchedulerSpec::build`] instantiates it
/// against the run's limits; custom [`Scheduler`] impls bypass this and
/// plug in as boxed trait objects directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerSpec {
    /// [`SimpleScheduler`] with the limits' `max_matches_per_rule`.
    Simple,
    /// [`BackoffScheduler`] with egg's default budget and ban window.
    Backoff,
}

impl SchedulerSpec {
    pub fn build(self, limits: &RunnerLimits) -> Box<dyn Scheduler> {
        match self {
            SchedulerSpec::Simple => Box::new(SimpleScheduler::new(limits.max_matches_per_rule)),
            SchedulerSpec::Backoff => Box::<BackoffScheduler>::default(),
        }
    }
}

impl std::str::FromStr for SchedulerSpec {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self, Error> {
        match s {
            "simple" => Ok(SchedulerSpec::Simple),
            "backoff" => Ok(SchedulerSpec::Backoff),
            other => Err(Error::UnknownScheduler(other.to_string())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::OpKind;

    fn dummy_rule() -> Rewrite {
        Rewrite::node_scan("dummy", OpKind::EAdd, |_, _, _| None)
    }

    fn fake_matches(n: usize) -> Vec<(Id, Subst)> {
        (0..n).map(|i| (Id::from_index(i), Subst::default())).collect()
    }

    #[test]
    fn simple_truncates_to_limit() {
        let mut s = SimpleScheduler::new(3);
        let rule = dummy_rule();
        assert_eq!(s.filter_matches(0, 0, &rule, fake_matches(10)).len(), 3);
        assert_eq!(s.filter_matches(1, 0, &rule, fake_matches(2)).len(), 2);
        assert!(s.can_search(2, 0, &rule), "simple never bans");
    }

    #[test]
    fn backoff_bans_exponentially_then_readmits() {
        let mut s = BackoffScheduler::new(4, 2);
        let rule = dummy_rule();
        // Overflow: everything dropped, banned for 2 iterations.
        assert!(s.filter_matches(0, 0, &rule, fake_matches(5)).is_empty());
        assert!(!s.can_search(1, 0, &rule));
        assert!(!s.can_search(2, 0, &rule));
        assert!(s.can_search(3, 0, &rule));
        // Second overflow needs > 8 matches and bans for 4.
        assert_eq!(s.filter_matches(3, 0, &rule, fake_matches(8)).len(), 8);
        assert!(s.filter_matches(4, 0, &rule, fake_matches(9)).is_empty());
        assert!(!s.can_search(8, 0, &rule));
        assert!(s.can_search(9, 0, &rule));
        // Other rules are unaffected throughout.
        assert!(s.can_search(1, 1, &rule));
    }

    #[test]
    fn spec_parses_and_builds() {
        let limits = RunnerLimits { max_matches_per_rule: 7, ..Default::default() };
        assert_eq!("simple".parse::<SchedulerSpec>().unwrap(), SchedulerSpec::Simple);
        assert_eq!("backoff".parse::<SchedulerSpec>().unwrap(), SchedulerSpec::Backoff);
        assert!(matches!(
            "bogus".parse::<SchedulerSpec>().unwrap_err(),
            Error::UnknownScheduler(ref n) if n == "bogus"
        ));
        // Simple picks up the limits' cap.
        let mut built = SchedulerSpec::Simple.build(&limits);
        let rule = dummy_rule();
        assert_eq!(built.filter_matches(0, 0, &rule, fake_matches(20)).len(), 7);
    }
}
