//! The phased saturation engine: **search** (read-only, incremental,
//! parallel) → **apply** (staged in parallel waves, committed
//! deterministically, memoized) → **rebuild** (congruence repair); repeat
//! until saturation or a budget trips.
//!
//! ## Phases
//!
//! **Search** never mutates the e-graph, so it fans out over the scoped
//! worker pool ([`crate::par::parallel_map`]): the work list is sharded
//! into `(rule × class-chunk)` items and the shard results are merged back
//! in item order, which makes the match stream — and therefore the whole
//! run — deterministic regardless of worker count.
//!
//! By default search is **incremental** ([`SearchMode::Incremental`]):
//! after the first iteration, rules only re-match against classes that
//! gained e-nodes since the last iteration ([`EGraph::take_dirty`]) widened
//! by each rule's ancestor reach ([`Rewrite::ancestor_levels`]) — a change
//! `k` levels below a match root can only create new matches for patterns
//! that look `k` deep. [`SearchMode::FullRescan`] re-matches everything
//! every iteration; the equivalence tests pin that both modes produce the
//! same e-graph.
//!
//! **Apply** walks the scheduler-filtered match stream in deterministic
//! order, cutting it into *waves* of matches whose footprints (root class +
//! binding classes, under the current union-find) are pairwise disjoint.
//! Each wave's appliers run **in parallel** against the frozen graph
//! (`--apply-workers` wide), building node/union *intents*
//! ([`super::rewrite::ApplyIntent`]) through the staged
//! [`super::rewrite::ApplyGraph`]; the intents are then committed
//! single-threaded, in stream order. Wave boundaries and commit order
//! depend only on the (deterministic) match stream — and staged appliers
//! mint position-derived fresh symbols instead of drawing from the global
//! counter — so the resulting e-graph is **bit-identical for any worker
//! count**. Fired applications are memoized by `(rule, root class,
//! canonicalized bindings)` and never replayed: appliers mint fresh
//! loop-variable symbols, so without the memo every re-found match would
//! union in another α-variant of an RHS the graph already has, bloating
//! the node budget with junk. Declined matches are *not* memoized — an
//! applier may legitimately succeed later (e.g. once a child class gains a
//! schedule node).
//!
//! **Rebuild** restores the congruence invariant ([`EGraph::rebuild`]),
//! feeding the next iteration's dirty set.
//!
//! ## Scheduling
//!
//! Which rules run, and which of their matches survive, is delegated to a
//! pluggable [`Scheduler`] (default: [`SimpleScheduler`], the historical
//! `max_matches_per_rule` truncation; [`BackoffScheduler`] for egg-style
//! exponential backoff). While a rule is banned the engine banks the dirty
//! classes it did not get to search (`rule_backlog`) and re-offers them
//! when the ban lifts, so scheduling delays matches rather than losing
//! them.
//!
//! Per-iteration growth statistics ([`IterationStats`], including per-rule
//! match/apply counts) remain the raw data for the paper's
//! design-space-size experiments.

use super::count;
use super::graph::EGraph;
use super::pattern::Subst;
use super::rewrite::{ApplyIntent, Rewrite};
use super::scheduler::{Scheduler, SimpleScheduler};
use super::Id;
use crate::fx::FxHashSet;
use crate::ir::{Node, Op, RecExpr, Symbol};
use crate::par::{default_workers, parallel_map};
use std::time::{Duration, Instant};

/// Why a run stopped.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StopReason {
    /// No rule produced a new fact: the space is fully enumerated.
    Saturated,
    /// Hit the iteration budget.
    IterLimit,
    /// Hit the e-node budget.
    NodeLimit,
    /// Hit the wall-clock budget.
    TimeLimit,
}

/// How the search phase picks the classes each rule matches against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SearchMode {
    /// Match only against classes that changed since the last iteration
    /// (plus each rule's ancestor reach). The default.
    #[default]
    Incremental,
    /// Match against every live class every iteration — the reference
    /// semantics the equivalence tests compare against.
    FullRescan,
}

/// Budgets for a run. Defaults are sized for interactive exploration.
#[derive(Debug, Clone)]
pub struct RunnerLimits {
    pub max_iters: usize,
    pub max_nodes: usize,
    pub max_time: Duration,
    /// Per-rule, per-iteration match cap applied by the default
    /// [`SimpleScheduler`]; a custom scheduler may interpret or ignore it.
    pub max_matches_per_rule: usize,
    /// Recompute the distinct-design lower bound after every iteration
    /// (an `O(nodes × rounds)` fixpoint — see [`super::count`]). Growth
    /// experiments want the per-iteration curve; plain enumeration (the
    /// session path) defaults it off and `designs_lower_bound` in
    /// [`IterationStats`] is `NaN`. The final count in [`RunnerReport`] is
    /// always computed.
    pub track_designs: bool,
}

impl Default for RunnerLimits {
    fn default() -> Self {
        RunnerLimits {
            max_iters: 16,
            max_nodes: 200_000,
            max_time: Duration::from_secs(30),
            max_matches_per_rule: 50_000,
            track_designs: true,
        }
    }
}

/// Per-rule search/apply counters for one iteration, indexed like
/// `Runner::rules` (names in [`RunnerReport::rule_names`]).
#[derive(Debug, Clone, Default)]
pub struct RuleIterStats {
    /// Matches found by the search phase (before scheduler filtering).
    pub matches: usize,
    /// Applications that changed the e-graph.
    pub applied: usize,
    /// True if the scheduler sidelined the rule this iteration — refused
    /// the search outright, or dropped some or all of its matches (overflow
    /// ban / cap truncation). Its pending work is banked and re-offered.
    pub banned: bool,
}

/// Growth metrics after one iteration.
#[derive(Debug, Clone)]
pub struct IterationStats {
    pub iteration: usize,
    pub nodes: usize,
    pub classes: usize,
    pub applied: usize,
    pub unions_total: usize,
    /// Lower bound on the number of distinct designs rooted at the
    /// workload (see [`super::count`]). `NaN` when
    /// [`RunnerLimits::track_designs`] is off.
    pub designs_lower_bound: f64,
    pub elapsed: Duration,
    /// How many e-classes the widest rule's search visited this iteration
    /// (equals the live class count on iteration 0 and under
    /// [`SearchMode::FullRescan`]; shrinks toward the dirty-set size as the
    /// graph stabilizes).
    pub searched_classes: usize,
    /// Wall-clock of the search phase (work lists + parallel match +
    /// scheduler filtering).
    pub search_time: Duration,
    /// Wall-clock of the apply phase (wave partitioning + parallel staging
    /// + sequential commit).
    pub apply_time: Duration,
    /// Wall-clock of the rebuild phase (congruence repair + memo
    /// re-canonicalization).
    pub rebuild_time: Duration,
    /// How many conflict-free waves the apply phase cut the match stream
    /// into (1 when every match's footprint was disjoint).
    pub apply_waves: usize,
    /// Per-rule breakdown.
    pub per_rule: Vec<RuleIterStats>,
}

/// Summary of a completed run.
#[derive(Debug, Clone)]
pub struct RunnerReport {
    pub stop: StopReason,
    pub iterations: Vec<IterationStats>,
    pub nodes: usize,
    pub classes: usize,
    pub designs_lower_bound: f64,
    pub elapsed: Duration,
    /// Rule names, indexing [`IterationStats::per_rule`].
    pub rule_names: Vec<String>,
}

impl RunnerReport {
    /// Summed per-phase wall-clock across all iterations:
    /// `(search, apply, rebuild)`. The perf benches report these as the
    /// saturation breakdown.
    pub fn phase_totals(&self) -> (Duration, Duration, Duration) {
        let mut t = (Duration::ZERO, Duration::ZERO, Duration::ZERO);
        for it in &self.iterations {
            t.0 += it.search_time;
            t.1 += it.apply_time;
            t.2 += it.rebuild_time;
        }
        t
    }

    /// Render as an aligned text table (used by examples and benches).
    pub fn table(&self) -> String {
        let mut s = String::from(
            "iter    e-nodes  e-classes   searched    applied     designs(lb)   elapsed\n",
        );
        for it in &self.iterations {
            let designs = if it.designs_lower_bound.is_nan() {
                format!("{:>15}", "-")
            } else {
                format!("{:>15.4e}", it.designs_lower_bound)
            };
            s.push_str(&format!(
                "{:<4} {:>10} {:>10} {:>10} {:>10} {} {:>9.1?}\n",
                it.iteration, it.nodes, it.classes, it.searched_classes, it.applied, designs,
                it.elapsed,
            ));
        }
        s.push_str(&format!("stop: {:?}\n", self.stop));
        s
    }

    /// Per-rule totals across the run (matches found, effective
    /// applications, iterations sat out banned), as an aligned table.
    pub fn rule_table(&self) -> String {
        let mut s = String::from("rule                      matches    applied     banned\n");
        for (ri, name) in self.rule_names.iter().enumerate() {
            let (mut m, mut a, mut b) = (0usize, 0usize, 0usize);
            for it in &self.iterations {
                if let Some(r) = it.per_rule.get(ri) {
                    m += r.matches;
                    a += r.applied;
                    b += r.banned as usize;
                }
            }
            s.push_str(&format!("{name:<24} {m:>9} {a:>10} {b:>10}\n"));
        }
        s
    }
}

/// Memo key for one fired application: rule index, root class, and the
/// substitution's bindings, all canonical *as of the searched (frozen)
/// graph*. Keys are computed before any of the iteration's unions and the
/// stored set is re-canonicalized after every rebuild, so a replayed match
/// always hits the memo even after its bindings' classes merge. See the
/// module docs — replaying a fired match would mint a fresh α-variant RHS.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct MatchKey {
    rule: usize,
    root: Id,
    node: Option<Node>,
    vars: Vec<(Symbol, Id)>,
    ops: Vec<(Symbol, Op)>,
}

impl MatchKey {
    fn of(eg: &EGraph, rule: usize, root: Id, subst: &Subst) -> Self {
        let mut vars: Vec<(Symbol, Id)> = subst.vars.iter().map(|(&s, &id)| (s, id)).collect();
        vars.sort_unstable_by_key(|(s, _)| *s);
        let mut ops: Vec<(Symbol, Op)> =
            subst.ops.iter().map(|(&s, op)| (s, op.clone())).collect();
        ops.sort_unstable_by_key(|(s, _)| *s);
        MatchKey { rule, root, node: subst.node.clone(), vars, ops }.canonicalize(eg)
    }

    fn canonicalize(mut self, eg: &EGraph) -> Self {
        self.root = eg.find_ref(self.root);
        if let Some(n) = &mut self.node {
            for c in &mut n.children {
                *c = eg.find_ref(*c);
            }
        }
        for (_, id) in &mut self.vars {
            *id = eg.find_ref(*id);
        }
        self
    }
}

/// Drives rewrites over an [`EGraph`] holding one workload.
pub struct Runner {
    pub egraph: EGraph,
    pub root: Id,
    pub rules: Vec<Rewrite>,
    pub limits: RunnerLimits,
    /// Rule scheduler; `None` means "a [`SimpleScheduler`] built from
    /// `limits.max_matches_per_rule` at run time".
    pub scheduler: Option<Box<dyn Scheduler>>,
    /// Worker-pool width for the search phase (≥ 1; 1 searches inline).
    pub search_workers: usize,
    /// Worker-pool width for staging each apply wave (≥ 1; 1 stages
    /// inline). Any value produces the bit-identical e-graph — staging is
    /// a pure function of the frozen graph and commits replay in stream
    /// order either way.
    pub apply_workers: usize,
    pub search_mode: SearchMode,
    pub stats: Vec<IterationStats>,
    /// Fired-application memo (see [`MatchKey`]).
    applied_memo: FxHashSet<MatchKey>,
    /// Dirty classes a banned rule has not yet searched, per rule;
    /// re-offered when its ban lifts.
    rule_backlog: Vec<Vec<Id>>,
}

impl Runner {
    /// Build a runner seeded with `expr` (the lowered workload).
    pub fn new(expr: RecExpr, rules: Vec<Rewrite>) -> Self {
        let mut egraph = EGraph::new();
        let root = egraph.add_expr(&expr);
        let n = rules.len();
        Runner {
            egraph,
            root,
            rules,
            limits: RunnerLimits::default(),
            scheduler: None,
            search_workers: default_workers(),
            apply_workers: default_workers(),
            search_mode: SearchMode::default(),
            stats: Vec::new(),
            applied_memo: FxHashSet::default(),
            rule_backlog: vec![Vec::new(); n],
        }
    }

    /// Build a runner over an **existing** e-graph (a snapshot-restored
    /// enumeration being extended with more rules). Restored graphs carry
    /// no dirty backlog for the incremental matcher — their dirty set was
    /// drained by the writing process — so the search mode defaults to
    /// [`SearchMode::FullRescan`]; with the default incremental mode the
    /// first iteration would find nothing and report spurious saturation.
    pub fn from_egraph(egraph: EGraph, root: Id, rules: Vec<Rewrite>) -> Self {
        let n = rules.len();
        Runner {
            egraph,
            root,
            rules,
            limits: RunnerLimits::default(),
            scheduler: None,
            search_workers: default_workers(),
            apply_workers: default_workers(),
            search_mode: SearchMode::FullRescan,
            stats: Vec::new(),
            applied_memo: FxHashSet::default(),
            rule_backlog: vec![Vec::new(); n],
        }
    }

    pub fn with_limits(mut self, limits: RunnerLimits) -> Self {
        self.limits = limits;
        self
    }

    pub fn with_scheduler(mut self, scheduler: Box<dyn Scheduler>) -> Self {
        self.scheduler = Some(scheduler);
        self
    }

    pub fn with_search_workers(mut self, workers: usize) -> Self {
        self.search_workers = workers.max(1);
        self
    }

    pub fn with_apply_workers(mut self, workers: usize) -> Self {
        self.apply_workers = workers.max(1);
        self
    }

    pub fn with_search_mode(mut self, mode: SearchMode) -> Self {
        self.search_mode = mode;
        self
    }

    /// Run up to `iters` iterations (further bounded by `self.limits`).
    pub fn run(&mut self, iters: usize) -> RunnerReport {
        let start = Instant::now();
        let mut stop = StopReason::IterLimit;
        let iters = iters.min(self.limits.max_iters);
        // Take the scheduler out of `self` for the duration of the run so
        // its `&mut` calls don't alias the rule/e-graph borrows.
        let mut scheduler: Box<dyn Scheduler> = self.scheduler.take().unwrap_or_else(|| {
            Box::new(SimpleScheduler::new(self.limits.max_matches_per_rule))
        });
        let base = self.stats.len();
        for i in 0..iters {
            let iteration = base + i;
            let outcome = self.run_one(iteration, scheduler.as_mut());
            let designs = if self.limits.track_designs {
                count::designs(&self.egraph, self.root, 64)
            } else {
                f64::NAN
            };
            self.stats.push(IterationStats {
                iteration,
                nodes: self.egraph.total_nodes(),
                classes: self.egraph.num_classes(),
                applied: outcome.applied,
                unions_total: self.egraph.n_unions,
                designs_lower_bound: designs,
                elapsed: start.elapsed(),
                searched_classes: outcome.searched_classes,
                search_time: outcome.search_time,
                apply_time: outcome.apply_time,
                rebuild_time: outcome.rebuild_time,
                apply_waves: outcome.apply_waves,
                per_rule: outcome.per_rule,
            });
            // Saturation: nothing changed AND no rule was sitting out a ban
            // (a banned rule's pending work may still produce new facts
            // once its window expires).
            if outcome.applied == 0 && !outcome.any_banned {
                stop = StopReason::Saturated;
                break;
            }
            if self.egraph.total_nodes() >= self.limits.max_nodes {
                stop = StopReason::NodeLimit;
                break;
            }
            if start.elapsed() >= self.limits.max_time {
                stop = StopReason::TimeLimit;
                break;
            }
        }
        self.scheduler = Some(scheduler);
        RunnerReport {
            stop,
            iterations: self.stats.clone(),
            nodes: self.egraph.total_nodes(),
            classes: self.egraph.num_classes(),
            designs_lower_bound: count::designs(&self.egraph, self.root, 64),
            elapsed: start.elapsed(),
            rule_names: self.rules.iter().map(|r| r.name.clone()).collect(),
        }
    }

    /// One search → apply → rebuild round.
    fn run_one(&mut self, iteration: usize, scheduler: &mut dyn Scheduler) -> IterOutcome {
        let search_t0 = Instant::now();
        let nrules = self.rules.len();
        if self.rule_backlog.len() != nrules {
            self.rule_backlog = vec![Vec::new(); nrules];
        }
        let mut per_rule = vec![RuleIterStats::default(); nrules];
        let mut any_banned = false;

        // ---- Phase 0: per-rule class work lists ------------------------
        let dirty = self.egraph.take_dirty();
        // Where a rule's class work list lives: banned rules have none,
        // rules with an empty backlog share the per-level expansion cache
        // (no clone per rule), rules with a banked backlog own a merged
        // list.
        enum WorkSource {
            Banned,
            Cached(usize),
            Owned(Vec<Id>),
        }
        // Expansion cache by ancestor level; shared by every rule with an
        // empty backlog (the common case — backlogs only build up under
        // bans).
        let mut by_level: Vec<Option<Vec<Id>>> = Vec::new();
        // Full-rescan runs share the one whole-graph list via level 0.
        let mut work: Vec<WorkSource> = Vec::with_capacity(nrules);
        for ri in 0..nrules {
            if !scheduler.can_search(iteration, ri, &self.rules[ri]) {
                self.rule_backlog[ri].extend_from_slice(&dirty);
                per_rule[ri].banned = true;
                any_banned = true;
                work.push(WorkSource::Banned);
                continue;
            }
            let source = match self.search_mode {
                SearchMode::FullRescan => {
                    self.rule_backlog[ri].clear();
                    if by_level.is_empty() {
                        by_level.push(Some(self.egraph.class_ids()));
                    }
                    WorkSource::Cached(0)
                }
                SearchMode::Incremental => {
                    let levels = self.rules[ri].ancestor_levels();
                    if self.rule_backlog[ri].is_empty() {
                        if by_level.len() <= levels {
                            by_level.resize(levels + 1, None);
                        }
                        if by_level[levels].is_none() {
                            by_level[levels] =
                                Some(self.egraph.with_ancestors(&dirty, levels));
                        }
                        WorkSource::Cached(levels)
                    } else {
                        let mut seeds = std::mem::take(&mut self.rule_backlog[ri]);
                        seeds.extend_from_slice(&dirty);
                        WorkSource::Owned(self.egraph.with_ancestors(&seeds, levels))
                    }
                }
            };
            work.push(source);
        }
        // Per-rule borrowed views into the cache / owned lists.
        let lists: Vec<Option<&[Id]>> = work
            .iter()
            .map(|w| match w {
                WorkSource::Banned => None,
                WorkSource::Cached(level) => Some(by_level[*level].as_deref().expect("cached")),
                WorkSource::Owned(v) => Some(v.as_slice()),
            })
            .collect();
        let searched_classes = lists.iter().flatten().map(|w| w.len()).max().unwrap_or(0);

        // ---- Phase 1: parallel search over the frozen e-graph ----------
        // Shard each rule's class list; item order (rule-major, then chunk
        // order) plus `parallel_map`'s order preservation make the merged
        // match stream deterministic for any worker count.
        let eg = &self.egraph;
        let rules = &self.rules;
        let chunk = searched_classes.div_ceil(self.search_workers.max(1) * 4).max(64);
        let mut items: Vec<(usize, &[Id])> = Vec::new();
        for (ri, w) in lists.iter().enumerate() {
            if let Some(classes) = w {
                for c in classes.chunks(chunk) {
                    items.push((ri, c));
                }
            }
        }
        let shard_results: Vec<Vec<(Id, Subst)>> =
            parallel_map(self.search_workers, items, |&(ri, classes)| {
                rules[ri].search_classes(eg, classes)
            });
        // Re-group shards by rule, in order.
        let mut found: Vec<Vec<(Id, Subst)>> = vec![Vec::new(); nrules];
        let mut shard_iter = shard_results.into_iter();
        for (ri, w) in lists.iter().enumerate() {
            if let Some(classes) = w {
                for _ in 0..classes.chunks(chunk).len() {
                    found[ri].extend(shard_iter.next().expect("shard per chunk"));
                }
            }
        }

        // ---- Scheduler filtering (single-threaded) ---------------------
        // Already-fired matches (memo hits — replays the search re-found)
        // are dropped BEFORE scheduler accounting, so caps and backoff
        // thresholds see only genuinely pending work. This is what makes a
        // cap an actual throttle rather than a starvation trap: every
        // admitted prefix is new work, so a capped rule still progresses
        // through its backlog and the run can saturate. Keys are computed
        // against the still-frozen searched graph — the memo stores
        // search-time-canonical keys — so the hits are exact.
        let mut all: Vec<(usize, Id, Subst, MatchKey)> = Vec::new();
        for (ri, matches) in found.into_iter().enumerate() {
            let Some(classes) = lists[ri] else { continue };
            per_rule[ri].matches = matches.len();
            let pending: Vec<(Id, Subst)> = matches
                .into_iter()
                .filter(|(id, s)| {
                    !self.applied_memo.contains(&MatchKey::of(&self.egraph, ri, *id, s))
                })
                .collect();
            let before = pending.len();
            let filtered = scheduler.filter_matches(iteration, ri, &self.rules[ri], pending);
            if filtered.len() < before {
                // The scheduler dropped pending matches (overflow ban or
                // cap truncation). Bank the rule's whole work list so they
                // are re-offered once the scheduler readmits them —
                // scheduling must delay matches, never lose them. Counting
                // this as a ban also stops `applied == 0` from reading as
                // saturation while work is still pending.
                per_rule[ri].banned = true;
                any_banned = true;
                self.rule_backlog[ri].extend_from_slice(classes);
            }
            for (id, s) in filtered {
                let key = MatchKey::of(&self.egraph, ri, id, &s);
                all.push((ri, id, s, key));
            }
        }
        let search_time = search_t0.elapsed();

        // ---- Phase 2: apply (staged in parallel waves, committed in
        // deterministic stream order) ------------------------------------
        // Walk the match stream in order, claiming each match's footprint
        // (root + binding classes, canonical under the *current*
        // union-find). When a match touches an already-claimed class, cut
        // a wave: stage the wave's appliers in parallel against the frozen
        // graph, then commit their intents sequentially in stream order.
        // Wave boundaries depend only on the deterministic stream, and the
        // commit replay is single-threaded — so the e-graph that results
        // is bit-identical for any `apply_workers`.
        let apply_t0 = Instant::now();
        let mut changed = 0;
        let mut apply_waves = 0;
        let mut pos = 0;
        'waves: while pos < all.len() {
            let mut claimed: FxHashSet<Id> = FxHashSet::default();
            let mut end = pos;
            while end < all.len() {
                let fp = footprint(&self.egraph, all[end].1, &all[end].2);
                if end > pos && fp.iter().any(|f| claimed.contains(f)) {
                    break;
                }
                claimed.extend(fp);
                end += 1;
            }
            apply_waves += 1;

            // Stage the wave against the frozen graph (read-only: safe to
            // fan out). The per-match tag (iteration + stream index) seeds
            // deterministic fresh symbols.
            let eg = &self.egraph;
            let rules = &self.rules;
            let intents: Vec<Option<ApplyIntent>> =
                parallel_map(self.apply_workers, (pos..end).collect(), |&i| {
                    let (ri, id, subst, _) = &all[i];
                    rules[*ri].stage(eg, *id, subst, format!("{iteration}_{i}"))
                });

            // Commit sequentially, in stream order.
            for (i, intent) in (pos..end).zip(intents) {
                let Some(intent) = intent else {
                    continue; // declined: retry whenever re-offered
                };
                let (ri, id, _, key) = &all[i];
                // Re-check: a duplicate match earlier in this very stream
                // may have fired and inserted the same key.
                if self.applied_memo.contains(key) {
                    continue;
                }
                let rhs = intent.commit(&mut self.egraph);
                let (_, did_change) = self.egraph.union(*id, rhs);
                self.applied_memo.insert(key.clone());
                if did_change {
                    changed += 1;
                    per_rule[*ri].applied += 1;
                }
                if self.egraph.approx_nodes() >= self.limits.max_nodes * 2 {
                    break 'waves; // hard brake if a rule explodes
                }
            }
            pos = end;
        }
        let apply_time = apply_t0.elapsed();

        // ---- Phase 3: restore congruence -------------------------------
        let rebuild_t0 = Instant::now();
        self.egraph.rebuild();
        // Canonical ids moved for the classes that lost this iteration's
        // unions: re-canonicalize just the memo keys that mention one of
        // them (the untouched majority stays put), so replays keep hitting
        // the memo against the graph the next search phase will freeze.
        let merged = self.egraph.take_merged_roots();
        if !merged.is_empty() && !self.applied_memo.is_empty() {
            let merged: FxHashSet<Id> = merged.into_iter().collect();
            let is_stale = |k: &MatchKey| {
                merged.contains(&k.root)
                    || k.node
                        .as_ref()
                        .is_some_and(|n| n.children.iter().any(|c| merged.contains(c)))
                    || k.vars.iter().any(|(_, id)| merged.contains(id))
            };
            let stale: Vec<MatchKey> =
                self.applied_memo.iter().filter(|k| is_stale(k)).cloned().collect();
            let eg = &self.egraph;
            for k in stale {
                self.applied_memo.remove(&k);
                self.applied_memo.insert(k.canonicalize(eg));
            }
        }
        let rebuild_time = rebuild_t0.elapsed();
        IterOutcome {
            applied: changed,
            searched_classes,
            per_rule,
            any_banned,
            search_time,
            apply_time,
            rebuild_time,
            apply_waves,
        }
    }
}

/// The classes one match reads or merges: its root plus every class its
/// substitution binds (pattern variables and the matched node's children),
/// canonicalized under the current union-find. Two matches with disjoint
/// footprints can be staged in the same parallel wave without either
/// observing state the other is about to commit.
fn footprint(eg: &EGraph, root: Id, subst: &Subst) -> Vec<Id> {
    let mut fp = Vec::with_capacity(1 + subst.vars.len());
    fp.push(eg.find_ref(root));
    for &id in subst.vars.values() {
        fp.push(eg.find_ref(id));
    }
    if let Some(n) = &subst.node {
        for &c in &n.children {
            fp.push(eg.find_ref(c));
        }
    }
    fp.sort_unstable();
    fp.dedup();
    fp
}

struct IterOutcome {
    applied: usize,
    searched_classes: usize,
    per_rule: Vec<RuleIterStats>,
    any_banned: bool,
    search_time: Duration,
    apply_time: Duration,
    rebuild_time: Duration,
    apply_waves: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::rewrite::Rewrite;
    use crate::egraph::scheduler::BackoffScheduler;
    use crate::ir::{parse_expr, Node, Op, OpKind};

    fn commute() -> Rewrite {
        Rewrite::node_scan("commute-eadd", OpKind::EAdd, |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            Some(eg.add(Node::new(Op::EAdd, vec![n.children[1], n.children[0]])))
        })
    }

    #[test]
    fn saturates_on_commutativity() {
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut r = Runner::new(e, vec![commute()]);
        let rep = r.run(10);
        assert_eq!(rep.stop, StopReason::Saturated);
        // a+b and b+a: two designs.
        assert_eq!(rep.designs_lower_bound, 2.0);
        assert!(rep.iterations.len() <= 3);
    }

    #[test]
    fn node_limit_trips() {
        // A rule that keeps minting fresh integer leaves — never saturates.
        // (Nesting rules do NOT work for this: the e-graph folds infinite
        // regress into a cycle and saturates — see `count` tests.)
        let pump = Rewrite::node_scan("pump", OpKind::Int, |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            match n.op {
                Op::Int(v) => Some(eg.add(Node::leaf(Op::Int(v + 1)))),
                _ => None,
            }
        });
        let e = parse_expr("(slice 0 2 0 (input x [4]))").unwrap();
        let mut r = Runner::new(e, vec![pump]).with_limits(RunnerLimits {
            max_nodes: 50,
            max_iters: 1000,
            ..Default::default()
        });
        let rep = r.run(1000);
        assert_eq!(rep.stop, StopReason::NodeLimit);
    }

    #[test]
    fn nesting_rule_folds_into_cycle_and_saturates() {
        // relu(x) => relu(relu(x)): hashcons + union collapse the tower
        // into a self-referential class; the runner detects saturation and
        // the design count lower bound saturates upward.
        let pump = Rewrite::node_scan("nest", OpKind::Relu, |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let inner = eg.add(n.clone());
            Some(eg.add(Node::new(Op::Relu, vec![inner])))
        });
        let e = parse_expr("(relu (input x [4]))").unwrap();
        let mut r = Runner::new(e, vec![pump]);
        let rep = r.run(10);
        assert_eq!(rep.stop, StopReason::Saturated);
        assert!(rep.designs_lower_bound > 1.0);
    }

    #[test]
    fn report_table_renders() {
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut r = Runner::new(e, vec![commute()]);
        let rep = r.run(4);
        let t = rep.table();
        assert!(t.contains("e-nodes"));
        assert!(t.contains("Saturated"));
        let rt = rep.rule_table();
        assert!(rt.contains("commute-eadd"));
    }

    #[test]
    fn incremental_and_full_rescan_agree_on_toy_rules() {
        let run = |mode: SearchMode, workers: usize| {
            let e = parse_expr("(eadd (relu (input a [4])) (relu (input b [4])))").unwrap();
            let mut r = Runner::new(e, vec![commute()])
                .with_search_mode(mode)
                .with_search_workers(workers);
            let rep = r.run(10);
            (rep.stop.clone(), rep.nodes, rep.classes, rep.designs_lower_bound)
        };
        let reference = run(SearchMode::FullRescan, 1);
        for workers in [1, 4] {
            assert_eq!(run(SearchMode::Incremental, workers), reference);
        }
    }

    #[test]
    fn per_rule_stats_and_searched_classes_recorded() {
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut r = Runner::new(e, vec![commute()]);
        let rep = r.run(10);
        assert_eq!(rep.rule_names, vec!["commute-eadd".to_string()]);
        let it0 = &rep.iterations[0];
        assert_eq!(it0.per_rule.len(), 1);
        assert_eq!(it0.per_rule[0].matches, 1);
        assert_eq!(it0.per_rule[0].applied, 1);
        // Iteration 0 searches everything; later iterations only the dirty
        // neighborhood, which is no larger.
        assert_eq!(it0.searched_classes, 3);
        for it in &rep.iterations[1..] {
            assert!(it.searched_classes <= it0.searched_classes);
        }
    }

    #[test]
    fn track_designs_off_skips_per_iteration_counts() {
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut r = Runner::new(e, vec![commute()])
            .with_limits(RunnerLimits { track_designs: false, ..Default::default() });
        let rep = r.run(10);
        assert!(rep.iterations.iter().all(|it| it.designs_lower_bound.is_nan()));
        // The final count is still computed.
        assert_eq!(rep.designs_lower_bound, 2.0);
        // And the table renders the gap as '-'.
        assert!(rep.table().contains(" - "));
    }

    #[test]
    fn fired_applications_are_not_replayed() {
        // An applier that mints a fresh symbol per firing (like the split
        // rules): without the memo, every iteration re-applies the same
        // match and the e-graph grows α-variant junk forever.
        let fresh_wrap = Rewrite::node_scan("fresh-wrap", OpKind::InvokeRelu, |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let var = crate::ir::Symbol::fresh("t");
            let inner = eg.lookup(n).expect("matched node exists");
            Some(eg.add(Node::new(Op::SchedLoop { var, axis: 0, extent: 1 }, vec![inner])))
        });
        let e = parse_expr("(invoke-relu (relu-engine 8) (input x [8]))").unwrap();
        let mut r = Runner::new(e, vec![fresh_wrap]);
        let rep = r.run(6);
        // One firing wraps the invoke in a loop; the wrap node is then a new
        // member of the root class, matched... but only the *original*
        // invoke node ever fires (the memo blocks replays), so the graph
        // stops growing and the run saturates.
        assert_eq!(rep.stop, StopReason::Saturated);
        let eg = &r.egraph;
        let loops = eg
            .classes()
            .flat_map(|c| eg.class_nodes(c.id))
            .filter(|n| matches!(n.op, Op::SchedLoop { .. }))
            .count();
        assert_eq!(loops, 1, "memo must block α-variant replays");
    }

    #[test]
    fn backoff_scheduler_delays_but_does_not_lose_matches() {
        // Two commutable sites but a backoff budget of 1 match: the rule
        // overflows, gets banned, and must still deliver both rewrites
        // once readmitted (via the banked backlog).
        let e = parse_expr(
            "(eadd (eadd (input a [4]) (input b [4])) \
              (eadd (input c [4]) (input d [4])))",
        )
        .unwrap();
        let mut r = Runner::new(e, vec![commute()])
            .with_scheduler(Box::new(BackoffScheduler::new(1, 1)));
        let rep = r.run(30);
        assert_eq!(rep.stop, StopReason::Saturated);
        // All three eadd classes hold both operand orders: 2*2*2 designs at
        // the root... the root eadd's own swap doubles it once more.
        assert!(rep.designs_lower_bound >= 8.0, "got {}", rep.designs_lower_bound);
        assert!(
            rep.iterations.iter().any(|it| it.per_rule[0].banned),
            "budget of 1 must trigger a ban"
        );
    }
}
