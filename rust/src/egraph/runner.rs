//! The rewrite iteration engine: search all rules, apply all matches, union,
//! rebuild; repeat until saturation or a budget trips. Records per-iteration
//! growth statistics — the raw data for the paper's design-space-size
//! experiments (E1/E4 in DESIGN.md).

use super::count;
use super::graph::EGraph;
use super::rewrite::Rewrite;
use super::Id;
use crate::ir::RecExpr;
use std::time::{Duration, Instant};

/// Why a run stopped.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StopReason {
    /// No rule produced a new fact: the space is fully enumerated.
    Saturated,
    /// Hit the iteration budget.
    IterLimit,
    /// Hit the e-node budget.
    NodeLimit,
    /// Hit the wall-clock budget.
    TimeLimit,
}

/// Budgets for a run. Defaults are sized for interactive exploration.
#[derive(Debug, Clone)]
pub struct RunnerLimits {
    pub max_iters: usize,
    pub max_nodes: usize,
    pub max_time: Duration,
    /// Per-rule, per-iteration match cap: a crude fairness throttle so one
    /// explosive rule cannot starve the rest within an iteration.
    pub max_matches_per_rule: usize,
}

impl Default for RunnerLimits {
    fn default() -> Self {
        RunnerLimits {
            max_iters: 16,
            max_nodes: 200_000,
            max_time: Duration::from_secs(30),
            max_matches_per_rule: 50_000,
        }
    }
}

/// Growth metrics after one iteration.
#[derive(Debug, Clone)]
pub struct IterationStats {
    pub iteration: usize,
    pub nodes: usize,
    pub classes: usize,
    pub applied: usize,
    pub unions_total: usize,
    /// Lower bound on the number of distinct designs rooted at the
    /// workload (see [`super::count`]).
    pub designs_lower_bound: f64,
    pub elapsed: Duration,
}

/// Summary of a completed run.
#[derive(Debug, Clone)]
pub struct RunnerReport {
    pub stop: StopReason,
    pub iterations: Vec<IterationStats>,
    pub nodes: usize,
    pub classes: usize,
    pub designs_lower_bound: f64,
    pub elapsed: Duration,
}

impl RunnerReport {
    /// Render as an aligned text table (used by examples and benches).
    pub fn table(&self) -> String {
        let mut s = String::from(
            "iter    e-nodes  e-classes    applied     designs(lb)   elapsed\n",
        );
        for it in &self.iterations {
            s.push_str(&format!(
                "{:<4} {:>10} {:>10} {:>10} {:>15.4e} {:>9.1?}\n",
                it.iteration, it.nodes, it.classes, it.applied, it.designs_lower_bound,
                it.elapsed,
            ));
        }
        s.push_str(&format!("stop: {:?}\n", self.stop));
        s
    }
}

/// Drives rewrites over an [`EGraph`] holding one workload.
pub struct Runner {
    pub egraph: EGraph,
    pub root: Id,
    pub rules: Vec<Rewrite>,
    pub limits: RunnerLimits,
    pub stats: Vec<IterationStats>,
}

impl Runner {
    /// Build a runner seeded with `expr` (the lowered workload).
    pub fn new(expr: RecExpr, rules: Vec<Rewrite>) -> Self {
        let mut egraph = EGraph::new();
        let root = egraph.add_expr(&expr);
        Runner { egraph, root, rules, limits: RunnerLimits::default(), stats: Vec::new() }
    }

    pub fn with_limits(mut self, limits: RunnerLimits) -> Self {
        self.limits = limits;
        self
    }

    /// Run up to `iters` iterations (further bounded by `self.limits`).
    pub fn run(&mut self, iters: usize) -> RunnerReport {
        let start = Instant::now();
        let mut stop = StopReason::IterLimit;
        let iters = iters.min(self.limits.max_iters);
        for i in 0..iters {
            let applied = self.run_one();
            let designs = count::designs(&self.egraph, self.root, 64);
            self.stats.push(IterationStats {
                iteration: i,
                nodes: self.egraph.total_nodes(),
                classes: self.egraph.num_classes(),
                applied,
                unions_total: self.egraph.n_unions,
                designs_lower_bound: designs,
                elapsed: start.elapsed(),
            });
            if applied == 0 {
                stop = StopReason::Saturated;
                break;
            }
            if self.egraph.total_nodes() >= self.limits.max_nodes {
                stop = StopReason::NodeLimit;
                break;
            }
            if start.elapsed() >= self.limits.max_time {
                stop = StopReason::TimeLimit;
                break;
            }
        }
        RunnerReport {
            stop,
            iterations: self.stats.clone(),
            nodes: self.egraph.total_nodes(),
            classes: self.egraph.num_classes(),
            designs_lower_bound: count::designs(&self.egraph, self.root, 64),
            elapsed: start.elapsed(),
        }
    }

    /// One search-then-apply round; returns how many applications changed
    /// the e-graph.
    fn run_one(&mut self) -> usize {
        // Phase 1: search everything against the frozen e-graph.
        let mut all: Vec<(usize, Id, super::pattern::Subst)> = Vec::new();
        for (ri, rule) in self.rules.iter().enumerate() {
            let mut matches = rule.search(&self.egraph);
            if matches.len() > self.limits.max_matches_per_rule {
                matches.truncate(self.limits.max_matches_per_rule);
            }
            for (id, s) in matches {
                all.push((ri, id, s));
            }
        }
        // Phase 2: apply (mutates; matched ids may need re-canonicalizing,
        // which `EGraph::union` does internally via find).
        let mut changed = 0;
        let rules = self.rules.clone();
        for (ri, id, subst) in all {
            if rules[ri].apply(&mut self.egraph, id, &subst) {
                changed += 1;
            }
            if self.egraph.approx_nodes() >= self.limits.max_nodes * 2 {
                break; // hard brake mid-iteration if a rule explodes
            }
        }
        // Phase 3: restore congruence.
        self.egraph.rebuild();
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::rewrite::Rewrite;
    use crate::ir::{parse_expr, Node, Op, OpKind};

    fn commute() -> Rewrite {
        Rewrite::node_scan("commute-eadd", OpKind::EAdd, |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            Some(eg.add(Node::new(Op::EAdd, vec![n.children[1], n.children[0]])))
        })
    }

    #[test]
    fn saturates_on_commutativity() {
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut r = Runner::new(e, vec![commute()]);
        let rep = r.run(10);
        assert_eq!(rep.stop, StopReason::Saturated);
        // a+b and b+a: two designs.
        assert_eq!(rep.designs_lower_bound, 2.0);
        assert!(rep.iterations.len() <= 3);
    }

    #[test]
    fn node_limit_trips() {
        // A rule that keeps minting fresh integer leaves — never saturates.
        // (Nesting rules do NOT work for this: the e-graph folds infinite
        // regress into a cycle and saturates — see `count` tests.)
        let pump = Rewrite::node_scan("pump", OpKind::Int, |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            match n.op {
                Op::Int(v) => Some(eg.add(Node::leaf(Op::Int(v + 1)))),
                _ => None,
            }
        });
        let e = parse_expr("(slice 0 2 0 (input x [4]))").unwrap();
        let mut r = Runner::new(e, vec![pump]).with_limits(RunnerLimits {
            max_nodes: 50,
            max_iters: 1000,
            ..Default::default()
        });
        let rep = r.run(1000);
        assert_eq!(rep.stop, StopReason::NodeLimit);
    }

    #[test]
    fn nesting_rule_folds_into_cycle_and_saturates() {
        // relu(x) => relu(relu(x)): hashcons + union collapse the tower
        // into a self-referential class; the runner detects saturation and
        // the design count lower bound saturates upward.
        let pump = Rewrite::node_scan("nest", OpKind::Relu, |eg, _, s| {
            let n = s.node.as_ref().unwrap();
            let inner = eg.add(n.clone());
            Some(eg.add(Node::new(Op::Relu, vec![inner])))
        });
        let e = parse_expr("(relu (input x [4]))").unwrap();
        let mut r = Runner::new(e, vec![pump]);
        let rep = r.run(10);
        assert_eq!(rep.stop, StopReason::Saturated);
        assert!(rep.designs_lower_bound > 1.0);
    }

    #[test]
    fn report_table_renders() {
        let e = parse_expr("(eadd (input a [4]) (input b [4]))").unwrap();
        let mut r = Runner::new(e, vec![commute()]);
        let rep = r.run(4);
        let t = rep.table();
        assert!(t.contains("e-nodes"));
        assert!(t.contains("Saturated"));
    }
}
