//! Arena interning for e-nodes: every inserted [`Node`] body lives exactly
//! once in a `Vec`-backed arena and is referenced everywhere else — class
//! membership lists, parent back-edges, the hashcons — by a `u32` [`NodeId`].
//!
//! The hashcons itself is a [`NodeTable`]: an open-addressing FxHash table
//! mapping *node content* to `(NodeId, class Id)` without owning a second
//! copy of any node. Lookups probe by hash and compare content through the
//! arena (the raw-entry pattern), so the table stores 20 bytes per entry
//! where the old `HashMap<Node, Id>` stored a full cloned `Node` per key.
//! `rebuild()` exploits the same indirection to re-canonicalize parent
//! nodes *in place* in the arena — a re-key is two table probes, zero node
//! clones.

use super::Id;
use crate::fx::FxHasher;
use crate::ir::Node;
use std::hash::{Hash, Hasher};

/// Index of an interned e-node body in the [`EGraph`](super::EGraph) arena.
#[derive(Copy, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(u32);

impl NodeId {
    #[inline]
    pub fn from_index(i: usize) -> Self {
        NodeId(u32::try_from(i).expect("e-graph overflow: more than u32::MAX e-nodes"))
    }

    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Debug for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// FxHash of a node's content (op + children), the probe key for
/// [`NodeTable`]. Callers hash once and thread the value through
/// `get`/`insert`/`remove` so a re-key costs no re-hash.
#[inline]
pub(crate) fn node_hash(node: &Node) -> u64 {
    let mut h = FxHasher::default();
    node.hash(&mut h);
    h.finish()
}

/// One slot of the open-addressing table.
#[derive(Copy, Clone)]
enum Slot {
    Empty,
    /// A deleted entry; probes continue past it, inserts may reuse it.
    Tomb,
    Full { hash: u64, nid: NodeId, class: Id },
}

/// The hashcons: node content → `(NodeId, class)`, content-compared through
/// the arena. Linear probing, power-of-two capacity, tombstone deletion
/// (cleared on growth). Replace-by-content `insert` preserves the old
/// `HashMap<Node, Id>` semantics: at most one entry per distinct content.
pub(crate) struct NodeTable {
    slots: Vec<Slot>,
    /// Live entries (what [`NodeTable::len`] reports).
    live: usize,
    /// Live + tombstones — the probe-length load factor.
    used: usize,
}

impl Default for NodeTable {
    fn default() -> Self {
        NodeTable { slots: vec![Slot::Empty; 16], live: 0, used: 0 }
    }
}

impl NodeTable {
    pub fn with_capacity(n: usize) -> Self {
        let cap = (n * 2).next_power_of_two().max(16);
        NodeTable { slots: vec![Slot::Empty; cap], live: 0, used: 0 }
    }

    pub fn len(&self) -> usize {
        self.live
    }

    #[inline]
    fn mask(&self) -> usize {
        self.slots.len() - 1
    }

    /// The class of the entry whose content equals `node`, if present.
    pub fn get(&self, hash: u64, node: &Node, arena: &[Node]) -> Option<Id> {
        let mask = self.mask();
        let mut i = hash as usize & mask;
        loop {
            match self.slots[i] {
                Slot::Empty => return None,
                Slot::Tomb => {}
                Slot::Full { hash: h, nid, class } => {
                    if h == hash && &arena[nid.index()] == node {
                        return Some(class);
                    }
                }
            }
            i = (i + 1) & mask;
        }
    }

    /// Insert `arena[nid] → class`, replacing any existing entry of equal
    /// content (the existing entry keeps its own `NodeId`; content equality
    /// makes the difference unobservable to lookups).
    pub fn insert(&mut self, hash: u64, nid: NodeId, class: Id, arena: &[Node]) {
        if self.used * 8 >= self.slots.len() * 7 {
            self.grow(arena);
        }
        let node = &arena[nid.index()];
        let mask = self.mask();
        let mut i = hash as usize & mask;
        let mut first_tomb: Option<usize> = None;
        loop {
            match self.slots[i] {
                Slot::Empty => {
                    let dst = first_tomb.unwrap_or(i);
                    if first_tomb.is_none() {
                        self.used += 1;
                    }
                    self.slots[dst] = Slot::Full { hash, nid, class };
                    self.live += 1;
                    return;
                }
                Slot::Tomb => {
                    if first_tomb.is_none() {
                        first_tomb = Some(i);
                    }
                }
                Slot::Full { hash: h, nid: enid, class: _ } => {
                    if h == hash && &arena[enid.index()] == node {
                        self.slots[i] = Slot::Full { hash, nid: enid, class };
                        return;
                    }
                }
            }
            i = (i + 1) & mask;
        }
    }

    /// Remove the entry whose content equals `node`, returning its class.
    pub fn remove(&mut self, hash: u64, node: &Node, arena: &[Node]) -> Option<Id> {
        let mask = self.mask();
        let mut i = hash as usize & mask;
        loop {
            match self.slots[i] {
                Slot::Empty => return None,
                Slot::Tomb => {}
                Slot::Full { hash: h, nid, class } => {
                    if h == hash && &arena[nid.index()] == node {
                        self.slots[i] = Slot::Tomb;
                        self.live -= 1;
                        return Some(class);
                    }
                }
            }
            i = (i + 1) & mask;
        }
    }

    /// All live `(NodeId, class)` entries, in unspecified order (used by
    /// invariant checks only — never on a result-determining path).
    pub fn iter(&self) -> impl Iterator<Item = (NodeId, Id)> + '_ {
        self.slots.iter().filter_map(|s| match s {
            Slot::Full { nid, class, .. } => Some((*nid, *class)),
            _ => None,
        })
    }

    fn grow(&mut self, arena: &[Node]) {
        // Double when genuinely full; same-size rehash when tombstones are
        // the bulk of the load (deletion-heavy phases like rebuild).
        let cap = if self.live * 4 >= self.slots.len() {
            self.slots.len() * 2
        } else {
            self.slots.len()
        };
        let old = std::mem::replace(&mut self.slots, vec![Slot::Empty; cap]);
        self.live = 0;
        self.used = 0;
        for s in old {
            if let Slot::Full { hash, nid, class } = s {
                self.insert(hash, nid, class, arena);
            }
        }
    }
}

impl std::fmt::Debug for NodeTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "NodeTable({} live / {} slots)", self.live, self.slots.len())
    }
}

impl Clone for NodeTable {
    fn clone(&self) -> Self {
        NodeTable { slots: self.slots.clone(), live: self.live, used: self.used }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Op, Shape, Symbol};

    fn nodes() -> Vec<Node> {
        (0..100)
            .map(|i| Node::leaf(Op::Input(Symbol::new(&format!("x{i}")), Shape::new(&[4]))))
            .collect()
    }

    #[test]
    fn insert_get_remove_roundtrip() {
        let arena = nodes();
        let mut t = NodeTable::default();
        for (i, n) in arena.iter().enumerate() {
            t.insert(node_hash(n), NodeId::from_index(i), Id::from_index(i), &arena);
        }
        assert_eq!(t.len(), arena.len());
        for (i, n) in arena.iter().enumerate() {
            assert_eq!(t.get(node_hash(n), n, &arena), Some(Id::from_index(i)));
        }
        let victim = &arena[7];
        assert_eq!(t.remove(node_hash(victim), victim, &arena), Some(Id::from_index(7)));
        assert_eq!(t.get(node_hash(victim), victim, &arena), None);
        assert_eq!(t.len(), arena.len() - 1);
        // The probe chain past the tombstone still reaches later entries.
        for (i, n) in arena.iter().enumerate().filter(|(i, _)| *i != 7) {
            assert_eq!(t.get(node_hash(n), n, &arena), Some(Id::from_index(i)));
        }
    }

    #[test]
    fn insert_replaces_by_content() {
        // Two arena slots with identical content: the table keeps one entry.
        let n = Node::leaf(Op::Int(42));
        let arena = vec![n.clone(), n.clone()];
        let mut t = NodeTable::default();
        let h = node_hash(&n);
        t.insert(h, NodeId::from_index(0), Id::from_index(3), &arena);
        t.insert(h, NodeId::from_index(1), Id::from_index(9), &arena);
        assert_eq!(t.len(), 1);
        assert_eq!(t.get(h, &n, &arena), Some(Id::from_index(9)));
    }

    #[test]
    fn survives_growth_and_tombstone_churn() {
        let arena = nodes();
        let mut t = NodeTable::default();
        // Repeated insert/remove cycles force tombstone accumulation and
        // same-size rehashes.
        for round in 0..5 {
            for (i, n) in arena.iter().enumerate() {
                t.insert(node_hash(n), NodeId::from_index(i), Id::from_index(i), &arena);
            }
            for (i, n) in arena.iter().enumerate() {
                if i % 2 == round % 2 {
                    assert!(t.remove(node_hash(n), n, &arena).is_some());
                }
            }
        }
        assert_eq!(t.len(), 50);
        assert_eq!(t.iter().count(), 50);
    }
}
