//! A from-scratch e-graph (equality graph) implementation.
//!
//! E-graphs — the data structure the paper adopts from the program
//! verification literature (Nelson 1980) — compactly represent an
//! exponential number of equivalent programs: nodes whose operator and
//! (canonical) children are equal are hash-consed into one *e-node*, and
//! equivalent e-nodes are grouped into *e-classes* by a union-find.
//!
//! The implementation follows the modern "rebuild-deferred" discipline
//! (congruence closure restored in batches after a round of unions), with:
//!
//! * [`unionfind`] — path-halving union-find over [`Id`]s;
//! * [`graph`] — the [`EGraph`] itself: hashcons, e-classes, deferred
//!   congruence closure, a shape/type *analysis* attached to every e-class
//!   (broken rewrites are caught as analysis merge conflicts), live
//!   class/node counters, and dirty-set tracking (which classes gained
//!   nodes since the last search — the incremental engine's work list);
//! * [`intern`] — arena interning: node bodies stored once, referenced by
//!   `u32` [`NodeId`] from classes, parent edges and the open-addressing
//!   hashcons (content compared through the arena, never cloned);
//! * [`pattern`] — pattern ASTs with variables and op-kind matchers;
//! * [`matcher`] — backtracking e-matching over the e-graph, whole-graph or
//!   restricted to a class work list (`&self`-only, so search shards share
//!   the frozen graph across worker threads);
//! * [`rewrite`] — rewrite = searcher pattern + (possibly dynamic) applier,
//!   plus each rule's declared *ancestor reach* for incremental matching;
//! * [`scheduler`] — pluggable per-iteration rule fairness: the historical
//!   truncation ([`SimpleScheduler`]) or egg-style exponential backoff
//!   ([`BackoffScheduler`]);
//! * [`runner`] — the phased saturation engine: incremental parallel
//!   search → memoized parallel apply (conflict-free waves staged against
//!   the frozen graph on the worker pool, committed single-threaded in
//!   deterministic match order) → rebuild, with node/time budgets,
//!   saturation detection, and per-iteration + per-rule growth metrics
//!   (the data behind the paper's "exponential design space" claim);
//! * [`count`] — counting the number of distinct terms an e-graph
//!   represents (the size of the enumerated design space).

pub mod count;
pub mod graph;
pub mod intern;
pub mod matcher;
pub mod pattern;
pub mod rewrite;
pub mod runner;
pub mod scheduler;
pub mod unionfind;

pub use graph::{EClass, EGraph};
pub use intern::NodeId;
pub use pattern::{Pattern, Subst};
pub use rewrite::{Applier, ApplyGraph, Rewrite};
pub use runner::{
    IterationStats, RuleIterStats, Runner, RunnerLimits, RunnerReport, SearchMode, StopReason,
};
pub use scheduler::{BackoffScheduler, Scheduler, SchedulerSpec, SimpleScheduler};
pub use unionfind::UnionFind;

/// An e-class id (also used as the node index inside a
/// [`crate::ir::RecExpr`]). A plain `u32` newtype: cheap to copy, hash and
/// store in the hashcons.
#[derive(Copy, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Id(u32);

impl Id {
    #[inline]
    pub fn from_index(i: usize) -> Self {
        Id(u32::try_from(i).expect("e-graph overflow: more than u32::MAX classes"))
    }

    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Debug for Id {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "e{}", self.0)
    }
}

impl std::fmt::Display for Id {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "e{}", self.0)
    }
}
