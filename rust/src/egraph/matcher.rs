//! Backtracking e-matching: find all substitutions under which a
//! [`Pattern`] matches (some e-node in) an e-class.
//!
//! The search walks pattern and e-graph in lockstep: at each pattern node it
//! tries every e-node of the candidate class whose op satisfies the matcher,
//! forking the substitution per alternative. Complexity is bounded by the
//! product of class sizes along the pattern spine — fine for the small,
//! shallow patterns the rewrite library uses (≤3 levels).

use super::graph::EGraph;
use super::pattern::{OpMatch, Pattern, Subst};
use super::Id;

/// All substitutions under which `pat` matches class `id`.
pub fn match_class(eg: &EGraph, pat: &Pattern, id: Id) -> Vec<Subst> {
    let mut out = Vec::new();
    match_rec(eg, pat, id, Subst::default(), &mut out);
    out
}

fn match_rec(eg: &EGraph, pat: &Pattern, id: Id, subst: Subst, out: &mut Vec<Subst>) {
    let id = eg.find_ref(id);
    match pat {
        Pattern::Var(v) => {
            if let Some(&bound) = subst.vars.get(v) {
                // Non-linear pattern: the variable must rebind consistently.
                if eg.find_ref(bound) == id {
                    out.push(subst);
                }
            } else {
                let mut s = subst;
                s.vars.insert(*v, id);
                out.push(s);
            }
        }
        Pattern::Node { op, children } => {
            for node in eg.class_nodes(id) {
                if !op.matches(&node.op) || node.children.len() != children.len() {
                    continue;
                }
                let mut s = subst.clone();
                if let OpMatch::Kind(_, Some(binder)) = op {
                    s.ops.insert(*binder, node.op.clone());
                }
                // Match children sequentially, threading substitutions.
                let mut states = vec![s];
                for (cpat, &cid) in children.iter().zip(&node.children) {
                    let mut next = Vec::new();
                    for st in states {
                        match_rec(eg, cpat, cid, st, &mut next);
                    }
                    states = next;
                    if states.is_empty() {
                        break;
                    }
                }
                out.extend(states);
            }
        }
    }
}

/// Search the whole e-graph: all `(class, subst)` pairs where `pat` matches.
pub fn search(eg: &EGraph, pat: &Pattern) -> Vec<(Id, Subst)> {
    let mut out = Vec::new();
    for class in eg.classes() {
        for s in match_class(eg, pat, class.id) {
            out.push((class.id, s));
        }
    }
    out
}

/// Search only the given classes (ids must be live; non-canonical ids are
/// resolved). The incremental engine's entry point: `&self`-only, so the
/// frozen e-graph can be shared across search workers.
pub fn search_classes(eg: &EGraph, pat: &Pattern, ids: &[Id]) -> Vec<(Id, Subst)> {
    let mut out = Vec::new();
    for &id in ids {
        let id = eg.find_ref(id);
        for s in match_class(eg, pat, id) {
            out.push((id, s));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::pattern::{pexact, pkind, pvar};
    use crate::ir::{parse_expr, Op, OpKind};

    fn graph(src: &str) -> (EGraph, Id) {
        let e = parse_expr(src).unwrap();
        let mut eg = EGraph::new();
        let root = eg.add_expr(&e);
        (eg, root)
    }

    #[test]
    fn matches_exact_engine() {
        let (eg, root) = graph("(invoke-relu (relu-engine 128) (input x [128]))");
        let pat = pexact(Op::InvokeRelu, vec![pexact(Op::ReluEngine { w: 128 }, vec![]), pvar("?x")]);
        let m = match_class(&eg, &pat, root);
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn kind_matcher_binds_op() {
        let (eg, root) = graph("(invoke-relu (relu-engine 128) (input x [128]))");
        let pat = pexact(Op::InvokeRelu, vec![pkind(OpKind::ReluEngine, "e", vec![]), pvar("?x")]);
        let m = match_class(&eg, &pat, root);
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].op("e"), &Op::ReluEngine { w: 128 });
    }

    #[test]
    fn nonlinear_variable_requires_same_class() {
        // (eadd x x) matches (eadd a a) but not (eadd a b).
        let (eg, root) = graph("(eadd (input a [4]) (input a [4]))");
        let pat = pexact(Op::EAdd, vec![pvar("?x"), pvar("?x")]);
        assert_eq!(match_class(&eg, &pat, root).len(), 1);

        let (eg2, root2) = graph("(eadd (input a [4]) (input b [4]))");
        assert_eq!(match_class(&eg2, &pat, root2).len(), 0);
    }

    #[test]
    fn search_finds_all_sites() {
        let (eg, _) = graph("(eadd (relu (input a [4])) (relu (input b [4])))");
        let pat = pexact(Op::Relu, vec![pvar("?x")]);
        assert_eq!(search(&eg, &pat).len(), 2);
    }

    #[test]
    fn search_classes_restricts_to_given_roots() {
        let (eg, _) = graph("(eadd (relu (input a [4])) (relu (input b [4])))");
        let pat = pexact(Op::Relu, vec![pvar("?x")]);
        let all = search(&eg, &pat);
        assert_eq!(all.len(), 2);
        let one = search_classes(&eg, &pat, &[all[0].0]);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].0, all[0].0);
        let both: Vec<Id> = all.iter().map(|(id, _)| *id).collect();
        assert_eq!(search_classes(&eg, &pat, &both).len(), 2);
    }

    #[test]
    fn matches_through_unions() {
        // After x = relu(y) union, a pattern over relu sees both shapes.
        let (mut eg, _) = graph("(relu (input y [4]))");
        let x = {
            let e = parse_expr("(input x [4])").unwrap();
            eg.add_expr(&e)
        };
        let r = {
            let e = parse_expr("(relu (input y [4]))").unwrap();
            eg.add_expr(&e)
        };
        eg.union(x, r);
        eg.rebuild();
        // (relu (relu y)) should now be matchable starting from x's class
        // only if such a node exists — it does not; but (relu ?x) matches
        // the merged class itself once.
        let pat = pexact(Op::Relu, vec![pvar("?x")]);
        let hits = search(&eg, &pat);
        assert_eq!(hits.len(), 1);
    }
}
