//! Counting the number of distinct terms (designs) an e-graph represents.
//!
//! This is the quantity behind the paper's core claim — "the e-graph will
//! expand to include an exponential number of equivalent hardware–software
//! programs". For an acyclic e-graph the count is exact:
//!
//! ```text
//! |class| = Σ_{node ∈ class} Π_{child} |child|
//! ```
//!
//! computed to fixpoint. With cycles (introduced by inverse rewrite pairs,
//! e.g. split ⇄ merge) the true count is infinite; the fixpoint iteration is
//! cut off after `max_rounds`, yielding a **lower bound**, and saturating
//! `f64` arithmetic caps runaway values.

use super::graph::EGraph;
use super::Id;
use crate::fx::FxHashMap as HashMap;

/// Cap so products never overflow to `inf` (keeps comparisons meaningful).
const CAP: f64 = 1e300;

/// Number of distinct terms rooted at each class (lower bound; see module
/// docs). `max_rounds` bounds the fixpoint iteration — the default used by
/// the runner is 64, enough for every workload in the library.
pub fn class_counts(eg: &EGraph, max_rounds: usize) -> HashMap<Id, f64> {
    let mut counts: HashMap<Id, f64> = HashMap::default();
    for round in 0..max_rounds {
        let mut changed = false;
        for class in eg.classes() {
            let mut total = 0.0f64;
            for node in eg.class_nodes(class.id) {
                let mut prod = 1.0f64;
                for &c in &node.children {
                    let c = eg.find_ref(c);
                    prod *= counts.get(&c).copied().unwrap_or(0.0);
                    if prod >= CAP {
                        prod = CAP;
                        break;
                    }
                }
                total += prod;
                if total >= CAP {
                    total = CAP;
                    break;
                }
            }
            let entry = counts.entry(class.id).or_insert(0.0);
            if total > *entry {
                *entry = total;
                changed = true;
            }
        }
        if !changed {
            // Fixpoint: counts are exact (graph is acyclic w.r.t. nonzero
            // choices) — no need to keep iterating.
            let _ = round;
            break;
        }
    }
    counts
}

/// Count of distinct designs rooted at `root`.
pub fn designs(eg: &EGraph, root: Id, max_rounds: usize) -> f64 {
    let root = eg.find_ref(root);
    class_counts(eg, max_rounds).get(&root).copied().unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{parse_expr, Node, Op};

    #[test]
    fn single_term_counts_one() {
        let e = parse_expr("(invoke-relu (relu-engine 128) (input x [128]))").unwrap();
        let mut eg = EGraph::new();
        let root = eg.add_expr(&e);
        assert_eq!(designs(&eg, root, 64), 1.0);
    }

    #[test]
    fn union_doubles_choices() {
        let mut eg = EGraph::new();
        let a = eg.add_expr(&parse_expr("(relu (input x [4]))").unwrap());
        let b = eg.add_expr(&parse_expr("(invoke-relu (relu-engine 4) (input x [4]))").unwrap());
        eg.union(a, b);
        eg.rebuild();
        assert_eq!(designs(&eg, a, 64), 2.0);
    }

    #[test]
    fn products_multiply_across_children() {
        // eadd with two 2-choice children -> 4 designs... plus the root
        // class itself has 1 node, so 2*2 = 4.
        let mut eg = EGraph::new();
        let x1 = eg.add_expr(&parse_expr("(relu (input x [4]))").unwrap());
        let x2 =
            eg.add_expr(&parse_expr("(invoke-relu (relu-engine 4) (input x [4]))").unwrap());
        eg.union(x1, x2);
        let y1 = eg.add_expr(&parse_expr("(relu (input y [4]))").unwrap());
        let y2 =
            eg.add_expr(&parse_expr("(invoke-relu (relu-engine 4) (input y [4]))").unwrap());
        eg.union(y1, y2);
        eg.rebuild();
        let root = eg.add(Node::new(Op::EAdd, vec![x1, y1]));
        assert_eq!(designs(&eg, root, 64), 4.0);
    }

    #[test]
    fn cyclic_lower_bound_is_finite_and_large() {
        // Create a cycle: class A contains relu(A) after a (contrived)
        // union of x with relu(x) — type-preserving, semantically nonsense,
        // but structurally what inverse rewrite pairs produce.
        let mut eg = EGraph::new();
        let x = eg.add_expr(&parse_expr("(input x [4])").unwrap());
        let r = eg.add(Node::new(Op::Relu, vec![x]));
        eg.union(x, r);
        eg.rebuild();
        let d = designs(&eg, x, 64);
        assert!(d >= 64.0, "cycle should pump the lower bound, got {d}");
        assert!(d.is_finite());
    }
}
