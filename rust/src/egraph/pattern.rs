//! Patterns over EngineIR e-graphs.
//!
//! A pattern is a term with **pattern variables** (matching any e-class) and
//! **op matchers** that either require an exact op or any op of a given
//! [`OpKind`] (optionally binding the matched op so the applier can read its
//! parameters — engine sizes, schedule extents, …).

use super::Id;
use crate::ir::{Node, Op, OpKind, Symbol};
use std::collections::HashMap;

/// How a pattern node matches an e-node's operator.
#[derive(Clone, Debug)]
pub enum OpMatch {
    /// Exactly this op (including its scalar parameters).
    Exact(Op),
    /// Any op of this kind; if a binder is given, the concrete op is
    /// recorded in the substitution under that name.
    Kind(OpKind, Option<Symbol>),
}

impl OpMatch {
    pub fn matches(&self, op: &Op) -> bool {
        match self {
            OpMatch::Exact(want) => want == op,
            OpMatch::Kind(kind, _) => op.kind() == *kind,
        }
    }
}

/// A pattern AST.
#[derive(Clone, Debug)]
pub enum Pattern {
    /// Matches any e-class, binding it.
    Var(Symbol),
    /// Matches an e-node whose op satisfies the matcher and whose children
    /// match the sub-patterns.
    Node { op: OpMatch, children: Vec<Pattern> },
}

impl Pattern {
    /// Nesting depth: a bare variable is 0, a node is 1 + its deepest child.
    ///
    /// This bounds how far below a match root any pattern position sits,
    /// which is what the incremental engine needs: when an e-class changes,
    /// a *new* match of this pattern can only be rooted within `depth()`
    /// parent hops of it (see [`crate::egraph::graph::EGraph::with_ancestors`]).
    pub fn depth(&self) -> usize {
        match self {
            Pattern::Var(_) => 0,
            Pattern::Node { children, .. } => {
                1 + children.iter().map(Pattern::depth).max().unwrap_or(0)
            }
        }
    }
}

/// Build a pattern variable.
pub fn pvar(name: &str) -> Pattern {
    Pattern::Var(Symbol::new(name))
}

/// Build an exact-op pattern node.
pub fn pexact(op: Op, children: Vec<Pattern>) -> Pattern {
    Pattern::Node { op: OpMatch::Exact(op), children }
}

/// Build a kind pattern node binding the concrete op as `binder`.
pub fn pkind(kind: OpKind, binder: &str, children: Vec<Pattern>) -> Pattern {
    Pattern::Node { op: OpMatch::Kind(kind, Some(Symbol::new(binder))), children }
}

/// Build a kind pattern node without binding the op.
pub fn pkind_(kind: OpKind, children: Vec<Pattern>) -> Pattern {
    Pattern::Node { op: OpMatch::Kind(kind, None), children }
}

/// The result of a successful match: class bindings for pattern variables,
/// op bindings for kind matchers, and — for node-scan rewrites — the
/// concrete matched e-node.
#[derive(Clone, Debug, Default)]
pub struct Subst {
    pub vars: HashMap<Symbol, Id>,
    pub ops: HashMap<Symbol, Op>,
    /// The root e-node matched by a node-scan searcher.
    pub node: Option<Node>,
}

impl Subst {
    /// Class bound to pattern variable `name` (panics if unbound — rewrite
    /// authoring error).
    pub fn class(&self, name: &str) -> Id {
        self.vars[&Symbol::new(name)]
    }

    /// Op bound by kind matcher `name`.
    pub fn op(&self, name: &str) -> &Op {
        &self.ops[&Symbol::new(name)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opmatch_exact_and_kind() {
        let e = Op::ReluEngine { w: 64 };
        assert!(OpMatch::Exact(Op::ReluEngine { w: 64 }).matches(&e));
        assert!(!OpMatch::Exact(Op::ReluEngine { w: 32 }).matches(&e));
        assert!(OpMatch::Kind(OpKind::ReluEngine, None).matches(&e));
        assert!(!OpMatch::Kind(OpKind::AddEngine, None).matches(&e));
    }

    #[test]
    fn builders_build() {
        let p = pkind(OpKind::InvokeRelu, "inv", vec![pvar("?e"), pvar("?x")]);
        match p {
            Pattern::Node { children, .. } => assert_eq!(children.len(), 2),
            _ => panic!(),
        }
    }

    #[test]
    fn depth_counts_node_nesting() {
        assert_eq!(pvar("?x").depth(), 0);
        let flat = pkind_(OpKind::EAdd, vec![pvar("?a"), pvar("?b")]);
        assert_eq!(flat.depth(), 1);
        let nested = pkind_(
            OpKind::InvokeRelu,
            vec![pkind_(OpKind::ReluEngine, vec![]), pvar("?x")],
        );
        assert_eq!(nested.depth(), 2);
    }
}
