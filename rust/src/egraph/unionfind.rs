//! Union-find over [`Id`]s with path halving. The hot core of congruence
//! closure: `find` is called for every child of every canonicalized node on
//! every rebuild, so it is kept allocation-free and branch-light.

use super::Id;

/// Disjoint-set forest. `parents[i] == i` marks a root.
#[derive(Debug, Clone, Default)]
pub struct UnionFind {
    parents: Vec<u32>,
}

impl UnionFind {
    pub fn new() -> Self {
        UnionFind { parents: Vec::new() }
    }

    /// Add a fresh singleton set, returning its id.
    pub fn make_set(&mut self) -> Id {
        let id = self.parents.len() as u32;
        self.parents.push(id);
        Id::from_index(id as usize)
    }

    pub fn len(&self) -> usize {
        self.parents.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parents.is_empty()
    }

    /// Canonical representative of `id`'s set (with path halving).
    #[inline]
    pub fn find(&mut self, id: Id) -> Id {
        let mut cur = id.index() as u32;
        loop {
            let parent = self.parents[cur as usize];
            if parent == cur {
                return Id::from_index(cur as usize);
            }
            // Path halving: point at grandparent on the way up.
            let grand = self.parents[parent as usize];
            self.parents[cur as usize] = grand;
            cur = grand;
        }
    }

    /// Read-only find (no compression) for immutable contexts.
    #[inline]
    pub fn find_immutable(&self, id: Id) -> Id {
        let mut cur = id.index() as u32;
        loop {
            let parent = self.parents[cur as usize];
            if parent == cur {
                return Id::from_index(cur as usize);
            }
            cur = parent;
        }
    }

    /// Merge the sets of `a` and `b`; returns the surviving root.
    /// The *lower* id wins, keeping canonical ids stable over time (useful
    /// for deterministic extraction and for tests).
    pub fn union(&mut self, a: Id, b: Id) -> Id {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra == rb {
            return ra;
        }
        let (keep, merge) = if ra < rb { (ra, rb) } else { (rb, ra) };
        self.parents[merge.index()] = keep.index() as u32;
        keep
    }

    pub fn same(&mut self, a: Id, b: Id) -> bool {
        self.find(a) == self.find(b)
    }

    /// The raw parent array, for the snapshot codec. `parents[i] == i`
    /// marks a root; path compression state is incidental and carried
    /// verbatim.
    pub(crate) fn raw_parents(&self) -> &[u32] {
        &self.parents
    }

    /// Rebuild from a raw parent array (snapshot load). The caller is
    /// responsible for having validated that every entry indexes into the
    /// array.
    pub(crate) fn from_raw(parents: Vec<u32>) -> Self {
        UnionFind { parents }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singletons_are_their_own_roots() {
        let mut uf = UnionFind::new();
        let ids: Vec<Id> = (0..10).map(|_| uf.make_set()).collect();
        for &id in &ids {
            assert_eq!(uf.find(id), id);
        }
    }

    #[test]
    fn union_merges_and_lower_id_wins() {
        let mut uf = UnionFind::new();
        let a = uf.make_set();
        let b = uf.make_set();
        let c = uf.make_set();
        assert_eq!(uf.union(b, c), b);
        assert_eq!(uf.union(c, a), a);
        assert_eq!(uf.find(b), a);
        assert_eq!(uf.find(c), a);
        assert!(uf.same(a, c));
    }

    #[test]
    fn transitive_chains_compress() {
        let mut uf = UnionFind::new();
        let ids: Vec<Id> = (0..100).map(|_| uf.make_set()).collect();
        for w in ids.windows(2) {
            uf.union(w[0], w[1]);
        }
        for &id in &ids {
            assert_eq!(uf.find(id), ids[0]);
        }
        // After compression every element points (nearly) at the root.
        assert!(uf.parents.iter().filter(|&&p| p == 0).count() >= 50);
    }

    #[test]
    fn find_immutable_agrees_with_find() {
        let mut uf = UnionFind::new();
        let ids: Vec<Id> = (0..20).map(|_| uf.make_set()).collect();
        uf.union(ids[3], ids[7]);
        uf.union(ids[7], ids[11]);
        for &id in &ids {
            assert_eq!(uf.find_immutable(id), uf.find(id));
        }
    }
}
