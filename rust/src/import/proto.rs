//! A zero-dependency reader for the subset of the ONNX protobuf schema the
//! importer needs.
//!
//! ONNX models are protobuf messages (`onnx.proto`), but depending on
//! `protoc`/`prost` for five message types would violate the crate's
//! zero-dependency rule — so this module hand-decodes the wire format:
//! varints, the four wire types (varint / fixed64 / length-delimited /
//! fixed32), and packed-or-unpacked repeated scalars. Unknown fields are
//! skipped by wire type, so models carrying metadata, doc strings, or
//! newer fields decode fine; only the fields named below are retained.
//!
//! Field numbers (from `onnx/onnx.proto`, stable since ONNX IR v3):
//!
//! ```text
//! ModelProto      graph=7
//! GraphProto      node=1 name=2 initializer=5 input=11 output=12
//! NodeProto       input=1 output=2 name=3 op_type=4 attribute=5
//! AttributeProto  name=1 f=2 i=3 s=4 floats=7 ints=8 type=20
//! TensorProto     dims=1 data_type=2 float_data=4 int64_data=7 name=8 raw_data=9
//! ValueInfoProto  name=1 type=2 → TypeProto.tensor_type=1
//!                 → elem_type=1 shape=2 → dim=1 → dim_value=1
//! ```
//!
//! Every malformed input returns `Err(String)` (the importer wraps it into
//! the crate's typed error) — no panics on attacker-controlled bytes.

/// ONNX `TensorProto.DataType.FLOAT`.
pub const DT_FLOAT: i64 = 1;
/// ONNX `TensorProto.DataType.INT64`.
pub const DT_INT64: i64 = 7;

#[derive(Debug, Default)]
pub struct ModelProto {
    pub graph: GraphProto,
}

#[derive(Debug, Default)]
pub struct GraphProto {
    pub name: String,
    pub nodes: Vec<NodeProto>,
    pub initializers: Vec<TensorProto>,
    pub inputs: Vec<ValueInfoProto>,
    pub outputs: Vec<ValueInfoProto>,
}

#[derive(Debug, Default)]
pub struct NodeProto {
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    pub name: String,
    pub op_type: String,
    pub attrs: Vec<AttributeProto>,
}

impl NodeProto {
    /// Attribute by name (ONNX attributes are a flat named list).
    pub fn attr(&self, name: &str) -> Option<&AttributeProto> {
        self.attrs.iter().find(|a| a.name == name)
    }

    pub fn attr_i(&self, name: &str, default: i64) -> i64 {
        self.attr(name).map_or(default, |a| a.i)
    }

    pub fn attr_f(&self, name: &str, default: f32) -> f32 {
        self.attr(name).map_or(default, |a| a.f)
    }

    pub fn attr_s(&self, name: &str) -> Option<String> {
        self.attr(name).map(|a| String::from_utf8_lossy(&a.s).into_owned())
    }

    pub fn attr_ints(&self, name: &str) -> Option<&[i64]> {
        self.attr(name).map(|a| a.ints.as_slice())
    }
}

#[derive(Debug, Default)]
pub struct AttributeProto {
    pub name: String,
    pub f: f32,
    pub i: i64,
    pub s: Vec<u8>,
    pub floats: Vec<f32>,
    pub ints: Vec<i64>,
    /// `AttributeProto.AttributeType` discriminant (FLOAT=1, INT=2,
    /// STRING=3, FLOATS=6, INTS=7, …). Retained for report rendering.
    pub kind: i64,
}

impl AttributeProto {
    /// Render the attribute's value for the unsupported-op report —
    /// deterministic and compact, e.g. `strides=[2, 2]` or `alpha=0.5`.
    pub fn render_value(&self) -> String {
        match self.kind {
            1 => format!("{}", self.f),
            2 => format!("{}", self.i),
            3 => String::from_utf8_lossy(&self.s).into_owned(),
            6 => format!("{:?}", self.floats),
            7 => format!("{:?}", self.ints),
            _ if !self.ints.is_empty() => format!("{:?}", self.ints),
            _ if !self.floats.is_empty() => format!("{:?}", self.floats),
            _ if !self.s.is_empty() => String::from_utf8_lossy(&self.s).into_owned(),
            _ => format!("{}", self.i),
        }
    }
}

#[derive(Debug, Default)]
pub struct TensorProto {
    pub dims: Vec<i64>,
    pub data_type: i64,
    pub float_data: Vec<f32>,
    pub int64_data: Vec<i64>,
    pub raw_data: Vec<u8>,
    pub name: String,
}

impl TensorProto {
    /// The tensor's shape as `usize` dims (rejects negative dims).
    pub fn shape(&self) -> Result<Vec<usize>, String> {
        self.dims
            .iter()
            .map(|&d| {
                usize::try_from(d)
                    .map_err(|_| format!("initializer '{}' has negative dim {d}", self.name))
            })
            .collect()
    }

    /// f32 payload, from whichever encoding the writer chose (`raw_data`
    /// little-endian bytes or the `float_data` repeated field).
    pub fn f32_values(&self) -> Result<Vec<f32>, String> {
        if self.data_type != DT_FLOAT {
            return Err(format!(
                "initializer '{}' has data type {} (only float32 tensors import)",
                self.name, self.data_type
            ));
        }
        if !self.raw_data.is_empty() {
            if self.raw_data.len() % 4 != 0 {
                return Err(format!("initializer '{}': raw_data not a multiple of 4", self.name));
            }
            return Ok(self
                .raw_data
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect());
        }
        Ok(self.float_data.clone())
    }

    /// i64 payload (shape tensors for `Reshape`).
    pub fn i64_values(&self) -> Result<Vec<i64>, String> {
        if self.data_type != DT_INT64 {
            return Err(format!(
                "initializer '{}' has data type {} where int64 was expected",
                self.name, self.data_type
            ));
        }
        if !self.raw_data.is_empty() {
            if self.raw_data.len() % 8 != 0 {
                return Err(format!("initializer '{}': raw_data not a multiple of 8", self.name));
            }
            return Ok(self
                .raw_data
                .chunks_exact(8)
                .map(|c| i64::from_le_bytes(c.try_into().expect("chunks_exact(8)")))
                .collect());
        }
        Ok(self.int64_data.clone())
    }
}

#[derive(Debug, Default)]
pub struct ValueInfoProto {
    pub name: String,
    /// Static tensor dims from the nested `TypeProto`; a symbolic dim
    /// (`dim_param`) decodes as 0 and is rejected by the importer.
    pub dims: Vec<i64>,
}

/// Decode a serialized `ModelProto`.
pub fn parse_model(bytes: &[u8]) -> Result<ModelProto, String> {
    let mut model = ModelProto::default();
    let mut r = Reader::new(bytes);
    let mut saw_graph = false;
    while !r.at_end() {
        let (field, wire) = r.key()?;
        match (field, wire) {
            (7, 2) => {
                model.graph = parse_graph(r.len_delim()?)?;
                saw_graph = true;
            }
            _ => r.skip(wire)?,
        }
    }
    if !saw_graph {
        return Err("model has no graph (not an ONNX ModelProto?)".into());
    }
    Ok(model)
}

fn parse_graph(bytes: &[u8]) -> Result<GraphProto, String> {
    let mut g = GraphProto::default();
    let mut r = Reader::new(bytes);
    while !r.at_end() {
        let (field, wire) = r.key()?;
        match (field, wire) {
            (1, 2) => g.nodes.push(parse_node(r.len_delim()?)?),
            (2, 2) => g.name = r.string()?,
            (5, 2) => g.initializers.push(parse_tensor(r.len_delim()?)?),
            (11, 2) => g.inputs.push(parse_value_info(r.len_delim()?)?),
            (12, 2) => g.outputs.push(parse_value_info(r.len_delim()?)?),
            _ => r.skip(wire)?,
        }
    }
    Ok(g)
}

fn parse_node(bytes: &[u8]) -> Result<NodeProto, String> {
    let mut n = NodeProto::default();
    let mut r = Reader::new(bytes);
    while !r.at_end() {
        let (field, wire) = r.key()?;
        match (field, wire) {
            (1, 2) => n.inputs.push(r.string()?),
            (2, 2) => n.outputs.push(r.string()?),
            (3, 2) => n.name = r.string()?,
            (4, 2) => n.op_type = r.string()?,
            (5, 2) => n.attrs.push(parse_attr(r.len_delim()?)?),
            _ => r.skip(wire)?,
        }
    }
    Ok(n)
}

fn parse_attr(bytes: &[u8]) -> Result<AttributeProto, String> {
    let mut a = AttributeProto::default();
    let mut r = Reader::new(bytes);
    while !r.at_end() {
        let (field, wire) = r.key()?;
        match (field, wire) {
            (1, 2) => a.name = r.string()?,
            (2, 5) => a.f = r.f32()?,
            (3, 0) => a.i = r.varint()? as i64,
            (4, 2) => a.s = r.len_delim()?.to_vec(),
            (7, 5) => a.floats.push(r.f32()?),
            (7, 2) => {
                // Packed repeated float.
                let mut p = Reader::new(r.len_delim()?);
                while !p.at_end() {
                    a.floats.push(p.f32()?);
                }
            }
            (8, 0) => a.ints.push(r.varint()? as i64),
            (8, 2) => {
                // Packed repeated int64.
                let mut p = Reader::new(r.len_delim()?);
                while !p.at_end() {
                    a.ints.push(p.varint()? as i64);
                }
            }
            (20, 0) => a.kind = r.varint()? as i64,
            _ => r.skip(wire)?,
        }
    }
    Ok(a)
}

fn parse_tensor(bytes: &[u8]) -> Result<TensorProto, String> {
    let mut t = TensorProto::default();
    let mut r = Reader::new(bytes);
    while !r.at_end() {
        let (field, wire) = r.key()?;
        match (field, wire) {
            (1, 0) => t.dims.push(r.varint()? as i64),
            (1, 2) => {
                let mut p = Reader::new(r.len_delim()?);
                while !p.at_end() {
                    t.dims.push(p.varint()? as i64);
                }
            }
            (2, 0) => t.data_type = r.varint()? as i64,
            (4, 5) => t.float_data.push(r.f32()?),
            (4, 2) => {
                let mut p = Reader::new(r.len_delim()?);
                while !p.at_end() {
                    t.float_data.push(p.f32()?);
                }
            }
            (7, 0) => t.int64_data.push(r.varint()? as i64),
            (7, 2) => {
                let mut p = Reader::new(r.len_delim()?);
                while !p.at_end() {
                    t.int64_data.push(p.varint()? as i64);
                }
            }
            (8, 2) => t.name = r.string()?,
            (9, 2) => t.raw_data = r.len_delim()?.to_vec(),
            _ => r.skip(wire)?,
        }
    }
    Ok(t)
}

fn parse_value_info(bytes: &[u8]) -> Result<ValueInfoProto, String> {
    let mut v = ValueInfoProto::default();
    let mut r = Reader::new(bytes);
    while !r.at_end() {
        let (field, wire) = r.key()?;
        match (field, wire) {
            (1, 2) => v.name = r.string()?,
            (2, 2) => v.dims = parse_type_dims(r.len_delim()?)?,
            _ => r.skip(wire)?,
        }
    }
    Ok(v)
}

/// `TypeProto` → `tensor_type.shape.dim[*].dim_value`, flattened.
fn parse_type_dims(bytes: &[u8]) -> Result<Vec<i64>, String> {
    let mut dims = Vec::new();
    let mut r = Reader::new(bytes);
    while !r.at_end() {
        let (field, wire) = r.key()?;
        match (field, wire) {
            (1, 2) => {
                // TypeProto.tensor_type (TensorTypeProto)
                let mut tt = Reader::new(r.len_delim()?);
                while !tt.at_end() {
                    let (f, w) = tt.key()?;
                    match (f, w) {
                        (2, 2) => {
                            // TensorTypeProto.shape (TensorShapeProto)
                            let mut sh = Reader::new(tt.len_delim()?);
                            while !sh.at_end() {
                                let (f, w) = sh.key()?;
                                match (f, w) {
                                    (1, 2) => {
                                        // TensorShapeProto.dim (Dimension)
                                        let mut d = Reader::new(sh.len_delim()?);
                                        let mut val = 0i64;
                                        while !d.at_end() {
                                            let (f, w) = d.key()?;
                                            match (f, w) {
                                                (1, 0) => val = d.varint()? as i64,
                                                _ => d.skip(w)?,
                                            }
                                        }
                                        dims.push(val);
                                    }
                                    _ => sh.skip(w)?,
                                }
                            }
                        }
                        _ => tt.skip(w)?,
                    }
                }
            }
            _ => r.skip(wire)?,
        }
    }
    Ok(dims)
}

/// Bounds-checked protobuf wire reader.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn at_end(&self) -> bool {
        self.pos >= self.buf.len()
    }

    fn byte(&mut self) -> Result<u8, String> {
        let b = *self.buf.get(self.pos).ok_or("truncated protobuf")?;
        self.pos += 1;
        Ok(b)
    }

    fn varint(&mut self) -> Result<u64, String> {
        let mut out = 0u64;
        for shift in (0..64).step_by(7) {
            let b = self.byte()?;
            out |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                return Ok(out);
            }
        }
        Err("varint longer than 10 bytes".into())
    }

    /// Field key: `(field_number, wire_type)`.
    fn key(&mut self) -> Result<(u64, u8), String> {
        let k = self.varint()?;
        Ok((k >> 3, (k & 7) as u8))
    }

    fn len_delim(&mut self) -> Result<&'a [u8], String> {
        let len = self.varint()? as usize;
        let end = self.pos.checked_add(len).ok_or("length overflow")?;
        if end > self.buf.len() {
            return Err("truncated length-delimited field".into());
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn string(&mut self) -> Result<String, String> {
        String::from_utf8(self.len_delim()?.to_vec()).map_err(|_| "non-UTF-8 string".into())
    }

    fn f32(&mut self) -> Result<f32, String> {
        let end = self.pos.checked_add(4).ok_or("length overflow")?;
        if end > self.buf.len() {
            return Err("truncated fixed32".into());
        }
        let v = f32::from_le_bytes(self.buf[self.pos..end].try_into().expect("4 bytes"));
        self.pos = end;
        Ok(v)
    }

    /// Skip one field's payload by wire type (unknown-field tolerance).
    fn skip(&mut self, wire: u8) -> Result<(), String> {
        match wire {
            0 => {
                self.varint()?;
            }
            1 => {
                let end = self.pos.checked_add(8).ok_or("length overflow")?;
                if end > self.buf.len() {
                    return Err("truncated fixed64".into());
                }
                self.pos = end;
            }
            2 => {
                self.len_delim()?;
            }
            5 => {
                let end = self.pos.checked_add(4).ok_or("length overflow")?;
                if end > self.buf.len() {
                    return Err("truncated fixed32".into());
                }
                self.pos = end;
            }
            w => return Err(format!("unsupported wire type {w}")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-encode a tiny model and read it back — the writer here mirrors
    /// the fixture generator in `python/tests/gen_onnx_fixtures.py`.
    fn varint(mut v: u64, out: &mut Vec<u8>) {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                out.push(b);
                break;
            }
            out.push(b | 0x80);
        }
    }

    fn key(field: u64, wire: u64, out: &mut Vec<u8>) {
        varint(field << 3 | wire, out);
    }

    fn ld(field: u64, payload: &[u8], out: &mut Vec<u8>) {
        key(field, 2, out);
        varint(payload.len() as u64, out);
        out.extend_from_slice(payload);
    }

    fn test_model_bytes() -> Vec<u8> {
        // NodeProto: Relu(x) -> y, named "act"
        let mut node = Vec::new();
        ld(1, b"x", &mut node);
        ld(2, b"y", &mut node);
        ld(3, b"act", &mut node);
        ld(4, b"Relu", &mut node);
        // AttributeProto: axis = -1 (INT) — exercises negative varint
        let mut attr = Vec::new();
        ld(1, b"axis", &mut attr);
        key(3, 0, &mut attr);
        varint((-1i64) as u64, &mut attr);
        key(20, 0, &mut attr);
        varint(2, &mut attr);
        ld(5, &attr, &mut node);
        // TensorProto initializer: w = [2] float32 {1.5, -0.25}, raw_data
        let mut tensor = Vec::new();
        key(1, 0, &mut tensor);
        varint(2, &mut tensor);
        key(2, 0, &mut tensor);
        varint(DT_FLOAT as u64, &mut tensor);
        ld(8, b"w", &mut tensor);
        let mut raw = Vec::new();
        raw.extend_from_slice(&1.5f32.to_le_bytes());
        raw.extend_from_slice(&(-0.25f32).to_le_bytes());
        ld(9, &raw, &mut tensor);
        // ValueInfoProto: x : float32[4, 8]
        let mut dim4 = Vec::new();
        key(1, 0, &mut dim4);
        varint(4, &mut dim4);
        let mut dim8 = Vec::new();
        key(1, 0, &mut dim8);
        varint(8, &mut dim8);
        let mut shape = Vec::new();
        ld(1, &dim4, &mut shape);
        ld(1, &dim8, &mut shape);
        let mut tt = Vec::new();
        key(1, 0, &mut tt);
        varint(DT_FLOAT as u64, &mut tt);
        ld(2, &shape, &mut tt);
        let mut ty = Vec::new();
        ld(1, &tt, &mut ty);
        let mut vi = Vec::new();
        ld(1, b"x", &mut vi);
        ld(2, &ty, &mut vi);
        // GraphProto
        let mut graph = Vec::new();
        ld(1, &node, &mut graph);
        ld(2, b"tiny", &mut graph);
        ld(5, &tensor, &mut graph);
        ld(11, &vi, &mut graph);
        ld(12, &vi, &mut graph);
        // ModelProto (with an unknown field 1 = ir_version to skip)
        let mut model = Vec::new();
        key(1, 0, &mut model);
        varint(8, &mut model);
        ld(7, &graph, &mut model);
        model
    }

    #[test]
    fn roundtrips_a_hand_encoded_model() {
        let m = parse_model(&test_model_bytes()).expect("parses");
        assert_eq!(m.graph.name, "tiny");
        assert_eq!(m.graph.nodes.len(), 1);
        let n = &m.graph.nodes[0];
        assert_eq!(n.op_type, "Relu");
        assert_eq!(n.name, "act");
        assert_eq!(n.inputs, ["x"]);
        assert_eq!(n.outputs, ["y"]);
        assert_eq!(n.attr_i("axis", 0), -1);
        assert_eq!(m.graph.initializers.len(), 1);
        let t = &m.graph.initializers[0];
        assert_eq!(t.name, "w");
        assert_eq!(t.shape().unwrap(), [2]);
        assert_eq!(t.f32_values().unwrap(), [1.5, -0.25]);
        assert_eq!(m.graph.inputs[0].name, "x");
        assert_eq!(m.graph.inputs[0].dims, [4, 8]);
    }

    #[test]
    fn malformed_bytes_error_instead_of_panicking() {
        assert!(parse_model(&[]).is_err(), "no graph");
        assert!(parse_model(&[0xff; 16]).is_err(), "garbage");
        let good = test_model_bytes();
        for cut in [1, 5, good.len() / 2, good.len() - 1] {
            // Truncations either fail or drop the graph — never panic.
            let _ = parse_model(&good[..cut]);
        }
    }
}
