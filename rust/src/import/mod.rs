//! Real-model front-end: ONNX → relay import.
//!
//! The paper's pipeline starts from workloads "written in Relay"; this
//! module grows that front door to real exported models. An `.onnx` file
//! is decoded by the zero-dependency [`proto`] reader, each graph node is
//! mapped through a declarative table ([`supported_ops`]) onto the typed
//! [`GraphBuilder`], and the result is an ordinary [`Workload`] — it
//! saturates, snapshots (embedded as format v4), and serves exactly like
//! the built-in library.
//!
//! Import conventions:
//!
//! * **Batch-1 squeeze** — rank-4 `[1, C, H, W]` activations become the
//!   crate's rank-3 `[C, H, W]`; any other batch size is rejected.
//! * **Initializers become [`Op::Constant`] leaves** — trained weights are
//!   inlined (content-hashed, so shared initializers intern to one
//!   e-node), which keeps imported workloads self-contained: the interp
//!   backend evaluates the *trained* network, not random weights.
//! * **Padding** — ONNX `pads = [top, left, bottom, right]` maps onto the
//!   IR's total `pad_h = top + bottom` / `pad_w = left + right`, accepted
//!   only when the begin-side is `floor(total/2)` (the IR's fixed
//!   floor-before/ceil-after split, which equals ONNX `SAME_UPPER`).
//!   `auto_pad = SAME_UPPER` is computed from the input shape via
//!   [`same_pad`]; `VALID` means zero.
//! * **Unsupported ops report, they don't panic** — every node the table
//!   cannot express is collected into an [`ImportReport`] (op type, node
//!   name, attributes, reason); the import fails with the full list, not
//!   the first casualty. Nodes downstream of a failed node are skipped
//!   silently (they are casualties, not themselves unsupported).
//!
//! [`Op::Constant`]: crate::ir::Op::Constant

pub mod proto;

use crate::egraph::Id;
use crate::error::Error;
use crate::relay::{same_pad, GraphBuilder, Workload};
use proto::{GraphProto, NodeProto, TensorProto};
use std::collections::{HashMap, HashSet};
use std::path::Path;

/// One ONNX op the importer cannot express, with enough context to fix
/// the model (or extend the mapping table) without re-running.
#[derive(Debug, Clone, PartialEq)]
pub struct UnsupportedOp {
    /// ONNX `op_type` (e.g. `HardSwish`).
    pub op_type: String,
    /// ONNX node name (may be empty — exporters are inconsistent).
    pub node_name: String,
    /// Attribute name → rendered value, in model order.
    pub attrs: Vec<(String, String)>,
    /// Why the mapping refused: no table entry, or an attribute/shape the
    /// relay subset cannot express.
    pub reason: String,
}

/// Structured import failure: every unsupported node in the model, so one
/// run reports the full porting surface.
#[derive(Debug, Clone, PartialEq)]
pub struct ImportReport {
    /// Graph name from the model (or the workload name if unnamed).
    pub model: String,
    /// Total node count in the graph.
    pub total_nodes: usize,
    pub unsupported: Vec<UnsupportedOp>,
}

impl std::fmt::Display for ImportReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "cannot import '{}': {} unsupported node(s) out of {}",
            self.model,
            self.unsupported.len(),
            self.total_nodes
        )?;
        for u in &self.unsupported {
            write!(f, "  - {} '{}': {}", u.op_type, u.node_name, u.reason)?;
            if !u.attrs.is_empty() {
                let rendered: Vec<String> =
                    u.attrs.iter().map(|(k, v)| format!("{k}={v}")).collect();
                write!(f, " [attrs: {}]", rendered.join(", "))?;
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

/// Why an import failed: a structurally bad model, or mappable structure
/// containing unsupported ops (with the full report).
#[derive(Debug)]
pub enum ImportError {
    /// The file is not a readable ONNX model (bad protobuf, non-float
    /// tensors, symbolic shapes, undefined tensors, …).
    Model(String),
    /// The model decoded fine but contains ops outside the relay subset.
    Unsupported(Box<ImportReport>),
}

impl std::fmt::Display for ImportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ImportError::Model(m) => write!(f, "malformed ONNX model: {m}"),
            ImportError::Unsupported(r) => write!(f, "{r}"),
        }
    }
}

impl From<ImportError> for Error {
    fn from(e: ImportError) -> Self {
        match e {
            ImportError::Model(m) => Error::InvalidConfig(format!("onnx import: {m}")),
            ImportError::Unsupported(r) => Error::Unsupported(r.to_string()),
        }
    }
}

/// Import an `.onnx` file as a [`Workload`] named after the file stem.
pub fn import_onnx(path: impl AsRef<Path>) -> Result<Workload, Error> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).map_err(|e| Error::Io(format!("{}: {e}", path.display())))?;
    import_onnx_bytes(&bytes, &sanitize_name(path))
}

/// Import serialized ONNX bytes as a [`Workload`] with the given name.
pub fn import_onnx_bytes(bytes: &[u8], name: &str) -> Result<Workload, Error> {
    try_import(bytes, name).map_err(Error::from)
}

/// Like [`import_onnx_bytes`] but preserving the structured
/// [`ImportError`] (the CLI and tests want the typed report).
pub fn try_import(bytes: &[u8], name: &str) -> Result<Workload, ImportError> {
    let model = proto::parse_model(bytes).map_err(ImportError::Model)?;
    import_graph(&model.graph, name)
}

/// The `(onnx op_type, relay mapping)` table — source of truth for
/// `docs/importer.md` and the CLI's import help.
pub fn supported_ops() -> impl Iterator<Item = (&'static str, &'static str)> {
    MAPPINGS.iter().map(|m| (m.op_type, m.maps_to))
}

/// Workload name from a model path: lowercased stem, non-alphanumerics
/// folded to `_` (so it is addressable in EngineIR text and CLI flags).
fn sanitize_name(path: &Path) -> String {
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("onnx_model");
    let name: String = stem
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
        .collect();
    if name.chars().all(|c| c == '_') {
        "onnx_model".to_string()
    } else {
        name
    }
}

type EmitFn = for<'m> fn(&mut Importer<'m>, &'m NodeProto) -> Result<(), String>;

struct OpMapping {
    op_type: &'static str,
    /// Human-readable target, surfaced through [`supported_ops`].
    maps_to: &'static str,
    emit: EmitFn,
}

const MAPPINGS: &[OpMapping] = &[
    OpMapping { op_type: "Add", maps_to: "bias-add / eadd", emit: emit_add },
    OpMapping { op_type: "Conv", maps_to: "conv2d / dwconv2d (+ bias-add)", emit: emit_conv },
    OpMapping { op_type: "Flatten", maps_to: "flatten", emit: emit_flatten },
    OpMapping { op_type: "Gemm", maps_to: "dense (+ bias-add)", emit: emit_gemm },
    OpMapping { op_type: "GlobalAveragePool", maps_to: "gap", emit: emit_gap },
    OpMapping { op_type: "Identity", maps_to: "aliased (no node)", emit: emit_identity },
    OpMapping { op_type: "MatMul", maps_to: "matmul / batch-matmul", emit: emit_matmul },
    OpMapping { op_type: "MaxPool", maps_to: "maxpool2d", emit: emit_maxpool },
    OpMapping { op_type: "Mul", maps_to: "emul (+ bcast const)", emit: emit_mul },
    OpMapping { op_type: "Relu", maps_to: "relu", emit: emit_relu },
    OpMapping { op_type: "Reshape", maps_to: "reshape", emit: emit_reshape },
    OpMapping { op_type: "Softmax", maps_to: "softmax", emit: emit_softmax },
    OpMapping { op_type: "Transpose", maps_to: "transpose", emit: emit_transpose },
];

/// Build a [`Workload`] from a decoded graph. Separated from the byte
/// entry points so tests can construct [`GraphProto`] values directly.
fn import_graph(g: &GraphProto, name: &str) -> Result<Workload, ImportError> {
    let display_name = if g.name.is_empty() { name } else { g.name.as_str() };
    if g.outputs.is_empty() {
        return Err(ImportError::Model("graph has no outputs".into()));
    }
    if g.nodes.is_empty() {
        return Err(ImportError::Model("graph has no nodes".into()));
    }

    let mut imp = Importer::new(g)?;
    let mut unsupported: Vec<UnsupportedOp> = Vec::new();
    // Outputs of failed nodes: downstream consumers are skipped silently
    // (casualties of an upstream failure, not themselves unsupported).
    let mut failed: HashSet<&str> = HashSet::new();

    for n in &g.nodes {
        let mapping = MAPPINGS.iter().find(|m| m.op_type == n.op_type);
        let outcome = match mapping {
            None => Err("no ONNX→relay mapping for this op type".to_string()),
            Some(_) if n.inputs.iter().any(|i| failed.contains(i.as_str())) => {
                failed.extend(n.outputs.iter().map(String::as_str));
                continue;
            }
            Some(m) => (m.emit)(&mut imp, n),
        };
        if let Err(reason) = outcome {
            unsupported.push(UnsupportedOp {
                op_type: n.op_type.clone(),
                node_name: n.name.clone(),
                attrs: n
                    .attrs
                    .iter()
                    .map(|a| (a.name.clone(), a.render_value()))
                    .collect(),
                reason,
            });
            failed.extend(n.outputs.iter().map(String::as_str));
        }
    }

    if !unsupported.is_empty() {
        return Err(ImportError::Unsupported(Box::new(ImportReport {
            model: display_name.to_string(),
            total_nodes: g.nodes.len(),
            unsupported,
        })));
    }

    let out = g.outputs[0].name.as_str();
    let root = *imp
        .env
        .get(out)
        .ok_or_else(|| ImportError::Model(format!("graph output '{out}' was never produced")))?;
    let node_count = g.nodes.len();
    let expr = imp.b.finish();
    if expr.root() != root {
        // The RecExpr root is its final node; an aliased or non-final
        // output would silently change the workload's meaning.
        return Err(ImportError::Model(format!(
            "graph output '{out}' is not the final computed node"
        )));
    }
    Ok(Workload {
        name: name.to_string(),
        description: format!("ONNX import of '{display_name}' ({node_count} nodes)"),
        expr,
    })
}

/// Mapping state: the typed builder plus the tensor-name environment.
///
/// Every `GraphBuilder` push is pre-validated by the emit functions —
/// the builder's eager type checker panics on ill-typed pushes, and a
/// malformed *model* must report, not abort.
struct Importer<'m> {
    b: GraphBuilder,
    /// Tensor name → built node, for graph inputs (lazily pushed),
    /// materialized initializers, and node outputs.
    env: HashMap<&'m str, Id>,
    /// Graph inputs not yet pushed (name → squeezed dims).
    pending_inputs: HashMap<&'m str, Vec<usize>>,
    /// Initializers not yet materialized.
    inits: HashMap<&'m str, &'m TensorProto>,
}

impl<'m> Importer<'m> {
    fn new(g: &'m GraphProto) -> Result<Self, ImportError> {
        let mut inits: HashMap<&str, &TensorProto> = HashMap::new();
        for t in &g.initializers {
            inits.insert(t.name.as_str(), t);
        }
        let mut pending_inputs = HashMap::new();
        for vi in &g.inputs {
            // Older exporters also list initializers under graph.input.
            if inits.contains_key(vi.name.as_str()) {
                continue;
            }
            let dims = squeeze_input_dims(&vi.name, &vi.dims).map_err(ImportError::Model)?;
            pending_inputs.insert(vi.name.as_str(), dims);
        }
        Ok(Importer { b: GraphBuilder::new(), env: HashMap::new(), pending_inputs, inits })
    }

    /// Resolve a tensor name to a built node, lazily pushing graph inputs
    /// and materializing initializers as `const` leaves.
    fn tensor(&mut self, name: &'m str) -> Result<Id, String> {
        if let Some(&id) = self.env.get(name) {
            return Ok(id);
        }
        if let Some(dims) = self.pending_inputs.remove(name) {
            let id = self.b.input(name, &dims);
            self.env.insert(name, id);
            return Ok(id);
        }
        if let Some(t) = self.inits.get(name) {
            let (dims, vals) = init_data(t)?;
            let id = self.b.constant(&dims, &vals);
            self.env.insert(name, id);
            return Ok(id);
        }
        Err(format!("tensor '{name}' is not defined (initializer, input, or node output)"))
    }

    /// The dims a tensor would have if resolved — without building
    /// anything, so shape validation can precede materialization.
    fn dims_of(&self, name: &str) -> Result<Vec<usize>, String> {
        if let Some(&id) = self.env.get(name) {
            let s = self.b.shape_of(id);
            return Ok((0..s.rank()).map(|i| s.dim(i)).collect());
        }
        if let Some(dims) = self.pending_inputs.get(name) {
            return Ok(dims.clone());
        }
        if let Some(t) = self.inits.get(name) {
            return t.shape();
        }
        Err(format!("tensor '{name}' is not defined (initializer, input, or node output)"))
    }

    /// Raw initializer payload, for ops that consume weights structurally
    /// (conv weight reshape, Gemm transB pre-transpose, scalar scale).
    fn init_data(&self, name: &str) -> Result<(Vec<usize>, Vec<f32>), String> {
        let t = self
            .inits
            .get(name)
            .ok_or_else(|| format!("'{name}' must be an initializer (a trained constant)"))?;
        init_data(t)
    }

    fn is_init(&self, name: &str) -> bool {
        self.inits.contains_key(name)
    }

    fn bind(&mut self, n: &'m NodeProto, id: Id) -> Result<(), String> {
        let out = n
            .outputs
            .first()
            .ok_or_else(|| "node has no outputs".to_string())?;
        self.env.insert(out.as_str(), id);
        Ok(())
    }
}

/// Validate and extract an initializer's shape + payload.
fn init_data(t: &TensorProto) -> Result<(Vec<usize>, Vec<f32>), String> {
    let dims = t.shape()?;
    let vals = t.f32_values()?;
    let numel: usize = dims.iter().product();
    if vals.len() != numel {
        return Err(format!(
            "initializer '{}' declares shape {dims:?} ({numel} elements) but carries {}",
            t.name,
            vals.len()
        ));
    }
    Ok((dims, vals))
}

/// Graph-input dims: static, positive, rank ≤ 3 after squeezing a
/// leading batch-1 from rank-4 NCHW.
fn squeeze_input_dims(name: &str, dims: &[i64]) -> Result<Vec<usize>, String> {
    let mut out = Vec::with_capacity(dims.len());
    for &d in dims {
        if d <= 0 {
            return Err(format!(
                "input '{name}' has symbolic or non-positive dim {d}; re-export with static shapes"
            ));
        }
        out.push(d as usize);
    }
    if out.len() == 4 {
        if out[0] != 1 {
            return Err(format!("input '{name}' has batch size {} (only 1 imports)", out[0]));
        }
        out.remove(0);
    }
    if out.is_empty() || out.len() > 3 {
        return Err(format!("input '{name}' has rank {} (1–3 after batch squeeze)", out.len()));
    }
    Ok(out)
}

// ---- per-op emit functions ----------------------------------------------

fn one_input<'m>(n: &'m NodeProto) -> Result<&'m str, String> {
    match n.inputs.as_slice() {
        [x] => Ok(x.as_str()),
        other => Err(format!("expected 1 input, got {}", other.len())),
    }
}

fn two_inputs<'m>(n: &'m NodeProto) -> Result<(&'m str, &'m str), String> {
    match n.inputs.as_slice() {
        [a, b] => Ok((a.as_str(), b.as_str())),
        other => Err(format!("expected 2 inputs, got {}", other.len())),
    }
}

/// Stride from the `strides` attribute: the IR has one stride for both
/// spatial dims.
fn isotropic_stride(n: &NodeProto) -> Result<usize, String> {
    match n.attr_ints("strides") {
        None => Ok(1),
        Some([s]) => usize::try_from(*s).map_err(|_| format!("negative stride {s}")),
        Some([sh, sw]) if sh == sw => {
            usize::try_from(*sh).map_err(|_| format!("negative stride {sh}"))
        }
        Some(other) => Err(format!("anisotropic strides {other:?} unsupported")),
    }
}

fn reject_dilations(n: &NodeProto) -> Result<(), String> {
    if let Some(d) = n.attr_ints("dilations") {
        if d.iter().any(|&x| x != 1) {
            return Err(format!("dilations {d:?} unsupported (only 1)"));
        }
    }
    Ok(())
}

/// ONNX explicit `pads = [top, left, bottom, right]` → the IR's total
/// `(pad_h, pad_w)`. The IR always splits a total `p` as `floor(p/2)`
/// before / `ceil(p/2)` after, so only that split is expressible.
fn explicit_pads(n: &NodeProto) -> Result<(usize, usize), String> {
    let pads = match n.attr_ints("pads") {
        None => return Ok((0, 0)),
        Some(p) => p,
    };
    let &[top, left, bottom, right] = pads else {
        return Err(format!("pads {pads:?} unsupported (want [top, left, bottom, right])"));
    };
    let as_usize = |v: i64| usize::try_from(v).map_err(|_| format!("negative pad {v}"));
    let (top, left, bottom, right) =
        (as_usize(top)?, as_usize(left)?, as_usize(bottom)?, as_usize(right)?);
    let (pad_h, pad_w) = (top + bottom, left + right);
    if top != pad_h / 2 || left != pad_w / 2 {
        return Err(format!(
            "pads [{top}, {left}, {bottom}, {right}] split differs from the IR's \
             floor-before/ceil-after convention"
        ));
    }
    Ok((pad_h, pad_w))
}

/// `(padded - k)` must tile exactly by `stride` (the IR has no ceil-mode
/// or implicit crop).
fn check_window(dim: usize, pad: usize, k: usize, stride: usize, axis: &str) -> Result<(), String> {
    let padded = dim + pad;
    if padded < k || (padded - k) % stride != 0 {
        return Err(format!(
            "window k={k} stride={stride} does not tile the padded {axis} extent {padded}"
        ));
    }
    Ok(())
}

fn emit_conv<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let (x_name, w_name) = match n.inputs.as_slice() {
        [x, w] => (x.as_str(), w.as_str()),
        [x, w, _b] => (x.as_str(), w.as_str()),
        other => return Err(format!("expected 2–3 inputs, got {}", other.len())),
    };
    reject_dilations(n)?;
    let stride = isotropic_stride(n)?;
    if stride == 0 {
        return Err("stride 0".into());
    }
    let xdims = imp.dims_of(x_name)?;
    let [c, h, w] = xdims[..] else {
        return Err(format!("conv input has shape {xdims:?} (want [C, H, W] after batch squeeze)"));
    };
    let (wdims, wvals) = imp.init_data(w_name)?;
    let [oc, icg, kh, kw] = wdims[..] else {
        return Err(format!("conv weight has shape {wdims:?} (want [OC, IC/group, kh, kw])"));
    };

    let (pad_h, pad_w) = match n.attr_s("auto_pad").as_deref() {
        None | Some("NOTSET") | Some("") => explicit_pads(n)?,
        Some("VALID") => (0, 0),
        Some("SAME_UPPER") => (same_pad(h, kh, stride), same_pad(w, kw, stride)),
        Some(other) => return Err(format!("auto_pad {other} unsupported")),
    };
    check_window(h, pad_h, kh, stride, "height")?;
    check_window(w, pad_w, kw, stride, "width")?;

    let group = n.attr_i("group", 1);
    let y = if group == 1 {
        if icg != c {
            return Err(format!("weight expects {icg} input channels, input has {c}"));
        }
        let wid = imp.b.constant(&wdims, &wvals);
        let x = imp.tensor(x_name)?;
        imp.b.conv2d(x, wid, stride, pad_h, pad_w)
    } else if group == c as i64 && icg == 1 && oc == c {
        // Depthwise: ONNX weight [C, 1, kh, kw] is the IR's [C, kh, kw].
        let wid = imp.b.constant(&[c, kh, kw], &wvals);
        let x = imp.tensor(x_name)?;
        imp.b.depthwise_conv2d(x, wid, stride, pad_h, pad_w)
    } else {
        return Err(format!(
            "group={group} with weight {wdims:?} unsupported (want group=1 or depthwise)"
        ));
    };

    let y = match n.inputs.get(2) {
        None => y,
        Some(b_name) => {
            let (bdims, bvals) = imp.init_data(b_name)?;
            if bdims != [oc] {
                return Err(format!("conv bias has shape {bdims:?} (want [{oc}])"));
            }
            let bid = imp.b.constant(&bdims, &bvals);
            imp.b.bias_add(y, bid)
        }
    };
    imp.bind(n, y)
}

fn emit_relu<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let x_name = one_input(n)?;
    let dims = imp.dims_of(x_name)?;
    if dims.is_empty() || dims.len() > 3 {
        return Err(format!("relu input has rank {} (want 1–3)", dims.len()));
    }
    let x = imp.tensor(x_name)?;
    let y = imp.b.relu(x);
    imp.bind(n, y)
}

fn emit_gemm<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let (a_name, b_name) = match n.inputs.as_slice() {
        [a, b] => (a.as_str(), b.as_str()),
        [a, b, _c] => (a.as_str(), b.as_str()),
        other => return Err(format!("expected 2–3 inputs, got {}", other.len())),
    };
    if n.attr_f("alpha", 1.0) != 1.0 || n.attr_f("beta", 1.0) != 1.0 {
        return Err(format!(
            "alpha={} beta={} unsupported (want 1)",
            n.attr_f("alpha", 1.0),
            n.attr_f("beta", 1.0)
        ));
    }
    if n.attr_i("transA", 0) != 0 {
        return Err("transA=1 unsupported".into());
    }
    let adims = imp.dims_of(a_name)?;
    let [_rows, k] = adims[..] else {
        return Err(format!("Gemm input has shape {adims:?} (want rank 2)"));
    };
    let (wdims, wvals) = imp.init_data(b_name)?;
    let [d0, d1] = wdims[..] else {
        return Err(format!("Gemm weight has shape {wdims:?} (want rank 2)"));
    };
    // `dense` computes X[n,k] @ W[k,m]; transB=1 stores W as [m,k], so
    // pre-transpose the constant *data* at import time.
    let (wdims, wvals) = if n.attr_i("transB", 0) == 1 {
        let (m, kk) = (d0, d1);
        let mut t = vec![0.0f32; wvals.len()];
        for i in 0..m {
            for j in 0..kk {
                t[j * m + i] = wvals[i * kk + j];
            }
        }
        (vec![kk, m], t)
    } else {
        (wdims, wvals)
    };
    if wdims[0] != k {
        return Err(format!("Gemm weight expects {} input features, input has {k}", wdims[0]));
    }
    let wid = imp.b.constant(&wdims, &wvals);
    let a = imp.tensor(a_name)?;
    let y = imp.b.dense(a, wid);
    let y = match n.inputs.get(2) {
        None => y,
        Some(c_name) => {
            let (cdims, cvals) = imp.init_data(c_name)?;
            if cdims != [wdims[1]] {
                return Err(format!("Gemm bias has shape {cdims:?} (want [{}])", wdims[1]));
            }
            let cid = imp.b.constant(&cdims, &cvals);
            imp.b.bias_add(y, cid)
        }
    };
    imp.bind(n, y)
}

fn emit_matmul<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let (a_name, b_name) = two_inputs(n)?;
    let adims = imp.dims_of(a_name)?;
    let bdims = imp.dims_of(b_name)?;
    match (adims.as_slice(), bdims.as_slice()) {
        ([_, ak], [bk, _]) if ak == bk => {
            let a = imp.tensor(a_name)?;
            let b = imp.tensor(b_name)?;
            let y = imp.b.matmul(a, b);
            imp.bind(n, y)
        }
        ([ab, _, ak], [bb, bk, _]) if ab == bb && ak == bk => {
            let a = imp.tensor(a_name)?;
            let b = imp.tensor(b_name)?;
            let y = imp.b.batch_matmul(a, b);
            imp.bind(n, y)
        }
        _ => Err(format!("MatMul shapes {adims:?} × {bdims:?} unsupported")),
    }
}

fn emit_mul<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let (a_name, b_name) = two_inputs(n)?;
    let adims = imp.dims_of(a_name)?;
    let bdims = imp.dims_of(b_name)?;
    // Scalar constant on either side → broadcast scale (`1/√dh` etc.).
    for (t_name, t_dims, c_name) in
        [(a_name, &adims, b_name), (b_name, &bdims, a_name)]
    {
        if imp.is_init(c_name) {
            let (_, cvals) = imp.init_data(c_name)?;
            if cvals.len() == 1 {
                if t_dims.is_empty() || t_dims.len() > 3 {
                    return Err(format!("Mul input has rank {} (want 1–3)", t_dims.len()));
                }
                let x = imp.tensor(t_name)?;
                let y = imp.b.scale(x, cvals[0]);
                return imp.bind(n, y);
            }
        }
    }
    if adims == bdims && !adims.is_empty() && adims.len() <= 3 {
        let a = imp.tensor(a_name)?;
        let b = imp.tensor(b_name)?;
        let y = imp.b.emul(a, b);
        return imp.bind(n, y);
    }
    // Rank-1 constant against the broadcast axis (channel for rank 3,
    // features for rank 2).
    for (t_name, t_dims, c_name, c_dims) in
        [(a_name, &adims, b_name, &bdims), (b_name, &bdims, a_name, &adims)]
    {
        let bcast_dim = match t_dims.as_slice() {
            [c, _, _] => *c,
            [_, f] => *f,
            _ => continue,
        };
        if c_dims.as_slice() == [bcast_dim] {
            let c = imp.tensor(c_name)?;
            let b = imp.b.bcast(c, t_dims);
            let x = imp.tensor(t_name)?;
            let y = imp.b.emul(x, b);
            return imp.bind(n, y);
        }
    }
    Err(format!("Mul shapes {adims:?} × {bdims:?} unsupported"))
}

fn emit_add<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let (a_name, b_name) = two_inputs(n)?;
    let adims = imp.dims_of(a_name)?;
    let bdims = imp.dims_of(b_name)?;
    if adims == bdims && !adims.is_empty() && adims.len() <= 3 {
        let a = imp.tensor(a_name)?;
        let b = imp.tensor(b_name)?;
        let y = imp.b.add(a, b);
        return imp.bind(n, y);
    }
    // Rank-1 bias against the bias axis (channel for rank 3, features
    // for rank 2) — `Add(x, b)` is how exporters spell bias-add.
    for (t_name, t_dims, c_name, c_dims) in
        [(a_name, &adims, b_name, &bdims), (b_name, &bdims, a_name, &adims)]
    {
        let bias_dim = match t_dims.as_slice() {
            [c, _, _] => *c,
            [_, f] => *f,
            _ => continue,
        };
        if c_dims.as_slice() == [bias_dim] {
            let x = imp.tensor(t_name)?;
            let b = imp.tensor(c_name)?;
            let y = imp.b.bias_add(x, b);
            return imp.bind(n, y);
        }
    }
    Err(format!("Add shapes {adims:?} + {bdims:?} unsupported"))
}

fn emit_softmax<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let x_name = one_input(n)?;
    let dims = imp.dims_of(x_name)?;
    if dims.len() < 2 || dims.len() > 3 {
        return Err(format!("softmax input has rank {} (want 2–3)", dims.len()));
    }
    let axis = n.attr_i("axis", -1);
    if axis != -1 && axis != dims.len() as i64 - 1 {
        return Err(format!("axis={axis} unsupported (last axis only)"));
    }
    let x = imp.tensor(x_name)?;
    let y = imp.b.softmax(x);
    imp.bind(n, y)
}

fn emit_transpose<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let x_name = one_input(n)?;
    let dims = imp.dims_of(x_name)?;
    let perm = n.attr_ints("perm");
    let ok = match (dims.len(), perm) {
        (2, None) | (2, Some([1, 0])) => true,
        (3, Some([0, 2, 1])) => true,
        _ => false,
    };
    if !ok {
        return Err(format!(
            "perm {perm:?} on rank {} unsupported (trailing-axes swap only)",
            dims.len()
        ));
    }
    let x = imp.tensor(x_name)?;
    let y = imp.b.transpose(x);
    imp.bind(n, y)
}

fn emit_reshape<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let (x_name, shape_name) = two_inputs(n)?;
    let xdims = imp.dims_of(x_name)?;
    let numel: usize = xdims.iter().product();
    let shape_t = imp
        .inits
        .get(shape_name)
        .ok_or_else(|| "reshape target must be a constant shape tensor".to_string())?;
    let target = shape_t.i64_values()?;
    // Resolve -1 (infer); reject 0 (copy-dim — ambiguous after squeeze).
    let mut dims: Vec<usize> = Vec::with_capacity(target.len());
    let mut infer_at: Option<usize> = None;
    for (i, &d) in target.iter().enumerate() {
        match d {
            -1 if infer_at.is_none() => {
                infer_at = Some(i);
                dims.push(1);
            }
            d if d > 0 => dims.push(d as usize),
            _ => return Err(format!("reshape target {target:?} unsupported")),
        }
    }
    if let Some(i) = infer_at {
        let rest: usize = dims.iter().product();
        if rest == 0 || numel % rest != 0 {
            return Err(format!("cannot infer -1 in reshape target {target:?}"));
        }
        dims[i] = numel / rest;
    }
    // Squeeze a leading batch-1 from a rank-4 target (mirrors inputs).
    if dims.len() == 4 && dims[0] == 1 {
        dims.remove(0);
    }
    if dims.is_empty() || dims.len() > 3 {
        return Err(format!("reshape target rank {} unsupported (1–3)", dims.len()));
    }
    if dims.iter().product::<usize>() != numel {
        return Err(format!("reshape {xdims:?} → {dims:?} changes the element count"));
    }
    let x = imp.tensor(x_name)?;
    let y = imp.b.reshape(x, &dims);
    imp.bind(n, y)
}

fn emit_flatten<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let x_name = one_input(n)?;
    let dims = imp.dims_of(x_name)?;
    if dims.len() != 3 {
        return Err(format!("flatten input has rank {} (want 3)", dims.len()));
    }
    // ONNX axis=1 on [1, C, H, W] → [1, C·H·W]; the batch is already
    // squeezed here, so axis 0 and 1 coincide.
    let axis = n.attr_i("axis", 1);
    if !(0..=1).contains(&axis) {
        return Err(format!("flatten axis={axis} unsupported"));
    }
    let x = imp.tensor(x_name)?;
    let y = imp.b.flatten(x);
    imp.bind(n, y)
}

fn emit_gap<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let x_name = one_input(n)?;
    let dims = imp.dims_of(x_name)?;
    if dims.len() != 3 {
        return Err(format!("global-avg-pool input has rank {} (want 3)", dims.len()));
    }
    let x = imp.tensor(x_name)?;
    let y = imp.b.global_avg_pool(x);
    imp.bind(n, y)
}

fn emit_maxpool<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let x_name = one_input(n)?;
    reject_dilations(n)?;
    if n.attr_i("ceil_mode", 0) != 0 {
        return Err("ceil_mode=1 unsupported".into());
    }
    match n.attr_s("auto_pad").as_deref() {
        None | Some("NOTSET") | Some("") | Some("VALID") => {}
        Some(other) => return Err(format!("auto_pad {other} unsupported for MaxPool")),
    }
    if explicit_pads(n)? != (0, 0) {
        return Err("padded MaxPool unsupported (the IR's maxpool has no pad)".into());
    }
    let (kh, kw) = match n.attr_ints("kernel_shape") {
        Some(&[kh, kw]) => (kh, kw),
        other => return Err(format!("kernel_shape {other:?} unsupported")),
    };
    let (kh, kw) = (
        usize::try_from(kh).map_err(|_| format!("negative kernel {kh}"))?,
        usize::try_from(kw).map_err(|_| format!("negative kernel {kw}"))?,
    );
    let stride = isotropic_stride(n)?;
    if stride == 0 {
        return Err("stride 0".into());
    }
    let dims = imp.dims_of(x_name)?;
    let [_, h, w] = dims[..] else {
        return Err(format!("maxpool input has shape {dims:?} (want [C, H, W])"));
    };
    check_window(h, 0, kh, stride, "height")?;
    check_window(w, 0, kw, stride, "width")?;
    let x = imp.tensor(x_name)?;
    let y = imp.b.maxpool2d_rect(x, kh, kw, stride);
    imp.bind(n, y)
}

fn emit_identity<'m>(imp: &mut Importer<'m>, n: &'m NodeProto) -> Result<(), String> {
    let x_name = one_input(n)?;
    let x = imp.tensor(x_name)?;
    imp.bind(n, x)
}

#[cfg(test)]
mod tests {
    use super::proto::{AttributeProto, TensorProto, ValueInfoProto, DT_FLOAT};
    use super::*;
    use crate::ir::Shape;

    fn float_init(name: &str, dims: &[i64], vals: &[f32]) -> TensorProto {
        TensorProto {
            dims: dims.to_vec(),
            data_type: DT_FLOAT,
            float_data: vals.to_vec(),
            name: name.to_string(),
            ..Default::default()
        }
    }

    fn vi(name: &str, dims: &[i64]) -> ValueInfoProto {
        ValueInfoProto { name: name.to_string(), dims: dims.to_vec() }
    }

    fn node(op: &str, name: &str, ins: &[&str], out: &str) -> NodeProto {
        NodeProto {
            inputs: ins.iter().map(|s| s.to_string()).collect(),
            outputs: vec![out.to_string()],
            name: name.to_string(),
            op_type: op.to_string(),
            attrs: Vec::new(),
        }
    }

    fn attr_ints(name: &str, vals: &[i64]) -> AttributeProto {
        AttributeProto {
            name: name.to_string(),
            ints: vals.to_vec(),
            kind: 7,
            ..Default::default()
        }
    }

    #[test]
    fn imports_a_conv_relu_graph_with_same_upper_pads() {
        // [1,3,8,8] --Conv(3→4, k3, s2, pads [0,0,1,1])--> Relu
        let mut conv = node("Conv", "c0", &["x", "w", "b"], "t0");
        conv.attrs.push(attr_ints("strides", &[2, 2]));
        conv.attrs.push(attr_ints("pads", &[0, 0, 1, 1]));
        let g = GraphProto {
            name: "convnet".into(),
            nodes: vec![conv, node("Relu", "r0", &["t0"], "y")],
            initializers: vec![
                float_init("w", &[4, 3, 3, 3], &[0.01; 108]),
                float_init("b", &[4], &[0.5; 4]),
            ],
            inputs: vec![vi("x", &[1, 3, 8, 8])],
            outputs: vec![vi("y", &[1, 4, 4, 4])],
        };
        let w = import_graph(&g, "convnet").expect("imports");
        assert_eq!(w.name, "convnet");
        // SAME_UPPER on 8/s2/k3: total pad 1, out = ceil(8/2) = 4.
        assert_eq!(
            w.expr.typecheck().unwrap(),
            crate::ir::Ty::Tensor(Shape::new(&[4, 4, 4]))
        );
        // Weights arrived as constant leaves, not symbols.
        assert_eq!(w.expr.count(|op| matches!(op, crate::ir::Op::Constant(_))), 2);
        assert_eq!(w.expr.count(|op| matches!(op, crate::ir::Op::Weight(..))), 0);
    }

    #[test]
    fn depthwise_conv_reshapes_the_onnx_weight_layout() {
        let mut conv = node("Conv", "dw", &["x", "w"], "y");
        conv.attrs.push(AttributeProto {
            name: "group".into(),
            i: 3,
            kind: 2,
            ..Default::default()
        });
        conv.attrs.push(attr_ints("pads", &[1, 1, 1, 1]));
        let g = GraphProto {
            name: String::new(),
            nodes: vec![conv],
            initializers: vec![float_init("w", &[3, 1, 3, 3], &[0.1; 27])],
            inputs: vec![vi("x", &[1, 3, 6, 6])],
            outputs: vec![vi("y", &[1, 3, 6, 6])],
        };
        let w = import_graph(&g, "dwnet").expect("imports");
        assert_eq!(
            w.expr.typecheck().unwrap(),
            crate::ir::Ty::Tensor(Shape::new(&[3, 6, 6]))
        );
        assert_eq!(w.expr.count(|op| matches!(op, crate::ir::Op::DepthwiseConv2d { .. })), 1);
    }

    #[test]
    fn gemm_trans_b_pre_transposes_the_constant() {
        // W stored [out=2, in=3] with transB=1 must act like [3, 2].
        let mut gemm = node("Gemm", "fc", &["x", "w"], "y");
        gemm.attrs.push(AttributeProto {
            name: "transB".into(),
            i: 1,
            kind: 2,
            ..Default::default()
        });
        let g = GraphProto {
            name: String::new(),
            nodes: vec![gemm],
            initializers: vec![float_init("w", &[2, 3], &[1., 2., 3., 4., 5., 6.])],
            inputs: vec![vi("x", &[1, 3])],
            outputs: vec![vi("y", &[1, 2])],
        };
        let w = import_graph(&g, "fcnet").expect("imports");
        assert_eq!(
            w.expr.typecheck().unwrap(),
            crate::ir::Ty::Tensor(Shape::new(&[1, 2]))
        );
        // The evaluated result must match x @ Wᵀ.
        use crate::tensor::{eval_expr, Env, Tensor};
        let mut env = Env::new();
        env.tensors.insert(
            crate::ir::Symbol::new("x"),
            Tensor::new(Shape::new(&[1, 3]), vec![1.0, 0.0, 2.0]),
        );
        let got = eval_expr(&w.expr, &mut env).unwrap();
        // x @ Wᵀ: [1*1 + 0*2 + 2*3, 1*4 + 0*5 + 2*6] = [7, 16].
        assert_eq!(got.data, vec![7.0, 16.0]);
    }

    #[test]
    fn unsupported_ops_are_collected_not_cascaded() {
        // HardSwish has no mapping; the downstream Relu consuming its
        // output must be skipped silently, not double-reported.
        let g = GraphProto {
            name: "oddnet".into(),
            nodes: vec![
                node("Relu", "r0", &["x"], "t0"),
                node("HardSwish", "hs0", &["t0"], "t1"),
                node("Relu", "r1", &["t1"], "y"),
            ],
            initializers: vec![],
            inputs: vec![vi("x", &[16])],
            outputs: vec![vi("y", &[16])],
        };
        let err = import_graph(&g, "oddnet").unwrap_err();
        let ImportError::Unsupported(report) = err else {
            panic!("want Unsupported, got {err:?}")
        };
        assert_eq!(report.total_nodes, 3);
        assert_eq!(report.unsupported.len(), 1);
        assert_eq!(report.unsupported[0].op_type, "HardSwish");
        assert_eq!(report.unsupported[0].node_name, "hs0");
    }

    #[test]
    fn bad_pad_split_reports_with_attrs() {
        let mut conv = node("Conv", "c0", &["x", "w"], "y");
        // Total pad 2 split [2, 0] — the IR can only split it [1, 1].
        conv.attrs.push(attr_ints("pads", &[2, 0, 0, 2]));
        let g = GraphProto {
            name: String::new(),
            nodes: vec![conv],
            initializers: vec![float_init("w", &[4, 3, 3, 3], &[0.01; 108])],
            inputs: vec![vi("x", &[1, 3, 8, 8])],
            outputs: vec![vi("y", &[1, 4, 8, 8])],
        };
        let err = import_graph(&g, "badpad").unwrap_err();
        let ImportError::Unsupported(report) = err else {
            panic!("want Unsupported, got {err:?}")
        };
        let u = &report.unsupported[0];
        assert_eq!(u.op_type, "Conv");
        assert!(u.reason.contains("floor-before/ceil-after"), "{}", u.reason);
        assert!(u.attrs.iter().any(|(k, v)| k == "pads" && v == "[2, 0, 0, 2]"));
        // And the rendered report carries all of it.
        let text = report.to_string();
        assert!(text.contains("Conv 'c0'"), "{text}");
        assert!(text.contains("pads=[2, 0, 0, 2]"), "{text}");
    }

    #[test]
    fn scalar_mul_becomes_a_broadcast_scale() {
        let g = GraphProto {
            name: String::new(),
            nodes: vec![node("Mul", "sc", &["x", "k"], "y")],
            initializers: vec![float_init("k", &[], &[0.25])],
            inputs: vec![vi("x", &[4, 8])],
            outputs: vec![vi("y", &[4, 8])],
        };
        let w = import_graph(&g, "scalenet").expect("imports");
        use crate::tensor::{eval_expr, Env};
        let env = Env::random_for(&w.expr, 11);
        let got = eval_expr(&w.expr, &mut env.clone()).unwrap();
        let x = env.tensors[&crate::ir::Symbol::new("x")].clone();
        for (g, x) in got.data.iter().zip(&x.data) {
            assert!((g - x * 0.25).abs() < 1e-6);
        }
    }

    #[test]
    fn name_sanitization_makes_cli_safe_names() {
        assert_eq!(sanitize_name(Path::new("/tmp/MobileNet-V1.slice.onnx")), "mobilenet_v1_slice");
        assert_eq!(sanitize_name(Path::new("---.onnx")), "onnx_model");
    }
}
