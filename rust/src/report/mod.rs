//! Small table/CSV emitters shared by the CLI, examples and benches.
//! (No serde in the vendored dep set, so output formats are hand-rolled.)

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// An aligned plain-text table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column alignment.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:>w$}  ", c, w = widths[i]);
            }
            let _ = writeln!(out);
        };
        line(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }

    /// CSV rendering (RFC-4180-lite: quotes around cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write CSV next to the repo's bench outputs.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// Format a float compactly (3 significant-ish digits, scientific for big).
pub fn fmt_f64(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1e6 || v.abs() < 1e-3 {
        format!("{v:.3e}")
    } else if v.fract() == 0.0 && v.abs() < 1e6 {
        format!("{v:.0}")
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "23".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["x,y".into(), "z".into()]);
        assert!(t.to_csv().contains("\"x,y\",z"));
    }

    #[test]
    fn fmt_f64_modes() {
        assert_eq!(fmt_f64(0.0), "0");
        assert_eq!(fmt_f64(3.0), "3");
        assert_eq!(fmt_f64(3.14159), "3.142");
        assert!(fmt_f64(1.5e9).contains('e'));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
