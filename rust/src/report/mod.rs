//! Small table/CSV emitters shared by the CLI, examples and benches.
//! (No serde in the vendored dep set, so output formats are hand-rolled.)

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// An aligned plain-text table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column alignment.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:>w$}  ", c, w = widths[i]);
            }
            let _ = writeln!(out);
        };
        line(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }

    /// CSV rendering (RFC-4180-lite: quotes around cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write CSV next to the repo's bench outputs.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// A JSON scalar for the machine-readable bench records (no serde in the
/// zero-dependency build). Non-finite numbers serialize as `null` — JSON
/// has no NaN/Infinity.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Str(String),
    Num(f64),
    Int(i64),
}

impl JsonValue {
    /// Render as a JSON literal (also used by the serving layer's
    /// hand-rolled response writer).
    pub fn render(&self) -> String {
        match self {
            JsonValue::Str(s) => {
                let mut out = String::with_capacity(s.len() + 2);
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
                out
            }
            JsonValue::Num(v) if !v.is_finite() => "null".to_string(),
            JsonValue::Num(v) => format!("{v}"),
            JsonValue::Int(v) => format!("{v}"),
        }
    }
}

/// A flat list of key/value records rendered as a JSON array of objects —
/// the `bench_results.json` format the CI perf job uploads, one record per
/// measured (workload, engine) cell.
#[derive(Debug, Clone, Default)]
pub struct JsonRecords {
    records: Vec<Vec<(String, JsonValue)>>,
}

impl JsonRecords {
    pub fn new() -> Self {
        JsonRecords::default()
    }

    pub fn push(&mut self, record: Vec<(String, JsonValue)>) {
        self.records.push(record);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn to_json(&self) -> String {
        let mut out = String::from("[\n");
        for (i, rec) in self.records.iter().enumerate() {
            let fields: Vec<String> = rec
                .iter()
                .map(|(k, v)| format!("{}: {}", JsonValue::Str(k.clone()).render(), v.render()))
                .collect();
            let _ = write!(out, "  {{{}}}", fields.join(", "));
            out.push_str(if i + 1 < self.records.len() { ",\n" } else { "\n" });
        }
        out.push(']');
        out.push('\n');
        out
    }

    /// Write the records, creating parent directories as needed.
    pub fn write(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json().as_bytes())
    }
}

/// Format a float compactly (3 significant-ish digits, scientific for big).
pub fn fmt_f64(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1e6 || v.abs() < 1e-3 {
        format!("{v:.3e}")
    } else if v.fract() == 0.0 && v.abs() < 1e6 {
        format!("{v:.0}")
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "23".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["x,y".into(), "z".into()]);
        assert!(t.to_csv().contains("\"x,y\",z"));
    }

    #[test]
    fn fmt_f64_modes() {
        assert_eq!(fmt_f64(0.0), "0");
        assert_eq!(fmt_f64(3.0), "3");
        assert_eq!(fmt_f64(3.14159), "3.142");
        assert!(fmt_f64(1.5e9).contains('e'));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn json_records_render_escaped_and_typed() {
        let mut j = JsonRecords::new();
        j.push(vec![
            ("workload".into(), JsonValue::Str("le\"net\n".into())),
            ("wall_ms".into(), JsonValue::Num(12.5)),
            ("designs_per_sec".into(), JsonValue::Num(f64::NAN)),
            ("n".into(), JsonValue::Int(3)),
        ]);
        j.push(vec![("workload".into(), JsonValue::Str("mlp".into()))]);
        let s = j.to_json();
        assert!(s.starts_with("[\n"));
        assert!(s.contains(r#""workload": "le\"net\n""#), "{s}");
        assert!(s.contains(r#""wall_ms": 12.5"#));
        assert!(s.contains(r#""designs_per_sec": null"#), "NaN must be null: {s}");
        assert!(s.contains(r#""n": 3"#));
        assert!(s.trim_end().ends_with(']'));
        assert_eq!(j.len(), 2);
    }
}
