//! Minimal property-based-testing helpers (no external deps are available
//! in this build environment, so this is a tiny, deterministic stand-in for
//! `proptest`): a fast xorshift PRNG plus a case runner that reports the
//! failing seed for reproduction.

/// xorshift64* PRNG — deterministic, seedable, good enough for test-case
/// generation (NOT for cryptography).
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0,1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// A power of two in `[lo, hi]` (both must be powers of two).
    pub fn pow2(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo.is_power_of_two() && hi.is_power_of_two());
        let lo_bits = lo.trailing_zeros();
        let hi_bits = hi.trailing_zeros();
        1 << self.range(lo_bits as usize, hi_bits as usize)
    }
}

/// Run `f` on `cases` seeded RNGs; panics with the failing seed on error so
/// the case can be replayed with `Rng::new(seed)`.
pub fn check(name: &str, cases: usize, mut f: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0x9E37_79B9 ^ (case as u64).wrapping_mul(0x517c_c1b7_2722_0a95);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed on case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let v = r.range(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn pow2_yields_powers() {
        let mut r = Rng::new(4);
        for _ in 0..100 {
            let v = r.pow2(2, 64);
            assert!(v.is_power_of_two() && (2..=64).contains(&v));
        }
    }

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counts", 17, |_| n += 1);
        assert_eq!(n, 17);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check("fails", 5, |rng| assert!(rng.below(10) > 100));
    }
}
