//! # hwsplit — Enumerating Hardware–Software Splits with Program Rewriting
//!
//! A reproduction of Smith, Tatlock & Ceze (UW, 2020): machine-learning
//! inference workloads are lowered from a Relay-like operator IR into
//! **EngineIR**, a language that reifies the three components of an
//! accelerated workload — fixed-size *hardware engines*, *software
//! schedules* (loops / parallelism), and *storage buffers* — and the space
//! of functionally-equivalent hardware–software designs is enumerated by
//! running semantics-preserving rewrites over an e-graph.
//!
//! ## Crate layout
//!
//! | module | role |
//! |---|---|
//! | [`ir`] | EngineIR term language: ops, `RecExpr`, parser, printer, shapes |
//! | [`egraph`] | from-scratch e-graph: union-find, hashcons, congruence closure, e-matching, rewrite runner |
//! | [`relay`] | Relay-like frontend operator graphs + workload library |
//! | [`lower`] | Relay → EngineIR reification (paper Fig. 1) |
//! | [`rewrites`] | the split-altering rewrite library (paper Fig. 2 + extensions) |
//! | [`tensor`] | pure-Rust tensor math + EngineIR evaluator (semantics oracle) |
//! | [`cost`] | analytic area / latency / energy models over designs |
//! | [`extract`] | greedy, cost-directed and Pareto design extraction |
//! | [`sim`] | cycle-approximate accelerator simulator (usefulness oracle) |
//! | [`runtime`] | PJRT executor for AOT-compiled Pallas engine kernels |
//! | [`coordinator`] | threaded design-space-exploration driver |
//! | [`prop`] | tiny property-testing helpers (PRNG + runners) |
//! | [`report`] | table / CSV emitters shared by benches |

pub mod bench_util;
pub mod coordinator;
pub mod cost;
pub mod egraph;
pub mod extract;
pub mod ir;
pub mod lower;
pub mod prop;
pub mod relay;
pub mod report;
pub mod rewrites;
pub mod runtime;
pub mod sim;
pub mod tensor;

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::egraph::{EGraph, Id, Runner};
    pub use crate::ir::{Op, RecExpr, Symbol};
    pub use crate::rewrites;
}
