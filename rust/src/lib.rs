//! # hwsplit — Enumerating Hardware–Software Splits with Program Rewriting
//!
//! A reproduction of Smith, Tatlock & Ceze (UW, 2020): machine-learning
//! inference workloads are lowered from a Relay-like operator IR into
//! **EngineIR**, a language that reifies the three components of an
//! accelerated workload — fixed-size *hardware engines*, *software
//! schedules* (loops / parallelism), and *storage buffers* — and the space
//! of functionally-equivalent hardware–software designs is enumerated by
//! running semantics-preserving rewrites over an e-graph.
//!
//! ## The `Session` API
//!
//! The paper's point is economic: enumeration is the expensive step, and
//! the e-graph makes the enumerated space *cheap to re-query*. The crate's
//! primary API is shaped accordingly — a [`session::Session`] lowers and
//! enumerates a workload **once** (lazily, cached) and then answers any
//! number of [`session::Query`]s against the shared read-only e-graph:
//!
//! ```no_run
//! use hwsplit::prelude::*;
//!
//! let mut session = Session::builder()
//!     .workload(hwsplit::relay::workloads::lenet())
//!     .rules(RuleSet::All)
//!     .build()?;
//!
//! // Pay enumeration once…
//! let fast = session.query(&Query::new().objective(Objective::Latency).samples(256))?;
//! // …then re-query freely: new objective, new backend, new cost params.
//! let small = session.query(&Query::new().objective(Objective::Area).backend(Backend::Sim))?;
//! let checked = session.query(&Query::new().backend(Backend::Interp).samples(32))?;
//! assert_eq!(session.enumeration_count(), 1);
//! # let _ = (fast, small, checked);
//! # Ok::<(), hwsplit::Error>(())
//! ```
//!
//! Evaluation is backend-pluggable ([`session::Backend`]): the closed-form
//! **analytic** cost model, the pure-Rust **interp**reter (functional
//! outputs), the cycle-approximate **sim**ulator, and — with `--features
//! pjrt` — the **PJRT** runtime executing AOT-compiled Pallas kernels.
//! Fallible API boundaries return the typed [`Error`] instead of
//! panicking.
//!
//! ## Crate layout
//!
//! | module | role |
//! |---|---|
//! | [`ir`] | EngineIR term language: ops, `RecExpr`, parser, printer, shapes |
//! | [`ir::spec`] | **the operator registry**: one declarative `OpSpec` per op (arity, attrs, shape rule, eval kernel, lowering template, cost) — every generic pass dispatches through it |
//! | [`egraph`] | from-scratch e-graph: union-find, arena-interned nodes, hashcons, congruence closure, e-matching, wave-parallel rewrite runner |
//! | [`relay`] | Relay-like frontend operator graphs + workload library |
//! | [`import`] | real-model front door: zero-dependency ONNX → relay importer (`hwsplit explore --model net.onnx`) with a structured unsupported-op report |
//! | [`lower`] | Relay → EngineIR reification (paper Fig. 1) |
//! | [`rewrites`] | the split-altering rewrite library (paper Fig. 2 + extensions) + [`rewrites::RuleSet`] |
//! | [`tensor`] | pure-Rust tensor math + EngineIR evaluator (semantics oracle) |
//! | [`cost`] | analytic area / latency / energy models over designs |
//! | [`extract`] | parallel, memoized design extraction: incremental cost-table memo, seeded sampling, streaming Pareto frontier |
//! | [`persist`] | versioned zero-dependency snapshot format: saturated e-graph + cost tables on disk, loaded with zero re-saturation; v3 *delta* snapshots persist only the growth against a fingerprint-checked base file |
//! | [`serve`] | `hwsplit serve`: TCP daemon (bounded worker pool, typed backpressure, per-request deadlines, hot snapshot reload) answering design-space queries from loaded snapshots; [`serve::shard`] scales past one process — a supervisor/router over health-checked child daemons — wire protocol spec in `docs/serving.md` |
//! | [`sim`] | cycle-approximate accelerator simulator (usefulness oracle) |
//! | [`runtime`] | PJRT executor for AOT-compiled Pallas engine kernels (feature `pjrt`; stub otherwise) |
//! | [`session`] | **the primary API**: reusable sessions, queries, pluggable backends |
//! | [`error`] | the crate-wide typed [`Error`] |
//! | [`fx`] | in-tree FxHash (zero-dependency fast hashing) |
//! | [`par`] | scoped worker pool shared by search/extraction/evaluation fan-outs |
//! | [`prop`] | tiny property-testing helpers (PRNG + runners) |
//! | [`report`] | table / CSV emitters shared by benches |
//!
//! A one-page dataflow map of how these fit together (relay → e-graph
//! saturation → extraction → persistence → serving, with the design
//! decisions behind each stage) lives in `docs/architecture.md`; the
//! serving wire protocol is specified in `docs/serving.md`.

pub mod bench_util;
pub mod cost;
pub mod egraph;
pub mod error;
pub mod extract;
pub mod fx;
pub mod import;
pub mod ir;
pub mod lower;
pub mod par;
pub mod persist;
pub mod prop;
pub mod relay;
pub mod report;
pub mod rewrites;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod sim;
pub mod tensor;

pub use error::{Error, Result};

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::cost::CostParams;
    pub use crate::egraph::{EGraph, Id, Runner, RunnerLimits};
    pub use crate::error::{Error, Result};
    pub use crate::ir::{Op, RecExpr, Symbol};
    pub use crate::relay::{workloads, Workload};
    pub use crate::rewrites::{self, RuleSet};
    pub use crate::session::{
        Backend, Evaluation, EvaluatedDesign, Objective, Query, Session,
    };
}
