//! `hwsplit serve` — a long-running daemon answering design-space queries
//! from persisted snapshots.
//!
//! This is the paper's "enumerate once, query many" economics pushed past
//! process lifetime: saturation happens offline (`hwsplit explore
//! --snapshot-out`), and the daemon [`Session::load_snapshot`]s the result
//! — enumerated *and* warm — then serves concurrent clients over
//! line-delimited JSON on TCP (std-only; no HTTP framework in the
//! zero-dependency build).
//!
//! ## Protocol
//!
//! One request per line, one JSON object per response line:
//!
//! ```text
//! → {"cmd":"query","workload":"relu128","objective":"latency","samples":16,"seed":0}
//! ← {"ok":true,"workload":"relu128","objective":"latency","designs":12,
//!    "frontier":3,"best_area":128,"best_latency":34.1,"memo_hits":18,
//!    "memo_misses":0,"latency_ms":1.42}
//! → {"cmd":"stats"}
//! ← {"ok":true,"served":9,"errors":1,"queries_per_sec":310.2,
//!    "p50_ms":1.4,"p99_ms":6.0,"cached_sessions":2}
//! → {"cmd":"ping"}        ← {"ok":true,"pong":true}
//! → {"cmd":"shutdown"}    ← {"ok":true,"shutting_down":true}
//! ```
//!
//! ## Architecture
//!
//! * [`SessionStore`] — lazily loads one [`Session`] per snapshot file and
//!   bounds residency with an LRU (`--max-sessions`): serving many
//!   workloads from one daemon without holding every e-graph at once.
//! * One thread per connection; each request fans its extraction over the
//!   session's worker pool through [`Session::answer_query`] (`&self`-only
//!   — many threads share one `Arc<Session>`, cost-table fixpoints are
//!   shared through the session memo).
//! * **Error isolation**: a malformed line or failed query answers
//!   `{"ok":false,"error":...}` on that connection and affects nothing
//!   else; connection I/O errors kill only their own thread.
//! * [`ServerStats`] — per-request latency + throughput counters behind
//!   atomics, drained by `{"cmd":"stats"}` (and by the serving bench).
//!
//! [`Session`]: crate::session::Session
//! [`Session::load_snapshot`]: crate::session::Session::load_snapshot
//! [`Session::answer_query`]: crate::session::Session::answer_query

pub mod json;

use crate::error::{Error, Result};
use crate::persist;
use crate::report::JsonValue;
use crate::session::{Evaluation, Objective, Query, Session};
use json::Json;
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Multi-workload session residency: a registry of snapshot files (one
/// per workload, discovered via [`persist::peek_header`] without decoding
/// payloads) and an LRU-bounded cache of loaded [`Session`]s. `get` loads
/// lazily outside the lock; the cache never holds more than `max_sessions`
/// entries (the serving tests pin this).
pub struct SessionStore {
    registry: HashMap<String, PathBuf>,
    max_sessions: usize,
    inner: Mutex<StoreInner>,
}

#[derive(Default)]
struct StoreInner {
    sessions: HashMap<String, Arc<Session>>,
    /// Workload names, least-recently-used first.
    lru: VecDeque<String>,
}

impl SessionStore {
    pub fn new(max_sessions: usize) -> Self {
        SessionStore {
            registry: HashMap::new(),
            max_sessions: max_sessions.max(1),
            inner: Mutex::new(StoreInner::default()),
        }
    }

    /// Register a snapshot file, keyed by the workload its header names.
    /// Cheap (header peek only); returns the workload name.
    pub fn register(&mut self, path: impl Into<PathBuf>) -> Result<String> {
        let path = path.into();
        let meta = persist::peek_header(&path)?;
        self.registry.insert(meta.workload.clone(), path);
        Ok(meta.workload)
    }

    /// Registered workload names (sorted, for stable output).
    pub fn workloads(&self) -> Vec<String> {
        let mut names: Vec<String> = self.registry.keys().cloned().collect();
        names.sort();
        names
    }

    /// Number of sessions currently resident.
    pub fn cached_count(&self) -> usize {
        self.inner.lock().unwrap().sessions.len()
    }

    /// Seed the cache with an already-built session (CLI pre-warm, tests).
    /// Subject to the same LRU bound as lazy loads.
    pub fn insert_session(&self, workload: &str, session: Arc<Session>) {
        let mut inner = self.inner.lock().unwrap();
        inner.sessions.insert(workload.to_string(), session);
        Self::touch(&mut inner, workload);
        self.evict(&mut inner);
    }

    /// The session for `workload`, loading its snapshot on first use.
    /// Snapshot decode runs *outside* the store lock, so a cold workload
    /// doesn't stall queries against resident ones; a racing duplicate
    /// load resolves first-insert-wins.
    pub fn get(&self, workload: &str) -> Result<Arc<Session>> {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(s) = inner.sessions.get(workload).cloned() {
                Self::touch(&mut inner, workload);
                return Ok(s);
            }
        }
        let path = self
            .registry
            .get(workload)
            .ok_or_else(|| Error::UnknownWorkload(workload.to_string()))?;
        let loaded = Arc::new(Session::load_snapshot(path)?);
        let mut inner = self.inner.lock().unwrap();
        let session =
            inner.sessions.entry(workload.to_string()).or_insert_with(|| loaded).clone();
        Self::touch(&mut inner, workload);
        self.evict(&mut inner);
        Ok(session)
    }

    fn touch(inner: &mut StoreInner, workload: &str) {
        inner.lru.retain(|n| n != workload);
        inner.lru.push_back(workload.to_string());
    }

    fn evict(&self, inner: &mut StoreInner) {
        while inner.sessions.len() > self.max_sessions {
            match inner.lru.pop_front() {
                Some(victim) => {
                    inner.sessions.remove(&victim);
                }
                None => break, // sessions not in the LRU can't be chosen
            }
        }
    }
}

/// Lock-light serving counters: request count and error count as atomics,
/// per-request latencies appended under a mutex (drained by `stats`
/// requests and the serving bench).
pub struct ServerStats {
    served: AtomicUsize,
    errors: AtomicUsize,
    latencies_ms: Mutex<Vec<f64>>,
    started: Instant,
}

impl Default for ServerStats {
    fn default() -> Self {
        ServerStats::new()
    }
}

impl ServerStats {
    pub fn new() -> Self {
        ServerStats {
            served: AtomicUsize::new(0),
            errors: AtomicUsize::new(0),
            latencies_ms: Mutex::new(Vec::new()),
            started: Instant::now(),
        }
    }

    /// Record one successfully answered query.
    pub fn record(&self, latency_ms: f64) {
        self.served.fetch_add(1, Ordering::Relaxed);
        self.latencies_ms.lock().unwrap().push(latency_ms);
    }

    /// Record one failed request (parse error, unknown workload, …).
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn served(&self) -> usize {
        self.served.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> usize {
        self.errors.load(Ordering::Relaxed)
    }

    /// Throughput + latency percentiles since construction.
    pub fn summary(&self) -> StatsSummary {
        let mut lat = self.latencies_ms.lock().unwrap().clone();
        lat.sort_by(f64::total_cmp);
        let elapsed = self.started.elapsed().as_secs_f64();
        let served = self.served();
        StatsSummary {
            served,
            errors: self.errors(),
            queries_per_sec: if elapsed > 0.0 { served as f64 / elapsed } else { 0.0 },
            p50_ms: percentile(&lat, 50.0),
            p99_ms: percentile(&lat, 99.0),
        }
    }
}

/// One point-in-time view of [`ServerStats`].
#[derive(Debug, Clone, Copy)]
pub struct StatsSummary {
    pub served: usize,
    pub errors: usize,
    pub queries_per_sec: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Nearest-rank percentile over an ascending-sorted slice (`NaN` when
/// empty). Shared by the stats endpoint and the serving bench.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// The TCP daemon: accept loop + one handler thread per connection.
pub struct Server {
    store: Arc<SessionStore>,
    stats: Arc<ServerStats>,
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Bind to `addr` (e.g. `"127.0.0.1:7878"`; port 0 picks a free port —
    /// the tests do this).
    pub fn bind(addr: &str, store: Arc<SessionStore>) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        Ok(Server {
            store,
            stats: Arc::new(ServerStats::new()),
            listener,
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn stats(&self) -> Arc<ServerStats> {
        self.stats.clone()
    }

    /// Ask the accept loop to stop, nudging it out of `accept()` with a
    /// throwaway connection. Callable from any thread.
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Ok(addr) = self.listener.local_addr() {
            // Ignore failure: if the listener is already gone, done anyway.
            let _ = TcpStream::connect(addr);
        }
    }

    /// Run the accept loop until [`Server::request_shutdown`] (or a client
    /// sends `{"cmd":"shutdown"}`). Handler threads are detached; each owns
    /// exactly one connection, so a panic or I/O error on one client never
    /// touches another.
    pub fn run(&self) -> Result<()> {
        let addr = self.listener.local_addr()?;
        for stream in self.listener.incoming() {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let store = self.store.clone();
            let stats = self.stats.clone();
            let shutdown = self.shutdown.clone();
            std::thread::spawn(move || {
                let _ = handle_client(stream, &store, &stats, &shutdown, addr);
            });
        }
        Ok(())
    }
}

/// Serve one connection: read line-delimited requests until EOF (or a
/// shutdown request). Request-level failures answer an error object and
/// keep the connection; only I/O failures end it.
fn handle_client(
    stream: TcpStream,
    store: &SessionStore,
    stats: &ServerStats,
    shutdown: &AtomicBool,
    listener_addr: SocketAddr,
) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let (response, stop) = handle_line(trimmed, store, stats);
        writer.write_all(response.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if stop {
            shutdown.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(listener_addr); // nudge the acceptor
            return Ok(());
        }
    }
}

/// Answer one request line. Returns the JSON response and whether this
/// request asked the daemon to shut down. Never panics on bad input —
/// every failure becomes `{"ok":false,...}` (and counts as an error).
/// Exposed for the CLI's one-shot mode and the tests.
pub fn handle_line(line: &str, store: &SessionStore, stats: &ServerStats) -> (String, bool) {
    match handle_request(line, store, stats) {
        Ok(reply) => reply,
        Err(e) => {
            stats.record_error();
            (error_response(&e.to_string()), false)
        }
    }
}

fn handle_request(
    line: &str,
    store: &SessionStore,
    stats: &ServerStats,
) -> Result<(String, bool)> {
    let req = Json::parse(line).map_err(|e| Error::InvalidConfig(format!("bad request: {e}")))?;
    let cmd = req.get("cmd").and_then(Json::as_str).unwrap_or("query");
    match cmd {
        "ping" => Ok(("{\"ok\":true,\"pong\":true}".to_string(), false)),
        "shutdown" => Ok(("{\"ok\":true,\"shutting_down\":true}".to_string(), true)),
        "stats" => {
            let s = stats.summary();
            let fields = [
                ("served", JsonValue::Int(s.served as i64)),
                ("errors", JsonValue::Int(s.errors as i64)),
                ("queries_per_sec", JsonValue::Num(s.queries_per_sec)),
                ("p50_ms", JsonValue::Num(s.p50_ms)),
                ("p99_ms", JsonValue::Num(s.p99_ms)),
                ("cached_sessions", JsonValue::Int(store.cached_count() as i64)),
                ("workloads", JsonValue::Str(store.workloads().join(","))),
            ];
            Ok((ok_response(&fields), false))
        }
        "query" => {
            let workload = req
                .get("workload")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::InvalidConfig("query needs a 'workload' field".into()))?;
            let objective: Objective = req
                .get("objective")
                .and_then(Json::as_str)
                .unwrap_or("latency")
                .parse()?;
            let samples = req
                .get("samples")
                .map(|v| {
                    v.as_u64()
                        .ok_or_else(|| Error::InvalidConfig("'samples' must be a non-negative integer".into()))
                })
                .transpose()?
                .unwrap_or(16) as usize;
            let seed = req
                .get("seed")
                .map(|v| {
                    v.as_u64()
                        .ok_or_else(|| Error::InvalidConfig("'seed' must be a non-negative integer".into()))
                })
                .transpose()?
                .unwrap_or(0);
            let session = store.get(workload)?;
            let t0 = Instant::now();
            let q = Query::new().objective(objective).samples(samples).seed(seed);
            let ev = session.answer_query(&q)?;
            let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
            stats.record(latency_ms);
            Ok((query_response(&ev, latency_ms), false))
        }
        other => Err(Error::InvalidConfig(format!(
            "unknown cmd '{other}' (expected query | stats | ping | shutdown)"
        ))),
    }
}

fn objective_name(o: Objective) -> &'static str {
    match o {
        Objective::Latency => "latency",
        Objective::Area => "area",
        Objective::Balanced(_) => "balanced",
    }
}

fn query_response(ev: &Evaluation, latency_ms: f64) -> String {
    let best = ev.best();
    let fields = [
        ("workload", JsonValue::Str(ev.workload.clone())),
        ("objective", JsonValue::Str(objective_name(ev.objective).to_string())),
        ("designs", JsonValue::Int(ev.designs.len() as i64)),
        ("frontier", JsonValue::Int(ev.frontier.len() as i64)),
        ("best_area", JsonValue::Num(best.map_or(f64::NAN, |d| d.point.cost.area))),
        ("best_latency", JsonValue::Num(best.map_or(f64::NAN, |d| d.point.cost.latency))),
        ("memo_hits", JsonValue::Int(ev.extract.memo_hits as i64)),
        ("memo_misses", JsonValue::Int(ev.extract.memo_misses as i64)),
        ("latency_ms", JsonValue::Num(latency_ms)),
    ];
    ok_response(&fields)
}

/// `{"ok":true, <fields...>}` through the report emitter's escaping.
fn ok_response(fields: &[(&str, JsonValue)]) -> String {
    let mut out = String::from("{\"ok\":true");
    for (k, v) in fields {
        out.push(',');
        out.push_str(&JsonValue::Str(k.to_string()).render());
        out.push(':');
        out.push_str(&v.render());
    }
    out.push('}');
    out
}

fn error_response(msg: &str) -> String {
    format!("{{\"ok\":false,\"error\":{}}}", JsonValue::Str(msg.to_string()).render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::workloads;
    use crate::rewrites::RuleSet;

    fn tiny_session() -> Arc<Session> {
        let mut s = Session::builder()
            .workload(workloads::relu128())
            .rules(RuleSet::Fig2)
            .iters(4)
            .workers(2)
            .build()
            .unwrap();
        s.enumerate().unwrap();
        Arc::new(s)
    }

    #[test]
    fn handle_line_answers_query_and_isolates_errors() {
        let store = SessionStore::new(4);
        store.insert_session("relu128", tiny_session());
        let stats = ServerStats::new();
        // Malformed line: error response, connection-level state untouched.
        let (bad, stop) = handle_line("not json", &store, &stats);
        assert!(bad.starts_with("{\"ok\":false"));
        assert!(!stop);
        assert_eq!(stats.errors(), 1);
        // Unknown workload: typed error surfaced, not a panic.
        let (unknown, _) = handle_line(r#"{"cmd":"query","workload":"nope"}"#, &store, &stats);
        assert!(unknown.contains("unknown workload"), "{unknown}");
        // Valid query answers with design counts.
        let (good, stop) =
            handle_line(r#"{"workload":"relu128","samples":4,"seed":1}"#, &store, &stats);
        assert!(!stop);
        let parsed = Json::parse(&good).expect("response is valid JSON");
        assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true));
        assert!(parsed.get("designs").and_then(Json::as_u64).unwrap() >= 2);
        assert_eq!(parsed.get("workload").and_then(Json::as_str), Some("relu128"));
        assert_eq!(stats.served(), 1);
        // Stats reflect the traffic.
        let (stats_resp, _) = handle_line(r#"{"cmd":"stats"}"#, &store, &stats);
        let s = Json::parse(&stats_resp).unwrap();
        assert_eq!(s.get("served").and_then(Json::as_u64), Some(1));
        assert_eq!(s.get("errors").and_then(Json::as_u64), Some(2));
    }

    #[test]
    fn shutdown_command_signals_stop() {
        let store = SessionStore::new(1);
        let stats = ServerStats::new();
        let (resp, stop) = handle_line(r#"{"cmd":"shutdown"}"#, &store, &stats);
        assert!(stop);
        assert!(resp.contains("shutting_down"));
    }

    #[test]
    fn lru_store_never_exceeds_bound() {
        let store = SessionStore::new(2);
        store.insert_session("a", tiny_session());
        store.insert_session("b", tiny_session());
        store.insert_session("c", tiny_session());
        assert_eq!(store.cached_count(), 2);
        // "a" was least recently used — evicted first.
        assert!(store.get("a").is_err(), "evicted and unregistered: must miss");
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert!(percentile(&[], 50.0).is_nan());
    }
}
