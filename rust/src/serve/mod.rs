//! `hwsplit serve` — a long-running daemon answering design-space queries
//! from persisted snapshots.
//!
//! This is the paper's "enumerate once, query many" economics pushed past
//! process lifetime: saturation happens offline (`hwsplit explore
//! --snapshot-out`), and the daemon [`Session::load_snapshot`]s the result
//! — enumerated *and* warm — then serves concurrent clients over
//! line-delimited JSON on TCP (std-only; no HTTP framework in the
//! zero-dependency build).
//!
//! ## Protocol
//!
//! One request per line, one JSON object per response line. Commands:
//! `query` / `stats` / `ping` / `reload` / `shutdown`; failures answer
//! `{"ok":false,"code":...,"error":...}` with a typed code from
//! [`protocol::ErrorCode`]. **The authoritative wire-protocol spec is
//! `docs/serving.md`** — request/response schemas, the full error
//! taxonomy, timeout/backpressure semantics and client examples; a test
//! cross-checks that document against the protocol enums.
//!
//! ## Architecture
//!
//! * **Bounded acceptor + fixed worker pool** ([`ServeConfig`]): the
//!   accept loop owns the listener and hands connections to
//!   `--serve-workers` pool threads through a bounded queue
//!   (`--queue-depth`). When the queue is full the acceptor answers an
//!   immediate typed `busy` error with a `retry_after_ms` hint and drops
//!   the connection — load past capacity degrades into fast typed
//!   rejections, never unbounded thread spawn or queueing. (Setting
//!   `--serve-workers 0` restores the legacy thread-per-connection path,
//!   now hard-capped at `--max-connections` with the same busy refusal.)
//! * **Per-request deadlines** (`--request-timeout-ms`): socket
//!   read/write timeouts bound slow clients, and each request carries a
//!   deadline into [`Session::answer_query`], whose phase-boundary checks
//!   turn an over-budget query into a typed `timeout` error instead of a
//!   held worker.
//! * **Hot snapshot reload**: the `reload` command — or touching the
//!   `--reload-marker` file, checked on every accepted connection —
//!   atomically swaps each resident workload's [`Session`] for a fresh
//!   decode of its snapshot ([`SessionStore::reload`]). In-flight
//!   connections keep their `Arc<Session>` and complete on the old graph;
//!   a failed decode aborts the whole reload with the old sessions
//!   untouched.
//! * [`SessionStore`] — lazily loads one [`Session`] per snapshot file
//!   and bounds residency with an LRU (`--max-sessions`); racing lazy
//!   loads resolve first-insert-wins.
//! * **Error isolation**: a malformed line or failed query answers a
//!   typed error on that connection and affects nothing else; connection
//!   I/O errors end only their own connection. Persistent accept-loop
//!   failures surface as a typed error from [`Server::run`] after bounded
//!   retries — the listener is never silently dropped.
//! * [`ServerStats`] — served/error/rejected/timeout counters, queue
//!   depth, latency percentiles and per-workload served counts behind
//!   atomics, drained by `{"cmd":"stats"}` (and by the serving bench's
//!   overload rows).
//!
//! [`Session`]: crate::session::Session
//! [`Session::load_snapshot`]: crate::session::Session::load_snapshot
//! [`Session::answer_query`]: crate::session::Session::answer_query

pub mod json;
pub mod protocol;
pub mod shard;

pub use protocol::{Command, ErrorCode};

use crate::error::{Error, Result};
use crate::persist;
use crate::report::JsonValue;
use crate::session::{Evaluation, Objective, Query, Session};
use json::Json;
use protocol::{error_response, ok_response};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant, SystemTime};

/// Multi-workload session residency: a registry of snapshot files (one
/// per workload, discovered via [`persist::peek_header`] without decoding
/// payloads) and an LRU-bounded cache of loaded [`Session`]s. `get` loads
/// lazily outside the lock; the cache never holds more than `max_sessions`
/// entries (the serving tests pin this). [`SessionStore::reload`] swaps
/// resident sessions for fresh decodes without disturbing in-flight
/// `Arc<Session>` holders.
pub struct SessionStore {
    registry: HashMap<String, PathBuf>,
    max_sessions: usize,
    /// Bumped once per successful [`SessionStore::reload`]; serving
    /// exposes it so clients can observe snapshot swaps.
    generation: AtomicUsize,
    inner: Mutex<StoreInner>,
}

#[derive(Default)]
struct StoreInner {
    sessions: HashMap<String, Arc<Session>>,
    /// Workload names, least-recently-used first.
    lru: VecDeque<String>,
}

impl SessionStore {
    pub fn new(max_sessions: usize) -> Self {
        SessionStore {
            registry: HashMap::new(),
            max_sessions: max_sessions.max(1),
            generation: AtomicUsize::new(0),
            inner: Mutex::new(StoreInner::default()),
        }
    }

    /// Register a snapshot file, keyed by the workload its header names.
    /// Cheap (header peek only); returns the workload name.
    pub fn register(&mut self, path: impl Into<PathBuf>) -> Result<String> {
        let path = path.into();
        let meta = persist::peek_header(&path)?;
        self.registry.insert(meta.workload.clone(), path);
        Ok(meta.workload)
    }

    /// Registered workload names (sorted, for stable output).
    pub fn workloads(&self) -> Vec<String> {
        let mut names: Vec<String> = self.registry.keys().cloned().collect();
        names.sort();
        names
    }

    /// Number of sessions currently resident.
    pub fn cached_count(&self) -> usize {
        self.inner.lock().unwrap().sessions.len()
    }

    /// How many successful [`SessionStore::reload`]s have run.
    pub fn generation(&self) -> usize {
        self.generation.load(Ordering::SeqCst)
    }

    /// Seed the cache with an already-built session (CLI pre-warm, tests).
    /// Subject to the same LRU bound as lazy loads.
    pub fn insert_session(&self, workload: &str, session: Arc<Session>) {
        let mut inner = self.inner.lock().unwrap();
        inner.sessions.insert(workload.to_string(), session);
        Self::touch(&mut inner, workload);
        self.evict(&mut inner);
    }

    /// The session for `workload`, loading its snapshot on first use.
    /// Snapshot decode runs *outside* the store lock, so a cold workload
    /// doesn't stall queries against resident ones; a racing duplicate
    /// load resolves first-insert-wins.
    pub fn get(&self, workload: &str) -> Result<Arc<Session>> {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(s) = inner.sessions.get(workload).cloned() {
                Self::touch(&mut inner, workload);
                return Ok(s);
            }
        }
        let path = self
            .registry
            .get(workload)
            .ok_or_else(|| Error::UnknownWorkload(workload.to_string()))?;
        let loaded = Arc::new(Session::load_snapshot(path)?);
        let mut inner = self.inner.lock().unwrap();
        let session = inner.sessions.entry(workload.to_string()).or_insert(loaded).clone();
        Self::touch(&mut inner, workload);
        self.evict(&mut inner);
        Ok(session)
    }

    /// Hot snapshot reload: re-decode every **resident** workload's
    /// snapshot from disk and atomically swap it into the cache. Returns
    /// the reloaded workload names (sorted).
    ///
    /// Semantics the serving tests pin:
    /// * **In-flight queries are untouched** — connections hold their own
    ///   `Arc<Session>` clone, so a swap retires the old graph only once
    ///   the last in-flight query drops it.
    /// * **All-or-nothing** — every decode runs *outside* the lock first;
    ///   any failure (e.g. [`Error::SnapshotCorrupt`]) aborts the whole
    ///   reload with the old sessions still serving.
    /// * Non-resident workloads need no swap: their next lazy
    ///   [`SessionStore::get`] reads the file fresh anyway (and racing
    ///   lazy loads keep their first-insert-wins resolution).
    pub fn reload(&self) -> Result<Vec<String>> {
        let mut resident: Vec<String> = {
            let inner = self.inner.lock().unwrap();
            self.registry.keys().filter(|n| inner.sessions.contains_key(*n)).cloned().collect()
        };
        resident.sort();
        let mut fresh = Vec::with_capacity(resident.len());
        for name in &resident {
            let path = self.registry.get(name).expect("resident implies registered");
            fresh.push((name.clone(), Arc::new(Session::load_snapshot(path)?)));
        }
        {
            let mut inner = self.inner.lock().unwrap();
            for (name, session) in fresh {
                inner.sessions.insert(name.clone(), session);
                Self::touch(&mut inner, &name);
            }
        }
        self.generation.fetch_add(1, Ordering::SeqCst);
        Ok(resident)
    }

    fn touch(inner: &mut StoreInner, workload: &str) {
        inner.lru.retain(|n| n != workload);
        inner.lru.push_back(workload.to_string());
    }

    fn evict(&self, inner: &mut StoreInner) {
        while inner.sessions.len() > self.max_sessions {
            match inner.lru.pop_front() {
                Some(victim) => {
                    inner.sessions.remove(&victim);
                }
                None => break, // sessions not in the LRU can't be chosen
            }
        }
    }
}

/// Lock-light serving counters: request outcomes and the queue-depth
/// gauge as atomics, per-request latencies and per-workload served counts
/// under mutexes (drained by `stats` requests and the serving bench).
///
/// Counter taxonomy (each failed request increments **exactly one**):
/// * `served` — successful `query` responses.
/// * `errors` — error responses on an established connection (bad
///   request, unknown workload, snapshot/internal failures).
/// * `rejected` — typed `busy` refusals (full queue / connection cap).
/// * `timeouts` — requests that exceeded their deadline.
pub struct ServerStats {
    served: AtomicUsize,
    errors: AtomicUsize,
    rejected: AtomicUsize,
    timeouts: AtomicUsize,
    reloads: AtomicUsize,
    accept_errors: AtomicUsize,
    /// Connections accepted but not yet picked up by a pool worker.
    queue_depth: AtomicUsize,
    latencies_ms: Mutex<Vec<f64>>,
    per_workload: Mutex<HashMap<String, usize>>,
    started: Instant,
}

impl Default for ServerStats {
    fn default() -> Self {
        ServerStats::new()
    }
}

impl ServerStats {
    pub fn new() -> Self {
        ServerStats {
            served: AtomicUsize::new(0),
            errors: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            timeouts: AtomicUsize::new(0),
            reloads: AtomicUsize::new(0),
            accept_errors: AtomicUsize::new(0),
            queue_depth: AtomicUsize::new(0),
            latencies_ms: Mutex::new(Vec::new()),
            per_workload: Mutex::new(HashMap::new()),
            started: Instant::now(),
        }
    }

    /// Record one successfully answered query against `workload`.
    pub fn record(&self, workload: &str, latency_ms: f64) {
        self.served.fetch_add(1, Ordering::Relaxed);
        self.latencies_ms.lock().unwrap().push(latency_ms);
        *self.per_workload.lock().unwrap().entry(workload.to_string()).or_insert(0) += 1;
    }

    /// Record one failed request (parse error, unknown workload, …).
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one typed `busy` refusal.
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request that exceeded its deadline.
    pub fn record_timeout(&self) {
        self.timeouts.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one completed hot reload.
    pub fn record_reload(&self) {
        self.reloads.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one accept-loop failure (the loop retries with backoff).
    pub fn record_accept_error(&self) {
        self.accept_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// A connection entered the pending queue.
    pub fn queue_arrived(&self) {
        self.queue_depth.fetch_add(1, Ordering::SeqCst);
    }

    /// A connection left the pending queue (picked up or refused).
    pub fn queue_departed(&self) {
        self.queue_depth.fetch_sub(1, Ordering::SeqCst);
    }

    pub fn served(&self) -> usize {
        self.served.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> usize {
        self.errors.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> usize {
        self.rejected.load(Ordering::Relaxed)
    }

    pub fn timeouts(&self) -> usize {
        self.timeouts.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::SeqCst)
    }

    /// Served counts per workload (sorted by name).
    pub fn workload_counts(&self) -> Vec<(String, usize)> {
        let mut counts: Vec<(String, usize)> = self
            .per_workload
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect();
        counts.sort();
        counts
    }

    /// The busy response's `retry_after_ms` hint: the median observed
    /// service latency times the queue occupancy ahead of a retrying
    /// client, clamped to a sane range (50 ms/request before any query
    /// has completed).
    pub fn retry_hint_ms(&self, queued: usize) -> u64 {
        let per_request = {
            let lat = self.latencies_ms.lock().unwrap();
            if lat.is_empty() {
                50.0
            } else {
                let mut sorted = lat.clone();
                sorted.sort_by(f64::total_cmp);
                percentile(&sorted, 50.0).max(1.0)
            }
        };
        ((per_request * queued.max(1) as f64) as u64).clamp(10, 5_000)
    }

    /// Throughput + latency percentiles since construction.
    pub fn summary(&self) -> StatsSummary {
        let mut lat = self.latencies_ms.lock().unwrap().clone();
        lat.sort_by(f64::total_cmp);
        let elapsed = self.started.elapsed().as_secs_f64();
        let served = self.served();
        StatsSummary {
            served,
            errors: self.errors(),
            rejected: self.rejected(),
            timeouts: self.timeouts(),
            reloads: self.reloads.load(Ordering::Relaxed),
            queue_depth: self.queue_depth(),
            queries_per_sec: if elapsed > 0.0 {
                served as f64 / elapsed
            } else {
                0.0
            },
            p50_ms: percentile(&lat, 50.0),
            p99_ms: percentile(&lat, 99.0),
        }
    }
}

/// One point-in-time view of [`ServerStats`].
#[derive(Debug, Clone, Copy)]
pub struct StatsSummary {
    pub served: usize,
    pub errors: usize,
    pub rejected: usize,
    pub timeouts: usize,
    pub reloads: usize,
    pub queue_depth: usize,
    pub queries_per_sec: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Nearest-rank percentile over an ascending-sorted slice (`NaN` when
/// empty). Shared by the stats endpoint and the serving bench.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Daemon sizing and robustness knobs (every field has a CLI flag — see
/// `hwsplit serve` in `usage.txt` and the semantics in `docs/serving.md`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Fixed worker-pool width (`--serve-workers`). `0` selects the
    /// legacy thread-per-connection path, hard-capped at
    /// [`ServeConfig::max_connections`].
    pub workers: usize,
    /// Bound on connections accepted but not yet picked up by a worker
    /// (`--queue-depth`); past it the acceptor answers `busy`.
    pub queue_depth: usize,
    /// Per-request deadline in milliseconds (`--request-timeout-ms`);
    /// `0` disables deadlines. Also bounds socket writes.
    pub request_timeout_ms: u64,
    /// Legacy-path concurrent-connection hard cap (`--max-connections`).
    pub max_connections: usize,
    /// Optional marker file (`--reload-marker`): when its mtime changes
    /// (or it appears), the next accepted connection triggers a hot
    /// snapshot reload, same as the `reload` command.
    pub reload_marker: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: crate::par::default_workers(),
            queue_depth: 64,
            request_timeout_ms: 10_000,
            max_connections: 256,
            reload_marker: None,
        }
    }
}

/// How often blocked reads/dequeues wake to observe the shutdown flag.
const POLL_INTERVAL: Duration = Duration::from_millis(100);
/// Consecutive accept failures tolerated (with backoff) before
/// [`Server::run`] surfaces a typed error instead of spinning.
const MAX_ACCEPT_ERROR_STREAK: u32 = 100;

/// The TCP daemon: a bounded accept loop feeding a fixed worker pool
/// (or, with `workers: 0`, the capped legacy thread-per-connection path).
pub struct Server {
    store: Arc<SessionStore>,
    stats: Arc<ServerStats>,
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
    config: ServeConfig,
}

impl Server {
    /// Bind to `addr` (e.g. `"127.0.0.1:7878"`; port 0 picks a free port —
    /// the tests do this) with the default [`ServeConfig`].
    pub fn bind(addr: &str, store: Arc<SessionStore>) -> Result<Server> {
        Server::bind_with(addr, store, ServeConfig::default())
    }

    /// Bind with explicit pool/timeout/reload configuration.
    pub fn bind_with(addr: &str, store: Arc<SessionStore>, config: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        Ok(Server {
            store,
            stats: Arc::new(ServerStats::new()),
            listener,
            shutdown: Arc::new(AtomicBool::new(false)),
            config,
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn stats(&self) -> Arc<ServerStats> {
        self.stats.clone()
    }

    pub fn config(&self) -> &ServeConfig {
        &self.config
    }

    /// Ask the accept loop to stop, nudging it out of `accept()` with a
    /// throwaway connection. Callable from any thread.
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Ok(addr) = self.listener.local_addr() {
            // Ignore failure: if the listener is already gone, done anyway.
            let _ = TcpStream::connect(addr);
        }
    }

    /// Run the daemon until [`Server::request_shutdown`] (or a client
    /// sends `{"cmd":"shutdown"}`). With `workers > 0` this is the
    /// bounded pool; `workers == 0` selects the legacy
    /// thread-per-connection path (hard-capped). Graceful shutdown stops
    /// accepting, lets in-progress requests finish, and closes
    /// connections still waiting in the queue unanswered.
    pub fn run(&self) -> Result<()> {
        if self.config.workers == 0 {
            self.run_legacy()
        } else {
            self.run_pool()
        }
    }

    fn run_pool(&self) -> Result<()> {
        let addr = self.listener.local_addr()?;
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(self.config.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let workers: Vec<_> = (0..self.config.workers)
            .map(|_| {
                let rx = rx.clone();
                let store = self.store.clone();
                let stats = self.stats.clone();
                let shutdown = self.shutdown.clone();
                let config = self.config.clone();
                std::thread::spawn(move || {
                    worker_loop(&rx, &store, &stats, &shutdown, &config, addr)
                })
            })
            .collect();

        let mut marker = MarkerWatch::new(self.config.reload_marker.clone());
        let mut err_streak = 0u32;
        for stream in self.listener.incoming() {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => {
                    err_streak = 0;
                    s
                }
                Err(e) => {
                    self.note_accept_error(&mut err_streak, &e)?;
                    continue;
                }
            };
            self.check_marker(&mut marker);
            // Gauge rises before the send so a racing dequeue can never
            // observe a decrement ahead of its increment.
            self.stats.queue_arrived();
            match tx.try_send(stream) {
                Ok(()) => {}
                Err(mpsc::TrySendError::Full(stream)) => {
                    self.stats.queue_departed();
                    self.reject_busy(stream, self.config.queue_depth);
                }
                Err(mpsc::TrySendError::Disconnected(_)) => break,
            }
        }
        // Dropping the sender drains the pool: idle workers observe the
        // disconnect, busy workers finish their connection first.
        drop(tx);
        for w in workers {
            let _ = w.join();
        }
        Ok(())
    }

    /// The legacy thread-per-connection path (`--serve-workers 0`), kept
    /// for comparison benches — now refusing connections past
    /// `max_connections` with the same typed busy error instead of
    /// spawning without bound.
    fn run_legacy(&self) -> Result<()> {
        let addr = self.listener.local_addr()?;
        let active = Arc::new(AtomicUsize::new(0));
        let mut marker = MarkerWatch::new(self.config.reload_marker.clone());
        let mut err_streak = 0u32;
        for stream in self.listener.incoming() {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => {
                    err_streak = 0;
                    s
                }
                Err(e) => {
                    self.note_accept_error(&mut err_streak, &e)?;
                    continue;
                }
            };
            self.check_marker(&mut marker);
            if active.load(Ordering::SeqCst) >= self.config.max_connections {
                let queued = active.load(Ordering::SeqCst);
                self.reject_busy(stream, queued);
                continue;
            }
            active.fetch_add(1, Ordering::SeqCst);
            let guard = ActiveGuard(active.clone());
            let store = self.store.clone();
            let stats = self.stats.clone();
            let shutdown = self.shutdown.clone();
            let config = self.config.clone();
            std::thread::spawn(move || {
                let _guard = guard; // decrements even if the handler panics
                let _ = serve_connection(stream, &store, &stats, &shutdown, &config, addr);
            });
        }
        Ok(())
    }

    /// Accept failures back off and count; a persistent streak becomes a
    /// typed error from `run` instead of a hot spin or a silent return.
    fn note_accept_error(&self, streak: &mut u32, e: &std::io::Error) -> Result<()> {
        self.stats.record_accept_error();
        *streak += 1;
        if *streak >= MAX_ACCEPT_ERROR_STREAK {
            return Err(Error::Io(format!(
                "accept loop failing persistently ({streak} consecutive errors): {e}"
            )));
        }
        std::thread::sleep(Duration::from_millis(10));
        Ok(())
    }

    fn check_marker(&self, marker: &mut MarkerWatch) {
        if marker.changed() {
            match self.store.reload() {
                Ok(_) => self.stats.record_reload(),
                Err(e) => {
                    self.stats.record_error();
                    eprintln!("serve: marker-triggered reload failed ({e}); serving old snapshots");
                }
            }
        }
    }

    /// Typed backpressure: answer one `busy` line (bounded write) and
    /// close the connection.
    fn reject_busy(&self, mut stream: TcpStream, queued: usize) {
        self.stats.record_rejected();
        let hint = self.stats.retry_hint_ms(queued);
        let err = Error::Busy { queued, retry_after_ms: hint };
        let resp = error_response(
            ErrorCode::Busy,
            &err.to_string(),
            &[("retry_after_ms", JsonValue::Int(hint as i64))],
        );
        let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
        if stream.write_all(resp.as_bytes()).is_ok() {
            let _ = stream.write_all(b"\n");
        }
        // Lingering close: the refused client's request is still unread in
        // our receive buffer, and closing with unread data sends RST —
        // which can race the busy line off the client's socket. Send FIN,
        // then drain briefly until the client closes, so the reply is
        // reliably delivered. Bounded: a client that neither sends nor
        // closes costs the acceptor at most the read timeout.
        let _ = stream.shutdown(std::net::Shutdown::Write);
        let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
        let mut sink = [0u8; 512];
        while let Ok(n) = std::io::Read::read(&mut stream, &mut sink) {
            if n == 0 {
                break;
            }
        }
    }
}

/// Decrements the legacy path's active-connection count on drop, so a
/// panicking handler can't leak a slot.
struct ActiveGuard(Arc<AtomicUsize>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Watches the optional reload-marker file for mtime changes (or
/// appearance). The initial state is whatever exists at startup, so a
/// pre-existing marker does not trigger a spurious reload.
struct MarkerWatch {
    path: Option<PathBuf>,
    last: Option<SystemTime>,
}

impl MarkerWatch {
    fn new(path: Option<PathBuf>) -> MarkerWatch {
        let last = path.as_deref().and_then(mtime);
        MarkerWatch { path, last }
    }

    fn changed(&mut self) -> bool {
        let Some(path) = self.path.as_deref() else {
            return false;
        };
        let now = mtime(path);
        if now.is_some() && now != self.last {
            self.last = now;
            true
        } else {
            false
        }
    }
}

fn mtime(p: &Path) -> Option<SystemTime> {
    std::fs::metadata(p).ok().and_then(|m| m.modified().ok())
}

/// One pool worker: dequeue a connection, serve it to completion, repeat.
/// Dequeues poll so the worker observes shutdown while idle; the sender
/// disconnecting (acceptor exit) drains the pool.
fn worker_loop(
    rx: &Mutex<mpsc::Receiver<TcpStream>>,
    store: &SessionStore,
    stats: &ServerStats,
    shutdown: &AtomicBool,
    config: &ServeConfig,
    listener_addr: SocketAddr,
) {
    loop {
        let stream = {
            let rx = rx.lock().unwrap();
            match rx.recv_timeout(POLL_INTERVAL) {
                Ok(s) => s,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        };
        stats.queue_departed();
        let _ = serve_connection(stream, store, stats, shutdown, config, listener_addr);
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
    }
}

/// Serve one connection: read line-delimited requests until EOF (or a
/// shutdown request). Request-level failures answer a typed error object
/// and keep the connection; only I/O failures end it. Reads poll on
/// [`POLL_INTERVAL`] so an idle connection observes shutdown (partial
/// lines survive the poll — `read_line` appends); writes are bounded by
/// the request timeout so a stuck client can't wedge a worker.
fn serve_connection(
    stream: TcpStream,
    store: &SessionStore,
    stats: &ServerStats,
    shutdown: &AtomicBool,
    config: &ServeConfig,
    listener_addr: SocketAddr,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(POLL_INTERVAL))?;
    let write_ms = if config.request_timeout_ms > 0 {
        config.request_timeout_ms
    } else {
        10_000
    };
    stream.set_write_timeout(Some(Duration::from_millis(write_ms)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue; // idle poll; `line` keeps any partial request
            }
            Err(e) => return Err(e),
        }
        let trimmed = line.trim();
        if !trimmed.is_empty() {
            // The deadline clock starts when the full request line is in.
            let ctx = RequestCtx {
                deadline: (config.request_timeout_ms > 0)
                    .then(|| Instant::now() + Duration::from_millis(config.request_timeout_ms)),
                timeout_ms: config.request_timeout_ms,
            };
            let (response, stop) = handle_line_at(trimmed, store, stats, &ctx);
            writer.write_all(response.as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            if stop {
                shutdown.store(true, Ordering::SeqCst);
                let _ = TcpStream::connect(listener_addr); // nudge the acceptor
                return Ok(());
            }
        }
        line.clear();
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
    }
}

/// Per-request context: the deadline derived from `--request-timeout-ms`
/// at request receipt (None = no deadline) plus the configured budget,
/// echoed in timeout responses.
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestCtx {
    pub deadline: Option<Instant>,
    pub timeout_ms: u64,
}

/// Answer one request line with no deadline (the CLI's one-shot mode and
/// tests). See [`handle_line_at`].
pub fn handle_line(line: &str, store: &SessionStore, stats: &ServerStats) -> (String, bool) {
    handle_line_at(line, store, stats, &RequestCtx::default())
}

/// Answer one request line under a request context. Returns the JSON
/// response and whether this request asked the daemon to shut down.
/// Never panics on bad input — every failure becomes a typed
/// `{"ok":false,"code":...}` response and increments exactly one of the
/// error/timeout/rejected counters (see [`ServerStats`]).
pub fn handle_line_at(
    line: &str,
    store: &SessionStore,
    stats: &ServerStats,
    ctx: &RequestCtx,
) -> (String, bool) {
    match handle_request(line, store, stats, ctx) {
        Ok(reply) => reply,
        Err(e) => {
            let code = ErrorCode::classify(&e);
            let mut extra: Vec<(&str, JsonValue)> = Vec::new();
            match code {
                ErrorCode::Timeout => {
                    stats.record_timeout();
                    if ctx.timeout_ms > 0 {
                        extra.push(("timeout_ms", JsonValue::Int(ctx.timeout_ms as i64)));
                    }
                }
                ErrorCode::Busy => {
                    stats.record_rejected();
                    if let Error::Busy { retry_after_ms, .. } = &e {
                        extra.push(("retry_after_ms", JsonValue::Int(*retry_after_ms as i64)));
                    }
                }
                _ => stats.record_error(),
            }
            (error_response(code, &e.to_string(), &extra), false)
        }
    }
}

fn handle_request(
    line: &str,
    store: &SessionStore,
    stats: &ServerStats,
    ctx: &RequestCtx,
) -> Result<(String, bool)> {
    let req = Json::parse(line).map_err(|e| Error::InvalidConfig(format!("bad request: {e}")))?;
    let cmd_name = req.get("cmd").and_then(Json::as_str).unwrap_or("query");
    let cmd = Command::parse(cmd_name).ok_or_else(|| {
        Error::InvalidConfig(format!("unknown cmd '{cmd_name}' (expected {})", Command::names()))
    })?;
    match cmd {
        Command::Ping => Ok(("{\"ok\":true,\"pong\":true}".to_string(), false)),
        Command::Shutdown => Ok(("{\"ok\":true,\"shutting_down\":true}".to_string(), true)),
        Command::Reload => {
            let names = store.reload()?;
            stats.record_reload();
            let fields = [
                ("reloaded", JsonValue::Str(names.join(","))),
                ("generation", JsonValue::Int(store.generation() as i64)),
            ];
            Ok((ok_response(&fields), false))
        }
        Command::Stats => {
            let s = stats.summary();
            let by_workload = stats
                .workload_counts()
                .into_iter()
                .map(|(w, n)| format!("{w}={n}"))
                .collect::<Vec<_>>()
                .join(",");
            let fields = [
                ("served", JsonValue::Int(s.served as i64)),
                ("errors", JsonValue::Int(s.errors as i64)),
                ("rejected", JsonValue::Int(s.rejected as i64)),
                ("timeouts", JsonValue::Int(s.timeouts as i64)),
                ("reloads", JsonValue::Int(s.reloads as i64)),
                ("queue_depth", JsonValue::Int(s.queue_depth as i64)),
                ("queries_per_sec", JsonValue::Num(s.queries_per_sec)),
                ("p50_ms", JsonValue::Num(s.p50_ms)),
                ("p99_ms", JsonValue::Num(s.p99_ms)),
                ("cached_sessions", JsonValue::Int(store.cached_count() as i64)),
                ("generation", JsonValue::Int(store.generation() as i64)),
                ("workloads", JsonValue::Str(store.workloads().join(","))),
                ("served_by_workload", JsonValue::Str(by_workload)),
            ];
            Ok((ok_response(&fields), false))
        }
        Command::Query => {
            let workload = req
                .get("workload")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::InvalidConfig("query needs a 'workload' field".into()))?;
            let objective: Objective = req
                .get("objective")
                .and_then(Json::as_str)
                .unwrap_or("latency")
                .parse()?;
            let samples = req
                .get("samples")
                .map(|v| {
                    v.as_u64().ok_or_else(|| {
                        Error::InvalidConfig("'samples' must be a non-negative integer".into())
                    })
                })
                .transpose()?
                .unwrap_or(16) as usize;
            let seed = req
                .get("seed")
                .map(|v| {
                    v.as_u64().ok_or_else(|| {
                        Error::InvalidConfig("'seed' must be a non-negative integer".into())
                    })
                })
                .transpose()?
                .unwrap_or(0);
            let session = store.get(workload)?;
            let t0 = Instant::now();
            let mut q = Query::new().objective(objective).samples(samples).seed(seed);
            q.deadline = ctx.deadline;
            let ev = session.answer_query(&q)?;
            let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
            stats.record(workload, latency_ms);
            Ok((query_response(&ev, latency_ms), false))
        }
    }
}

fn objective_name(o: Objective) -> &'static str {
    match o {
        Objective::Latency => "latency",
        Objective::Area => "area",
        Objective::Balanced(_) => "balanced",
    }
}

fn query_response(ev: &Evaluation, latency_ms: f64) -> String {
    let best = ev.best();
    let fields = [
        ("workload", JsonValue::Str(ev.workload.clone())),
        ("objective", JsonValue::Str(objective_name(ev.objective).to_string())),
        ("designs", JsonValue::Int(ev.designs.len() as i64)),
        ("frontier", JsonValue::Int(ev.frontier.len() as i64)),
        ("best_area", JsonValue::Num(best.map_or(f64::NAN, |d| d.point.cost.area))),
        ("best_latency", JsonValue::Num(best.map_or(f64::NAN, |d| d.point.cost.latency))),
        ("memo_hits", JsonValue::Int(ev.extract.memo_hits as i64)),
        ("memo_misses", JsonValue::Int(ev.extract.memo_misses as i64)),
        ("latency_ms", JsonValue::Num(latency_ms)),
    ];
    ok_response(&fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::workloads;
    use crate::rewrites::RuleSet;

    fn tiny_session() -> Arc<Session> {
        let mut s = Session::builder()
            .workload(workloads::relu128())
            .rules(RuleSet::Fig2)
            .iters(4)
            .workers(2)
            .build()
            .unwrap();
        s.enumerate().unwrap();
        Arc::new(s)
    }

    #[test]
    fn handle_line_answers_query_and_isolates_errors() {
        let store = SessionStore::new(4);
        store.insert_session("relu128", tiny_session());
        let stats = ServerStats::new();
        // Malformed line: error response, connection-level state untouched.
        let (bad, stop) = handle_line("not json", &store, &stats);
        assert!(bad.starts_with("{\"ok\":false"));
        assert!(bad.contains("\"code\":\"bad_request\""), "{bad}");
        assert!(!stop);
        assert_eq!(stats.errors(), 1);
        // Unknown workload: typed error surfaced, not a panic.
        let (unknown, _) = handle_line(r#"{"cmd":"query","workload":"nope"}"#, &store, &stats);
        assert!(unknown.contains("unknown workload"), "{unknown}");
        assert!(unknown.contains("\"code\":\"unknown_workload\""), "{unknown}");
        // Unknown command: bad_request naming the valid set.
        let (what, _) = handle_line(r#"{"cmd":"frobnicate"}"#, &store, &stats);
        assert!(what.contains("\"code\":\"bad_request\""), "{what}");
        assert!(what.contains("reload"), "must list valid commands: {what}");
        // Valid query answers with design counts.
        let (good, stop) =
            handle_line(r#"{"workload":"relu128","samples":4,"seed":1}"#, &store, &stats);
        assert!(!stop);
        let parsed = Json::parse(&good).expect("response is valid JSON");
        assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true));
        assert!(parsed.get("designs").and_then(Json::as_u64).unwrap() >= 2);
        assert_eq!(parsed.get("workload").and_then(Json::as_str), Some("relu128"));
        assert_eq!(stats.served(), 1);
        // Stats reflect the traffic, including per-workload counts.
        let (stats_resp, _) = handle_line(r#"{"cmd":"stats"}"#, &store, &stats);
        let s = Json::parse(&stats_resp).unwrap();
        assert_eq!(s.get("served").and_then(Json::as_u64), Some(1));
        assert_eq!(s.get("errors").and_then(Json::as_u64), Some(3));
        assert_eq!(s.get("rejected").and_then(Json::as_u64), Some(0));
        assert_eq!(s.get("timeouts").and_then(Json::as_u64), Some(0));
        assert_eq!(s.get("queue_depth").and_then(Json::as_u64), Some(0));
        assert_eq!(s.get("served_by_workload").and_then(Json::as_str), Some("relu128=1"));
    }

    #[test]
    fn expired_deadline_is_a_typed_timeout_with_exact_counters() {
        let store = SessionStore::new(4);
        store.insert_session("relu128", tiny_session());
        let stats = ServerStats::new();
        let ctx = RequestCtx {
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            timeout_ms: 1,
        };
        let (resp, stop) =
            handle_line_at(r#"{"workload":"relu128","samples":4}"#, &store, &stats, &ctx);
        assert!(!stop);
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false), "{resp}");
        assert_eq!(j.get("code").and_then(Json::as_str), Some("timeout"), "{resp}");
        assert_eq!(j.get("timeout_ms").and_then(Json::as_u64), Some(1), "{resp}");
        // Exactly one counter moved.
        assert_eq!(stats.timeouts(), 1);
        assert_eq!(stats.errors(), 0);
        assert_eq!(stats.served(), 0);
        assert_eq!(stats.rejected(), 0);
    }

    #[test]
    fn shutdown_command_signals_stop() {
        let store = SessionStore::new(1);
        let stats = ServerStats::new();
        let (resp, stop) = handle_line(r#"{"cmd":"shutdown"}"#, &store, &stats);
        assert!(stop);
        assert!(resp.contains("shutting_down"));
    }

    #[test]
    fn lru_store_never_exceeds_bound() {
        let store = SessionStore::new(2);
        store.insert_session("a", tiny_session());
        store.insert_session("b", tiny_session());
        store.insert_session("c", tiny_session());
        assert_eq!(store.cached_count(), 2);
        // "a" was least recently used — evicted first.
        assert!(store.get("a").is_err(), "evicted and unregistered: must miss");
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn retry_hint_clamp_holds_at_queue_position_zero_and_extremes() {
        // Queue position 0 prices like position 1 (`queued.max(1)`), and
        // the documented 10..=5000 ms clamp holds at every extreme.
        let stats = ServerStats::new();
        assert_eq!(stats.retry_hint_ms(0), 50, "no samples yet: the 50 ms default");
        stats.record("w", 1e9); // pathological latency sample
        assert_eq!(stats.retry_hint_ms(0), 5_000, "upper clamp at queue position 0");
        assert_eq!(stats.retry_hint_ms(usize::MAX), 5_000, "upper clamp at extreme occupancy");
        let stats = ServerStats::new();
        stats.record("w", 0.0); // zero-latency sample exercises the floor
        assert_eq!(stats.retry_hint_ms(0), 10, "lower clamp at queue position 0");
    }

    #[test]
    fn retry_hint_scales_with_queue_and_clamps() {
        let stats = ServerStats::new();
        // No latency data yet: 50 ms/request default.
        assert_eq!(stats.retry_hint_ms(1), 50);
        stats.record("w", 100.0);
        assert_eq!(stats.retry_hint_ms(2), 200);
        assert_eq!(stats.retry_hint_ms(1_000_000), 5_000, "clamped above");
        stats.record("w", 0.001); // tiny latencies clamp below
        let hint = stats.retry_hint_ms(1);
        assert!(hint >= 10, "{hint}");
    }
}
